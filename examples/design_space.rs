//! Design-space exploration, closed-loop: the Fig. 9 sweep driven by
//! the autotuner instead of a hand-enumerated grid.
//!
//! Part A re-derives the classic exhaustive sweep — every (algorithm,
//! square MXU size) point that fits the SX 660 at an 8-bit datapath,
//! scored in projected inferences/second on ResNet-50 — **independently
//! of the tuner**, straight from the public analytical models
//! (`sched::plan_layer` + `sched::timing` cycles over
//! `fpga::frequency` clocks, feasibility from `fpga::resources`).  The
//! tuner, restricted to the same axes (uniform algorithm, pinned
//! batch, one replica), must land on a point **in** that sweep and
//! **dominate** every point of it — the self-check that the search
//! really optimizes the model it claims to.
//!
//! Part B releases the remaining axes (per-layer algorithm mix, batch
//! depth) and prints the winning [`TunedPlan`] report with its
//! per-layer breakdown and projected-vs-heuristic comparison.
//!
//! Run: `cargo run --release --example design_space`

use ffip::algo::Algo;
use ffip::arith::FixedSpec;
use ffip::fpga::{self, Device};
use ffip::mxu::LoaderKind;
use ffip::nn::{models, GemmShape, Graph};
use ffip::sched::{plan_layer, timing, LAYER_REPROGRAM_CYCLES, STREAM_BATCH};
use ffip::tune::{tune_graph, TuneBudget};

/// Projected seconds per image of a uniform-algorithm deployment at a
/// square `s x s` MXU — the sweep's objective, computed from the
/// public analytical models only (deliberately *not* via the tuner,
/// so the assertions below compare two independent derivations).
fn sweep_seconds_per_image(
    graph: &Graph,
    algo: Algo,
    s: usize,
    batch: usize,
    fmax_mhz: f64,
) -> f64 {
    let mut micros = 0.0f64;
    for layer in &graph.layers {
        for g in layer.gemms() {
            let gb = GemmShape { m: g.m * batch, ..g };
            let plan = plan_layer(gb, algo, s, s, LoaderKind::Localized);
            let t = timing::gemm_cycles(gb, &plan.cfg);
            let cycles = t.cycles.div_ceil(batch as u64)
                + LAYER_REPROGRAM_CYCLES.div_ceil(batch as u64);
            micros += cycles as f64 / fmax_mhz;
        }
    }
    micros * 1e-6
}

fn main() {
    let sx = Device::arria10_sx660();
    let spec = FixedSpec::signed(8);
    let graph = models::resnet50();
    let batch = STREAM_BATCH;

    // -- Part A: the exhaustive sweep, derived independently ------------
    println!(
        "## Fig. 9-style sweep: {} on {} (8-bit datapath, batch {batch})\n",
        graph.name, sx.name
    );
    println!(
        "{:>4}  {:>10} {:>10} {:>10}   projected inf/s ('-': does not fit)",
        "s", "baseline", "FIP", "FFIP"
    );
    let cap = Algo::ALL
        .iter()
        .map(|&a| fpga::max_square_mxu(a, spec, &sx))
        .max()
        .unwrap();
    let mut points: Vec<(Algo, usize, f64)> = Vec::new();
    for s in (8..=cap).step_by(8) {
        let mut cells = Vec::new();
        for &algo in Algo::ALL.iter() {
            let u = fpga::estimate(algo, spec, s, s, &sx);
            if !u.fits {
                cells.push(format!("{:>10}", "-"));
                continue;
            }
            let f = fpga::fmax_mhz(algo, spec, s, s, &sx);
            let sec = sweep_seconds_per_image(&graph, algo, s, batch, f);
            points.push((algo, s, sec));
            cells.push(format!("{:>10.2}", 1.0 / sec));
        }
        println!("{s:>4}  {} {} {}", cells[0], cells[1], cells[2]);
    }
    let &(best_algo, best_s, best_sec) = points
        .iter()
        .min_by(|a, b| a.2.total_cmp(&b.2))
        .expect("some point fits");
    println!(
        "\nsweep winner: {} {best_s}x{best_s} at {:.2} inf/s",
        best_algo.name(),
        1.0 / best_sec
    );

    // -- the tuner on the same axes must land on and dominate the sweep
    let uniform = TuneBudget::new(sx)
        .uniform_algos()
        .with_batch(batch)
        .with_max_replicas(1);
    let plan_a = tune_graph(&graph, 8, &uniform).expect("fits the SX 660");
    let algo_a = plan_a.layers[0].algo;
    assert!(
        plan_a.layers.iter().all(|l| l.algo == algo_a),
        "uniform-only budget must produce a uniform plan"
    );
    assert!(
        points.iter().any(|&(a, s, _)| a == algo_a && s == plan_a.x),
        "tuner chose ({}, {}) which the sweep never scored",
        algo_a.name(),
        plan_a.x
    );
    for &(a, s, sec) in &points {
        assert!(
            plan_a.score.seconds_per_image <= sec * (1.0 + 1e-9),
            "sweep point ({}, {s}) beats the tuner: {sec} vs {}",
            a.name(),
            plan_a.score.seconds_per_image
        );
    }
    let rel = (plan_a.score.seconds_per_image - best_sec).abs() / best_sec;
    assert!(
        rel < 1e-9,
        "tuner score {} != independent sweep winner {best_sec}",
        plan_a.score.seconds_per_image
    );
    println!(
        "tuner (sweep axes):  {} {}x{} at {:.2} inf/s -- matches the \
         sweep winner [self-check OK]",
        algo_a.name(),
        plan_a.x,
        plan_a.y,
        plan_a.score.throughput
    );

    // -- Part B: release the per-layer and batch axes -------------------
    let plan_b =
        tune_graph(&graph, 8, &TuneBudget::new(sx)).expect("fits the SX 660");
    assert!(
        plan_b.score.throughput >= plan_a.score.throughput * (1.0 - 1e-12),
        "freeing axes can never lose: {} vs {}",
        plan_b.score.throughput,
        plan_a.score.throughput
    );
    assert!(
        plan_b.speedup() >= 1.0,
        "the tuned plan must dominate the fixed heuristic"
    );
    println!("\n{}", plan_b.report());
    println!(
        "(free per-layer/batch axes vs the sweep's best uniform point: \
         {:+.1}%)",
        (plan_b.score.throughput / plan_a.score.throughput - 1.0) * 100.0
    );
}
