//! Design-space exploration: the Fig. 9 sweep plus what-if questions the
//! paper's §6.1 answers — how large an MXU fits each device, and what
//! each algorithm's fmax/DSP/throughput trade looks like across sizes
//! and bitwidths.
//!
//! Run: `cargo run --release --example design_space`

use ffip::algo::Algo;
use ffip::arith::FixedSpec;
use ffip::fpga::{self, Device};
use ffip::report::experiments;

fn main() {
    let sx = Device::arria10_sx660();
    let gx = Device::arria10_gx1150();

    // -- Fig. 9 on the paper's validation device -----------------------
    let (table, charts) = experiments::fig9(&sx, 8);
    println!("{}", table.render());
    for c in &charts[..3] {
        println!("{c}");
    }

    // -- largest fitting MXU per device / algorithm / bitwidth ---------
    println!("## Largest square MXU that fits (multiples of 8)\n");
    println!("device            w    baseline  FIP   FFIP");
    for dev in [&sx, &gx] {
        for w in [8u32, 16] {
            let spec = FixedSpec::signed(w);
            let row: Vec<usize> = Algo::ALL
                .iter()
                .map(|&a| fpga::max_square_mxu(a, spec, dev))
                .collect();
            println!(
                "{:<16} {:>2}    {:>5}     {:>4}  {:>4}",
                dev.name, w, row[0], row[1], row[2]
            );
        }
    }
    println!(
        "\n(§6.1 headline: 56x56 baseline -> 80x80 (F)FIP on the SX 660, \
         >2x effective PEs)"
    );

    // -- the d-penalty: same vs mixed signedness (§4.4) ----------------
    println!("\n## Quantization signedness ablation (FFIP 64x64, GX 1150)\n");
    for (label, spec) in [
        ("both signed   (d=1)", FixedSpec::signed(8)),
        ("mixed sign    (d=2)", FixedSpec::mixed(8)),
    ] {
        let u = fpga::estimate(Algo::Ffip, spec, 64, 64, &gx);
        let f = fpga::fmax_mhz(Algo::Ffip, spec, 64, 64, &gx);
        println!(
            "  {label}: {:>6} ALMs  {:>6} regs  fmax {:>3.0} MHz",
            u.alms, u.registers, f
        );
    }
}
