//! Fault tolerance end to end: the deterministic fault plan injects
//! real damage into the serving engine — a corrupted accumulator, a
//! panicking kernel, a wedged worker, a fault that refuses to go away —
//! and the stack detects, recovers, or sheds *typed*, while every
//! served output stays bit-identical to a clean oracle.  The ABFT
//! checksums are exact over the integer datapath, so a trip is always a
//! real fault and a clean run provably trips nothing.
//!
//! Run: `cargo run --release --example fault_tolerance`

use ffip::algo::Algo;
use ffip::coordinator::{
    compile, DeployConfig, InferenceSession, Model, PostGemm, RequestError,
    Router, TensorView,
};
use ffip::engine::{FaultKind, FaultPlan, GemmPool};
use ffip::metrics::FaultMetrics;
use ffip::nn::models;
use ffip::quant::QuantScheme;
use std::sync::Arc;
use std::time::Duration;

fn main() {
    // -- a small requantized MLP and its fault-free oracle -------------
    let mut model = Model::random(models::mlp(&[8, 6, 4]), 0xF417, 3);
    for (idx, cout) in [6usize, 4].into_iter().enumerate() {
        model
            .set_post(
                idx,
                PostGemm {
                    bias: (0..cout as i64).map(|j| 3 - j).collect(),
                    scheme: QuantScheme::symmetric_signed(8, 1.0 / 32.0),
                    relu: idx == 0,
                },
            )
            .unwrap();
    }
    let input: Vec<i32> =
        (0..8).map(|i| (i % 5) as i32 - 2 + i32::from(i % 5 == 2)).collect();
    let cfg = DeployConfig::new(Algo::Ffip)
        .with_tile(4, 2)
        .with_batch(1)
        .with_linger(Duration::from_millis(1));
    let want = {
        let compiled = compile(&model, cfg).unwrap();
        let mut sess =
            InferenceSession::new(&compiled, Arc::new(GemmPool::new(1)));
        sess.infer_batch(TensorView::new(1, 8, &input)).unwrap().data
    };

    // -- act 1: a clean deployment trips nothing -----------------------
    let mut r = Router::with_engine(Arc::new(GemmPool::new(1)));
    r.deploy_model("clean", model.compile(cfg).unwrap()).unwrap();
    for _ in 0..3 {
        let out = r.infer("clean", input.clone()).unwrap();
        assert_eq!(out.output().data, want, "clean serve is bit-exact");
    }
    let clean = FaultMetrics::from_stats(&r.undeploy("clean").unwrap());
    assert!(!clean.any(), "zero false positives: {clean:?}");
    println!("clean run: 3 batches served, zero checksum trips");

    // -- act 2: a transient corruption heals silently ------------------
    // the plan flips one accumulator block once; the post-drain ABFT
    // pass catches the bad rowsum and the scalar-oracle recompute heals
    // the GEMM in place — the caller never sees an error
    r.deploy_model(
        "heal",
        model
            .compile(
                cfg.with_fault_plan(FaultPlan::new(FaultKind::AccCorrupt)),
            )
            .unwrap(),
    )
    .unwrap();
    let out = r.infer("heal", input.clone()).unwrap();
    assert_eq!(
        out.output().data,
        want,
        "a healed transient fault must be invisible in the bits"
    );
    let m = FaultMetrics::from_stats(&r.undeploy("heal").unwrap());
    assert_eq!(m.injected, 1, "the plan fired exactly once");
    assert!(m.detected >= 1 && m.recovered == m.detected, "{m:?}");
    assert!(m.fully_healed(), "nothing shed, nothing panicked: {m:?}");
    println!(
        "transient AccCorrupt: {} injected, {} detected, {} healed — \
         output bit-exact",
        m.injected, m.detected, m.recovered
    );

    // -- act 3: a panicking kernel is contained, not fatal -------------
    r.deploy_model(
        "panic",
        model
            .compile(
                cfg.with_fault_plan(FaultPlan::new(FaultKind::PanicKernel)),
            )
            .unwrap(),
    )
    .unwrap();
    let first = r.infer("panic", input.clone()).unwrap();
    assert!(
        matches!(first.result, Err(RequestError::FaultDetected { .. })),
        "a poisoned job sheds typed, got {:?}",
        first.result
    );
    let second = r.infer("panic", input.clone()).unwrap();
    assert_eq!(second.output().data, want, "the deployment recovered");
    let m = FaultMetrics::from_stats(&r.undeploy("panic").unwrap());
    assert_eq!(m.fault_shed, 1, "{m:?}");
    println!(
        "transient PanicKernel: struck batch shed typed, next batch \
         bit-exact"
    );

    // -- act 4: a wedged worker resolves via the watchdog --------------
    r.deploy_model(
        "stall",
        model
            .compile(
                cfg.with_fault_plan(
                    FaultPlan::new(FaultKind::StallWorker)
                        .with_stall(Duration::from_millis(250)),
                )
                .with_request_deadline(Duration::from_millis(80)),
            )
            .unwrap(),
    )
    .unwrap();
    let first = r.infer("stall", input.clone()).unwrap();
    match first.result {
        Err(RequestError::DeadlineExceeded { waited_ms, deadline_ms }) => {
            println!(
                "transient StallWorker: watchdog expired the request \
                 after {waited_ms}ms (deadline {deadline_ms}ms) — no hang"
            );
        }
        other => panic!("expected a typed deadline expiry, got {other:?}"),
    }
    let second = r.infer("stall", input.clone()).unwrap();
    assert_eq!(second.output().data, want, "post-stall output");
    let m = FaultMetrics::from_stats(&r.undeploy("stall").unwrap());
    assert!(m.watchdog_trips >= 1, "{m:?}");

    // -- act 5: a persistent fault sheds only the struck requests ------
    // the recompute reproduces the corruption, so healing is impossible:
    // each request sheds typed and — crucially — releases its admission
    // slot, so a depth-2 bound never refuses the next request
    r.deploy_model(
        "persist",
        model
            .compile(
                cfg.with_max_queue_depth(2).with_fault_plan(
                    FaultPlan::new(FaultKind::AccCorrupt).persistent(),
                ),
            )
            .unwrap(),
    )
    .unwrap();
    for i in 0..4 {
        let resp = r.infer("persist", input.clone()).unwrap();
        assert!(
            matches!(resp.result, Err(RequestError::FaultDetected { .. })),
            "request {i}: an Overloaded here would mean a leaked slot: {:?}",
            resp.result
        );
    }
    let stats = r.undeploy("persist").unwrap();
    let m = FaultMetrics::from_stats(&stats);
    assert_eq!(m.fault_shed, 4, "{m:?}");
    assert_eq!(stats.shed, 0, "admission never refused a request");
    println!(
        "persistent AccCorrupt: 4 requests shed typed, 0 admission \
         refusals — every slot came back"
    );
    println!("[self-check OK]");
}
