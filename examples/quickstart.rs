//! Quickstart: the FFIP algorithm in five minutes.
//!
//! 1. compute a GEMM three ways (Eq. 1 baseline, Eq. 2 FIP, Eqs. 7-9
//!    FFIP) and check they agree bit-exactly;
//! 2. count operations (Eqs. 5-6): FIP/FFIP trade ~half the multiplies
//!    for cheap adds;
//! 3. run the same GEMM through the register-level MXU simulator and
//!    watch the cycle counts;
//! 4. ask the FPGA model what each architecture costs.
//!
//! Run: `cargo run --release --example quickstart`

use ffip::algo::{
    baseline_matmul, ffip_matmul, fip_matmul, op_counts, Algo, Mat,
};
use ffip::arith::FixedSpec;
use ffip::fpga::{self, Device};
use ffip::mxu::{MxuConfig, MxuSim};
use ffip::util::Rng;

fn main() {
    // -- 1. three algorithms, one answer -------------------------------
    let (m, k, n) = (48, 96, 32);
    let mut rng = Rng::new(2023);
    let a = Mat::from_fn(m, k, |_, _| rng.fixed(8, true));
    let b = Mat::from_fn(k, n, |_, _| rng.fixed(8, true));

    let c_base = baseline_matmul(&a, &b);
    let c_fip = fip_matmul(&a, &b);
    let c_ffip = ffip_matmul(&a, &b, n);
    assert_eq!(c_base, c_fip, "FIP must equal the baseline");
    assert_eq!(c_base, c_ffip, "FFIP must equal the baseline");
    println!("[1] baseline == FIP == FFIP on a {m}x{k} x {k}x{n} GEMM  OK");

    // -- 2. the arithmetic trade (Eqs. 5-6) ----------------------------
    println!("[2] operation counts for this GEMM:");
    for algo in Algo::ALL {
        let c = op_counts(m as u64, n as u64, k as u64, algo);
        println!(
            "    {:<8}: {:>7} mults, {:>7} adds (adds/mults = {:.2})",
            algo.name(),
            c.mults,
            c.adds,
            c.add_mult_ratio()
        );
    }

    // -- 3. the hardware, register by register -------------------------
    println!("[3] register-level MXU simulation (X=16, Y=8, Tm=16):");
    for algo in Algo::ALL {
        let mut sim = MxuSim::new(
            MxuConfig::new(algo, 16, 8, 16),
            FixedSpec::signed(8),
        );
        let (c, stats) = sim.gemm(&a, &b);
        assert_eq!(c, c_base);
        println!(
            "    {:<8}: exact OK  {:>5} cycles (pipelined), {:>6} multiplier activations",
            algo.name(),
            stats.cycles_pipelined,
            stats.mac_ops
        );
    }

    // -- 4. what it costs on an FPGA -----------------------------------
    let dev = Device::arria10_gx1150();
    let spec = FixedSpec::signed(8);
    println!("[4] 64x64 effective MXU on {}:", dev.name);
    for algo in Algo::ALL {
        let u = fpga::estimate(algo, spec, 64, 64, &dev);
        let f = fpga::fmax_mhz(algo, spec, 64, 64, &dev);
        println!(
            "    {:<8}: {:>4} DSPs, {:>6} ALMs, fmax {:>3.0} MHz{}",
            algo.name(),
            u.dsps,
            u.alms,
            f,
            if u.fits { "" } else { "   ** does not fit **" }
        );
    }
    println!("\nquickstart OK — see examples/resnet_inference.rs for the full system");
}
