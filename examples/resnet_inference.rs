//! **End-to-end driver** (EXPERIMENTS.md §E2E): quantized CNN inference
//! through every layer of the stack on a real small workload.
//!
//! Phase A — *real numerics through the AOT path*: batched requests flow
//! through the coordinator into the PJRT-compiled MiniCNN artifact
//! (Pallas FFIP kernels inside), demonstrating the request path with
//! Python nowhere on it; latency and throughput are measured.
//!
//! Phase B — *bit-exact accelerator simulation*: a quantized 6-layer CNN
//! (synthetic weights) runs conv-by-conv through the in-place conv→GEMM
//! tiler + the FFIP tiled MXU decomposition + the Post-GEMM requantizer,
//! and the logits are checked bit-for-bit against baseline arithmetic.
//!
//! Phase C — *paper workload*: ResNet-50 is timed layer-by-layer on the
//! modeled FFIP 64x64 @ Arria 10 GX 1150 accelerator and the Table 1
//! metrics are reported.
//!
//! Run: `cargo run --release --example resnet_inference`

use ffip::algo::{tiled_matmul, Algo, Mat, TileShape};
use ffip::arith::FixedSpec;
use ffip::coordinator::{
    BatcherConfig, Coordinator, DeployConfig, InferenceSession,
    LayerWeights, Model, PipelinedSession, PostGemm, TensorView,
};
use ffip::engine::GemmPool;
use ffip::fpga::{self, Device};
use ffip::memory::{ConvShape, Im2Gemm};
use ffip::metrics::PerfMetrics;
use ffip::nn::{models, Graph, Layer};
use ffip::quant::{fold_beta_into_bias, requantize_tile, QuantScheme};
use ffip::sched;
use ffip::util::Rng;
use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    phase_a_pjrt_serving()?;
    phase_b_bit_exact_cnn();
    phase_c_resnet50_metrics();
    println!("\nresnet_inference e2e OK");
    Ok(())
}

/// Phase A: 64 batched requests through coordinator -> PJRT MiniCNN.
fn phase_a_pjrt_serving() -> anyhow::Result<()> {
    println!("== Phase A: PJRT serving path (MiniCNN artifact) ==");
    let dir = std::env::var("FFIP_ARTIFACTS")
        .unwrap_or_else(|_| "artifacts".into());
    let manifest = ffip::runtime::Manifest::load(Path::new(&dir))?;
    let spec = manifest.get("mini_cnn_b4")?;
    let batch = spec.inputs[0].shape[0];
    let row = spec.inputs[0].numel() / batch;
    let dir2 = dir.clone();
    let c = Coordinator::start(
        move || {
            ffip::examples_support::MiniCnnBackend::new(Path::new(&dir2))
        },
        BatcherConfig {
            batch,
            linger: std::time::Duration::from_millis(2),
        },
    )?;
    let mut rng = Rng::new(1);
    let t0 = Instant::now();
    let n_req = 64;
    let rxs: Vec<_> = (0..n_req)
        .map(|_| {
            let input: Vec<i32> =
                (0..row).map(|_| rng.fixed(7, true) as i32).collect();
            c.submit(input)
        })
        .collect();
    let mut checksum = 0.0f64;
    for rx in rxs {
        let out = rx.recv()?.output();
        assert_eq!(out.data.len(), 10, "10 logits");
        assert!(out.data.iter().all(|v| v.is_finite()));
        checksum += f64::from(out.data[0]);
    }
    let wall = t0.elapsed();
    let s = c.shutdown();
    println!(
        "  {} requests in {:?}  ({:.0} req/s, batch occupancy {:.0}%)",
        n_req,
        wall,
        n_req as f64 / wall.as_secs_f64(),
        100.0 * s.occupancy()
    );
    println!(
        "  latency: p50 {:.2} ms  p99 {:.2} ms   (logit checksum {checksum:.3})",
        s.latency_pct_us(50.0) as f64 / 1e3,
        s.latency_pct_us(99.0) as f64 / 1e3
    );
    Ok(())
}

/// One quantized conv layer through the simulated accelerator.
struct QLayer {
    shape: ConvShape,
    weights: Mat<i64>,   // (K, N) GEMM form
    bias: Vec<i64>,
    bias_folded: Vec<i64>,
    scheme: QuantScheme,
}

fn qconv(
    rng: &mut Rng,
    shape: ConvShape,
    requant: f32,
) -> QLayer {
    let (_, k, n) = shape.gemm_dims();
    let weights = Mat::from_fn(k, n, |_, _| rng.fixed(6, true));
    let bias: Vec<i64> = (0..n).map(|_| rng.fixed(9, true)).collect();
    // Eq. 15: beta folded offline
    let bias_folded = fold_beta_into_bias(&bias, &weights);
    QLayer {
        shape,
        weights,
        bias,
        bias_folded,
        scheme: QuantScheme::symmetric_signed(8, requant),
    }
}

/// The same CNN as a deployable [`Model`]: conv layers with post-GEMM
/// requantization, ready for the `compile → InferenceSession` pipeline.
fn session_model(layers: &[&QLayer]) -> anyhow::Result<Model> {
    let graph = Graph {
        name: "qcnn".into(),
        layers: layers
            .iter()
            .enumerate()
            .map(|(i, l)| Layer::Conv {
                name: format!("conv{}", i + 1),
                shape: l.shape,
                groups: 1,
            })
            .collect(),
    };
    let weights = layers
        .iter()
        .map(|l| {
            Some(LayerWeights {
                w: l.weights.clone(),
                post: Some(PostGemm {
                    bias: l.bias.clone(),
                    scheme: l.scheme,
                    relu: true,
                }),
            })
        })
        .collect();
    Model::new(graph, weights)
}

fn run_layer(l: &QLayer, fm: &Mat<i64>, algo: Algo) -> Mat<i64> {
    let ig = Im2Gemm::new(l.shape, 64);
    // pad the feature map ring
    let s = &l.shape;
    let (ph, pw) = (s.h + 2 * s.pad, s.w + 2 * s.pad);
    let padded = Mat::from_fn(ph * pw, s.cin, |pos, c| {
        let (h, w) = (pos / pw, pos % pw);
        if h < s.pad || h >= s.h + s.pad || w < s.pad || w >= s.w + s.pad {
            0
        } else {
            fm[((h - s.pad) * s.w + (w - s.pad), c)]
        }
    });
    let a = ig.virtual_a(&padded);
    // the MXU computes c = A W exactly (beta handled via folding when
    // the FFIP datapath skips the beta subtraction; tiled_matmul's
    // reference algorithms subtract beta internally, so the folded bias
    // is re-expanded by beta — both give A W + bias)
    let acc = tiled_matmul(&a, &l.weights, algo, TileShape::square(64, 256));
    let beta = ffip::algo::beta_terms(&l.weights);
    let bias_full: Vec<i64> = l
        .bias_folded
        .iter()
        .zip(&beta)
        .map(|(bf, be)| bf + be)
        .collect();
    requantize_tile(&acc, &bias_full, &l.scheme, true)
}

/// Phase B: 3-conv quantized CNN, FFIP vs baseline, bit-exact.
fn phase_b_bit_exact_cnn() {
    println!("== Phase B: bit-exact simulated accelerator (3-conv CNN) ==");
    let mut rng = Rng::new(42);
    let l1 = qconv(
        &mut rng,
        ConvShape { h: 16, w: 16, cin: 4, cout: 16, kh: 3, kw: 3, stride: 1, pad: 1 },
        1.0 / 64.0,
    );
    let l2 = qconv(
        &mut rng,
        ConvShape { h: 16, w: 16, cin: 16, cout: 32, kh: 3, kw: 3, stride: 2, pad: 1 },
        1.0 / 128.0,
    );
    let l3 = qconv(
        &mut rng,
        ConvShape { h: 8, w: 8, cin: 32, cout: 32, kh: 3, kw: 3, stride: 2, pad: 1 },
        1.0 / 128.0,
    );

    let input = Mat::from_fn(16 * 16, 4, |_, _| rng.fixed(7, true));
    let t0 = Instant::now();
    let mut outs = Vec::new();
    for algo in Algo::ALL {
        let x1 = run_layer(&l1, &input, algo);
        let x2 = run_layer(&l2, &x1, algo);
        let x3 = run_layer(&l3, &x2, algo);
        outs.push(x3);
    }
    assert_eq!(outs[0], outs[1], "FIP != baseline");
    assert_eq!(outs[0], outs[2], "FFIP != baseline");
    println!(
        "  {} output activations bit-identical across baseline/FIP/FFIP ({:?})",
        outs[0].data.len(),
        t0.elapsed()
    );

    // the same CNN through the serving pipeline: compile the conv stack
    // (conv→GEMM lowering per layer) and run an InferenceSession on the
    // persistent pool — must reproduce the hand-rolled composition
    // bit-for-bit for every algorithm.  Every layer requantizes to the
    // 8-bit domain, so compile() selects i8 storage: the session
    // stages i8 activations and weights (i16 offline y, i32
    // accumulators) yet stays bit-exact with the wide oracle.
    let model = session_model(&[&l1, &l2, &l3]).expect("model builds");
    let row: Vec<i32> = input.data.iter().map(|&v| v as i32).collect();
    let pool = Arc::new(GemmPool::new(2));
    let mut storage = None;
    for algo in Algo::ALL {
        let cfg = DeployConfig::new(algo).with_tile(64, 64).with_batch(1);
        let compiled = model.compile(cfg).expect("compiles");
        storage = Some(compiled.storage());
        let mut sess = InferenceSession::new(&compiled, pool.clone());
        let out = sess
            .infer_batch(TensorView::new(1, row.len(), &row))
            .expect("session batch");
        let got: Vec<i64> = out.data.iter().map(|&v| v as i64).collect();
        assert_eq!(got, outs[0].data, "session ({}) != oracle", algo.name());
    }
    println!(
        "  InferenceSession (conv→GEMM on the engine pool, {} storage) \
         matches the oracle for all three algorithms",
        storage.expect("compiled at least once").name()
    );

    // the pipeline-overlapped executor on the same CNN: a 2-row batch
    // splits into two micro-batches whose im2gemm staging overlaps the
    // other's GEMM drain on the pool — and stays bit-exact with the
    // hand-rolled composition on both rows, for every algorithm
    let two_rows: Vec<i32> =
        row.iter().chain(row.iter()).copied().collect();
    for algo in Algo::ALL {
        let cfg = DeployConfig::new(algo).with_tile(64, 64).with_batch(2);
        let compiled = model.compile(cfg).expect("compiles");
        let mut pipe = PipelinedSession::new(&compiled, pool.clone());
        let out = pipe
            .infer_batch(TensorView::new(2, row.len(), &two_rows))
            .expect("pipelined batch");
        let got: Vec<i64> = out.data.iter().map(|&v| v as i64).collect();
        let want: Vec<i64> = outs[0]
            .data
            .iter()
            .chain(outs[0].data.iter())
            .copied()
            .collect();
        assert_eq!(got, want, "pipelined ({}) != oracle", algo.name());
    }
    println!(
        "  PipelinedSession (staging overlapped with GEMM drain) \
         reproduces the same logits bit-for-bit"
    );
}

/// Phase C: the paper's ResNet-50 row of Table 1.
fn phase_c_resnet50_metrics() {
    println!("== Phase C: ResNet-50 on modeled FFIP 64x64 @ GX 1150 ==");
    let dev = Device::arria10_gx1150();
    let spec = FixedSpec::signed(8);
    let g = models::resnet50();
    let util = fpga::estimate(Algo::Ffip, spec, 64, 64, &dev);
    let fmax = fpga::fmax_mhz(Algo::Ffip, spec, 64, 64, &dev);
    let nt = sched::network_timing(&g, Algo::Ffip, 64, 64, fmax);
    let m = PerfMetrics::from_measured(
        g.ops_per_inference(),
        nt.inferences_per_second(),
        util.multipliers,
        fmax,
    );
    println!(
        "  {} DSPs, fmax {:.0} MHz, {:.2} ms/inference",
        util.dsps,
        fmax,
        nt.seconds_per_inference() * 1e3
    );
    println!(
        "  {:.0} GOPS | {:.3} GOPS/mult | {:.3} ops/mult/cycle   (paper: 2529 | 1.180 | 3.042)",
        m.gops, m.gops_per_multiplier, m.ops_per_multiplier_per_cycle
    );
    // the paper's headline: exceed the baseline's theoretical roof of 2
    assert!(m.ops_per_multiplier_per_cycle > 2.0);
}
