//! Serving demo: an open-loop load generator against the coordinator,
//! sweeping offered load and reporting latency/throughput/occupancy —
//! the L3 stack behaving like a small model server.
//!
//! With AOT artifacts present (and the `pjrt` feature enabled) the
//! backend is the PJRT-compiled MiniCNN.  Otherwise the demo falls back
//! to the bit-exact simulated FFIP accelerator served through a
//! [`Router`] whose batch GEMMs run on the persistent worker pool
//! (`ffip::engine::GemmPool`) — the default path in this offline tree —
//! and additionally reports the pool's job/item/queue counters.
//!
//! Run: `cargo run --release --example serve`

use ffip::algo::{Algo, Mat, TileShape};
use ffip::coordinator::{BatcherConfig, Coordinator, Router};
use ffip::engine::GemmPool;
use ffip::metrics::PoolMetrics;
use ffip::util::Rng;
use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn main() -> anyhow::Result<()> {
    let dir = std::env::var("FFIP_ARTIFACTS")
        .unwrap_or_else(|_| "artifacts".into());
    match serve_pjrt(&dir) {
        Ok(()) => Ok(()),
        Err(e) => {
            println!(
                "PJRT backend unavailable ({e:#});\n\
                 falling back to the simulated FFIP accelerator on the \
                 persistent engine pool\n"
            );
            serve_sim()
        }
    }
}

/// Open-loop sweep against the PJRT MiniCNN backend.
fn serve_pjrt(dir: &str) -> anyhow::Result<()> {
    let manifest = ffip::runtime::Manifest::load(Path::new(dir))?;
    let spec = manifest.get("mini_cnn_b4")?;
    let batch = spec.inputs[0].shape[0];
    let row = spec.inputs[0].numel() / batch;

    println!(
        "open-loop load sweep over the PJRT MiniCNN backend (batch {batch})"
    );
    println!(
        "{:>9} {:>9} {:>10} {:>10} {:>10} {:>10}",
        "offered/s", "served/s", "p50 ms", "p99 ms", "batches", "occupancy"
    );

    for offered in [200u64, 500, 1000, 2000] {
        let dir2 = dir.to_string();
        let c = Coordinator::start(
            move || {
                ffip::examples_support::MiniCnnBackend::new(Path::new(
                    &dir2,
                ))
            },
            BatcherConfig {
                batch,
                linger: Duration::from_millis(2),
            },
        )?;
        let mut rng = Rng::new(offered);
        open_loop(offered, row, 7, &mut rng, |input| Ok(c.submit(input)))?;
        let s = c.stats.lock().unwrap().clone();
        println!(
            "{:>9} {:>9.0} {:>10.2} {:>10.2} {:>10} {:>9.0}%",
            offered,
            s.throughput_rps(),
            s.latency_pct_us(50.0) as f64 / 1e3,
            s.latency_pct_us(99.0) as f64 / 1e3,
            s.batches,
            100.0 * s.occupancy()
        );
    }
    println!("\nserve sweep OK (low load -> linger-bound latency, high load -> full batches)");
    Ok(())
}

/// Open-loop sweep against a router-deployed simulated FFIP model whose
/// batch GEMMs execute on a shared persistent pool.
fn serve_sim() -> anyhow::Result<()> {
    let (k, n, batch) = (512usize, 256usize, 8usize);
    let mut rng = Rng::new(2023);
    let weights = Mat::from_fn(k, n, |_, _| rng.fixed(8, true));

    let pool = Arc::new(GemmPool::new(GemmPool::default_threads()));
    let workers = pool.threads();
    let mut router = Router::with_engine(pool);

    println!(
        "open-loop load sweep over the simulated FFIP accelerator \
         (batch {batch}, K={k}, N={n}, engine pool: {workers} workers)"
    );
    println!(
        "{:>9} {:>9} {:>10} {:>10} {:>10} {:>10}",
        "offered/s", "served/s", "p50 ms", "p99 ms", "batches", "occupancy"
    );

    for offered in [500u64, 1000, 2000, 4000] {
        // fresh deployment per load level (replacing drains the old
        // worker) so each row's stats cover exactly one level
        router.deploy_sim(
            "ffip-512x256",
            weights.clone(),
            Algo::Ffip,
            TileShape::square(64, 64),
            BatcherConfig { batch, linger: Duration::from_millis(2) },
        )?;
        let mut rng = Rng::new(offered);
        open_loop(offered, k, 8, &mut rng, |input| {
            Ok(router.submit("ffip-512x256", input)?)
        })?;
        let s = router
            .model_stats("ffip-512x256")
            .expect("model deployed");
        println!(
            "{:>9} {:>9.0} {:>10.2} {:>10.2} {:>10} {:>9.0}%",
            offered,
            s.throughput_rps(),
            s.latency_pct_us(50.0) as f64 / 1e3,
            s.latency_pct_us(99.0) as f64 / 1e3,
            s.batches,
            100.0 * s.occupancy()
        );
    }

    let ps = router.engine_stats().expect("router owns an engine");
    let pm = PoolMetrics::from_stats(&ps);
    println!(
        "\nengine pool: {} workers | {} jobs | {} items \
         ({:.1} items/job) | peak queue depth {} | mean enqueue \
         backlog {:.2}",
        ps.workers,
        ps.jobs,
        ps.items,
        pm.items_per_job,
        ps.peak_queue_depth,
        pm.mean_enqueue_backlog
    );
    println!(
        "serve sweep OK (persistent pool on the request path; \
         no thread spawn, no tile allocation)"
    );
    Ok(())
}

/// Drive `offered` req/s of open-loop traffic (submitting on schedule
/// regardless of completions) through `submit`, then drain every
/// response.  `row`/`bits` shape the random input rows.
fn open_loop<F>(
    offered: u64,
    row: usize,
    bits: u32,
    rng: &mut Rng,
    mut submit: F,
) -> anyhow::Result<()>
where
    F: FnMut(
        Vec<i32>,
    ) -> anyhow::Result<std::sync::mpsc::Receiver<ffip::coordinator::Response>>,
{
    let n_req = (offered / 4).max(40) as usize; // ~250ms of traffic
    let gap = Duration::from_nanos(1_000_000_000 / offered);
    let t0 = Instant::now();
    let mut rxs = Vec::with_capacity(n_req);
    for i in 0..n_req {
        let target = t0 + gap * i as u32;
        if let Some(sleep) = target.checked_duration_since(Instant::now()) {
            std::thread::sleep(sleep);
        }
        let input: Vec<i32> =
            (0..row).map(|_| rng.fixed(bits, true) as i32).collect();
        rxs.push(submit(input)?);
    }
    for rx in rxs {
        rx.recv()?;
    }
    Ok(())
}
