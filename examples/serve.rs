//! Serving demo: an open-loop load generator against the coordinator
//! (batcher + PJRT MiniCNN backend), sweeping offered load and reporting
//! latency/throughput/occupancy — the L3 stack behaving like a small
//! model server.
//!
//! Run: `cargo run --release --example serve`

use ffip::coordinator::{BatcherConfig, Coordinator};
use ffip::util::Rng;
use std::path::Path;
use std::time::{Duration, Instant};

fn main() -> anyhow::Result<()> {
    let dir = std::env::var("FFIP_ARTIFACTS")
        .unwrap_or_else(|_| "artifacts".into());
    let manifest = ffip::runtime::Manifest::load(Path::new(&dir))?;
    let spec = manifest.get("mini_cnn_b4")?;
    let batch = spec.inputs[0].shape[0];
    let row = spec.inputs[0].numel() / batch;

    println!(
        "open-loop load sweep over the PJRT MiniCNN backend (batch {batch})"
    );
    println!(
        "{:>9} {:>9} {:>10} {:>10} {:>10} {:>10}",
        "offered/s", "served/s", "p50 ms", "p99 ms", "batches", "occupancy"
    );

    for offered in [200u64, 500, 1000, 2000] {
        let dir2 = dir.clone();
        let c = Coordinator::start(
            move || {
                ffip::examples_support::MiniCnnBackend::new(Path::new(
                    &dir2,
                ))
            },
            BatcherConfig {
                batch,
                linger: Duration::from_millis(2),
            },
        )?;
        let mut rng = Rng::new(offered);
        let n_req = (offered / 4).max(40) as usize; // ~250ms of traffic
        let gap = Duration::from_nanos(1_000_000_000 / offered);
        let t0 = Instant::now();
        let mut rxs = Vec::with_capacity(n_req);
        for i in 0..n_req {
            // open loop: submit on schedule regardless of completions
            let target = t0 + gap * i as u32;
            if let Some(sleep) = target.checked_duration_since(Instant::now())
            {
                std::thread::sleep(sleep);
            }
            let input: Vec<i32> =
                (0..row).map(|_| rng.fixed(7, true) as i32).collect();
            rxs.push(c.submit(input));
        }
        for rx in rxs {
            rx.recv()?;
        }
        let s = c.shutdown();
        println!(
            "{:>9} {:>9.0} {:>10.2} {:>10.2} {:>10} {:>9.0}%",
            offered,
            s.throughput_rps(),
            s.latency_pct_us(50.0) as f64 / 1e3,
            s.latency_pct_us(99.0) as f64 / 1e3,
            s.batches,
            100.0 * s.occupancy()
        );
    }
    println!("\nserve sweep OK (low load -> linger-bound latency, high load -> full batches)");
    Ok(())
}
