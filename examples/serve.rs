//! Serving demo: the unified `Model → CompiledModel → InferenceSession`
//! pipeline behind an open-loop load generator — the L3 stack behaving
//! like a small model server.
//!
//! With AOT artifacts present (and the `pjrt` feature enabled with real
//! bindings) the backend is the PJRT-compiled MiniCNN.  Otherwise the
//! demo serves a **multi-layer quantized MLP** (3 FC layers with
//! post-GEMM requantization) through [`Router::deploy_model`]: one
//! deployment per inner-product algorithm, all sharing one persistent
//! [`GemmPool`], checked bit-exact against the layer-by-layer `algo`
//! oracle before the load sweep, with the per-layer wall-time breakdown
//! (§6's layer-wise view) reported from the server's own stats.
//!
//! Because every layer requantizes back to the 8-bit domain, `compile`
//! selects **i8 storage** automatically (`Storage::Auto`): the deployed
//! sessions stage `i8` activations, stream `i8` weights with `i16`
//! offline FFIP y terms, and accumulate in `i32` — the paper's §4.4
//! datapath widths, 4–8× less operand traffic than `i64` staging (the
//! printed deployment lines show the storage each model compiled to;
//! bench H8 quantifies the delta).
//!
//! The final section exercises the replica scheduler: the same MLP
//! deployed with `replicas(2)` and a deliberately small
//! `max_queue_depth`, hit with a burst that overflows admission — the
//! overflow comes back as typed `RequestError::Overloaded` responses
//! (clients told to back off, latency of admitted work stays bounded),
//! and the undeploy stats show the per-replica breakdown plus the shed
//! counter.
//!
//! Run: `cargo run --release --example serve`

use ffip::algo::{
    baseline_matmul, ffip_matmul, fip_matmul, Algo, Mat,
};
use ffip::coordinator::{
    BatcherConfig, Coordinator, DeployConfig, Model, PostGemm, Router,
};
use ffip::engine::GemmPool;
use ffip::metrics::PoolMetrics;
use ffip::nn::models;
use ffip::quant::{requantize_tile, QuantScheme};
use ffip::util::Rng;
use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// MLP layer widths: three GEMM layers, all even (FIP/FFIP-ready).
const DIMS: [usize; 4] = [512, 256, 128, 64];

fn main() -> anyhow::Result<()> {
    let dir = std::env::var("FFIP_ARTIFACTS")
        .unwrap_or_else(|_| "artifacts".into());
    match serve_pjrt(&dir) {
        Ok(()) => Ok(()),
        Err(e) => {
            println!(
                "PJRT backend unavailable ({e:#});\n\
                 serving the simulated multi-layer MLP on the persistent \
                 engine pool instead\n"
            );
            serve_sim()
        }
    }
}

/// Open-loop sweep against the PJRT MiniCNN backend.
fn serve_pjrt(dir: &str) -> anyhow::Result<()> {
    let manifest = ffip::runtime::Manifest::load(Path::new(dir))?;
    let spec = manifest.get("mini_cnn_b4")?;
    let batch = spec.inputs[0].shape[0];
    let row = spec.inputs[0].numel() / batch;

    println!(
        "open-loop load sweep over the PJRT MiniCNN backend (batch {batch})"
    );
    println!(
        "{:>9} {:>9} {:>10} {:>10} {:>10} {:>10}",
        "offered/s", "served/s", "p50 ms", "p99 ms", "batches", "occupancy"
    );

    for offered in [200u64, 500, 1000, 2000] {
        let dir2 = dir.to_string();
        let c = Coordinator::start(
            move || {
                ffip::examples_support::MiniCnnBackend::new(Path::new(
                    &dir2,
                ))
            },
            BatcherConfig {
                batch,
                linger: Duration::from_millis(2),
            },
        )?;
        let mut rng = Rng::new(offered);
        open_loop(offered, row, 7, &mut rng, |input| Ok(c.submit(input)))?;
        let s = c.stats();
        println!(
            "{:>9} {:>9.0} {:>10.2} {:>10.2} {:>10} {:>9.0}%",
            offered,
            s.throughput_rps(),
            s.latency_pct_us(50.0) as f64 / 1e3,
            s.latency_pct_us(99.0) as f64 / 1e3,
            s.batches,
            100.0 * s.occupancy()
        );
    }
    println!("\nserve sweep OK (low load -> linger-bound latency, high load -> full batches)");
    Ok(())
}

/// Build the quantized 3-layer MLP: random 8-bit weights plus per-layer
/// bias + requantization back to the 8-bit domain (ReLU between layers).
fn build_mlp() -> anyhow::Result<Model> {
    let mut model = Model::random(models::mlp(&DIMS), 2023, 8);
    let mut rng = Rng::new(77);
    for (idx, w) in DIMS.windows(2).enumerate() {
        let cout = w[1];
        let bias: Vec<i64> = (0..cout).map(|_| rng.fixed(9, true)).collect();
        let last = idx == DIMS.len() - 2;
        model.set_post(
            idx,
            PostGemm {
                bias,
                scheme: QuantScheme::symmetric_signed(8, 1.0 / 1024.0),
                relu: !last,
            },
        )?;
    }
    Ok(model)
}

/// The layer-by-layer oracle: compose each layer's exact GEMM (per
/// algorithm) with the same post-GEMM requantization.
fn oracle(model: &Model, rows: &Mat<i64>, algo: Algo) -> Mat<i64> {
    let mut act = rows.clone();
    for idx in 0..DIMS.len() - 1 {
        let lw = model.layer_weights(idx).expect("fc weights");
        let acc = match algo {
            Algo::Baseline => baseline_matmul(&act, &lw.w),
            Algo::Fip => fip_matmul(&act, &lw.w),
            Algo::Ffip => ffip_matmul(&act, &lw.w, lw.w.cols),
        };
        let post = lw.post.as_ref().expect("post-GEMM requant");
        act = requantize_tile(&acc, &post.bias, &post.scheme, post.relu);
    }
    act
}

/// Multi-layer MLP serving on the shared persistent pool: deploy one
/// model per algorithm, prove bit-exactness against the oracle, then
/// sweep offered load and report the per-layer breakdown.
fn serve_sim() -> anyhow::Result<()> {
    let batch = 8usize;
    let model = build_mlp()?;
    let pool = Arc::new(GemmPool::new(GemmPool::default_threads()));
    let workers = pool.threads();
    let mut router = Router::with_engine(pool);

    println!(
        "multi-layer MLP {:?} on the simulated accelerator \
         (batch {batch}, engine pool: {workers} workers)",
        DIMS
    );

    // one deployment per algorithm, all sharing the engine; the fully
    // requantized 8-bit model compiles to i8 storage automatically
    for algo in Algo::ALL {
        let cfg = DeployConfig::new(algo)
            .with_tile(64, 64)
            .with_batch(batch)
            .with_linger(Duration::from_millis(2));
        let compiled = model.compile(cfg)?;
        println!(
            "  mlp-{:<8} -> {} storage ({} stationary operand bytes)",
            algo.name(),
            compiled.storage().name(),
            compiled.stationary_bytes()
        );
        router.deploy_model(&format!("mlp-{}", algo.name()), compiled)?;
    }
    println!("deployed: {:?}", router.deployed());

    // bit-exactness: identical requests through all three deployments
    // must match the layer-by-layer oracle exactly
    let mut rng = Rng::new(11);
    for case in 0..4 {
        let input: Vec<i32> =
            (0..DIMS[0]).map(|_| rng.fixed(7, true) as i32).collect();
        let rows = Mat::from_fn(1, DIMS[0], |_, j| i64::from(input[j]));
        for algo in Algo::ALL {
            let name = format!("mlp-{}", algo.name());
            let out = router
                .infer(&name, input.clone())
                .map_err(|e| anyhow::anyhow!("{e}"))?
                .output();
            let got: Vec<i64> =
                out.data.iter().map(|&v| v as i64).collect();
            let gold = oracle(&model, &rows, algo);
            assert_eq!(got, gold.data, "case {case}: {name} vs oracle");
        }
    }
    println!(
        "bit-exact: {} logits per request agree with the layer-by-layer \
         oracle for baseline/FIP/FFIP\n",
        DIMS[DIMS.len() - 1]
    );

    // open-loop sweep over the FFIP deployment
    println!(
        "{:>9} {:>9} {:>10} {:>10} {:>10} {:>10}",
        "offered/s", "served/s", "p50 ms", "p99 ms", "batches", "occupancy"
    );
    for offered in [500u64, 1000, 2000, 4000] {
        // fresh deployment per load level (undeploy drains the old
        // worker) so each row's stats cover exactly one level
        router.undeploy("mlp-sweep");
        let cfg = DeployConfig::new(Algo::Ffip)
            .with_tile(64, 64)
            .with_batch(batch)
            .with_linger(Duration::from_millis(2));
        router.deploy_model("mlp-sweep", model.compile(cfg)?)?;
        let mut rng = Rng::new(offered);
        open_loop(offered, DIMS[0], 7, &mut rng, |input| {
            Ok(router.submit("mlp-sweep", input)?)
        })?;
        let s = router
            .model_stats("mlp-sweep")
            .expect("model deployed");
        println!(
            "{:>9} {:>9.0} {:>10.2} {:>10.2} {:>10} {:>9.0}%",
            offered,
            s.throughput_rps(),
            s.latency_pct_us(50.0) as f64 / 1e3,
            s.latency_pct_us(99.0) as f64 / 1e3,
            s.batches,
            100.0 * s.occupancy()
        );
    }

    // the §6 layer-wise view, from the server's own stats
    let s = router.model_stats("mlp-sweep").expect("model deployed");
    println!("\nper-layer breakdown (last load level):");
    for (idx, l) in s.layers.iter().enumerate() {
        println!(
            "  {:<8} {:>7} batches  {:>9.1} us/batch  {:>5.1}% of layer time",
            l.name,
            l.batches,
            l.mean_us(),
            100.0 * s.layer_share(idx)
        );
    }

    // replica-sharded serving with admission control: two session
    // replicas (weights Arc-shared, buffers per replica) behind a
    // deliberately small admission bound, hit with an instant burst.
    // Admission counts a request until its response is sent, so the
    // burst overflows the bound and the overflow is shed immediately
    // with a typed Overloaded error instead of queueing unboundedly.
    router.undeploy("mlp-sweep");
    let burst = 64usize;
    let depth = 6usize;
    let cfg = DeployConfig::new(Algo::Ffip)
        .with_tile(64, 64)
        .with_batch(4)
        .with_linger(Duration::from_millis(2))
        .with_replicas(2)
        .with_max_queue_depth(depth);
    router.deploy_model("mlp-replicated", model.compile(cfg)?)?;
    let mut rng = Rng::new(2024);
    let rxs: Vec<_> = (0..burst)
        .map(|_| {
            let input: Vec<i32> =
                (0..DIMS[0]).map(|_| rng.fixed(7, true) as i32).collect();
            router.submit("mlp-replicated", input)
        })
        .collect::<Result<_, _>>()
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let (mut served, mut overloaded) = (0u64, 0u64);
    for rx in rxs {
        match rx.recv()?.result {
            Ok(_) => served += 1,
            Err(ffip::coordinator::RequestError::Overloaded { .. }) => {
                overloaded += 1
            }
            Err(e) => anyhow::bail!("unexpected request error: {e}"),
        }
    }
    let s = router.undeploy("mlp-replicated").expect("was deployed");
    println!(
        "\nreplica-sharded deployment (replicas=2, max_queue_depth={depth}, \
         burst {burst}):"
    );
    for (idx, r) in s.replicas.iter().enumerate() {
        println!(
            "  replica {idx}: {:>3} requests  {:>3} batches  {:>8} us busy",
            r.requests, r.batches, r.busy_us
        );
    }
    println!(
        "  served {served} | shed {overloaded} (client-observed) = {} \
         (server shed counter)",
        s.shed
    );
    assert_eq!(s.shed, overloaded, "every shed is a typed response");
    assert_eq!(served + overloaded, burst as u64);
    assert!(
        overloaded > 0,
        "a {burst}-request burst against depth {depth} must shed"
    );
    assert_eq!(
        s.replicas.iter().map(|r| r.batches).sum::<u64>(),
        s.batches,
        "per-replica breakdown covers all batches"
    );

    let ps = router.engine_stats().expect("router owns an engine");
    let pm = PoolMetrics::from_stats(&ps);
    println!(
        "\nengine pool: {} workers | {} jobs | {} items \
         ({:.1} items/job) | peak queue depth {} | mean enqueue \
         backlog {:.2}",
        ps.workers,
        ps.jobs,
        ps.items,
        pm.items_per_job,
        ps.peak_queue_depth,
        pm.mean_enqueue_backlog
    );
    println!(
        "serve OK (whole models on the request path: compile -> \
         deploy_model -> infer, one persistent pool underneath)"
    );
    Ok(())
}

/// Drive `offered` req/s of open-loop traffic (submitting on schedule
/// regardless of completions) through `submit`, then drain every
/// response.  `row`/`bits` shape the random input rows.
fn open_loop<F>(
    offered: u64,
    row: usize,
    bits: u32,
    rng: &mut Rng,
    mut submit: F,
) -> anyhow::Result<()>
where
    F: FnMut(
        Vec<i32>,
    ) -> anyhow::Result<std::sync::mpsc::Receiver<ffip::coordinator::Response>>,
{
    let n_req = (offered / 4).max(40) as usize; // ~250ms of traffic
    let gap = Duration::from_nanos(1_000_000_000 / offered);
    let t0 = Instant::now();
    let mut rxs = Vec::with_capacity(n_req);
    for i in 0..n_req {
        let target = t0 + gap * i as u32;
        if let Some(sleep) = target.checked_duration_since(Instant::now()) {
            std::thread::sleep(sleep);
        }
        let input: Vec<i32> =
            (0..row).map(|_| rng.fixed(bits, true) as i32).collect();
        rxs.push(submit(input)?);
    }
    for rx in rxs {
        let resp = rx.recv()?;
        if let Err(e) = resp.result {
            anyhow::bail!("request {} failed: {e}", resp.id);
        }
    }
    Ok(())
}
