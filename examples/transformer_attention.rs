//! FFIP beyond CNNs: attention / transformer / LSTM workloads (the
//! paper's §1 claim that FIP applies to "all ML model layers that can
//! mainly decompose to matrix multiplication").
//!
//! Part 1 runs the AOT-compiled attention artifact (Pallas FFIP kernels
//! inside) via PJRT and checks its numerics against a pure-Rust f32
//! attention reference.
//!
//! Part 2 times transformer and BiLSTM workloads on the modeled FFIP
//! accelerator alongside ResNet-50, showing the MXU serves them all.
//!
//! Run: `cargo run --release --example transformer_attention`

use ffip::algo::Algo;
use ffip::arith::FixedSpec;
use ffip::fpga::{self, Device};
use ffip::metrics::PerfMetrics;
use ffip::nn::models;
use ffip::runtime::{Input, Runtime};
use ffip::sched;
use ffip::util::Rng;
use std::path::Path;

/// Pure-Rust single-head attention reference (f32).
fn attention_ref(q: &[f32], k: &[f32], v: &[f32], s: usize, d: usize) -> Vec<f32> {
    let mut scores = vec![0f32; s * s];
    let scale = 1.0 / (d as f32).sqrt();
    for i in 0..s {
        for j in 0..s {
            let mut acc = 0f32;
            for t in 0..d {
                acc += q[i * d + t] * k[j * d + t];
            }
            scores[i * s + j] = acc * scale;
        }
    }
    // softmax rows
    for i in 0..s {
        let row = &mut scores[i * s..(i + 1) * s];
        let max = row.iter().cloned().fold(f32::MIN, f32::max);
        let mut sum = 0f32;
        for x in row.iter_mut() {
            *x = (*x - max).exp();
            sum += *x;
        }
        for x in row.iter_mut() {
            *x /= sum;
        }
    }
    let mut out = vec![0f32; s * d];
    for i in 0..s {
        for t in 0..d {
            let mut acc = 0f32;
            for j in 0..s {
                acc += scores[i * s + j] * v[j * d + t];
            }
            out[i * d + t] = acc;
        }
    }
    out
}

fn main() -> anyhow::Result<()> {
    // -- Part 1: PJRT attention artifact vs Rust reference -------------
    let dir = std::env::var("FFIP_ARTIFACTS")
        .unwrap_or_else(|_| "artifacts".into());
    let mut rt = Runtime::new(Path::new(&dir))?;
    let exe = rt.load("attention_s64_d32")?;
    let (s, d) = (64usize, 32usize);
    let mut rng = Rng::new(11);
    let mut mk = || -> Vec<f32> {
        (0..s * d).map(|_| rng.fixed(8, true) as f32 / 64.0).collect()
    };
    let (q, k, v) = (mk(), mk(), mk());
    let got = exe.run_f32(&[
        Input::F32(q.clone()),
        Input::F32(k.clone()),
        Input::F32(v.clone()),
    ])?;
    let want = attention_ref(&q, &k, &v, s, d);
    let max_err = got
        .iter()
        .zip(&want)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    assert!(max_err < 1e-3, "attention mismatch: max err {max_err}");
    println!(
        "[1] PJRT attention artifact (FFIP Pallas kernels) matches the \
         Rust reference: max |err| = {max_err:.2e}  OK"
    );

    // -- Part 2: every layer family on the same MXU --------------------
    println!("\n[2] modeled FFIP 64x64 @ GX 1150 across layer families:");
    let dev = Device::arria10_gx1150();
    let spec = FixedSpec::signed(8);
    let util = fpga::estimate(Algo::Ffip, spec, 64, 64, &dev);
    let fmax = fpga::fmax_mhz(Algo::Ffip, spec, 64, 64, &dev);
    let workloads = [
        models::resnet50(),
        models::transformer(256, 512, 8, 6),
        models::bilstm(128, 512, 256),
        models::mlp(&[784, 512, 512, 10]),
    ];
    println!(
        "    {:<24} {:>10} {:>9} {:>10} {:>8}",
        "workload", "GMACs/inf", "ms/inf", "GOPS", "ops/m/c"
    );
    for g in workloads {
        let nt = sched::network_timing(&g, Algo::Ffip, 64, 64, fmax);
        let m = PerfMetrics::from_measured(
            g.ops_per_inference(),
            nt.inferences_per_second(),
            util.multipliers,
            fmax,
        );
        println!(
            "    {:<24} {:>10.2} {:>9.3} {:>10.0} {:>8.3}",
            g.name,
            g.macs_per_inference() as f64 * 1e-9,
            nt.seconds_per_inference() * 1e3,
            m.gops,
            m.ops_per_multiplier_per_cycle
        );
    }
    println!("\ntransformer_attention OK");
    Ok(())
}
