//! FFIP beyond CNNs: attention / transformer / LSTM workloads (the
//! paper's §1 claim that FIP applies to "all ML model layers that can
//! mainly decompose to matrix multiplication").
//!
//! Part 1 runs the AOT-compiled attention artifact (Pallas FFIP kernels
//! inside) via PJRT and checks its numerics against a pure-Rust f32
//! attention reference.
//!
//! Part 2 times transformer and BiLSTM workloads on the modeled FFIP
//! accelerator alongside ResNet-50, showing the MXU serves them all.
//!
//! Part 3 serves a quantized attention layer through the compiled
//! pipeline — `Router::deploy_model` over ragged `[len, tokens, pad]`
//! requests, with FFIP's y transform running **online** on the request
//! path — and self-checks every response against the same attention
//! math as Part 1's reference, re-derived here in fixed point.
//!
//! Run: `cargo run --release --example transformer_attention`

use ffip::algo::{Algo, Mat};
use ffip::arith::FixedSpec;
use ffip::coordinator::{
    compile, pack_ragged_row, DeployConfig, Model, PostGemm, Router,
};
use ffip::engine::GemmPool;
use ffip::fpga::{self, Device};
use ffip::metrics::PerfMetrics;
use ffip::nn::{models, Graph, Layer};
use ffip::quant::{
    requantize, softmax_fixed_row, QuantScheme, SoftmaxScratch, SoftmaxSpec,
};
use ffip::runtime::{Input, Runtime};
use ffip::sched;
use ffip::util::Rng;
use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

/// Pure-Rust single-head attention reference (f32).
fn attention_ref(q: &[f32], k: &[f32], v: &[f32], s: usize, d: usize) -> Vec<f32> {
    let mut scores = vec![0f32; s * s];
    let scale = 1.0 / (d as f32).sqrt();
    for i in 0..s {
        for j in 0..s {
            let mut acc = 0f32;
            for t in 0..d {
                acc += q[i * d + t] * k[j * d + t];
            }
            scores[i * s + j] = acc * scale;
        }
    }
    // softmax rows
    for i in 0..s {
        let row = &mut scores[i * s..(i + 1) * s];
        let max = row.iter().cloned().fold(f32::MIN, f32::max);
        let mut sum = 0f32;
        for x in row.iter_mut() {
            *x = (*x - max).exp();
            sum += *x;
        }
        for x in row.iter_mut() {
            *x /= sum;
        }
    }
    let mut out = vec![0f32; s * d];
    for i in 0..s {
        for t in 0..d {
            let mut acc = 0f32;
            for j in 0..s {
                acc += scores[i * s + j] * v[j * d + t];
            }
            out[i * d + t] = acc;
        }
    }
    out
}

/// Part 1's attention math in the serving pipeline's fixed-point
/// contract: plain `i64` loops over one `[len, tokens, pad]` request
/// row, sharing only `requantize` and `softmax_fixed_row` with the
/// library — the oracle each deployed response must match bit for bit.
fn fixed_attention_oracle(
    w: &Mat<i64>,
    post: &PostGemm,
    heads: usize,
    d_head: usize,
    max_seq: usize,
    row: &[i32],
) -> Vec<i64> {
    let d = heads * d_head;
    let s = row[0] as usize;
    let mut out = vec![0i64; 1 + max_seq * d];
    out[0] = s as i64;
    if s == 0 {
        return out;
    }
    let x: Vec<i64> = row[1..1 + s * d].iter().map(|&v| i64::from(v)).collect();
    // one projection against segment `seg` of the packed [Wq|Wk|Wv|Wo]
    let project = |seg: usize, xin: &[i64], relu: bool| -> Vec<i64> {
        let mut p = vec![0i64; s * d];
        for i in 0..s {
            for j in 0..d {
                let mut acc = 0i64;
                for t in 0..d {
                    acc += xin[i * d + t] * w[(t, seg * d + j)];
                }
                let v = requantize(acc, post.bias[seg * d + j], &post.scheme);
                p[i * d + j] = if relu { v.max(0) } else { v };
            }
        }
        p
    };
    let q = project(0, &x, false);
    let k = project(1, &x, false);
    let v = project(2, &x, false);
    let softmax = SoftmaxSpec::for_attention(post.scheme.spec.w, d_head);
    let av_scheme = QuantScheme {
        spec: FixedSpec::signed(post.scheme.spec.w),
        zero_b: 0,
        requant: 1.0 / softmax.one as f32,
    };
    let mut scr = SoftmaxScratch::default();
    let mut att = vec![0i64; s * d];
    for h in 0..heads {
        let hc = h * d_head;
        for i in 0..s {
            let mut scores = vec![0i64; s];
            for (j, sc) in scores.iter_mut().enumerate() {
                let mut acc = 0i64;
                for c in 0..d_head {
                    acc += q[i * d + hc + c] * k[j * d + hc + c];
                }
                *sc = acc;
            }
            let mut probs = vec![0i64; s];
            softmax_fixed_row(&scores, &softmax, &mut scr, &mut probs);
            for c in 0..d_head {
                let mut acc = 0i64;
                for (j, &pj) in probs.iter().enumerate() {
                    acc += pj * v[j * d + hc + c];
                }
                att[i * d + hc + c] = requantize(acc, 0, &av_scheme);
            }
        }
    }
    let o = project(3, &att, post.relu);
    out[1..1 + s * d].copy_from_slice(&o);
    out
}

fn main() -> anyhow::Result<()> {
    // -- Part 1: PJRT attention artifact vs Rust reference -------------
    let dir = std::env::var("FFIP_ARTIFACTS")
        .unwrap_or_else(|_| "artifacts".into());
    let mut rt = Runtime::new(Path::new(&dir))?;
    let exe = rt.load("attention_s64_d32")?;
    let (s, d) = (64usize, 32usize);
    let mut rng = Rng::new(11);
    let mut mk = || -> Vec<f32> {
        (0..s * d).map(|_| rng.fixed(8, true) as f32 / 64.0).collect()
    };
    let (q, k, v) = (mk(), mk(), mk());
    let got = exe.run_f32(&[
        Input::F32(q.clone()),
        Input::F32(k.clone()),
        Input::F32(v.clone()),
    ])?;
    let want = attention_ref(&q, &k, &v, s, d);
    let max_err = got
        .iter()
        .zip(&want)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    assert!(max_err < 1e-3, "attention mismatch: max err {max_err}");
    println!(
        "[1] PJRT attention artifact (FFIP Pallas kernels) matches the \
         Rust reference: max |err| = {max_err:.2e}  OK"
    );

    // -- Part 2: every layer family on the same MXU --------------------
    println!("\n[2] modeled FFIP 64x64 @ GX 1150 across layer families:");
    let dev = Device::arria10_gx1150();
    let spec = FixedSpec::signed(8);
    let util = fpga::estimate(Algo::Ffip, spec, 64, 64, &dev);
    let fmax = fpga::fmax_mhz(Algo::Ffip, spec, 64, 64, &dev);
    let workloads = [
        models::resnet50(),
        models::transformer(256, 512, 8, 6),
        models::bilstm(128, 512, 256),
        models::mlp(&[784, 512, 512, 10]),
    ];
    println!(
        "    {:<24} {:>10} {:>9} {:>10} {:>8}",
        "workload", "GMACs/inf", "ms/inf", "GOPS", "ops/m/c"
    );
    for g in workloads {
        let nt = sched::network_timing(&g, Algo::Ffip, 64, 64, fmax);
        let m = PerfMetrics::from_measured(
            g.ops_per_inference(),
            nt.inferences_per_second(),
            util.multipliers,
            fmax,
        );
        println!(
            "    {:<24} {:>10.2} {:>9.3} {:>10.0} {:>8.3}",
            g.name,
            g.macs_per_inference() as f64 * 1e-9,
            nt.seconds_per_inference() * 1e3,
            m.gops,
            m.ops_per_multiplier_per_cycle
        );
    }
    // -- Part 3: attention through the compiled serving pipeline -------
    // the full transformer above is modeled analytically; serving
    // compiles a deployable single-attention-layer graph (the ragged
    // wire format is the attention layer's own I/O contract)
    let (heads, d_head, max_seq) = (2usize, 4usize, 6usize);
    let d = heads * d_head;
    let graph = Graph {
        name: "attn-serve".into(),
        layers: vec![Layer::Attention {
            name: "attn0".into(),
            heads,
            d_model: d,
            d_head,
            max_seq,
            causal: false,
        }],
    };
    let mut model = Model::random(graph, 0xA77E, 8);
    let mut brng = Rng::new(0xB1A5);
    let bias: Vec<i64> = (0..4 * d).map(|_| brng.fixed(6, true)).collect();
    model.set_post(
        0,
        PostGemm {
            bias,
            scheme: QuantScheme::symmetric_signed(8, 1.0 / 64.0),
            relu: false,
        },
    )?;
    let lw = model.layer_weights(0).expect("one layer");
    let (weights, post) = (lw.w.clone(), lw.post.clone().expect("post set"));
    let cfg = DeployConfig::new(Algo::Ffip)
        .with_tile(4, 4)
        .with_batch(2)
        .with_linger(Duration::from_millis(1))
        .with_replicas(2);
    let compiled = compile(&model, cfg)?;
    let mut router = Router::with_engine(Arc::new(GemmPool::new(2)));
    router.deploy_model("attn", compiled)?;
    // ragged burst: every sequence length 0..=max_seq once
    let requests: Vec<Vec<i32>> = (0..=max_seq)
        .map(|s| (0..s * d).map(|_| rng.fixed(7, true) as i32).collect())
        .collect();
    let rxs: Vec<_> = requests
        .iter()
        .map(|tokens| router.submit("attn", pack_ragged_row(tokens, d, max_seq)))
        .collect::<Result<_, _>>()?;
    for (tokens, rx) in requests.iter().zip(rxs) {
        let got = rx.recv()?.output();
        let packed = pack_ragged_row(tokens, d, max_seq);
        let want = fixed_attention_oracle(
            &weights, &post, heads, d_head, max_seq, &packed,
        );
        let out: Vec<i64> = got.data.iter().map(|&v| v as i64).collect();
        assert_eq!(out, want, "served attention != fixed-point oracle");
    }
    let stats = router.undeploy("attn").expect("deployed");
    println!(
        "\n[3] served {} ragged attention requests (lengths 0..={max_seq}) \
         through {} FFIP replicas — online y on the request path — all \
         bit-exact vs the fixed-point oracle  OK",
        stats.count(),
        stats.replicas.len()
    );

    println!("\ntransformer_attention OK");
    Ok(())
}
