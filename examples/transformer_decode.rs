//! Autoregressive transformer decode: KV cache + continuous batching.
//!
//! Part 1 compiles `models::transformer` end to end — attention, MLP
//! and residual layers over the ragged wire format — and builds a
//! [`DecodeScheduler`] on the artifact, printing the per-sequence KV
//! geometry the compiled plan implies.
//!
//! Part 2 decodes a continuously batched workload — staggered admits
//! and a mid-flight `feed` — and self-checks every emitted row bit for
//! bit against a full-recompute ragged prefill of the same prompts
//! (causal attention makes prefill row `t` the decode output at
//! position `t`, so KV caching must be arithmetically invisible).
//!
//! Part 3 drives both admission gates (`max_active_seqs`,
//! `max_kv_bytes`) into typed shedding and shows retirement handing
//! the freed budget to the shed client.
//!
//! Run: `cargo run --release --example transformer_decode`

use ffip::algo::Algo;
use ffip::coordinator::{
    compile, pack_ragged_row, CompiledModel, DecodeScheduler, DeployConfig,
    InferenceSession, Model, PostGemm, RequestError, TensorView,
};
use ffip::engine::GemmPool;
use ffip::nn::models;
use ffip::quant::QuantScheme;
use std::collections::HashMap;
use std::sync::Arc;

const SEQ: usize = 8;
const DIM: usize = 16;
const HEADS: usize = 4;
const BLOCKS: usize = 2;

/// Quantized two-block transformer over the ragged wire format.
fn transformer_model() -> anyhow::Result<Model> {
    let mut model = Model::random(
        models::transformer(SEQ, DIM, HEADS, BLOCKS),
        0xDEC0,
        3,
    );
    let post = |n: usize, relu: bool| PostGemm {
        bias: vec![0; n],
        scheme: QuantScheme::symmetric_signed(8, 1.0 / 32.0),
        relu,
    };
    // per block: [attn, res, mlp_up, mlp_down, res]
    for b in 0..BLOCKS {
        model.set_post(5 * b, post(4 * DIM, false))?;
        model.set_post(5 * b + 2, post(4 * DIM, true))?;
        model.set_post(5 * b + 3, post(DIM, false))?;
    }
    Ok(model)
}

/// `len` deterministic tokens for sequence `s`.
fn prompt(s: u64, len: usize) -> Vec<i32> {
    (0..len * DIM)
        .map(|i| ((i as i64 + 3 * s as i64) % 7 - 3) as i32)
        .collect()
}

/// Full-recompute oracle: ragged prefill rows, keyed by (id, position).
fn prefill_oracle(
    compiled: &CompiledModel,
    pool: &Arc<GemmPool>,
    prompts: &[(u64, Vec<i32>)],
) -> anyhow::Result<HashMap<(u64, usize), Vec<i64>>> {
    let mut sess = InferenceSession::new(compiled, pool.clone());
    let mut want = HashMap::new();
    for (id, toks) in prompts {
        let packed = pack_ragged_row(toks, DIM, SEQ);
        let out =
            sess.infer_batch(TensorView::new(1, packed.len(), &packed))?;
        for t in 0..toks.len() / DIM {
            let row: Vec<i64> = out.data[1 + t * DIM..1 + (t + 1) * DIM]
                .iter()
                .map(|&v| v as i64)
                .collect();
            want.insert((*id, t), row);
        }
    }
    Ok(want)
}

fn main() -> anyhow::Result<()> {
    // -- Part 1: transformer artifact + decode state -------------------
    let model = transformer_model()?;
    let pool = Arc::new(GemmPool::new(2));
    let compiled =
        compile(&model, DeployConfig::new(Algo::Ffip).with_tile(4, 4))?;
    let mut dec = DecodeScheduler::new(&compiled, pool.clone())?;
    let m = dec.metrics();
    let storage = format!("{:?}", dec.storage()).to_lowercase();
    println!(
        "[1] {}-block transformer (d_model {}, {} heads, max_seq {}) \
         compiled for FFIP; decode state: {} KV bytes per sequence \
         ({storage} storage)  OK",
        BLOCKS,
        dec.d_model(),
        HEADS,
        dec.max_seq(),
        m.seq_bytes,
    );

    // -- Part 2: continuous batching vs full recompute -----------------
    let prompts: Vec<(u64, Vec<i32>)> =
        vec![(1, prompt(1, 5)), (2, prompt(2, 4)), (3, prompt(3, 3))];
    let want = prefill_oracle(&compiled, &pool, &prompts)?;
    // sequences join and feed *between* steps, never between layers
    dec.admit(1, &prompts[0].1)?;
    dec.admit(2, &prompts[1].1[..2 * DIM])?;
    let mut got = HashMap::new();
    let mut collect = |outs: Vec<ffip::coordinator::StepOutput>,
                       got: &mut HashMap<(u64, usize), Vec<i64>>| {
        for o in outs {
            let row: Vec<i64> =
                o.out.data.iter().map(|&v| v as i64).collect();
            got.insert((o.id, o.pos), row);
        }
    };
    collect(dec.step()?, &mut got);
    collect(dec.step()?, &mut got);
    dec.admit(3, &prompts[2].1)?;
    dec.feed(2, &prompts[1].1[2 * DIM..])?;
    loop {
        let outs = dec.step()?;
        if outs.is_empty() {
            break;
        }
        collect(outs, &mut got);
    }
    assert_eq!(got.len(), want.len(), "decode must cover every position");
    for (key, w) in &want {
        assert_eq!(
            got.get(key),
            Some(w),
            "KV-cached decode diverged from full recompute at {key:?}"
        );
    }
    let m = dec.metrics();
    println!(
        "[2] decoded {} tokens over {} continuously batched steps \
         ({:.2} tokens/step) — every row bit-exact vs full-recompute \
         prefill  OK",
        m.tokens,
        m.steps,
        m.tokens_per_step()
    );
    for (id, _) in &prompts {
        dec.retire(*id)?;
    }

    // -- Part 3: typed admission shedding ------------------------------
    let seq_bytes = dec.metrics().seq_bytes;
    let cfg = DeployConfig::new(Algo::Ffip)
        .with_tile(4, 4)
        .with_max_active_seqs(2)
        .with_max_kv_bytes(2 * seq_bytes);
    let compiled = compile(&model, cfg)?;
    let mut dec = DecodeScheduler::new(&compiled, pool.clone())?;
    dec.admit(1, &prompt(1, 2))?;
    dec.admit(2, &prompt(2, 2))?;
    let shed = dec.admit(3, &prompt(3, 2)).unwrap_err();
    assert!(
        matches!(shed, RequestError::Overloaded { max_queue_depth: 2 }),
        "want the depth gate, got {shed:?}"
    );
    let m = dec.metrics();
    assert!((m.kv_occupancy() - 1.0).abs() < 1e-12);
    // retiring a sequence hands the freed slot + bytes to the retry
    dec.retire(1)?;
    dec.admit(3, &prompt(3, 2))?;
    println!(
        "[3] admission gates shed typed errors at {} active sequences / \
         {} KV bytes (occupancy {:.0}%); retirement freed the budget for \
         the shed client  OK",
        2,
        2 * seq_bytes,
        100.0 * m.kv_occupancy()
    );

    println!("\ntransformer_decode OK");
    Ok(())
}
