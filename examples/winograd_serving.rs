//! Winograd×FFIP serving, closed-loop: the autotuner discovers the
//! F(2×2, 3×3) conv lowering on its own, the compiled session serves
//! through it, and the output is checked bit-exact against the direct
//! (im2col + baseline GEMM) convolution — composition on top of the
//! inner-product algorithms, never an approximation.
//!
//! The model's conv layer also has a quarter of its output channels
//! pruned to zero, so the run demonstrates the engine's packed-strip
//! zero-column skipping: the pool reports the lane-MACs it elided
//! while the bits stay identical.
//!
//! Run: `cargo run --release --example winograd_serving`

use ffip::algo::{
    baseline_matmul, winograd_mult_counts, Algo, ConvAlgo, Mat,
};
use ffip::coordinator::{
    InferenceSession, LayerWeights, Model, PostGemm, TensorView,
};
use ffip::engine::GemmPool;
use ffip::fpga::Device;
use ffip::memory::{ConvShape, Im2Gemm};
use ffip::nn::{Graph, Layer};
use ffip::quant::{requantize_tile, QuantScheme};
use ffip::tune::TuneBudget;
use ffip::util::Rng;
use std::sync::Arc;

fn main() {
    // -- a small CNN: one wide 3x3 conv + a classifier head -----------
    let shape = ConvShape {
        h: 16,
        w: 16,
        cin: 64,
        cout: 64,
        kh: 3,
        kw: 3,
        stride: 1,
        pad: 1,
    };
    let fc_in = shape.out_h() * shape.out_w() * shape.cout;
    let graph = Graph {
        name: "wino-cnn".into(),
        layers: vec![
            Layer::Conv { name: "conv1".into(), shape, groups: 1 },
            Layer::Fc { name: "fc".into(), cin: fc_in, cout: 10 },
        ],
    };
    // conv weights with every 4th output channel pruned to zero — the
    // structured sparsity the packed-strip skip detector recognizes
    let mut rng = Rng::new(0x1306);
    let conv_w = Mat::from_fn(9 * shape.cin, shape.cout, |_, j| {
        if j % 4 == 0 {
            0
        } else {
            rng.fixed(4, true)
        }
    });
    let fc_w = Mat::from_fn(fc_in, 10, |_, _| rng.fixed(4, true));
    let mut model = Model::new(
        graph,
        vec![
            Some(LayerWeights { w: conv_w, post: None }),
            Some(LayerWeights { w: fc_w, post: None }),
        ],
    )
    .unwrap();
    for (idx, (cout, relu)) in [(shape.cout, true), (10, false)]
        .into_iter()
        .enumerate()
    {
        let bias: Vec<i64> = (0..cout).map(|_| rng.fixed(8, true)).collect();
        model
            .set_post(
                idx,
                PostGemm {
                    bias,
                    scheme: QuantScheme::symmetric_signed(8, 1.0 / 1024.0),
                    relu,
                },
            )
            .unwrap();
    }

    // -- the tuner must discover the Winograd lowering on its own -----
    let budget = TuneBudget::new(Device::arria10_gx1150())
        .with_batch(1)
        .with_max_replicas(1);
    let (plan, compiled) = model.compile_tuned(&budget).unwrap();
    println!("{}", plan.report());
    assert_eq!(
        plan.layers[0].conv,
        ConvAlgo::WinogradFfip,
        "the tuner must lower the eligible 3x3 conv through Winograd"
    );
    assert_eq!(plan.layers[1].conv, ConvAlgo::Im2Gemm, "FC is never lowered");
    let (direct, wino) =
        winograd_mult_counts(shape.out_h(), shape.out_w(), shape.cin, shape.cout);
    println!(
        "conv1 elementwise multiplies: direct {direct} -> winograd {wino} \
         ({:.3}x, exact 4/9 = {:.3})",
        wino as f64 / direct as f64,
        4.0 / 9.0
    );

    // -- serve and check bit-exactness vs the direct convolution ------
    let in_len = shape.h * shape.w * shape.cin;
    let input: Vec<i32> =
        (0..in_len).map(|_| rng.fixed(8, true) as i32).collect();
    let pool = Arc::new(GemmPool::new(2));
    let mut sess = InferenceSession::new(&compiled, pool.clone());
    let out = sess.infer_batch(TensorView::new(1, in_len, &input)).unwrap();
    let got: Vec<i64> = out.data.iter().map(|&v| v as i64).collect();

    // oracle: materialized im2col + exact baseline GEMM + requantize,
    // then the FC head — no Winograd anywhere
    let flat: Vec<i64> = input.iter().map(|&v| i64::from(v)).collect();
    let (ph, pw) = (shape.h + 2 * shape.pad, shape.w + 2 * shape.pad);
    let padded = Mat::from_fn(ph * pw, shape.cin, |pos, ch| {
        let (hh, ww) = (pos / pw, pos % pw);
        if hh < shape.pad
            || hh >= shape.h + shape.pad
            || ww < shape.pad
            || ww >= shape.w + shape.pad
        {
            0
        } else {
            flat[((hh - shape.pad) * shape.w + (ww - shape.pad)) * shape.cin
                + ch]
        }
    });
    let a = Im2Gemm::new(shape, 4).virtual_a(&padded);
    let lw = model.layer_weights(0).unwrap();
    let post = lw.post.as_ref().unwrap();
    let conv_out = requantize_tile(
        &baseline_matmul(&a, &lw.w),
        &post.bias,
        &post.scheme,
        post.relu,
    );
    // NHWC (oh*ow, cout) row-major flattens to exactly the FC input row
    let fc_row = Mat::from_fn(1, fc_in, |_, j| conv_out.data[j]);
    let lw = model.layer_weights(1).unwrap();
    let post = lw.post.as_ref().unwrap();
    let gold = requantize_tile(
        &baseline_matmul(&fc_row, &lw.w),
        &post.bias,
        &post.scheme,
        post.relu,
    );
    assert_eq!(got, gold.data, "Winograd serving must be bit-exact");
    println!("served output matches the direct conv oracle bit-for-bit");

    // -- the pruned channels were actually skipped, not recomputed ----
    let stats = pool.stats();
    println!(
        "engine: {} strips built, {} lane-MACs elided by zero-column \
         skipping",
        stats.strips_built, stats.lanes_skipped
    );
    let fast = matches!(plan.layers[0].algo, Algo::Fip | Algo::Ffip);
    if fast && compiled.storage() != ffip::ElemKind::I64 {
        assert!(
            stats.lanes_skipped > 0,
            "pruned channels must be elided under (F)FIP"
        );
    }
    println!("[self-check OK]");
}
