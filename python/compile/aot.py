"""AOT lowering: JAX entry points -> HLO *text* artifacts for the Rust
runtime.

HLO text (NOT ``lowered.compile()``/``.serialize()``) is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which
the xla crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``);
the text parser reassigns ids and round-trips cleanly.  See
rust/src/runtime/mod.rs for the Rust side of the artifact flow.

Each artifact is lowered with ``return_tuple=True`` so the Rust side
unwraps with ``to_tuple1()``.  A ``manifest.tsv`` records name, input
dtypes/shapes and output shape for the Rust loader's sanity checks.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-reassigning path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


# name -> (entry_fn, [input specs]). Shapes are the AOT contract with the
# Rust runtime (runtime/artifact.rs re-reads them from manifest.tsv).
ARTIFACTS = {
    "ffip_gemm_f32_128": (
        model.ffip_gemm_f32_entry,
        [spec((128, 128), jnp.float32), spec((128, 128), jnp.float32)],
    ),
    "fip_gemm_f32_128": (
        model.fip_gemm_f32_entry,
        [spec((128, 128), jnp.float32), spec((128, 128), jnp.float32)],
    ),
    "baseline_gemm_f32_128": (
        model.baseline_gemm_f32_entry,
        [spec((128, 128), jnp.float32), spec((128, 128), jnp.float32)],
    ),
    "ffip_gemm_i32_64": (
        model.ffip_gemm_i32_entry,
        [spec((64, 64), jnp.int32), spec((64, 64), jnp.int32)],
    ),
    "ffip_gemm_i16_64": (
        model.ffip_gemm_i16_entry,
        [spec((64, 64), jnp.int32), spec((64, 64), jnp.int32)],
    ),
    "mini_cnn_b4": (
        model.mini_cnn_entry,
        [spec((4, 16, 16, 4), jnp.int32)],
    ),
    "attention_s64_d32": (
        model.attention_entry,
        [spec((64, 32), jnp.float32)] * 3,
    ),
}


def build(out_dir: str, only: list[str] | None = None) -> None:
    os.makedirs(out_dir, exist_ok=True)
    manifest_rows = []
    names = only or list(ARTIFACTS)
    for name in names:
        fn, specs = ARTIFACTS[name]
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        outs = jax.eval_shape(fn, *specs)
        out_desc = ";".join(
            f"{o.dtype}:{','.join(map(str, o.shape))}" for o in outs
        )
        in_desc = ";".join(
            f"{s.dtype}:{','.join(map(str, s.shape))}" for s in specs
        )
        manifest_rows.append(f"{name}\t{in_desc}\t{out_desc}")
        print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(out_dir, "manifest.tsv"), "w") as f:
        f.write("\n".join(manifest_rows) + "\n")
    print(f"wrote {out_dir}/manifest.tsv ({len(manifest_rows)} artifacts)")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", nargs="*", default=None,
                    help="subset of artifact names")
    args = ap.parse_args()
    build(args.out_dir, args.only)


if __name__ == "__main__":
    main()
