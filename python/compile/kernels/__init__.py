"""L1 kernels: Pallas FIP/FFIP GEMMs (`ffip`) and the pure-jnp oracle
(`ref`). Build-time only — lowered to HLO text by ``compile.aot``."""

from . import ffip, ref  # noqa: F401
