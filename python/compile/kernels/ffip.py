"""Layer-1 Pallas kernels for the FIP / FFIP fast inner-product GEMMs.

These kernels express the paper's arithmetic rearrangement (trade half the
multiplications for pre-additions, Eqs. 2 and 7) as Pallas GEMM kernels.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper tiles a
systolic array; here BlockSpec tiles (M, N) output blocks with a K-grid
accumulating partial products, the VMEM analog of holding a b/y tile in
the array while a-tiles stream through.  The alpha/beta corrections are
applied *per K-block* (partial corrections sum to the full correction), so
the accumulation pattern matches the hardware's running accumulators.

All kernels run with ``interpret=True``: real-TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute; interpret mode lowers to
plain HLO that the Rust runtime loads and runs (rust/src/runtime/mod.rs).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

__all__ = [
    "fip_gemm",
    "ffip_gemm",
    "baseline_gemm",
    "ffip_gemm_from_y",
    "pad_to_multiple",
]


def pad_to_multiple(x: jax.Array, mults: tuple[int, int]) -> jax.Array:
    """Zero-pad a 2-D array so each dim is a multiple of ``mults``.

    Zero padding is exact for all three algorithms: padded a/b rows and
    columns contribute zero products and zero alpha/beta corrections.
    """
    m, n = x.shape
    pm = (-m) % mults[0]
    pn = (-n) % mults[1]
    if pm == 0 and pn == 0:
        return x
    return jnp.pad(x, ((0, pm), (0, pn)))


def _acc_dtype(dtype) -> jnp.dtype:
    return jnp.int32 if jnp.issubdtype(dtype, jnp.integer) else jnp.float32


def _baseline_kernel(a_ref, b_ref, o_ref):
    """Eq. (1) per block: plain MAC accumulation over the K grid axis."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    acc = o_ref.dtype
    o_ref[...] += jnp.dot(
        a_ref[...].astype(acc), b_ref[...].astype(acc),
        preferred_element_type=acc,
    )


def _fip_kernel(a_ref, b_ref, o_ref):
    """Eq. (2) per block: K/2 pair-products minus partial alpha/beta.

    Partial corrections over each K block sum to the full Eq. (3)/(4)
    corrections, so accumulating (products - alpha_part - beta_part) per
    block yields the exact FIP result.
    """
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    acc = o_ref.dtype
    a = a_ref[...].astype(acc)  # (bm, bk)
    b = b_ref[...].astype(acc)  # (bk, bn)
    a_odd, a_even = a[:, 0::2], a[:, 1::2]  # (bm, bk/2)
    b_odd, b_even = b[0::2, :], b[1::2, :]  # (bk/2, bn)
    lhs = a_odd[:, :, None] + b_even[None, :, :]
    rhs = a_even[:, :, None] + b_odd[None, :, :]
    prod = jnp.sum(lhs * rhs, axis=1)  # (bm, bn): bk/2 mults per element
    alpha_part = jnp.sum(a_odd * a_even, axis=1)  # (bm,)
    beta_part = jnp.sum(b_odd * b_even, axis=0)  # (bn,)
    o_ref[...] += prod - alpha_part[:, None] - beta_part[None, :]


def _ffip_kernel(a_ref, y_ref, o_ref, *, subtract_beta: bool):
    """Eqs. (7)-(9) per block: g-recurrence over the j (column) axis.

    ``y_ref`` holds the y-matrix block (Eq. 9, recurrence restarted at
    this block's first column, as the hardware re-seeds g per loaded
    tile).  The cumulative sum over j realizes g^{(j)} = g^{(j-1)} + y_j;
    it also reconstructs b for the partial beta correction.

    ``subtract_beta=False`` gives the Eq. (16) form where beta was folded
    into the layer bias (the output is then c' + beta).
    """
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    acc = o_ref.dtype
    a = a_ref[...].astype(acc)  # (bm, bk)
    y = y_ref[...].astype(acc)  # (bk, bn)
    bm, bk = a.shape
    # Eqs. (8a)/(8b): the a operand entering g-lane k is the other element
    # of its pair.
    a_swapped = jnp.stack([a[:, 1::2], a[:, 0::2]], axis=2).reshape(bm, bk)
    # g^{(j)} = a_swapped + sum_{j'<=j} y_{:,j'}  (the free-pipeline
    # recurrence, realized as a prefix sum over the column axis).
    g = a_swapped[:, :, None] + jnp.cumsum(y, axis=1)[None, :, :]
    prod = jnp.sum(g[:, 0::2, :] * g[:, 1::2, :], axis=1)  # (bm, bn)
    alpha_part = jnp.sum(a[:, 0::2] * a[:, 1::2], axis=1)
    out = prod - alpha_part[:, None]
    if subtract_beta:
        b = jnp.cumsum(y, axis=1)  # reconstructed b block
        beta_part = jnp.sum(b[0::2, :] * b[1::2, :], axis=0)
        out = out - beta_part[None, :]
    o_ref[...] += out


def _tiled_call(kernel, a, b_or_y, block_m, block_n, block_k, interpret):
    m, k = a.shape
    k2, n = b_or_y.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0, (
        f"shape ({m},{k})x({k},{n}) not divisible by blocks "
        f"({block_m},{block_n},{block_k}); use pad_to_multiple"
    )
    assert block_k % 2 == 0, "K block must be even (pair reduction)"
    acc = _acc_dtype(a.dtype)
    grid = (m // block_m, n // block_n, k // block_k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), acc),
        interpret=interpret,
    )(a, b_or_y)


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_k", "interpret")
)
def baseline_gemm(a, b, *, block_m=64, block_n=64, block_k=64,
                  interpret=True):
    """Eq. (1) tiled baseline GEMM (comparison reference kernel)."""
    return _tiled_call(_baseline_kernel, a, b, block_m, block_n, block_k,
                       interpret)


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_k", "interpret")
)
def fip_gemm(a, b, *, block_m=64, block_n=64, block_k=64, interpret=True):
    """Eq. (2) tiled FIP GEMM: K/2 multiplications per output element."""
    return _tiled_call(_fip_kernel, a, b, block_m, block_n, block_k,
                       interpret)


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "interpret",
                     "subtract_beta"),
)
def ffip_gemm(a, b, *, block_m=64, block_n=64, block_k=64, interpret=True,
              subtract_beta=True):
    """Eqs. (7)-(9) tiled FFIP GEMM.

    y is precomputed from b at trace time (paper §3.3: y is a function of
    the weights and can be precomputed after training), with the
    recurrence restarted every ``block_n`` columns to match tile loads.
    """
    # y needs one extra bit vs b (paper §4.4: "precomputed at the cost of
    # storing them in 1 extra bit") — widen before differencing.
    y = ref.y_from_b(b.astype(_acc_dtype(b.dtype)), tile_n=block_n)
    kernel = functools.partial(_ffip_kernel, subtract_beta=subtract_beta)
    return _tiled_call(kernel, a, y, block_m, block_n, block_k, interpret)


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "interpret",
                     "subtract_beta"),
)
def ffip_gemm_from_y(a, y, *, block_m=64, block_n=64, block_k=64,
                     interpret=True, subtract_beta=True):
    """FFIP GEMM taking the precomputed y matrix directly (offline-y mode,
    paper §4.4: 'precomputed at the cost of storing them in 1 extra bit')."""
    kernel = functools.partial(_ffip_kernel, subtract_beta=subtract_beta)
    return _tiled_call(kernel, a, y, block_m, block_n, block_k, interpret)
