"""Pure-jnp reference oracle for the FIP / FFIP inner-product algorithms.

This module is the correctness ground truth for the Pallas kernels in
``ffip.py`` and for the Rust cycle-level simulator (which cross-checks the
same identities in ``rust/src/algo``).  Everything here follows the paper's
equations literally:

* Eq. (1)  baseline inner product            -> :func:`baseline_matmul`
* Eqs. (2)-(4)  FIP                          -> :func:`fip_matmul`
* Eqs. (7)-(9)  FFIP (recurrence form)       -> :func:`ffip_matmul`
* Eq. (9)  y-matrix construction             -> :func:`y_from_b`
* Eqs. (5)-(6)  operation counts             -> :func:`op_counts`
* Eq. (15)  beta folding into biases         -> :func:`fold_beta_into_bias`

The FFIP recurrence is implemented with ``jax.lax.scan`` over the output
column index j, mirroring how the g terms propagate between adjacent PE
columns in the hardware (paper Fig. 1c), rather than algebraically
simplifying it away.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "baseline_matmul",
    "alpha_terms",
    "beta_terms",
    "fip_matmul",
    "y_from_b",
    "ffip_matmul",
    "fold_beta_into_bias",
    "op_counts",
]


def _acc_dtype(x: jax.Array):
    """Accumulator dtype: int32 for integer inputs (2w + clog2(X) widening
    in hardware), float32 otherwise."""
    return jnp.int32 if jnp.issubdtype(x.dtype, jnp.integer) else jnp.float32


def baseline_matmul(a: jax.Array, b: jax.Array) -> jax.Array:
    """Eq. (1): traditional inner product, C = A @ B."""
    acc = _acc_dtype(a)
    return jnp.matmul(a.astype(acc), b.astype(acc))


def alpha_terms(a: jax.Array) -> jax.Array:
    """Eq. (3): alpha_i = sum_k a_{i,2k-1} * a_{i,2k} (1-indexed pairs).

    Shape (M,). Odd K is zero-padded by one column (exact: the padded
    element contributes a zero product), matching the kernels' padding.
    """
    a = a.astype(_acc_dtype(a))
    if a.shape[1] % 2:
        a = jnp.pad(a, ((0, 0), (0, 1)))
    return jnp.sum(a[:, 0::2] * a[:, 1::2], axis=1)


def beta_terms(b: jax.Array) -> jax.Array:
    """Eq. (4): beta_j = sum_k b_{2k-1,j} * b_{2k,j}. Shape (N,).

    Odd K is zero-padded by one row (exact), matching the kernels."""
    b = b.astype(_acc_dtype(b))
    if b.shape[0] % 2:
        b = jnp.pad(b, ((0, 1), (0, 0)))
    return jnp.sum(b[0::2, :] * b[1::2, :], axis=0)


def fip_matmul(a: jax.Array, b: jax.Array) -> jax.Array:
    """Eq. (2): Winograd's 1968 Fast Inner Product.

    c_{i,j} = sum_{k=1}^{K/2} (a_{i,2k-1} + b_{2k,j})(a_{i,2k} + b_{2k-1,j})
              - alpha_i - beta_j

    Implemented in the literal product form (pair-sums then multiply), the
    same compute pattern the FIP PE performs, so it exercises the halved
    multiplication count rather than simplifying to A @ B.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2 and k % 2 == 0, f"K must match and be even, got {k}, {k2}"
    acc = _acc_dtype(a)
    a = a.astype(acc)
    b = b.astype(acc)
    a_odd, a_even = a[:, 0::2], a[:, 1::2]  # (M, K/2), 1-indexed odd/even
    b_odd, b_even = b[0::2, :], b[1::2, :]  # (K/2, N)
    # (M, K/2, N) pairwise products -- K/2 multiplications per (i, j).
    lhs = a_odd[:, :, None] + b_even[None, :, :]
    rhs = a_even[:, :, None] + b_odd[None, :, :]
    prod = jnp.sum(lhs * rhs, axis=1)
    return prod - alpha_terms(a)[:, None] - beta_terms(b)[None, :]


def y_from_b(b: jax.Array, tile_n: int | None = None) -> jax.Array:
    """Eq. (9): y_{i,1} = b_{i,1}; y_{i,j} = b_{i,j} - b_{i,j-1} for j > 1.

    ``tile_n`` restarts the recurrence every ``tile_n`` columns, mirroring
    the hardware where each b/y tile loaded into the MXU re-seeds the g
    recurrence at its first PE column.  ``None`` = single tile.
    """
    n = b.shape[1]
    t = n if tile_n is None else tile_n
    shifted = jnp.pad(b, ((0, 0), (1, 0)))[:, :-1]
    y = b - shifted
    # Columns at tile boundaries restart: y[:, j] = b[:, j].
    restart = (jnp.arange(n) % t) == 0
    return jnp.where(restart[None, :], b, y)


def ffip_matmul(a: jax.Array, b: jax.Array) -> jax.Array:
    """Eqs. (7)-(9): Free-pipeline Fast Inner Product, recurrence form.

    The g terms are propagated column-to-column with ``lax.scan`` exactly
    as they flow between adjacent PE columns in Fig. 1c:

        g^{(1)}_{i,2k-1} = a_{i,2k}   + y_{2k-1,1}
        g^{(1)}_{i,2k}   = a_{i,2k-1} + y_{2k,1}
        g^{(j)}_{i,k}    = g^{(j-1)}_{i,k} + y_{k,j}
        c_{i,j} = sum_k g^{(j)}_{i,2k-1} g^{(j)}_{i,2k} - alpha_i - beta_j
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2 and k % 2 == 0
    acc = _acc_dtype(a)
    a = a.astype(acc)
    b = b.astype(acc)
    y = y_from_b(b)

    # Swapped-pair base: the a operand entering g-lane k is the *other*
    # element of its pair (Eqs. 8a/8b).
    a_swapped = jnp.stack([a[:, 1::2], a[:, 0::2]], axis=2).reshape(m, k)

    def step(g_prev, y_col):
        g = g_prev + y_col[None, :]
        c_col = jnp.sum(g[:, 0::2] * g[:, 1::2], axis=1)
        return g, c_col

    _, c_cols = jax.lax.scan(step, a_swapped, y.T)
    c = c_cols.T  # (M, N)
    return c - alpha_terms(a)[:, None] - beta_terms(b)[None, :]


def fold_beta_into_bias(bias: jax.Array, b: jax.Array) -> jax.Array:
    """Eq. (15): bias_j <- bias_j - beta_j (beta precomputed from weights)."""
    return bias - beta_terms(b).astype(bias.dtype)


def op_counts(m: int, n: int, k: int, algo: str) -> dict[str, int]:
    """Eqs. (1), (5), (6): multiplication/addition counts for even K.

    Cross-checked against rust/src/algo/counts.rs by the test suites.
    """
    assert k % 2 == 0, "counts derived for even K"
    if algo == "baseline":
        return {"mults": m * n * k, "adds": m * n * (k - 1)}
    if algo in ("fip", "ffip"):
        mults = (m * n * k + m * k + n * k) // 2
        adds = (3 * m * n * k + m * k + n * k) // 2 - m * n - m - n
        if algo == "ffip":
            # Eq. (9): Theta(NK) extra subtractions to form y.
            adds += n * k
        return {"mults": mults, "adds": adds}
    raise ValueError(f"unknown algo {algo!r}")
