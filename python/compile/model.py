"""Layer-2 JAX compute graphs built on the L1 FFIP Pallas kernels.

Everything that runs on the accelerator decomposes to matrix
multiplication (the paper's premise): convolutions are mapped to GEMM with
an im2col that mirrors the Rust memory tiler's in-place mapping
(Algorithm 1), fully-connected layers map directly, and the attention
block maps its two batched matmuls.  All GEMMs execute through the FFIP
Pallas kernel so the AOT-lowered HLO exercises the paper's arithmetic.

Quantization follows §3.3/§4.4: symmetric int8 (both operands signed, so
d = 1), int32 accumulation, beta folded into the bias (Eq. 15/16), and
per-layer requantization in the Post-GEMM stage.

Build-time only: lowered to HLO text by ``compile.aot``; never imported on
the Rust request path.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import ffip as k
from .kernels import ref

# Block shape shared with the Rust tiler (64x64 effective MXU tiles).
BLOCK = dict(block_m=32, block_n=32, block_k=32)


def gemm(a: jax.Array, b: jax.Array, algo: str = "ffip",
         subtract_beta: bool = True, block=None) -> jax.Array:
    """Tiled GEMM through the selected L1 kernel, padding to block size."""
    blk = dict(BLOCK if block is None else block)
    m, kk = a.shape
    _, n = b.shape
    blk["block_m"] = min(blk["block_m"], _ceil_pow2(m))
    blk["block_n"] = min(blk["block_n"], _ceil_pow2(n))
    blk["block_k"] = max(2, min(blk["block_k"], _ceil_pow2(kk)))
    ap = k.pad_to_multiple(a, (blk["block_m"], blk["block_k"]))
    bp = k.pad_to_multiple(b, (blk["block_k"], blk["block_n"]))
    if algo == "ffip":
        out = k.ffip_gemm(ap, bp, subtract_beta=subtract_beta, **blk)
    elif algo == "fip":
        out = k.fip_gemm(ap, bp, **blk)
    elif algo == "baseline":
        out = k.baseline_gemm(ap, bp, **blk)
    else:
        raise ValueError(f"unknown algo {algo!r}")
    return out[:m, :n]


def _ceil_pow2(x: int) -> int:
    p = 1
    while p < x:
        p *= 2
    return p


# ---------------------------------------------------------------------------
# Conv -> GEMM mapping (jnp analog of the Algorithm 1 memory tiler)
# ---------------------------------------------------------------------------

def im2col(x: jax.Array, kh: int, kw: int, stride: int = 1,
           pad: int = 0) -> tuple[jax.Array, tuple[int, int]]:
    """Unfold NHWC input into the (M, K) GEMM operand.

    M = N * OH * OW, K = KH * KW * Cin — the same loop nest order as the
    paper's Algorithm 1 counters (kh, kw, cin innermost along K).
    Returns the matrix and the (OH, OW) output spatial dims.
    """
    n, h, w, c = x.shape
    if pad:
        x = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    oh = (h + 2 * pad - kh) // stride + 1
    ow = (w + 2 * pad - kw) // stride + 1
    patches = []
    for i in range(kh):
        for j in range(kw):
            patches.append(
                jax.lax.slice(
                    x, (0, i, j, 0),
                    (n, i + (oh - 1) * stride + 1, j + (ow - 1) * stride + 1, c),
                    (1, stride, stride, 1),
                )
            )
    # (N, OH, OW, KH*KW*C) -> (N*OH*OW, KH*KW*C)
    cols = jnp.concatenate(patches, axis=-1)
    return cols.reshape(n * oh * ow, kh * kw * c), (oh, ow)


def weights_to_gemm(w: jax.Array) -> jax.Array:
    """HWIO conv weights -> (K, N) = (KH*KW*Cin, Cout) GEMM operand."""
    kh, kw, cin, cout = w.shape
    return w.reshape(kh * kw * cin, cout)


# ---------------------------------------------------------------------------
# Quantized layers (int8 symmetric, d = 1)
# ---------------------------------------------------------------------------

class QConvParams(NamedTuple):
    """One quantized conv/fc layer: int8 weights, folded int32 bias
    (bias - beta, Eq. 15), and the float requantization multiplier."""
    weight: jax.Array        # int8  (KH,KW,Cin,Cout) or (K,N) for fc
    bias_folded: jax.Array   # int32 (Cout,) = bias - beta(weights)
    requant: jax.Array       # f32 scalar: s_in * s_w / s_out


def make_qconv(rng: np.random.Generator, kh: int, kw: int, cin: int,
               cout: int, requant: float = 1.0 / 128.0) -> QConvParams:
    """Random-but-deterministic quantized layer with beta pre-folded."""
    w = rng.integers(-64, 64, (kh, kw, cin, cout)).astype(np.int8)
    bias = rng.integers(-512, 512, (cout,)).astype(np.int32)
    wg = weights_to_gemm(jnp.asarray(w))
    folded = ref.fold_beta_into_bias(jnp.asarray(bias), wg)
    return QConvParams(jnp.asarray(w), folded, jnp.float32(requant))


def qconv2d(x_i8: jax.Array, p: QConvParams, stride: int = 1, pad: int = 0,
            relu: bool = True, algo: str = "ffip") -> jax.Array:
    """Quantized conv: im2col -> FFIP GEMM (beta folded) -> bias ->
    requantize -> ReLU -> int8. x is NHWC int8 (carried as int32-safe)."""
    n = x_i8.shape[0]
    kh, kw, cin, cout = p.weight.shape
    a, (oh, ow) = im2col(x_i8.astype(jnp.int8), kh, kw, stride, pad)
    b = weights_to_gemm(p.weight)
    acc = gemm(a, b, algo=algo, subtract_beta=(algo != "ffip"))
    acc = acc + _effective_bias(p, b, algo)[None, :]
    out = _requantize(acc, p.requant, relu)
    return out.reshape(n, oh, ow, cout)


def qdense(x_i8: jax.Array, p: QConvParams, relu: bool = True,
           algo: str = "ffip") -> jax.Array:
    """Quantized fully-connected layer (weight stored as (1,1,K,N))."""
    b = weights_to_gemm(p.weight)
    acc = gemm(x_i8.astype(jnp.int8), b, algo=algo,
               subtract_beta=(algo != "ffip"))
    acc = acc + _effective_bias(p, b, algo)[None, :]
    return _requantize(acc, p.requant, relu)


def _effective_bias(p: QConvParams, b_gemm: jax.Array,
                    algo: str) -> jax.Array:
    """Biases are stored beta-folded (Eq. 15).  The FFIP path runs the
    kernel in the Eq. (16) form (output = c' + beta), so the folded bias
    restores c' + bias exactly.  Baseline/FIP kernels subtract beta
    internally, so the full bias (folded + beta) is re-derived."""
    if algo == "ffip":
        return p.bias_folded
    return p.bias_folded + ref.beta_terms(b_gemm)


def _requantize(acc_i32: jax.Array, m: jax.Array, relu: bool) -> jax.Array:
    """Post-GEMM unit: scale, round, saturate to int8 (+ optional ReLU)."""
    y = jnp.round(acc_i32.astype(jnp.float32) * m)
    if relu:
        y = jnp.maximum(y, 0.0)
    return jnp.clip(y, -128, 127).astype(jnp.int8)


def maxpool2d(x: jax.Array, size: int = 2, stride: int = 2) -> jax.Array:
    """NHWC max pool (runs beside the MXU in the Post-GEMM unit)."""
    if jnp.issubdtype(x.dtype, jnp.integer):
        init = jnp.asarray(jnp.iinfo(x.dtype).min, x.dtype)
    else:
        init = jnp.asarray(-jnp.inf, x.dtype)
    return jax.lax.reduce_window(
        x, init, jax.lax.max, (1, size, size, 1), (1, stride, stride, 1),
        "VALID")


# ---------------------------------------------------------------------------
# MiniCNN: the end-to-end quantized model artifact
# ---------------------------------------------------------------------------

class MiniCNNParams(NamedTuple):
    conv1: QConvParams
    conv2: QConvParams
    conv3: QConvParams
    fc: QConvParams


def make_mini_cnn(seed: int = 0, cin: int = 4, n_classes: int = 10
                  ) -> MiniCNNParams:
    rng = np.random.default_rng(seed)
    return MiniCNNParams(
        conv1=make_qconv(rng, 3, 3, cin, 16),
        conv2=make_qconv(rng, 3, 3, 16, 32),
        conv3=make_qconv(rng, 3, 3, 32, 32),
        fc=make_qconv(rng, 1, 1, 32 * 2 * 2, n_classes),  # 2x2x32 flattened
    )


def mini_cnn_forward(params: MiniCNNParams, x_i32: jax.Array,
                     algo: str = "ffip") -> jax.Array:
    """Quantized CNN forward. Input: (N,16,16,Cin) int32 carrying int8
    values (the PJRT boundary only speaks i32/f32). Output: f32 logits."""
    x = x_i32.astype(jnp.int8)
    x = qconv2d(x, params.conv1, pad=1, algo=algo)       # (N,16,16,16)
    x = maxpool2d(x)                                     # (N, 8, 8,16)
    x = qconv2d(x, params.conv2, pad=1, algo=algo)       # (N, 8, 8,32)
    x = maxpool2d(x)                                     # (N, 4, 4,32)
    x = qconv2d(x, params.conv3, pad=1, algo=algo)       # (N, 4, 4,32)
    x = maxpool2d(x)                                     # (N, 2, 2,32)
    x = x.reshape(x.shape[0], -1)                        # (N, 128)
    logits = qdense(x, params.fc, relu=False, algo=algo) # (N, 10) int8
    return logits.astype(jnp.float32)


# ---------------------------------------------------------------------------
# Transformer attention block on FFIP GEMMs (paper §1: applicable to
# "fully-connected, convolutional, recurrent, and attention/transformer")
# ---------------------------------------------------------------------------

def attention_ffip(q: jax.Array, kmat: jax.Array, v: jax.Array,
                   algo: str = "ffip") -> jax.Array:
    """Single-head attention with both matmuls through the (F)FIP kernel.

    q,k,v: (S, D) f32. Returns (S, D).
    """
    s, d = q.shape
    scores = gemm(q, kmat.T, algo=algo) / jnp.sqrt(jnp.float32(d))
    probs = jax.nn.softmax(scores, axis=-1)
    return gemm(probs, v, algo=algo)


def mlp_block_ffip(x: jax.Array, w1: jax.Array, w2: jax.Array,
                   algo: str = "ffip") -> jax.Array:
    """Transformer MLP block: two FFIP GEMMs with GELU between."""
    h = jax.nn.gelu(gemm(x, w1, algo=algo))
    return gemm(h, w2, algo=algo)


# ---------------------------------------------------------------------------
# Artifact entry points (shapes fixed at AOT time; see aot.py)
# ---------------------------------------------------------------------------

def ffip_gemm_f32_entry(a, b):
    return (gemm(a, b, algo="ffip"),)


def fip_gemm_f32_entry(a, b):
    return (gemm(a, b, algo="fip"),)


def baseline_gemm_f32_entry(a, b):
    return (gemm(a, b, algo="baseline"),)


def ffip_gemm_i32_entry(a, b):
    """int8-valued i32 tensors in, i32 accumulator out."""
    return (gemm(a.astype(jnp.int8), b.astype(jnp.int8), algo="ffip"),)


def ffip_gemm_i16_entry(a, b):
    """int16-valued i32 tensors in (the paper's 16-bit datapath),
    i32 accumulator out.

    Note: the hardware accumulates on 2w + clog2(X) = 38 bits; the jnp
    int32 accumulator caps exact operation at |values| <= ~2^12 for
    K = 64 (the runtime tests respect this bound)."""
    return (gemm(a.astype(jnp.int16), b.astype(jnp.int16), algo="ffip"),)


@functools.cache
def _cnn_params():
    return make_mini_cnn(seed=0)


def mini_cnn_entry(x):
    return (mini_cnn_forward(_cnn_params(), x),)


def attention_entry(q, kmat, v):
    return (attention_ffip(q, kmat, v),)
