"""AOT contract tests: every registered artifact lowers to HLO text the
runtime can rely on, the manifest matches jax.eval_shape, and lowering is
deterministic (same input -> same HLO), which `make artifacts` relies on
for no-op rebuilds."""

import os
import tempfile

import jax
import pytest

from compile import aot, model


def test_every_artifact_lowers_to_hlo_text():
    for name, (fn, specs) in aot.ARTIFACTS.items():
        lowered = jax.jit(fn).lower(*specs)
        text = aot.to_hlo_text(lowered)
        assert text.startswith("HloModule"), name
        # return_tuple=True: the root computation returns a tuple
        assert "ROOT" in text, name


def test_manifest_matches_eval_shape():
    with tempfile.TemporaryDirectory() as d:
        aot.build(d, only=["ffip_gemm_i32_64"])
        rows = open(os.path.join(d, "manifest.tsv")).read().strip()
        name, ins, outs = rows.split("\t")
        assert name == "ffip_gemm_i32_64"
        assert ins == "int32:64,64;int32:64,64"
        assert outs == "int32:64,64"
        assert os.path.exists(os.path.join(d, f"{name}.hlo.txt"))


def test_lowering_is_deterministic():
    fn, specs = aot.ARTIFACTS["ffip_gemm_f32_128"]
    t1 = aot.to_hlo_text(jax.jit(fn).lower(*specs))
    t2 = aot.to_hlo_text(jax.jit(fn).lower(*specs))
    assert t1 == t2


def test_artifact_shapes_cover_runtime_contract():
    # the Rust examples/serve path assumes mini_cnn_b4 is (4,16,16,4)
    # int32 -> (4,10) float32; fail loudly here if someone changes it
    fn, specs = aot.ARTIFACTS["mini_cnn_b4"]
    assert tuple(specs[0].shape) == (4, 16, 16, 4)
    out = jax.eval_shape(fn, *specs)
    assert tuple(out[0].shape) == (4, 10)
    assert out[0].dtype == "float32"


@pytest.mark.parametrize("name", list(aot.ARTIFACTS))
def test_entries_have_static_shapes(name):
    _, specs = aot.ARTIFACTS[name]
    for s in specs:
        assert all(isinstance(d, int) and d > 0 for d in s.shape), name


def test_mini_cnn_uses_ffip_by_default():
    """The artifact model must run the FFIP path (Eq. 16 beta-folded)."""
    params = model.make_mini_cnn(seed=0)
    import jax.numpy as jnp
    import numpy as np

    x = jnp.zeros((1, 16, 16, 4), jnp.int32)
    default = model.mini_cnn_forward(params, x)
    explicit = model.mini_cnn_forward(params, x, algo="ffip")
    np.testing.assert_array_equal(default, explicit)
