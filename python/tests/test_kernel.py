"""L1 correctness: Pallas FIP/FFIP kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes, dtypes, block shapes and value ranges; every
case asserts allclose (float) or exact equality (integer) against
``ref.baseline_matmul`` — the paper's central claim that FIP/FFIP compute
the identical GEMM.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ffip, ref

jax.config.update("jax_platform_name", "cpu")

KERNELS = {
    "baseline": ffip.baseline_gemm,
    "fip": ffip.fip_gemm,
    "ffip": ffip.ffip_gemm,
}


def _rand(rng, shape, dtype):
    if np.issubdtype(dtype, np.integer):
        info = np.iinfo(dtype)
        return jnp.asarray(
            rng.integers(info.min, info.max + 1, shape), dtype)
    return jnp.asarray(rng.standard_normal(shape), dtype)


# ---------------------------------------------------------------------------
# Fixed-shape smoke tests (fast, always run)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algo", ["baseline", "fip", "ffip"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.int8, jnp.int16])
def test_kernel_matches_oracle_square(algo, dtype):
    rng = np.random.default_rng(42)
    a = _rand(rng, (64, 64), dtype)
    b = _rand(rng, (64, 64), dtype)
    gold = ref.baseline_matmul(a, b)
    out = KERNELS[algo](a, b, block_m=32, block_n=32, block_k=32)
    if jnp.issubdtype(dtype, jnp.integer):
        np.testing.assert_array_equal(out, gold)
    else:
        np.testing.assert_allclose(out, gold, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("algo", ["fip", "ffip"])
def test_reference_forms_match_eq1(algo):
    """Eq. (2) and Eqs. (7)-(9) reference implementations == Eq. (1)."""
    rng = np.random.default_rng(7)
    a = _rand(rng, (33, 62), jnp.float32)
    b = _rand(rng, (62, 45), jnp.float32)
    fn = ref.fip_matmul if algo == "fip" else ref.ffip_matmul
    np.testing.assert_allclose(
        fn(a, b), ref.baseline_matmul(a, b), rtol=2e-4, atol=2e-4)


def test_ffip_equals_fip_exactly_int():
    """§3.2.1: FFIP's multiplied terms are identical to FIP's — on integer
    inputs the two algorithms must agree bit-exactly, not just allclose."""
    rng = np.random.default_rng(3)
    a = _rand(rng, (24, 32), jnp.int16)
    b = _rand(rng, (32, 16), jnp.int16)
    np.testing.assert_array_equal(ref.fip_matmul(a, b), ref.ffip_matmul(a, b))


def test_y_from_b_roundtrip():
    """cumsum(y) reconstructs b within each tile (Eq. 9 inverse)."""
    rng = np.random.default_rng(5)
    b = _rand(rng, (16, 24), jnp.float32)
    for tile_n in (24, 8, 4):
        y = ref.y_from_b(b, tile_n=tile_n)
        rec = np.concatenate(
            [np.cumsum(np.asarray(y[:, j:j + tile_n]), axis=1)
             for j in range(0, 24, tile_n)], axis=1)
        np.testing.assert_allclose(rec, b, rtol=1e-6, atol=1e-6)


def test_beta_folding():
    """Eq. (15)/(16): ffip(subtract_beta=False) + (bias - beta) ==
    ffip(subtract_beta=True) + bias."""
    rng = np.random.default_rng(11)
    a = _rand(rng, (32, 32), jnp.int8)
    b = _rand(rng, (32, 32), jnp.int8)
    bias = jnp.asarray(rng.integers(-100, 100, (32,)), jnp.int32)
    folded = ref.fold_beta_into_bias(bias, b)
    lhs = ffip.ffip_gemm(a, b, block_m=16, block_n=16, block_k=16,
                         subtract_beta=False) + folded[None, :]
    rhs = ffip.ffip_gemm(a, b, block_m=16, block_n=16, block_k=16,
                         subtract_beta=True) + bias[None, :]
    np.testing.assert_array_equal(lhs, rhs)


def test_zero_padding_is_exact():
    """pad_to_multiple preserves the valid region for all algorithms."""
    rng = np.random.default_rng(13)
    a = _rand(rng, (30, 42), jnp.float32)
    b = _rand(rng, (42, 26), jnp.float32)
    gold = ref.baseline_matmul(a, b)
    ap = ffip.pad_to_multiple(a, (16, 16))
    bp = ffip.pad_to_multiple(b, (16, 16))
    for algo, fn in KERNELS.items():
        out = fn(ap, bp, block_m=16, block_n=16, block_k=16)[:30, :26]
        np.testing.assert_allclose(out, gold, rtol=2e-4, atol=2e-4,
                                   err_msg=algo)


@pytest.mark.parametrize("m,n,k", [(2, 2, 2), (4, 6, 8), (10, 3, 20)])
@pytest.mark.parametrize("algo", ["baseline", "fip", "ffip"])
def test_op_counts_match_paper_equations(m, n, k, algo):
    c = ref.op_counts(m, n, k, algo)
    if algo == "baseline":
        assert c["mults"] == m * n * k
        assert c["adds"] == m * n * (k - 1)
    else:
        assert c["mults"] == (m * n * k + m * k + n * k) // 2
        base = (3 * m * n * k + m * k + n * k) // 2 - m * n - m - n
        assert c["adds"] == base + (n * k if algo == "ffip" else 0)
    if algo in ("fip", "ffip"):
        # the headline claim: ~half the multiplications for large MNK
        if m * n * k >= 8 * max(m * k, n * k):
            assert c["mults"] < 0.6 * m * n * k


# ---------------------------------------------------------------------------
# Hypothesis sweeps
# ---------------------------------------------------------------------------

even = st.integers(1, 6).map(lambda x: 2 * x)


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 40),
    n=st.integers(1, 40),
    k2=st.integers(1, 24),
    algo=st.sampled_from(["fip", "ffip"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_reference_sweep_float(m, n, k2, algo, seed):
    rng = np.random.default_rng(seed)
    k = 2 * k2
    a = _rand(rng, (m, k), jnp.float32)
    b = _rand(rng, (k, n), jnp.float32)
    fn = ref.fip_matmul if algo == "fip" else ref.ffip_matmul
    np.testing.assert_allclose(
        fn(a, b), ref.baseline_matmul(a, b), rtol=5e-4, atol=5e-4)


@settings(max_examples=15, deadline=None)
@given(
    bm=st.sampled_from([8, 16, 32]),
    bn=st.sampled_from([8, 16, 32]),
    bk=st.sampled_from([8, 16, 32]),
    mt=st.integers(1, 3),
    nt=st.integers(1, 3),
    kt=st.integers(1, 3),
    dtype=st.sampled_from([np.int8, np.int16]),
    algo=st.sampled_from(["baseline", "fip", "ffip"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_pallas_kernel_sweep_int_exact(bm, bn, bk, mt, nt, kt, dtype, algo,
                                       seed):
    """Block-shape / grid-shape sweep: integer results must be bit-exact."""
    rng = np.random.default_rng(seed)
    a = _rand(rng, (bm * mt, bk * kt), dtype)
    b = _rand(rng, (bk * kt, bn * nt), dtype)
    gold = ref.baseline_matmul(a, b)
    out = KERNELS[algo](a, b, block_m=bm, block_n=bn, block_k=bk)
    np.testing.assert_array_equal(out, gold)


@settings(max_examples=10, deadline=None)
@given(
    m=st.integers(1, 50),
    n=st.integers(1, 50),
    k=st.integers(1, 50),
    seed=st.integers(0, 2**31 - 1),
)
def test_pallas_ffip_arbitrary_shapes_via_padding(m, n, k, seed):
    """Arbitrary (M,N,K) through pad_to_multiple + FFIP kernel."""
    rng = np.random.default_rng(seed)
    a = _rand(rng, (m, k), jnp.float32)
    b = _rand(rng, (k, n), jnp.float32)
    gold = ref.baseline_matmul(a, b)
    ap = ffip.pad_to_multiple(a, (16, 16))
    bp = ffip.pad_to_multiple(b, (16, 16))
    out = ffip.ffip_gemm(ap, bp, block_m=16, block_n=16, block_k=16)[:m, :n]
    np.testing.assert_allclose(out, gold, rtol=5e-4, atol=5e-4)
