"""L2 correctness: conv->GEMM mapping, quantized layers, model shapes,
attention block — all against jax.lax reference convolutions / matmuls."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def _conv_ref(x, w, stride, pad):
    """jax.lax NHWC/HWIO conv in int32 as the conv ground truth."""
    return jax.lax.conv_general_dilated(
        x.astype(jnp.int32), w.astype(jnp.int32),
        window_strides=(stride, stride),
        padding=((pad, pad), (pad, pad)),
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


@pytest.mark.parametrize("stride,pad", [(1, 0), (1, 1), (2, 1), (2, 0)])
def test_im2col_matches_lax_conv(stride, pad):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.integers(-128, 128, (2, 9, 11, 3)), jnp.int8)
    w = jnp.asarray(rng.integers(-64, 64, (3, 3, 3, 5)), jnp.int8)
    a, (oh, ow) = model.im2col(x, 3, 3, stride, pad)
    b = model.weights_to_gemm(w)
    got = ref.baseline_matmul(a, b).reshape(2, oh, ow, 5)
    np.testing.assert_array_equal(got, _conv_ref(x, w, stride, pad))


def test_qconv_bias_and_requant_semantics():
    """qconv2d == lax conv + bias + round/clip requant, bit-exactly."""
    rng = np.random.default_rng(1)
    p = model.make_qconv(rng, 3, 3, 4, 8)
    x = jnp.asarray(rng.integers(-128, 128, (1, 8, 8, 4)), jnp.int8)
    got = model.qconv2d(x, p, stride=1, pad=1)
    acc = _conv_ref(x, p.weight, 1, 1)
    # bias_folded = bias - beta; FFIP(no beta sub) output = c + beta, so
    # reconstruct: c + bias = acc + bias. Gold uses the unfolded bias.
    bias = p.bias_folded + ref.beta_terms(model.weights_to_gemm(p.weight))
    y = jnp.round((acc + bias[None, None, None, :]).astype(jnp.float32)
                  * p.requant)
    gold = jnp.clip(jnp.maximum(y, 0), -128, 127).astype(jnp.int8)
    np.testing.assert_array_equal(got, gold)


@pytest.mark.parametrize("algo", ["baseline", "fip", "ffip"])
def test_mini_cnn_algo_equivalence(algo):
    """The model produces identical logits under all three algorithms —
    the paper's functional-equivalence claim at the full-model level."""
    params = model.make_mini_cnn(seed=0)
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.integers(-128, 128, (2, 16, 16, 4)), jnp.int32)
    gold = model.mini_cnn_forward(params, x, algo="baseline")
    got = model.mini_cnn_forward(params, x, algo=algo)
    np.testing.assert_array_equal(got, gold)


def test_mini_cnn_shapes_and_dtype():
    params = model.make_mini_cnn(seed=0)
    x = jnp.zeros((4, 16, 16, 4), jnp.int32)
    out = model.mini_cnn_forward(params, x)
    assert out.shape == (4, 10)
    assert out.dtype == jnp.float32


def test_attention_matches_plain_jnp():
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.standard_normal((64, 32)), jnp.float32)
    kk = jnp.asarray(rng.standard_normal((64, 32)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((64, 32)), jnp.float32)
    gold = jax.nn.softmax(q @ kk.T / jnp.sqrt(32.0), axis=-1) @ v
    got = model.attention_ffip(q, kk, v)
    np.testing.assert_allclose(got, gold, rtol=1e-3, atol=1e-3)


def test_mlp_block():
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.standard_normal((16, 32)), jnp.float32)
    w1 = jnp.asarray(rng.standard_normal((32, 64)), jnp.float32)
    w2 = jnp.asarray(rng.standard_normal((64, 32)), jnp.float32)
    gold = jax.nn.gelu(x @ w1) @ w2
    np.testing.assert_allclose(model.mlp_block_ffip(x, w1, w2), gold,
                               rtol=2e-3, atol=2e-3)


def test_maxpool_int8():
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.integers(-128, 128, (1, 4, 4, 2)), jnp.int8)
    out = model.maxpool2d(x)
    assert out.shape == (1, 2, 2, 2)
    xn = np.asarray(x)
    gold = xn.reshape(1, 2, 2, 2, 2, 2).transpose(0, 1, 3, 2, 4, 5)
    gold = gold.reshape(1, 2, 2, 4, 2).max(axis=3)
    np.testing.assert_array_equal(np.asarray(out), gold)


@settings(max_examples=10, deadline=None)
@given(
    h=st.integers(4, 12),
    w=st.integers(4, 12),
    cin=st.integers(1, 6),
    cout=st.integers(1, 8),
    kh=st.sampled_from([1, 3]),
    stride=st.sampled_from([1, 2]),
    seed=st.integers(0, 2**31 - 1),
)
def test_qconv_sweep_vs_lax(h, w, cin, cout, kh, stride, seed):
    rng = np.random.default_rng(seed)
    pad = kh // 2
    p = model.make_qconv(rng, kh, kh, cin, cout)
    x = jnp.asarray(rng.integers(-128, 128, (1, h, w, cin)), jnp.int8)
    got = model.qconv2d(x, p, stride=stride, pad=pad)
    acc = _conv_ref(x, p.weight, stride, pad)
    bias = p.bias_folded + ref.beta_terms(model.weights_to_gemm(p.weight))
    y = jnp.round((acc + bias[None, None, None, :]).astype(jnp.float32)
                  * p.requant)
    gold = jnp.clip(jnp.maximum(y, 0), -128, 127).astype(jnp.int8)
    np.testing.assert_array_equal(got, gold)
