"""Offline-y path (§4.4: y precomputed after training, stored with one
extra bit) and numeric edge cases for the L1 kernels."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ffip, ref


def test_ffip_gemm_from_y_matches_online():
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.integers(-128, 128, (32, 32)), jnp.int8)
    b = jnp.asarray(rng.integers(-128, 128, (32, 32)), jnp.int8)
    y = ref.y_from_b(b.astype(jnp.int32), tile_n=16)
    online = ffip.ffip_gemm(a, b, block_m=16, block_n=16, block_k=16)
    offline = ffip.ffip_gemm_from_y(a, y, block_m=16, block_n=16,
                                    block_k=16)
    np.testing.assert_array_equal(online, offline)


def test_extreme_int8_values_no_overflow():
    """Alternating ±127/-128 maximizes pair sums and y diffs — the
    worst case for the w+1-bit claims."""
    n = 32
    a = jnp.asarray(
        np.where(np.indices((n, n)).sum(0) % 2, 127, -128), jnp.int8)
    b = jnp.asarray(
        np.where(np.indices((n, n)).sum(0) % 2, -128, 127), jnp.int8)
    gold = ref.baseline_matmul(a, b)
    for fn in (ffip.fip_gemm, ffip.ffip_gemm):
        np.testing.assert_array_equal(
            fn(a, b, block_m=16, block_n=16, block_k=16), gold)


def test_zero_matrices():
    z = jnp.zeros((16, 16), jnp.int8)
    out = ffip.ffip_gemm(z, z, block_m=16, block_n=16, block_k=16)
    np.testing.assert_array_equal(out, jnp.zeros((16, 16), jnp.int32))


def test_identity_weights():
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.integers(-128, 128, (16, 16)), jnp.int8)
    eye = jnp.eye(16, dtype=jnp.int8)
    out = ffip.ffip_gemm(a, eye, block_m=16, block_n=16, block_k=16)
    np.testing.assert_array_equal(out, a.astype(jnp.int32))


@settings(max_examples=10, deadline=None)
@given(
    tile_n=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_y_tile_restart_consistency(tile_n, seed):
    """ffip_gemm's internal y restarts every block_n; the equivalent
    explicit y (same tile_n) through ffip_gemm_from_y must agree."""
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.integers(-64, 64, (16, 32)), jnp.int8)
    b = jnp.asarray(rng.integers(-64, 64, (32, 32)), jnp.int8)
    y = ref.y_from_b(b.astype(jnp.int32), tile_n=tile_n)
    got = ffip.ffip_gemm_from_y(a, y, block_m=16, block_n=tile_n,
                                block_k=16)
    np.testing.assert_array_equal(got, ref.baseline_matmul(a, b))


def test_f32_large_magnitude_stability():
    """Float FIP is known to lose precision when |a|,|b| are large and
    products cancel (the pair-product form squares the dynamic range);
    quantized inference avoids this by construction.  Assert the float
    error stays within the documented bound for unit-scale data."""
    rng = np.random.default_rng(2)
    a = jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)
    gold = np.asarray(ref.baseline_matmul(a, b), np.float64)
    got = np.asarray(
        ffip.ffip_gemm(a, b, block_m=32, block_n=32, block_k=32),
        np.float64)
    rel = np.abs(got - gold) / (np.abs(gold) + 1e-3)
    assert rel.max() < 1e-3, rel.max()
