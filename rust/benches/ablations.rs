//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! * A1 — weight-loader mechanism: Fig. 7 broadcast vs Fig. 8 localized
//!   (fmax + load-cycle trade, §5.2);
//! * A2 — layer-IO banking: B = 1 vs 2 vs 4 (tiler clock cap, §5.1.1);
//! * A3 — quantization signedness: d = 1 vs d = 2 (§4.4);
//! * A4 — y offline vs online: op-count delta of precomputing y (§3.3);
//! * A5 — beta folding: with vs without (extra output-stage subtractions);
//! * A6 — Tm (rows streamed per weight residency): load hiding threshold.
//!
//! Run: `cargo bench --bench ablations`

use ffip::algo::{op_counts, Algo};
use ffip::arith::FixedSpec;
use ffip::fpga::{fmax_mhz_with, Device, FreqParams};
use ffip::mxu::{LoaderKind, MxuConfig};
use ffip::nn::GemmShape;
use ffip::sched::timing::gemm_cycles;

fn main() {
    let gx = Device::arria10_gx1150();
    let spec = FixedSpec::signed(8);
    let p = FreqParams::default();

    println!("## A1 — weight-column loader (FFIP 64x64, GX 1150)\n");
    for (name, kind) in [
        ("Fig. 7 broadcast enable", LoaderKind::Broadcast),
        ("Fig. 8 localized enable", LoaderKind::Localized),
    ] {
        let f = fmax_mhz_with(&p, Algo::Ffip, spec, 64, 64, &gx, kind, 2);
        println!(
            "  {name:<26} fmax {f:>5.1} MHz   load {:>3} cycles/tile   fanout {}",
            kind.cycles_per_tile(65),
            kind.control_fanout(65)
        );
    }
    println!(
        "  -> localized loader wins: its 2x slower shifting hides under\n\
         compute whenever Tm >= 2Y, while broadcast fanout costs fmax.\n"
    );

    println!("## A2 — layer-IO banking (FFIP 64x64, GX 1150)\n");
    for banks in [1usize, 2, 4] {
        let f = fmax_mhz_with(
            &p,
            Algo::Ffip,
            spec,
            64,
            64,
            &gx,
            LoaderKind::Localized,
            banks,
        );
        println!("  B = {banks}: accelerator clock {f:>5.1} MHz");
    }
    println!(
        "  -> unbanked tilers (B=1) cap the whole accelerator at the\n\
         230 MHz counter fmax; B=2 frees the MXU's 388 MHz (§5.1.1).\n"
    );

    println!("## A3 — quantization signedness (FFIP 64x64, GX 1150)\n");
    for (name, s) in [
        ("both signed (d=1)", FixedSpec::signed(8)),
        ("mixed       (d=2)", FixedSpec::mixed(8)),
    ] {
        let u = ffip::fpga::estimate(Algo::Ffip, s, 64, 64, &gx);
        let f = fmax_mhz_with(
            &p,
            Algo::Ffip,
            s,
            64,
            64,
            &gx,
            LoaderKind::Localized,
            2,
        );
        println!(
            "  {name}: {:>6} ALMs  {:>6} regs  fmax {f:>5.1} MHz  (pair sums on {} bits)",
            u.alms,
            u.registers,
            s.pair_sum_bits()
        );
    }
    println!();

    println!("## A4 — y precomputed offline vs generated online (§3.3/§4.4)\n");
    let (m, n, k) = (3136u64, 256, 2304);
    let on = op_counts(m, n, k, Algo::Ffip);
    let off = ffip::algo::op_counts(m, n, k, Algo::Fip); // = offline-y FFIP
    println!(
        "  online y : {:>12} adds  (y generator in the datapath)",
        on.adds
    );
    println!(
        "  offline y: {:>12} adds  (+1 bit/weight of storage)",
        off.adds
    );
    println!(
        "  -> Θ(NK) = {} adds saved, negligible vs Θ(MNK); choose by\n\
         whether memory (1 extra bit) or adders are scarcer.\n",
        on.adds - off.adds
    );

    println!("## A5 — beta folding into biases (Eq. 15)\n");
    let without = m * n; // per-output beta subtractions on the MXU edge
    println!(
        "  without folding: {without} extra output-stage subtractions per GEMM"
    );
    println!(
        "  with folding   : 0 (beta merged into the bias add, Eq. 16)\n"
    );

    println!("## A7 — Winograd F(2,3) composed with FFIP (§6.2.2)\n");
    {
        use ffip::algo::winograd::winograd_mult_counts;
        let (oh, ow, cin, cout) = (56usize, 56, 64, 64);
        let (direct, wino) = winograd_mult_counts(oh, ow, cin, cout);
        println!(
            "  3x3 conv @{oh}x{ow}, {cin}->{cout} channels:"
        );
        println!("    direct conv mults          : {direct:>12}");
        println!(
            "    Winograd GEMM-stage mults  : {wino:>12}  ({:.2}x fewer)",
            direct as f64 / wino as f64
        );
        println!(
            "    ... on FFIP hardware       : {:>12}  physical multipliers\n\
             \x20   ({:.2}x total multiplier reduction vs direct baseline)\n",
            wino / 2,
            direct as f64 / (wino as f64 / 2.0)
        );
        println!(
            "  (winograd_conv3x3 in algo/winograd.rs executes the 16\n\
             \x20 elementwise stages as GEMMs through the FFIP tile path,\n\
             \x20 bit-exact vs direct convolution — the paper's point that\n\
             \x20 Winograd and FFIP compose.)\n"
        );
    }

    println!("## A6 — Tm sweep: weight-load hiding (FFIP 64x64)\n");
    let g = GemmShape::new(4096, 2304, 256);
    for tm in [32usize, 64, 128, 256, 1024, 4096] {
        let mut cfg = MxuConfig::new(Algo::Ffip, 64, 64, tm);
        cfg.loader = LoaderKind::Localized;
        // stream in Tm-row slices: timing model on an M=tm GEMM slice,
        // scaled to full M
        let slices = g.m.div_ceil(tm) as u64;
        let slice = GemmShape::new(tm, g.k, g.n);
        let t = gemm_cycles(slice, &cfg);
        let total = t.cycles * slices;
        let ideal = t.ideal_cycles * slices;
        println!(
            "  Tm = {tm:>4}: {total:>9} cycles  (utilization {:>5.1}%)",
            100.0 * ideal as f64 / total as f64
        );
    }
    println!(
        "  -> throughput saturates once Tm >= 2Y = 128 (§5.2's condition\n\
         for the every-other-cycle loader to hide)."
    );
}
