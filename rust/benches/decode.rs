//! H14 benches — autoregressive decode: KV-cached continuous batching
//! vs full recompute:
//!
//! * **H14a** single-sequence decode: L tokens decoded step by step
//!   against the KV cache vs re-prefilling the growing prefix once per
//!   token (the O(L²·d) recompute the cache eliminates).  Decode
//!   outputs are asserted bit-identical to the prefill rows *before*
//!   anything is timed — the speedup must be arithmetically free;
//! * **H14b** continuous batching fill: B staggered sequences sharing
//!   decode iterations vs decoding the same B sequences one at a time;
//!   the mean tokens-per-step fill is reported next to the clocks.
//!
//! Run: `cargo bench --bench decode`

use ffip::algo::Algo;
use ffip::bench_harness::{black_box, run_bench};
use ffip::coordinator::{
    compile, pack_ragged_row, DecodeScheduler, DeployConfig,
    InferenceSession, Model, PostGemm, TensorView,
};
use ffip::engine::GemmPool;
use ffip::nn::models;
use ffip::quant::QuantScheme;
use std::sync::Arc;

const SEQ: usize = 24;
const DIM: usize = 32;
const HEADS: usize = 4;
const BLOCKS: usize = 2;

fn transformer_model() -> Model {
    let mut model = Model::random(
        models::transformer(SEQ, DIM, HEADS, BLOCKS),
        0x1414,
        3,
    );
    let post = |n: usize, relu: bool| PostGemm {
        bias: vec![0; n],
        scheme: QuantScheme::symmetric_signed(8, 1.0 / 32.0),
        relu,
    };
    for b in 0..BLOCKS {
        model.set_post(5 * b, post(4 * DIM, false)).unwrap();
        model.set_post(5 * b + 2, post(4 * DIM, true)).unwrap();
        model.set_post(5 * b + 3, post(DIM, false)).unwrap();
    }
    model
}

fn prompt(s: u64, len: usize) -> Vec<i32> {
    (0..len * DIM)
        .map(|i| ((i as i64 + 3 * s as i64) % 7 - 3) as i32)
        .collect()
}

fn main() {
    let model = transformer_model();
    let pool = Arc::new(GemmPool::new(2));
    let compiled = compile(
        &model,
        DeployConfig::new(Algo::Ffip).with_tile(8, 8),
    )
    .unwrap();

    // correctness gate before any timing: decode == prefill, bit for bit
    let toks = prompt(1, SEQ);
    let mut sess = InferenceSession::new(&compiled, pool.clone());
    let packed = pack_ragged_row(&toks, DIM, SEQ);
    let want = sess
        .infer_batch(TensorView::new(1, packed.len(), &packed))
        .unwrap();
    let mut dec = DecodeScheduler::new(&compiled, pool.clone()).unwrap();
    dec.admit(1, &toks).unwrap();
    loop {
        let outs = dec.step().unwrap();
        if outs.is_empty() {
            break;
        }
        for o in outs {
            let w = &want.data[1 + o.pos * DIM..1 + (o.pos + 1) * DIM];
            let got: Vec<i64> =
                o.out.data.iter().map(|&v| v as i64).collect();
            let w: Vec<i64> = w.iter().map(|&v| v as i64).collect();
            assert_eq!(got, w, "KV decode != prefill at pos {}", o.pos);
        }
    }
    dec.retire(1).unwrap();
    println!(
        "## H14a — KV-cached decode vs full recompute \
         (FFIP int8, {BLOCKS} blocks, d={DIM}, L={SEQ})\n"
    );
    println!("  decode output asserted bit-identical to prefill first\n");

    run_bench(&format!("kv decode ({SEQ} tokens)"), 2, 10, || {
        dec.admit(1, &toks).unwrap();
        while !dec.step().unwrap().is_empty() {}
        dec.retire(1).unwrap();
    });
    // the cache-less alternative: re-run the whole growing prefix
    // through the prefill session once per emitted token
    run_bench(&format!("full recompute ({SEQ} tokens)"), 2, 10, || {
        for t in 1..=SEQ {
            let packed = pack_ragged_row(&toks[..t * DIM], DIM, SEQ);
            black_box(
                sess.infer_batch(TensorView::new(1, packed.len(), &packed))
                    .unwrap(),
            );
        }
    });

    // -- H14b: continuous batching fill --------------------------------
    const B: u64 = 6;
    const LEN: usize = 12;
    println!("\n## H14b — continuous batching: {B} sequences x {LEN} tokens\n");
    let m0 = dec.metrics();
    let batched = run_bench("batched decode (staggered admits)", 1, 10, || {
        // half the fleet joins up front, the rest mid-flight — each
        // step gathers every sequence holding a pending token
        for s in 0..B / 2 {
            dec.admit(s, &prompt(s, LEN)).unwrap();
        }
        for _ in 0..LEN / 2 {
            black_box(dec.step().unwrap());
        }
        for s in B / 2..B {
            dec.admit(s, &prompt(s, LEN)).unwrap();
        }
        while !dec.step().unwrap().is_empty() {}
        for s in 0..B {
            dec.retire(s).unwrap();
        }
    });
    // fill over the batched section only (the H14a runs above decoded
    // one sequence at a time and would dilute the mean)
    let m1 = dec.metrics();
    let fill =
        (m1.tokens - m0.tokens) as f64 / (m1.steps - m0.steps) as f64;
    let serial = run_bench("serial decode (one sequence at a time)", 1, 10, || {
        for s in 0..B {
            dec.admit(s, &prompt(s, LEN)).unwrap();
            while !dec.step().unwrap().is_empty() {}
            dec.retire(s).unwrap();
        }
    });
    assert!(fill > 1.0, "staggered admits must share steps, got {fill:.2}");
    println!(
        "\nmean fill {fill:.2} tokens/step; batched p50 {:?} vs serial p50 {:?}",
        batched.p50, serial.p50
    );
    let m = dec.metrics();
    assert_eq!(m.active_seqs, 0, "every benched sequence retired");
    println!(
        "engine totals: {} steps, {} tokens, {} admits, {} retires",
        m.steps, m.tokens, m.admitted, m.retired
    );
}
