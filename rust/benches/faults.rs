//! H15 benches — ABFT overhead on the serving path:
//!
//! * **H15a** checksummed serving vs `with_abft(false)`: the post-drain
//!   verify costs O(M·N + M·K) next to the O(M·N·K) GEMM it guards, so
//!   the two clocks should sit close together.  Both deployments are
//!   asserted bit-identical *before* anything is timed — the checksums
//!   must be arithmetically invisible;
//! * **H15b** the heal path: a transient accumulator corruption is
//!   injected every iteration, so each serve pays detect + scalar-oracle
//!   recompute on top of H15a.  The healed output is asserted bit-exact
//!   against the clean oracle first.
//!
//! Run: `cargo bench --bench faults`

use ffip::algo::Algo;
use ffip::bench_harness::{black_box, run_bench};
use ffip::coordinator::{
    compile, DeployConfig, InferenceSession, Model, PostGemm, TensorView,
};
use ffip::engine::{FaultKind, FaultPlan, GemmPool};
use ffip::nn::models;
use ffip::quant::QuantScheme;
use std::sync::Arc;

const BATCH: usize = 32;
const DIMS: [usize; 4] = [256, 256, 128, 32];

fn main() {
    let mut model = Model::random(models::mlp(&DIMS), 0x1515, 3);
    for (idx, &cout) in DIMS[1..].iter().enumerate() {
        model
            .set_post(
                idx,
                PostGemm {
                    bias: vec![0; cout],
                    scheme: QuantScheme::symmetric_signed(8, 1.0 / 32.0),
                    relu: idx + 2 < DIMS.len(),
                },
            )
            .unwrap();
    }
    let input: Vec<i32> =
        (0..BATCH * DIMS[0]).map(|i| (i % 5) as i32 - 2).collect();
    let view = || TensorView::new(BATCH, DIMS[0], &input);

    let cfg = DeployConfig::new(Algo::Ffip).with_tile(8, 8).with_batch(BATCH);
    let pool = Arc::new(GemmPool::new(2));
    let on = compile(&model, cfg).unwrap();
    let off = compile(&model, cfg.with_abft(false)).unwrap();
    let mut sess_on = InferenceSession::new(&on, pool.clone());
    let mut sess_off = InferenceSession::new(&off, pool.clone());

    // correctness gate before any timing: the checksums change nothing
    let want = sess_on.infer_batch(view()).unwrap().data;
    let got = sess_off.infer_batch(view()).unwrap().data;
    assert_eq!(got, want, "ABFT must be arithmetically invisible");
    let counts = sess_on.take_fault_counts();
    assert_eq!(counts.detected, 0, "clean run trips nothing: {counts:?}");

    println!(
        "## H15a — ABFT checksummed serving vs unchecked \
         (FFIP int8 MLP {DIMS:?}, batch {BATCH})\n"
    );
    println!("  outputs asserted bit-identical before timing\n");
    run_bench("serve, abft on (verify every gemm)", 3, 20, || {
        black_box(sess_on.infer_batch(view()).unwrap());
    });
    run_bench("serve, abft off", 3, 20, || {
        black_box(sess_off.infer_batch(view()).unwrap());
    });

    // -- H15b: the heal path -------------------------------------------
    println!("\n## H15b — detect + recompute under a transient fault\n");
    pool.install_fault_plan(FaultPlan::new(FaultKind::AccCorrupt));
    let healed = sess_on.infer_batch(view()).unwrap().data;
    assert_eq!(healed, want, "healed output is bit-exact");
    let counts = sess_on.take_fault_counts();
    assert!(
        counts.detected >= 1 && counts.recovered == counts.detected,
        "the injected corruption was caught and healed: {counts:?}"
    );
    println!("  healed output asserted bit-exact before timing\n");
    run_bench("serve + heal one corrupted gemm", 3, 20, || {
        // re-arm the one-shot plan so every iteration pays the
        // detect-and-recompute path
        pool.install_fault_plan(FaultPlan::new(FaultKind::AccCorrupt));
        black_box(sess_on.infer_batch(view()).unwrap());
    });
    pool.clear_fault_plan();
    let counts = sess_on.take_fault_counts();
    assert!(counts.recomputes >= 1, "{counts:?}");
    println!(
        "\nheal-path totals: {} detected, {} recovered, {} recomputes \
         (all transient, nothing shed)",
        counts.detected, counts.recovered, counts.recomputes
    );
}
