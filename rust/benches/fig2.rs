//! Regenerates paper Fig. 2: PE register requirements vs bitwidth for
//! FIP (Eq. 17), FIP + input registers (Eq. 18) and FFIP (Eq. 19), at
//! X = 64, d = 1.
//!
//! Run: `cargo bench --bench fig2`

use ffip::report::experiments;

fn main() {
    let (table, chart) = experiments::fig2();
    println!("{}", table.render());
    println!("{chart}");
    println!(
        "paper check: FFIP costs a constant 4 extra bits over plain FIP\n\
         and far less than frequency-matched FIP (Eq. 18) for w >= 4;\n\
         the FFIP/FIP overhead ratio grows only below w = 4."
    );
}
