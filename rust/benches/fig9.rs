//! Regenerates paper Fig. 9: baseline / FIP / FFIP MXUs at sizes
//! 32..=80 (step 8) instantiated in the example accelerator system on
//! the Arria 10 SX 660, 8-bit inputs — ALMs, registers, memories, DSPs,
//! clock frequency and ResNet-50 throughput per design point.  Curves
//! stop where the device's DSPs run out (baseline: 56x56).
//!
//! Run: `cargo bench --bench fig9`

use ffip::fpga::Device;
use ffip::report::experiments;

fn main() {
    let device = Device::arria10_sx660();
    let (table, charts) = experiments::fig9(&device, 8);
    println!("{}", table.render());
    for c in charts {
        println!("{c}");
    }
    println!(
        "paper checks: (F)FIP ~ half the baseline DSPs at equal effective\n\
         size; FIP fmax ~30% below baseline; FFIP fmax recovers to\n\
         baseline's; baseline tops out at 56x56 while (F)FIP reach 80x80."
    );
}
