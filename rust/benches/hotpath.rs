//! Hot-path microbenchmarks for the perf pass (EXPERIMENTS.md §Perf):
//!
//! * H1 — register-level MXU simulator throughput (PE-ticks/s);
//! * H2 — functional tiled GEMM (the coordinator's fast path);
//! * H3 — memory tiler address generation rate;
//! * H4 — PJRT artifact execution latency (128x128 FFIP GEMM, MiniCNN);
//! * H5 — whole-network timing-model evaluation (ResNet-152);
//! * H6 — persistent-pool engine vs per-call thread spawning
//!   (spawn-per-call `tiled_matmul_parallel` against
//!   `engine::GemmPool` on the same FFIP GEMMs; target >= 1.5x on the
//!   large shape — results logged in EXPERIMENTS.md §Perf);
//! * H7 — serving-abstraction overhead: a single-layer
//!   `InferenceSession` batch vs the direct `GemmPool::gemm` it wraps
//!   (same GEMM, same pool, same tile plan), so the cost of the
//!   `Model → CompiledModel → InferenceSession` pipeline is tracked;
//! * H8 — narrow vs wide datapath: the same FFIP GEMMs and the same
//!   quantized MLP on `i8` storage (i16 offline y, i32 accumulators)
//!   against the historical all-`i64` staging — operand bytes moved
//!   (exact, from the type widths) and wall time (results logged in
//!   EXPERIMENTS.md §Perf);
//! * H9 — replica-sharded serving throughput: the same int8 MLP
//!   deployed with 1 / 2 / 4 session replicas (pipeline-overlapped
//!   staging) on one shared pool, closed request bursts drained
//!   end-to-end — replicas keep multiple batches in flight, so req/s
//!   should scale until the pool saturates (results logged in
//!   EXPERIMENTS.md §Perf);
//! * H10 — vector vs scalar item kernels: `engine::item_gemm` on the
//!   production dispatch (u64-packed SWAR lanes on stable,
//!   `std::simd` under `--features portable_simd`) against the forced
//!   scalar reference, per algorithm × narrow width, bit-exactness
//!   self-asserted before every timed pair (results logged in
//!   EXPERIMENTS.md §Perf);
//! * H11 — compiled attention serving: a quantized attention layer
//!   through `InferenceSession` per algorithm — QKᵀ and AV take two
//!   activation operands, so FFIP's y transform runs **online** on the
//!   request critical path — plus a ragged closed burst through a
//!   2-replica Router deployment (results logged in EXPERIMENTS.md
//!   §Perf).
//!
//! Run: `cargo bench --bench hotpath`

use ffip::algo::{
    tiled_matmul, tiled_matmul_parallel, y_from_b, Algo, ElemKind, Mat,
    TileShape,
};
use ffip::arith::FixedSpec;
use ffip::bench_harness::{black_box, run_bench};
use ffip::coordinator::{
    compile, pack_ragged_row, DeployConfig, InferenceSession, Model,
    PostGemm, Router, Storage, TensorView,
};
use ffip::quant::QuantScheme;
use ffip::engine::{item_gemm, GemmPool, KernelPath};
use ffip::memory::{ConvShape, Im2Gemm};
use ffip::mxu::{MxuConfig, MxuSim};
use ffip::nn::{models, Graph, Layer};
use ffip::runtime::{Input, Runtime};
use ffip::sched;
use ffip::util::Rng;
use std::path::Path;
use std::sync::Arc;

fn main() {
    let mut rng = Rng::new(99);

    // H1: cycle simulator
    let a = Mat::from_fn(64, 64, |_, _| rng.fixed(8, true));
    let b = Mat::from_fn(64, 64, |_, _| rng.fixed(8, true));
    for algo in Algo::ALL {
        let mut sim =
            MxuSim::new(MxuConfig::new(algo, 32, 32, 64), FixedSpec::signed(8));
        sim.check_ranges = false;
        let r = run_bench(
            &format!("H1 mxu_sim 64^3 gemm ({})", algo.name()),
            2,
            10,
            || {
                let (c, _) = sim.gemm(black_box(&a), black_box(&b));
                black_box(c);
            },
        );
        // PE-ticks/s: ticks = cycles * physical PEs
        let (cols, rows) = (sim.cfg.cols(), sim.cfg.rows());
        let (_, stats) = sim.gemm(&a, &b);
        let ticks =
            stats.cycles_unoverlapped as f64 * (cols * rows) as f64;
        println!(
            "     -> {:.1} M PE-ticks/s",
            ticks / r.p50.as_secs_f64() / 1e6
        );
    }

    // H2: functional tiled GEMM (256^3)
    let a2 = Mat::from_fn(256, 256, |_, _| rng.fixed(8, true));
    let b2 = Mat::from_fn(256, 256, |_, _| rng.fixed(8, true));
    for algo in Algo::ALL {
        let r = run_bench(
            &format!("H2 tiled_matmul 256^3 ({})", algo.name()),
            2,
            10,
            || {
                black_box(tiled_matmul(
                    black_box(&a2),
                    black_box(&b2),
                    algo,
                    TileShape::square(64, 64),
                ));
            },
        );
        let macs = 256f64.powi(3);
        println!(
            "     -> {:.1} M MAC/s",
            macs / r.p50.as_secs_f64() / 1e6
        );
    }

    // H2b: parallel tiled GEMM (the coordinator's batched fast path)
    let a_wide = Mat::from_fn(512, 256, |_, _| rng.fixed(8, true));
    for threads in [1usize, 2, 4] {
        let r = run_bench(
            &format!("H2b tiled_matmul_parallel 512x256x256 t={threads}"),
            1,
            6,
            || {
                black_box(ffip::algo::tiled_matmul_parallel(
                    black_box(&a_wide),
                    black_box(&b2),
                    Algo::Ffip,
                    TileShape::square(64, 64),
                    threads,
                ));
            },
        );
        let macs = 512.0 * 256.0 * 256.0;
        println!(
            "     -> {:.1} M MAC/s",
            macs / r.min.as_secs_f64() / 1e6
        );
    }

    // H3: tiler address generation
    let ig = Im2Gemm::new(
        ConvShape {
            h: 56,
            w: 56,
            cin: 64,
            cout: 64,
            kh: 3,
            kw: 3,
            stride: 1,
            pad: 1,
        },
        64,
    );
    let n_addrs = ig.program().len();
    let r = run_bench("H3 tiler addresses (3x3x64 conv @56^2)", 2, 20, || {
        let mut t = ig.program();
        let mut acc = 0i64;
        while let Some(a) = t.next_addr() {
            acc = acc.wrapping_add(a);
        }
        black_box(acc);
    });
    println!(
        "     -> {:.1} M addr/s ({n_addrs} addresses)",
        n_addrs as f64 / r.p50.as_secs_f64() / 1e6
    );

    // H4: PJRT execution latency
    match Runtime::new(Path::new("artifacts")) {
        Ok(mut rt) => {
            let gemm = rt.load("ffip_gemm_f32_128").expect("artifact");
            let x: Vec<f32> = (0..128 * 128)
                .map(|_| rng.fixed(8, true) as f32 / 64.0)
                .collect();
            run_bench("H4 pjrt ffip_gemm_f32_128", 3, 20, || {
                let out = gemm
                    .run_f32(&[
                        Input::F32(black_box(x.clone())),
                        Input::F32(black_box(x.clone())),
                    ])
                    .unwrap();
                black_box(out);
            });
            let cnn = rt.load("mini_cnn_b4").expect("artifact");
            let img: Vec<i32> = (0..4 * 16 * 16 * 4)
                .map(|_| rng.fixed(7, true) as i32)
                .collect();
            run_bench("H4 pjrt mini_cnn_b4 (batch 4)", 3, 20, || {
                let out =
                    cnn.run_f32(&[Input::I32(black_box(img.clone()))]).unwrap();
                black_box(out);
            });
        }
        Err(e) => println!("H4 skipped (no artifacts: {e})"),
    }

    // H5: timing-model evaluation
    let g = models::resnet152();
    run_bench("H5 network_timing ResNet-152", 2, 20, || {
        black_box(sched::network_timing(
            black_box(&g),
            Algo::Ffip,
            64,
            64,
            388.0,
        ));
    });

    // H6: persistent-pool engine vs per-call thread spawning.  Same
    // FFIP GEMMs, same compute-thread budget: the submitter helps the
    // pool while it waits, so a pool of (threads - 1) workers plus the
    // helping submitter equals the spawn path's `threads` (whose
    // submitter idles in join).  The pool adds no spawn, no per-tile
    // allocation, and claims fine-grained (M-band x N-tile) items
    // instead of 'threads' coarse M bands.
    let threads = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(8)
        .min(8);
    let pool = GemmPool::new(threads.saturating_sub(1));
    let shape64 = TileShape::square(64, 64);
    let b_big = Mat::from_fn(1024, 1024, |_, _| rng.fixed(8, true));

    // serving-shaped: one accelerator batch (M = 64) against a large
    // weight matrix — the coordinator's per-request workload.  The
    // spawn path has a single M band here and degenerates to serial;
    // the pool still spreads the 16 N tiles across its workers.
    let a_srv = Mat::from_fn(64, 1024, |_, _| rng.fixed(8, true));
    let r_spawn = run_bench(
        &format!("H6 spawn-per-call 64x1024x1024 FFIP t={threads}"),
        1,
        6,
        || {
            black_box(tiled_matmul_parallel(
                black_box(&a_srv),
                black_box(&b_big),
                Algo::Ffip,
                shape64,
                threads,
            ));
        },
    );
    let r_pool = run_bench(
        &format!("H6 engine-pool    64x1024x1024 FFIP t={threads}"),
        1,
        6,
        || {
            black_box(pool.gemm(
                black_box(&a_srv),
                black_box(&b_big),
                Algo::Ffip,
                shape64,
            ));
        },
    );
    let macs = 64.0 * 1024.0 * 1024.0;
    println!(
        "     -> spawn {:.1} M MAC/s | pool {:.1} M MAC/s | speedup {:.2}x",
        macs / r_spawn.min.as_secs_f64() / 1e6,
        macs / r_pool.min.as_secs_f64() / 1e6,
        r_spawn.min.as_secs_f64() / r_pool.min.as_secs_f64()
    );

    // large square GEMM: 1024^3 (the EXPERIMENTS.md §Perf anchor; the
    // acceptance target for the pool is >= 1.5x over spawn-per-call)
    let a_big = Mat::from_fn(1024, 1024, |_, _| rng.fixed(8, true));
    let r_spawn2 = run_bench(
        &format!("H6 spawn-per-call 1024^3 FFIP t={threads}"),
        1,
        3,
        || {
            black_box(tiled_matmul_parallel(
                black_box(&a_big),
                black_box(&b_big),
                Algo::Ffip,
                shape64,
                threads,
            ));
        },
    );
    let r_pool2 = run_bench(
        &format!("H6 engine-pool    1024^3 FFIP t={threads}"),
        1,
        3,
        || {
            black_box(pool.gemm(
                black_box(&a_big),
                black_box(&b_big),
                Algo::Ffip,
                shape64,
            ));
        },
    );
    let macs2 = 1024f64.powi(3);
    let speedup = r_spawn2.min.as_secs_f64() / r_pool2.min.as_secs_f64();
    println!(
        "     -> spawn {:.1} M MAC/s | pool {:.1} M MAC/s | speedup {:.2}x \
         (target >= 1.5x; record in EXPERIMENTS.md §Perf)",
        macs2 / r_spawn2.min.as_secs_f64() / 1e6,
        macs2 / r_pool2.min.as_secs_f64() / 1e6,
        speedup
    );
    let s = pool.shutdown();
    println!(
        "     -> pool counters: {} jobs, {} items, peak queue {}",
        s.jobs, s.items, s.peak_queue_depth
    );

    // H7: serving-abstraction overhead.  A one-layer compiled model
    // batch through InferenceSession performs exactly one pool GEMM
    // plus staging/activation copies; comparing against the direct
    // GemmPool::gemm on the same shape, pool and tile plan prices the
    // session abstraction per request.
    let pool7 = Arc::new(GemmPool::new(threads.saturating_sub(1)));
    let (k7, n7, batch7) = (512usize, 256usize, 8usize);
    let model7 = Model::random(models::mlp(&[k7, n7]), 7, 8);
    let cfg7 = DeployConfig::new(Algo::Ffip).with_tile(64, 64).with_batch(batch7);
    let compiled7 = compile(&model7, cfg7).expect("compiles");
    let tile7 = compiled7.layer(0).expect("one layer").tile;
    let w7 = model7.layer_weights(0).expect("fc weights").w.clone();
    let mut sess7 = InferenceSession::new(&compiled7, pool7.clone());
    let input7: Vec<i32> = (0..batch7 * k7)
        .map(|_| rng.fixed(7, true) as i32)
        .collect();
    let a7 = Mat::from_fn(batch7, k7, |i, j| i64::from(input7[i * k7 + j]));
    let r_direct = run_bench(
        &format!("H7 direct pool GEMM {batch7}x{k7}x{n7} FFIP"),
        2,
        20,
        || {
            black_box(pool7.gemm(
                black_box(&a7),
                black_box(&w7),
                Algo::Ffip,
                tile7,
            ));
        },
    );
    let r_sess = run_bench(
        &format!("H7 1-layer session  {batch7}x{k7}x{n7} FFIP"),
        2,
        20,
        || {
            let out = sess7
                .infer_batch(TensorView::new(
                    batch7,
                    k7,
                    black_box(&input7),
                ))
                .unwrap();
            black_box(out);
        },
    );
    let d = r_direct.min.as_secs_f64();
    let s7 = r_sess.min.as_secs_f64();
    println!(
        "     -> direct {:.1} us | session {:.1} us | abstraction \
         overhead {:.1}% ({:.2} us/request; record in EXPERIMENTS.md \
         §Perf)",
        d * 1e6,
        s7 * 1e6,
        100.0 * (s7 - d) / d,
        (s7 - d) * 1e6 / batch7 as f64
    );

    // H8: narrow vs wide datapath.  (a) the serving-shaped FFIP GEMM
    // (64x1024x1024, 64x64 tiles, offline y) on i8 storage (i16 y, i32
    // accumulators) against the same values widened to i64 — identical
    // math, 1/8 the A/B operand bytes; (b) the same quantized 3-layer
    // MLP compiled to i8 storage (Storage::Auto) vs force-compiled to
    // i64, through identical InferenceSessions.
    let pool8 = GemmPool::new(threads.saturating_sub(1));
    let (m8, k8, n8) = (64usize, 1024usize, 1024usize);
    let a8 = Mat::from_fn(m8, k8, |_, _| rng.fixed(8, true) as i8);
    let b8 = Mat::from_fn(k8, n8, |_, _| rng.fixed(8, true) as i8);
    let (a64, b64) = (a8.widen(), b8.widen());
    let y8 = y_from_b(&b8, 64); // Mat<i16>: the §4.4 one-extra-bit storage
    let y64 = y_from_b(&b64, 64);
    let mut c_n: Mat<i32> = Mat::zeros(0, 0);
    let mut c_w: Mat<i64> = Mat::zeros(0, 0);
    let r_wide = run_bench(
        &format!("H8 i64 {m8}x{k8}x{n8} FFIP offline-y"),
        1,
        8,
        || {
            pool8.gemm_into(
                black_box(&a64),
                black_box(&b64),
                Some(black_box(&y64)),
                &mut c_w,
                Algo::Ffip,
                shape64,
            );
        },
    );
    let r_narrow = run_bench(
        &format!("H8 i8  {m8}x{k8}x{n8} FFIP offline-y"),
        1,
        8,
        || {
            pool8.gemm_into(
                black_box(&a8),
                black_box(&b8),
                Some(black_box(&y8)),
                &mut c_n,
                Algo::Ffip,
                shape64,
            );
        },
    );
    assert_eq!(c_n.widen(), c_w, "narrow GEMM must be bit-exact");
    // exact operand traffic from the type widths: A + B (+ offline y)
    let ab_elems = (m8 * k8 + k8 * n8) as f64;
    let y_elems = (k8 * n8) as f64;
    let op_narrow = ab_elems * 1.0 + y_elems * 2.0;
    let op_wide = ab_elems * 8.0 + y_elems * 8.0;
    println!(
        "     -> operand bytes (A+B+y): i8 {:.2} MiB vs i64 {:.2} MiB \
         = {:.3}x (A+B alone: 0.125x) | wall: i8 {:.1} ms vs i64 \
         {:.1} ms, speedup {:.2}x (record in EXPERIMENTS.md §Perf)",
        op_narrow / (1 << 20) as f64,
        op_wide / (1 << 20) as f64,
        op_narrow / op_wide,
        r_narrow.min.as_secs_f64() * 1e3,
        r_wide.min.as_secs_f64() * 1e3,
        r_wide.min.as_secs_f64() / r_narrow.min.as_secs_f64()
    );

    // (b) whole-model serving: int8 MLP on i8 vs forced-i64 storage
    let mut model8 = Model::random(models::mlp(&[512, 256, 64]), 8, 8);
    let mut brng = Rng::new(88);
    for (idx, cout) in [256usize, 64].into_iter().enumerate() {
        let bias: Vec<i64> =
            (0..cout).map(|_| brng.fixed(9, true)).collect();
        model8
            .set_post(
                idx,
                PostGemm {
                    bias,
                    scheme: QuantScheme::symmetric_signed(8, 1.0 / 1024.0),
                    relu: idx == 0,
                },
            )
            .expect("post binds");
    }
    let cfg8 =
        DeployConfig::new(Algo::Ffip).with_tile(64, 64).with_batch(batch7);
    let narrow = compile(&model8, cfg8).expect("compiles");
    assert_eq!(narrow.storage(), ElemKind::I8, "auto-selects i8");
    let wide = compile(&model8, cfg8.with_storage(Storage::I64))
        .expect("compiles");
    let mut sess_n = InferenceSession::new(&narrow, pool7.clone());
    let mut sess_w = InferenceSession::new(&wide, pool7.clone());
    let input8: Vec<i32> = (0..batch7 * 512)
        .map(|_| rng.fixed(8, true) as i32)
        .collect();
    let r_sn = run_bench("H8 i8  session 2-layer int8 MLP b=8", 2, 20, || {
        let out = sess_n
            .infer_batch(TensorView::new(batch7, 512, black_box(&input8)))
            .unwrap();
        black_box(out);
    });
    let r_sw = run_bench("H8 i64 session 2-layer int8 MLP b=8", 2, 20, || {
        let out = sess_w
            .infer_batch(TensorView::new(batch7, 512, black_box(&input8)))
            .unwrap();
        black_box(out);
    });
    println!(
        "     -> stationary operand bytes: i8 {} vs i64 {} ({:.3}x) | \
         wall: i8 {:.1} us vs i64 {:.1} us, speedup {:.2}x (record in \
         EXPERIMENTS.md §Perf)",
        narrow.stationary_bytes(),
        wide.stationary_bytes(),
        narrow.stationary_bytes() as f64 / wide.stationary_bytes() as f64,
        r_sn.min.as_secs_f64() * 1e6,
        r_sw.min.as_secs_f64() * 1e6,
        r_sw.min.as_secs_f64() / r_sn.min.as_secs_f64()
    );

    // H9: replica-sharded serving throughput on one shared pool.  The
    // same int8 MLP deployed with 1, 2 and 4 session replicas
    // (pipeline-overlapped staging on): a closed burst of requests is
    // pushed through the full submit -> batcher -> replica -> response
    // path and drained.  One replica holds one batch in flight; more
    // replicas overlap batches on the shared pool, so req/s should
    // scale until the pool saturates.
    let pool9 = Arc::new(GemmPool::new(threads.saturating_sub(1)));
    let n_req = 128usize;
    for replicas in [1usize, 2, 4] {
        let cfg9 = DeployConfig::new(Algo::Ffip)
            .with_tile(64, 64)
            .with_batch(8)
            .with_linger(std::time::Duration::from_millis(1))
            .with_replicas(replicas);
        let compiled9 = compile(&model8, cfg9).expect("compiles");
        let mut router = Router::with_engine(pool9.clone());
        router.deploy_model("m", compiled9).expect("deploys");
        let mut rng9 = Rng::new(9 + replicas as u64);
        let r = run_bench(
            &format!(
                "H9 serve burst {n_req} int8 MLP b=8 replicas={replicas}"
            ),
            1,
            5,
            || {
                let rxs: Vec<_> = (0..n_req)
                    .map(|_| {
                        let input: Vec<i32> = (0..512)
                            .map(|_| rng9.fixed(7, true) as i32)
                            .collect();
                        router.submit("m", input).expect("deployed")
                    })
                    .collect();
                for rx in rxs {
                    black_box(rx.recv().expect("response").output());
                }
            },
        );
        let s = router.undeploy("m").expect("deployed");
        println!(
            "     -> {:.0} req/s | {} batches split {:?} across {replicas} \
             replica(s) (record in EXPERIMENTS.md §Perf)",
            n_req as f64 / r.min.as_secs_f64(),
            s.batches,
            s.replicas.iter().map(|x| x.batches).collect::<Vec<_>>()
        );
    }

    // H10: vector vs scalar item kernels.  The same single-threaded
    // item sweep (engine::item_gemm — the raw per-item compute, no pool
    // scheduling) on the production dispatch (SWAR lanes on stable,
    // std::simd under --features portable_simd) against the forced
    // scalar reference, per algorithm and per narrow width.  Each pair
    // self-asserts bit-exactness before timing; lines are ready to
    // paste into EXPERIMENTS.md §Perf (H10).
    let (m10, k10, n10) = (64usize, 512usize, 256usize);
    let shape10 = TileShape { x: 64, y: 64, tm: 16 };
    let macs10 = (m10 * k10 * n10) as f64;
    let a10_8 = Mat::from_fn(m10, k10, |_, _| rng.fixed(8, true) as i8);
    let b10_8 = Mat::from_fn(k10, n10, |_, _| rng.fixed(8, true) as i8);
    let a10_16 = Mat::from_fn(m10, k10, |_, _| rng.fixed(16, true) as i16);
    let b10_16 = Mat::from_fn(k10, n10, |_, _| rng.fixed(16, true) as i16);
    let h10 = |label: &str, run_scalar: &dyn Fn(), run_auto: &dyn Fn()| {
        let r_s = run_bench(
            &format!("H10 scalar {label} {m10}x{k10}x{n10}"),
            1,
            6,
            || run_scalar(),
        );
        let r_v = run_bench(
            &format!("H10 vector {label} {m10}x{k10}x{n10}"),
            1,
            6,
            || run_auto(),
        );
        println!(
            "     -> H10 {label}: scalar {:.1} M MAC/s | vector {:.1} \
             M MAC/s | speedup {:.2}x (record in EXPERIMENTS.md §Perf)",
            macs10 / r_s.min.as_secs_f64() / 1e6,
            macs10 / r_v.min.as_secs_f64() / 1e6,
            r_s.min.as_secs_f64() / r_v.min.as_secs_f64()
        );
    };
    for algo in Algo::ALL {
        // bit-exactness gate before timing
        assert_eq!(
            item_gemm(&a10_8, &b10_8, None, algo, shape10, KernelPath::Auto),
            item_gemm(&a10_8, &b10_8, None, algo, shape10, KernelPath::Scalar),
            "H10 i8 {algo:?} vector != scalar"
        );
        assert_eq!(
            item_gemm(&a10_16, &b10_16, None, algo, shape10, KernelPath::Auto),
            item_gemm(&a10_16, &b10_16, None, algo, shape10, KernelPath::Scalar),
            "H10 i16 {algo:?} vector != scalar"
        );
        h10(
            &format!("i8  {}", algo.name()),
            &|| {
                black_box(item_gemm(
                    black_box(&a10_8),
                    black_box(&b10_8),
                    None,
                    algo,
                    shape10,
                    KernelPath::Scalar,
                ));
            },
            &|| {
                black_box(item_gemm(
                    black_box(&a10_8),
                    black_box(&b10_8),
                    None,
                    algo,
                    shape10,
                    KernelPath::Auto,
                ));
            },
        );
        // i16 baseline has no vector arm (a single 16-bit product
        // already fills the 32-bit lane — see engine/simd.rs), so Auto
        // == Scalar there; timing it would log a meaningless ~1.00x
        if algo != Algo::Baseline {
            h10(
                &format!("i16 {}", algo.name()),
                &|| {
                    black_box(item_gemm(
                        black_box(&a10_16),
                        black_box(&b10_16),
                        None,
                        algo,
                        shape10,
                        KernelPath::Scalar,
                    ));
                },
                &|| {
                    black_box(item_gemm(
                        black_box(&a10_16),
                        black_box(&b10_16),
                        None,
                        algo,
                        shape10,
                        KernelPath::Auto,
                    ));
                },
            );
        }
    }
    // offline-y FFIP, the serving hot path, i8
    let y10 = y_from_b(&b10_8, shape10.y);
    assert_eq!(
        item_gemm(&a10_8, &b10_8, Some(&y10), Algo::Ffip, shape10, KernelPath::Auto),
        item_gemm(&a10_8, &b10_8, Some(&y10), Algo::Ffip, shape10, KernelPath::Scalar),
        "H10 i8 offline-y vector != scalar"
    );
    h10(
        "i8  ffip+offline-y",
        &|| {
            black_box(item_gemm(
                black_box(&a10_8),
                black_box(&b10_8),
                Some(black_box(&y10)),
                Algo::Ffip,
                shape10,
                KernelPath::Scalar,
            ));
        },
        &|| {
            black_box(item_gemm(
                black_box(&a10_8),
                black_box(&b10_8),
                Some(black_box(&y10)),
                Algo::Ffip,
                shape10,
                KernelPath::Auto,
            ));
        },
    );

    // H11: compiled attention serving.  QKᵀ and AV take two activation
    // operands, so under FFIP the y transform runs **online** on the
    // request critical path (y_from_b_into into per-layer scratch) —
    // unlike every GEMM above, where y is offline or absent.  (a) a
    // full-length batch through InferenceSession per algorithm — the
    // baseline/FIP vs FFIP gap prices the online transform; (b) a
    // ragged closed burst through a 2-replica Router deployment.
    let (heads11, d_head11, max_seq11) = (4usize, 16usize, 32usize);
    let d11 = heads11 * d_head11;
    let row_len11 = 1 + max_seq11 * d11;
    let batch11 = 4usize;
    let mut model11 = Model::random(
        Graph {
            name: "attn".into(),
            layers: vec![Layer::Attention {
                name: "attn0".into(),
                heads: heads11,
                d_model: d11,
                d_head: d_head11,
                max_seq: max_seq11,
                causal: false,
            }],
        },
        11,
        8,
    );
    let bias11: Vec<i64> =
        (0..4 * d11).map(|_| brng.fixed(6, true)).collect();
    model11
        .set_post(
            0,
            PostGemm {
                bias: bias11,
                scheme: QuantScheme::symmetric_signed(8, 1.0 / 64.0),
                relu: false,
            },
        )
        .expect("post binds");
    // full-length rows: the worst-case online-y volume per request
    let mut rng11 = Rng::new(0x11);
    let input11: Vec<i32> = (0..batch11)
        .flat_map(|_| {
            let tokens: Vec<i32> = (0..max_seq11 * d11)
                .map(|_| rng11.fixed(7, true) as i32)
                .collect();
            pack_ragged_row(&tokens, d11, max_seq11)
        })
        .collect();
    // MACs per batch: 4 projections (s*d*d each) + QKᵀ + AV (s*s*d each)
    let s11 = max_seq11 as f64;
    let macs11 = batch11 as f64
        * (4.0 * s11 * (d11 * d11) as f64 + 2.0 * s11 * s11 * d11 as f64);
    for algo in Algo::ALL {
        let cfg11 =
            DeployConfig::new(algo).with_tile(16, 16).with_batch(batch11);
        let compiled11 = compile(&model11, cfg11).expect("compiles");
        let mut sess11 = InferenceSession::new(&compiled11, pool9.clone());
        let r = run_bench(
            &format!(
                "H11 attention session b={batch11} s={max_seq11} d={d11} \
                 ({})",
                algo.name()
            ),
            1,
            8,
            || {
                let out = sess11
                    .infer_batch(TensorView::new(
                        batch11,
                        row_len11,
                        black_box(&input11),
                    ))
                    .unwrap();
                black_box(out);
            },
        );
        println!(
            "     -> {:.1} M MAC/s ({}; record in EXPERIMENTS.md §Perf)",
            macs11 / r.min.as_secs_f64() / 1e6,
            if algo == Algo::Ffip {
                "online y on the critical path"
            } else {
                "no y transform"
            }
        );
    }
    let n_req11 = 32usize;
    let cfg11r = DeployConfig::new(Algo::Ffip)
        .with_tile(16, 16)
        .with_batch(batch11)
        .with_linger(std::time::Duration::from_millis(1))
        .with_replicas(2);
    let compiled11r = compile(&model11, cfg11r).expect("compiles");
    let mut router11 = Router::with_engine(pool9.clone());
    router11.deploy_model("attn", compiled11r).expect("deploys");
    let r11 = run_bench(
        &format!("H11 serve ragged burst {n_req11} attention replicas=2"),
        1,
        5,
        || {
            let rxs: Vec<_> = (0..n_req11)
                .map(|i| {
                    let s = i % (max_seq11 + 1);
                    let tokens: Vec<i32> = (0..s * d11)
                        .map(|_| rng11.fixed(7, true) as i32)
                        .collect();
                    router11
                        .submit(
                            "attn",
                            pack_ragged_row(&tokens, d11, max_seq11),
                        )
                        .expect("deployed")
                })
                .collect();
            for rx in rxs {
                black_box(rx.recv().expect("response").output());
            }
        },
    );
    let s11r = router11.undeploy("attn").expect("deployed");
    println!(
        "     -> {:.0} req/s | {} batches split {:?} across 2 replicas \
         (record in EXPERIMENTS.md §Perf)",
        n_req11 as f64 / r11.min.as_secs_f64(),
        s11r.batches,
        s11r.replicas.iter().map(|x| x.batches).collect::<Vec<_>>()
    );
}
