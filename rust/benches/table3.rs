//! Regenerates paper Table 3: prior-work columns are published
//! constants (rust/src/data/prior_works.rs); the "Ours" rows are
//! produced live by the resource/frequency models + the deterministic
//! timing analysis on the FFIP 64x64 accelerator.
//!
//! Run: `cargo bench --bench table3`

use ffip::report::experiments;

fn main() {
    println!("{}", experiments::comparison_table(3).render());
}
