//! H12 benches — the design-space autotuner, measured end to end:
//!
//! * **H12a** search latency: full-axis `tune_graph` sweeps (per-layer
//!   algorithm x MXU geometry x batch x replicas) over real model
//!   graphs — the closed compile-time loop is only usable if the search
//!   itself is cheap;
//! * **H12b** tuned vs heuristic serving: the same quantized MLP
//!   deployed twice — the fixed `DeployConfig` heuristic vs
//!   `DeployConfig::auto_tune` — driven with identical requests.
//!   Outputs are asserted bit-identical *before* anything is timed
//!   (tuning must never change arithmetic); wall clocks and the
//!   analytical projection are reported side by side;
//! * **H12c** calibration loop: H12b's measured wall clock folds back
//!   into a [`CalPoint`] and the rescaled projection of the same
//!   winning configuration is printed — the measurement-driven half of
//!   the loop EXPERIMENTS.md §H12 describes.
//!
//! Run: `cargo bench --bench tuner`

use ffip::algo::Algo;
use ffip::bench_harness::{black_box, run_bench};
use ffip::coordinator::{DeployConfig, Model, PostGemm, Router};
use ffip::fpga::Device;
use ffip::nn::models;
use ffip::quant::QuantScheme;
use ffip::tune::{autotune, tune_graph, CalPoint, Calibration, TuneBudget};

/// A fully-requantized int8 MLP: large enough that geometry matters,
/// small enough that serving iterations stay in bench territory.
fn quantized_mlp(seed: u64) -> Model {
    let dims = [256usize, 192, 128, 64, 10];
    let mut model = Model::random(models::mlp(&dims), seed, 4);
    for (idx, &cout) in dims[1..].iter().enumerate() {
        model
            .set_post(
                idx,
                PostGemm {
                    bias: vec![0; cout],
                    scheme: QuantScheme::symmetric_signed(8, 0.25),
                    relu: idx + 2 < dims.len(),
                },
            )
            .unwrap();
    }
    model
}

fn main() {
    let gx = Device::arria10_gx1150();
    let sx = Device::arria10_sx660();

    println!("## H12a — tune_graph search latency (8-bit, full axes)\n");
    for (graph, device) in
        [(models::resnet50(), sx), (models::resnet152(), gx)]
    {
        let budget = TuneBudget::new(device);
        run_bench(
            &format!("tune {} on {}", graph.name, device.name),
            1,
            5,
            || {
                black_box(tune_graph(&graph, 8, &budget).unwrap());
            },
        );
    }

    println!("\n## H12b — tuned vs heuristic serving (quantized MLP)\n");
    let model = quantized_mlp(7);
    // batch pinned to 1 so neither deployment waits out batcher linger
    // on this sequential driver — the comparison is pure geometry
    let budget =
        TuneBudget::new(gx).with_batch(1).with_max_replicas(1);
    let plan = autotune(&model, &budget).unwrap();
    println!(
        "projected: tuned {:.1} inf/s vs heuristic {:.1} inf/s ({:.2}x)",
        plan.score.throughput,
        plan.heuristic.score.throughput,
        plan.speedup()
    );
    let mut r = Router::new();
    r.deploy_model(
        "heuristic",
        model
            .compile(DeployConfig::new(Algo::Ffip).with_batch(1))
            .unwrap(),
    )
    .unwrap();
    r.deploy_model(
        "tuned",
        model.compile(DeployConfig::auto_tune(budget)).unwrap(),
    )
    .unwrap();
    let inputs: Vec<Vec<i32>> = (0..16)
        .map(|q| (0..256).map(|i| ((i * 3 + q * 17) % 19) - 9).collect())
        .collect();
    // bit-exactness self-check before anything is timed
    for inp in &inputs {
        let a = r.infer("heuristic", inp.clone()).unwrap().output();
        let b = r.infer("tuned", inp.clone()).unwrap().output();
        assert_eq!(a.data, b.data, "tuning changed arithmetic");
    }
    let mut measured = Vec::new();
    for name in ["heuristic", "tuned"] {
        let res =
            run_bench(&format!("serve 16 requests ({name})"), 2, 10, || {
                for inp in &inputs {
                    black_box(r.infer(name, inp.clone()).unwrap().output());
                }
            });
        measured.push(res);
    }

    println!("\n## H12c — calibration from the measured wall clock\n");
    // fold the tuned deployment's per-image wall time back into the
    // cycle model at the clock the projection assumed
    let predicted: u64 = plan.layers.iter().map(|l| l.cycles).sum();
    let per_image = measured[1].p50 / inputs.len() as u32;
    let point = CalPoint::from_wall_clock(
        plan.dominant_algo(),
        predicted,
        per_image,
        plan.fmax_mhz,
    );
    let cal = Calibration::from_measurements(&[point]);
    println!(
        "measured/predicted cycle scale for {}: {:.2}",
        plan.dominant_algo().name(),
        cal.scale(plan.dominant_algo())
    );
    let recal =
        tune_graph(&model.graph, 8, &budget.with_calibration(cal)).unwrap();
    println!(
        "recalibrated projection: {:.1} inf/s (analytical said {:.1})",
        recal.score.throughput, plan.score.throughput
    );
}
