//! H13 benches — Winograd×FFIP composed convolutions in the serving
//! path:
//!
//! * **H13a** lowering wall clock: the same quantized CNN served twice
//!   through identical plans except for the conv lowering —
//!   `ConvAlgo::Im2Gemm` (one big implicit-im2col GEMM) vs
//!   `ConvAlgo::WinogradFfip` (16 elementwise-stage GEMMs over
//!   F(2×2,3×3) transforms).  Outputs are asserted bit-identical
//!   *before* anything is timed (the composition is exact over the
//!   integers); the analytical multiply-count ratio (4/9 per eligible
//!   layer) is printed next to the measured clocks;
//! * **H13b** zero-column skipping: the Winograd deployment re-served
//!   with a structurally pruned copy of the model (half the conv
//!   output channels zeroed) — the pool's `lanes_skipped` counter is
//!   reported alongside the wall clock.
//!
//! Run: `cargo bench --bench winograd`

use ffip::algo::{winograd_mult_counts, Algo, ConvAlgo, Mat};
use ffip::bench_harness::{black_box, run_bench};
use ffip::coordinator::{
    compile_with_plan, InferenceSession, LayerWeights, Model, PostGemm,
    TensorView,
};
use ffip::engine::GemmPool;
use ffip::fpga::Device;
use ffip::memory::ConvShape;
use ffip::nn::{Graph, Layer};
use ffip::quant::QuantScheme;
use ffip::tune::{tune_graph, TuneBudget};
use ffip::util::Rng;
use std::sync::Arc;

const SHAPES: [ConvShape; 2] = [
    ConvShape {
        h: 16,
        w: 16,
        cin: 64,
        cout: 64,
        kh: 3,
        kw: 3,
        stride: 1,
        pad: 1,
    },
    ConvShape {
        h: 16,
        w: 16,
        cin: 64,
        cout: 64,
        kh: 3,
        kw: 3,
        stride: 1,
        pad: 1,
    },
];

fn cnn(prune_every: Option<usize>, seed: u64) -> Model {
    let graph = Graph {
        name: "h13-cnn".into(),
        layers: SHAPES
            .iter()
            .enumerate()
            .map(|(i, s)| Layer::Conv {
                name: format!("conv{}", i + 1),
                shape: *s,
                groups: 1,
            })
            .collect(),
    };
    let mut rng = Rng::new(seed);
    let weights = SHAPES
        .iter()
        .map(|s| {
            Some(LayerWeights {
                w: Mat::from_fn(9 * s.cin, s.cout, |_, j| {
                    match prune_every {
                        Some(p) if j % p == 0 => 0,
                        _ => rng.fixed(4, true),
                    }
                }),
                post: None,
            })
        })
        .collect();
    let mut model = Model::new(graph, weights).unwrap();
    for (idx, s) in SHAPES.iter().enumerate() {
        model
            .set_post(
                idx,
                PostGemm {
                    bias: vec![0; s.cout],
                    scheme: QuantScheme::symmetric_signed(8, 1.0 / 1024.0),
                    relu: true,
                },
            )
            .unwrap();
    }
    model
}

fn main() {
    let budget = TuneBudget::new(Device::arria10_gx1150())
        .with_batch(1)
        .with_max_replicas(1);
    let model = cnn(None, 0xB13);
    let base = tune_graph(&model.graph, 8, &budget).unwrap();
    let in_len = SHAPES[0].h * SHAPES[0].w * SHAPES[0].cin;
    let mut rng = Rng::new(23);
    let input: Vec<i32> =
        (0..in_len).map(|_| rng.fixed(8, true) as i32).collect();

    println!("## H13a — conv lowering: im2gemm vs winograd (FFIP, int8)\n");
    for s in &SHAPES {
        let (direct, wino) =
            winograd_mult_counts(s.out_h(), s.out_w(), s.cin, s.cout);
        println!(
            "  {}x{}x{}->{}: {direct} -> {wino} multiplies ({:.3}x)",
            s.h, s.w, s.cin, s.cout,
            wino as f64 / direct as f64
        );
    }
    let mut outputs = Vec::new();
    let mut sessions = Vec::new();
    for conv in [ConvAlgo::Im2Gemm, ConvAlgo::WinogradFfip] {
        let mut plan = base.clone();
        for l in plan.layers.iter_mut() {
            l.algo = Algo::Ffip;
            l.conv = conv;
        }
        let compiled = compile_with_plan(&model, &plan).unwrap();
        let pool = Arc::new(GemmPool::new(2));
        let mut sess = InferenceSession::new(&compiled, pool.clone());
        let out = sess
            .infer_batch(TensorView::new(1, in_len, &input))
            .unwrap();
        outputs.push(out.data);
        sessions.push((conv, sess, pool));
    }
    assert_eq!(
        outputs[0], outputs[1],
        "the Winograd lowering changed arithmetic"
    );
    for (conv, sess, _) in sessions.iter_mut() {
        run_bench(&format!("serve CNN ({})", conv.name()), 2, 10, || {
            black_box(
                sess.infer_batch(TensorView::new(1, in_len, &input))
                    .unwrap(),
            );
        });
    }

    println!("\n## H13b — zero-column skipping on a pruned copy\n");
    let pruned = cnn(Some(2), 0x1306);
    let mut plan = base.clone();
    for l in plan.layers.iter_mut() {
        l.algo = Algo::Ffip;
        l.conv = ConvAlgo::WinogradFfip;
    }
    let compiled = compile_with_plan(&pruned, &plan).unwrap();
    let pool = Arc::new(GemmPool::new(2));
    let mut sess = InferenceSession::new(&compiled, pool.clone());
    run_bench("serve pruned CNN (winograd)", 2, 10, || {
        black_box(
            sess.infer_batch(TensorView::new(1, in_len, &input)).unwrap(),
        );
    });
    let stats = pool.stats();
    println!(
        "engine: {} strips built, {} lane-MACs elided",
        stats.strips_built, stats.lanes_skipped
    );
    assert!(stats.lanes_skipped > 0, "pruned channels must be elided");
}
