//! Operation-count identities (paper Eqs. 1, 5, 6, 23, 27).
//!
//! These drive the paper's entire throughput-per-multiplier argument:
//! baseline GEMM needs `MNK` multiplications; (F)FIP needs
//! `(MNK + MK + NK) / 2` — asymptotically half — by trading the other
//! half for low-bitwidth additions.

/// Which inner-product algorithm an MXU implements.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Algo {
    Baseline,
    Fip,
    Ffip,
}

impl Algo {
    pub const ALL: [Algo; 3] = [Algo::Baseline, Algo::Fip, Algo::Ffip];

    pub fn name(&self) -> &'static str {
        match self {
            Algo::Baseline => "baseline",
            Algo::Fip => "FIP",
            Algo::Ffip => "FFIP",
        }
    }

    /// True for the fast (halved-multiplier) algorithms.
    pub fn is_fast(&self) -> bool {
        !matches!(self, Algo::Baseline)
    }
}

/// Multiplication / addition counts for one `M x K . K x N` GEMM.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OpCounts {
    pub mults: u64,
    pub adds: u64,
}

impl OpCounts {
    /// Total effective operations (Eq. 21d ≈ mults + adds).
    pub fn total(&self) -> u64 {
        self.mults + self.adds
    }

    /// adds : mults ratio (Eq. 23 gives ≈1 for baseline, Eq. 27 ≈3 for
    /// (F)FIP).
    pub fn add_mult_ratio(&self) -> f64 {
        self.adds as f64 / self.mults as f64
    }
}

/// Eqs. (1), (5), (6): operation counts for even K.
///
/// FFIP adds the Θ(NK) subtractions of Eq. (9) for forming y (noted as
/// negligible in the paper; they can also be precomputed offline, in
/// which case use [`op_counts_offline_y`]).
pub fn op_counts(m: u64, n: u64, k: u64, algo: Algo) -> OpCounts {
    assert!(k % 2 == 0, "counts derived for even K");
    match algo {
        Algo::Baseline => OpCounts {
            mults: m * n * k,
            adds: m * n * (k - 1),
        },
        Algo::Fip | Algo::Ffip => {
            let mults = (m * n * k + m * k + n * k) / 2;
            let adds =
                (3 * m * n * k + m * k + n * k) / 2 - m * n - m - n;
            let adds = if algo == Algo::Ffip { adds + n * k } else { adds };
            OpCounts { mults, adds }
        }
    }
}

/// FFIP counts when y is precomputed after training (§3.3): the Θ(NK)
/// y-forming subtractions leave the inference path.
pub fn op_counts_offline_y(m: u64, n: u64, k: u64) -> OpCounts {
    op_counts(m, n, k, Algo::Fip)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eq5_eq6_literal() {
        let (m, n, k) = (12, 34, 56);
        let c = op_counts(m, n, k, Algo::Fip);
        assert_eq!(c.mults, (m * n * k + m * k + n * k) / 2);
        assert_eq!(
            c.adds,
            (3 * m * n * k + m * k + n * k) / 2 - m * n - m - n
        );
    }

    #[test]
    fn fast_algos_halve_mults_asymptotically() {
        let base = op_counts(512, 512, 512, Algo::Baseline);
        let fast = op_counts(512, 512, 512, Algo::Fip);
        let ratio = fast.mults as f64 / base.mults as f64;
        assert!((0.5..0.51).contains(&ratio), "ratio={ratio}");
    }

    #[test]
    fn add_mult_ratios_match_eq23_eq27() {
        let base = op_counts(256, 256, 256, Algo::Baseline);
        assert!((base.add_mult_ratio() - 1.0).abs() < 0.01);
        let fip = op_counts(256, 256, 256, Algo::Fip);
        assert!((fip.add_mult_ratio() - 3.0).abs() < 0.05, "Eq. 27");
    }

    #[test]
    fn total_ops_preserved() {
        // (F)FIP computes the same GEMM: effective op count (Eq. 21)
        // stays ~2MNK regardless of algorithm.
        let (m, n, k) = (128u64, 128, 128);
        for algo in Algo::ALL {
            let c = op_counts(m, n, k, algo);
            let eff = 2.0 * (m * n * k) as f64;
            let actual = c.total() as f64;
            assert!(
                (actual / eff - 1.0).abs() < 0.05,
                "{algo:?}: {actual} vs {eff}"
            );
        }
    }

    #[test]
    fn ffip_counts_y_formation() {
        let (m, n, k) = (8u64, 8, 8);
        assert_eq!(
            op_counts(m, n, k, Algo::Ffip).adds,
            op_counts(m, n, k, Algo::Fip).adds + n * k
        );
        assert_eq!(
            op_counts_offline_y(m, n, k),
            op_counts(m, n, k, Algo::Fip)
        );
    }
}
