//! Storage element types for the quantized datapath (paper §4.1, §4.4).
//!
//! The paper's whole value proposition is arithmetic on **8-to-16-bit
//! fixed-point operands**: `s`-bit inputs, `w + 1`-bit FFIP y terms, and
//! `2w + clog2(X)`-bit accumulators.  Storing every operand as `i64`
//! moves 4–8× the memory traffic the modeled hardware would; this module
//! makes the element width a first-class type parameter instead.
//!
//! * [`Element`] — a storage type for A/B operands (`i8`, `i16`, `i32`,
//!   `i64`) with two associated widened types:
//!   * [`Element::Y`] — storage of the offline FFIP y transform, which
//!     needs **one extra bit** relative to the operand (§4.4: `y = b -
//!     b_prev` spans `[-(2^w - 1), 2^w - 1]` for `w`-bit `b`), so `i8`
//!     operands store y as `i16`, `i16` as `i32`;
//!   * [`Element::Acc`] — the widened accumulator ([`AccElem`]) all
//!     kernel arithmetic runs in: `i32` for `i8` operands (the paper's
//!     `2w + clog2(X)` ≤ 32 for every practical X), `i64` otherwise.
//! * [`AccElem`] — the minimal arithmetic surface the GEMM kernels need
//!   on an accumulator (`+`, `-`, `*`, assign forms), implemented for
//!   `i32` and `i64`.
//! * [`ElemKind`] — the runtime width tag the type-erased engine jobs
//!   carry ([`crate::engine::GemmPool`] stores raw `*const u8` operand
//!   pointers; the tag is the only key for casting them back).
//!
//! `i64` remains the *oracle* domain: its `Acc` is itself, so every
//! existing wide-path caller behaves exactly as before, and the typed
//! kernels are property-tested bit-identical against it (for inputs that
//! fit the narrow storage).  The release-mode overflow guard for narrow
//! accumulators lives in [`FixedSpec::gemm_acc_bits`][gab] and is
//! asserted at job submit; see `engine/pool.rs`.
//!
//! [gab]: crate::arith::FixedSpec::gemm_acc_bits

use super::Mat;
use std::fmt::Debug;
use std::ops::{Add, AddAssign, Mul, Sub, SubAssign};

/// Runtime width tag for a storage element type — what the type-erased
/// engine jobs and the serving stack report.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ElemKind {
    I8,
    I16,
    I32,
    I64,
}

impl ElemKind {
    pub fn name(&self) -> &'static str {
        match self {
            ElemKind::I8 => "i8",
            ElemKind::I16 => "i16",
            ElemKind::I32 => "i32",
            ElemKind::I64 => "i64",
        }
    }

    /// Bytes per stored operand element.
    pub fn bytes(&self) -> usize {
        match self {
            ElemKind::I8 => 1,
            ElemKind::I16 => 2,
            ElemKind::I32 => 4,
            ElemKind::I64 => 8,
        }
    }

    /// Storage width in bits (including the sign bit).
    pub fn bits(&self) -> u32 {
        (self.bytes() * 8) as u32
    }
}

/// Widened accumulator element: the arithmetic surface of the GEMM
/// kernels.  All kernel math (pair sums, products, the g recurrence,
/// alpha/beta corrections, cross-tile accumulation) happens in this
/// type; only *storage* uses the narrow [`Element`].
pub trait AccElem:
    Copy
    + Default
    + PartialEq
    + Eq
    + Debug
    + Send
    + Sync
    + 'static
    + Add<Output = Self>
    + AddAssign
    + Sub<Output = Self>
    + SubAssign
    + Mul<Output = Self>
{
    /// Total register width in bits (including the sign bit).
    const BITS: u32;
    fn to_i64(self) -> i64;
    /// Widen an `i32` into the accumulator domain (always exact: every
    /// accumulator type is at least 32 bits wide).  The SWAR kernels
    /// use this to lift unpacked lane values and small correction
    /// constants into accumulator arithmetic.
    fn from_i32(v: i32) -> Self;
    /// Narrow an `i64` into the accumulator domain.  Used only where
    /// the value is known to fit (kernel partial sums bounded by the
    /// [`FixedSpec::gemm_acc_bits`][gab] guard); debug-asserted.
    ///
    /// [gab]: crate::arith::FixedSpec::gemm_acc_bits
    fn from_i64(v: i64) -> Self;
}

impl AccElem for i32 {
    const BITS: u32 = 32;
    #[inline(always)]
    fn to_i64(self) -> i64 {
        i64::from(self)
    }
    #[inline(always)]
    fn from_i32(v: i32) -> Self {
        v
    }
    #[inline(always)]
    fn from_i64(v: i64) -> Self {
        debug_assert!(
            i32::try_from(v).is_ok(),
            "accumulator value {v} exceeds i32"
        );
        v as i32
    }
}

impl AccElem for i64 {
    const BITS: u32 = 64;
    #[inline(always)]
    fn to_i64(self) -> i64 {
        self
    }
    #[inline(always)]
    fn from_i32(v: i32) -> Self {
        i64::from(v)
    }
    #[inline(always)]
    fn from_i64(v: i64) -> Self {
        v
    }
}

/// A fixed-point storage element for GEMM operands.
///
/// Implemented for `i8`, `i16`, `i32` and `i64`.  The narrow types are
/// what a deployed quantized model stores and streams; `i64` is the
/// widened oracle domain the property tests compare against.
pub trait Element:
    Copy + Default + PartialEq + Eq + Debug + Send + Sync + 'static
{
    /// Storage type of the offline FFIP y transform: one extra bit
    /// relative to the operand (§4.4), so the next-wider integer.
    type Y: Copy + Default + PartialEq + Eq + Debug + Send + Sync + 'static;
    /// Widened accumulator all kernel arithmetic runs in.
    type Acc: AccElem;
    /// The next-wider storage element — what the Winograd-transformed
    /// operand domain of a `Self`-storage conv layer travels as.  The
    /// F(2,3) transforms grow magnitudes by at most ×4 (input, `BᵀdB`)
    /// and ×9 (weights, `(2G)g(2Gᵀ)`), so transformed tiles always fit
    /// `BITS + 4` bits — one widening step.  `i64` is its own `Wide`
    /// (the oracle domain absorbs the growth).
    type Wide: Element;
    /// Storage width in bits (including the sign bit).
    const BITS: u32;
    /// Runtime width tag (what [`crate::engine::GemmPool`] jobs carry).
    const KIND: ElemKind;
    const NAME: &'static str;
    /// True for the quantized narrow storage types (`i8`/`i16`), whose
    /// finite accumulator gets the explicit release-mode overflow guard
    /// at engine submit.  False for the wide oracle types (`i32`/`i64`),
    /// which keep the historical semantics: exact in practice for
    /// quantized data, debug-checked arithmetic otherwise.
    const GUARDED: bool;

    // ---- SWAR lane descriptor (the engine's vector kernels) ----
    //
    // A narrow storage type packs several *widened* lanes into one
    // 64-bit word: `i8` operands travel as 4 × 16-bit lanes, `i16`
    // operands as 2 × 32-bit lanes.  The lane width is chosen so every
    // value the fast-algorithm inner loops hold per lane — operands,
    // FIP pair sums `a + b`, the FFIP g state (telescoped to
    // `a_swapped + b_j`, §3.2) and offline y terms (±(2^w − 1), §4.4)
    // — provably fits: magnitudes are bounded by 2^BITS, and the lane
    // has 2·BITS bits.  `engine/simd.rs` builds the packed kernels on
    // these four primitives; the defaults (one lane, unreachable ops)
    // mark a width as scalar-only.

    /// Lanes per packed 64-bit SWAR word; 1 means the width has no
    /// vector path and the engine runs the scalar kernels.
    const SWAR_LANES: usize = 1;
    /// Bits per SWAR lane (`64 / SWAR_LANES` when vectorized).
    const SWAR_LANE_BITS: u32 = 0;
    /// Mask selecting the top (sign) bit of every lane.
    const SWAR_HI: u64 = 0;
    /// Mask selecting the even-index lanes (pair-swap helper).
    const SWAR_EVEN: u64 = 0;

    /// Truncate an accumulator value to its lane bit pattern (the low
    /// `SWAR_LANE_BITS` bits, two's complement).  Exact whenever the
    /// value fits the lane — the packed kernels only store
    /// lane-bounded values (see the bound argument above).
    fn swar_lane(_v: Self::Acc) -> u64 {
        unreachable!("{}: no SWAR lane descriptor", Self::NAME)
    }

    /// Widening pairwise product-sum over one packed word:
    /// `Σ_t sext(lane_{2t}) · sext(lane_{2t+1})` — Eq. (2)/(7)'s "half
    /// the multiplications" step, one call per word.
    fn swar_mul_pairs(_w: u64) -> Self::Acc {
        unreachable!("{}: no SWAR lane descriptor", Self::NAME)
    }

    /// Widen into the accumulator domain (always exact).
    fn acc(self) -> Self::Acc;
    /// Widen a stored y term into the accumulator domain (always exact).
    fn y_to_acc(y: Self::Y) -> Self::Acc;
    /// Narrow an accumulator value into y storage.  Exact for actual y
    /// terms (`b - b_prev` fits `BITS + 1 ≤` y-storage bits by
    /// construction); debug-asserted.
    fn acc_to_y(v: Self::Acc) -> Self::Y;
    /// Checked narrowing from the oracle domain; `None` when `v` does
    /// not fit this storage type.
    fn from_i64(v: i64) -> Option<Self>;
    fn to_i64(self) -> i64;
}

macro_rules! element_impl {
    ($t:ty, $y:ty, $acc:ty, $wide:ty, $bits:expr, $kind:expr, $name:expr,
     $guarded:expr
     $(, swar($lanes:expr, $lane_bits:expr, $hi:expr, $even:expr,
              $lane_ty:ty, $prod_ty:ty))?) => {
        impl Element for $t {
            type Y = $y;
            type Acc = $acc;
            type Wide = $wide;
            const BITS: u32 = $bits;
            const KIND: ElemKind = $kind;
            const NAME: &'static str = $name;
            const GUARDED: bool = $guarded;

            $(
                const SWAR_LANES: usize = $lanes;
                const SWAR_LANE_BITS: u32 = $lane_bits;
                const SWAR_HI: u64 = $hi;
                const SWAR_EVEN: u64 = $even;

                #[inline(always)]
                fn swar_lane(v: Self::Acc) -> u64 {
                    // two's-complement truncation to the lane width;
                    // exact for lane-bounded values (debug-checked)
                    debug_assert!(
                        <$lane_ty>::try_from(AccElem::to_i64(v)).is_ok(),
                        "value {v:?} exceeds the {}-bit SWAR lane",
                        $lane_bits
                    );
                    (v as u64) & (u64::MAX >> (64 - $lane_bits))
                }

                // the product type coincides with Acc for every
                // vectorized width, so the closing cast is identity
                #[allow(clippy::unnecessary_cast)]
                #[inline(always)]
                fn swar_mul_pairs(w: u64) -> Self::Acc {
                    let mut s: Self::Acc = Default::default();
                    let mut t = 0u32;
                    while t < $lanes as u32 {
                        let lo = (w >> (t * $lane_bits))
                            as $lane_ty as $prod_ty;
                        let hi = (w >> ((t + 1) * $lane_bits))
                            as $lane_ty as $prod_ty;
                        s += (lo * hi) as $acc;
                        t += 2;
                    }
                    s
                }
            )?

            // identity casts appear for the widest instantiation
            #[allow(clippy::unnecessary_cast)]
            #[inline(always)]
            fn acc(self) -> Self::Acc {
                self as $acc
            }

            #[allow(clippy::unnecessary_cast)]
            #[inline(always)]
            fn y_to_acc(y: Self::Y) -> Self::Acc {
                y as $acc
            }

            #[allow(clippy::unnecessary_cast)]
            #[inline(always)]
            fn acc_to_y(v: Self::Acc) -> Self::Y {
                debug_assert!(
                    <$y>::try_from(AccElem::to_i64(v)).is_ok(),
                    "y term {v:?} exceeds {} y storage",
                    stringify!($y)
                );
                v as $y
            }

            #[inline(always)]
            fn from_i64(v: i64) -> Option<Self> {
                <$t>::try_from(v).ok()
            }

            #[allow(clippy::unnecessary_cast)]
            #[inline(always)]
            fn to_i64(self) -> i64 {
                self as i64
            }
        }
    };
}

element_impl!(
    i8, i16, i32, i16, 8, ElemKind::I8, "i8", true,
    swar(4, 16, 0x8000_8000_8000_8000, 0x0000_FFFF_0000_FFFF, i16, i32)
);
element_impl!(
    i16, i32, i64, i32, 16, ElemKind::I16, "i16", true,
    swar(2, 32, 0x8000_0000_8000_0000, 0x0000_0000_FFFF_FFFF, i32, i64)
);
element_impl!(i32, i64, i64, i64, 32, ElemKind::I32, "i32", false);
element_impl!(i64, i64, i64, i64, 64, ElemKind::I64, "i64", false);

impl<E: Element> Mat<E> {
    /// Widen every element into the `i64` oracle domain.
    pub fn widen(&self) -> Mat<i64> {
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&v| v.to_i64()).collect(),
        }
    }
}

impl Mat<i64> {
    /// Checked narrowing into storage type `E`: `None` when any element
    /// exceeds `E`'s range.  How the serving compiler turns wide
    /// training-domain weights into deployable narrow storage.
    pub fn narrow<E: Element>(&self) -> Option<Mat<E>> {
        let mut data = Vec::with_capacity(self.data.len());
        for &v in &self.data {
            data.push(E::from_i64(v)?);
        }
        Some(Mat { rows: self.rows, cols: self.cols, data })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn widths_and_tags() {
        assert_eq!(<i8 as Element>::BITS, 8);
        assert_eq!(<i8 as Element>::KIND.bytes(), 1);
        assert_eq!(<i16 as Element>::KIND, ElemKind::I16);
        assert_eq!(<i64 as Element>::KIND.name(), "i64");
        // y storage is the next-wider type (one extra bit, §4.4)
        assert_eq!(std::mem::size_of::<<i8 as Element>::Y>(), 2);
        assert_eq!(std::mem::size_of::<<i16 as Element>::Y>(), 4);
        // i8 accumulates in i32, everything wider in i64
        assert_eq!(<<i8 as Element>::Acc as AccElem>::BITS, 32);
        assert_eq!(<<i16 as Element>::Acc as AccElem>::BITS, 64);
        // the Winograd-transformed domain is one widening step up, and
        // i64 absorbs its own growth
        assert_eq!(<<i8 as Element>::Wide as Element>::BITS, 16);
        assert_eq!(<<i16 as Element>::Wide as Element>::BITS, 32);
        assert_eq!(<<i32 as Element>::Wide as Element>::BITS, 64);
        assert_eq!(<<i64 as Element>::Wide as Element>::BITS, 64);
    }

    #[test]
    fn checked_narrowing() {
        assert_eq!(<i8 as Element>::from_i64(127), Some(127i8));
        assert_eq!(<i8 as Element>::from_i64(-128), Some(-128i8));
        assert_eq!(<i8 as Element>::from_i64(128), None);
        assert_eq!(<i16 as Element>::from_i64(-40_000), None);
        assert_eq!(<i64 as Element>::from_i64(i64::MIN), Some(i64::MIN));
    }

    #[test]
    fn mat_widen_narrow_roundtrip() {
        let m = Mat::from_fn(3, 4, |i, j| (i as i64 * 10 + j as i64) - 15);
        let n: Mat<i8> = m.narrow().expect("fits i8");
        assert_eq!(n.widen(), m);
        // out-of-range values refuse to narrow
        let big = Mat::from_fn(1, 1, |_, _| 1000i64);
        assert!(big.narrow::<i8>().is_none());
        assert!(big.narrow::<i16>().is_some());
    }

    #[test]
    fn swar_lane_descriptor_geometry() {
        // vectorized widths tile the 64-bit word exactly
        for (lanes, bits) in [
            (<i8 as Element>::SWAR_LANES, <i8 as Element>::SWAR_LANE_BITS),
            (<i16 as Element>::SWAR_LANES, <i16 as Element>::SWAR_LANE_BITS),
        ] {
            assert_eq!(lanes as u32 * bits, 64);
            assert!(lanes % 2 == 0, "pairwise products need even lanes");
        }
        // i32/i64 are scalar-only (the oracle / fallback widths)
        assert_eq!(<i32 as Element>::SWAR_LANES, 1);
        assert_eq!(<i64 as Element>::SWAR_LANES, 1);
        // masks: one hi bit and alternating even-lane coverage
        assert_eq!(
            <i8 as Element>::SWAR_HI.count_ones(),
            <i8 as Element>::SWAR_LANES as u32
        );
        assert_eq!(<i8 as Element>::SWAR_EVEN, 0x0000_FFFF_0000_FFFF);
        assert_eq!(<i16 as Element>::SWAR_EVEN, 0x0000_0000_FFFF_FFFF);
    }

    #[test]
    fn swar_lane_roundtrip_and_mul_pairs() {
        // i8 lanes: pack the 4 values [3, -7, -256, 255] low-to-high
        let vals = [3i32, -7, -256, 255];
        let mut w = 0u64;
        for (t, &v) in vals.iter().enumerate() {
            w |= <i8 as Element>::swar_lane(v) << (16 * t as u32);
        }
        // lanes sign-extend back out through mul_pairs:
        // 3*-7 + -256*255 = -21 - 65280
        assert_eq!(<i8 as Element>::swar_mul_pairs(w), -21 - 65280);
        // i16 lanes: one pair per word
        let w16 = <i16 as Element>::swar_lane(-65536)
            | (<i16 as Element>::swar_lane(65535) << 32);
        assert_eq!(
            <i16 as Element>::swar_mul_pairs(w16),
            -65536i64 * 65535
        );
    }

    #[test]
    fn worst_case_y_fits_y_storage() {
        // §4.4: y spans ±(2^w - 1); the next-wider type holds it
        let acc = <i8 as Element>::acc(-128) - <i8 as Element>::acc(127);
        let y = <i8 as Element>::acc_to_y(acc);
        assert_eq!(y, -255i16);
        assert_eq!(<i8 as Element>::y_to_acc(y), -255i32);
    }
}
