//! FFIP — the Free-pipeline Fast Inner Product (paper §3.2, Eqs. 7-9).
//!
//! The defining difference from FIP is *where* the b operand enters: FFIP
//! adds the column-difference `y_{k,j} = b_{k,j} - b_{k,j-1}` to a running
//! `g` term carried from the previous output column (the adjacent PE in
//! hardware), so the systolic buffer register doubles as the pipeline
//! register (§4.2).  This module implements the recurrence literally —
//! `g` state propagated column by column — rather than simplifying it to
//! `A @ B`, so the Rust oracle exercises the same dataflow the hardware
//! and the Pallas kernel do.

use super::element::Element;
use super::fip::{alpha_terms, beta_terms};
use super::Mat;

/// Eq. (9) with tile restarts: `y_{i,j} = b_{i,j}` when `j` is the first
/// column of a tile (`j % tile_n == 0`), else `b_{i,j} - b_{i,j-1}`.
///
/// The restart mirrors the hardware: each b/y tile loaded into the MXU
/// re-seeds the g recurrence at its first PE column (§4.3).  y needs one
/// extra bit of storage relative to b (§4.4) — which is why the result
/// is stored in [`Element::Y`], the next-wider integer type (`i16` for
/// `i8` operands), not the operand type itself.
pub fn y_from_b<E: Element>(b: &Mat<E>, tile_n: usize) -> Mat<E::Y> {
    let mut y = Mat { rows: 0, cols: 0, data: Vec::new() };
    y_from_b_into(b, tile_n, &mut y);
    y
}

/// [`y_from_b`] into a caller-owned matrix, resized in place.
///
/// This is the **online-y** variant: when both GEMM operands are
/// per-request activations (attention's QKᵀ and AV), the y transform
/// cannot be precomputed at compile time and runs on the serving
/// critical path instead — the caller recycles `y` across requests so
/// steady-state inference allocates nothing.
pub fn y_from_b_into<E: Element>(
    b: &Mat<E>,
    tile_n: usize,
    y: &mut Mat<E::Y>,
) {
    assert!(tile_n >= 1);
    y.rows = b.rows;
    y.cols = b.cols;
    y.data.clear();
    y.data.reserve(b.rows * b.cols);
    for i in 0..b.rows {
        let brow = b.row(i);
        for (j, &bv) in brow.iter().enumerate() {
            y.data.push(if j % tile_n == 0 {
                E::acc_to_y(bv.acc())
            } else {
                E::acc_to_y(bv.acc() - brow[j - 1].acc())
            });
        }
    }
}

/// Incremental [`y_from_b`] maintenance after writing column `col` of
/// `b`: only columns `col` and `col + 1` of the difference transform
/// depend on `b[:, col]`, so a KV cache appending one token's key
/// column refreshes exactly those two instead of re-running the full
/// transform over the strip (the append-time y packing of the decode
/// subsystem).  `y` must already be `y_from_b(b, tile_n)`-consistent
/// for every other column; on return it is consistent for all of `b`.
pub fn y_append_col<E: Element>(
    b: &Mat<E>,
    tile_n: usize,
    col: usize,
    y: &mut Mat<E::Y>,
) {
    assert!(tile_n >= 1);
    assert_eq!((y.rows, y.cols), (b.rows, b.cols), "y matches b dims");
    assert!(col < b.cols, "column in range");
    for i in 0..b.rows {
        for j in [col, col + 1] {
            if j >= b.cols {
                continue;
            }
            let bv = b[(i, j)].acc();
            y[(i, j)] = if j % tile_n == 0 {
                E::acc_to_y(bv)
            } else {
                E::acc_to_y(bv - b[(i, j - 1)].acc())
            };
        }
    }
}

/// Incremental [`y_from_b`] maintenance after writing row `row` of `b`:
/// the difference transform runs along each row independently, so a KV
/// cache appending one token's value row refreshes exactly that row
/// (the AV-side counterpart of [`y_append_col`]).
pub fn y_append_row<E: Element>(
    b: &Mat<E>,
    tile_n: usize,
    row: usize,
    y: &mut Mat<E::Y>,
) {
    assert!(tile_n >= 1);
    assert_eq!((y.rows, y.cols), (b.rows, b.cols), "y matches b dims");
    assert!(row < b.rows, "row in range");
    let brow = b.row(row);
    for (j, &bv) in brow.iter().enumerate() {
        y[(row, j)] = if j % tile_n == 0 {
            E::acc_to_y(bv.acc())
        } else {
            E::acc_to_y(bv.acc() - brow[j - 1].acc())
        };
    }
}

/// Eqs. (7)-(9): FFIP matrix multiplication via the g recurrence.
///
/// `tile_n` restarts the recurrence every `tile_n` columns (use `n` for a
/// single tile).  Requires even K.
pub fn ffip_matmul<E: Element>(
    a: &Mat<E>,
    b: &Mat<E>,
    tile_n: usize,
) -> Mat<E::Acc> {
    assert_eq!(a.cols, b.rows, "inner dimensions must match");
    assert_eq!(a.cols % 2, 0, "FFIP requires even K (pad with a zero column)");
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let alpha = alpha_terms(a);
    let beta = beta_terms(b);
    // transpose y once so each output column's y vector is contiguous
    // in the recurrence scan (§Perf log in EXPERIMENTS.md).
    let yt = y_from_b(b, tile_n).transpose(); // (n, k)

    let mut c = Mat::zeros(m, n);
    // g state per row of A: K accumulator values, reused across the
    // column scan.
    let mut g = vec![<E::Acc>::default(); k];
    for i in 0..m {
        let arow = a.row(i);
        let crow = &mut c.data[i * n..(i + 1) * n];
        for (j, cv) in crow.iter_mut().enumerate() {
            if j % tile_n == 0 {
                // Eqs. (8a)/(8b): re-seed with the swapped a pairs.
                for p in 0..k / 2 {
                    g[2 * p] = arow[2 * p + 1].acc();
                    g[2 * p + 1] = arow[2 * p].acc();
                }
            }
            // Eq. (8c): g^{(j)} = g^{(j-1)} + y_{:,j}
            for (gv, &yv) in g.iter_mut().zip(yt.row(j)) {
                *gv += E::y_to_acc(yv);
            }
            // Eq. (7): c_{i,j} = sum_k g_odd * g_even - alpha_i - beta_j
            let mut acc = <E::Acc>::default();
            for p in g.chunks_exact(2) {
                acc += p[0] * p[1];
            }
            *cv = acc - alpha[i] - beta[j];
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::{baseline_matmul, fip_matmul};
    use crate::util::{prop, Rng};

    #[test]
    fn y_reconstructs_b_by_prefix_sum() {
        let mut rng = Rng::new(3);
        let b = Mat::from_fn(6, 9, |_, _| rng.fixed(8, true));
        for tile_n in [1, 2, 3, 4, 9] {
            let y = y_from_b(&b, tile_n);
            // prefix-sum y within each tile must give back b
            for i in 0..b.rows {
                let mut acc = 0;
                for j in 0..b.cols {
                    if j % tile_n == 0 {
                        acc = 0;
                    }
                    acc += y[(i, j)];
                    assert_eq!(acc, b[(i, j)], "tile_n={tile_n} ({i},{j})");
                }
            }
        }
    }

    #[test]
    fn ffip_equals_fip_equals_baseline() {
        prop::check("ffip == fip == baseline", 30, 16, |c| {
            let m = c.rng.range(1, c.size + 2);
            let k = 2 * c.rng.range(1, c.size + 2);
            let n = c.rng.range(1, c.size + 2);
            let a = Mat::from_fn(m, k, |_, _| c.rng.fixed(8, true));
            let b = Mat::from_fn(k, n, |_, _| c.rng.fixed(8, true));
            let gold = baseline_matmul(&a, &b);
            assert_eq!(fip_matmul(&a, &b), gold);
            let tile_n = c.rng.range(1, n + 1);
            assert_eq!(ffip_matmul(&a, &b, tile_n), gold);
        });
    }

    #[test]
    fn y_from_b_into_matches_and_recycles_capacity() {
        let mut rng = Rng::new(9);
        let mut y = Mat { rows: 0, cols: 0, data: Vec::new() };
        let b0 = Mat::from_fn(12, 10, |_, _| rng.fixed(8, true) as i8);
        y_from_b_into(&b0, 4, &mut y);
        assert_eq!(y, y_from_b(&b0, 4));
        let cap = y.data.capacity();
        // ragged shapes no larger than the high-water mark reuse the
        // buffer: the online-y serving path allocates nothing
        for (r, c, t) in [(3usize, 7usize, 3usize), (12, 10, 4), (1, 9, 2)] {
            let b = Mat::from_fn(r, c, |_, _| rng.fixed(8, true) as i8);
            y_from_b_into(&b, t, &mut y);
            assert_eq!(y, y_from_b(&b, t), "({r},{c},{t})");
            assert_eq!(y.data.capacity(), cap, "no reallocation");
        }
    }

    /// Growing b one position at a time with the incremental append
    /// transforms reproduces the full `y_from_b` at every prefix — the
    /// KV-cache invariant: a strip with a zero tail plus per-append
    /// column/row refreshes always equals the from-scratch transform.
    #[test]
    fn incremental_y_appends_match_full_transform() {
        let mut rng = Rng::new(0x5eed);
        for tile_n in [1usize, 2, 3, 4, 7, 10] {
            // K-strip shape (d_head x cap): tokens arrive as columns
            let full = Mat::from_fn(5, 10, |_, _| rng.fixed(8, true) as i8);
            let mut b: Mat<i8> = Mat::zeros(5, 10);
            let mut y = y_from_b(&b, tile_n);
            for t in 0..10 {
                for i in 0..5 {
                    b[(i, t)] = full[(i, t)];
                }
                y_append_col(&b, tile_n, t, &mut y);
                assert_eq!(y, y_from_b(&b, tile_n), "col t={t} tile={tile_n}");
            }
            // V-strip shape (cap x d_head): tokens arrive as rows
            let full = Mat::from_fn(10, 5, |_, _| rng.fixed(8, true) as i8);
            let mut b: Mat<i8> = Mat::zeros(10, 5);
            let mut y = y_from_b(&b, tile_n);
            for t in 0..10 {
                for j in 0..5 {
                    b[(t, j)] = full[(t, j)];
                }
                y_append_row(&b, tile_n, t, &mut y);
                assert_eq!(y, y_from_b(&b, tile_n), "row t={t} tile={tile_n}");
            }
        }
    }

    #[test]
    fn y_extra_bit_bound() {
        // §4.4: y fits in w+1 bits when b is w-bit.
        let mut rng = Rng::new(4);
        let w = 8u32;
        let b = Mat::from_fn(16, 16, |_, _| rng.fixed(w, true));
        let y = y_from_b(&b, 16);
        let bound = 1i64 << w; // w+1-bit signed range is [-2^w, 2^w)
        assert!(y.data.iter().all(|&v| -bound <= v && v < bound));
    }

    #[test]
    fn worst_case_y_needs_extra_bit() {
        // b alternating extremes: y = ±(2^w - 1) exceeds w-1 magnitude
        let b = Mat::from_rows(&[vec![-128i64, 127, -128, 127]]);
        let y = y_from_b(&b, 4);
        assert_eq!(y.data, vec![-128, 255, -255, 255]);
        assert!(y.data.iter().any(|&v| !(-128..=127).contains(&v)));
    }
}
