//! FIP — Winograd's 1968 Fast Inner Product (paper §3.1, Eqs. 2-4).
//!
//! Generic over the storage [`Element`]: operands stream in their
//! quantized width, all arithmetic (pair sums, products, corrections)
//! runs in the widened [`Element::Acc`] accumulator type.

use super::element::Element;
use super::Mat;

/// Eq. (3): `alpha_i = sum_{j=1}^{K/2} a_{i,2j-1} a_{i,2j}`.
///
/// Odd K is implicitly zero-padded by one column (exact; mirrors the
/// hardware where K is always padded to the even array depth).
pub fn alpha_terms<E: Element>(a: &Mat<E>) -> Vec<E::Acc> {
    (0..a.rows)
        .map(|i| {
            let row = a.row(i);
            let mut acc = <E::Acc>::default();
            for p in row.chunks(2) {
                let second =
                    p.get(1).copied().map_or(<E::Acc>::default(), E::acc);
                acc += p[0].acc() * second;
            }
            acc
        })
        .collect()
}

/// Eq. (4): `beta_j = sum_{i=1}^{K/2} b_{2i-1,j} b_{2i,j}`.
pub fn beta_terms<E: Element>(b: &Mat<E>) -> Vec<E::Acc> {
    (0..b.cols)
        .map(|j| {
            let mut acc = <E::Acc>::default();
            let mut i = 0;
            while i + 1 < b.rows {
                acc += b[(i, j)].acc() * b[(i + 1, j)].acc();
                i += 2;
            }
            acc // odd final row pairs with implicit zero
        })
        .collect()
}

/// Eq. (2): FIP matrix multiplication.
///
/// `c_{i,j} = sum_{k=1}^{K/2} (a_{i,2k-1} + b_{2k,j})(a_{i,2k} + b_{2k-1,j})
///            - alpha_i - beta_j`
///
/// K/2 multiplications per output element; the product form is kept
/// literal (pair-sums then multiply) to match the FIP PE datapath.
pub fn fip_matmul<E: Element>(a: &Mat<E>, b: &Mat<E>) -> Mat<E::Acc> {
    assert_eq!(a.cols, b.rows, "inner dimensions must match");
    assert_eq!(a.cols % 2, 0, "FIP requires even K (pad with a zero column)");
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let alpha = alpha_terms(a);
    let beta = beta_terms(b);
    let mut c = Mat::zeros(m, n);
    // ipj order: per pair p the inner loop walks contiguous B rows
    // (b_odd = row 2p, b_even = row 2p+1) and the contiguous C row.
    for i in 0..m {
        let arow = a.row(i);
        let crow = &mut c.data[i * n..(i + 1) * n];
        for p in 0..k / 2 {
            // 1-indexed: a_{i,2k-1} = arow[2p], a_{i,2k} = arow[2p+1]
            let a_odd = arow[2 * p].acc();
            let a_even = arow[2 * p + 1].acc();
            let b_odd = b.row(2 * p);
            let b_even = b.row(2 * p + 1);
            for ((cv, &bo), &be) in
                crow.iter_mut().zip(b_odd).zip(b_even)
            {
                *cv += (a_odd + be.acc()) * (a_even + bo.acc());
            }
        }
        for (cv, &bj) in crow.iter_mut().zip(&beta) {
            *cv -= alpha[i] + bj;
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::baseline_matmul;
    use crate::util::Rng;

    #[test]
    fn fip_matches_baseline_small_exhaustive() {
        // exhaustive over tiny 2x2 * 2x2 with 3-bit values
        for seed in 0..50 {
            let mut rng = Rng::new(seed);
            let a = Mat::from_fn(2, 2, |_, _| rng.fixed(3, true));
            let b = Mat::from_fn(2, 2, |_, _| rng.fixed(3, true));
            assert_eq!(fip_matmul(&a, &b), baseline_matmul(&a, &b));
        }
    }

    #[test]
    fn alpha_beta_definitions() {
        // K = 4: alpha_0 = a0*a1 + a2*a3
        let a = Mat::from_rows(&[vec![1i64, 2, 3, 4]]);
        assert_eq!(alpha_terms(&a), vec![1 * 2 + 3 * 4]);
        let b = Mat::from_rows(&[vec![5i64], vec![6], vec![7], vec![8]]);
        assert_eq!(beta_terms(&b), vec![5 * 6 + 7 * 8]);
    }

    #[test]
    fn odd_k_pads_with_zero() {
        let a = Mat::from_rows(&[vec![1i64, 2, 3]]);
        assert_eq!(alpha_terms(&a), vec![2]); // 1*2 + 3*0
        let b = Mat::from_rows(&[vec![4i64], vec![5], vec![6]]);
        assert_eq!(beta_terms(&b), vec![20]); // 4*5 + 6*0
    }

    #[test]
    #[should_panic(expected = "even K")]
    fn fip_rejects_odd_k() {
        let a = Mat::zeros(2, 3);
        let b = Mat::zeros(3, 2);
        fip_matmul(&a, &b);
    }
}
