//! Row-major dense matrix, the lingua franca between the algorithm
//! implementations, the cycle simulator, the memory tilers and the
//! coordinator.  Deliberately minimal: this crate's matrices carry
//! quantized integers — narrow storage elements (`i8`/`i16`, see
//! [`crate::algo::Element`]), widened accumulators (`i32`/`i64`) or the
//! `i64` oracle domain — or f32, and the hot GEMM paths index the flat
//! buffer directly.

use std::ops::{Index, IndexMut};

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Mat<T> {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<T>,
}

impl<T: Copy + Default> Mat<T> {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![T::default(); rows * cols] }
    }

    pub fn from_fn(
        rows: usize,
        cols: usize,
        mut f: impl FnMut(usize, usize) -> T,
    ) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Mat { rows, cols, data }
    }

    pub fn from_rows(rows_data: &[Vec<T>]) -> Self {
        let rows = rows_data.len();
        let cols = rows_data.first().map_or(0, Vec::len);
        assert!(rows_data.iter().all(|r| r.len() == cols));
        Mat {
            rows,
            cols,
            data: rows_data.iter().flatten().copied().collect(),
        }
    }

    /// Row `i` as a slice.
    pub fn row(&self, i: usize) -> &[T] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Column `j` copied out.
    pub fn col(&self, j: usize) -> Vec<T> {
        (0..self.rows).map(|i| self[(i, j)]).collect()
    }

    pub fn transpose(&self) -> Self {
        Mat::from_fn(self.cols, self.rows, |i, j| self[(j, i)])
    }

    /// Zero-pad to `(rows, cols)` (must each be >= current). Exact for
    /// all the inner-product algorithms: padded elements contribute zero
    /// products and zero alpha/beta corrections.
    pub fn pad_to(&self, rows: usize, cols: usize) -> Self {
        assert!(rows >= self.rows && cols >= self.cols);
        Mat::from_fn(rows, cols, |i, j| {
            if i < self.rows && j < self.cols {
                self[(i, j)]
            } else {
                T::default()
            }
        })
    }

    /// The `(rows, cols)` submatrix at offset `(i0, j0)`, zero-padded when
    /// it overhangs the edge (how the tiler fetches edge tiles).
    pub fn tile(&self, i0: usize, j0: usize, rows: usize, cols: usize) -> Self {
        Mat::from_fn(rows, cols, |i, j| {
            if i0 + i < self.rows && j0 + j < self.cols {
                self[(i0 + i, j0 + j)]
            } else {
                T::default()
            }
        })
    }

    /// The top-left `(rows, cols)` corner (inverse of `pad_to`).
    pub fn crop(&self, rows: usize, cols: usize) -> Self {
        assert!(rows <= self.rows && cols <= self.cols);
        self.tile(0, 0, rows, cols)
    }

    /// Reshape in place to `(rows, cols)` with every element zeroed.
    /// The serving path's buffer-recycling primitive: capacity grows to
    /// the high-water mark once, then steady-state reuse allocates
    /// nothing.
    pub fn reset_to(&mut self, rows: usize, cols: usize) {
        self.rows = rows;
        self.cols = cols;
        self.data.clear();
        self.data.resize(rows * cols, T::default());
    }
}

impl<T> Index<(usize, usize)> for Mat<T> {
    type Output = T;
    #[inline(always)]
    fn index(&self, (i, j): (usize, usize)) -> &T {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl<T> IndexMut<(usize, usize)> for Mat<T> {
    #[inline(always)]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut T {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

impl<T: Copy + std::ops::Add<Output = T>> Mat<T> {
    /// Elementwise add (any accumulator element type — the tiled GEMM
    /// driver sums partial tile products of `i32` or `i64`).
    pub fn add(&self, other: &Self) -> Self {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self
                .data
                .iter()
                .zip(&other.data)
                .map(|(&a, &b)| a + b)
                .collect(),
        }
    }
}

impl Mat<i64> {
    /// Max |element|.
    pub fn max_abs(&self) -> i64 {
        self.data.iter().map(|v| v.abs()).max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn indexing_row_major() {
        let m = Mat::from_fn(2, 3, |i, j| (i * 10 + j) as i64);
        assert_eq!(m.data, vec![0, 1, 2, 10, 11, 12]);
        assert_eq!(m[(1, 2)], 12);
        assert_eq!(m.row(1), &[10, 11, 12]);
        assert_eq!(m.col(2), vec![2, 12]);
    }

    #[test]
    fn transpose_involution() {
        let m = Mat::from_fn(3, 5, |i, j| (i * 7 + j * 3) as i64);
        assert_eq!(m.transpose().transpose(), m);
    }

    #[test]
    fn pad_crop_roundtrip() {
        let m = Mat::from_fn(3, 5, |i, j| (i + j) as i64);
        let p = m.pad_to(8, 8);
        assert_eq!(p.crop(3, 5), m);
        assert_eq!(p[(7, 7)], 0);
    }

    #[test]
    fn tile_overhang_is_zero_padded() {
        let m = Mat::from_fn(3, 3, |i, j| (i * 3 + j + 1) as i64);
        let t = m.tile(2, 2, 2, 2);
        assert_eq!(t.data, vec![9, 0, 0, 0]);
    }
}
