//! The inner-product algorithms (paper §2.2 and §3) on a plain matrix
//! type, plus the operation-count identities (Eqs. 1, 5, 6) and a tiled
//! GEMM driver matching the MXU's tile decomposition.
//!
//! These are the *functional* definitions: the cycle-level hardware
//! simulator in [`crate::mxu`] is checked against them, and they are in
//! turn checked against the Python oracle (`python/compile/kernels/ref.py`)
//! through shared test vectors.

mod counts;
mod ffip;
mod fip;
mod mat;
mod tiled;
pub mod winograd;

pub use counts::{op_counts, op_counts_offline_y, Algo, OpCounts};
pub use ffip::{ffip_matmul, y_from_b};
pub use fip::{alpha_terms, beta_terms, fip_matmul};
pub use mat::Mat;
pub use tiled::{tiled_matmul, tiled_matmul_parallel, TileShape};

/// Eq. (1): the traditional inner product, `C = A B`, with i64
/// accumulators (the simulator separately asserts values fit the
/// architecture's `2w + clog2(X)`-bit registers).
///
/// ikj loop order: the inner loop runs over contiguous B and C rows so
/// LLVM auto-vectorizes the multiply-accumulate (§Perf log in
/// EXPERIMENTS.md).
pub fn baseline_matmul(a: &Mat<i64>, b: &Mat<i64>) -> Mat<i64> {
    assert_eq!(a.cols, b.rows, "inner dimensions must match");
    let n = b.cols;
    let mut c = Mat::zeros(a.rows, n);
    for i in 0..a.rows {
        let crow = &mut c.data[i * n..(i + 1) * n];
        for (k, &av) in a.row(i).iter().enumerate() {
            let brow = b.row(k);
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, Rng};

    pub(crate) fn rand_mat(
        rng: &mut Rng,
        rows: usize,
        cols: usize,
        w: u32,
    ) -> Mat<i64> {
        Mat::from_fn(rows, cols, |_, _| rng.fixed(w, true))
    }

    #[test]
    fn baseline_identity() {
        let id = Mat::from_fn(4, 4, |i, j| i64::from(i == j));
        let mut rng = Rng::new(1);
        let a = rand_mat(&mut rng, 4, 4, 8);
        assert_eq!(baseline_matmul(&a, &id), a);
        assert_eq!(baseline_matmul(&id, &a), a);
    }

    #[test]
    fn all_three_algorithms_agree_property() {
        prop::check("algos agree", 40, 24, |c| {
            let size = c.size;
            let m = c.rng.range(1, size + 2);
            let k = 2 * c.rng.range(1, size + 2); // even K
            let n = c.rng.range(1, size + 2);
            let w = [4, 8, 12, 16][c.rng.range(0, 4)];
            let a = rand_mat(&mut c.rng, m, k, w);
            let b = rand_mat(&mut c.rng, k, n, w);
            let gold = baseline_matmul(&a, &b);
            assert_eq!(fip_matmul(&a, &b), gold, "FIP m={m} k={k} n={n}");
            assert_eq!(ffip_matmul(&a, &b, n), gold, "FFIP m={m} k={k} n={n}");
        });
    }

    #[test]
    fn ffip_tile_restart_agrees_for_all_tile_widths() {
        let mut rng = Rng::new(9);
        let a = rand_mat(&mut rng, 5, 8, 8);
        let b = rand_mat(&mut rng, 8, 12, 8);
        let gold = baseline_matmul(&a, &b);
        for tile_n in 1..=12 {
            assert_eq!(ffip_matmul(&a, &b, tile_n), gold, "tile_n={tile_n}");
        }
    }
}
