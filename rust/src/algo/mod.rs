//! The inner-product algorithms (paper §2.2 and §3) on a plain matrix
//! type, plus the operation-count identities (Eqs. 1, 5, 6) and a tiled
//! GEMM driver matching the MXU's tile decomposition.
//!
//! These are the *functional* definitions: the cycle-level hardware
//! simulator in [`crate::mxu`] is checked against them, and they are in
//! turn checked against the Python oracle (`python/compile/kernels/ref.py`)
//! through shared test vectors.

mod counts;
pub mod element;
mod ffip;
mod fip;
mod mat;
mod tiled;
pub mod winograd;

pub use counts::{op_counts, op_counts_offline_y, Algo, OpCounts};
pub use element::{AccElem, ElemKind, Element};
pub use ffip::{
    ffip_matmul, y_append_col, y_append_row, y_from_b, y_from_b_into,
};
pub use fip::{alpha_terms, beta_terms, fip_matmul};
pub use mat::Mat;
pub use tiled::{tiled_matmul, tiled_matmul_parallel, TileShape};
pub use winograd::{winograd_mult_counts, wino_eligible, ConvAlgo};

/// Eq. (1): the traditional inner product, `C = A B`, generic over the
/// storage [`Element`]: `i8`/`i16` operands accumulate in their widened
/// [`Element::Acc`] type, `i64` operands keep the historical
/// all-`i64` oracle semantics.  Narrow accumulators are guarded against
/// overflow at the engine boundary
/// ([`FixedSpec::gemm_acc_bits`](crate::arith::FixedSpec::gemm_acc_bits)).
///
/// ikj loop order: the inner loop runs over contiguous B and C rows so
/// LLVM auto-vectorizes the multiply-accumulate (§Perf log in
/// EXPERIMENTS.md).
pub fn baseline_matmul<E: Element>(a: &Mat<E>, b: &Mat<E>) -> Mat<E::Acc> {
    assert_eq!(a.cols, b.rows, "inner dimensions must match");
    let n = b.cols;
    let mut c = Mat::zeros(a.rows, n);
    for i in 0..a.rows {
        let crow = &mut c.data[i * n..(i + 1) * n];
        for (k, &av) in a.row(i).iter().enumerate() {
            let av = av.acc();
            let brow = b.row(k);
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv.acc();
            }
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, Rng};

    pub(crate) fn rand_mat(
        rng: &mut Rng,
        rows: usize,
        cols: usize,
        w: u32,
    ) -> Mat<i64> {
        Mat::from_fn(rows, cols, |_, _| rng.fixed(w, true))
    }

    #[test]
    fn baseline_identity() {
        let id = Mat::from_fn(4, 4, |i, j| i64::from(i == j));
        let mut rng = Rng::new(1);
        let a = rand_mat(&mut rng, 4, 4, 8);
        assert_eq!(baseline_matmul(&a, &id), a);
        assert_eq!(baseline_matmul(&id, &a), a);
    }

    #[test]
    fn all_three_algorithms_agree_property() {
        prop::check("algos agree", 40, 24, |c| {
            let size = c.size;
            let m = c.rng.range(1, size + 2);
            let k = 2 * c.rng.range(1, size + 2); // even K
            let n = c.rng.range(1, size + 2);
            let w = [4, 8, 12, 16][c.rng.range(0, 4)];
            let a = rand_mat(&mut c.rng, m, k, w);
            let b = rand_mat(&mut c.rng, k, n, w);
            let gold = baseline_matmul(&a, &b);
            assert_eq!(fip_matmul(&a, &b), gold, "FIP m={m} k={k} n={n}");
            assert_eq!(ffip_matmul(&a, &b, n), gold, "FFIP m={m} k={k} n={n}");
        });
    }

    /// Narrow storage elements (`i8`/`i16`) are bit-identical to the
    /// widened `i64` oracle for every algorithm — the tentpole property
    /// of the typed datapath.
    #[test]
    fn narrow_elements_agree_with_widened_oracle() {
        prop::check("i8/i16 == i64 oracle", 24, 16, |c| {
            let m = c.rng.range(1, c.size + 2);
            let k = 2 * c.rng.range(1, c.size + 2);
            let n = c.rng.range(1, c.size + 2);
            let tile_n = c.rng.range(1, n + 1);
            let a8 = Mat::from_fn(m, k, |_, _| c.rng.fixed(8, true) as i8);
            let b8 = Mat::from_fn(k, n, |_, _| c.rng.fixed(8, true) as i8);
            let gold8 = baseline_matmul(&a8.widen(), &b8.widen());
            assert_eq!(baseline_matmul(&a8, &b8).widen(), gold8);
            assert_eq!(fip_matmul(&a8, &b8).widen(), gold8);
            assert_eq!(ffip_matmul(&a8, &b8, tile_n).widen(), gold8);
            let a16 =
                Mat::from_fn(m, k, |_, _| c.rng.fixed(16, true) as i16);
            let b16 =
                Mat::from_fn(k, n, |_, _| c.rng.fixed(16, true) as i16);
            let gold16 = baseline_matmul(&a16.widen(), &b16.widen());
            assert_eq!(baseline_matmul(&a16, &b16).widen(), gold16);
            assert_eq!(fip_matmul(&a16, &b16).widen(), gold16);
            assert_eq!(ffip_matmul(&a16, &b16, tile_n).widen(), gold16);
        });
    }

    #[test]
    fn ffip_tile_restart_agrees_for_all_tile_widths() {
        let mut rng = Rng::new(9);
        let a = rand_mat(&mut rng, 5, 8, 8);
        let b = rand_mat(&mut rng, 8, 12, 8);
        let gold = baseline_matmul(&a, &b);
        for tile_n in 1..=12 {
            assert_eq!(ffip_matmul(&a, &b, tile_n), gold, "tile_n={tile_n}");
        }
    }
}
