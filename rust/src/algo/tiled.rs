//! Tiled GEMM driver matching the MXU tile decomposition (§4.3):
//!
//! > "the input matrices are divided into tiles fed to the MXU one-by-one.
//! > Following each tile multiplication, the partial tile products are
//! > accumulated outside of the MXU to generate each final matrix product
//! > tile."
//!
//! This is the *functional fast path* the coordinator uses when it needs
//! bit-exact results for a full network without paying for the
//! register-level cycle simulation; the decomposition (K tiles of depth X,
//! N tiles of width Y, M streamed in Tm-row chunks) is identical to what
//! the cycle simulator and the timing model use, so the three agree
//! structurally.

use super::element::Element;
use super::{baseline_matmul, ffip_matmul, fip_matmul, Algo, Mat};
use crate::util::ceil_div;

/// MXU tile geometry, in *effective* MAC dimensions (§4.1): `x` is the
/// K-depth of one loaded tile, `y` is the N-width, `tm` is the number of
/// a-rows streamed per tile pass.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TileShape {
    pub x: usize,
    pub y: usize,
    pub tm: usize,
}

impl TileShape {
    pub fn square(xy: usize, tm: usize) -> Self {
        TileShape { x: xy, y: xy, tm }
    }

    /// Tile counts for a given GEMM: (m_tiles, k_tiles, n_tiles).
    pub fn tiles(&self, m: usize, k: usize, n: usize) -> (usize, usize, usize) {
        (ceil_div(m, self.tm), ceil_div(k, self.x), ceil_div(n, self.y))
    }
}

/// Execute `C = A B` tile by tile through the chosen algorithm,
/// accumulating partial tile products outside the (simulated) MXU.
/// Edge tiles are zero-padded, exactly as the memory tiler feeds them.
/// Generic over the storage [`Element`]: tiles stream in the quantized
/// width, partial products accumulate in [`Element::Acc`].
pub fn tiled_matmul<E: Element>(
    a: &Mat<E>,
    b: &Mat<E>,
    algo: Algo,
    shape: TileShape,
) -> Mat<E::Acc> {
    assert_eq!(a.cols, b.rows);
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let (mt, kt, nt) = shape.tiles(m, k, n);
    let mut c = Mat::zeros(m, n);
    for it in 0..mt {
        for jt in 0..nt {
            // accumulate over K tiles (outside-MXU accumulation)
            let mut acc = Mat::zeros(shape.tm, shape.y);
            for kt_i in 0..kt {
                let a_tile =
                    a.tile(it * shape.tm, kt_i * shape.x, shape.tm, shape.x);
                let b_tile =
                    b.tile(kt_i * shape.x, jt * shape.y, shape.x, shape.y);
                let part = match algo {
                    Algo::Baseline => baseline_matmul(&a_tile, &b_tile),
                    Algo::Fip => fip_matmul(&a_tile, &b_tile),
                    // one loaded tile = one y recurrence: tile_n = full
                    // tile width
                    Algo::Ffip => ffip_matmul(&a_tile, &b_tile, shape.y),
                };
                acc = acc.add(&part);
            }
            // write back the valid region
            for i in 0..shape.tm.min(m - it * shape.tm) {
                for j in 0..shape.y.min(n - jt * shape.y) {
                    c[(it * shape.tm + i, jt * shape.y + j)] = acc[(i, j)];
                }
            }
        }
    }
    c
}

/// Multi-threaded [`tiled_matmul`] that spawns `threads` scoped std
/// threads *per call*: M-tile bands are independent (each output row
/// block touches disjoint C rows), so they fan out naively.
/// Bit-identical to the serial version.
///
/// This is the legacy spawn-per-call path, kept as the comparison
/// baseline for the persistent worker pool in [`crate::engine`] (bench
/// H6 in `benches/hotpath.rs`; §Perf log in EXPERIMENTS.md).  The
/// serving stack routes through [`crate::engine::GemmPool`] instead:
/// no thread spawn or tile-buffer allocation on the request path.
pub fn tiled_matmul_parallel<E: Element>(
    a: &Mat<E>,
    b: &Mat<E>,
    algo: Algo,
    shape: TileShape,
    threads: usize,
) -> Mat<E::Acc> {
    assert!(threads >= 1);
    let (m, n) = (a.rows, b.cols);
    let mt = ceil_div(m, shape.tm);
    if threads == 1 || mt == 1 {
        return tiled_matmul(a, b, algo, shape);
    }
    // split M into contiguous bands of whole tiles
    let bands = threads.min(mt);
    let tiles_per_band = ceil_div(mt, bands);
    let band_rows = tiles_per_band * shape.tm;
    let mut c = Mat::zeros(m, n);
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for band in 0..bands {
            let i0 = band * band_rows;
            if i0 >= m {
                break;
            }
            let rows = band_rows.min(m - i0);
            let a_band = a.tile(i0, 0, rows, a.cols);
            handles.push((
                i0,
                rows,
                scope.spawn(move || {
                    tiled_matmul(&a_band, b, algo, shape)
                }),
            ));
        }
        for (i0, rows, h) in handles {
            let part = h.join().expect("band worker");
            for i in 0..rows {
                let dst = (i0 + i) * n;
                c.data[dst..dst + n]
                    .copy_from_slice(part.row(i));
            }
        }
    });
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, Rng};

    #[test]
    fn parallel_equals_serial() {
        prop::check("parallel == serial", 10, 16, |c| {
            let m = c.rng.range(1, 6 * c.size + 2);
            let k = c.rng.range(1, 2 * c.size + 2);
            let n = c.rng.range(1, 2 * c.size + 2);
            let threads = c.rng.range(1, 5);
            let shape = TileShape {
                x: 2 * c.rng.range(1, 5),
                y: c.rng.range(1, 9),
                tm: c.rng.range(1, 17),
            };
            let a = Mat::from_fn(m, k, |_, _| c.rng.fixed(8, true));
            let b = Mat::from_fn(k, n, |_, _| c.rng.fixed(8, true));
            for algo in Algo::ALL {
                assert_eq!(
                    tiled_matmul_parallel(&a, &b, algo, shape, threads),
                    tiled_matmul(&a, &b, algo, shape),
                    "{algo:?} threads={threads}"
                );
            }
        });
    }

    #[test]
    fn tiled_equals_untiled_all_algos() {
        prop::check("tiled == untiled", 24, 20, |c| {
            let m = c.rng.range(1, 3 * c.size + 2);
            let k = c.rng.range(1, 3 * c.size + 2);
            let n = c.rng.range(1, 3 * c.size + 2);
            let x = 2 * c.rng.range(1, 9); // even K-depth
            let y = c.rng.range(1, 17);
            let tm = c.rng.range(1, 33);
            let a = Mat::from_fn(m, k, |_, _| c.rng.fixed(8, true));
            let b = Mat::from_fn(k, n, |_, _| c.rng.fixed(8, true));
            let gold = crate::algo::baseline_matmul(&a, &b);
            for algo in Algo::ALL {
                let got =
                    tiled_matmul(&a, &b, algo, TileShape { x, y, tm });
                assert_eq!(got, gold, "{algo:?} m={m} k={k} n={n} x={x} y={y} tm={tm}");
            }
        });
    }

    #[test]
    fn tile_counts() {
        let s = TileShape::square(64, 128);
        assert_eq!(s.tiles(147, 147, 147), (2, 3, 3));
        assert_eq!(s.tiles(64, 64, 64), (1, 1, 1));
        assert_eq!(s.tiles(1, 1, 1), (1, 1, 1));
    }

    #[test]
    fn resnet_first_layer_shape() {
        // ResNet conv1: K = 7*7*3 = 147 against X = 64 -> 3 K-tiles with
        // the last 45/64 utilized; this is where the paper's <100%
        // utilization comes from.
        let mut rng = Rng::new(5);
        let a = Mat::from_fn(10, 147, |_, _| rng.fixed(8, true));
        let b = Mat::from_fn(147, 64, |_, _| rng.fixed(8, true));
        let gold = crate::algo::baseline_matmul(&a, &b);
        let got = tiled_matmul(&a, &b, Algo::Ffip, TileShape::square(64, 16));
        assert_eq!(got, gold);
    }
}
