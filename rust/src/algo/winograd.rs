//! Winograd minimal filtering F(2x2, 3x3) convolution — the *other*
//! Winograd algorithm (Lavin & Gray [2]), implemented as the
//! prior-work baseline the paper compares against ([18], [31], [33])
//! and to demonstrate the paper's §6.2.2 composition claim:
//!
//! > "the Winograd convolution technique still results in matrix
//! > multiplication, which can therefore still achieve further compute
//! > efficiency improvements by also executing the resulting matrix
//! > multiplication on a systolic array architecture housing FFIP PEs."
//!
//! F(2x2, 3x3) computes a 2x2 output tile from a 4x4 input tile with 16
//! multiplications instead of 36 (2.25x reduction), via
//! `Y = A^T [ (G g G^T) .* (B^T d B) ] A`.  Batched over tiles and
//! channels, the elementwise stage becomes 16 independent (tiles x Cin)
//! x (Cin x Cout) GEMMs — which [`winograd_conv3x3`] executes through
//! any of the three inner-product algorithms, FFIP included.
//!
//! Integer exactness: the F(2,3) transform matrices are small integers
//! (B^T, G·2, A^T are integral; G has halves), so we scale G by 2 and
//! divide the result by 4 — exact for integer inputs, keeping the
//! bit-exactness story of the rest of the crate.

use super::element::AccElem;
use super::{tiled_matmul, Algo, Element, Mat, TileShape};
use crate::memory::ConvShape;

/// Per-conv-layer lowering choice: how `compile()` turns a conv layer
/// into GEMMs.  An axis of the autotuner's search space next to the
/// inner-product [`Algo`] — the two compose (§6.2.2): Winograd cuts
/// multiplies across the *spatial* dimension, (F)FIP across the *inner
/// product*.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum ConvAlgo {
    /// Implicit im2col lowering: one `(OH·OW) × (KH·KW·Cin) × Cout`
    /// GEMM per image (the historical, always-applicable path).
    #[default]
    Im2Gemm,
    /// Winograd F(2×2, 3×3) lowering: 16 elementwise-stage
    /// `(tiles × Cin) × Cout` GEMMs per image, each run under the
    /// layer's inner-product [`Algo`].  Only for [`wino_eligible`]
    /// layers.
    WinogradFfip,
}

impl ConvAlgo {
    pub fn name(&self) -> &'static str {
        match self {
            ConvAlgo::Im2Gemm => "im2gemm",
            ConvAlgo::WinogradFfip => "winograd",
        }
    }
}

/// True when a conv layer can lower through [`ConvAlgo::WinogradFfip`]:
/// dense 3×3 stride-1 with even output dims (F(2,3) tiles the output in
/// 2×2 blocks; padding is fine — the tile gather zero-fills outside the
/// input).
pub fn wino_eligible(shape: &ConvShape, groups: usize) -> bool {
    groups == 1
        && shape.kh == 3
        && shape.kw == 3
        && shape.stride == 1
        && shape.out_h() % 2 == 0
        && shape.out_w() % 2 == 0
}

/// 3x3 convolution, stride 1, no padding, direct reference.
pub fn direct_conv3x3(
    input: &Mat<i64>,  // (H*W, Cin) row-major spatial
    h: usize,
    w: usize,
    weights: &[Mat<i64>], // per (cin, cout): weights[cout] is (3*3*Cin) col? see below
    cin: usize,
    cout: usize,
) -> Mat<i64> {
    // weights: single Mat (9*Cin, Cout), k index = (kh*3 + kw)*cin + c
    assert_eq!(weights.len(), 1);
    let wmat = &weights[0];
    assert_eq!(wmat.rows, 9 * cin);
    assert_eq!(wmat.cols, cout);
    let (oh, ow) = (h - 2, w - 2);
    let mut out = Mat::zeros(oh * ow, cout);
    for oy in 0..oh {
        for ox in 0..ow {
            for co in 0..cout {
                let mut acc = 0;
                for kh in 0..3 {
                    for kw in 0..3 {
                        for c in 0..cin {
                            let iv =
                                input[((oy + kh) * w + (ox + kw), c)];
                            let wv = wmat[((kh * 3 + kw) * cin + c, co)];
                            acc += iv * wv;
                        }
                    }
                }
                out[(oy * ow + ox, co)] = acc;
            }
        }
    }
    out
}

/// `B^T d B` for one 4x4 input tile `d`, generic over the accumulator
/// domain (every coefficient is 0/±1, so magnitudes grow at most ×4 —
/// `BITS + 3` bits always suffice).
pub fn input_transform<A: AccElem>(d: &[[A; 4]; 4]) -> [[A; 4]; 4] {
    // B^T = [1 0 -1 0; 0 1 1 0; 0 -1 1 0; 0 1 0 -1]
    let mut t = [[A::default(); 4]; 4];
    for j in 0..4 {
        t[0][j] = d[0][j] - d[2][j];
        t[1][j] = d[1][j] + d[2][j];
        t[2][j] = d[2][j] - d[1][j];
        t[3][j] = d[1][j] - d[3][j];
    }
    let mut v = [[A::default(); 4]; 4];
    for i in 0..4 {
        v[i][0] = t[i][0] - t[i][2];
        v[i][1] = t[i][1] + t[i][2];
        v[i][2] = t[i][2] - t[i][1];
        v[i][3] = t[i][1] - t[i][3];
    }
    v
}

/// `(2G) g (2G)^T` for one 3x3 kernel `g` — scaled by 4 to stay integral
/// (G = [1 0 0; .5 .5 .5; .5 -.5 .5; 0 0 1]).  Magnitudes grow at most
/// ×9 (row coefficient sums ≤ 3 per side).
pub fn weight_transform<A: AccElem>(g: &[[A; 3]; 3]) -> [[A; 4]; 4] {
    let mut t = [[A::default(); 3]; 4]; // (2G) g
    for j in 0..3 {
        t[0][j] = g[0][j] + g[0][j];
        t[1][j] = g[0][j] + g[1][j] + g[2][j];
        t[2][j] = g[0][j] - g[1][j] + g[2][j];
        t[3][j] = g[2][j] + g[2][j];
    }
    let mut u = [[A::default(); 4]; 4]; // ... (2G)^T
    for i in 0..4 {
        u[i][0] = t[i][0] + t[i][0];
        u[i][1] = t[i][0] + t[i][1] + t[i][2];
        u[i][2] = t[i][0] - t[i][1] + t[i][2];
        u[i][3] = t[i][2] + t[i][2];
    }
    u
}

/// `A^T m A` for one 4x4 elementwise-product tile, then /4 (undoing the
/// weight scaling). A^T = [1 1 1 0; 0 1 -1 -1].
pub fn output_transform<A: AccElem>(m: &[[A; 4]; 4]) -> [[A; 2]; 2] {
    let mut t = [[A::default(); 4]; 2];
    for j in 0..4 {
        t[0][j] = m[0][j] + m[1][j] + m[2][j];
        t[1][j] = m[1][j] - m[2][j] - m[3][j];
    }
    let mut y = [[A::default(); 2]; 2];
    for i in 0..2 {
        let a = (t[i][0] + t[i][1] + t[i][2]).to_i64();
        let b = (t[i][1] - t[i][2] - t[i][3]).to_i64();
        assert!(a % 4 == 0 && b % 4 == 0, "integral Winograd invariant");
        y[i][0] = A::from_i64(a / 4);
        y[i][1] = A::from_i64(b / 4);
    }
    y
}

/// Narrow a transformed-domain value into [`Element::Wide`] storage.
/// Exact by the transform growth bounds (`input_transform` ×4,
/// `weight_transform` ×9 — both fit the one-step-wider element).
#[inline]
pub fn to_wide<E: Element>(v: E::Acc) -> E::Wide {
    <E::Wide as Element>::from_i64(v.to_i64())
        .expect("Winograd-transformed value exceeds the Wide element")
}

/// F(2x2, 3x3) Winograd convolution with the 16 elementwise stages
/// batched into GEMMs executed by `algo` on an MXU tile `shape` — the
/// §6.2.2 composition (Winograd *on top of* FFIP).  Generic over the
/// storage [`Element`]: transformed tiles travel as [`Element::Wide`]
/// (one widening step absorbs the ×4/×9 transform growth) and the GEMM
/// stage accumulates in the wide element's own accumulator.
///
/// `input`: (H*W, Cin); `wmat`: (9*Cin, Cout) with k = (kh*3+kw)*cin+c.
/// Output: ((H-2)*(W-2), Cout). H-2 and W-2 must be even.
pub fn winograd_conv3x3<E: Element>(
    input: &Mat<E>,
    h: usize,
    w: usize,
    wmat: &Mat<E>,
    cin: usize,
    cout: usize,
    algo: Algo,
    shape: TileShape,
) -> Mat<E::Acc> {
    let (oh, ow) = (h - 2, w - 2);
    assert!(oh % 2 == 0 && ow % 2 == 0, "F(2,3) needs even output dims");
    let (th, tw) = (oh / 2, ow / 2);
    let n_tiles = th * tw;

    // -- input transform: V[16][tile][cin]
    let mut v = vec![Mat::<E::Wide>::zeros(n_tiles, cin); 16];
    for ty in 0..th {
        for tx in 0..tw {
            for c in 0..cin {
                let mut d = [[<E::Acc>::default(); 4]; 4];
                for (i, row) in d.iter_mut().enumerate() {
                    for (j, cell) in row.iter_mut().enumerate() {
                        *cell = input
                            [((2 * ty + i) * w + 2 * tx + j, c)]
                            .acc();
                    }
                }
                let tv = input_transform(&d);
                for (i, row) in tv.iter().enumerate() {
                    for (j, &val) in row.iter().enumerate() {
                        v[i * 4 + j][(ty * tw + tx, c)] =
                            to_wide::<E>(val);
                    }
                }
            }
        }
    }

    // -- weight transform: U[16][cin][cout] (scaled by 4)
    let mut u = vec![Mat::<E::Wide>::zeros(cin, cout); 16];
    for co in 0..cout {
        for c in 0..cin {
            let mut g = [[<E::Acc>::default(); 3]; 3];
            for (kh, row) in g.iter_mut().enumerate() {
                for (kw, cell) in row.iter_mut().enumerate() {
                    *cell = wmat[((kh * 3 + kw) * cin + c, co)].acc();
                }
            }
            let tu = weight_transform(&g);
            for (i, row) in tu.iter().enumerate() {
                for (j, &val) in row.iter().enumerate() {
                    u[i * 4 + j][(c, co)] = to_wide::<E>(val);
                }
            }
        }
    }

    // -- 16 batched GEMMs through the chosen inner-product algorithm:
    //    M[xi] = V[xi] (tiles x cin)  @  U[xi] (cin x cout)
    let m: Vec<Mat<<E::Wide as Element>::Acc>> = (0..16)
        .map(|xi| tiled_matmul(&v[xi], &u[xi], algo, shape))
        .collect();

    // -- output transform per tile/cout
    let mut out = Mat::zeros(oh * ow, cout);
    for t in 0..n_tiles {
        let (ty, tx) = (t / tw, t % tw);
        for co in 0..cout {
            let mut mm =
                [[<<E::Wide as Element>::Acc>::default(); 4]; 4];
            for (i, row) in mm.iter_mut().enumerate() {
                for (j, cell) in row.iter_mut().enumerate() {
                    *cell = m[i * 4 + j][(t, co)];
                }
            }
            let y = output_transform(&mm);
            for (i, row) in y.iter().enumerate() {
                for (j, &val) in row.iter().enumerate() {
                    out[((2 * ty + i) * ow + 2 * tx + j, co)] =
                        <E::Acc>::from_i64(val.to_i64());
                }
            }
        }
    }
    out
}

/// Multiplication counts: direct vs Winograd GEMM stage (per §6.2.2's
/// compute-reduction comparison). Returns (direct, winograd_gemm_mults).
pub fn winograd_mult_counts(
    oh: usize,
    ow: usize,
    cin: usize,
    cout: usize,
) -> (u64, u64) {
    let direct = (oh * ow * 9 * cin * cout) as u64;
    let tiles = (oh / 2) * (ow / 2);
    let wino = (16 * tiles * cin * cout) as u64;
    (direct, wino)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, Rng};

    fn setup(
        rng: &mut Rng,
        h: usize,
        w: usize,
        cin: usize,
        cout: usize,
    ) -> (Mat<i64>, Mat<i64>) {
        let input = Mat::from_fn(h * w, cin, |_, _| rng.fixed(7, true));
        let wmat = Mat::from_fn(9 * cin, cout, |_, _| rng.fixed(6, true));
        (input, wmat)
    }

    #[test]
    fn winograd_equals_direct_exactly() {
        let mut rng = Rng::new(1);
        let (h, w, cin, cout) = (8, 10, 3, 4);
        let (input, wmat) = setup(&mut rng, h, w, cin, cout);
        let direct =
            direct_conv3x3(&input, h, w, &[wmat.clone()], cin, cout);
        for algo in Algo::ALL {
            let got = winograd_conv3x3(
                &input,
                h,
                w,
                &wmat,
                cin,
                cout,
                algo,
                TileShape::square(4, 8),
            );
            assert_eq!(got, direct, "{algo:?}");
        }
    }

    #[test]
    fn winograd_property_sweep() {
        prop::check("winograd == direct", 12, 6, |c| {
            let h = 2 * c.rng.range(2, c.size + 3);
            let w = 2 * c.rng.range(2, c.size + 3);
            let cin = c.rng.range(1, 5);
            let cout = c.rng.range(1, 5);
            let (input, wmat) = setup(&mut c.rng, h, w, cin, cout);
            let direct =
                direct_conv3x3(&input, h, w, &[wmat.clone()], cin, cout);
            let got = winograd_conv3x3(
                &input,
                h,
                w,
                &wmat,
                cin,
                cout,
                Algo::Ffip,
                TileShape::square(4, 4),
            );
            assert_eq!(got, direct);
        });
    }

    #[test]
    fn narrow_elements_match_the_wide_oracle() {
        // the generic Winograd path on i8/i16 storage is bit-identical
        // to the i64 oracle (transformed tiles travel as Element::Wide)
        let mut rng = Rng::new(7);
        let (h, w, cin, cout) = (6, 8, 2, 3);
        let (input, wmat) = setup(&mut rng, h, w, cin, cout);
        let gold = winograd_conv3x3(
            &input,
            h,
            w,
            &wmat,
            cin,
            cout,
            Algo::Ffip,
            TileShape::square(4, 4),
        );
        let i8in: Mat<i8> = input.narrow().unwrap();
        let i8w: Mat<i8> = wmat.narrow().unwrap();
        let got8 = winograd_conv3x3(
            &i8in,
            h,
            w,
            &i8w,
            cin,
            cout,
            Algo::Ffip,
            TileShape::square(4, 4),
        );
        assert_eq!(got8.widen(), gold);
        let i16in: Mat<i16> = input.narrow().unwrap();
        let i16w: Mat<i16> = wmat.narrow().unwrap();
        let got16 = winograd_conv3x3(
            &i16in,
            h,
            w,
            &i16w,
            cin,
            cout,
            Algo::Fip,
            TileShape::square(4, 4),
        );
        assert_eq!(got16.widen(), gold);
    }

    #[test]
    fn eligibility_predicate() {
        let base = ConvShape {
            h: 8,
            w: 8,
            cin: 4,
            cout: 8,
            kh: 3,
            kw: 3,
            stride: 1,
            pad: 1,
        };
        assert!(wino_eligible(&base, 1)); // 8x8 output, even
        assert!(!wino_eligible(&base, 2)); // grouped
        assert!(!wino_eligible(&ConvShape { stride: 2, ..base }, 1));
        assert!(!wino_eligible(&ConvShape { kh: 5, kw: 5, ..base }, 1));
        // 7x7 output: odd output dims cannot tile in 2x2 blocks
        assert!(!wino_eligible(&ConvShape { pad: 0, h: 9, w: 9, ..base }, 1));
    }

    #[test]
    fn multiplication_reduction_2_25x() {
        let (direct, wino) = winograd_mult_counts(56, 56, 64, 64);
        let ratio = direct as f64 / wino as f64;
        assert!((2.2..2.3).contains(&ratio), "{ratio}");
    }

    #[test]
    fn composition_stacks_reductions() {
        // §6.2.2: Winograd (2.25x fewer mults) composed with FFIP (~2x
        // fewer MACs in hardware) => ~4.5x total multiplier reduction
        // vs direct baseline conv.
        let (direct, wino) = winograd_mult_counts(56, 56, 64, 64);
        let ffip_hw_factor = 2.0; // half the physical multipliers
        let total = direct as f64 / (wino as f64 / ffip_hw_factor);
        assert!(total > 4.0, "{total}");
    }
}
