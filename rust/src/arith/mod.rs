//! Fixed-point arithmetic semantics (paper §4.1, §4.4).
//!
//! The hardware quantizes weights and activations to `w` bits, each either
//! signed or unsigned.  The paper's `d` parameter captures the pre-adder
//! widening penalty of mixed signedness:
//!
//! > *d = 1 if a and b are both signed or both unsigned, and d = 2 if
//! > either a or b is signed while the other is unsigned.*
//!
//! [`FixedSpec`] carries `(w, signedness, signedness)` through the PE cost
//! models, the resource estimator and the simulators, and provides
//! range-checking helpers so the bit-accurate simulator can assert that no
//! datapath value ever exceeds the register width the architecture
//! allocates for it.

use crate::util::clog2;

/// Signedness of a quantized operand.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Sign {
    Signed,
    Unsigned,
}

/// Fixed-point datapath specification: operand bitwidth and signedness of
/// the a (activation) and b (weight) operands.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FixedSpec {
    /// Quantized bitwidth of both operands (paper evaluates w in 8..=16).
    pub w: u32,
    pub sign_a: Sign,
    pub sign_b: Sign,
}

impl FixedSpec {
    /// Both-signed spec — the recommended configuration (§4.4), d = 1.
    pub const fn signed(w: u32) -> Self {
        FixedSpec { w, sign_a: Sign::Signed, sign_b: Sign::Signed }
    }

    /// Mixed signed/unsigned spec — the penalized configuration, d = 2.
    pub const fn mixed(w: u32) -> Self {
        FixedSpec { w, sign_a: Sign::Signed, sign_b: Sign::Unsigned }
    }

    /// The paper's `d`: 1 when both operands share signedness, else 2.
    pub const fn d(&self) -> u32 {
        match (self.sign_a, self.sign_b) {
            (Sign::Signed, Sign::Signed)
            | (Sign::Unsigned, Sign::Unsigned) => 1,
            _ => 2,
        }
    }

    /// Bits needed for the FIP/FFIP pre-adder output a + b: `w + d`
    /// (§4.4: w+1 if same signedness, w+2 otherwise — i.e. w + d).
    pub const fn pair_sum_bits(&self) -> u32 {
        self.w + self.d()
    }

    /// Bits of one multiplier output for (F)FIP: product of two
    /// (w+d)-bit pair sums.
    pub const fn fip_product_bits(&self) -> u32 {
        2 * self.pair_sum_bits()
    }

    /// Accumulator width for an MXU of width `x` effective MACs:
    /// `2w + clog2(X)` (paper Fig. 1 datapaths).
    pub const fn acc_bits(&self, x: usize) -> u32 {
        2 * self.w + clog2(x as u64)
    }

    /// Signed accumulator bits that provably hold **every** per-tile
    /// partial and the full cross-tile accumulation of a `K`-deep GEMM
    /// executed in depth-`x` tiles — the `2w + clog2(X)` rule of
    /// [`FixedSpec::acc_bits`] extended to (a) the fast algorithms'
    /// wider products (pair sums are `w + d` bits, and the kernel
    /// result carries the `+ alpha + beta` correction magnitude) and
    /// (b) the outside-MXU accumulation over `ceil(K/x)` tiles.
    ///
    /// This is the *release-mode* overflow guard for the narrow
    /// ([`i8`]/[`i16`]) element datapath: the engine asserts
    /// `gemm_acc_bits(..) <= Acc::BITS` once per submitted job, which
    /// bounds every tile the job's kernels will touch — debug-build
    /// overflow panics are thereby promoted to an explicit, always-on
    /// precondition (see `engine/pool.rs`).
    pub fn gemm_acc_bits(&self, fast: bool, x: usize, k: usize) -> u32 {
        let (amax, bmax) = self.operand_magnitudes();
        bits_for_magnitude(gemm_acc_worst(fast, x, k, amax, bmax))
    }

    /// Accumulator guard for a conv layer lowered through the Winograd
    /// F(2,3) × (F)FIP composition, whose 16 elementwise-stage GEMMs
    /// (depth `cin`) run on *transformed* operands: `BᵀdB` grows input
    /// magnitudes by at most ×4 (each Bᵀ row's absolute coefficient sum
    /// is 2, applied on both sides), `(2G)g(2G)ᵀ` grows weights by at
    /// most ×9 (row sums ≤ 3 per side), and the output transform `AᵀmA`
    /// accumulates up to 9 elementwise products (row sums ≤ 3 per side)
    /// before its exact ÷4.  The GEMM-stage worst case with the inflated
    /// magnitudes, further scaled ×9 for the output accumulation, bounds
    /// every value the Winograd datapath holds — checked against the
    /// `Element::Wide` accumulator width at compile time (see
    /// `coordinator::model::storage_obstacle_for_plan`).
    pub fn winograd_acc_bits(&self, fast: bool, x: usize, cin: usize) -> u32 {
        let (amax, bmax) = self.operand_magnitudes();
        let worst = gemm_acc_worst(fast, x, cin, 4 * amax, 9 * bmax);
        bits_for_magnitude(9 * worst)
    }

    /// Accumulator guard for the ABFT checksum datapath of an `M x K x N`
    /// GEMM (`engine::abft`): the per-row verification invariant
    /// `rowsum(C_i) == A_i · bsum` sums `n` guarded accumulators on the
    /// left and dots `K` activations against the stored B row checksums
    /// `bsum[k] = Σ_j b[k][j]` (magnitude ≤ `n · bmax`) on the right —
    /// both sides are bounded by `n ×` the plain GEMM worst case.
    /// Checked at compile time against the accumulator width so a
    /// checksum can never overflow before the guarded accumulator would;
    /// layers whose checksum headroom does not fit compile with ABFT
    /// disabled instead of risking a false trip.
    pub fn abft_acc_bits(
        &self,
        fast: bool,
        x: usize,
        k: usize,
        n: usize,
    ) -> u32 {
        let (amax, bmax) = self.operand_magnitudes();
        let worst = gemm_acc_worst(fast, x, k, amax, bmax);
        bits_for_magnitude(n.max(1) as u128 * worst)
    }

    /// Largest absolute values of the (a, b) operands under this spec.
    fn operand_magnitudes(&self) -> (u128, u128) {
        let (alo, ahi) = self.a_range();
        let (blo, bhi) = self.b_range();
        (
            alo.unsigned_abs().max(ahi.unsigned_abs()) as u128,
            blo.unsigned_abs().max(bhi.unsigned_abs()) as u128,
        )
    }

    /// Value range of a `bits`-wide register under this spec's operand
    /// signedness (`signed` selects two's complement vs unsigned).
    pub fn range(bits: u32, signed: bool) -> (i64, i64) {
        if signed {
            (-(1i64 << (bits - 1)), (1i64 << (bits - 1)) - 1)
        } else {
            (0, (1i64 << bits) - 1)
        }
    }

    /// True iff `v` fits in a `bits`-wide signed register.
    pub fn fits_signed(v: i64, bits: u32) -> bool {
        let (lo, hi) = Self::range(bits, true);
        v >= lo && v <= hi
    }

    /// Range of a quantized operand under this spec (the a operand).
    pub fn a_range(&self) -> (i64, i64) {
        Self::range(self.w, matches!(self.sign_a, Sign::Signed))
    }

    /// Range of the b operand.
    pub fn b_range(&self) -> (i64, i64) {
        Self::range(self.w, matches!(self.sign_b, Sign::Signed))
    }
}

/// Worst-case accumulated magnitude of a `K`-deep GEMM executed in
/// depth-`x` tiles on operands of magnitude (`amax`, `bmax`) — the
/// shared core of [`FixedSpec::gemm_acc_bits`] and
/// [`FixedSpec::winograd_acc_bits`].
fn gemm_acc_worst(
    fast: bool,
    x: usize,
    k: usize,
    amax: u128,
    bmax: u128,
) -> u128 {
    let x = x.max(1) as u128;
    let kt = crate::util::ceil_div(k.max(1), x as usize) as u128;
    if fast {
        // Eq. (2) per tile: x/2 products of pair sums plus the
        // alpha and beta corrections, each bounded by x/2 products
        // of the raw operands (x is even on the fast paths; the
        // max(1) keeps degenerate x = 1 conservative).
        let pairs = (x / 2).max(1);
        kt * pairs
            * ((amax + bmax) * (amax + bmax) + amax * amax + bmax * bmax)
    } else {
        // Eq. (1): K multiply-accumulates of raw operands.
        kt * x * amax * bmax
    }
}

/// Saturate `v` into a `bits`-wide signed register (post-GEMM requantize).
pub fn saturate_signed(v: i64, bits: u32) -> i64 {
    let (lo, hi) = FixedSpec::range(bits, true);
    v.clamp(lo, hi)
}

/// Smallest signed register width (bits, including sign) whose range
/// `[-2^(b-1), 2^(b-1) - 1]` contains ±`mag`.
pub fn bits_for_magnitude(mag: u128) -> u32 {
    if mag == 0 {
        return 1;
    }
    // need mag <= 2^(b-1) - 1, i.e. b = bit_length(mag) + 1
    (128 - mag.leading_zeros()) + 1
}

/// Bits required to represent `v` in two's complement.
pub fn bits_for_signed(v: i64) -> u32 {
    match v {
        0 | -1 => 1,
        v if v > 0 => 64 - v.leading_zeros() + 1,
        v => 64 - (!v).leading_zeros() + 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn d_rule_matches_paper() {
        assert_eq!(FixedSpec::signed(8).d(), 1);
        assert_eq!(FixedSpec::mixed(8).d(), 2);
        let both_unsigned = FixedSpec {
            w: 8,
            sign_a: Sign::Unsigned,
            sign_b: Sign::Unsigned,
        };
        assert_eq!(both_unsigned.d(), 1);
    }

    #[test]
    fn pair_sum_width_covers_worst_case() {
        // w+1 bits must hold the sum of two signed w-bit values;
        // w+2 bits must hold signed + unsigned.
        for w in 2..=16u32 {
            let s = FixedSpec::signed(w);
            let (lo, hi) = s.a_range();
            for (x, y) in [(lo, lo), (hi, hi), (lo, hi)] {
                assert!(
                    FixedSpec::fits_signed(x + y, s.pair_sum_bits()),
                    "w={w} sum {x}+{y}"
                );
            }
            let m = FixedSpec::mixed(w);
            let (alo, ahi) = m.a_range();
            let (blo, bhi) = m.b_range();
            for (x, y) in [(alo, blo), (ahi, bhi), (alo, bhi), (ahi, blo)] {
                assert!(
                    FixedSpec::fits_signed(x + y, m.pair_sum_bits()),
                    "w={w} mixed sum {x}+{y}"
                );
            }
        }
    }

    #[test]
    fn w_plus_one_is_tight_for_same_signedness() {
        // the architecture allocates exactly w+1: w bits must NOT suffice
        let s = FixedSpec::signed(8);
        let (lo, _) = s.a_range();
        assert!(!FixedSpec::fits_signed(lo + lo, s.w));
        assert!(FixedSpec::fits_signed(lo + lo, s.w + 1));
    }

    #[test]
    fn acc_width() {
        assert_eq!(FixedSpec::signed(8).acc_bits(64), 22);
        assert_eq!(FixedSpec::signed(16).acc_bits(64), 38);
    }

    #[test]
    fn bits_for_magnitude_boundaries() {
        assert_eq!(bits_for_magnitude(0), 1);
        assert_eq!(bits_for_magnitude(1), 2); // ±1 needs 2 bits
        assert_eq!(bits_for_magnitude(127), 8);
        assert_eq!(bits_for_magnitude(128), 9); // +128 overflows i8
        assert_eq!(bits_for_magnitude((1 << 31) - 1), 32);
        assert_eq!(bits_for_magnitude(1 << 31), 33);
    }

    #[test]
    fn gemm_acc_guard_brackets_the_worst_case() {
        let s = FixedSpec::signed(8);
        // one baseline tile of depth 64: 2w + clog2(64) + small slack
        // for the ±128 signed extreme (the paper's 2w + clog2(X) uses
        // the 2^(w-1) magnitude, which is exactly what we bound)
        let b1 = s.gemm_acc_bits(false, 64, 64);
        assert!(b1 >= s.acc_bits(64), "{b1} vs {}", s.acc_bits(64));
        assert!(b1 <= s.acc_bits(64) + 2, "{b1}");
        // an 8-bit serving layer (K = 4608, FFIP 64-deep tiles) fits a
        // 32-bit accumulator…
        assert!(s.gemm_acc_bits(true, 64, 4608) <= 32);
        // …but a pathologically deep K does not — the guard is what
        // forces such models onto wider storage
        assert!(s.gemm_acc_bits(false, 64, 1 << 18) > 32);
        // 16-bit operands always need the 64-bit accumulator
        let s16 = FixedSpec::signed(16);
        assert!(s16.gemm_acc_bits(true, 64, 4608) > 32);
        assert!(s16.gemm_acc_bits(true, 64, 4608) <= 64);
    }

    #[test]
    fn winograd_guard_covers_the_transform_growth() {
        let s = FixedSpec::signed(8);
        // the transformed domain costs a fixed number of extra bits
        // (×4 · ×9 operand growth and the ×9 output accumulation are
        // all constants), so the Winograd guard sits a constant margin
        // above the plain GEMM guard for the same depth …
        for k in [16usize, 64, 512, 4096] {
            let plain = s.gemm_acc_bits(true, 64, k);
            let wino = s.winograd_acc_bits(true, 64, k);
            assert!(wino > plain, "k={k}: {wino} vs {plain}");
            assert!(wino - plain <= 14, "k={k}: {wino} vs {plain}");
        }
        // … and an i8-storage conv's Winograd stage (i16 transformed
        // operands, i64 accumulator) has enormous headroom
        assert!(s.winograd_acc_bits(true, 64, 4608) <= 64);
        // a 16-bit model's Winograd stage also fits the i64 accumulator
        // at serving depths
        assert!(FixedSpec::signed(16).winograd_acc_bits(true, 64, 4608) <= 64);
    }

    #[test]
    fn abft_guard_scales_with_the_checksummed_width() {
        let s = FixedSpec::signed(8);
        // the row-sum checksum accumulates N guarded values, so the
        // guard sits ~clog2(N) above the plain GEMM guard …
        for n in [1usize, 8, 64, 512] {
            let plain = s.gemm_acc_bits(true, 64, 64);
            let abft = s.abft_acc_bits(true, 64, 64, n);
            assert!(abft >= plain, "n={n}: {abft} vs {plain}");
            assert!(
                abft <= plain + clog2(n as u64) + 1,
                "n={n}: {abft} vs {plain}"
            );
        }
        // an i8 serving layer's checksums fit the i32 accumulator …
        assert!(s.abft_acc_bits(true, 64, 512, 512) <= 32);
        // … but a pathologically wide N does not: compile() must fall
        // back to unchecked execution rather than risk a false trip
        assert!(s.abft_acc_bits(false, 64, 1 << 14, 1 << 14) > 32);
        // 16-bit operands still fit the 64-bit accumulator at serving
        // widths
        assert!(FixedSpec::signed(16).abft_acc_bits(true, 64, 4608, 4096) <= 64);
    }

    #[test]
    fn saturate() {
        assert_eq!(saturate_signed(1000, 8), 127);
        assert_eq!(saturate_signed(-1000, 8), -128);
        assert_eq!(saturate_signed(5, 8), 5);
    }

    #[test]
    fn bits_for_signed_boundaries() {
        assert_eq!(bits_for_signed(127), 8);
        assert_eq!(bits_for_signed(-128), 8);
        assert_eq!(bits_for_signed(128), 9);
        assert_eq!(bits_for_signed(0), 1);
        assert_eq!(bits_for_signed(-1), 1);
    }
}
