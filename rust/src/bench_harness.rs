//! Minimal benchmarking harness for the `harness = false` bench targets
//! (the offline vendor set has no criterion).  Provides warmup +
//! multi-iteration timing with min/mean/p50 reporting, and re-exports
//! `black_box`.

pub use std::hint::black_box;
use std::time::{Duration, Instant};

/// Timing summary of one benchmark.
#[derive(Debug, Clone, Copy)]
pub struct BenchResult {
    pub iters: u32,
    pub min: Duration,
    pub mean: Duration,
    pub p50: Duration,
}

impl BenchResult {
    pub fn line(&self, name: &str) -> String {
        format!(
            "bench {name:<44} iters={:<4} min={:>12?} p50={:>12?} mean={:>12?}",
            self.iters, self.min, self.p50, self.mean
        )
    }
}

/// Run `f` `iters` times after `warmup` runs; returns timing stats.
pub fn bench<F: FnMut()>(warmup: u32, iters: u32, mut f: F) -> BenchResult {
    assert!(iters >= 1);
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
    }
    samples.sort_unstable();
    let min = samples[0];
    let p50 = samples[samples.len() / 2];
    let mean = samples.iter().sum::<Duration>() / iters;
    BenchResult { iters, min, mean, p50 }
}

/// Run + print in one call. Returns the result for further use.
pub fn run_bench<F: FnMut()>(name: &str, warmup: u32, iters: u32, f: F) -> BenchResult {
    let r = bench(warmup, iters, f);
    println!("{}", r.line(name));
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_returns_ordered_stats() {
        let r = bench(1, 10, || {
            black_box((0..1000).sum::<u64>());
        });
        assert!(r.min <= r.p50);
        assert!(r.min <= r.mean);
        assert_eq!(r.iters, 10);
    }

    #[test]
    fn line_formats() {
        let r = bench(0, 2, || {});
        let s = r.line("x");
        assert!(s.contains("bench x"));
        assert!(s.contains("iters=2"));
    }
}
