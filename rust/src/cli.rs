//! Hand-rolled CLI (the offline vendor set has no clap).
//!
//! ```text
//! ffip fig2
//! ffip fig9 [--device sx660|gx1150] [--wbits 8|16]
//! ffip table --id 1|2|3
//! ffip simulate --model resnet-50 [--algo ffip] [--mxu 64] [--wbits 8]
//! ffip verify [--size 24]
//! ffip runtime-check [--artifacts artifacts]
//! ffip serve [--requests 64] [--artifacts artifacts]
//! ```

use std::collections::HashMap;

/// Parsed command line: subcommand + `--key value` flags.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub cmd: String,
    flags: HashMap<String, String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args, String> {
        let mut args = Args::default();
        let mut it = argv.iter();
        args.cmd = it.next().cloned().unwrap_or_else(|| "help".into());
        while let Some(a) = it.next() {
            let key = a
                .strip_prefix("--")
                .ok_or_else(|| format!("expected --flag, got {a:?}"))?;
            let val = it
                .next()
                .ok_or_else(|| format!("--{key} needs a value"))?;
            args.flags.insert(key.to_string(), val.clone());
        }
        Ok(args)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{key} must be an integer, got {v:?}")),
        }
    }

    /// Error on unknown flags (catches typos early).
    pub fn expect_only(&self, allowed: &[&str]) -> Result<(), String> {
        for k in self.flags.keys() {
            if !allowed.contains(&k.as_str()) {
                return Err(format!(
                    "unknown flag --{k} for `{}` (allowed: {})",
                    self.cmd,
                    allowed.join(", ")
                ));
            }
        }
        Ok(())
    }
}

pub const USAGE: &str = "\
ffip — Fast Inner-Product accelerator reproduction (Pogue & Nicolici, IEEE TC 2023)

USAGE: ffip <command> [flags]

COMMANDS
  fig2                       PE register cost sweep (paper Fig. 2)
  fig9                       MXU size sweep (paper Fig. 9)
      --device sx660|gx1150    (default sx660)
      --wbits  8|16            (default 8)
  table --id 1|2|3           comparison tables vs prior work (Tables 1-3)
  simulate                   time one model on the simulated accelerator
      --model  alexnet|vgg16|resnet-18|-34|-50|-101|-152
      --algo   baseline|fip|ffip   (default ffip)
      --mxu    N                  (default 64)
      --wbits  8|16               (default 8)
      --device sx660|gx1150       (default gx1150)
  workload                   per-layer GEMM trace + timing breakdown
      --model/--algo/--mxu/--wbits as for simulate
  verify                     cycle-accurate sim vs algorithm cross-check
      --size   N               (default 24)
  runtime-check              load + execute all AOT artifacts via PJRT
      --artifacts DIR          (default artifacts)
  serve                      batched inference demo over the PJRT model
      --requests N             (default 64)
      --artifacts DIR          (default artifacts)
  help                       this text
";

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parse_subcommand_and_flags() {
        let a = Args::parse(&sv(&["table", "--id", "2"])).unwrap();
        assert_eq!(a.cmd, "table");
        assert_eq!(a.get("id"), Some("2"));
        assert_eq!(a.get_usize("id", 0).unwrap(), 2);
    }

    #[test]
    fn defaults() {
        let a = Args::parse(&sv(&["fig9"])).unwrap();
        assert_eq!(a.get_or("device", "sx660"), "sx660");
        assert_eq!(a.get_usize("wbits", 8).unwrap(), 8);
    }

    #[test]
    fn errors() {
        assert!(Args::parse(&sv(&["x", "oops"])).is_err());
        assert!(Args::parse(&sv(&["x", "--flag"])).is_err());
        let a = Args::parse(&sv(&["x", "--bad", "1"])).unwrap();
        assert!(a.expect_only(&["good"]).is_err());
        assert!(a.get_usize("bad", 0).is_ok());
        let b = Args::parse(&sv(&["x", "--n", "abc"])).unwrap();
        assert!(b.get_usize("n", 0).is_err());
    }

    #[test]
    fn empty_argv_is_help() {
        let a = Args::parse(&[]).unwrap();
        assert_eq!(a.cmd, "help");
    }
}
