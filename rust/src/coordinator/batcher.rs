//! Dynamic request batcher.
//!
//! Accumulates requests until the accelerator batch size is reached or
//! the linger timeout expires, then emits a [`Batch`].  Partial batches
//! are padded to the fixed accelerator batch (the AOT artifact's static
//! shape) with zero rows that are dropped on the way out.

use super::Request;
use std::time::{Duration, Instant};

#[derive(Debug, Clone, Copy)]
pub struct BatcherConfig {
    /// Accelerator batch size (the artifact's static leading dim).
    pub batch: usize,
    /// Max time the first request of a batch waits for company.
    pub linger: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { batch: 4, linger: Duration::from_millis(2) }
    }
}

/// A formed batch: up to `cfg.batch` requests plus their arrival times.
#[derive(Debug)]
pub struct Batch {
    pub requests: Vec<(Request, Instant)>,
}

impl Batch {
    pub fn len(&self) -> usize {
        self.requests.len()
    }

    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }

    /// Remove and return every request whose input is not a `row_len`
    /// row.  The worker answers these with a typed
    /// [`RequestError::BadShape`](super::RequestError::BadShape)
    /// response *before* the batch reaches the backend, so one
    /// malformed client input can never panic the model's worker thread
    /// or poison the batch it rode in with.
    pub fn take_malformed(
        &mut self,
        row_len: usize,
    ) -> Vec<(Request, Instant)> {
        // fast path: submit-side validation rejects bad shapes before
        // they enter the queue, so this is almost always all-valid —
        // Vec::new() allocates nothing and the batch Vec is untouched
        if self.requests.iter().all(|(req, _)| req.input.len() == row_len) {
            return Vec::new();
        }
        let (good, bad): (Vec<_>, Vec<_>) = std::mem::take(&mut self.requests)
            .into_iter()
            .partition(|(req, _)| req.input.len() == row_len);
        self.requests = good;
        bad
    }

    /// Remove and return every request containing a value that does
    /// not fit a `bits`-wide signed storage element, with the first
    /// offending value of each.  The narrow-storage analogue of
    /// [`Batch::take_malformed`]: the worker answers these with typed
    /// [`RequestError::Domain`](super::RequestError::Domain) responses
    /// *before* the batch reaches the backend, so one client's
    /// out-of-range value never fails its co-batched neighbours.
    pub fn take_out_of_domain(
        &mut self,
        bits: u32,
    ) -> Vec<(Request, Instant, i32)> {
        let offender = |req: &Request| {
            req.input.iter().copied().find(|&v| {
                !crate::arith::FixedSpec::fits_signed(i64::from(v), bits)
            })
        };
        // fast path: quantized clients send in-domain values, so this
        // is almost always all-valid and allocates nothing
        if self.requests.iter().all(|(req, _)| offender(req).is_none()) {
            return Vec::new();
        }
        let mut bad = Vec::new();
        let mut good = Vec::new();
        for (req, t) in std::mem::take(&mut self.requests) {
            match offender(&req) {
                Some(v) => bad.push((req, t, v)),
                None => good.push((req, t)),
            }
        }
        self.requests = good;
        bad
    }

    /// Remove and return every request whose ragged sequence-length
    /// prefix (`row[0]` of the attention wire format) is negative or
    /// exceeds `max_seq`, with each offending prefix.  The ragged
    /// analogue of [`Batch::take_out_of_domain`]: the worker answers
    /// these with typed
    /// [`RequestError::BadSequence`](super::RequestError::BadSequence)
    /// responses *before* the batch reaches the backend, so one bad
    /// length never fails its co-batched neighbours.  Callers must have
    /// validated row lengths first ([`Batch::take_malformed`]), so every
    /// row is non-empty.
    pub fn take_bad_sequence(
        &mut self,
        max_seq: usize,
    ) -> Vec<(Request, Instant, i64)> {
        let ok = |req: &Request| {
            (0..=max_seq as i32).contains(&req.input[0])
        };
        // fast path: clients packing with `pack_ragged_row` can't send a
        // bad prefix, so this is almost always all-valid
        if self.requests.iter().all(|(req, _)| ok(req)) {
            return Vec::new();
        }
        let mut bad = Vec::new();
        let mut good = Vec::new();
        for (req, t) in std::mem::take(&mut self.requests) {
            if ok(&req) {
                good.push((req, t));
            } else {
                let len = i64::from(req.input[0]);
                bad.push((req, t, len));
            }
        }
        self.requests = good;
        bad
    }

    /// Remove and return every request that has already waited longer
    /// than `deadline` since it arrived at the batcher.  The worker
    /// answers these with typed
    /// [`RequestError::DeadlineExceeded`](super::RequestError::DeadlineExceeded)
    /// responses *before* the batch reaches the backend — stale work
    /// (queued behind a slow or wedged batch) sheds instead of
    /// occupying a batch slot whose result the client has given up on.
    pub fn take_expired(
        &mut self,
        deadline: Duration,
    ) -> Vec<(Request, Instant)> {
        // fast path: under a healthy deployment nothing queues longer
        // than the deadline, so this is almost always all-fresh
        if self.requests.iter().all(|(_, t)| t.elapsed() <= deadline) {
            return Vec::new();
        }
        let (good, stale): (Vec<_>, Vec<_>) =
            std::mem::take(&mut self.requests)
                .into_iter()
                .partition(|(_, t)| t.elapsed() <= deadline);
        self.requests = good;
        stale
    }

    /// Concatenate inputs, zero-padding to `batch` rows of `row_len`.
    /// Callers must have validated row lengths first
    /// ([`Batch::take_malformed`]).
    pub fn padded_input(&self, batch: usize, row_len: usize) -> Vec<i32> {
        let mut v = vec![0i32; batch * row_len];
        for (i, (req, _)) in self.requests.iter().enumerate() {
            assert_eq!(req.input.len(), row_len, "request row length");
            v[i * row_len..(i + 1) * row_len].copy_from_slice(&req.input);
        }
        v
    }
}

/// Pull-based batcher over an mpsc receiver.
pub struct Batcher {
    pub cfg: BatcherConfig,
    rx: std::sync::mpsc::Receiver<Request>,
}

impl Batcher {
    pub fn new(
        cfg: BatcherConfig,
        rx: std::sync::mpsc::Receiver<Request>,
    ) -> Self {
        Batcher { cfg, rx }
    }

    /// Block for the next batch; `None` when all senders are dropped.
    pub fn next_batch(&mut self) -> Option<Batch> {
        // block for the first request
        let first = self.rx.recv().ok()?;
        let t0 = Instant::now();
        let mut requests = vec![(first, t0)];
        // gather until full or linger expires
        while requests.len() < self.cfg.batch {
            let left = self.cfg.linger.saturating_sub(t0.elapsed());
            if left.is_zero() {
                break;
            }
            match self.rx.recv_timeout(left) {
                Ok(r) => requests.push((r, Instant::now())),
                Err(_) => break,
            }
        }
        Some(Batch { requests })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;

    fn req(id: u64, input: Vec<i32>) -> (Request, mpsc::Receiver<super::super::Response>) {
        let (tx, rx) = mpsc::channel();
        (Request { id, input, resp: tx }, rx)
    }

    #[test]
    fn batches_fill_to_capacity() {
        let (tx, rx) = mpsc::channel();
        let mut b = Batcher::new(
            BatcherConfig { batch: 3, linger: Duration::from_millis(50) },
            rx,
        );
        let mut keep = Vec::new();
        for i in 0..5 {
            let (r, rx) = req(i, vec![i as i32]);
            keep.push(rx);
            tx.send(r).unwrap();
        }
        let b1 = b.next_batch().unwrap();
        assert_eq!(b1.len(), 3);
        let b2 = b.next_batch().unwrap();
        assert_eq!(b2.len(), 2); // linger expires with 2 in hand... or
                                 // senders still alive: timeout path
    }

    #[test]
    fn padded_input_layout() {
        let (r1, _k1) = req(1, vec![1, 2]);
        let (r2, _k2) = req(2, vec![3, 4]);
        let t = Instant::now();
        let b = Batch { requests: vec![(r1, t), (r2, t)] };
        assert_eq!(b.padded_input(4, 2), vec![1, 2, 3, 4, 0, 0, 0, 0]);
    }

    #[test]
    fn take_malformed_splits_by_row_length() {
        let (r1, _k1) = req(1, vec![1, 2]);
        let (r2, _k2) = req(2, vec![3, 4, 5]); // wrong length
        let (r3, _k3) = req(3, vec![6, 7]);
        let t = Instant::now();
        let mut b = Batch { requests: vec![(r1, t), (r2, t), (r3, t)] };
        let bad = b.take_malformed(2);
        assert_eq!(bad.len(), 1);
        assert_eq!(bad[0].0.id, 2);
        assert_eq!(b.len(), 2);
        assert_eq!(b.padded_input(2, 2), vec![1, 2, 6, 7]);
    }

    #[test]
    fn none_when_senders_dropped() {
        let (tx, rx) = mpsc::channel::<Request>();
        drop(tx);
        let mut b = Batcher::new(BatcherConfig::default(), rx);
        assert!(b.next_batch().is_none());
    }

    /// batch = 1: every request is its own batch, emitted immediately
    /// (no linger wait), even with a backlog queued.
    #[test]
    fn batch_of_one_never_lingers() {
        let (tx, rx) = mpsc::channel();
        let mut b = Batcher::new(
            BatcherConfig { batch: 1, linger: Duration::from_secs(3600) },
            rx,
        );
        let mut keep = Vec::new();
        for i in 0..3 {
            let (r, k) = req(i, vec![i as i32]);
            keep.push(k);
            tx.send(r).unwrap();
        }
        let t0 = std::time::Instant::now();
        for i in 0..3 {
            let batch = b.next_batch().unwrap();
            assert_eq!(batch.len(), 1);
            assert_eq!(batch.requests[0].0.id, i, "FIFO order");
        }
        // an hour-long linger must not be observable with batch = 1
        assert!(t0.elapsed() < Duration::from_secs(60));
    }

    /// linger = 0: the first request ships alone even though more are
    /// already queued — zero linger means zero waiting for company.
    #[test]
    fn zero_linger_ships_first_request_alone() {
        let (tx, rx) = mpsc::channel();
        let mut b = Batcher::new(
            BatcherConfig { batch: 4, linger: Duration::ZERO },
            rx,
        );
        let mut keep = Vec::new();
        for i in 0..2 {
            let (r, k) = req(i, vec![0]);
            keep.push(k);
            tx.send(r).unwrap();
        }
        let b1 = b.next_batch().unwrap();
        assert_eq!(b1.len(), 1, "no gathering at linger = 0");
        let b2 = b.next_batch().unwrap();
        assert_eq!(b2.len(), 1);
    }

    /// Partial-batch zero-row padding round-trips: padded slots are
    /// zero rows, real rows are preserved at their slot offsets, and
    /// un-padding (taking the first `len` rows) recovers the inputs.
    #[test]
    fn partial_batch_zero_row_padding_roundtrip() {
        let (r1, _k1) = req(1, vec![7, -3]);
        let t = Instant::now();
        let b = Batch { requests: vec![(r1, t)] };
        let padded = b.padded_input(4, 2);
        assert_eq!(padded.len(), 4 * 2);
        assert_eq!(&padded[..2], &[7, -3], "slot 0 = the real request");
        assert!(padded[2..].iter().all(|&v| v == 0), "pad slots are zero");
        // round trip: slot rows 0..len() are exactly the request inputs
        for (slot, (req, _)) in b.requests.iter().enumerate() {
            assert_eq!(&padded[slot * 2..(slot + 1) * 2], &req.input[..]);
        }
        // empty batch degenerates to all-zero padding
        let empty = Batch { requests: vec![] };
        assert!(empty.is_empty());
        assert!(empty.padded_input(2, 3).iter().all(|&v| v == 0));
    }

    /// take_out_of_domain sweeps only the requests whose values exceed
    /// the signed storage range, reporting the first offender each,
    /// and preserves arrival order on both sides.
    #[test]
    fn take_out_of_domain_splits_and_reports_offender() {
        let t = Instant::now();
        let (r1, _k1) = req(1, vec![127, -128]); // extremes still fit i8
        let (r2, _k2) = req(2, vec![0, 1000]); // 1000 does not
        let (r3, _k3) = req(3, vec![-5, 5]);
        let (r4, _k4) = req(4, vec![-129, 0]); // -129 does not
        let mut b = Batch {
            requests: vec![(r1, t), (r2, t), (r3, t), (r4, t)],
        };
        let bad = b.take_out_of_domain(8);
        let bad_info: Vec<(u64, i32)> =
            bad.iter().map(|(r, _, v)| (r.id, *v)).collect();
        assert_eq!(bad_info, vec![(2, 1000), (4, -129)]);
        let good_ids: Vec<u64> =
            b.requests.iter().map(|(r, _)| r.id).collect();
        assert_eq!(good_ids, vec![1, 3]);
        // wide enough storage sweeps nothing
        assert!(b.take_out_of_domain(16).is_empty());
        assert_eq!(b.len(), 2);
    }

    /// take_bad_sequence sweeps only the requests whose ragged length
    /// prefix is negative or over max_seq, reporting each offending
    /// prefix, and preserves arrival order on both sides.  Length 0 and
    /// length == max_seq are legal.
    #[test]
    fn take_bad_sequence_splits_and_reports_prefix() {
        let t = Instant::now();
        let (r1, _k1) = req(1, vec![0, 9, 9]); // empty sequence: legal
        let (r2, _k2) = req(2, vec![3, 9, 9]); // over max_seq 2
        let (r3, _k3) = req(3, vec![2, 9, 9]); // exactly max_seq: legal
        let (r4, _k4) = req(4, vec![-1, 9, 9]); // negative
        let mut b = Batch {
            requests: vec![(r1, t), (r2, t), (r3, t), (r4, t)],
        };
        let bad = b.take_bad_sequence(2);
        let bad_info: Vec<(u64, i64)> =
            bad.iter().map(|(r, _, len)| (r.id, *len)).collect();
        assert_eq!(bad_info, vec![(2, 3), (4, -1)]);
        let good_ids: Vec<u64> =
            b.requests.iter().map(|(r, _)| r.id).collect();
        assert_eq!(good_ids, vec![1, 3]);
        // idempotent: a second sweep finds nothing
        assert!(b.take_bad_sequence(2).is_empty());
        assert_eq!(b.len(), 2);
    }

    /// take_malformed preserves arrival order on both sides of the
    /// split, across multiple interleaved malformed requests.
    #[test]
    fn take_malformed_preserves_order_on_both_sides() {
        let t = Instant::now();
        let mut requests = Vec::new();
        let mut keep = Vec::new();
        // ids 0..6: odd ids malformed (length 3), even ids valid
        for id in 0..6u64 {
            let len = if id % 2 == 1 { 3 } else { 2 };
            let (r, k) = req(id, vec![0; len]);
            keep.push(k);
            requests.push((r, t));
        }
        let mut b = Batch { requests };
        let bad = b.take_malformed(2);
        let bad_ids: Vec<u64> = bad.iter().map(|(r, _)| r.id).collect();
        assert_eq!(bad_ids, vec![1, 3, 5], "malformed keep arrival order");
        let good_ids: Vec<u64> =
            b.requests.iter().map(|(r, _)| r.id).collect();
        assert_eq!(good_ids, vec![0, 2, 4], "survivors keep arrival order");
        // idempotent: a second sweep finds nothing and moves nothing
        assert!(b.take_malformed(2).is_empty());
        assert_eq!(b.len(), 3);
    }
}
