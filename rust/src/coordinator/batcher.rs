//! Dynamic request batcher.
//!
//! Accumulates requests until the accelerator batch size is reached or
//! the linger timeout expires, then emits a [`Batch`].  Partial batches
//! are padded to the fixed accelerator batch (the AOT artifact's static
//! shape) with zero rows that are dropped on the way out.

use super::Request;
use std::time::{Duration, Instant};

#[derive(Debug, Clone, Copy)]
pub struct BatcherConfig {
    /// Accelerator batch size (the artifact's static leading dim).
    pub batch: usize,
    /// Max time the first request of a batch waits for company.
    pub linger: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { batch: 4, linger: Duration::from_millis(2) }
    }
}

/// A formed batch: up to `cfg.batch` requests plus their arrival times.
#[derive(Debug)]
pub struct Batch {
    pub requests: Vec<(Request, Instant)>,
}

impl Batch {
    pub fn len(&self) -> usize {
        self.requests.len()
    }

    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }

    /// Remove and return every request whose input is not a `row_len`
    /// row.  The worker answers these with a typed
    /// [`RequestError::BadShape`](super::RequestError::BadShape)
    /// response *before* the batch reaches the backend, so one
    /// malformed client input can never panic the model's worker thread
    /// or poison the batch it rode in with.
    pub fn take_malformed(
        &mut self,
        row_len: usize,
    ) -> Vec<(Request, Instant)> {
        // fast path: submit-side validation rejects bad shapes before
        // they enter the queue, so this is almost always all-valid —
        // Vec::new() allocates nothing and the batch Vec is untouched
        if self.requests.iter().all(|(req, _)| req.input.len() == row_len) {
            return Vec::new();
        }
        let (good, bad): (Vec<_>, Vec<_>) = std::mem::take(&mut self.requests)
            .into_iter()
            .partition(|(req, _)| req.input.len() == row_len);
        self.requests = good;
        bad
    }

    /// Concatenate inputs, zero-padding to `batch` rows of `row_len`.
    /// Callers must have validated row lengths first
    /// ([`Batch::take_malformed`]).
    pub fn padded_input(&self, batch: usize, row_len: usize) -> Vec<i32> {
        let mut v = vec![0i32; batch * row_len];
        for (i, (req, _)) in self.requests.iter().enumerate() {
            assert_eq!(req.input.len(), row_len, "request row length");
            v[i * row_len..(i + 1) * row_len].copy_from_slice(&req.input);
        }
        v
    }
}

/// Pull-based batcher over an mpsc receiver.
pub struct Batcher {
    pub cfg: BatcherConfig,
    rx: std::sync::mpsc::Receiver<Request>,
}

impl Batcher {
    pub fn new(
        cfg: BatcherConfig,
        rx: std::sync::mpsc::Receiver<Request>,
    ) -> Self {
        Batcher { cfg, rx }
    }

    /// Block for the next batch; `None` when all senders are dropped.
    pub fn next_batch(&mut self) -> Option<Batch> {
        // block for the first request
        let first = self.rx.recv().ok()?;
        let t0 = Instant::now();
        let mut requests = vec![(first, t0)];
        // gather until full or linger expires
        while requests.len() < self.cfg.batch {
            let left = self.cfg.linger.saturating_sub(t0.elapsed());
            if left.is_zero() {
                break;
            }
            match self.rx.recv_timeout(left) {
                Ok(r) => requests.push((r, Instant::now())),
                Err(_) => break,
            }
        }
        Some(Batch { requests })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;

    fn req(id: u64, input: Vec<i32>) -> (Request, mpsc::Receiver<super::super::Response>) {
        let (tx, rx) = mpsc::channel();
        (Request { id, input, resp: tx }, rx)
    }

    #[test]
    fn batches_fill_to_capacity() {
        let (tx, rx) = mpsc::channel();
        let mut b = Batcher::new(
            BatcherConfig { batch: 3, linger: Duration::from_millis(50) },
            rx,
        );
        let mut keep = Vec::new();
        for i in 0..5 {
            let (r, rx) = req(i, vec![i as i32]);
            keep.push(rx);
            tx.send(r).unwrap();
        }
        let b1 = b.next_batch().unwrap();
        assert_eq!(b1.len(), 3);
        let b2 = b.next_batch().unwrap();
        assert_eq!(b2.len(), 2); // linger expires with 2 in hand... or
                                 // senders still alive: timeout path
    }

    #[test]
    fn padded_input_layout() {
        let (r1, _k1) = req(1, vec![1, 2]);
        let (r2, _k2) = req(2, vec![3, 4]);
        let t = Instant::now();
        let b = Batch { requests: vec![(r1, t), (r2, t)] };
        assert_eq!(b.padded_input(4, 2), vec![1, 2, 3, 4, 0, 0, 0, 0]);
    }

    #[test]
    fn take_malformed_splits_by_row_length() {
        let (r1, _k1) = req(1, vec![1, 2]);
        let (r2, _k2) = req(2, vec![3, 4, 5]); // wrong length
        let (r3, _k3) = req(3, vec![6, 7]);
        let t = Instant::now();
        let mut b = Batch { requests: vec![(r1, t), (r2, t), (r3, t)] };
        let bad = b.take_malformed(2);
        assert_eq!(bad.len(), 1);
        assert_eq!(bad[0].0.id, 2);
        assert_eq!(b.len(), 2);
        assert_eq!(b.padded_input(2, 2), vec![1, 2, 6, 7]);
    }

    #[test]
    fn none_when_senders_dropped() {
        let (tx, rx) = mpsc::channel::<Request>();
        drop(tx);
        let mut b = Batcher::new(BatcherConfig::default(), rx);
        assert!(b.next_batch().is_none());
    }
}
