//! Autoregressive decode: KV-cached transformer serving with
//! iteration-level continuous batching.
//!
//! The batch serving path ([`InferenceSession`] /
//! [`PipelinedSession`](super::PipelinedSession)) recomputes every
//! token's attention from scratch each request — right for prefill,
//! quadratically wasteful for generation, where each new token only
//! *adds* one key and one value per layer.  [`DecodeScheduler`] serves
//! the generation phase instead:
//!
//! * **KV cache** — each admitted sequence owns per-layer, per-head K/V
//!   strips ([`SeqKv`](super::kv)) in the deployment's storage element.
//!   A decode step appends the new token's key column / value row and
//!   runs QKᵀ and AV against the resident strips, so only the new
//!   token's query runs per step.  Under FFIP the strips carry y terms
//!   maintained **at append time** ([`y_append_col`] /
//!   [`y_append_row`](crate::algo::y_append_row)): the §3.3 transform
//!   for every cached token is already paid, and only the single new
//!   column/row's O(d_head) refresh rides the critical path — the
//!   decode-side analogue of the offline-y weight transform.
//! * **Continuous batching** — scheduling is iteration-level (the Orca
//!   model): sequences are admitted and retired *between* steps, and
//!   every [`DecodeScheduler::step`] gathers whichever sequences have a
//!   pending token into one batch, so a long generation never blocks a
//!   short one behind it.  Each step runs **one GEMM per projection**
//!   across all gathered rows (Q/K/V/output, and each token-parallel
//!   FC), not one GEMM per sequence.
//! * **Bounded admission** — [`DeployConfig::max_active_seqs`] bounds
//!   in-flight sequences ([`RequestError::Overloaded`]) and
//!   [`DeployConfig::max_kv_bytes`] bounds resident slab bytes
//!   ([`RequestError::KvExhausted`]), both shed typed at
//!   [`DecodeScheduler::admit`] instead of panicking or queueing
//!   unboundedly.  Retiring a sequence releases its slot and bytes
//!   (and zeroes its slabs, so readmission is bit-deterministic).
//!
//! Decode is **bit-identical to full recompute**: with causal
//! attention, position `t`'s hidden state depends only on tokens
//! `0..=t` at every layer, the integer GEMMs are exact under any
//! tiling, and the zero strip tails contribute exact zeros — so
//! feeding a prompt token by token through `step()` produces the same
//! bits as one ragged prefill batch (`tests/decode.rs` holds this for
//! every algorithm × storage width under mid-run admit/retire churn).
//!
//! [`InferenceSession`]: super::InferenceSession
//! [`DeployConfig::max_active_seqs`]: super::DeployConfig::max_active_seqs
//! [`DeployConfig::max_kv_bytes`]: super::DeployConfig::max_kv_bytes
//! [`RequestError::Overloaded`]: RequestError::Overloaded
//! [`RequestError::KvExhausted`]: RequestError::KvExhausted
//! [`y_append_col`]: crate::algo::y_append_col

use super::kv::{KvCache, KvLayout, SeqKv};
use super::model::{
    AttnExec, CompiledLayer, CompiledModel, LayerExec, TypedModel,
};
use super::scheduler::Admission;
use super::session::{
    apply_post_gemm, gemm_error_to_request, gemm_layer_checked, narrow_rows,
    project, run_residual,
};
use super::stats::FaultCounts;
use super::tensor::{RequestError, Tensor};
use crate::algo::element::{ElemKind, Element};
use crate::algo::Mat;
use crate::engine::GemmPool;
use crate::metrics::DecodeMetrics;
use crate::quant::{requantize_to, softmax_fixed_row, SoftmaxScratch};
use crate::util::with_width;
use std::sync::Arc;
use std::time::Instant;

/// One decoded token's result from a [`DecodeScheduler::step`].
#[derive(Debug, Clone, PartialEq)]
pub struct StepOutput {
    /// The sequence the token belongs to.
    pub id: u64,
    /// Absolute (0-based) position of the token just decoded.
    pub pos: usize,
    /// The block stack's output row for this token (`1 x d_model`).
    pub out: Tensor,
}

/// One admitted sequence: its resident KV slabs plus the narrowed
/// tokens awaiting decode.
struct Seq<E: Element> {
    id: u64,
    kv: SeqKv<E>,
    /// Tokens already decoded (resident in the KV strips).
    pos: usize,
    /// Narrowed queued tokens, `d_model` values each.
    queue: Vec<E>,
    /// Prefix of `queue` already consumed by steps.
    consumed: usize,
    /// When the sequence last became pending without being served —
    /// the deadline policy's staleness clock (`None` while the queue
    /// is empty; reset every step that serves the sequence).
    pending_since: Option<Instant>,
}

impl<E: Element> Seq<E> {
    fn queued(&self, d: usize) -> usize {
        (self.queue.len() - self.consumed) / d
    }
}

/// The typed decode state: the compiled model, the admission ledgers,
/// the KV slab pool, the active sequence table, and the step scratch
/// buffers (all recycled — steady-state decode allocates nothing).
struct TypedDecode<E: Element> {
    model: Arc<TypedModel<E>>,
    pool: Arc<GemmPool>,
    layout: KvLayout,
    admission: Admission,
    /// KV bytes one sequence's slabs charge against the ledger.
    seq_bytes: usize,
    kv: KvCache<E>,
    /// Active sequences in admission order (the step batch gathers in
    /// this order, so scheduling is deterministic).
    seqs: Vec<Seq<E>>,
    // --- step scratch ---
    /// The step slab: one dense `d`-wide row per gathered token.
    act: Vec<E>,
    /// Saved layer inputs for residual adds (step-local).
    saves: Vec<Vec<E>>,
    /// Dense GEMM A for token-parallel FC layers.
    a: Mat<E>,
    /// Widened GEMM output (shared by projections and FCs).
    c: Mat<E::Acc>,
    /// Stacked new-token rows for the attention projections.
    xa: Mat<E>,
    q: Mat<E>,
    k: Mat<E>,
    v: Mat<E>,
    /// Per-head attention outputs restacked for the output projection.
    o: Mat<E>,
    /// The single new query row (per sequence, per head).
    qh: Mat<E>,
    /// Per-head widened QKᵀ / AV accumulators.
    ch: Mat<E::Acc>,
    /// The probability row, zero-padded to the strip capacity.
    ph: Mat<E>,
    zrow: Vec<i64>,
    probs: Vec<i64>,
    smax: SoftmaxScratch,
    /// Gathered sequence indices of the current step.
    pend: Vec<usize>,
    // --- counters ---
    steps: u64,
    tokens: u64,
    admitted: u64,
    retired: u64,
    /// Sequences shed by the deadline policy, with their typed errors
    /// (drained by [`DecodeScheduler::take_deadline_shed`]).
    shed_deadline: Vec<(u64, RequestError)>,
    deadline_shed_count: u64,
    /// Fault-tolerance counters accumulated since the last drain.
    faults: FaultCounts,
    started: Instant,
}

impl<E: Element> TypedDecode<E> {
    fn new(
        model: Arc<TypedModel<E>>,
        pool: Arc<GemmPool>,
    ) -> anyhow::Result<Self> {
        let layout = KvLayout::from_model(&model)?;
        for layer in &model.layers {
            match &layer.exec {
                LayerExec::Attention(_)
                | LayerExec::TokenFc { .. }
                | LayerExec::Residual { .. } => {}
                LayerExec::Fc | LayerExec::Conv { .. }
                | LayerExec::WinoConv(_) => anyhow::bail!(
                    "decode serves transformer blocks (attention / \
                     token-fc / residual) only; layer {} compiled as a \
                     dense/conv layer outside the ragged chain",
                    layer.name
                ),
            }
        }
        let admission = Admission::new(model.cfg.decode_admission());
        let seq_bytes = layout.seq_bytes::<E>();
        let n_layers = model.layers.len();
        Ok(TypedDecode {
            model,
            pool,
            kv: KvCache::new(layout.clone()),
            layout,
            admission,
            seq_bytes,
            seqs: Vec::new(),
            act: Vec::new(),
            saves: (0..n_layers).map(|_| Vec::new()).collect(),
            a: Mat::zeros(0, 0),
            c: Mat::zeros(0, 0),
            xa: Mat::zeros(0, 0),
            q: Mat::zeros(0, 0),
            k: Mat::zeros(0, 0),
            v: Mat::zeros(0, 0),
            o: Mat::zeros(0, 0),
            qh: Mat::zeros(0, 0),
            ch: Mat::zeros(0, 0),
            ph: Mat::zeros(0, 0),
            zrow: Vec::new(),
            probs: Vec::new(),
            smax: SoftmaxScratch::default(),
            pend: Vec::new(),
            steps: 0,
            tokens: 0,
            admitted: 0,
            retired: 0,
            shed_deadline: Vec::new(),
            deadline_shed_count: 0,
            faults: FaultCounts::default(),
            started: Instant::now(),
        })
    }

    fn admit(&mut self, id: u64, prompt: &[i32]) -> Result<(), RequestError> {
        let d = self.layout.d_model;
        if prompt.len() % d != 0 {
            return Err(RequestError::BadShape {
                expected: d,
                got: prompt.len(),
            });
        }
        let len = prompt.len() / d;
        if len > self.layout.max_seq {
            return Err(RequestError::BadSequence {
                len: len as i64,
                max_seq: self.layout.max_seq,
            });
        }
        if self.seqs.iter().any(|s| s.id == id) {
            return Err(RequestError::Backend(format!(
                "sequence {id} is already admitted"
            )));
        }
        // two-gate admission: a sequence slot, then its KV bytes —
        // releasing the slot again if the byte ledger sheds
        self.admission.try_admit()?;
        if let Err(e) = self.admission.try_admit_kv(self.seq_bytes) {
            self.admission.complete();
            return Err(e);
        }
        // narrow before any state mutates, so a Domain error admits
        // nothing (its co-batched neighbours never see the sequence)
        let mut queue = Vec::with_capacity(prompt.len());
        if let Err(e) = narrow_rows(prompt, &mut queue) {
            self.admission.release_kv(self.seq_bytes);
            self.admission.complete();
            return Err(e);
        }
        let kv = self.kv.acquire();
        let pending_since = (!queue.is_empty()).then(Instant::now);
        self.seqs.push(Seq {
            id,
            kv,
            pos: 0,
            queue,
            consumed: 0,
            pending_since,
        });
        self.admitted += 1;
        Ok(())
    }

    fn feed(&mut self, id: u64, tokens: &[i32]) -> Result<(), RequestError> {
        let d = self.layout.d_model;
        let max_seq = self.layout.max_seq;
        if tokens.len() % d != 0 {
            return Err(RequestError::BadShape {
                expected: d,
                got: tokens.len(),
            });
        }
        let Some(seq) = self.seqs.iter_mut().find(|s| s.id == id) else {
            return Err(RequestError::Backend(format!(
                "sequence {id} is not admitted"
            )));
        };
        // a sequence at capacity gets a typed retirement signal; the
        // tokens it already holds stay valid and keep decoding
        let total = seq.pos + seq.queued(d) + tokens.len() / d;
        if total > max_seq {
            return Err(RequestError::BadSequence {
                len: total as i64,
                max_seq,
            });
        }
        // narrow into a scratch first: a Domain error must leave the
        // queue (and every co-batched sequence) untouched
        let mut fresh = Vec::with_capacity(tokens.len());
        narrow_rows(tokens, &mut fresh)?;
        if seq.pending_since.is_none() && !fresh.is_empty() {
            seq.pending_since = Some(Instant::now());
        }
        seq.queue.extend_from_slice(&fresh);
        Ok(())
    }

    fn retire(&mut self, id: u64) -> Result<(), RequestError> {
        let Some(idx) = self.seqs.iter().position(|s| s.id == id) else {
            return Err(RequestError::Backend(format!(
                "sequence {id} is not admitted"
            )));
        };
        let seq = self.seqs.remove(idx);
        self.kv.release(seq.kv);
        self.admission.release_kv(self.seq_bytes);
        self.admission.complete();
        self.retired += 1;
        Ok(())
    }

    /// One decode iteration: gather every sequence with a pending
    /// token, run the batch through the block stack (one GEMM per
    /// projection / FC, per-sequence-per-head GEMMs against the cached
    /// strips), and return each gathered token's output row.  Returns
    /// an empty vec when nothing is pending.
    ///
    /// Under [`DeployConfig::with_request_deadline`](super::DeployConfig::with_request_deadline),
    /// sequences whose queued tokens the scheduler failed to serve for
    /// a full deadline period are shed first — retired with their slot
    /// and KV bytes released, their typed
    /// [`RequestError::DeadlineExceeded`] drained through
    /// [`DecodeScheduler::take_deadline_shed`].  An `Err` from the
    /// step itself is an engine fault (ABFT-detected persistent
    /// corruption, poisoned job, watchdog expiry): the gathered tokens
    /// are consumed and callers should retire the affected sequences.
    fn step(&mut self) -> Result<Vec<StepOutput>, RequestError> {
        let model = self.model.clone();
        let d = self.layout.d_model;
        // deadline policy first: a stale sequence never occupies a
        // batch slot, and its admission slot + KV bytes free up before
        // this step's gather
        if let Some(deadline) = model.cfg.request_deadline {
            let mut i = 0;
            while i < self.seqs.len() {
                let waited = self.seqs[i]
                    .pending_since
                    .map(|t| t.elapsed())
                    .filter(|w| *w > deadline);
                match waited {
                    Some(waited) => {
                        let seq = self.seqs.remove(i);
                        self.kv.release(seq.kv);
                        self.admission.release_kv(self.seq_bytes);
                        self.admission.complete();
                        self.deadline_shed_count += 1;
                        self.faults.deadline_shed += 1;
                        self.shed_deadline.push((
                            seq.id,
                            RequestError::DeadlineExceeded {
                                waited_ms: waited.as_millis() as u64,
                                deadline_ms: deadline.as_millis() as u64,
                            },
                        ));
                    }
                    None => i += 1,
                }
            }
        }
        self.pend.clear();
        for (i, s) in self.seqs.iter().enumerate() {
            if s.queued(d) > 0 {
                self.pend.push(i);
            }
        }
        if self.pend.is_empty() {
            return Ok(Vec::new());
        }
        let n = self.pend.len();
        // gather the step batch: one queued token per pending sequence
        self.act.clear();
        for pi in 0..n {
            let s = &mut self.seqs[self.pend[pi]];
            self.act
                .extend_from_slice(&s.queue[s.consumed..s.consumed + d]);
            s.consumed += d;
            if s.consumed == s.queue.len() {
                s.queue.clear();
                s.consumed = 0;
                s.pending_since = None;
            } else {
                // being actively served: the staleness clock restarts
                s.pending_since = Some(Instant::now());
            }
        }
        // walk the block stack over the dense n x d step slab
        let mut attn_ord = 0usize;
        for (li, layer) in model.layers.iter().enumerate() {
            if layer.save_input {
                self.saves[li].clear();
                self.saves[li].extend_from_slice(&self.act);
            }
            match &layer.exec {
                LayerExec::Attention(at) => {
                    self.decode_attention(layer, at, attn_ord, n)?;
                    attn_ord += 1;
                }
                LayerExec::TokenFc { .. } => {
                    // token-parallel FC: the step's new-token rows ARE
                    // the valid tokens — one dense GEMM, no gather;
                    // ABFT-verified against the stationary weights
                    self.a.rows = n;
                    self.a.cols = layer.weights.rows;
                    self.a.data.clear();
                    self.a.data.extend_from_slice(&self.act);
                    gemm_layer_checked(
                        &self.pool,
                        layer,
                        &self.a,
                        &mut self.c,
                        &mut self.faults,
                        model.cfg.request_deadline,
                    )?;
                    apply_post_gemm(layer, &self.c, &mut self.act);
                }
                LayerExec::Residual { span, bits, .. } => {
                    // the step slab is dense (no ragged length prefix),
                    // so the prefix-skip of the batch path is off
                    let row = self.act.len() / n;
                    run_residual(
                        *bits,
                        false,
                        row,
                        n,
                        &self.saves[li - span],
                        &mut self.act,
                    );
                }
                LayerExec::Fc | LayerExec::Conv { .. }
                | LayerExec::WinoConv(_) => {
                    unreachable!("rejected at DecodeScheduler construction")
                }
            }
        }
        // emit outputs and advance each sequence's resident position
        let mut out = Vec::with_capacity(n);
        for (i, &si) in self.pend.iter().enumerate() {
            let s = &mut self.seqs[si];
            let row = &self.act[i * d..(i + 1) * d];
            out.push(StepOutput {
                id: s.id,
                pos: s.pos,
                out: Tensor::new(
                    1,
                    d,
                    row.iter().map(|&v| v.to_i64() as f32).collect(),
                ),
            });
            s.pos += 1;
        }
        self.steps += 1;
        self.tokens += n as u64;
        Ok(out)
    }

    /// The KV-cached attention step for attention ordinal `attn`:
    /// batched Q/K/V projections over all `n` gathered rows, then per
    /// sequence and head append + QKᵀ + causal softmax + AV against the
    /// resident strips, then the batched output projection.
    fn decode_attention(
        &mut self,
        layer: &CompiledLayer<E>,
        at: &AttnExec<E>,
        attn: usize,
        n: usize,
    ) -> Result<(), RequestError> {
        let d = at.d_model;
        let dh = at.d_head;
        let cap = self.layout.cap;
        let deadline = self.model.cfg.request_deadline;
        let post = layer
            .post
            .as_ref()
            .expect("attention compiles with a post-GEMM stage");
        // Q/K/V projections: one GEMM per projection across the whole
        // step batch (stationary weights, compile-time offline y)
        self.xa.rows = n;
        self.xa.cols = d;
        self.xa.data.clear();
        self.xa.data.extend_from_slice(&self.act);
        project(&self.pool, layer.algo, &self.xa, &at.wq, at.yq.as_deref(),
                at.proj_tile, post, 0, false, &mut self.c, &mut self.q)
            .map_err(|e| {
                gemm_error_to_request(e, &layer.name, deadline, &mut self.faults)
            })?;
        project(&self.pool, layer.algo, &self.xa, &at.wk, at.yk.as_deref(),
                at.proj_tile, post, d, false, &mut self.c, &mut self.k)
            .map_err(|e| {
                gemm_error_to_request(e, &layer.name, deadline, &mut self.faults)
            })?;
        project(&self.pool, layer.algo, &self.xa, &at.wv, at.yv.as_deref(),
                at.proj_tile, post, 2 * d, false, &mut self.c, &mut self.v)
            .map_err(|e| {
                gemm_error_to_request(e, &layer.name, deadline, &mut self.faults)
            })?;
        self.o.reset_to(n, d);
        for i in 0..n {
            let seq = &mut self.seqs[self.pend[i]];
            let t = seq.pos;
            for h in 0..at.heads {
                let hc = h * dh;
                // append this token's key column / value row; the
                // cached y terms refresh incrementally at append time
                seq.kv.append(
                    &self.layout,
                    attn,
                    h,
                    t,
                    &self.k.row(i)[hc..hc + dh],
                    &self.v.row(i)[hc..hc + dh],
                );
                // QKᵀ against the resident Kᵀ strip: constant
                // 1 x d_head x cap geometry, cached y — only the new
                // query row is "online"
                self.qh.rows = 1;
                self.qh.cols = dh;
                self.qh.data.clear();
                self.qh.data.extend_from_slice(&self.q.row(i)[hc..hc + dh]);
                let (kt, y_kt) = seq.kv.qk_operands(&self.layout, attn, h);
                if let Err(e) = self.pool.gemm_into_checked(
                    &self.qh, kt, y_kt, &mut self.ch, layer.algo, at.qk_tile,
                ) {
                    return Err(gemm_error_to_request(
                        e,
                        &layer.name,
                        deadline,
                        &mut self.faults,
                    ));
                }
                // causal softmax over the resident keys 0..=t (the
                // zero tail never enters: softmax is not padding-exact)
                self.zrow.clear();
                self.zrow.extend(
                    self.ch.row(0)[..t + 1].iter().map(|&z| z.to_i64()),
                );
                self.probs.clear();
                self.probs.resize(t + 1, 0);
                softmax_fixed_row(
                    &self.zrow,
                    &at.softmax,
                    &mut self.smax,
                    &mut self.probs,
                );
                self.ph.rows = 1;
                self.ph.cols = cap;
                self.ph.data.clear();
                self.ph.data.extend(self.probs.iter().map(|&p| {
                    E::from_i64(p).expect(
                        "probabilities fit the activation width \
                         (w <= storage bits)",
                    )
                }));
                self.ph.data.resize(cap, E::default());
                // AV against the resident V strip: the zero-padded
                // probability tail weighs the zero tail rows by zero
                let (vs, y_v) = seq.kv.av_operands(&self.layout, attn, h);
                if let Err(e) = self.pool.gemm_into_checked(
                    &self.ph, vs, y_v, &mut self.ch, layer.algo, at.av_tile,
                ) {
                    return Err(gemm_error_to_request(
                        e,
                        &layer.name,
                        deadline,
                        &mut self.faults,
                    ));
                }
                for (j, &acc) in self.ch.row(0).iter().enumerate() {
                    self.o[(i, hc + j)] =
                        requantize_to::<E>(acc, 0, &at.av_scheme, false);
                }
            }
        }
        // output projection over the restacked heads (bias segment 3,
        // the layer's ReLU if any); `q` is recycled as the result
        project(&self.pool, layer.algo, &self.o, &at.wo, at.yo.as_deref(),
                at.proj_tile, post, 3 * d, post.relu, &mut self.c, &mut self.q)
            .map_err(|e| {
                gemm_error_to_request(e, &layer.name, deadline, &mut self.faults)
            })?;
        self.act.clear();
        self.act.extend_from_slice(&self.q.data[..n * d]);
        Ok(())
    }

    fn metrics(&self) -> DecodeMetrics {
        DecodeMetrics {
            steps: self.steps,
            tokens: self.tokens,
            active_seqs: self.seqs.len(),
            admitted: self.admitted,
            retired: self.retired,
            shed: self.admission.shed_count(),
            shed_kv: self.admission.shed_kv_count(),
            deadline_shed: self.deadline_shed_count,
            kv_bytes_in_use: self.admission.kv_bytes(),
            max_kv_bytes: self.admission.max_kv_bytes(),
            seq_bytes: self.seq_bytes,
            elapsed: self.started.elapsed(),
        }
    }
}

/// Width-tagged decode state (mirrors [`CompiledModel`]'s variants).
enum DecodeInner {
    I8(TypedDecode<i8>),
    I16(TypedDecode<i16>),
    I64(TypedDecode<i64>),
}

/// The autoregressive decode subsystem of one deployment: KV cache +
/// iteration-level continuous batching over a compiled transformer
/// (module docs).  Construction fails loudly for models that cannot
/// decode (no attention, non-causal attention, conv layers).
pub struct DecodeScheduler {
    inner: DecodeInner,
}

impl DecodeScheduler {
    /// Build decode state over a compiled model, at its compiled
    /// storage width, with admission bounds from the deployment's
    /// [`decode_admission`](super::DeployConfig::decode_admission)
    /// knobs.
    pub fn new(
        model: &CompiledModel,
        pool: Arc<GemmPool>,
    ) -> anyhow::Result<Self> {
        let inner = match model {
            CompiledModel::I8(m) => {
                DecodeInner::I8(TypedDecode::new(m.clone(), pool)?)
            }
            CompiledModel::I16(m) => {
                DecodeInner::I16(TypedDecode::new(m.clone(), pool)?)
            }
            CompiledModel::I64(m) => {
                DecodeInner::I64(TypedDecode::new(m.clone(), pool)?)
            }
        };
        Ok(DecodeScheduler { inner })
    }

    /// The storage element width this scheduler decodes on.
    pub fn storage(&self) -> ElemKind {
        match &self.inner {
            DecodeInner::I8(_) => ElemKind::I8,
            DecodeInner::I16(_) => ElemKind::I16,
            DecodeInner::I64(_) => ElemKind::I64,
        }
    }

    /// The model width of one token (values per token row).
    pub fn d_model(&self) -> usize {
        with_width!(DecodeInner, &self.inner, s => s.layout.d_model)
    }

    /// The longest sequence one KV slab can hold.
    pub fn max_seq(&self) -> usize {
        with_width!(DecodeInner, &self.inner, s => s.layout.max_seq)
    }

    /// Sequences currently admitted.
    pub fn active(&self) -> usize {
        with_width!(DecodeInner, &self.inner, s => s.seqs.len())
    }

    /// Admit sequence `id` with `prompt` (`len * d_model` values;
    /// `len` may be 0 — the sequence then just waits for
    /// [`DecodeScheduler::feed`]).  Typed failures: BadShape (not whole
    /// tokens), BadSequence (longer than `max_seq`), Backend (duplicate
    /// id), Overloaded (`max_active_seqs` reached), KvExhausted
    /// (`max_kv_bytes` reached), Domain (a value outside the storage
    /// width).  A failed admit mutates nothing.
    pub fn admit(
        &mut self,
        id: u64,
        prompt: &[i32],
    ) -> Result<(), RequestError> {
        with_width!(DecodeInner, &mut self.inner, s => s.admit(id, prompt))
    }

    /// Queue more tokens on an admitted sequence.  BadSequence when the
    /// sequence would exceed `max_seq` — the typed retirement signal;
    /// the sequence itself stays valid and keeps decoding what it has.
    pub fn feed(
        &mut self,
        id: u64,
        tokens: &[i32],
    ) -> Result<(), RequestError> {
        with_width!(DecodeInner, &mut self.inner, s => s.feed(id, tokens))
    }

    /// Retire a sequence: its KV slabs are zeroed back to the pool and
    /// its admission slot and KV bytes are released.
    pub fn retire(&mut self, id: u64) -> Result<(), RequestError> {
        with_width!(DecodeInner, &mut self.inner, s => s.retire(id))
    }

    /// One continuous-batching iteration (module docs): decodes one
    /// queued token for every sequence that has one, returns their
    /// output rows in admission order.  Empty when nothing is pending.
    ///
    /// With a deployment [`request_deadline`](super::DeployConfig::with_request_deadline),
    /// sequences whose queued tokens went unserved for a full deadline
    /// period are retired first (slot and KV bytes released); drain
    /// their typed errors with
    /// [`take_deadline_shed`](DecodeScheduler::take_deadline_shed).
    /// `Err` means an engine fault struck the step itself
    /// ([`RequestError::FaultDetected`] /
    /// [`RequestError::DeadlineExceeded`]); the gathered tokens are
    /// consumed, so callers should retire the affected sequences.
    pub fn step(&mut self) -> Result<Vec<StepOutput>, RequestError> {
        with_width!(DecodeInner, &mut self.inner, s => s.step())
    }

    /// Sequences the deadline policy shed since the last call, each
    /// with its typed [`RequestError::DeadlineExceeded`].
    pub fn take_deadline_shed(&mut self) -> Vec<(u64, RequestError)> {
        with_width!(DecodeInner, &mut self.inner,
                    s => std::mem::take(&mut s.shed_deadline))
    }

    /// Fault-tolerance counters accumulated since the last drain
    /// (drains them).  All zeros on a fault-free run.
    pub fn take_fault_counts(&mut self) -> FaultCounts {
        with_width!(DecodeInner, &mut self.inner,
                    s => std::mem::take(&mut s.faults))
    }

    /// Decode-side serving counters and KV occupancy.
    pub fn metrics(&self) -> DecodeMetrics {
        with_width!(DecodeInner, &self.inner, s => s.metrics())
    }
}
