//! Per-sequence KV cache slabs for the autoregressive decode subsystem.
//!
//! A decoding sequence revisits every earlier token's key and value at
//! every step, so the decode scheduler keeps them resident: one
//! [`SeqKv`] per admitted sequence holds, per attention layer and head,
//! a Kᵀ strip (`d_head x cap`, keys as columns) and a V strip
//! (`cap x d_head`, values as rows) in the deployment's storage element.
//! The strips are fixed-capacity with **zero tails** — `cap` is
//! `max_seq` rounded up to even — so every decode-step GEMM runs the
//! same `1 x d_head x cap` (QKᵀ) / `1 x cap x d_head` (AV) geometry
//! regardless of how many tokens are resident: the tail keys score
//! exactly zero (and are masked off before softmax anyway), and the
//! tail value rows multiply zero probabilities.  Constant geometry is
//! what lets one tile plan — and one cached FFIP y transform — serve
//! the whole life of a sequence.
//!
//! Under FFIP, the §3.3 y transform of a *stationary* B operand is
//! precomputed offline; a KV strip is neither stationary nor fully
//! online — it grows by one column (K) / one row (V) per step.  The
//! cache therefore maintains the y terms **incrementally at append
//! time** ([`y_append_col`] / [`y_append_row`]): appending token `t`
//! refreshes only the O(d_head) affected entries, so the per-step QKᵀ
//! and AV GEMMs consume cached y for every *previous* token and the
//! Θ(NK) online transform never re-runs over the whole strip.  That is
//! the decode-side amortization of FFIP's offline-y advantage.
//!
//! Retired sequences return their slabs to a free pool **zeroed**
//! ([`SeqKv::reset`]), so a sequence admitted after an eviction starts
//! from the exact state a fresh allocation would — readmission is
//! bit-deterministic by construction.

use super::model::{LayerExec, TypedModel};
use crate::algo::element::Element;
use crate::algo::{y_append_col, y_append_row, Mat};

/// Width-independent slab geometry shared by every sequence of one
/// decode deployment, derived from the compiled model.
#[derive(Debug, Clone)]
pub(crate) struct KvLayout {
    /// Compiled-layer indices of the attention layers, in order.
    pub attn_layers: Vec<usize>,
    pub heads: usize,
    pub d_head: usize,
    pub d_model: usize,
    pub max_seq: usize,
    /// Strip capacity: `max_seq` rounded up to even, so the AV depth
    /// stays legal for the inner-product algorithms at every length.
    pub cap: usize,
    /// Per attention layer: `Some((qk_tile_n, av_tile_n))` when that
    /// layer runs FFIP and the strips carry cached y terms.
    pub ffip_y: Vec<Option<(usize, usize)>>,
}

impl KvLayout {
    /// Derive the slab geometry from a compiled model's attention
    /// layers.  Fails loudly when the model cannot decode: no attention
    /// at all, non-causal attention (cached keys would need future
    /// tokens), or attention layers disagreeing on geometry.
    pub(crate) fn from_model<E: Element>(
        model: &TypedModel<E>,
    ) -> anyhow::Result<Self> {
        let mut layout: Option<KvLayout> = None;
        for (li, layer) in model.layers.iter().enumerate() {
            let LayerExec::Attention(at) = &layer.exec else { continue };
            anyhow::ensure!(
                at.causal,
                "decode requires causal attention: layer {} compiled \
                 with causal = false, so its cached keys would attend \
                 to future tokens",
                layer.name
            );
            anyhow::ensure!(
                layer.post.is_some(),
                "decode requires a post-GEMM stage on attention layer {}",
                layer.name
            );
            let y = (layer.algo == crate::algo::Algo::Ffip)
                .then_some((at.qk_tile.y, at.av_tile.y));
            match &mut layout {
                None => {
                    layout = Some(KvLayout {
                        attn_layers: vec![li],
                        heads: at.heads,
                        d_head: at.d_head,
                        d_model: at.d_model,
                        max_seq: at.max_seq,
                        cap: at.max_seq + at.max_seq % 2,
                        ffip_y: vec![y],
                    });
                }
                Some(l) => {
                    anyhow::ensure!(
                        (at.heads, at.d_head, at.d_model, at.max_seq)
                            == (l.heads, l.d_head, l.d_model, l.max_seq),
                        "decode requires uniform attention geometry: \
                         layer {} disagrees with the first attention \
                         layer",
                        layer.name
                    );
                    l.attn_layers.push(li);
                    l.ffip_y.push(y);
                }
            }
        }
        layout.ok_or_else(|| {
            anyhow::anyhow!(
                "decode requires at least one attention layer; model {} \
                 has none",
                model.name
            )
        })
    }

    /// Strip slot of `(attention ordinal, head)`.
    fn slot(&self, attn: usize, head: usize) -> usize {
        attn * self.heads + head
    }

    /// Resident bytes one sequence's slabs occupy — what the admission
    /// KV ledger charges per admitted sequence (capacity bytes, not
    /// occupancy: the slabs are allocated at full `cap` up front).
    pub(crate) fn seq_bytes<E: Element>(&self) -> usize {
        let strip = self.cap * self.d_head;
        let kv = self.attn_layers.len()
            * self.heads
            * 2
            * strip
            * std::mem::size_of::<E>();
        let y: usize = self
            .ffip_y
            .iter()
            .filter(|y| y.is_some())
            .map(|_| self.heads * 2 * strip * std::mem::size_of::<E::Y>())
            .sum();
        kv + y
    }
}

/// One admitted sequence's resident K/V strips (and cached FFIP y
/// terms), indexed by `(attention ordinal, head)`.
pub(crate) struct SeqKv<E: Element> {
    /// Kᵀ strips, `d_head x cap` — keys as columns so the decode QKᵀ
    /// GEMM consumes the strip directly as its B operand.
    kt: Vec<Mat<E>>,
    /// V strips, `cap x d_head` — values as rows for the AV GEMM.
    v: Vec<Mat<E>>,
    /// Cached y terms per strip (zero-sized for non-FFIP layers).
    y_kt: Vec<Mat<E::Y>>,
    y_v: Vec<Mat<E::Y>>,
}

impl<E: Element> SeqKv<E> {
    fn new(layout: &KvLayout) -> Self {
        let slots = layout.attn_layers.len() * layout.heads;
        let mut kv = SeqKv {
            kt: Vec::with_capacity(slots),
            v: Vec::with_capacity(slots),
            y_kt: Vec::with_capacity(slots),
            y_v: Vec::with_capacity(slots),
        };
        for attn in 0..layout.attn_layers.len() {
            for _ in 0..layout.heads {
                kv.kt.push(Mat::zeros(layout.d_head, layout.cap));
                kv.v.push(Mat::zeros(layout.cap, layout.d_head));
                let (ykr, ykc, yvr, yvc) = if layout.ffip_y[attn].is_some() {
                    (layout.d_head, layout.cap, layout.cap, layout.d_head)
                } else {
                    (0, 0, 0, 0)
                };
                kv.y_kt.push(Mat::zeros(ykr, ykc));
                kv.y_v.push(Mat::zeros(yvr, yvc));
            }
        }
        kv
    }

    /// Zero every strip (and cached y) back to the fresh-allocation
    /// state: `y_from_b` of an all-zero strip is all zeros, so a reset
    /// slab re-enters the free pool indistinguishable from a new one —
    /// the eviction-then-readmit determinism invariant.
    fn reset(&mut self) {
        for m in &mut self.kt {
            m.data.fill(E::default());
        }
        for m in &mut self.v {
            m.data.fill(E::default());
        }
        for m in &mut self.y_kt {
            m.data.fill(<E::Y>::default());
        }
        for m in &mut self.y_v {
            m.data.fill(<E::Y>::default());
        }
    }

    /// Append token `pos`'s per-head key and value (`d_head` values
    /// each) for attention ordinal `attn`, refreshing the cached FFIP y
    /// terms incrementally — the append-time y packing.
    pub(crate) fn append(
        &mut self,
        layout: &KvLayout,
        attn: usize,
        head: usize,
        pos: usize,
        k: &[E],
        v: &[E],
    ) {
        debug_assert!(pos < layout.max_seq, "append past max_seq");
        debug_assert_eq!(k.len(), layout.d_head);
        debug_assert_eq!(v.len(), layout.d_head);
        let s = layout.slot(attn, head);
        let kt = &mut self.kt[s];
        for (r, &kv) in k.iter().enumerate() {
            kt[(r, pos)] = kv;
        }
        let vs = &mut self.v[s];
        vs.data[pos * layout.d_head..(pos + 1) * layout.d_head]
            .copy_from_slice(v);
        if let Some((qk_y, av_y)) = layout.ffip_y[attn] {
            y_append_col(kt, qk_y, pos, &mut self.y_kt[s]);
            y_append_row(vs, av_y, pos, &mut self.y_v[s]);
        }
    }

    /// The QKᵀ B operand for `(attn, head)`: the Kᵀ strip and, when
    /// this layer caches y, the append-time y terms.
    pub(crate) fn qk_operands(
        &self,
        layout: &KvLayout,
        attn: usize,
        head: usize,
    ) -> (&Mat<E>, Option<&Mat<E::Y>>) {
        let s = layout.slot(attn, head);
        let y = layout.ffip_y[attn].map(|_| &self.y_kt[s]);
        (&self.kt[s], y)
    }

    /// The AV B operand for `(attn, head)`, like [`SeqKv::qk_operands`].
    pub(crate) fn av_operands(
        &self,
        layout: &KvLayout,
        attn: usize,
        head: usize,
    ) -> (&Mat<E>, Option<&Mat<E::Y>>) {
        let s = layout.slot(attn, head);
        let y = layout.ffip_y[attn].map(|_| &self.y_v[s]);
        (&self.v[s], y)
    }
}

/// The deployment's KV slab allocator: a free pool of zeroed [`SeqKv`]
/// slabs recycled across sequence lifetimes, so steady-state admit /
/// retire churn allocates nothing.
pub(crate) struct KvCache<E: Element> {
    layout: KvLayout,
    free: Vec<SeqKv<E>>,
}

impl<E: Element> KvCache<E> {
    pub(crate) fn new(layout: KvLayout) -> Self {
        KvCache { layout, free: Vec::new() }
    }

    pub(crate) fn layout(&self) -> &KvLayout {
        &self.layout
    }

    /// Slabs for one newly admitted sequence (recycled when possible).
    pub(crate) fn acquire(&mut self) -> SeqKv<E> {
        self.free.pop().unwrap_or_else(|| SeqKv::new(&self.layout))
    }

    /// Return a retired sequence's slabs, zeroed, to the free pool.
    pub(crate) fn release(&mut self, mut kv: SeqKv<E>) {
        kv.reset();
        self.free.push(kv);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::{y_from_b, Algo};
    use crate::coordinator::{compile, CompiledModel, DeployConfig, Model};
    use crate::nn::models;
    use crate::util::Rng;

    fn transformer_model(algo: Algo) -> CompiledModel {
        let mut model =
            Model::random(models::transformer(4, 8, 2, 1), 31, 3);
        let post = |n: usize| super::super::model::PostGemm {
            bias: vec![0; n],
            scheme: crate::quant::QuantScheme::symmetric_signed(8, 1.0 / 32.0),
            relu: false,
        };
        model.set_post(0, post(32)).unwrap();
        model.set_post(2, post(32)).unwrap();
        model.set_post(3, post(8)).unwrap();
        compile(&model, DeployConfig::new(algo).with_tile(4, 4).with_batch(2))
            .unwrap()
    }

    /// Appending tokens one by one keeps the cached y terms identical
    /// to a full `y_from_b` over the strip — at every prefix length.
    #[test]
    fn appended_strips_keep_y_consistent() {
        let CompiledModel::I8(m) = transformer_model(Algo::Ffip) else {
            panic!("8-bit transformer compiles to i8 storage")
        };
        let layout = KvLayout::from_model(&m).unwrap();
        assert_eq!(layout.attn_layers, vec![0]);
        assert_eq!((layout.heads, layout.d_head, layout.cap), (2, 4, 4));
        assert!(layout.ffip_y[0].is_some());
        let mut kv = SeqKv::<i8>::new(&layout);
        let mut rng = Rng::new(77);
        for pos in 0..layout.max_seq {
            let k: Vec<i8> =
                (0..4).map(|_| rng.fixed(5, true) as i8).collect();
            let v: Vec<i8> =
                (0..4).map(|_| rng.fixed(5, true) as i8).collect();
            kv.append(&layout, 0, 1, pos, &k, &v);
            let (kt, y_kt) = kv.qk_operands(&layout, 0, 1);
            let (qk_y, av_y) = layout.ffip_y[0].unwrap();
            assert_eq!(y_kt.unwrap().data, y_from_b(kt, qk_y).data, "{pos}");
            let (vs, y_v) = kv.av_operands(&layout, 0, 1);
            assert_eq!(y_v.unwrap().data, y_from_b(vs, av_y).data, "{pos}");
            // untouched (attn, head) slots stay zero
            let (other, _) = kv.qk_operands(&layout, 0, 0);
            assert!(other.data.iter().all(|&x| x == 0));
        }
    }

    /// Released slabs re-enter the pool zeroed — a readmitted sequence
    /// starts from the fresh-allocation state.
    #[test]
    fn released_slabs_are_indistinguishable_from_fresh() {
        let CompiledModel::I8(m) = transformer_model(Algo::Ffip) else {
            panic!("8-bit transformer compiles to i8 storage")
        };
        let mut cache = KvCache::<i8>::new(KvLayout::from_model(&m).unwrap());
        let layout = cache.layout().clone();
        let mut kv = cache.acquire();
        kv.append(&layout, 0, 0, 0, &[1, -2, 3, -4], &[5, -6, 7, -8]);
        cache.release(kv);
        let recycled = cache.acquire();
        for s in 0..layout.heads {
            let (kt, y) = recycled.qk_operands(&layout, 0, s);
            assert!(kt.data.iter().all(|&x| x == 0));
            assert!(y.unwrap().data.iter().all(|&x| x == 0));
            let (vs, yv) = recycled.av_operands(&layout, 0, s);
            assert!(vs.data.iter().all(|&x| x == 0));
            assert!(yv.unwrap().data.iter().all(|&x| x == 0));
        }
        assert!(cache.free.is_empty(), "slab came off the pool");
    }

    /// Non-FFIP deployments carry no y slabs (and charge no y bytes),
    /// and the per-sequence byte charge matches the slab arithmetic.
    #[test]
    fn layout_bytes_account_for_y_only_under_ffip() {
        let CompiledModel::I8(m) = transformer_model(Algo::Fip) else {
            panic!("8-bit transformer compiles to i8 storage")
        };
        let layout = KvLayout::from_model(&m).unwrap();
        assert_eq!(layout.ffip_y, vec![None]);
        // 1 attn layer x 2 heads x (K + V) x (4 x 4) strips x 1 byte
        assert_eq!(layout.seq_bytes::<i8>(), 2 * 2 * 16);
        let CompiledModel::I8(m) = transformer_model(Algo::Ffip) else {
            panic!("8-bit transformer compiles to i8 storage")
        };
        let layout = KvLayout::from_model(&m).unwrap();
        // + the same slab count of i16 y terms
        assert_eq!(layout.seq_bytes::<i8>(), 2 * 2 * 16 + 2 * 2 * 16 * 2);
    }

    /// A non-causal attention model is rejected with an actionable
    /// error instead of silently decoding wrong.
    #[test]
    fn non_causal_models_cannot_build_a_layout() {
        use crate::nn::{Graph, Layer};
        let g = Graph {
            name: "bidir".into(),
            layers: vec![Layer::Attention {
                name: "attn".into(),
                heads: 2,
                d_model: 8,
                d_head: 4,
                max_seq: 4,
                causal: false,
            }],
        };
        let mut model = Model::random(g, 5, 3);
        model
            .set_post(
                0,
                super::super::model::PostGemm {
                    bias: vec![0; 32],
                    scheme: crate::quant::QuantScheme::symmetric_signed(
                        8,
                        1.0 / 32.0,
                    ),
                    relu: false,
                },
            )
            .unwrap();
        let compiled = compile(
            &model,
            DeployConfig::new(Algo::Ffip).with_tile(4, 4).with_batch(1),
        )
        .unwrap();
        let CompiledModel::I8(m) = compiled else {
            panic!("8-bit attention compiles to i8 storage")
        };
        let err = KvLayout::from_model(&m).unwrap_err();
        assert!(err.to_string().contains("causal"), "{err:#}");
    }
}
