//! The Layer-3 inference coordinator (paper Fig. 4's host-side role).
//!
//! The paper's contribution is the arithmetic architecture, so L3 here is
//! a thin-but-real serving stack: a bounded request queue, a dynamic
//! [`batcher`] that groups requests into fixed-size accelerator batches
//! (padding the tail), a worker thread driving a [`Backend`] — either the
//! PJRT-compiled artifacts or the bit-exact simulated accelerator
//! ([`server::SimBackend`]) — and latency / throughput / engine-occupancy
//! [`stats`].
//!
//! Batch GEMMs execute on the persistent worker pool in
//! [`crate::engine`]: [`SimBackend`] submits to a
//! [`GemmPool`](crate::engine::GemmPool) shared across every model a
//! [`Router`] deploys ([`Router::deploy_sim`]),
//! and each batch samples the pool's job/item/queue-depth counters into
//! [`ServeStats`].
//!
//! std threads + mpsc (the offline vendor set has no tokio); the
//! interfaces are the same FIFO-in/FIFO-out shape as the paper's
//! PCIe/Xillybus host link.

pub mod batcher;
pub mod router;
pub mod server;
pub mod stats;

pub use batcher::{Batch, Batcher, BatcherConfig};
pub use router::{RouteError, Router};
pub use server::{Backend, Coordinator, EchoBackend, SimBackend};
pub use stats::ServeStats;

/// One inference request: flat input tensor + response channel.
#[derive(Debug)]
pub struct Request {
    pub id: u64,
    pub input: Vec<i32>,
    pub resp: std::sync::mpsc::Sender<Response>,
}

/// One inference response.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub output: Vec<f32>,
    /// end-to-end latency the request observed
    pub latency: std::time::Duration,
}
