//! The Layer-3 inference coordinator (paper Fig. 4's host-side role):
//! the unified model-serving API.
//!
//! Serving is a three-stage pipeline with whole models — not lone GEMMs
//! — as the unit of deployment:
//!
//! 1. [`Model`] — an [`nn::Graph`](crate::nn::Graph) plus quantized
//!    weights (and optional post-GEMM requantization) per layer;
//! 2. [`CompiledModel`] — produced by [`compile`]: each layer lowered
//!    to a GEMM plan (FC directly, conv through the §5.1 in-place
//!    conv→GEMM mapping) with tile geometry from
//!    [`sched::plan_tile`](crate::sched::plan_tile), the FFIP offline
//!    `y_from_b` weight terms precomputed (§3.3), and the **narrowest
//!    legal storage element** selected from the model's quantization
//!    schemes ([`Storage`]): an int8 model compiles to `i8`
//!    weights/activations, `i16` y terms and `i32` accumulators —
//!    the paper's §4.4 datapath widths, end to end;
//! 3. [`InferenceSession`] — executes the compiled layers sequentially
//!    on the shared persistent [`GemmPool`](crate::engine::GemmPool),
//!    typed at the compiled storage width, with preallocated
//!    inter-layer activation buffers and per-layer wall-time
//!    measurement.
//!
//! Around the pipeline sits the serving machinery: a [`Router`] owning
//! one [`Coordinator`] per deployed model
//! ([`Router::deploy_model`]), an admission-bounded request queue
//! ([`scheduler::Admission`]: excess arrivals shed with
//! [`RequestError::Overloaded`] instead of queueing without limit)
//! feeding a dynamic [`batcher`] that groups requests into fixed-size
//! accelerator batches (padding the tail), a [`scheduler::ReplicaSet`]
//! of worker threads driving [`Backend`]s — N cheap session replicas
//! per deployment, dispatched round-robin with least-outstanding-work
//! stealing; each replica runs the pipeline-overlapped
//! [`scheduler::PipelinedSession`] by default ([`SessionBackend`] for
//! the sequential path, or the PJRT-compiled artifacts) — and latency
//! / throughput / engine-occupancy / per-layer / per-replica
//! [`stats`].  Typed [`Tensor`]/[`TensorView`] carry batch data across
//! the backend boundary, and malformed requests come back as
//! [`RequestError`] responses instead of killing a worker.
//!
//! Transformer generation serves through the [`decode`] subsystem
//! instead of the batch path: a [`DecodeScheduler`] holds per-sequence
//! KV caches (FFIP y terms maintained at append time) and batches
//! whichever sequences have a pending token each iteration —
//! admission-bounded by sequence count *and* resident KV bytes
//! ([`RequestError::KvExhausted`]).
//!
//! std threads + mpsc (the offline vendor set has no tokio); the
//! interfaces are the same FIFO-in/FIFO-out shape as the paper's
//! PCIe/Xillybus host link.

pub mod batcher;
pub mod decode;
mod kv;
pub mod model;
pub mod router;
pub mod scheduler;
pub mod server;
pub mod session;
pub mod stats;
pub mod tensor;

pub use batcher::{Batch, Batcher, BatcherConfig};
pub use decode::{DecodeScheduler, StepOutput};
pub use model::{
    compile, compile_with_plan, CompiledLayer, CompiledModel, DeployConfig,
    LayerSummary, LayerWeights, Model, PostGemm, Storage, TypedModel,
};
pub use router::{DeployError, RouteError, Router};
pub use scheduler::{
    Admission, AdmissionConfig, PipeEvent, PipelinedBackend,
    PipelinedSession, ReplicaSet,
};
pub use server::{Backend, Coordinator, EchoBackend};
pub use session::{InferenceSession, LayerTiming, SessionBackend};
pub use stats::{FaultCounts, LayerStats, ReplicaStats, ServeStats};
pub use tensor::{
    pack_ragged_row, unpack_ragged_row, RequestError, Tensor, TensorView,
};

/// One inference request: flat input tensor + response channel.
#[derive(Debug)]
pub struct Request {
    pub id: u64,
    pub input: Vec<i32>,
    pub resp: std::sync::mpsc::Sender<Response>,
}

/// One inference response: the output tensor (a single row), or the
/// typed request failure.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub result: Result<Tensor, RequestError>,
    /// end-to-end latency the request observed
    pub latency: std::time::Duration,
}

impl Response {
    /// The output tensor, panicking on a request error — test and demo
    /// sugar for call sites that expect success.
    pub fn output(self) -> Tensor {
        match self.result {
            Ok(t) => t,
            Err(e) => panic!("request {}: {e}", self.id),
        }
    }
}
