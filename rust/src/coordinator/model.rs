//! The deployable-model pipeline, stage 1 and 2 of
//! `Model → CompiledModel → InferenceSession`.
//!
//! * [`Model`] — an [`nn::Graph`](crate::nn::Graph) plus quantized
//!   weights (and optional post-GEMM requantization) per layer: the
//!   paper's premise made concrete — every served layer type decomposes
//!   to matrix multiplication against a stationary weight operand.
//! * [`compile`] — lowers each layer to a GEMM execution plan: FC
//!   directly, convolution through the in-place conv→GEMM mapping
//!   ([`ConvShape::gemm_dims`](crate::memory::ConvShape::gemm_dims) /
//!   [`Im2Gemm`], §5.1 Algorithm 1), with tile geometry chosen per layer
//!   by [`sched::plan_tile`](crate::sched::plan_tile) and — for FFIP —
//!   the offline weight transform `y = y_from_b(w, tile.y)` precomputed
//!   once at compile time (§3.3: the Θ(NK) y-forming subtractions leave
//!   the request path).
//! * [`CompiledModel`] — the immutable result, shared (`Arc`) between
//!   the router's deployment and every
//!   [`InferenceSession`](super::InferenceSession) executing it.
//!
//! Compilation is where bad configurations die: degenerate tiles, odd
//! K-depths under a fast algorithm, missing/mis-shaped weights and
//! broken inter-layer chains are all deploy-time `Err`s, never worker
//! panics.

use super::batcher::BatcherConfig;
use crate::algo::{y_from_b, Algo, Mat, TileShape};
use crate::memory::Im2Gemm;
use crate::nn::{GemmShape, Graph, Layer};
use crate::quant::QuantScheme;
use crate::sched::plan_tile;
use anyhow::Context;
use std::sync::Arc;
use std::time::Duration;

/// Post-GEMM processing for one layer: bias add, requantization to the
/// next layer's integer domain, optional ReLU — the Post-GEMM Unit of
/// §4.4 (one multiplier per MXU row).
#[derive(Debug, Clone)]
pub struct PostGemm {
    /// Per-output-channel bias (length N).
    pub bias: Vec<i64>,
    pub scheme: QuantScheme,
    pub relu: bool,
}

impl PostGemm {
    /// Apply to one accumulator value of output channel `j`.
    pub fn apply(&self, acc: i64, j: usize) -> i64 {
        let v = crate::quant::requantize(acc, self.bias[j], &self.scheme);
        if self.relu {
            v.max(0)
        } else {
            v
        }
    }
}

/// Per-layer parameters: the stationary GEMM operand (K x N) plus
/// optional post-GEMM requantization.  `post: None` streams raw i64
/// accumulators to the next layer (useful for bit-exactness oracles).
#[derive(Debug, Clone)]
pub struct LayerWeights {
    pub w: Mat<i64>,
    pub post: Option<PostGemm>,
}

/// A whole deployable model: graph topology plus one [`LayerWeights`]
/// per parameterized layer (aligned with `graph.layers`; `None` for
/// layers that carry no weights).
#[derive(Debug, Clone)]
pub struct Model {
    pub graph: Graph,
    weights: Vec<Option<LayerWeights>>,
}

impl Model {
    /// Bind weights to a graph.  `weights` must align 1:1 with
    /// `graph.layers`; provided matrices are dimension-checked against
    /// the layer's GEMM lowering here (missing weights for executable
    /// layers are caught later, by [`compile`]).
    pub fn new(
        graph: Graph,
        weights: Vec<Option<LayerWeights>>,
    ) -> anyhow::Result<Self> {
        if weights.len() != graph.layers.len() {
            anyhow::bail!(
                "{}: {} weight entries for {} layers",
                graph.name,
                weights.len(),
                graph.layers.len()
            );
        }
        for (layer, lw) in graph.layers.iter().zip(&weights) {
            let Some(lw) = lw else { continue };
            let Some((k, n)) = stationary_dims(layer) else {
                anyhow::bail!(
                    "layer {:?} carries weights but has no GEMM lowering",
                    layer.name()
                );
            };
            if (lw.w.rows, lw.w.cols) != (k, n) {
                anyhow::bail!(
                    "layer {:?}: weights are {}x{}, GEMM lowering needs \
                     {k}x{n}",
                    layer.name(),
                    lw.w.rows,
                    lw.w.cols
                );
            }
            if let Some(post) = &lw.post {
                if post.bias.len() != n {
                    anyhow::bail!(
                        "layer {:?}: {} bias terms for {n} output channels",
                        layer.name(),
                        post.bias.len()
                    );
                }
            }
        }
        Ok(Model { graph, weights })
    }

    /// A model with seeded random `bits`-wide weights on every layer
    /// that takes them (no post-GEMM requantization) — examples, tests
    /// and benches.
    pub fn random(graph: Graph, seed: u64, bits: u32) -> Self {
        let mut rng = crate::util::Rng::new(seed);
        let weights = graph
            .layers
            .iter()
            .map(|l| {
                stationary_dims(l).map(|(k, n)| LayerWeights {
                    w: Mat::from_fn(k, n, |_, _| rng.fixed(bits, true)),
                    post: None,
                })
            })
            .collect();
        Model { graph, weights }
    }

    /// Attach post-GEMM requantization to layer `idx`.
    pub fn set_post(
        &mut self,
        idx: usize,
        post: PostGemm,
    ) -> anyhow::Result<()> {
        let lw = self
            .weights
            .get_mut(idx)
            .with_context(|| format!("no layer {idx}"))?
            .as_mut()
            .with_context(|| format!("layer {idx} has no weights"))?;
        if post.bias.len() != lw.w.cols {
            anyhow::bail!(
                "layer {idx}: {} bias terms for {} output channels",
                post.bias.len(),
                lw.w.cols
            );
        }
        lw.post = Some(post);
        Ok(())
    }

    /// The weights bound to layer `idx`, if any.
    pub fn layer_weights(&self, idx: usize) -> Option<&LayerWeights> {
        self.weights.get(idx).and_then(Option::as_ref)
    }

    /// Compile this model for serving (sugar for [`compile`]).
    pub fn compile(&self, cfg: DeployConfig) -> anyhow::Result<CompiledModel> {
        compile(self, cfg)
    }
}

/// The stationary-operand (K, N) dims of a layer's serving GEMM, for
/// layer kinds the serving path executes (FC and dense conv).
fn stationary_dims(layer: &Layer) -> Option<(usize, usize)> {
    match layer {
        Layer::Fc { cin, cout, .. } => Some((*cin, *cout)),
        Layer::Conv { shape, groups, .. } if *groups == 1 => {
            let (_, k, n) = shape.gemm_dims();
            Some((k, n))
        }
        _ => None,
    }
}

/// Deployment knobs for [`compile`] and
/// [`Router::deploy_model`](super::Router::deploy_model): algorithm,
/// MXU tile geometry, accelerator batch and batcher linger, built
/// fluently:
///
/// ```
/// use ffip::coordinator::DeployConfig;
/// use ffip::algo::Algo;
/// let cfg = DeployConfig::new(Algo::Ffip).with_tile(64, 64).with_batch(8);
/// ```
#[derive(Debug, Clone, Copy)]
pub struct DeployConfig {
    pub algo: Algo,
    /// MXU K-depth per loaded tile (even).
    pub x: usize,
    /// MXU N-width per loaded tile.
    pub y: usize,
    /// Accelerator batch size (the static leading dim requests pad to).
    pub batch: usize,
    /// Max time the first request of a batch waits for company.
    pub linger: Duration,
}

impl DeployConfig {
    pub fn new(algo: Algo) -> Self {
        DeployConfig {
            algo,
            x: 64,
            y: 64,
            batch: 4,
            linger: Duration::from_millis(2),
        }
    }

    pub fn with_tile(mut self, x: usize, y: usize) -> Self {
        self.x = x;
        self.y = y;
        self
    }

    pub fn with_batch(mut self, batch: usize) -> Self {
        self.batch = batch;
        self
    }

    pub fn with_linger(mut self, linger: Duration) -> Self {
        self.linger = linger;
        self
    }

    /// The batcher configuration this deployment serves under.
    pub fn batcher(&self) -> BatcherConfig {
        BatcherConfig { batch: self.batch, linger: self.linger }
    }
}

/// How a compiled layer stages its GEMM A operand from the flat
/// per-request activations.
#[derive(Debug, Clone)]
pub(crate) enum LayerExec {
    /// One activation row per request: A is `batch x cin` directly.
    Fc,
    /// Conv→GEMM lowering: each request's NHWC feature map contributes
    /// `out_h*out_w` A rows through the Algorithm 1 address walk.
    Conv { ig: Im2Gemm },
}

/// One layer lowered to its GEMM execution plan.
#[derive(Debug, Clone)]
pub struct CompiledLayer {
    pub name: String,
    /// The per-batch GEMM (`m` already scaled by the deployment batch).
    pub gemm: GemmShape,
    /// Tile geometry from [`sched::plan_tile`](crate::sched::plan_tile).
    pub tile: TileShape,
    /// Flat per-request input length this layer consumes.
    pub in_len: usize,
    /// Flat per-request output length this layer produces.
    pub out_len: usize,
    pub(crate) weights: Arc<Mat<i64>>,
    /// Offline FFIP weight transform (`y_from_b(w, tile.y)`); None for
    /// Baseline/FIP deployments.
    pub(crate) y: Option<Arc<Mat<i64>>>,
    pub(crate) post: Option<PostGemm>,
    pub(crate) exec: LayerExec,
}

impl CompiledLayer {
    /// The stationary GEMM operand (K x N).
    pub fn weights(&self) -> &Mat<i64> {
        &self.weights
    }

    /// The precomputed offline FFIP y terms, when compiled for FFIP.
    pub fn offline_y(&self) -> Option<&Mat<i64>> {
        self.y.as_deref()
    }
}

/// A model lowered to an executable per-layer GEMM pipeline — stage 2
/// of the serving API.  Immutable once built; deployments and sessions
/// share it behind an `Arc`.
#[derive(Debug, Clone)]
pub struct CompiledModel {
    pub name: String,
    pub cfg: DeployConfig,
    pub layers: Vec<CompiledLayer>,
    /// Flat per-request input length (first layer's input).
    pub input_len: usize,
    /// Flat per-request output length (last layer's output).
    pub output_len: usize,
}

impl CompiledModel {
    /// Largest staged A matrix any layer needs (elements), for
    /// preallocating session buffers.
    pub(crate) fn max_a_elems(&self) -> usize {
        self.layers
            .iter()
            .map(|l| l.gemm.m * l.gemm.k)
            .max()
            .unwrap_or(0)
    }

    /// Largest activation slab between layers (elements).
    pub(crate) fn max_act_elems(&self) -> usize {
        self.layers
            .iter()
            .map(|l| self.cfg.batch * l.out_len.max(l.in_len))
            .max()
            .unwrap_or(0)
    }
}

/// Lower `model` to a [`CompiledModel`] under `cfg` — stage 1 → 2 of
/// the serving pipeline.  Every validation that used to panic on a
/// worker thread happens here instead and returns an `Err`.
pub fn compile(model: &Model, cfg: DeployConfig) -> anyhow::Result<CompiledModel> {
    if cfg.batch < 1 {
        anyhow::bail!("{}: batch must be >= 1", model.graph.name);
    }
    if cfg.x < 2 || cfg.x % 2 != 0 {
        anyhow::bail!(
            "{}: MXU tile depth x must be even and >= 2, got {}",
            model.graph.name,
            cfg.x
        );
    }
    if cfg.y < 1 {
        anyhow::bail!("{}: MXU tile width y must be >= 1", model.graph.name);
    }
    let mut layers: Vec<CompiledLayer> = Vec::new();
    for (idx, layer) in model.graph.layers.iter().enumerate() {
        let (exec, m) = match layer {
            Layer::Fc { .. } => (LayerExec::Fc, cfg.batch),
            Layer::Conv { shape, groups, .. } => {
                if *groups != 1 {
                    anyhow::bail!(
                        "layer {:?}: grouped convolution is analysis-only \
                         (serving executes dense conv)",
                        layer.name()
                    );
                }
                let (m1, _, _) = shape.gemm_dims();
                (
                    LayerExec::Conv { ig: Im2Gemm::new(*shape, cfg.x) },
                    cfg.batch * m1,
                )
            }
            other => anyhow::bail!(
                "layer {:?}: this layer kind is analysis-only; the \
                 serving path executes FC and dense conv layers",
                other.name()
            ),
        };
        let (in_len, out_len) =
            layer.unit_io().expect("executable layers define unit io");
        let lw = model.weights[idx].as_ref().with_context(|| {
            format!("layer {:?} has no weights bound", layer.name())
        })?;
        let (k, n) = (lw.w.rows, lw.w.cols);
        if let Some(prev) = layers.last() {
            if prev.out_len != in_len {
                anyhow::bail!(
                    "layer chain broken at {:?}: previous layer emits \
                     {} values per request, this one consumes {}",
                    layer.name(),
                    prev.out_len,
                    in_len
                );
            }
        }
        let gemm = GemmShape::new(m, k, n);
        let tile = plan_tile(gemm, cfg.algo, cfg.x, cfg.y);
        let y = (cfg.algo == Algo::Ffip)
            .then(|| Arc::new(y_from_b(&lw.w, tile.y)));
        layers.push(CompiledLayer {
            name: layer.name().to_string(),
            gemm,
            tile,
            in_len,
            out_len,
            weights: Arc::new(lw.w.clone()),
            y,
            post: lw.post.clone(),
            exec,
        });
    }
    if layers.is_empty() {
        anyhow::bail!("{}: no executable layers", model.graph.name);
    }
    let input_len = layers[0].in_len;
    let output_len = layers[layers.len() - 1].out_len;
    Ok(CompiledModel {
        name: model.graph.name.clone(),
        cfg,
        layers,
        input_len,
        output_len,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::models;

    #[test]
    fn mlp_compiles_with_planned_tiles_and_offline_y() {
        let model = Model::random(models::mlp(&[16, 12, 8]), 1, 4);
        let c = model
            .compile(DeployConfig::new(Algo::Ffip).with_tile(8, 4).with_batch(2))
            .unwrap();
        assert_eq!(c.layers.len(), 2);
        assert_eq!((c.input_len, c.output_len), (16, 8));
        for l in &c.layers {
            assert_eq!(l.gemm.m, 2, "{}: m = batch", l.name);
            assert_eq!((l.tile.x, l.tile.y), (8, 4));
            let y = l.offline_y().expect("FFIP precomputes y");
            assert_eq!((y.rows, y.cols), (l.weights().rows, l.weights().cols));
        }
        // non-FFIP deployments carry no y terms
        let base = model
            .compile(DeployConfig::new(Algo::Baseline).with_tile(8, 4))
            .unwrap();
        assert!(base.layers.iter().all(|l| l.offline_y().is_none()));
    }

    #[test]
    fn conv_layers_lower_through_im2gemm_dims() {
        let g = Graph {
            name: "conv2".into(),
            layers: vec![Layer::Conv {
                name: "c1".into(),
                shape: crate::memory::ConvShape {
                    h: 8,
                    w: 8,
                    cin: 3,
                    cout: 5,
                    kh: 3,
                    kw: 3,
                    stride: 1,
                    pad: 1,
                },
                groups: 1,
            }],
        };
        let model = Model::random(g, 2, 4);
        let c = model
            .compile(DeployConfig::new(Algo::Ffip).with_tile(8, 4).with_batch(3))
            .unwrap();
        let l = &c.layers[0];
        // M = batch * OH*OW, K = kh*kw*cin, N = cout
        assert_eq!((l.gemm.m, l.gemm.k, l.gemm.n), (3 * 64, 27, 5));
        assert_eq!((l.in_len, l.out_len), (8 * 8 * 3, 8 * 8 * 5));
    }

    #[test]
    fn compile_rejects_bad_configs_gracefully() {
        let model = Model::random(models::mlp(&[8, 8]), 3, 4);
        // odd tile depth
        let err = model
            .compile(DeployConfig::new(Algo::Ffip).with_tile(3, 4))
            .unwrap_err();
        assert!(err.to_string().contains("even"), "{err:#}");
        // unsupported layer kind
        let pooled = Model::random(
            Graph {
                name: "p".into(),
                layers: vec![Layer::Pool {
                    name: "pool".into(),
                    size: 2,
                    stride: 2,
                }],
            },
            4,
            4,
        );
        let err = pooled
            .compile(DeployConfig::new(Algo::Ffip).with_tile(8, 4))
            .unwrap_err();
        assert!(err.to_string().contains("analysis-only"), "{err:#}");
    }

    #[test]
    fn broken_layer_chain_is_a_compile_error() {
        // fc 8->4 followed by fc 6->2: 4 != 6
        let g = Graph {
            name: "broken".into(),
            layers: vec![
                Layer::Fc { name: "a".into(), cin: 8, cout: 4 },
                Layer::Fc { name: "b".into(), cin: 6, cout: 2 },
            ],
        };
        let err = Model::random(g, 5, 4)
            .compile(DeployConfig::new(Algo::Baseline).with_tile(8, 4))
            .unwrap_err();
        assert!(err.to_string().contains("chain"), "{err:#}");
    }

    #[test]
    fn model_new_checks_weight_dims() {
        let g = models::mlp(&[4, 3]);
        let bad = vec![Some(LayerWeights {
            w: Mat::zeros(5, 3), // needs 4x3
            post: None,
        })];
        assert!(Model::new(g, bad).is_err());
    }
}
