//! The deployable-model pipeline, stage 1 and 2 of
//! `Model → CompiledModel → InferenceSession`.
//!
//! * [`Model`] — an [`nn::Graph`](crate::nn::Graph) plus quantized
//!   weights (and optional post-GEMM requantization) per layer: the
//!   paper's premise made concrete — every served layer type decomposes
//!   to matrix multiplication against a stationary weight operand.
//! * [`compile`] — lowers each layer to a GEMM execution plan: FC
//!   directly, convolution through the in-place conv→GEMM mapping
//!   ([`ConvShape::gemm_dims`](crate::memory::ConvShape::gemm_dims) /
//!   [`Im2Gemm`], §5.1 Algorithm 1), with tile geometry chosen per layer
//!   by [`sched::plan_tile`](crate::sched::plan_tile) and — for FFIP —
//!   the offline weight transform `y = y_from_b(w, tile.y)` precomputed
//!   once at compile time (§3.3: the Θ(NK) y-forming subtractions leave
//!   the request path).
//! * [`CompiledModel`] — the immutable result: a width-tagged
//!   [`TypedModel`] whose storage element is the **narrowest legal
//!   width** for the model's quantization schemes ([`Storage::Auto`]):
//!   an int8 MLP compiles to `i8` weights/activations with `i16`
//!   offline y terms and `i32` accumulators — the §4.4 datapath, and
//!   4–8× less operand traffic than the historical all-`i64` staging.
//!   Shared (cheaply cloned `Arc`s) between the router's deployment and
//!   every [`InferenceSession`](super::InferenceSession) executing it.
//!
//! Compilation is where bad configurations die: degenerate tiles, odd
//! K-depths under a fast algorithm, missing/mis-shaped weights, broken
//! inter-layer chains, weights that overflow a forced narrow storage,
//! and accumulator widths that cannot hold a layer's worst case
//! ([`FixedSpec::gemm_acc_bits`]) are all deploy-time `Err`s, never
//! worker panics.
//!
//! [`FixedSpec::gemm_acc_bits`]: crate::arith::FixedSpec::gemm_acc_bits

use super::batcher::BatcherConfig;
use crate::algo::element::{AccElem, ElemKind, Element};
use crate::algo::winograd::{to_wide, weight_transform};
use crate::algo::{wino_eligible, y_from_b, Algo, ConvAlgo, Mat, TileShape};
use crate::arith::FixedSpec;
use crate::engine::{abft_fits, AbftCheck, FaultPlan};
use crate::memory::{ConvShape, Im2Gemm};
use crate::nn::{GemmShape, Graph, Layer};
use crate::quant::{QuantScheme, SoftmaxSpec};
use crate::sched::plan_tile;
use crate::tune::{TuneBudget, TunedPlan};
use crate::util::{round_up, with_width};
use anyhow::Context;
use std::sync::Arc;
use std::time::Duration;

/// Post-GEMM processing for one layer: bias add, requantization to the
/// next layer's integer domain, optional ReLU — the Post-GEMM Unit of
/// §4.4 (one multiplier per MXU row).
#[derive(Debug, Clone)]
pub struct PostGemm {
    /// Per-output-channel bias (length N).
    pub bias: Vec<i64>,
    pub scheme: QuantScheme,
    pub relu: bool,
}

impl PostGemm {
    /// Apply to one accumulator value of output channel `j`.
    pub fn apply(&self, acc: i64, j: usize) -> i64 {
        let v = crate::quant::requantize(acc, self.bias[j], &self.scheme);
        if self.relu {
            v.max(0)
        } else {
            v
        }
    }

    /// Apply to one widened accumulator value, emitting the narrow
    /// storage element natively (the serving path's per-layer output;
    /// `scheme.spec.w <= E::BITS` is the compiler's storage-selection
    /// invariant, so the saturated value always fits).  Delegates to
    /// [`quant::requantize_to`](crate::quant::requantize_to) — the one
    /// accumulator→storage requantization implementation.
    pub fn apply_to<E: Element>(&self, acc: E::Acc, j: usize) -> E {
        crate::quant::requantize_to(acc, self.bias[j], &self.scheme, self.relu)
    }
}

/// Per-layer parameters: the stationary GEMM operand (K x N, in the
/// wide training domain — narrowed at compile) plus optional post-GEMM
/// requantization.  `post: None` streams raw accumulators to the next
/// layer (useful for bit-exactness oracles; forces `i64` storage).
#[derive(Debug, Clone)]
pub struct LayerWeights {
    pub w: Mat<i64>,
    pub post: Option<PostGemm>,
}

/// A whole deployable model: graph topology plus one [`LayerWeights`]
/// per parameterized layer (aligned with `graph.layers`; `None` for
/// layers that carry no weights).
#[derive(Debug, Clone)]
pub struct Model {
    pub graph: Graph,
    weights: Vec<Option<LayerWeights>>,
}

impl Model {
    /// Bind weights to a graph.  `weights` must align 1:1 with
    /// `graph.layers`; provided matrices are dimension-checked against
    /// the layer's GEMM lowering here (missing weights for executable
    /// layers are caught later, by [`compile`]).
    pub fn new(
        graph: Graph,
        weights: Vec<Option<LayerWeights>>,
    ) -> anyhow::Result<Self> {
        if weights.len() != graph.layers.len() {
            anyhow::bail!(
                "{}: {} weight entries for {} layers",
                graph.name,
                weights.len(),
                graph.layers.len()
            );
        }
        for (layer, lw) in graph.layers.iter().zip(&weights) {
            let Some(lw) = lw else { continue };
            let Some((k, n)) = stationary_dims(layer) else {
                anyhow::bail!(
                    "layer {:?} carries weights but has no GEMM lowering",
                    layer.name()
                );
            };
            if (lw.w.rows, lw.w.cols) != (k, n) {
                anyhow::bail!(
                    "layer {:?}: weights are {}x{}, GEMM lowering needs \
                     {k}x{n}",
                    layer.name(),
                    lw.w.rows,
                    lw.w.cols
                );
            }
            if let Some(post) = &lw.post {
                if post.bias.len() != n {
                    anyhow::bail!(
                        "layer {:?}: {} bias terms for {n} output channels",
                        layer.name(),
                        post.bias.len()
                    );
                }
            }
        }
        Ok(Model { graph, weights })
    }

    /// A model with seeded random `bits`-wide weights on every layer
    /// that takes them (no post-GEMM requantization) — examples, tests
    /// and benches.
    pub fn random(graph: Graph, seed: u64, bits: u32) -> Self {
        let mut rng = crate::util::Rng::new(seed);
        let weights = graph
            .layers
            .iter()
            .map(|l| {
                stationary_dims(l).map(|(k, n)| LayerWeights {
                    w: Mat::from_fn(k, n, |_, _| rng.fixed(bits, true)),
                    post: None,
                })
            })
            .collect();
        Model { graph, weights }
    }

    /// Attach post-GEMM requantization to layer `idx`.
    pub fn set_post(
        &mut self,
        idx: usize,
        post: PostGemm,
    ) -> anyhow::Result<()> {
        let lw = self
            .weights
            .get_mut(idx)
            .with_context(|| format!("no layer {idx}"))?
            .as_mut()
            .with_context(|| format!("layer {idx} has no weights"))?;
        if post.bias.len() != lw.w.cols {
            anyhow::bail!(
                "layer {idx}: {} bias terms for {} output channels",
                post.bias.len(),
                lw.w.cols
            );
        }
        lw.post = Some(post);
        Ok(())
    }

    /// The weights bound to layer `idx`, if any.
    pub fn layer_weights(&self, idx: usize) -> Option<&LayerWeights> {
        self.weights.get(idx).and_then(Option::as_ref)
    }

    /// Compile this model for serving (sugar for [`compile`]).
    pub fn compile(&self, cfg: DeployConfig) -> anyhow::Result<CompiledModel> {
        compile(self, cfg)
    }

    /// Autotune then compile: run the design-space search under
    /// `budget` and lower from the winning plan, returning both so the
    /// caller can inspect [`TunedPlan::report`] alongside the deployable
    /// model (sugar for [`tune::autotune`](crate::tune::autotune) +
    /// [`compile_with_plan`]).
    pub fn compile_tuned(
        &self,
        budget: &TuneBudget,
    ) -> anyhow::Result<(TunedPlan, CompiledModel)> {
        let plan = crate::tune::autotune(self, budget)?;
        let compiled = compile_with_plan(self, &plan)?;
        Ok((plan, compiled))
    }
}

/// The stationary-operand (K, N) dims of a layer's serving GEMM, for
/// layer kinds the serving path executes (FC, dense conv and
/// attention).  Attention packs its four projection weights into one
/// stationary operand: `d_model` rows by `4 * d_model` columns laid out
/// `[Wq | Wk | Wv | Wo]` (split back apart at compile).
fn stationary_dims(layer: &Layer) -> Option<(usize, usize)> {
    match layer {
        Layer::Fc { cin, cout, .. } => Some((*cin, *cout)),
        Layer::Conv { shape, groups, .. } if *groups == 1 => {
            let (_, k, n) = shape.gemm_dims();
            Some((k, n))
        }
        Layer::Attention { d_model, .. } => Some((*d_model, 4 * d_model)),
        _ => None,
    }
}

/// Storage element selection for a deployment: [`Storage::Auto`] (the
/// default) picks the narrowest width whose quantization schemes,
/// weight values and accumulator guard all check out; the explicit
/// variants force a width (an infeasibly narrow force is a compile
/// error, never a runtime overflow).
///
/// Note the storage width is also the deployment's **input domain**:
/// an `i8`-storage model accepts request values in `[-128, 127]` and
/// answers anything wider with a per-request
/// [`RequestError::Domain`](super::RequestError::Domain).  If the
/// first layer legitimately consumes activations wider than its
/// output schemes (unusual, but nothing in [`Model`] forbids it),
/// force [`Storage::I16`]/[`Storage::I64`] instead of `Auto`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Storage {
    Auto,
    I8,
    I16,
    I64,
}

/// Deployment knobs for [`compile`] and
/// [`Router::deploy_model`](super::Router::deploy_model): algorithm,
/// MXU tile geometry, accelerator batch, batcher linger, storage width,
/// replica count and admission bound, built fluently:
///
/// ```
/// use ffip::coordinator::{DeployConfig, Storage};
/// use ffip::algo::Algo;
/// let cfg = DeployConfig::new(Algo::Ffip)
///     .with_tile(64, 64)
///     .with_batch(8)
///     .with_storage(Storage::Auto)
///     .with_replicas(2)
///     .with_max_queue_depth(64);
/// ```
#[derive(Debug, Clone, Copy)]
pub struct DeployConfig {
    pub algo: Algo,
    /// MXU K-depth per loaded tile (even).
    pub x: usize,
    /// MXU N-width per loaded tile.
    pub y: usize,
    /// Accelerator batch size (the static leading dim requests pad to).
    pub batch: usize,
    /// Max time the first request of a batch waits for company.
    pub linger: Duration,
    /// Storage element selection (default [`Storage::Auto`]).
    pub storage: Storage,
    /// Session replicas served by this deployment (default 1).  The
    /// compiled weights and offline FFIP y terms are `Arc`-shared, so
    /// each extra replica costs only its staging/activation buffers;
    /// batches are dispatched round-robin with least-outstanding-work
    /// stealing across replicas
    /// ([`ReplicaSet`](super::scheduler::ReplicaSet)).
    pub replicas: usize,
    /// Admission bound: maximum admitted-but-unanswered requests before
    /// new arrivals are shed with
    /// [`RequestError::Overloaded`](super::RequestError::Overloaded)
    /// (default `usize::MAX`, i.e. unbounded).
    pub max_queue_depth: usize,
    /// Pipeline-overlapped staging (default `true`): replica workers
    /// split each batch into two micro-batches and stage the next
    /// layer's A operand while the previous micro-batch's GEMM drains
    /// asynchronously on the pool
    /// ([`PipelinedSession`](super::scheduler::PipelinedSession)).
    /// `false` runs the sequential stage→GEMM→post loop
    /// ([`InferenceSession`](super::InferenceSession)); both are
    /// bit-identical.
    pub pipeline: bool,
    /// Deploy-time capacity budget: reject deployment (typed
    /// [`DeployError::CapacityExceeded`](super::DeployError)) when the
    /// compiled model's stationary operand bytes
    /// ([`CompiledModel::stationary_bytes`]) exceed this (default
    /// `None`, unbounded).
    pub max_stationary_bytes: Option<usize>,
    /// Decode-subsystem admission bound: maximum sequences resident in
    /// the [`DecodeScheduler`](super::DecodeScheduler) at once; excess
    /// admissions are shed with
    /// [`RequestError::Overloaded`](super::RequestError::Overloaded)
    /// (default `usize::MAX`, unbounded).
    pub max_active_seqs: usize,
    /// Decode-subsystem KV-cache byte budget: admitting a sequence
    /// reserves its K/V strip bytes against this; when the reservation
    /// cannot fit the admission is shed with
    /// [`RequestError::KvExhausted`](super::RequestError::KvExhausted)
    /// and retiring a sequence frees its bytes (default `usize::MAX`,
    /// unbounded).
    pub max_kv_bytes: usize,
    /// Run the design-space autotuner at compile time: [`compile`]
    /// calls [`tune::autotune`](crate::tune::autotune) under this
    /// budget and lowers from the winning [`TunedPlan`] (per-layer
    /// algorithms, tuned geometry/batch/replicas/storage), keeping this
    /// config's linger / admission / pipeline knobs.  Set via
    /// [`DeployConfig::auto_tune`].
    pub tune: Option<TuneBudget>,
    /// Algorithm-based fault tolerance (default `true`): compile
    /// per-layer Huang–Abraham checksums of the stationary weights
    /// ([`AbftCheck`](crate::engine::AbftCheck)) and verify every
    /// served GEMM post-drain — `O(M·N + M·K)` per GEMM against the
    /// GEMM's `O(M·N·K)`.  Transient corruption heals silently
    /// (scalar-oracle recompute, counted in
    /// [`ServeStats`](super::ServeStats)); persistent faults shed the
    /// affected request as
    /// [`RequestError::FaultDetected`](super::RequestError).  Layers
    /// whose checksummed worst case exceeds the accumulator
    /// ([`abft_fits`](crate::engine::abft_fits)) compile unchecked.
    pub abft: bool,
    /// Deterministic fault injection for this deployment's engine
    /// (default `None`): installs the plan on the deployment pool at
    /// [`Router::deploy_model`](super::Router::deploy_model) so every
    /// ABFT/watchdog recovery path is testable end to end.  Test-only
    /// by default — no plan means the hot path pays one `Option`
    /// branch per item.
    pub fault_plan: Option<FaultPlan>,
    /// Per-request deadline (default `None`, unbounded): batches that
    /// waited longer than this before execution are shed with
    /// [`RequestError::DeadlineExceeded`](super::RequestError) (their
    /// admission slots released), and the deployment pool runs a
    /// watchdog of the same duration so a wedged GEMM becomes a typed
    /// [`GemmError::Timeout`](crate::engine::GemmError) instead of an
    /// infinite block.
    pub request_deadline: Option<Duration>,
}

impl DeployConfig {
    pub fn new(algo: Algo) -> Self {
        DeployConfig {
            algo,
            x: 64,
            y: 64,
            batch: 4,
            linger: Duration::from_millis(2),
            storage: Storage::Auto,
            replicas: 1,
            max_queue_depth: usize::MAX,
            pipeline: true,
            max_stationary_bytes: None,
            max_active_seqs: usize::MAX,
            max_kv_bytes: usize::MAX,
            tune: None,
            abft: true,
            fault_plan: None,
            request_deadline: None,
        }
    }

    /// A config that defers every tuned knob (algorithm, geometry,
    /// batch, replicas, storage) to the design-space autotuner at
    /// compile time; the remaining serving knobs (linger, admission,
    /// pipeline) keep their defaults and stay fluent:
    ///
    /// ```no_run
    /// use ffip::coordinator::DeployConfig;
    /// use ffip::fpga::Device;
    /// use ffip::tune::TuneBudget;
    /// let cfg = DeployConfig::auto_tune(
    ///     TuneBudget::new(Device::arria10_sx660()),
    /// )
    /// .with_max_queue_depth(64);
    /// ```
    pub fn auto_tune(budget: TuneBudget) -> Self {
        // algo/x/y/batch/replicas/storage below are placeholders the
        // tuned plan overwrites at compile
        let mut cfg = DeployConfig::new(Algo::Ffip);
        cfg.storage = budget.storage;
        cfg.max_stationary_bytes = budget.max_stationary_bytes;
        cfg.tune = Some(budget);
        cfg
    }

    /// Replace the uniform algorithm, keeping every other knob.
    pub fn with_algo(mut self, algo: Algo) -> Self {
        self.algo = algo;
        self
    }

    pub fn with_tile(mut self, x: usize, y: usize) -> Self {
        self.x = x;
        self.y = y;
        self
    }

    pub fn with_batch(mut self, batch: usize) -> Self {
        self.batch = batch;
        self
    }

    pub fn with_linger(mut self, linger: Duration) -> Self {
        self.linger = linger;
        self
    }

    pub fn with_storage(mut self, storage: Storage) -> Self {
        self.storage = storage;
        self
    }

    /// Serve this deployment with `replicas` session replicas (>= 1).
    pub fn with_replicas(mut self, replicas: usize) -> Self {
        self.replicas = replicas;
        self
    }

    /// Bound the admission queue at `max_queue_depth` in-flight
    /// requests (>= 1); excess arrivals are shed with
    /// [`RequestError::Overloaded`](super::RequestError::Overloaded).
    pub fn with_max_queue_depth(mut self, max_queue_depth: usize) -> Self {
        self.max_queue_depth = max_queue_depth;
        self
    }

    /// Enable or disable pipeline-overlapped staging.
    pub fn with_pipeline(mut self, pipeline: bool) -> Self {
        self.pipeline = pipeline;
        self
    }

    /// Bound the deployment's stationary operand bytes: deployment is
    /// rejected at [`Router::deploy_model`](super::Router::deploy_model)
    /// when the compiled model needs more.
    pub fn with_max_stationary_bytes(mut self, bytes: usize) -> Self {
        self.max_stationary_bytes = Some(bytes);
        self
    }

    /// Bound the decode subsystem at `max_active_seqs` resident
    /// sequences (>= 1); excess admissions are shed with
    /// [`RequestError::Overloaded`](super::RequestError::Overloaded).
    pub fn with_max_active_seqs(mut self, max_active_seqs: usize) -> Self {
        self.max_active_seqs = max_active_seqs;
        self
    }

    /// Bound the decode subsystem's resident KV-cache bytes (>= 1);
    /// admissions that cannot reserve their strip bytes are shed with
    /// [`RequestError::KvExhausted`](super::RequestError::KvExhausted).
    pub fn with_max_kv_bytes(mut self, max_kv_bytes: usize) -> Self {
        self.max_kv_bytes = max_kv_bytes;
        self
    }

    /// Run the design-space autotuner at compile time under `budget`
    /// (see [`DeployConfig::auto_tune`]).
    pub fn with_tune(mut self, budget: TuneBudget) -> Self {
        self.tune = Some(budget);
        self
    }

    /// Enable or disable ABFT checksum verification of served GEMMs
    /// (on by default).
    pub fn with_abft(mut self, abft: bool) -> Self {
        self.abft = abft;
        self
    }

    /// Install a deterministic [`FaultPlan`] on this deployment's
    /// engine pool (test-only; see
    /// [`crate::engine::FaultPlan`]).
    pub fn with_fault_plan(mut self, plan: FaultPlan) -> Self {
        self.fault_plan = Some(plan);
        self
    }

    /// Shed batches older than `deadline` with
    /// [`RequestError::DeadlineExceeded`](super::RequestError) and arm
    /// the pool watchdog at the same duration.
    pub fn with_request_deadline(mut self, deadline: Duration) -> Self {
        self.request_deadline = Some(deadline);
        self
    }

    /// The batcher configuration this deployment serves under.
    pub fn batcher(&self) -> BatcherConfig {
        BatcherConfig { batch: self.batch, linger: self.linger }
    }

    /// The admission-control configuration this deployment serves under.
    pub fn admission(&self) -> super::scheduler::AdmissionConfig {
        super::scheduler::AdmissionConfig {
            max_queue_depth: self.max_queue_depth,
            ..super::scheduler::AdmissionConfig::UNBOUNDED
        }
    }

    /// The decode-subsystem admission configuration: the depth bound
    /// covers resident *sequences* (not requests) and the KV-byte
    /// budget covers their cached K/V strips.
    pub fn decode_admission(&self) -> super::scheduler::AdmissionConfig {
        super::scheduler::AdmissionConfig {
            max_queue_depth: self.max_active_seqs,
            max_kv_bytes: self.max_kv_bytes,
        }
    }
}

/// How a compiled layer stages its GEMM A operand from the flat
/// per-request activations.
#[derive(Debug, Clone)]
pub(crate) enum LayerExec<E: Element> {
    /// One activation row per request: A is `batch x cin` directly.
    Fc,
    /// Conv→GEMM lowering: each request's NHWC feature map contributes
    /// `out_h*out_w` A rows through the Algorithm 1 address walk.
    Conv { ig: Im2Gemm },
    /// Winograd F(2×2,3×3) composed with the inner-product algorithm
    /// (§6.2.2): the input transform stages 16 elementwise-stage GEMMs
    /// over [`Element::Wide`] operands against the pre-transformed
    /// stationary weights in [`WinoExec`].
    WinoConv(Box<WinoExec<E>>),
    /// Multi-head self-attention over ragged length-prefixed rows:
    /// projections, per-head QKᵀ/softmax/AV, output projection.
    Attention(Box<AttnExec<E>>),
    /// An FC layer *inside* a ragged transformer block: each request's
    /// valid tokens gather into dense GEMM A rows (one GEMM over all
    /// tokens of the batch), and the requantized outputs scatter back
    /// under the same `[len, tokens, pad]` length prefix with the tail
    /// re-zeroed — the residual/projection I/O contract that lets
    /// `models::transformer` chain attention → MLP end-to-end.
    TokenFc { max_seq: usize },
    /// Residual add: `out = in + input-of-layer(idx − span)`, saturated
    /// to `bits` (the nearest preceding post-GEMM quantized width, so
    /// the sum stays in the activation domain at every storage width).
    /// Carries no GEMM; `ragged` skips the in-band length prefix slot
    /// when the wire rows are ragged.
    Residual { span: usize, bits: u32, ragged: bool },
}

/// The compiled execution plan of one [`ConvAlgo::WinogradFfip`] conv
/// layer: the 16 Winograd-domain stationary operands `U^{(i,j)} =
/// (G g Gᵀ)_{ij}` (each `cin × cout`, transformed once at compile time
/// in the exact ×4-scaled integer domain of `algo::winograd`) plus
/// their offline FFIP y terms.  Serving gathers each request's 4×4
/// input tiles, applies the input transform, runs the 16 GEMMs through
/// the pool under the layer's inner-product algorithm — the two
/// multiply reductions compose because they act on orthogonal
/// dimensions (spatial tiles vs. the `cin` inner product) — and folds
/// the products back through the output transform (an exact `/4`).
#[derive(Debug, Clone)]
pub(crate) struct WinoExec<E: Element> {
    pub shape: ConvShape,
    /// Winograd tile grid: `out_h / 2` × `out_w / 2` tiles per request.
    pub th: usize,
    pub tw: usize,
    /// The 16 transformed stationary operands, indexed `i * 4 + j`.
    pub u: Vec<Arc<Mat<E::Wide>>>,
    /// Offline FFIP y terms per transformed operand (None under
    /// Baseline/FIP).
    pub yu: Vec<Option<Arc<Mat<<E::Wide as Element>::Y>>>>,
    /// Tile geometry of the elementwise-stage GEMMs
    /// (`batch·tiles × cin × cout`).
    pub tile: TileShape,
}

/// The compiled execution plan of one [`Layer::Attention`]: split
/// projection weights (stationary, so their FFIP y terms precompute
/// here as usual), tile geometry for the three GEMM families, and the
/// fixed-point softmax / AV requantization specs.
///
/// The per-head QKᵀ and AV GEMMs multiply two **activation** operands,
/// so under FFIP their y terms cannot be precomputed at compile time:
/// [`y_from_b`] runs on the serving critical path instead — the
/// online-y scenario this layer kind introduces to the engine
/// ([`GemmPool::submit_online`](crate::engine::GemmPool::submit_online)).
#[derive(Debug, Clone)]
pub(crate) struct AttnExec<E: Element> {
    pub heads: usize,
    pub d_model: usize,
    pub d_head: usize,
    pub max_seq: usize,
    /// Causal (autoregressive) masking: score row `i` softmaxes over
    /// keys `0..=i` only — the precondition for KV-cached decode
    /// ([`DecodeScheduler`](super::DecodeScheduler)) matching a full
    /// recompute bit for bit.
    pub causal: bool,
    /// Projection weights split out of the packed `[Wq|Wk|Wv|Wo]`
    /// stationary operand, each `d_model x d_model`.
    pub wq: Arc<Mat<E>>,
    pub wk: Arc<Mat<E>>,
    pub wv: Arc<Mat<E>>,
    pub wo: Arc<Mat<E>>,
    /// Offline FFIP y terms of the stationary projections (None for
    /// Baseline/FIP).
    pub yq: Option<Arc<Mat<E::Y>>>,
    pub yk: Option<Arc<Mat<E::Y>>>,
    pub yv: Option<Arc<Mat<E::Y>>>,
    pub yo: Option<Arc<Mat<E::Y>>>,
    /// Tile geometry: token-stacked projections, per-head QKᵀ, per-head
    /// AV.
    pub proj_tile: TileShape,
    pub qk_tile: TileShape,
    pub av_tile: TileShape,
    /// Fixed-point softmax over each score row's valid (kv-length)
    /// prefix.
    pub softmax: SoftmaxSpec,
    /// Requantizes AV accumulators (probability-weighted V sums at
    /// scale `softmax.one`) back to the w-bit activation domain.
    pub av_scheme: QuantScheme,
}

/// One layer lowered to its GEMM execution plan, typed at the storage
/// element `E`: weights in `E`, offline FFIP y terms in `E::Y` (one
/// extra bit, §4.4).
#[derive(Debug, Clone)]
pub struct CompiledLayer<E: Element> {
    pub name: String,
    /// The inner-product algorithm this layer executes under — the
    /// deployment-wide [`DeployConfig::algo`] unless a [`TunedPlan`]
    /// overrode it per layer (sessions read this field, never the
    /// config, so mixed-algorithm deployments lower naturally).
    pub algo: Algo,
    /// The per-batch GEMM (`m` already scaled by the deployment batch).
    pub gemm: GemmShape,
    /// Tile geometry from [`sched::plan_tile`](crate::sched::plan_tile).
    pub tile: TileShape,
    /// Flat per-request input length this layer consumes.
    pub in_len: usize,
    /// Flat per-request output length this layer produces.
    pub out_len: usize,
    pub(crate) weights: Arc<Mat<E>>,
    /// Offline FFIP weight transform (`y_from_b(w, tile.y)`); None for
    /// Baseline/FIP deployments.
    pub(crate) y: Option<Arc<Mat<E::Y>>>,
    pub(crate) post: Option<PostGemm>,
    pub(crate) exec: LayerExec<E>,
    /// A later [`LayerExec::Residual`] adds this layer's *input* slab:
    /// sessions snapshot it before executing the layer.
    pub(crate) save_input: bool,
    /// Compile-time Huang–Abraham checksums of the stationary weights
    /// ([`DeployConfig::abft`]); `None` when ABFT is off, the layer
    /// carries no stationary GEMM operand (residual, Winograd — whose
    /// 16 transformed operands run in the wide domain — and the
    /// attention families, whose QKᵀ/AV operands are per-request
    /// activations), or the checksummed worst case exceeds the
    /// accumulator ([`abft_fits`](crate::engine::abft_fits)).
    pub(crate) abft: Option<Arc<AbftCheck<E>>>,
}

impl<E: Element> CompiledLayer<E> {
    /// The stationary GEMM operand (K x N) in its storage width.
    pub fn weights(&self) -> &Mat<E> {
        &self.weights
    }

    /// The precomputed offline FFIP y terms, when compiled for FFIP.
    pub fn offline_y(&self) -> Option<&Mat<E::Y>> {
        self.y.as_deref()
    }

    /// Bytes of stationary operand storage this layer streams per tile
    /// pass: weights (and offline y when present) at their native
    /// widths — the H8 bandwidth accounting.  Attention layers count
    /// the packed projection weights plus the four per-projection
    /// offline y terms (the online QKᵀ/AV y terms are per-request
    /// activations, not stationary traffic).
    pub fn stationary_bytes(&self) -> usize {
        // Winograd conv layers stream the 16 transformed U operands
        // (at the wide width) instead of the raw 3×3 weights, which
        // exist only as the transform's source.
        let w = match &self.exec {
            LayerExec::WinoConv(_) => 0,
            _ => self.weights.data.len() * std::mem::size_of::<E>(),
        };
        let y = self
            .y
            .as_ref()
            .map_or(0, |y| y.data.len() * std::mem::size_of::<E::Y>());
        let extra = match &self.exec {
            LayerExec::Attention(at) => [&at.yq, &at.yk, &at.yv, &at.yo]
                .into_iter()
                .filter_map(Option::as_deref)
                .map(|y| y.data.len() * std::mem::size_of::<E::Y>())
                .sum(),
            LayerExec::WinoConv(wx) => {
                let u: usize = wx
                    .u
                    .iter()
                    .map(|m| m.data.len() * std::mem::size_of::<E::Wide>())
                    .sum();
                let yu: usize = wx
                    .yu
                    .iter()
                    .filter_map(Option::as_deref)
                    .map(|y| {
                        y.data.len()
                            * std::mem::size_of::<<E::Wide as Element>::Y>()
                    })
                    .sum();
                u + yu
            }
            _ => 0,
        };
        w + y + extra
    }
}

/// A model lowered to an executable per-layer GEMM pipeline over
/// storage element `E` — the typed payload behind [`CompiledModel`]'s
/// width tag.  Immutable once built; deployments and sessions share it
/// behind an `Arc`.
#[derive(Debug, Clone)]
pub struct TypedModel<E: Element> {
    pub name: String,
    pub cfg: DeployConfig,
    pub layers: Vec<CompiledLayer<E>>,
    /// Flat per-request input length (first layer's input).
    pub input_len: usize,
    /// Flat per-request output length (last layer's output).
    pub output_len: usize,
}

impl<E: Element> TypedModel<E> {
    /// Largest staged A matrix any layer needs (elements), for
    /// preallocating session buffers.
    pub(crate) fn max_a_elems(&self) -> usize {
        self.layers
            .iter()
            .map(|l| l.gemm.m * l.gemm.k)
            .max()
            .unwrap_or(0)
    }

    /// Largest activation slab between layers (elements).
    pub(crate) fn max_act_elems(&self) -> usize {
        self.layers
            .iter()
            .map(|l| self.cfg.batch * l.out_len.max(l.in_len))
            .max()
            .unwrap_or(0)
    }

    /// The compiled `max_seq` when the model's *input* layer is
    /// attention — i.e. when request rows carry the ragged
    /// `[len, tokens, pad]` wire format whose length prefix the replica
    /// scheduler sweeps per request
    /// ([`RequestError`](super::tensor::RequestError)`::BadSequence`).
    pub(crate) fn max_seq(&self) -> Option<usize> {
        match self.layers.first().map(|l| &l.exec) {
            Some(LayerExec::Attention(at)) => Some(at.max_seq),
            _ => None,
        }
    }
}

/// Width-independent description of one compiled layer — what stats,
/// benches and tests read without caring about the storage type (the
/// typed weights stay inside the [`CompiledModel`] variant).
#[derive(Debug, Clone)]
pub struct LayerSummary {
    pub name: String,
    /// The algorithm the layer executes under (per-layer when compiled
    /// from a [`TunedPlan`]).
    pub algo: Algo,
    pub gemm: GemmShape,
    pub tile: TileShape,
    pub in_len: usize,
    pub out_len: usize,
    /// (K, N) of the stationary operand.
    pub weight_dims: (usize, usize),
    /// Dimensions of the precomputed offline y, when compiled for FFIP.
    pub offline_y_dims: Option<(usize, usize)>,
    /// Stationary operand bytes at the native storage widths.
    pub stationary_bytes: usize,
}

/// Stage-2 result of the serving pipeline: a [`TypedModel`] behind a
/// runtime width tag.  [`compile`] picks the narrowest legal storage
/// for the model's quantization schemes (or the forced
/// [`DeployConfig::storage`]), so a deployed int8 MLP really stores and
/// streams `i8` operands.  Cheap to clone (the typed payload is an
/// `Arc`).
#[derive(Debug, Clone)]
pub enum CompiledModel {
    I8(Arc<TypedModel<i8>>),
    I16(Arc<TypedModel<i16>>),
    I64(Arc<TypedModel<i64>>),
}

impl CompiledModel {
    /// The storage element width this model compiled to.
    pub fn storage(&self) -> ElemKind {
        match self {
            CompiledModel::I8(_) => ElemKind::I8,
            CompiledModel::I16(_) => ElemKind::I16,
            CompiledModel::I64(_) => ElemKind::I64,
        }
    }

    pub fn name(&self) -> &str {
        with_width!(CompiledModel, self, m => &m.name)
    }

    pub fn cfg(&self) -> DeployConfig {
        with_width!(CompiledModel, self, m => m.cfg)
    }

    /// Flat per-request input length (first layer's input).
    pub fn input_len(&self) -> usize {
        with_width!(CompiledModel, self, m => m.input_len)
    }

    /// Flat per-request output length (last layer's output).
    pub fn output_len(&self) -> usize {
        with_width!(CompiledModel, self, m => m.output_len)
    }

    pub fn num_layers(&self) -> usize {
        with_width!(CompiledModel, self, m => m.layers.len())
    }

    /// The compiled `max_seq` when request rows carry the ragged
    /// attention wire format (the input layer is attention); `None` for
    /// dense-row models.
    pub fn max_seq(&self) -> Option<usize> {
        with_width!(CompiledModel, self, m => m.max_seq())
    }

    /// Width-independent description of layer `idx`.
    pub fn layer(&self, idx: usize) -> Option<LayerSummary> {
        with_width!(CompiledModel, self, m => m.layers.get(idx).map(|l| LayerSummary {
            name: l.name.clone(),
            algo: l.algo,
            gemm: l.gemm,
            tile: l.tile,
            in_len: l.in_len,
            out_len: l.out_len,
            weight_dims: (l.weights.rows, l.weights.cols),
            offline_y_dims: l.y.as_ref().map(|y| (y.rows, y.cols)),
            stationary_bytes: l.stationary_bytes(),
        }))
    }

    /// Width-independent descriptions of every layer.
    pub fn layers(&self) -> Vec<LayerSummary> {
        (0..self.num_layers()).filter_map(|i| self.layer(i)).collect()
    }

    /// Total stationary operand bytes (weights + offline y) across all
    /// layers at the native storage widths — the H8 bandwidth number.
    pub fn stationary_bytes(&self) -> usize {
        with_width!(
            CompiledModel,
            self,
            m => m.layers.iter().map(|l| l.stationary_bytes()).sum()
        )
    }
}

/// Why a candidate storage width is not usable for a model (the reasons
/// [`Storage::Auto`] skips it, or a forced width fails with).
///
/// `Storage::Auto` may run this scan for two widths and `compile_typed`
/// re-narrows the weights it already range-checked — a deliberate
/// deploy-time-only redundancy that keeps width selection, error
/// reporting and lowering each single-purpose (the request path is
/// untouched).
/// When compiling from a [`TunedPlan`] the per-layer algorithm
/// overrides apply: the accumulator guard is algorithm-dependent (fast
/// algorithms need one more guard bit, [`FixedSpec::gemm_acc_bits`]),
/// so a mixed-algorithm plan is checked layer by layer.  This is also
/// the feasibility gate [`tune::autotune`](crate::tune::autotune) runs
/// on each candidate storage width.
pub(crate) fn storage_obstacle_for_plan<E: Element>(
    model: &Model,
    cfg: &DeployConfig,
    plan: Option<&TunedPlan>,
) -> Option<String> {
    if !E::GUARDED {
        // wide oracle storage accepts everything (historical semantics)
        return None;
    }
    for (idx, layer) in model.graph.layers.iter().enumerate() {
        if stationary_dims(layer).is_none() {
            continue; // non-executable kinds fail later, width-independent
        }
        let Some(lw) = model.layer_weights(idx) else {
            continue; // missing weights fail later, width-independent
        };
        let Some(post) = &lw.post else {
            return Some(format!(
                "layer {:?} streams raw accumulators (no post-GEMM \
                 requantization), which need wide storage",
                layer.name()
            ));
        };
        if post.scheme.spec.w > E::BITS {
            return Some(format!(
                "layer {:?} requantizes to {} bits > {}-bit storage",
                layer.name(),
                post.scheme.spec.w,
                E::BITS
            ));
        }
        if lw.w.data.iter().any(|&v| E::from_i64(v).is_none()) {
            return Some(format!(
                "layer {:?} has weight values outside the {} range",
                layer.name(),
                E::NAME
            ));
        }
        // attention rows carry the ragged length prefix in-band, so the
        // prefix itself must fit the storage element (Auto escalates a
        // max_seq-200 model to i16 here), and the deepest request-path
        // accumulation is the larger of the projection K (= d_model)
        // and the even-padded AV K (= max_seq rounded up)
        let k_max = match layer {
            Layer::Attention { d_model, max_seq, .. } => {
                if E::from_i64(*max_seq as i64).is_none() {
                    return Some(format!(
                        "layer {:?}: the ragged length prefix (up to \
                         {max_seq}) does not fit {} request rows",
                        layer.name(),
                        E::NAME
                    ));
                }
                (*d_model).max(round_up(*max_seq, 2))
            }
            _ => lw.w.rows,
        };
        // the release-mode accumulator guard (2w + clog2 rule) must
        // hold for this layer's full-K accumulation, under the
        // algorithm this layer actually runs
        let algo = plan
            .and_then(|p| p.layer_algo(idx))
            .unwrap_or(cfg.algo);
        let conv_algo = plan
            .and_then(|p| p.layer_conv(idx))
            .unwrap_or(ConvAlgo::Im2Gemm);
        match (layer, conv_algo) {
            (Layer::Conv { shape, .. }, ConvAlgo::WinogradFfip) => {
                // Winograd-lowered convs run their 16 stage GEMMs over
                // E::Wide operands (K = cin) but with the ×4/×9
                // transform growth folded into the guard; the wide
                // element's accumulator must absorb it.
                let need = FixedSpec::signed(E::BITS)
                    .winograd_acc_bits(algo.is_fast(), cfg.x, shape.cin);
                if need > <<E::Wide as Element>::Acc as AccElem>::BITS {
                    return Some(format!(
                        "layer {:?} needs a {need}-bit Winograd \
                         accumulator (cin = {}), exceeding {}'s {}-bit \
                         wide accumulator",
                        layer.name(),
                        shape.cin,
                        E::NAME,
                        <<E::Wide as Element>::Acc as AccElem>::BITS
                    ));
                }
            }
            _ => {
                let need = FixedSpec::signed(E::BITS)
                    .gemm_acc_bits(algo.is_fast(), cfg.x, k_max);
                if need > <E::Acc as AccElem>::BITS {
                    return Some(format!(
                        "layer {:?} needs a {need}-bit accumulator \
                         (K = {k_max}), exceeding {}'s {}-bit accumulator",
                        layer.name(),
                        E::NAME,
                        <E::Acc as AccElem>::BITS
                    ));
                }
            }
        }
    }
    None
}

/// Lower `model` to a [`CompiledModel`] under `cfg` — stage 1 → 2 of
/// the serving pipeline.  Picks the narrowest legal storage element
/// (or validates the forced one), then lowers every layer at that
/// width.  Every validation that used to panic on a worker thread
/// happens here instead and returns an `Err`.
///
/// When [`DeployConfig::tune`] is set (see [`DeployConfig::auto_tune`])
/// the design-space autotuner runs first and the winning [`TunedPlan`]
/// supplies algorithm/geometry/batch/replicas/storage — this config
/// keeps only its serving knobs (linger, admission bound, pipeline).
pub fn compile(model: &Model, cfg: DeployConfig) -> anyhow::Result<CompiledModel> {
    match cfg.tune {
        Some(budget) => {
            let plan = crate::tune::autotune(model, &budget)?;
            compile_inner(model, merge_plan(cfg, &plan), Some(&plan))
        }
        None => compile_inner(model, cfg, None),
    }
}

/// Lower `model` from an explicit [`TunedPlan`] (from
/// [`tune::autotune`](crate::tune::autotune) or
/// [`Model::compile_tuned`]): the plan's per-layer algorithms, tuned
/// geometry, batch, replicas and storage drive the lowering; serving
/// knobs stay at their [`DeployConfig::new`] defaults.
pub fn compile_with_plan(
    model: &Model,
    plan: &TunedPlan,
) -> anyhow::Result<CompiledModel> {
    let base = DeployConfig::new(plan.dominant_algo());
    compile_inner(model, merge_plan(base, plan), Some(plan))
}

/// The deployment-level knobs a [`TunedPlan`] decides, overlaid on a
/// caller config whose serving knobs (linger, admission, pipeline,
/// ABFT / fault-plan / deadline robustness) survive.
fn merge_plan(mut cfg: DeployConfig, plan: &TunedPlan) -> DeployConfig {
    cfg.algo = plan.dominant_algo();
    cfg.x = plan.x;
    cfg.y = plan.y;
    cfg.batch = plan.batch;
    cfg.replicas = plan.replicas;
    cfg.storage = plan.storage;
    if cfg.max_stationary_bytes.is_none() {
        cfg.max_stationary_bytes = plan.max_stationary_bytes;
    }
    cfg.tune = None;
    cfg
}

fn compile_inner(
    model: &Model,
    cfg: DeployConfig,
    plan: Option<&TunedPlan>,
) -> anyhow::Result<CompiledModel> {
    if cfg.batch < 1 {
        anyhow::bail!("{}: batch must be >= 1", model.graph.name);
    }
    if cfg.x < 2 || cfg.x % 2 != 0 {
        anyhow::bail!(
            "{}: MXU tile depth x must be even and >= 2, got {}",
            model.graph.name,
            cfg.x
        );
    }
    if cfg.y < 1 {
        anyhow::bail!("{}: MXU tile width y must be >= 1", model.graph.name);
    }
    if cfg.replicas < 1 {
        anyhow::bail!("{}: replicas must be >= 1", model.graph.name);
    }
    if cfg.max_queue_depth < 1 {
        anyhow::bail!(
            "{}: max_queue_depth must be >= 1 (use usize::MAX for \
             unbounded admission)",
            model.graph.name
        );
    }
    if cfg.max_active_seqs < 1 {
        anyhow::bail!(
            "{}: max_active_seqs must be >= 1 (use usize::MAX for \
             unbounded decode admission)",
            model.graph.name
        );
    }
    if cfg.max_kv_bytes < 1 {
        anyhow::bail!(
            "{}: max_kv_bytes must be >= 1 (use usize::MAX for an \
             unbounded KV cache)",
            model.graph.name
        );
    }
    let force = |obstacle: Option<String>, kind: ElemKind| match obstacle {
        None => Ok(()),
        Some(reason) => Err(anyhow::anyhow!(
            "{}: cannot compile with {} storage: {reason}",
            model.graph.name,
            kind.name()
        )),
    };
    match cfg.storage {
        Storage::I8 => {
            force(
                storage_obstacle_for_plan::<i8>(model, &cfg, plan),
                ElemKind::I8,
            )?;
            Ok(CompiledModel::I8(Arc::new(compile_typed(model, cfg, plan)?)))
        }
        Storage::I16 => {
            force(
                storage_obstacle_for_plan::<i16>(model, &cfg, plan),
                ElemKind::I16,
            )?;
            Ok(CompiledModel::I16(Arc::new(compile_typed(
                model, cfg, plan,
            )?)))
        }
        Storage::I64 => {
            Ok(CompiledModel::I64(Arc::new(compile_typed(
                model, cfg, plan,
            )?)))
        }
        Storage::Auto => {
            if storage_obstacle_for_plan::<i8>(model, &cfg, plan).is_none() {
                Ok(CompiledModel::I8(Arc::new(compile_typed(
                    model, cfg, plan,
                )?)))
            } else if storage_obstacle_for_plan::<i16>(model, &cfg, plan)
                .is_none()
            {
                Ok(CompiledModel::I16(Arc::new(compile_typed(
                    model, cfg, plan,
                )?)))
            } else {
                Ok(CompiledModel::I64(Arc::new(compile_typed(
                    model, cfg, plan,
                )?)))
            }
        }
    }
}

/// Lower every layer at a fixed storage element `E` (the width was
/// selected/validated by [`compile`]).  A [`TunedPlan`] supplies
/// per-layer algorithm overrides; layers the plan does not mention (or
/// a `None` plan) run the deployment-wide [`DeployConfig::algo`].
fn compile_typed<E: Element>(
    model: &Model,
    cfg: DeployConfig,
    plan: Option<&TunedPlan>,
) -> anyhow::Result<TypedModel<E>> {
    /// Width-independent lowering choice made before the weights are
    /// narrowed (attention needs the narrow weights to build its split
    /// execution plan, so `LayerExec` construction happens second).
    enum Plan {
        Fc,
        TokenFc { max_seq: usize },
        Conv(Im2Gemm),
        Wino(ConvShape),
        Attn {
            heads: usize,
            d_model: usize,
            d_head: usize,
            max_seq: usize,
            causal: bool,
        },
        Residual { span: usize, bits: u32, ragged: bool },
    }
    /// The inter-layer I/O contract propagated down the chain: dense
    /// flat activation rows, or the ragged `[len, tokens, pad]`
    /// attention wire format.  Propagating the *kind* (not just the
    /// flat length) is what lets an FC layer inside a transformer block
    /// lower token-parallel ([`LayerExec::TokenFc`]) and a residual add
    /// verify it spans back to a same-shaped input.
    #[derive(Clone, Copy, PartialEq, Eq)]
    enum Wire {
        Flat(usize),
        Ragged { max_seq: usize, d: usize },
    }
    impl Wire {
        fn len(self) -> usize {
            match self {
                Wire::Flat(n) => n,
                Wire::Ragged { max_seq, d } => 1 + max_seq * d,
            }
        }
        fn describe(self) -> String {
            match self {
                Wire::Flat(n) => format!("flat rows of {n} values"),
                Wire::Ragged { max_seq, d } => format!(
                    "ragged [len, tokens, pad] rows of up to {max_seq} \
                     tokens x {d}"
                ),
            }
        }
    }
    let mut layers: Vec<CompiledLayer<E>> = Vec::new();
    // per compiled layer: (input wire, output wire) — the chain check
    // and the Residual back-reference both read this
    let mut wires: Vec<(Wire, Wire)> = Vec::new();
    for (idx, layer) in model.graph.layers.iter().enumerate() {
        // the algorithm (and conv lowering) this layer executes under:
        // the tuned per-layer choice when a plan covers it, else the
        // deployment-wide algorithm with direct im2col lowering
        let choice = plan.and_then(|p| p.layers.iter().find(|l| l.layer == idx));
        let (algo, conv_algo) = match choice {
            Some(choice) => {
                if choice.name != layer.name() {
                    anyhow::bail!(
                        "{}: tuned plan names layer {idx} {:?} but the \
                         model has {:?} — plan built for another graph?",
                        model.graph.name,
                        choice.name,
                        layer.name()
                    );
                }
                (choice.algo, choice.conv)
            }
            None => (cfg.algo, ConvAlgo::Im2Gemm),
        };
        let prev_wire = wires.last().map(|&(_, out)| out);
        let (lplan, wire_in, wire_out, m) = match layer {
            Layer::Fc { cin, cout, .. } => match prev_wire {
                // inside a ragged block the FC lowers token-parallel:
                // gather valid tokens, one dense GEMM, scatter back
                Some(Wire::Ragged { max_seq, d }) if d == *cin => (
                    Plan::TokenFc { max_seq },
                    Wire::Ragged { max_seq, d: *cin },
                    Wire::Ragged { max_seq, d: *cout },
                    cfg.batch * max_seq,
                ),
                _ => {
                    (Plan::Fc, Wire::Flat(*cin), Wire::Flat(*cout), cfg.batch)
                }
            },
            Layer::Conv { shape, groups, .. } => {
                if *groups != 1 {
                    anyhow::bail!(
                        "layer {:?}: grouped convolution is analysis-only \
                         (serving executes dense conv)",
                        layer.name()
                    );
                }
                let (ui, uo) =
                    layer.unit_io().expect("conv layers define unit io");
                let (plan, m) = match conv_algo {
                    ConvAlgo::Im2Gemm => {
                        let (m1, _, _) = shape.gemm_dims();
                        (
                            Plan::Conv(Im2Gemm::new(*shape, cfg.x)),
                            cfg.batch * m1,
                        )
                    }
                    ConvAlgo::WinogradFfip => {
                        if !wino_eligible(shape, *groups) {
                            anyhow::bail!(
                                "layer {:?}: the tuned plan selects the \
                                 Winograd F(2×2,3×3) lowering, but the \
                                 layer is not a 3×3 stride-1 conv with \
                                 even output dims",
                                layer.name()
                            );
                        }
                        let tiles = (shape.out_h() / 2) * (shape.out_w() / 2);
                        (Plan::Wino(*shape), cfg.batch * tiles)
                    }
                };
                (plan, Wire::Flat(ui), Wire::Flat(uo), m)
            }
            Layer::Residual { span, .. } => {
                let Some(cur) = prev_wire else {
                    anyhow::bail!(
                        "layer {:?}: a residual add cannot be the first \
                         layer (there is no earlier input to add)",
                        layer.name()
                    );
                };
                let Some(target) =
                    (*span >= 1).then(|| layers.len().checked_sub(*span)).flatten()
                else {
                    anyhow::bail!(
                        "layer {:?}: residual span {} does not reach an \
                         earlier layer (this is executable layer {})",
                        layer.name(),
                        span,
                        layers.len()
                    );
                };
                let (t_in, _) = wires[target];
                if t_in != cur {
                    anyhow::bail!(
                        "layer chain broken at {:?}: the residual input \
                         ({}) does not match the input of layer {:?} a \
                         span of {span} earlier ({})",
                        layer.name(),
                        cur.describe(),
                        layers[target].name,
                        t_in.describe()
                    );
                }
                // the sum saturates back into the activation domain of
                // the nearest preceding quantized (post-GEMM) layer, so
                // residual outputs stay storable at every width
                let Some(bits) = layers
                    .iter()
                    .rev()
                    .find_map(|l| l.post.as_ref().map(|p| p.scheme.spec.w))
                else {
                    anyhow::bail!(
                        "layer {:?}: residual add needs a preceding \
                         post-GEMM quantized domain to saturate into \
                         (every earlier layer streams raw accumulators)",
                        layer.name()
                    );
                };
                let ragged = matches!(cur, Wire::Ragged { .. });
                let plan = Plan::Residual { span: *span, bits, ragged };
                (plan, cur, cur, cfg.batch)
            }
            Layer::Attention {
                heads, d_model, d_head, max_seq, causal, ..
            } => {
                let (heads, d_model, d_head, max_seq, causal) =
                    (*heads, *d_model, *d_head, *max_seq, *causal);
                if heads < 1 {
                    anyhow::bail!(
                        "layer {:?}: attention needs >= 1 heads",
                        layer.name()
                    );
                }
                if d_head < 2 || d_head % 2 != 0 {
                    anyhow::bail!(
                        "layer {:?}: d_head must be even and >= 2 (the \
                         per-head QKᵀ GEMM depth under the fast \
                         algorithms), got {d_head}",
                        layer.name()
                    );
                }
                if heads * d_head != d_model {
                    anyhow::bail!(
                        "layer {:?}: heads * d_head = {} does not equal \
                         d_model = {d_model}",
                        layer.name(),
                        heads * d_head
                    );
                }
                if max_seq < 1 {
                    anyhow::bail!(
                        "layer {:?}: max_seq must be >= 1",
                        layer.name()
                    );
                }
                // m: the projection GEMM over all stacked tokens of a
                // full batch (the worst case the session buffers for)
                (
                    Plan::Attn { heads, d_model, d_head, max_seq, causal },
                    Wire::Ragged { max_seq, d: d_model },
                    Wire::Ragged { max_seq, d: d_model },
                    cfg.batch * max_seq,
                )
            }
            other => anyhow::bail!(
                "layer {:?}: this layer kind is analysis-only; the \
                 serving path executes FC, dense conv, attention and \
                 residual layers",
                other.name()
            ),
        };
        if let Some(prev) = prev_wire {
            if prev != wire_in {
                anyhow::bail!(
                    "layer chain broken at {:?}: the previous layer \
                     emits {}, this one consumes {}",
                    layer.name(),
                    prev.describe(),
                    wire_in.describe()
                );
            }
        }
        let (in_len, out_len) = (wire_in.len(), wire_out.len());
        // residual layers carry no weights and run no GEMM: record the
        // contract, mark the spanned-back layer to save its input, done
        if let Plan::Residual { span, bits, ragged } = lplan {
            let target = layers.len() - span;
            layers[target].save_input = true;
            // a degenerate-but-valid tile: nothing stages against it
            let gemm = GemmShape::new(cfg.batch, 2, 1);
            let tile = plan_tile(gemm, algo, cfg.x, cfg.y);
            layers.push(CompiledLayer {
                name: layer.name().to_string(),
                algo,
                gemm,
                tile,
                in_len,
                out_len,
                weights: Arc::new(Mat::zeros(0, 0)),
                y: None,
                post: None,
                exec: LayerExec::Residual { span, bits, ragged },
                save_input: false,
                abft: None,
            });
            wires.push((wire_in, wire_out));
            continue;
        }
        let lw = model.weights[idx].as_ref().with_context(|| {
            format!("layer {:?} has no weights bound", layer.name())
        })?;
        let (k, n) = (lw.w.rows, lw.w.cols);
        let w: Mat<E> = lw.w.narrow().with_context(|| {
            format!(
                "layer {:?}: weight values exceed the {} storage range",
                layer.name(),
                E::NAME
            )
        })?;
        let (gemm, tile, y, exec) = match lplan {
            Plan::Fc => {
                let gemm = GemmShape::new(m, k, n);
                let tile = plan_tile(gemm, algo, cfg.x, cfg.y);
                let y = (algo == Algo::Ffip)
                    .then(|| Arc::new(y_from_b(&w, tile.y)));
                (gemm, tile, y, LayerExec::Fc)
            }
            Plan::TokenFc { max_seq } => {
                // same stationary operand as a plain FC (the offline y
                // precomputes as usual); only the A-staging differs
                let gemm = GemmShape::new(m, k, n);
                let tile = plan_tile(gemm, algo, cfg.x, cfg.y);
                let y = (algo == Algo::Ffip)
                    .then(|| Arc::new(y_from_b(&w, tile.y)));
                (gemm, tile, y, LayerExec::TokenFc { max_seq })
            }
            Plan::Residual { .. } => unreachable!("lowered above"),
            Plan::Conv(ig) => {
                let gemm = GemmShape::new(m, k, n);
                let tile = plan_tile(gemm, algo, cfg.x, cfg.y);
                let y = (algo == Algo::Ffip)
                    .then(|| Arc::new(y_from_b(&w, tile.y)));
                (gemm, tile, y, LayerExec::Conv { ig })
            }
            Plan::Wino(shape) => {
                let (th, tw) = (shape.out_h() / 2, shape.out_w() / 2);
                // 16 elementwise-stage GEMMs of batch·tiles × cin × cout
                let gemm = GemmShape {
                    m,
                    k: shape.cin,
                    n: shape.cout,
                    count: 16,
                    stream_factor: 1.0,
                };
                let tile = plan_tile(gemm, algo, cfg.x, cfg.y);
                // transform the stationary weights once: for each
                // (cin, cout) pair, lift the 3×3 kernel (im2col row
                // layout (kh*3+kw)*cin + c) into the 16 ×4-scaled
                // Winograd-domain operands U^{(i,j)} = (G g Gᵀ)_{ij}
                let mut umats: Vec<Mat<E::Wide>> =
                    (0..16).map(|_| Mat::zeros(shape.cin, shape.cout)).collect();
                for c in 0..shape.cin {
                    for co in 0..shape.cout {
                        let mut gm = [[<E::Acc>::default(); 3]; 3];
                        for (ki, row) in gm.iter_mut().enumerate() {
                            for (kj, v) in row.iter_mut().enumerate() {
                                let r = (ki * 3 + kj) * shape.cin + c;
                                *v = w.data[r * shape.cout + co].acc();
                            }
                        }
                        let ut = weight_transform(&gm);
                        for (i, row) in ut.iter().enumerate() {
                            for (j, &v) in row.iter().enumerate() {
                                umats[i * 4 + j].data[c * shape.cout + co] =
                                    to_wide::<E>(v);
                            }
                        }
                    }
                }
                let u: Vec<Arc<Mat<E::Wide>>> =
                    umats.into_iter().map(Arc::new).collect();
                let yu = u
                    .iter()
                    .map(|um| {
                        (algo == Algo::Ffip)
                            .then(|| Arc::new(y_from_b(um.as_ref(), tile.y)))
                    })
                    .collect();
                let exec = LayerExec::WinoConv(Box::new(WinoExec {
                    shape,
                    th,
                    tw,
                    u,
                    yu,
                    tile,
                }));
                (gemm, tile, None, exec)
            }
            Plan::Attn { heads, d_model, d_head, max_seq, causal } => {
                let post = lw.post.as_ref().with_context(|| {
                    format!(
                        "layer {:?}: attention needs a post-GEMM stage \
                         (softmax and the projection requantization run \
                         in its quantized domain)",
                        layer.name()
                    )
                })?;
                let aw = post.scheme.spec.w;
                if !(2..=30).contains(&aw) {
                    anyhow::bail!(
                        "layer {:?}: attention requantizes to {aw} bits, \
                         outside the softmax unit's 2..=30-bit domain",
                        layer.name()
                    );
                }
                // reported GEMM: the token-stacked projection
                let gemm = GemmShape::new(m, d_model, d_model);
                let proj_tile = plan_tile(gemm, algo, cfg.x, cfg.y);
                let qk_tile = plan_tile(
                    GemmShape::new(max_seq, d_head, max_seq),
                    algo,
                    cfg.x,
                    cfg.y,
                );
                let av_tile = plan_tile(
                    GemmShape::new(max_seq, round_up(max_seq, 2), d_head),
                    algo,
                    cfg.x,
                    cfg.y,
                );
                let split = |seg: usize| {
                    Arc::new(w.tile(0, seg * d_model, d_model, d_model))
                };
                let (wq, wk, wv, wo) =
                    (split(0), split(1), split(2), split(3));
                let offline = |p: &Arc<Mat<E>>| {
                    (algo == Algo::Ffip)
                        .then(|| Arc::new(y_from_b(p.as_ref(), proj_tile.y)))
                };
                let softmax = SoftmaxSpec::for_attention(aw, d_head);
                // probabilities sum to softmax.one, so dividing the AV
                // accumulators by it yields the weighted average of V
                // back in the w-bit activation domain
                let av_scheme = QuantScheme {
                    spec: FixedSpec::signed(aw),
                    zero_b: 0,
                    requant: 1.0 / softmax.one as f32,
                };
                let exec = LayerExec::Attention(Box::new(AttnExec {
                    heads,
                    d_model,
                    d_head,
                    max_seq,
                    causal,
                    yq: offline(&wq),
                    yk: offline(&wk),
                    yv: offline(&wv),
                    yo: offline(&wo),
                    wq,
                    wk,
                    wv,
                    wo,
                    proj_tile,
                    qk_tile,
                    av_tile,
                    softmax,
                    av_scheme,
                }));
                (gemm, proj_tile, None, exec)
            }
        };
        // ABFT checksums cover the layers whose stationary weights ARE
        // the served GEMM's B operand; Winograd runs its 16 GEMMs over
        // transformed wide-domain operands and attention's QKᵀ/AV
        // multiply per-request activations, so both stay unchecked
        // (their projections still verify end to end through the
        // engine differential tests).
        // (wide i64 oracle storage skips the headroom gate the same way
        // it skips the accumulator guard — its 64-bit magnitudes are
        // not representable in the u128 worst-case arithmetic — and
        // verification runs in i128 regardless)
        let abft = (cfg.abft
            && matches!(
                exec,
                LayerExec::Fc
                    | LayerExec::TokenFc { .. }
                    | LayerExec::Conv { .. }
            )
            && (!E::GUARDED
                || abft_fits::<E>(
                    &FixedSpec::signed(E::BITS),
                    algo,
                    tile.x,
                    w.rows,
                    w.cols,
                )))
        .then(|| AbftCheck::build(&w, algo, tile));
        layers.push(CompiledLayer {
            name: layer.name().to_string(),
            algo,
            gemm,
            tile,
            in_len,
            out_len,
            weights: Arc::new(w),
            y,
            post: lw.post.clone(),
            exec,
            save_input: false,
            abft,
        });
        wires.push((wire_in, wire_out));
    }
    if layers.is_empty() {
        anyhow::bail!("{}: no executable layers", model.graph.name);
    }
    let input_len = layers[0].in_len;
    let output_len = layers[layers.len() - 1].out_len;
    Ok(TypedModel {
        name: model.graph.name.clone(),
        cfg,
        layers,
        input_len,
        output_len,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::models;

    #[test]
    fn mlp_compiles_with_planned_tiles_and_offline_y() {
        let model = Model::random(models::mlp(&[16, 12, 8]), 1, 4);
        let c = model
            .compile(DeployConfig::new(Algo::Ffip).with_tile(8, 4).with_batch(2))
            .unwrap();
        assert_eq!(c.num_layers(), 2);
        assert_eq!((c.input_len(), c.output_len()), (16, 8));
        // raw-accumulator layers (no post) force wide storage
        assert_eq!(c.storage(), ElemKind::I64);
        for l in c.layers() {
            assert_eq!(l.gemm.m, 2, "{}: m = batch", l.name);
            assert_eq!((l.tile.x, l.tile.y), (8, 4));
            let y = l.offline_y_dims.expect("FFIP precomputes y");
            assert_eq!(y, l.weight_dims);
        }
        // non-FFIP deployments carry no y terms
        let base = model
            .compile(DeployConfig::new(Algo::Baseline).with_tile(8, 4))
            .unwrap();
        assert!(base.layers().iter().all(|l| l.offline_y_dims.is_none()));
    }

    /// The tentpole storage rule: a fully requantized 8-bit model
    /// compiles to i8 storage automatically; 12-bit schemes land on
    /// i16; forcing an infeasible width is a compile error.
    #[test]
    fn auto_storage_picks_narrowest_legal_width() {
        let mut model = Model::random(models::mlp(&[16, 12, 8]), 2, 4);
        for (idx, cout) in [12usize, 8].into_iter().enumerate() {
            model
                .set_post(
                    idx,
                    PostGemm {
                        bias: vec![0; cout],
                        scheme: QuantScheme::symmetric_signed(8, 0.25),
                        relu: false,
                    },
                )
                .unwrap();
        }
        let cfg = DeployConfig::new(Algo::Ffip).with_tile(8, 4).with_batch(2);
        let c = model.compile(cfg).unwrap();
        assert_eq!(c.storage(), ElemKind::I8);
        // an i8 model moves 1/8 the stationary-weight bytes of the
        // forced-wide compilation (y rides at 2 bytes vs 8)
        let wide = model
            .compile(cfg.with_storage(Storage::I64))
            .unwrap();
        assert!(
            c.stationary_bytes() * 4 < wide.stationary_bytes(),
            "{} vs {}",
            c.stationary_bytes(),
            wide.stationary_bytes()
        );

        // a 12-bit scheme no longer fits i8 storage
        model
            .set_post(
                0,
                PostGemm {
                    bias: vec![0; 12],
                    scheme: QuantScheme::symmetric_signed(12, 0.25),
                    relu: false,
                },
            )
            .unwrap();
        let c = model.compile(cfg).unwrap();
        assert_eq!(c.storage(), ElemKind::I16);
        // forcing i8 now fails loudly at compile time
        let err = model
            .compile(cfg.with_storage(Storage::I8))
            .unwrap_err();
        assert!(err.to_string().contains("i8 storage"), "{err:#}");
    }

    #[test]
    fn wide_weights_refuse_narrow_storage() {
        // 12-bit weights cannot narrow to i8 even with an 8-bit scheme
        let mut model = Model::random(models::mlp(&[8, 4]), 3, 12);
        model
            .set_post(
                0,
                PostGemm {
                    bias: vec![0; 4],
                    scheme: QuantScheme::symmetric_signed(8, 0.25),
                    relu: false,
                },
            )
            .unwrap();
        let cfg = DeployConfig::new(Algo::Baseline).with_tile(4, 4);
        let c = model.compile(cfg).unwrap();
        assert_eq!(c.storage(), ElemKind::I16, "weights force i16");
        let err =
            model.compile(cfg.with_storage(Storage::I8)).unwrap_err();
        assert!(err.to_string().contains("range"), "{err:#}");
    }

    #[test]
    fn conv_layers_lower_through_im2gemm_dims() {
        let g = Graph {
            name: "conv2".into(),
            layers: vec![Layer::Conv {
                name: "c1".into(),
                shape: crate::memory::ConvShape {
                    h: 8,
                    w: 8,
                    cin: 3,
                    cout: 5,
                    kh: 3,
                    kw: 3,
                    stride: 1,
                    pad: 1,
                },
                groups: 1,
            }],
        };
        let model = Model::random(g, 2, 4);
        let c = model
            .compile(DeployConfig::new(Algo::Ffip).with_tile(8, 4).with_batch(3))
            .unwrap();
        let l = c.layer(0).unwrap();
        // M = batch * OH*OW, K = kh*kw*cin, N = cout
        assert_eq!((l.gemm.m, l.gemm.k, l.gemm.n), (3 * 64, 27, 5));
        assert_eq!((l.in_len, l.out_len), (8 * 8 * 3, 8 * 8 * 5));
    }

    #[test]
    fn compile_rejects_bad_configs_gracefully() {
        let model = Model::random(models::mlp(&[8, 8]), 3, 4);
        // odd tile depth
        let err = model
            .compile(DeployConfig::new(Algo::Ffip).with_tile(3, 4))
            .unwrap_err();
        assert!(err.to_string().contains("even"), "{err:#}");
        // unsupported layer kind
        let pooled = Model::random(
            Graph {
                name: "p".into(),
                layers: vec![Layer::Pool {
                    name: "pool".into(),
                    size: 2,
                    stride: 2,
                }],
            },
            4,
            4,
        );
        let err = pooled
            .compile(DeployConfig::new(Algo::Ffip).with_tile(8, 4))
            .unwrap_err();
        assert!(err.to_string().contains("analysis-only"), "{err:#}");
    }

    /// The scheduler knobs validate at compile time: zero replicas and
    /// a zero admission bound are deploy-time errors, never a stalled
    /// or everything-shedding deployment.
    #[test]
    fn scheduler_knobs_validate_at_compile() {
        let model = Model::random(models::mlp(&[8, 4]), 6, 4);
        let base = DeployConfig::new(Algo::Ffip).with_tile(4, 2);
        assert_eq!(base.replicas, 1, "default: one replica");
        assert_eq!(base.max_queue_depth, usize::MAX, "default: unbounded");
        assert!(base.pipeline, "default: overlapped staging on");
        let err =
            model.compile(base.with_replicas(0)).unwrap_err();
        assert!(err.to_string().contains("replicas"), "{err:#}");
        let err =
            model.compile(base.with_max_queue_depth(0)).unwrap_err();
        assert!(err.to_string().contains("max_queue_depth"), "{err:#}");
        // the fluent knobs land in the compiled config
        let c = model
            .compile(
                base.with_replicas(3)
                    .with_max_queue_depth(32)
                    .with_pipeline(false),
            )
            .unwrap();
        assert_eq!(c.cfg().replicas, 3);
        assert_eq!(c.cfg().max_queue_depth, 32);
        assert!(!c.cfg().pipeline);
        assert_eq!(c.cfg().admission().max_queue_depth, 32);
    }

    fn attention_graph(
        heads: usize,
        d_model: usize,
        d_head: usize,
        max_seq: usize,
    ) -> Graph {
        Graph {
            name: "attn".into(),
            layers: vec![Layer::Attention {
                name: "mha".into(),
                heads,
                d_model,
                d_head,
                max_seq,
                causal: false,
            }],
        }
    }

    #[test]
    fn attention_lowers_to_split_projections_with_offline_y() {
        let mut model = Model::random(attention_graph(2, 8, 4, 6), 7, 4);
        model
            .set_post(
                0,
                PostGemm {
                    bias: vec![0; 32],
                    scheme: QuantScheme::symmetric_signed(8, 1.0 / 16.0),
                    relu: false,
                },
            )
            .unwrap();
        let c = model
            .compile(DeployConfig::new(Algo::Ffip).with_tile(4, 4).with_batch(2))
            .unwrap();
        // 8-bit schemes, tiny max_seq: the narrowest width serves
        assert_eq!(c.storage(), ElemKind::I8);
        let l = c.layer(0).unwrap();
        // ragged rows carry the in-band length prefix
        assert_eq!((l.in_len, l.out_len), (1 + 6 * 8, 1 + 6 * 8));
        // packed [Wq|Wk|Wv|Wo] stationary operand
        assert_eq!(l.weight_dims, (8, 32));
        // reported GEMM: the token-stacked projection (m = batch * max_seq)
        assert_eq!((l.gemm.m, l.gemm.k, l.gemm.n), (12, 8, 8));
        // stationary traffic: i8 packed weights + four i16 offline
        // projection y terms (the online QKᵀ/AV y terms are activations)
        assert_eq!(l.stationary_bytes, 8 * 32 + 4 * 8 * 8 * 2);
        // Baseline carries no offline y at all
        let base = model
            .compile(DeployConfig::new(Algo::Baseline).with_tile(4, 4))
            .unwrap();
        assert_eq!(base.layer(0).unwrap().stationary_bytes, 8 * 32);
    }

    #[test]
    fn attention_validations_fail_loudly() {
        let cfg = DeployConfig::new(Algo::Ffip).with_tile(4, 4).with_batch(1);
        // odd d_head breaks the fast-algorithm QKᵀ depth
        let err = Model::random(attention_graph(2, 6, 3, 4), 1, 4)
            .compile(cfg)
            .unwrap_err();
        assert!(err.to_string().contains("even"), "{err:#}");
        // heads * d_head must tile d_model
        let err = Model::random(attention_graph(3, 8, 4, 4), 1, 4)
            .compile(cfg)
            .unwrap_err();
        assert!(err.to_string().contains("d_model"), "{err:#}");
        // attention cannot stream raw accumulators: softmax needs the
        // quantized activation domain
        let err = Model::random(attention_graph(2, 8, 4, 4), 1, 4)
            .compile(cfg)
            .unwrap_err();
        assert!(err.to_string().contains("post-GEMM"), "{err:#}");
    }

    /// The ragged length prefix rides in-band, so `max_seq` itself must
    /// fit the storage element: a 200-token model escalates past i8
    /// automatically.
    #[test]
    fn attention_prefix_escalates_auto_storage() {
        let mut model = Model::random(attention_graph(2, 8, 4, 200), 9, 4);
        model
            .set_post(
                0,
                PostGemm {
                    bias: vec![0; 32],
                    scheme: QuantScheme::symmetric_signed(8, 1.0 / 16.0),
                    relu: false,
                },
            )
            .unwrap();
        let cfg = DeployConfig::new(Algo::Ffip).with_tile(4, 4);
        let c = model.compile(cfg).unwrap();
        assert_eq!(c.storage(), ElemKind::I16, "prefix 200 outgrows i8");
        let err = model.compile(cfg.with_storage(Storage::I8)).unwrap_err();
        assert!(err.to_string().contains("length prefix"), "{err:#}");
    }

    #[test]
    fn broken_layer_chain_is_a_compile_error() {
        // fc 8->4 followed by fc 6->2: 4 != 6
        let g = Graph {
            name: "broken".into(),
            layers: vec![
                Layer::Fc { name: "a".into(), cin: 8, cout: 4 },
                Layer::Fc { name: "b".into(), cin: 6, cout: 2 },
            ],
        };
        let err = Model::random(g, 5, 4)
            .compile(DeployConfig::new(Algo::Baseline).with_tile(8, 4))
            .unwrap_err();
        assert!(err.to_string().contains("chain"), "{err:#}");
    }

    /// The tentpole I/O contract: `models::transformer` — causal
    /// attention + MLP with residual adds over the ragged wire format —
    /// compiles end-to-end.  The block-interior FCs lower
    /// token-parallel and the residual layers span back to same-shaped
    /// inputs.
    #[test]
    fn transformer_blocks_compile_end_to_end() {
        let (seq, dim, heads, blocks) = (4usize, 8usize, 2usize, 2usize);
        let mut model = Model::random(
            models::transformer(seq, dim, heads, blocks),
            11,
            4,
        );
        let post = |n: usize, relu: bool| PostGemm {
            bias: vec![0; n],
            scheme: QuantScheme::symmetric_signed(8, 1.0 / 32.0),
            relu,
        };
        // per block: [attn, res, mlp_up, mlp_down, res]
        for b in 0..blocks {
            model.set_post(5 * b, post(4 * dim, false)).unwrap();
            model.set_post(5 * b + 2, post(4 * dim, true)).unwrap();
            model.set_post(5 * b + 3, post(dim, false)).unwrap();
        }
        let c = model
            .compile(
                DeployConfig::new(Algo::Ffip).with_tile(4, 4).with_batch(2),
            )
            .unwrap();
        assert_eq!(c.storage(), ElemKind::I8);
        assert_eq!(c.num_layers(), 5 * blocks);
        // the model speaks the ragged wire format end to end
        let row = 1 + seq * dim;
        assert_eq!((c.input_len(), c.output_len()), (row, row));
        // the MLP-up FC lowered token-parallel: m = batch * max_seq,
        // ragged in/out rows, offline y precomputed as usual
        let up = c.layer(2).unwrap();
        assert_eq!(
            (up.gemm.m, up.gemm.k, up.gemm.n),
            (2 * seq, dim, 4 * dim)
        );
        assert_eq!((up.in_len, up.out_len), (row, 1 + seq * 4 * dim));
        assert_eq!(up.offline_y_dims, Some((dim, 4 * dim)));
        // residual layers carry no stationary operand
        assert_eq!(c.layer(1).unwrap().stationary_bytes, 0);
        assert_eq!(c.layer(4).unwrap().in_len, row);
    }

    #[test]
    fn residual_validations_fail_loudly() {
        let cfg = DeployConfig::new(Algo::Baseline).with_tile(4, 4);
        let residual = |span: usize| Layer::Residual {
            name: "r".into(),
            span,
        };
        // a residual cannot be the first layer
        let g = Graph { name: "r0".into(), layers: vec![residual(1)] };
        let err = Model::random(g, 1, 4).compile(cfg).unwrap_err();
        assert!(err.to_string().contains("first"), "{err:#}");
        let fc = |name: &str, cin: usize, cout: usize| Layer::Fc {
            name: name.into(),
            cin,
            cout,
        };
        // span reaching past the start of the chain
        let g = Graph {
            name: "r1".into(),
            layers: vec![fc("a", 8, 8), residual(2)],
        };
        let err = Model::random(g, 2, 4).compile(cfg).unwrap_err();
        assert!(err.to_string().contains("span"), "{err:#}");
        // the spanned-back input must match the residual's own input
        let g = Graph {
            name: "r2".into(),
            layers: vec![fc("a", 8, 4), residual(1)],
        };
        let err = Model::random(g, 3, 4).compile(cfg).unwrap_err();
        assert!(err.to_string().contains("chain"), "{err:#}");
        // raw-accumulator chains give the residual no domain to clamp to
        let g = Graph {
            name: "r3".into(),
            layers: vec![fc("a", 8, 8), residual(1)],
        };
        let err = Model::random(g, 4, 4).compile(cfg).unwrap_err();
        assert!(err.to_string().contains("post-GEMM"), "{err:#}");
    }

    /// The decode knobs validate at compile time and land in the
    /// decode-subsystem admission config; the request-path admission
    /// config stays KV-unbounded.
    #[test]
    fn decode_knobs_validate_and_map_to_admission() {
        let model = Model::random(models::mlp(&[8, 4]), 7, 4);
        let base = DeployConfig::new(Algo::Ffip).with_tile(4, 2);
        let err =
            model.compile(base.with_max_active_seqs(0)).unwrap_err();
        assert!(err.to_string().contains("max_active_seqs"), "{err:#}");
        let err = model.compile(base.with_max_kv_bytes(0)).unwrap_err();
        assert!(err.to_string().contains("max_kv_bytes"), "{err:#}");
        let cfg = base.with_max_active_seqs(4).with_max_kv_bytes(1 << 20);
        let d = cfg.decode_admission();
        assert_eq!(d.max_queue_depth, 4);
        assert_eq!(d.max_kv_bytes, 1 << 20);
        assert_eq!(cfg.admission().max_kv_bytes, usize::MAX);
    }

    #[test]
    fn model_new_checks_weight_dims() {
        let g = models::mlp(&[4, 3]);
        let bad = vec![Some(LayerWeights {
            w: Mat::zeros(5, 3), // needs 4x3
            post: None,
        })];
        assert!(Model::new(g, bad).is_err());
    }
}
