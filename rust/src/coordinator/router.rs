//! Multi-model request router: the front door of the serving stack.
//!
//! A [`Router`] owns one [`Coordinator`] per deployed model and
//! dispatches requests by model name — the same leader-process shape as
//! production model servers (each model keeps its own batcher, so
//! batches never mix artifacts with different static shapes).  Routing
//! statistics feed capacity decisions (which model is hot, per-model
//! occupancy).
//!
//! A router built with [`Router::with_engine`] shares one persistent
//! [`GemmPool`] across every simulated-accelerator deployment
//! ([`Router::deploy_sim`]): model workers submit batch GEMMs to the
//! same worker pool instead of each spawning threads per call, which is
//! what lets many deployed models oversubscribe one machine gracefully
//! (pool/queue pressure is visible via [`Router::engine_stats`]).

use super::batcher::BatcherConfig;
use super::server::{Coordinator, SimBackend};
use super::Response;
use crate::algo::{Algo, Mat, TileShape};
use crate::engine::{GemmPool, PoolStats};
use std::collections::HashMap;
use std::sync::mpsc;
use std::sync::Arc;

/// Routing error.
#[derive(Debug)]
pub enum RouteError {
    UnknownModel(String, Vec<String>),
}

impl std::fmt::Display for RouteError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RouteError::UnknownModel(name, deployed) => {
                write!(f, "unknown model {name:?} (deployed: {deployed:?})")
            }
        }
    }
}

impl std::error::Error for RouteError {}

/// Dispatches requests to per-model coordinators.
pub struct Router {
    models: HashMap<String, Coordinator>,
    counts: HashMap<String, u64>,
    engine: Option<Arc<GemmPool>>,
}

impl Router {
    pub fn new() -> Self {
        Router {
            models: HashMap::new(),
            counts: HashMap::new(),
            engine: None,
        }
    }

    /// A router whose simulated-accelerator deployments share `engine`.
    pub fn with_engine(engine: Arc<GemmPool>) -> Self {
        Router {
            models: HashMap::new(),
            counts: HashMap::new(),
            engine: Some(engine),
        }
    }

    /// The shared execution engine, if this router owns one.
    pub fn engine(&self) -> Option<&Arc<GemmPool>> {
        self.engine.as_ref()
    }

    /// Counters of the shared engine (None for an engine-less router).
    pub fn engine_stats(&self) -> Option<PoolStats> {
        self.engine.as_ref().map(|p| p.stats())
    }

    /// Deploy a model under `name`.
    pub fn deploy(&mut self, name: &str, coordinator: Coordinator) {
        self.models.insert(name.to_string(), coordinator);
        self.counts.insert(name.to_string(), 0);
    }

    /// Deploy a simulated-accelerator GEMM model under `name`: one
    /// weight matrix served at `cfg.batch`, executing on the router's
    /// shared engine when present (serial fallback otherwise).
    ///
    /// Tile geometry is validated here so a bad config fails at deploy
    /// time with an error, not as a panic on the model's worker thread
    /// at its first request.
    pub fn deploy_sim(
        &mut self,
        name: &str,
        weights: Mat<i64>,
        algo: Algo,
        tile: TileShape,
        cfg: BatcherConfig,
    ) -> anyhow::Result<()> {
        if tile.x < 1 || tile.y < 1 || tile.tm < 1 {
            anyhow::bail!("model {name:?}: degenerate tile shape {tile:?}");
        }
        if algo.is_fast() && tile.x % 2 != 0 {
            anyhow::bail!(
                "model {name:?}: {} requires an even tile depth x, got {}",
                algo.name(),
                tile.x
            );
        }
        let engine = self.engine.clone();
        let batch = cfg.batch;
        let c = Coordinator::start(
            move || {
                Ok(match engine {
                    Some(pool) => SimBackend::with_engine(
                        weights, algo, tile, batch, pool,
                    ),
                    None => SimBackend::new(weights, algo, tile, batch),
                })
            },
            cfg,
        )?;
        self.deploy(name, c);
        Ok(())
    }

    pub fn deployed(&self) -> Vec<String> {
        let mut v: Vec<String> = self.models.keys().cloned().collect();
        v.sort();
        v
    }

    /// Route one request; returns the response channel.
    pub fn submit(
        &mut self,
        model: &str,
        input: Vec<i32>,
    ) -> Result<mpsc::Receiver<Response>, RouteError> {
        let c = self.models.get(model).ok_or_else(|| {
            RouteError::UnknownModel(model.to_string(), self.deployed())
        })?;
        *self.counts.get_mut(model).unwrap() += 1;
        Ok(c.submit(input))
    }

    /// Blocking route.
    pub fn infer(
        &mut self,
        model: &str,
        input: Vec<i32>,
    ) -> Result<Response, RouteError> {
        let rx = self.submit(model, input)?;
        Ok(rx.recv().expect("backend response"))
    }

    /// Requests routed per model.
    pub fn route_counts(&self) -> &HashMap<String, u64> {
        &self.counts
    }

    /// Snapshot of one deployed model's serving stats.
    pub fn model_stats(&self, name: &str) -> Option<super::ServeStats> {
        self.models.get(name).map(|c| c.stats.lock().unwrap().clone())
    }

    /// Undeploy (drains that model's worker).
    pub fn undeploy(&mut self, name: &str) -> bool {
        self.counts.remove(name);
        self.models.remove(name).is_some()
    }
}

impl Default for Router {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{BatcherConfig, EchoBackend};
    use std::time::Duration;

    fn echo(len: usize) -> Coordinator {
        Coordinator::start(
            move || Ok(EchoBackend { len, batch: 2 }),
            BatcherConfig { batch: 2, linger: Duration::from_millis(1) },
        )
        .unwrap()
    }

    #[test]
    fn routes_by_model_name() {
        let mut r = Router::new();
        r.deploy("small", echo(2));
        r.deploy("large", echo(4));
        let a = r.infer("small", vec![1, 2]).unwrap();
        assert_eq!(a.output, vec![2.0, 4.0]);
        let b = r.infer("large", vec![1, 2, 3, 4]).unwrap();
        assert_eq!(b.output.len(), 4);
        assert_eq!(r.route_counts()["small"], 1);
        assert_eq!(r.route_counts()["large"], 1);
    }

    #[test]
    fn unknown_model_is_an_error_listing_deployments() {
        let mut r = Router::new();
        r.deploy("only", echo(1));
        let err = r.infer("nope", vec![0]).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("nope") && msg.contains("only"), "{msg}");
    }

    #[test]
    fn undeploy_stops_routing() {
        let mut r = Router::new();
        r.deploy("m", echo(1));
        assert!(r.undeploy("m"));
        assert!(!r.undeploy("m"));
        assert!(r.infer("m", vec![0]).is_err());
    }

    #[test]
    fn sim_models_share_one_engine() {
        use crate::util::Rng;
        let mut rng = Rng::new(21);
        let w_a = crate::algo::Mat::from_fn(8, 6, |_, _| rng.fixed(8, true));
        let w_b = crate::algo::Mat::from_fn(4, 5, |_, _| rng.fixed(8, true));
        let pool = std::sync::Arc::new(crate::engine::GemmPool::new(2));
        let mut r = Router::with_engine(pool);
        let cfg = BatcherConfig { batch: 2, linger: Duration::from_millis(1) };
        let tile = crate::algo::TileShape::square(4, 2);
        r.deploy_sim("a", w_a.clone(), crate::algo::Algo::Ffip, tile, cfg)
            .unwrap();
        r.deploy_sim("b", w_b.clone(), crate::algo::Algo::Fip, tile, cfg)
            .unwrap();
        // route one request per model; outputs must match the direct GEMM
        let in_a: Vec<i32> = (0..8).map(|i| i - 4).collect();
        let in_b: Vec<i32> = (0..4).map(|i| 2 * i - 3).collect();
        let out_a = r.infer("a", in_a.clone()).unwrap().output;
        let out_b = r.infer("b", in_b.clone()).unwrap().output;
        let gold_a = crate::algo::baseline_matmul(
            &crate::algo::Mat::from_fn(1, 8, |_, j| i64::from(in_a[j])),
            &w_a,
        );
        let gold_b = crate::algo::baseline_matmul(
            &crate::algo::Mat::from_fn(1, 4, |_, j| i64::from(in_b[j])),
            &w_b,
        );
        let got_a: Vec<i64> = out_a.iter().map(|&v| v as i64).collect();
        let got_b: Vec<i64> = out_b.iter().map(|&v| v as i64).collect();
        assert_eq!(got_a, gold_a.data);
        assert_eq!(got_b, gold_b.data);
        // both deployments fed the same pool
        let s = r.engine_stats().expect("router owns an engine");
        assert!(s.jobs >= 2, "{s:?}");
        assert_eq!(s.workers, 2);
    }

    #[test]
    fn deploy_sim_rejects_odd_tile_depth_for_fast_algos() {
        let mut r = Router::new();
        let w = crate::algo::Mat::zeros(4, 4);
        let bad = crate::algo::TileShape { x: 3, y: 4, tm: 4 };
        let err = r
            .deploy_sim("bad", w, crate::algo::Algo::Ffip, bad, BatcherConfig::default())
            .unwrap_err();
        assert!(err.to_string().contains("even"), "{err:#}");
        assert!(r.deployed().is_empty());
    }

    #[test]
    fn per_model_batches_never_mix() {
        let mut r = Router::new();
        r.deploy("a", echo(2));
        r.deploy("b", echo(3));
        // interleave submissions; row lengths stay per-model consistent
        let rx1 = r.submit("a", vec![1, 1]).unwrap();
        let rx2 = r.submit("b", vec![2, 2, 2]).unwrap();
        let rx3 = r.submit("a", vec![3, 3]).unwrap();
        assert_eq!(rx1.recv().unwrap().output.len(), 2);
        assert_eq!(rx2.recv().unwrap().output.len(), 3);
        assert_eq!(rx3.recv().unwrap().output.len(), 2);
    }
}
