//! Multi-model request router: the front door of the serving stack.
//!
//! A [`Router`] owns one [`Coordinator`] per deployed model and
//! dispatches requests by model name — the same leader-process shape as
//! production model servers (each model keeps its own batcher, so
//! batches never mix artifacts with different static shapes).  Routing
//! statistics feed capacity decisions (which model is hot, per-model
//! occupancy, per-layer wall-time breakdowns).
//!
//! Models deploy through the unified pipeline: compile a
//! [`Model`](super::Model) to a
//! [`CompiledModel`](super::CompiledModel) (all geometry validated at
//! compile time), then [`Router::deploy_model`] spins up a replica set
//! of session workers (round-robin dispatch with
//! least-outstanding-work stealing; pipeline-overlapped staging by
//! default) executing the layers on the router's shared persistent
//! [`GemmPool`] ([`Router::with_engine`]) — many deployed models (and
//! many replicas per model) oversubscribe one machine gracefully
//! because every worker submits to the same pool (pressure is visible
//! via [`Router::engine_stats`]).  An engine-less router still serves
//! correctly: each deployment gets a private zero-worker pool that its
//! replica threads drain themselves.

use super::model::CompiledModel;
use super::scheduler::{PipelinedBackend, PipelinedSession};
use super::server::{Backend, Coordinator};
use super::session::{InferenceSession, SessionBackend};
use super::Response;
use crate::engine::{GemmPool, PoolStats};
use std::collections::HashMap;
use std::sync::mpsc;
use std::sync::Arc;

/// Routing error.
#[derive(Debug)]
pub enum RouteError {
    UnknownModel(String, Vec<String>),
}

impl std::fmt::Display for RouteError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RouteError::UnknownModel(name, deployed) => {
                write!(f, "unknown model {name:?} (deployed: {deployed:?})")
            }
        }
    }
}

impl std::error::Error for RouteError {}

/// Typed deployment failure from [`Router::deploy_model`].
#[derive(Debug)]
pub enum DeployError {
    /// The compiled model's stationary operand bytes
    /// ([`CompiledModel::stationary_bytes`]) exceed the deployment's
    /// capacity budget
    /// ([`DeployConfig::max_stationary_bytes`](super::DeployConfig)) —
    /// the deploy-time admission check standing in for a device's
    /// finite on-chip weight memory.
    CapacityExceeded {
        model: String,
        /// Stationary bytes the compiled model needs.
        need: usize,
        /// The configured budget it exceeded.
        budget: usize,
    },
    /// A replica worker failed to start.
    WorkerSpawn(anyhow::Error),
}

impl std::fmt::Display for DeployError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DeployError::CapacityExceeded { model, need, budget } => write!(
                f,
                "cannot deploy {model:?}: stationary operands need {need} \
                 bytes, capacity budget is {budget}"
            ),
            DeployError::WorkerSpawn(e) => {
                write!(f, "replica worker failed to start: {e}")
            }
        }
    }
}

impl std::error::Error for DeployError {}

/// Dispatches requests to per-model coordinators.
pub struct Router {
    models: HashMap<String, Coordinator>,
    counts: HashMap<String, u64>,
    engine: Option<Arc<GemmPool>>,
}

impl Router {
    pub fn new() -> Self {
        Router {
            models: HashMap::new(),
            counts: HashMap::new(),
            engine: None,
        }
    }

    /// A router whose deployments share `engine`.
    pub fn with_engine(engine: Arc<GemmPool>) -> Self {
        Router {
            models: HashMap::new(),
            counts: HashMap::new(),
            engine: Some(engine),
        }
    }

    /// The shared execution engine, if this router owns one.
    pub fn engine(&self) -> Option<&Arc<GemmPool>> {
        self.engine.as_ref()
    }

    /// Counters of the shared engine (None for an engine-less router).
    pub fn engine_stats(&self) -> Option<PoolStats> {
        self.engine.as_ref().map(|p| p.stats())
    }

    /// Deploy a model under `name` with an already-running coordinator
    /// (PJRT backends and tests use this directly).
    pub fn deploy(&mut self, name: &str, coordinator: Coordinator) {
        self.models.insert(name.to_string(), coordinator);
        self.counts.insert(name.to_string(), 0);
    }

    /// Deploy a compiled model under `name`: spawns
    /// [`DeployConfig::replicas`](super::DeployConfig) session-replica
    /// workers (compiled weights and offline FFIP y terms `Arc`-shared;
    /// each replica owns only its buffers) executing every layer on the
    /// router's shared engine (or a private caller-driven pool when the
    /// router has none), at the storage width the model compiled to
    /// (`i8` for a fully requantized int8 model).  Each replica runs
    /// the pipeline-overlapped executor
    /// ([`PipelinedSession`]) unless the config selected the sequential
    /// [`InferenceSession`]; admission is bounded at
    /// [`DeployConfig::max_queue_depth`](super::DeployConfig).  All
    /// geometry and storage legality was validated by
    /// [`compile`](super::compile); this fails only on the deploy-time
    /// capacity admission check
    /// ([`DeployError::CapacityExceeded`] when the compiled stationary
    /// operands exceed
    /// [`DeployConfig::max_stationary_bytes`](super::DeployConfig)) or
    /// if a worker cannot start.
    pub fn deploy_model(
        &mut self,
        name: &str,
        compiled: CompiledModel,
    ) -> Result<(), DeployError> {
        let engine = self
            .engine
            .clone()
            .unwrap_or_else(|| Arc::new(GemmPool::new(0)));
        let cfg = compiled.cfg();
        // robustness knobs ride the config onto the engine: a fault
        // plan arms deterministic injection (test-only), and a request
        // deadline doubles as the pool watchdog so a wedged GEMM
        // becomes a typed timeout instead of an infinite block
        if let Some(plan) = cfg.fault_plan {
            engine.install_fault_plan(plan);
        }
        if cfg.request_deadline.is_some() {
            engine.set_watchdog(cfg.request_deadline);
        }
        if let Some(budget) = cfg.max_stationary_bytes {
            let need = compiled.stationary_bytes();
            if need > budget {
                return Err(DeployError::CapacityExceeded {
                    model: name.to_string(),
                    need,
                    budget,
                });
            }
        }
        // one uniform boxed factory per replica; the executor choice is
        // a single branch inside it, so the spawn path cannot diverge
        // between the pipelined and sequential modes.  The factory is
        // re-invokable (`Fn`): the dispatcher re-runs it to respawn a
        // dead replica from this same Arc-shared compiled artifact.
        let factories: Vec<_> = (0..cfg.replicas)
            .map(|_| {
                let compiled = compiled.clone();
                let engine = engine.clone();
                move || -> anyhow::Result<Box<dyn Backend>> {
                    Ok(if cfg.pipeline {
                        Box::new(PipelinedBackend::new(
                            PipelinedSession::new(&compiled, engine.clone()),
                        ))
                    } else {
                        Box::new(SessionBackend::new(
                            InferenceSession::new(&compiled, engine.clone()),
                        ))
                    })
                }
            })
            .collect();
        let c = Coordinator::start_replicated(
            factories,
            cfg.batcher(),
            cfg.admission(),
        )
        .map_err(DeployError::WorkerSpawn)?;
        self.deploy(name, c);
        Ok(())
    }

    pub fn deployed(&self) -> Vec<String> {
        let mut v: Vec<String> = self.models.keys().cloned().collect();
        v.sort();
        v
    }

    /// Route one request; returns the response channel.
    pub fn submit(
        &mut self,
        model: &str,
        input: Vec<i32>,
    ) -> Result<mpsc::Receiver<Response>, RouteError> {
        let c = self.models.get(model).ok_or_else(|| {
            RouteError::UnknownModel(model.to_string(), self.deployed())
        })?;
        *self.counts.get_mut(model).unwrap() += 1;
        Ok(c.submit(input))
    }

    /// Blocking route.
    pub fn infer(
        &mut self,
        model: &str,
        input: Vec<i32>,
    ) -> Result<Response, RouteError> {
        let rx = self.submit(model, input)?;
        Ok(rx.recv().expect("backend response"))
    }

    /// Requests routed per model.
    pub fn route_counts(&self) -> &HashMap<String, u64> {
        &self.counts
    }

    /// Snapshot of one deployed model's serving stats (all replicas
    /// merged, with the per-replica breakdown attached).
    pub fn model_stats(&self, name: &str) -> Option<super::ServeStats> {
        self.models.get(name).map(Coordinator::stats)
    }

    /// Undeploy: drains and joins **every** replica worker of the
    /// model's deployment (queued requests are served, not dropped),
    /// removes its routing counters, and returns the final merged
    /// serving stats — per-replica layer stats are summed by name, so
    /// the breakdown is correct even when work stealing left replicas
    /// with different batch counts (`None` when no such model was
    /// deployed).  The name is immediately free for redeployment.
    pub fn undeploy(&mut self, name: &str) -> Option<super::ServeStats> {
        self.counts.remove(name);
        self.models.remove(name).map(Coordinator::shutdown)
    }
}

impl Default for Router {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::Algo;
    use crate::coordinator::{BatcherConfig, DeployConfig, EchoBackend, Model};
    use crate::nn::models;
    use std::time::Duration;

    fn echo(len: usize) -> Coordinator {
        Coordinator::start(
            move || Ok(EchoBackend { len, batch: 2 }),
            BatcherConfig { batch: 2, linger: Duration::from_millis(1) },
        )
        .unwrap()
    }

    /// A compiled single-FC model (the smallest deployable unit).
    fn fc_model(seed: u64, k: usize, n: usize, algo: Algo) -> (Model, DeployConfig) {
        let model = Model::random(models::mlp(&[k, n]), seed, 8);
        let cfg = DeployConfig::new(algo)
            .with_tile(4, 2)
            .with_batch(2)
            .with_linger(Duration::from_millis(1));
        (model, cfg)
    }

    #[test]
    fn routes_by_model_name() {
        let mut r = Router::new();
        r.deploy("small", echo(2));
        r.deploy("large", echo(4));
        let a = r.infer("small", vec![1, 2]).unwrap();
        assert_eq!(a.output().data, vec![2.0, 4.0]);
        let b = r.infer("large", vec![1, 2, 3, 4]).unwrap();
        assert_eq!(b.output().data.len(), 4);
        assert_eq!(r.route_counts()["small"], 1);
        assert_eq!(r.route_counts()["large"], 1);
    }

    #[test]
    fn unknown_model_is_an_error_listing_deployments() {
        let mut r = Router::new();
        r.deploy("only", echo(1));
        let err = r.infer("nope", vec![0]).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("nope") && msg.contains("only"), "{msg}");
    }

    #[test]
    fn undeploy_drains_and_frees_the_name_for_redeploy() {
        let mut r = Router::new();
        let (model, cfg) = fc_model(3, 8, 4, Algo::Ffip);
        r.deploy_model("m", model.compile(cfg).unwrap()).unwrap();
        let out1 =
            r.infer("m", (0..8).map(|i| i - 4).collect()).unwrap().output();
        // undeploy joins the worker and hands back its final stats
        let stats = r.undeploy("m").expect("was deployed");
        assert_eq!(stats.count(), 1);
        assert!(r.undeploy("m").is_none());
        assert!(r.infer("m", vec![0; 8]).is_err());
        assert!(r.route_counts().is_empty(), "counters removed");
        // redeploy under the same name and serve again
        r.deploy_model("m", model.compile(cfg).unwrap()).unwrap();
        let out2 =
            r.infer("m", (0..8).map(|i| i - 4).collect()).unwrap().output();
        assert_eq!(out1, out2, "same weights, same answer");
        assert_eq!(r.route_counts()["m"], 1);
    }

    #[test]
    fn deployed_models_share_one_engine() {
        let pool = std::sync::Arc::new(crate::engine::GemmPool::new(2));
        let mut r = Router::with_engine(pool);
        let (ma, cfg_a) = fc_model(21, 8, 6, Algo::Ffip);
        let (mb, cfg_b) = fc_model(22, 4, 5, Algo::Fip);
        r.deploy_model("a", ma.compile(cfg_a).unwrap()).unwrap();
        r.deploy_model("b", mb.compile(cfg_b).unwrap()).unwrap();
        // route one request per model; outputs must match the direct GEMM
        let in_a: Vec<i32> = (0..8).map(|i| i - 4).collect();
        let in_b: Vec<i32> = (0..4).map(|i| 2 * i - 3).collect();
        let out_a = r.infer("a", in_a.clone()).unwrap().output();
        let out_b = r.infer("b", in_b.clone()).unwrap().output();
        let gold_a = crate::algo::baseline_matmul(
            &crate::algo::Mat::from_fn(1, 8, |_, j| i64::from(in_a[j])),
            &ma.layer_weights(0).unwrap().w,
        );
        let gold_b = crate::algo::baseline_matmul(
            &crate::algo::Mat::from_fn(1, 4, |_, j| i64::from(in_b[j])),
            &mb.layer_weights(0).unwrap().w,
        );
        let got_a: Vec<i64> =
            out_a.data.iter().map(|&v| v as i64).collect();
        let got_b: Vec<i64> =
            out_b.data.iter().map(|&v| v as i64).collect();
        assert_eq!(got_a, gold_a.data);
        assert_eq!(got_b, gold_b.data);
        // both deployments fed the same pool
        let s = r.engine_stats().expect("router owns an engine");
        assert!(s.jobs >= 2, "{s:?}");
        assert_eq!(s.workers, 2);
    }

    #[test]
    fn engineless_router_still_serves_compiled_models() {
        let mut r = Router::new();
        let (model, cfg) = fc_model(31, 6, 3, Algo::Baseline);
        r.deploy_model("solo", model.compile(cfg).unwrap()).unwrap();
        let input: Vec<i32> = (0..6).map(|i| i + 1).collect();
        let out = r.infer("solo", input.clone()).unwrap().output();
        let gold = crate::algo::baseline_matmul(
            &crate::algo::Mat::from_fn(1, 6, |_, j| i64::from(input[j])),
            &model.layer_weights(0).unwrap().w,
        );
        let got: Vec<i64> = out.data.iter().map(|&v| v as i64).collect();
        assert_eq!(got, gold.data);
        assert!(r.engine_stats().is_none(), "no shared engine");
    }

    /// The replica-sharded undeploy: all replicas drain before the
    /// final stats come back, and per-replica layer stats merge by
    /// name even when the replicas served different batch counts.
    #[test]
    fn undeploy_drains_all_replicas_and_merges_layer_stats() {
        let pool = std::sync::Arc::new(crate::engine::GemmPool::new(1));
        let mut r = Router::with_engine(pool);
        let model = Model::random(models::mlp(&[8, 6, 4]), 17, 3);
        // batch=1 + zero linger: every request is its own batch, so 10
        // requests spread 4/3/3 over 3 replicas (unequal on purpose)
        let cfg = DeployConfig::new(Algo::Ffip)
            .with_tile(4, 2)
            .with_batch(1)
            .with_linger(Duration::ZERO)
            .with_replicas(3);
        r.deploy_model("m", model.compile(cfg).unwrap()).unwrap();
        let input: Vec<i32> = (0..8).map(|i| i - 4).collect();
        let first = r.infer("m", input.clone()).unwrap().output();
        for _ in 0..9 {
            let out = r.infer("m", input.clone()).unwrap().output();
            assert_eq!(out.data, first.data, "replicas are bit-identical");
        }
        let stats = r.undeploy("m").expect("deployed");
        assert_eq!(stats.count(), 10, "every request in the final stats");
        assert_eq!(stats.batches, 10);
        assert_eq!(stats.replicas.len(), 3, "per-replica breakdown");
        let by_replica: u64 =
            stats.replicas.iter().map(|x| x.batches).sum();
        assert_eq!(by_replica, 10, "{:?}", stats.replicas);
        assert!(
            stats.replicas.iter().all(|x| x.batches >= 1),
            "every replica served: {:?}",
            stats.replicas
        );
        // the merged per-layer breakdown accounts for every batch on
        // every layer, across replicas with differing batch counts
        assert_eq!(stats.layers.len(), 2);
        for l in &stats.layers {
            assert_eq!(l.batches, 10, "layer {} merged by name", l.name);
        }
    }

    /// Deploy-time capacity admission: a stationary-byte budget below
    /// the compiled model's needs rejects with the typed error (and
    /// nothing is deployed); a sufficient budget deploys and serves.
    #[test]
    fn capacity_admission_gates_deploy() {
        let mut r = Router::new();
        let (model, cfg) = fc_model(41, 8, 4, Algo::Ffip);
        let compiled = model.compile(cfg).unwrap();
        let need = compiled.stationary_bytes();
        assert!(need > 0);
        // too small: typed rejection, name stays free
        let tight = model
            .compile(cfg.with_max_stationary_bytes(need - 1))
            .unwrap();
        let err = r.deploy_model("m", tight).unwrap_err();
        match &err {
            DeployError::CapacityExceeded { model, need: n, budget } => {
                assert_eq!(model, "m");
                assert_eq!(*n, need);
                assert_eq!(*budget, need - 1);
            }
            other => panic!("expected CapacityExceeded, got {other:?}"),
        }
        let msg = err.to_string();
        assert!(msg.contains("capacity budget"), "{msg}");
        assert!(r.deployed().is_empty(), "rejected deploy leaves nothing");
        // exactly enough: deploys and serves
        let fits = model
            .compile(cfg.with_max_stationary_bytes(need))
            .unwrap();
        r.deploy_model("m", fits).unwrap();
        let out =
            r.infer("m", (0..8).map(|i| i - 4).collect()).unwrap().output();
        assert_eq!(out.data.len(), 4);
    }

    #[test]
    fn per_model_batches_never_mix() {
        let mut r = Router::new();
        r.deploy("a", echo(2));
        r.deploy("b", echo(3));
        // interleave submissions; row lengths stay per-model consistent
        let rx1 = r.submit("a", vec![1, 1]).unwrap();
        let rx2 = r.submit("b", vec![2, 2, 2]).unwrap();
        let rx3 = r.submit("a", vec![3, 3]).unwrap();
        assert_eq!(rx1.recv().unwrap().output().data.len(), 2);
        assert_eq!(rx2.recv().unwrap().output().data.len(), 3);
        assert_eq!(rx3.recv().unwrap().output().data.len(), 2);
    }
}
