//! Multi-model request router: the front door of the serving stack.
//!
//! A [`Router`] owns one [`Coordinator`] per deployed model and
//! dispatches requests by model name — the same leader-process shape as
//! production model servers (each model keeps its own batcher, so
//! batches never mix artifacts with different static shapes).  Routing
//! statistics feed capacity decisions (which model is hot, per-model
//! occupancy).

use super::server::Coordinator;
use super::Response;
use std::collections::HashMap;
use std::sync::mpsc;

/// Routing error.
#[derive(Debug, thiserror::Error)]
pub enum RouteError {
    #[error("unknown model {0:?} (deployed: {1:?})")]
    UnknownModel(String, Vec<String>),
}

/// Dispatches requests to per-model coordinators.
pub struct Router {
    models: HashMap<String, Coordinator>,
    counts: HashMap<String, u64>,
}

impl Router {
    pub fn new() -> Self {
        Router { models: HashMap::new(), counts: HashMap::new() }
    }

    /// Deploy a model under `name`.
    pub fn deploy(&mut self, name: &str, coordinator: Coordinator) {
        self.models.insert(name.to_string(), coordinator);
        self.counts.insert(name.to_string(), 0);
    }

    pub fn deployed(&self) -> Vec<String> {
        let mut v: Vec<String> = self.models.keys().cloned().collect();
        v.sort();
        v
    }

    /// Route one request; returns the response channel.
    pub fn submit(
        &mut self,
        model: &str,
        input: Vec<i32>,
    ) -> Result<mpsc::Receiver<Response>, RouteError> {
        let c = self.models.get(model).ok_or_else(|| {
            RouteError::UnknownModel(model.to_string(), self.deployed())
        })?;
        *self.counts.get_mut(model).unwrap() += 1;
        Ok(c.submit(input))
    }

    /// Blocking route.
    pub fn infer(
        &mut self,
        model: &str,
        input: Vec<i32>,
    ) -> Result<Response, RouteError> {
        let rx = self.submit(model, input)?;
        Ok(rx.recv().expect("backend response"))
    }

    /// Requests routed per model.
    pub fn route_counts(&self) -> &HashMap<String, u64> {
        &self.counts
    }

    /// Undeploy (drains that model's worker).
    pub fn undeploy(&mut self, name: &str) -> bool {
        self.counts.remove(name);
        self.models.remove(name).is_some()
    }
}

impl Default for Router {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{BatcherConfig, EchoBackend};
    use std::time::Duration;

    fn echo(len: usize) -> Coordinator {
        Coordinator::start(
            move || Ok(EchoBackend { len, batch: 2 }),
            BatcherConfig { batch: 2, linger: Duration::from_millis(1) },
        )
        .unwrap()
    }

    #[test]
    fn routes_by_model_name() {
        let mut r = Router::new();
        r.deploy("small", echo(2));
        r.deploy("large", echo(4));
        let a = r.infer("small", vec![1, 2]).unwrap();
        assert_eq!(a.output, vec![2.0, 4.0]);
        let b = r.infer("large", vec![1, 2, 3, 4]).unwrap();
        assert_eq!(b.output.len(), 4);
        assert_eq!(r.route_counts()["small"], 1);
        assert_eq!(r.route_counts()["large"], 1);
    }

    #[test]
    fn unknown_model_is_an_error_listing_deployments() {
        let mut r = Router::new();
        r.deploy("only", echo(1));
        let err = r.infer("nope", vec![0]).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("nope") && msg.contains("only"), "{msg}");
    }

    #[test]
    fn undeploy_stops_routing() {
        let mut r = Router::new();
        r.deploy("m", echo(1));
        assert!(r.undeploy("m"));
        assert!(!r.undeploy("m"));
        assert!(r.infer("m", vec![0]).is_err());
    }

    #[test]
    fn per_model_batches_never_mix() {
        let mut r = Router::new();
        r.deploy("a", echo(2));
        r.deploy("b", echo(3));
        // interleave submissions; row lengths stay per-model consistent
        let rx1 = r.submit("a", vec![1, 1]).unwrap();
        let rx2 = r.submit("b", vec![2, 2, 2]).unwrap();
        let rx3 = r.submit("a", vec![3, 3]).unwrap();
        assert_eq!(rx1.recv().unwrap().output.len(), 2);
        assert_eq!(rx2.recv().unwrap().output.len(), 3);
        assert_eq!(rx3.recv().unwrap().output.len(), 2);
    }
}
