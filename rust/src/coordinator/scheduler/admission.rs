//! Admission control: the bounded front door of a deployment.
//!
//! A serving tier fed by an unbounded queue has a failure mode worse
//! than refusing work: under sustained overload every queued request's
//! latency grows without limit while throughput stays flat, so *all*
//! clients time out instead of a few being told to back off.  The
//! [`Admission`] controller bounds the number of admitted-but-unanswered
//! requests at `max_queue_depth`; arrivals beyond the bound are shed
//! immediately with
//! [`RequestError::Overloaded`](crate::coordinator::RequestError::Overloaded)
//! (and counted — [`ServeStats::shed`](crate::coordinator::ServeStats)),
//! keeping the latency of everything admitted bounded by
//! `max_queue_depth / throughput`.
//!
//! The depth counter covers a request's whole server-side life
//! (admitted at [`Coordinator::submit`](crate::coordinator::Coordinator::submit),
//! released when its response is sent), so batches queued behind slow
//! replica workers count against the bound too — the bound cannot be
//! dodged by work sitting in an interior channel.

use super::super::tensor::RequestError;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;

/// Admission knobs for one deployment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AdmissionConfig {
    /// Maximum admitted-but-unanswered requests; `usize::MAX` (the
    /// default) admits everything.
    pub max_queue_depth: usize,
    /// KV-cache byte budget for the decode subsystem; `usize::MAX`
    /// (the default) never sheds on bytes.  The depth bound covers
    /// *in-flight sequences*, this one covers their *resident K/V
    /// strips* — a decode deployment is full when either runs out.
    pub max_kv_bytes: usize,
}

impl AdmissionConfig {
    /// Admit everything (the historical unbounded behavior).
    pub const UNBOUNDED: AdmissionConfig = AdmissionConfig {
        max_queue_depth: usize::MAX,
        max_kv_bytes: usize::MAX,
    };

    /// Bound the deployment at `max_queue_depth` in-flight requests.
    pub fn bounded(max_queue_depth: usize) -> Self {
        assert!(max_queue_depth >= 1, "max_queue_depth must be >= 1");
        AdmissionConfig { max_queue_depth, ..Self::UNBOUNDED }
    }

    /// Additionally bound resident KV-cache bytes (decode deployments).
    pub fn with_kv_bytes(mut self, max_kv_bytes: usize) -> Self {
        assert!(max_kv_bytes >= 1, "max_kv_bytes must be >= 1");
        self.max_kv_bytes = max_kv_bytes;
        self
    }
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        Self::UNBOUNDED
    }
}

/// Shared admission state: cloned into every replica worker (the
/// submit side admits, the response side releases).
#[derive(Debug, Clone)]
pub struct Admission {
    max_depth: usize,
    depth: Arc<AtomicUsize>,
    shed: Arc<AtomicU64>,
    max_kv_bytes: usize,
    kv_bytes: Arc<AtomicUsize>,
    shed_kv: Arc<AtomicU64>,
}

impl Admission {
    pub fn new(cfg: AdmissionConfig) -> Self {
        Admission {
            max_depth: cfg.max_queue_depth,
            depth: Arc::new(AtomicUsize::new(0)),
            shed: Arc::new(AtomicU64::new(0)),
            max_kv_bytes: cfg.max_kv_bytes,
            kv_bytes: Arc::new(AtomicUsize::new(0)),
            shed_kv: Arc::new(AtomicU64::new(0)),
        }
    }

    /// Try to admit one request.  `Err` is the typed shed response the
    /// caller must deliver (the shed counter is already bumped).
    pub fn try_admit(&self) -> Result<(), RequestError> {
        let admitted = self
            .depth
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |d| {
                (d < self.max_depth).then_some(d + 1)
            });
        match admitted {
            Ok(_) => Ok(()),
            Err(_) => {
                self.shed.fetch_add(1, Ordering::Relaxed);
                Err(RequestError::Overloaded {
                    max_queue_depth: self.max_depth,
                })
            }
        }
    }

    /// Release one admitted request (its response was sent).  Saturates
    /// at zero — tolerated, not asserted: a release without a matching
    /// admit (possible by feeding a [`ReplicaSet`](super::ReplicaSet)
    /// requests directly, bypassing [`Coordinator::submit`]) must
    /// neither wrap the counter (which would pin a bounded deployment
    /// at full depth, shedding forever) nor panic the replica thread.
    ///
    /// [`Coordinator::submit`]: crate::coordinator::Coordinator::submit
    pub fn complete(&self) {
        let _ = self
            .depth
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |d| {
                d.checked_sub(1)
            });
    }

    /// Admitted-but-unanswered requests right now.
    pub fn depth(&self) -> usize {
        self.depth.load(Ordering::Relaxed)
    }

    /// The configured bound (`usize::MAX` = unbounded).
    pub fn max_depth(&self) -> usize {
        self.max_depth
    }

    /// Requests shed since the deployment started.
    pub fn shed_count(&self) -> u64 {
        self.shed.load(Ordering::Relaxed)
    }

    /// Try to reserve `bytes` of the KV budget (one sequence's strips,
    /// reserved at admission).  `Err` is the typed
    /// [`RequestError::KvExhausted`] shed response; on success the bytes
    /// stay resident until [`Admission::release_kv`].
    pub fn try_admit_kv(&self, bytes: usize) -> Result<(), RequestError> {
        let reserved = self
            .kv_bytes
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |b| {
                b.checked_add(bytes).filter(|&nb| nb <= self.max_kv_bytes)
            });
        match reserved {
            Ok(_) => Ok(()),
            Err(in_use) => {
                self.shed_kv.fetch_add(1, Ordering::Relaxed);
                Err(RequestError::KvExhausted {
                    needed: bytes,
                    in_use,
                    max_kv_bytes: self.max_kv_bytes,
                })
            }
        }
    }

    /// Return `bytes` to the KV budget (the sequence was retired and
    /// its strips evicted).  Saturates at zero like
    /// [`Admission::complete`].
    pub fn release_kv(&self, bytes: usize) {
        let _ = self
            .kv_bytes
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |b| {
                Some(b.saturating_sub(bytes))
            });
    }

    /// Resident KV bytes right now.
    pub fn kv_bytes(&self) -> usize {
        self.kv_bytes.load(Ordering::Relaxed)
    }

    /// The configured KV budget (`usize::MAX` = unbounded).
    pub fn max_kv_bytes(&self) -> usize {
        self.max_kv_bytes
    }

    /// Sequences shed on the KV-byte budget since the deployment started.
    pub fn shed_kv_count(&self) -> u64 {
        self.shed_kv.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admits_up_to_the_bound_then_sheds() {
        let a = Admission::new(AdmissionConfig::bounded(2));
        assert!(a.try_admit().is_ok());
        assert!(a.try_admit().is_ok());
        assert_eq!(a.depth(), 2);
        // full: the third arrival sheds with the typed error
        assert_eq!(
            a.try_admit().unwrap_err(),
            RequestError::Overloaded { max_queue_depth: 2 }
        );
        assert_eq!(a.shed_count(), 1);
        // releasing one slot re-opens admission
        a.complete();
        assert!(a.try_admit().is_ok());
        assert_eq!(a.depth(), 2);
        assert_eq!(a.shed_count(), 1);
    }

    #[test]
    fn unbounded_never_sheds() {
        let a = Admission::new(AdmissionConfig::default());
        for _ in 0..10_000 {
            assert!(a.try_admit().is_ok());
        }
        assert_eq!(a.shed_count(), 0);
        assert_eq!(a.depth(), 10_000);
    }

    #[test]
    #[should_panic(expected = "max_queue_depth")]
    fn zero_bound_is_rejected() {
        let _ = AdmissionConfig::bounded(0);
    }

    /// The KV-byte ledger sheds with the typed error when a reservation
    /// would exceed the budget, and released bytes re-open admission.
    #[test]
    fn kv_budget_sheds_typed_and_reopens_on_release() {
        let a =
            Admission::new(AdmissionConfig::bounded(8).with_kv_bytes(1000));
        assert!(a.try_admit_kv(600).is_ok());
        assert!(a.try_admit_kv(400).is_ok());
        assert_eq!(a.kv_bytes(), 1000);
        assert_eq!(
            a.try_admit_kv(1).unwrap_err(),
            RequestError::KvExhausted {
                needed: 1,
                in_use: 1000,
                max_kv_bytes: 1000
            }
        );
        assert_eq!(a.shed_kv_count(), 1);
        a.release_kv(400);
        assert!(a.try_admit_kv(400).is_ok());
        assert_eq!(a.shed_kv_count(), 1);
        // unbounded-by-default ledger never sheds
        let u = Admission::new(AdmissionConfig::bounded(8));
        assert!(u.try_admit_kv(usize::MAX / 2).is_ok());
        // over-release saturates at zero instead of wrapping
        u.release_kv(usize::MAX);
        assert_eq!(u.kv_bytes(), 0);
    }

    /// Concurrent admits never exceed the bound (the CAS loop is the
    /// only writer of the depth counter on the admit side).
    #[test]
    fn concurrent_admission_respects_the_bound() {
        let a = Admission::new(AdmissionConfig::bounded(8));
        let admitted = Arc::new(AtomicUsize::new(0));
        std::thread::scope(|s| {
            for _ in 0..4 {
                let a = a.clone();
                let admitted = admitted.clone();
                s.spawn(move || {
                    for _ in 0..100 {
                        if a.try_admit().is_ok() {
                            admitted.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                });
            }
        });
        let ok = admitted.load(Ordering::Relaxed);
        assert_eq!(ok, 8, "exactly the bound admitted, rest shed");
        assert_eq!(a.shed_count(), 400 - 8);
        assert_eq!(a.depth(), 8);
    }
}
