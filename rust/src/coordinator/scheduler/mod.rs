//! The replica scheduler: sharded sessions, pipeline-overlapped
//! staging, and admission-controlled backpressure.
//!
//! The FFIP array doubles effective MAC throughput per multiplier, but
//! that only reaches the serving tier if the feeding layer keeps the
//! compute busy.  This subsystem attacks the two serial bottlenecks the
//! single-worker coordinator had, plus the failure mode that appears
//! once it no longer has them:
//!
//! * [`replica`] — a [`ReplicaSet`]: N cheap session replicas (buffers
//!   only; compiled weights and offline FFIP y terms stay `Arc`-shared)
//!   behind one batcher, dispatched round-robin with
//!   least-outstanding-work stealing, so a deployment keeps more than
//!   one batch in flight on the shared pool;
//! * [`pipeline`] — a [`PipelinedSession`]: each batch splits into two
//!   micro-batches whose staging (im2gemm walk, narrow copies) overlaps
//!   the other's GEMM drain via the pool's async
//!   [`submit_into`](crate::engine::GemmPool::submit_into) (recycled A
//!   and C rings — allocation-free in steady state), so neither the
//!   CPU staging walk nor the pool sits idle waiting on the other;
//! * [`admission`] — an [`Admission`] controller: a bounded in-flight
//!   depth that sheds excess arrivals with
//!   [`RequestError::Overloaded`](crate::coordinator::RequestError::Overloaded)
//!   instead of letting queueing latency grow without limit.
//!
//! All three compose under the existing
//! [`Coordinator`](crate::coordinator::Coordinator) front door; the
//! knobs live on [`DeployConfig`](crate::coordinator::DeployConfig)
//! (`replicas`, `max_queue_depth`, `pipeline`), and the merged
//! observability story — per-replica breakdown, shed counter — on
//! [`ServeStats`](crate::coordinator::ServeStats).

pub mod admission;
pub mod pipeline;
pub mod replica;

pub use admission::{Admission, AdmissionConfig};
pub use pipeline::{PipeEvent, PipelinedBackend, PipelinedSession};
pub use replica::ReplicaSet;
