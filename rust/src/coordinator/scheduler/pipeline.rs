//! Pipeline-overlapped batch execution: stage the next A operand while
//! the previous GEMM drains on the pool.
//!
//! The sequential [`InferenceSession`](crate::coordinator::InferenceSession)
//! serializes the three per-layer phases — stage A (im2gemm walk,
//! narrow copies), GEMM, post-GEMM — so the CPU-side staging walk sits
//! on the critical path while the [`GemmPool`] idles, and vice versa.
//! [`PipelinedSession`] splits each batch into **two micro-batches**
//! along request rows (row-block GEMM decomposition is exact, so the
//! split is bit-identical to the unsplit batch) and software-pipelines
//! them with a one-phase skew:
//!
//! ```text
//!  micro 0:  stage L0 ─ submit ─────── wait+post ─ stage L1 ─ submit ─ wait+post ─ …
//!  micro 1:            stage L0 ─ submit ───────── wait+post ─ stage L1 ─ submit ─ …
//!                      ^^^^^^^^
//!                      overlaps micro 0's in-flight L0 GEMM
//! ```
//!
//! In steady state, while one micro-batch's layer-*l* GEMM drains
//! asynchronously ([`GemmPool::submit_into`]), the CPU post-processes
//! and stages the *other* micro-batch's layer *l* (and, one step
//! later, layer *l+1*) — so layer *l+1*'s staging always completes
//! before layer *l*'s [`PendingGemm`] is waited on, which is the
//! overlap the FPGA feeding literature says is required to keep a
//! fast-algorithm compute array saturated.  Both operand rings
//! recycle: A staging buffers come back through
//! [`PendingGemm::wait_with_inputs`], and the widened C outputs cycle
//! through a spare ring handed to [`GemmPool::submit_into`] — so
//! steady state allocates nothing per batch.  Ownership transfer into
//! the pending handle makes aliasing between a staged-ahead A and an
//! in-flight job's operands structurally impossible (the optional
//! event trace additionally checksums every A buffer before submit and
//! after drain, so tests can assert it).
//!
//! [`GemmPool`]: crate::engine::GemmPool
//! [`GemmPool::submit_into`]: crate::engine::GemmPool::submit_into
//! [`PendingGemm`]: crate::engine::PendingGemm

use super::super::model::{
    CompiledLayer, CompiledModel, LayerExec, TypedModel,
};
use super::super::server::Backend;
use super::super::session::{
    apply_post_gemm, gemm_error_to_request, narrow_rows, run_attention,
    run_residual, run_token_fc, run_winograd, stage_layer_a,
    verify_layer_abft, AttnScratch, LayerTiming, WinoScratch,
};
use super::super::stats::FaultCounts;
use super::super::tensor::{RequestError, Tensor, TensorView};
use crate::algo::element::{ElemKind, Element};
use crate::algo::Mat;
use crate::engine::{GemmPool, PendingGemm, PoolStats};
use crate::util::with_width;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// One entry of the pipeline's event trace (enabled with
/// [`PipelinedSession::enable_trace`]; off by default so the request
/// path pays no checksum cost).  Event order is the schedule proof:
/// `Staged { micro: a, layer: l + 1 }` always precedes
/// `Drained { micro: b, layer: l }` for the other micro-batch `b`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PipeEvent {
    /// Micro-batch `micro` finished staging layer `layer`'s A operand
    /// (checksummed before the buffer is handed to the pool).
    Staged { micro: usize, layer: usize, a_checksum: u64 },
    /// The staged operand was submitted asynchronously to the pool.
    Submitted { micro: usize, layer: usize },
    /// The layer's [`PendingGemm`](crate::engine::PendingGemm) was
    /// waited on; `a_checksum` re-hashes the A buffer handed back, so
    /// `Staged.a_checksum == Drained.a_checksum` proves nothing touched
    /// the staged operand while it was in flight.
    Drained { micro: usize, layer: usize, a_checksum: u64 },
}

/// FNV-1a over the operand values — cheap, deterministic, and enough to
/// witness an aliasing write.
fn checksum<E: Element>(m: &Mat<E>) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for v in &m.data {
        h ^= v.to_i64() as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h ^ (((m.rows as u64) << 32) | m.cols as u64)
}

/// Layers the one-phase-skew schedule cannot stage/submit/drain:
/// attention (both QKᵀ/AV operands are this batch's activations — the
/// online-y scenario), Winograd convs (whose 16 stage GEMMs already run
/// concurrently inside `run_winograd`), token-FCs (whose ragged
/// gather/scatter brackets the GEMM) and residual adds (no GEMM at
/// all).  Each is a synchronization point for its micro-batch while the
/// other micro-batch's staged-ahead work still overlaps on the shared
/// pool.
fn is_sync<E: Element>(layer: &CompiledLayer<E>) -> bool {
    matches!(
        layer.exec,
        LayerExec::Attention(_)
            | LayerExec::WinoConv(_)
            | LayerExec::TokenFc { .. }
            | LayerExec::Residual { .. }
    )
}

/// The typed pipeline state: two micro-batch activation slabs, a pool
/// of recycled A staging buffers, and the per-batch timing/trace
/// records.
struct TypedPipeline<E: Element> {
    model: Arc<TypedModel<E>>,
    pool: Arc<GemmPool>,
    names: Vec<Arc<str>>,
    /// Per-micro-batch flat activations at storage width.
    act: [Vec<E>; 2],
    /// Recycled A staging buffers (refilled by `wait_with_inputs`).
    spare_a: Vec<Mat<E>>,
    /// Recycled widened C output buffers (handed to `submit_into`,
    /// refilled after each drain's post-GEMM pass).
    spare_c: Vec<Mat<E::Acc>>,
    /// Per-layer accumulated wall micros for the current batch.
    layer_us: Vec<u64>,
    /// Attention scratch (shared across micro-batches, which run an
    /// attention layer sequentially) — same steady-state recycling as
    /// the sequential session's.
    attn: AttnScratch<E>,
    /// Winograd conv scratch (shared the same way).
    wino: WinoScratch<E>,
    /// Saved input slabs per micro-batch, one per layer flagged
    /// [`CompiledLayer::save_input`] (a later residual adds it back).
    saves: [Vec<Vec<E>>; 2],
    /// Per-request valid lengths of the token-fc ragged rows.
    tf_lens: Vec<usize>,
    timings: Vec<LayerTiming>,
    /// Fault-tolerance counters accumulated since the last drain.
    faults: FaultCounts,
    trace: Vec<PipeEvent>,
    trace_enabled: bool,
}

impl<E: Element> TypedPipeline<E> {
    fn new(model: Arc<TypedModel<E>>, pool: Arc<GemmPool>) -> Self {
        let names = model
            .layers
            .iter()
            .map(|l| Arc::<str>::from(l.name.as_str()))
            .collect();
        let n_layers = model.layers.len();
        let act = [
            Vec::with_capacity(model.max_act_elems()),
            Vec::with_capacity(model.max_act_elems()),
        ];
        TypedPipeline {
            model,
            pool,
            names,
            act,
            spare_a: Vec::new(),
            spare_c: Vec::new(),
            layer_us: vec![0; n_layers],
            attn: AttnScratch::new(),
            wino: WinoScratch::new(),
            saves: [
                (0..n_layers).map(|_| Vec::new()).collect(),
                (0..n_layers).map(|_| Vec::new()).collect(),
            ],
            tf_lens: Vec::new(),
            timings: Vec::with_capacity(n_layers),
            faults: FaultCounts::default(),
            trace: Vec::new(),
            trace_enabled: false,
        }
    }

    /// Stage `rows` requests' layer-`lidx` A operand from micro-batch
    /// `micro`'s activations into a recycled buffer.
    fn stage(
        &mut self,
        layer: &CompiledLayer<E>,
        lidx: usize,
        micro: usize,
        rows: usize,
    ) -> Mat<E> {
        let mut a = self.spare_a.pop().unwrap_or_else(|| Mat::zeros(0, 0));
        stage_layer_a(layer, self.model.cfg.batch, rows, &self.act[micro], &mut a);
        if self.trace_enabled {
            self.trace.push(PipeEvent::Staged {
                micro,
                layer: lidx,
                a_checksum: checksum(&a),
            });
        }
        a
    }

    /// Hand the staged operand to the pool asynchronously; the compiled
    /// weights and offline FFIP y terms ride as shared `Arc`s, and the
    /// output buffer comes off the recycled C ring
    /// ([`GemmPool::submit_into`]), so steady state allocates nothing.
    fn submit(
        &mut self,
        layer: &CompiledLayer<E>,
        lidx: usize,
        micro: usize,
        a: Mat<E>,
    ) -> PendingGemm<E> {
        let c = self.spare_c.pop().unwrap_or_else(|| Mat::zeros(0, 0));
        let pending = self.pool.submit_into(
            a,
            layer.weights.clone(),
            layer.y.clone(),
            c,
            layer.algo,
            layer.tile,
        );
        if self.trace_enabled {
            self.trace.push(PipeEvent::Submitted { micro, layer: lidx });
        }
        pending
    }

    /// Join micro-batch `micro`'s layer-`lidx` GEMM (typed errors for
    /// poisoned jobs and watchdog expiries), verify and heal the
    /// accumulators through the layer's ABFT checksums, recycle its A
    /// and C buffers, and requantize into the micro-batch's
    /// activations.
    fn drain(
        &mut self,
        layer: &CompiledLayer<E>,
        lidx: usize,
        micro: usize,
        pending: PendingGemm<E>,
    ) -> Result<(), RequestError> {
        let (mut c, a) = pending.wait_with_inputs_checked().map_err(|e| {
            gemm_error_to_request(
                e,
                &layer.name,
                self.model.cfg.request_deadline,
                &mut self.faults,
            )
        })?;
        if self.trace_enabled {
            self.trace.push(PipeEvent::Drained {
                micro,
                layer: lidx,
                a_checksum: checksum(&a),
            });
        }
        // verify before the buffers recycle: the checksum walk needs
        // the exact (A, C) pair the pool just produced
        verify_layer_abft(layer, &a, &mut c, &self.pool, &mut self.faults)?;
        self.spare_a.push(a);
        apply_post_gemm(layer, &c, &mut self.act[micro]);
        self.spare_c.push(c);
        Ok(())
    }

    /// Execute an attention layer for one micro-batch.  Both GEMM
    /// operands are per-request activations (QKᵀ and AV, with FFIP's
    /// y-from-B on the critical path), so there is nothing to stage
    /// ahead: the layer is a synchronization point for its micro-batch,
    /// while the other micro-batch's staged-ahead work still overlaps
    /// on the shared pool.
    fn run_attn(
        &mut self,
        layer: &CompiledLayer<E>,
        micro: usize,
        rows: usize,
    ) -> Result<(), RequestError> {
        let LayerExec::Attention(at) = &layer.exec else {
            unreachable!("run_attn is only called on attention layers")
        };
        let post = layer
            .post
            .as_ref()
            .expect("attention compiles with a post-GEMM stage");
        run_attention(
            at,
            post,
            &self.pool,
            layer.algo,
            rows,
            &mut self.act[micro],
            &mut self.attn,
            &layer.name,
            &mut self.faults,
            self.model.cfg.request_deadline,
        )
    }

    /// Execute a Winograd conv layer for one micro-batch — synchronous
    /// at the layer level (see [`is_sync`]), internally fanned out over
    /// its 16 concurrent stage GEMMs.
    fn run_wino(
        &mut self,
        layer: &CompiledLayer<E>,
        micro: usize,
        rows: usize,
    ) -> Result<(), RequestError> {
        let LayerExec::WinoConv(wx) = &layer.exec else {
            unreachable!("run_wino is only called on winograd conv layers")
        };
        run_winograd(
            wx,
            layer.post.as_ref(),
            &self.pool,
            layer.algo,
            rows,
            &mut self.act[micro],
            &mut self.wino,
            &layer.name,
            &mut self.faults,
            self.model.cfg.request_deadline,
        )
    }

    /// Execute a token-FC layer for one micro-batch: gather the valid
    /// ragged tokens, run one dense GEMM over all of them, scatter the
    /// requantized outputs back.  Synchronous — the gather depends on
    /// this micro-batch's per-request lengths — but its A/C buffers
    /// still cycle through the spare rings.
    fn run_tfc(
        &mut self,
        layer: &CompiledLayer<E>,
        max_seq: usize,
        micro: usize,
        rows: usize,
    ) -> Result<(), RequestError> {
        let mut a = self.spare_a.pop().unwrap_or_else(|| Mat::zeros(0, 0));
        let mut c = self.spare_c.pop().unwrap_or_else(|| Mat::zeros(0, 0));
        let res = run_token_fc(
            layer,
            max_seq,
            &self.pool,
            rows,
            &mut self.act[micro],
            &mut a,
            &mut c,
            &mut self.tf_lens,
            &mut self.faults,
            self.model.cfg.request_deadline,
        );
        self.spare_a.push(a);
        self.spare_c.push(c);
        res
    }

    fn infer_batch(
        &mut self,
        input: TensorView<'_>,
    ) -> Result<Tensor, RequestError> {
        let model = self.model.clone();
        if input.row_len() != model.input_len {
            return Err(RequestError::BadShape {
                expected: model.input_len,
                got: input.row_len(),
            });
        }
        let rows = input.rows();
        assert!(
            rows >= 1 && rows <= model.cfg.batch,
            "session batch rows {rows} outside 1..={}",
            model.cfg.batch
        );
        self.trace.clear();
        self.layer_us.clear();
        self.layer_us.resize(model.layers.len(), 0);
        // split along request rows: micro 0 takes the first ceil(rows/2)
        let r0 = rows.div_ceil(2);
        let parts = [(0, r0), (r0, rows - r0)];
        let n_micro = if rows > 1 { 2 } else { 1 };
        let in_len = model.input_len;
        for (i, &(off, r)) in parts.iter().enumerate().take(n_micro) {
            narrow_rows(
                &input.data[off * in_len..(off + r) * in_len],
                &mut self.act[i],
            )?;
        }
        let n_layers = model.layers.len();
        let mut pending: [Option<PendingGemm<E>>; 2] = [None, None];
        // prologue: stage + submit layer 0 for every micro-batch, so by
        // the time micro 0's job is waited on, micro 1's staging has
        // already completed against the in-flight GEMM.  A synchronous
        // layer 0 (attention / winograd conv) has no single stationary
        // GEMM to stage; the main loop runs it in place instead.
        if !is_sync(&model.layers[0]) {
            for (i, &(_, r)) in parts.iter().enumerate().take(n_micro) {
                let t0 = Instant::now();
                let a = self.stage(&model.layers[0], 0, i, r);
                let p = self.submit(&model.layers[0], 0, i, a);
                pending[i] = Some(p);
                self.layer_us[0] += t0.elapsed().as_micros() as u64;
            }
        }
        // steady state: drain one micro-batch's layer l (or execute
        // its attention synchronously), immediately stage + submit its
        // layer l+1, then repeat for the other micro-batch — each
        // submitted job drains while the CPU works on the opposite
        // stream.  An early error return is safe while jobs are in
        // flight: dropping a `PendingGemm` settles it.
        for l in 0..n_layers {
            for (i, &(_, r)) in parts.iter().enumerate().take(n_micro) {
                let t0 = Instant::now();
                // At this point act[i] still holds layer l's *input*
                // (the drain below overwrites it with the output), so
                // this is the snapshot a later residual adds back.
                if model.layers[l].save_input {
                    self.saves[i][l].clear();
                    self.saves[i][l].extend_from_slice(&self.act[i]);
                }
                match &model.layers[l].exec {
                    LayerExec::Attention(_) => {
                        self.run_attn(&model.layers[l], i, r)?;
                    }
                    LayerExec::WinoConv(_) => {
                        self.run_wino(&model.layers[l], i, r)?;
                    }
                    LayerExec::TokenFc { max_seq } => {
                        let max_seq = *max_seq;
                        self.run_tfc(&model.layers[l], max_seq, i, r)?;
                    }
                    LayerExec::Residual { span, bits, ragged } => {
                        run_residual(
                            *bits,
                            *ragged,
                            model.layers[l].in_len,
                            r,
                            &self.saves[i][l - span],
                            &mut self.act[i],
                        );
                    }
                    LayerExec::Fc | LayerExec::Conv { .. } => {
                        let p = pending[i]
                            .take()
                            .expect("submitted in prior step");
                        self.drain(&model.layers[l], l, i, p)?;
                    }
                }
                self.layer_us[l] += t0.elapsed().as_micros() as u64;
                if l + 1 < n_layers && !is_sync(&model.layers[l + 1]) {
                    let t1 = Instant::now();
                    let a = self.stage(&model.layers[l + 1], l + 1, i, r);
                    let p = self.submit(&model.layers[l + 1], l + 1, i, a);
                    pending[i] = Some(p);
                    self.layer_us[l + 1] += t1.elapsed().as_micros() as u64;
                }
            }
        }
        self.timings.clear();
        for (li, &us) in self.layer_us.iter().enumerate() {
            self.timings.push(LayerTiming {
                name: self.names[li].clone(),
                micros: us,
            });
        }
        // assemble rows in request order: micro 0 then micro 1
        let mut data = Vec::with_capacity(rows * model.output_len);
        for act in self.act.iter().take(n_micro) {
            data.extend(act.iter().map(|&v| v.to_i64() as f32));
        }
        Ok(Tensor::new(rows, model.output_len, data))
    }
}

/// Width-tagged pipeline state (mirrors
/// [`CompiledModel`](crate::coordinator::CompiledModel)'s variants).
enum PipeInner {
    I8(TypedPipeline<i8>),
    I16(TypedPipeline<i16>),
    I64(TypedPipeline<i64>),
}

/// The pipeline-overlapped counterpart of
/// [`InferenceSession`](crate::coordinator::InferenceSession): same
/// compiled model, same pool, bit-identical outputs, but each batch's
/// staging overlaps the previous micro-batch's GEMM drain (module
/// docs).  Cheap to replicate: the compiled weights and offline y terms
/// stay `Arc`-shared; only the buffers are per-session.
pub struct PipelinedSession {
    inner: PipeInner,
}

impl PipelinedSession {
    /// Build pipeline state over a compiled model, at its compiled
    /// storage width.
    pub fn new(model: &CompiledModel, pool: Arc<GemmPool>) -> Self {
        let inner = match model {
            CompiledModel::I8(m) => {
                PipeInner::I8(TypedPipeline::new(m.clone(), pool))
            }
            CompiledModel::I16(m) => {
                PipeInner::I16(TypedPipeline::new(m.clone(), pool))
            }
            CompiledModel::I64(m) => {
                PipeInner::I64(TypedPipeline::new(m.clone(), pool))
            }
        };
        PipelinedSession { inner }
    }

    /// The storage element width this session executes on.
    pub fn storage(&self) -> ElemKind {
        match &self.inner {
            PipeInner::I8(_) => ElemKind::I8,
            PipeInner::I16(_) => ElemKind::I16,
            PipeInner::I64(_) => ElemKind::I64,
        }
    }

    pub fn input_len(&self) -> usize {
        with_width!(PipeInner, &self.inner, s => s.model.input_len)
    }

    pub fn output_len(&self) -> usize {
        with_width!(PipeInner, &self.inner, s => s.model.output_len)
    }

    pub fn batch(&self) -> usize {
        with_width!(PipeInner, &self.inner, s => s.model.cfg.batch)
    }

    pub fn pool(&self) -> &Arc<GemmPool> {
        with_width!(PipeInner, &self.inner, s => &s.pool)
    }

    /// The compiled `max_seq` when request rows carry the ragged
    /// attention wire format; `None` for dense-row models.
    pub fn max_seq(&self) -> Option<usize> {
        with_width!(PipeInner, &self.inner, s => s.model.max_seq())
    }

    /// Record the staging/submit/drain event trace (with A-operand
    /// checksums) for subsequent batches — test instrumentation; adds a
    /// checksum pass per staged operand.
    pub fn enable_trace(&mut self) {
        with_width!(PipeInner, &mut self.inner, s => s.trace_enabled = true);
    }

    /// The event trace of the most recent batch (drains it).
    pub fn take_trace(&mut self) -> Vec<PipeEvent> {
        with_width!(PipeInner, &mut self.inner, s => std::mem::take(&mut s.trace))
    }

    /// Execute one batch through every layer, pipelined.  Same contract
    /// as [`InferenceSession::infer_batch`](crate::coordinator::InferenceSession::infer_batch).
    pub fn infer_batch(
        &mut self,
        input: TensorView<'_>,
    ) -> Result<Tensor, RequestError> {
        with_width!(PipeInner, &mut self.inner, s => s.infer_batch(input))
    }

    /// Per-layer wall times of the most recent batch (drains them).
    pub fn take_layer_timings(&mut self) -> Vec<LayerTiming> {
        with_width!(PipeInner, &mut self.inner, s => std::mem::take(&mut s.timings))
    }

    /// Fault-tolerance counters accumulated since the last drain
    /// (drains them).  All zeros on a fault-free run.
    pub fn take_fault_counts(&mut self) -> FaultCounts {
        with_width!(PipeInner, &mut self.inner, s => std::mem::take(&mut s.faults))
    }

    /// The deployment's per-request deadline knob
    /// ([`DeployConfig::with_request_deadline`](crate::coordinator::DeployConfig)),
    /// if configured.
    pub fn request_deadline(&self) -> Option<Duration> {
        with_width!(PipeInner, &self.inner, s => s.model.cfg.request_deadline)
    }
}

/// The coordinator [`Backend`] over a [`PipelinedSession`] — what a
/// replica worker runs when
/// [`DeployConfig::pipeline`](crate::coordinator::DeployConfig) is on.
pub struct PipelinedBackend {
    session: PipelinedSession,
}

impl PipelinedBackend {
    pub fn new(session: PipelinedSession) -> Self {
        PipelinedBackend { session }
    }

    pub fn session(&self) -> &PipelinedSession {
        &self.session
    }
}

impl Backend for PipelinedBackend {
    fn input_len(&self) -> usize {
        self.session.input_len()
    }

    fn output_len(&self) -> usize {
        self.session.output_len()
    }

    fn batch(&self) -> usize {
        self.session.batch()
    }

    fn infer(&mut self, batch: TensorView<'_>) -> anyhow::Result<Tensor> {
        self.session.infer_batch(batch).map_err(anyhow::Error::from)
    }

    fn input_domain_bits(&self) -> Option<u32> {
        match self.session.storage() {
            ElemKind::I32 | ElemKind::I64 => None,
            narrow => Some(narrow.bits()),
        }
    }

    fn max_seq(&self) -> Option<usize> {
        self.session.max_seq()
    }

    fn engine_stats(&self) -> Option<PoolStats> {
        Some(self.session.pool().stats())
    }

    fn layer_timings(&mut self) -> Option<Vec<LayerTiming>> {
        Some(self.session.take_layer_timings())
    }

    fn fault_counts(&mut self) -> Option<FaultCounts> {
        Some(self.session.take_fault_counts())
    }

    fn request_deadline(&self) -> Option<Duration> {
        self.session.request_deadline()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::Algo;
    use crate::coordinator::{
        compile, DeployConfig, InferenceSession, Model,
    };
    use crate::nn::models;

    /// The pipelined executor is bit-identical to the sequential
    /// session on the same compiled model, for every algorithm and for
    /// partial batches (including the degenerate single-row batch that
    /// runs one micro-batch).
    #[test]
    fn pipeline_matches_sequential_for_all_algos_and_row_counts() {
        let model = Model::random(models::mlp(&[12, 10, 8, 6]), 0xBEEF, 3);
        let pool = Arc::new(GemmPool::new(2));
        for algo in Algo::ALL {
            let cfg =
                DeployConfig::new(algo).with_tile(4, 3).with_batch(4);
            let compiled = compile(&model, cfg).unwrap();
            let mut seq = InferenceSession::new(&compiled, pool.clone());
            let mut pipe = PipelinedSession::new(&compiled, pool.clone());
            for rows in 1..=4usize {
                let input: Vec<i32> = (0..rows * 12)
                    .map(|i| (i as i32 % 7) - 3)
                    .collect();
                let view = TensorView::new(rows, 12, &input);
                let a = seq.infer_batch(view).unwrap();
                let b = pipe.infer_batch(view).unwrap();
                assert_eq!(a, b, "{algo:?} rows={rows}");
            }
        }
    }

    /// Transformer blocks — causal attention, token-parallel FCs and
    /// residual adds over the ragged wire format — run bit-identically
    /// through the pipelined executor, including ragged batches with
    /// empty rows split across the two micro-batches.
    #[test]
    fn pipeline_matches_sequential_on_transformer_blocks() {
        use crate::coordinator::{pack_ragged_row, PostGemm};
        use crate::quant::QuantScheme;
        let (seq, dim, heads) = (3usize, 4usize, 2usize);
        let mut model =
            Model::random(models::transformer(seq, dim, heads, 1), 0xD0DE, 3);
        let post = |n: usize, relu: bool| PostGemm {
            bias: vec![0; n],
            scheme: QuantScheme::symmetric_signed(8, 1.0 / 16.0),
            relu,
        };
        model.set_post(0, post(4 * dim, false)).unwrap();
        model.set_post(2, post(4 * dim, true)).unwrap();
        model.set_post(3, post(dim, false)).unwrap();
        let pool = Arc::new(GemmPool::new(2));
        for algo in Algo::ALL {
            let cfg = DeployConfig::new(algo).with_tile(4, 4).with_batch(3);
            let compiled = compile(&model, cfg).unwrap();
            let mut seq_s = InferenceSession::new(&compiled, pool.clone());
            let mut pipe = PipelinedSession::new(&compiled, pool.clone());
            let mut data = Vec::new();
            for (s, &len) in [2usize, 0, 3].iter().enumerate() {
                let toks: Vec<i32> = (0..len * dim)
                    .map(|i| ((i + 3 * s) as i32 % 7) - 3)
                    .collect();
                data.extend(pack_ragged_row(&toks, dim, seq));
            }
            let view = TensorView::new(3, 1 + seq * dim, &data);
            let a = seq_s.infer_batch(view).unwrap();
            let b = pipe.infer_batch(view).unwrap();
            assert_eq!(a, b, "{algo:?}");
        }
    }

    /// The operand rings recycle: after any number of batches the
    /// pipeline holds at most two spare buffers of each kind (one per
    /// micro-batch in flight) and at least one recycled one — the
    /// steady state allocates neither A staging nor C output matrices
    /// per batch (`GemmPool::submit_into`).
    #[test]
    fn operand_rings_stay_bounded_across_batches() {
        let model = Model::random(models::mlp(&[10, 8, 6]), 0xA11C, 3);
        let cfg =
            DeployConfig::new(Algo::Ffip).with_tile(4, 3).with_batch(4);
        let compiled = compile(&model, cfg).unwrap();
        let mut pipe =
            PipelinedSession::new(&compiled, Arc::new(GemmPool::new(1)));
        let input: Vec<i32> =
            (0..4 * 10).map(|i| (i as i32 % 5) - 2).collect();
        for _ in 0..4 {
            pipe.infer_batch(TensorView::new(4, 10, &input)).unwrap();
        }
        let (na, nc) = match &pipe.inner {
            PipeInner::I64(p) => (p.spare_a.len(), p.spare_c.len()),
            _ => unreachable!("raw-accumulator models compile wide"),
        };
        assert!(na <= 2 && nc <= 2, "spare rings grew: a={na} c={nc}");
        assert!(na >= 1 && nc >= 1, "rings never recycled");
    }

    /// The overlap schedule: micro 0's layer l+1 staging (and submit)
    /// happens strictly before micro 1's layer-l PendingGemm is waited
    /// on, and every A buffer comes back from its drain with the
    /// checksum it was staged with.
    #[test]
    fn trace_proves_staging_overlaps_the_inflight_drain() {
        let model = Model::random(models::mlp(&[8, 6, 4, 2]), 0xFACE, 3);
        let cfg =
            DeployConfig::new(Algo::Ffip).with_tile(4, 2).with_batch(2);
        let compiled = compile(&model, cfg).unwrap();
        let mut pipe =
            PipelinedSession::new(&compiled, Arc::new(GemmPool::new(1)));
        pipe.enable_trace();
        let input: Vec<i32> = (0..2 * 8).map(|i| (i as i32 % 5) - 2).collect();
        pipe.infer_batch(TensorView::new(2, 8, &input)).unwrap();
        let trace = pipe.take_trace();
        let pos = |ev: &dyn Fn(&PipeEvent) -> bool| {
            trace.iter().position(|e| ev(e)).expect("event present")
        };
        // three layers pipelined over two micro-batches
        for l in 0..2usize {
            let staged_next = pos(&|e: &PipeEvent| {
                matches!(e, PipeEvent::Staged { micro: 0, layer, .. } if *layer == l + 1)
            });
            let drained_other = pos(&|e: &PipeEvent| {
                matches!(e, PipeEvent::Drained { micro: 1, layer, .. } if *layer == l)
            });
            assert!(
                staged_next < drained_other,
                "layer {} staging must complete before layer {l}'s \
                 pending GEMM is waited on: {trace:?}",
                l + 1
            );
        }
        // checksum round trip: nothing touched any staged A in flight
        for e in &trace {
            if let PipeEvent::Staged { micro, layer, a_checksum } = e {
                let drained = trace.iter().find_map(|d| match d {
                    PipeEvent::Drained {
                        micro: m,
                        layer: l,
                        a_checksum: c,
                    } if m == micro && l == layer => Some(*c),
                    _ => None,
                });
                assert_eq!(
                    drained,
                    Some(*a_checksum),
                    "micro {micro} layer {layer}: staged A mutated in \
                     flight"
                );
            }
        }
    }
}
