//! Replica-sharded batch execution: one batcher feeding N session
//! replicas on the shared pool.
//!
//! One deployment used to be one worker — one batch in flight, the
//! shared [`GemmPool`](crate::engine::GemmPool) idling between layers
//! while staging ran on the critical path.  A [`ReplicaSet`] splits the
//! deployment into:
//!
//! * a **dispatcher** thread running the existing [`Batcher`] and
//!   handing each formed batch to a replica, **round-robin with
//!   least-outstanding-work stealing**: the rotating candidate wins
//!   ties, but any replica with strictly fewer batches in flight steals
//!   the dispatch, so a replica stuck on a slow batch never builds a
//!   private backlog while its peers idle;
//! * N **replica workers**, each owning one backend built inside its
//!   own thread (PJRT handles are not `Send`, and session replicas are
//!   cheap — compiled weights and offline FFIP y terms stay
//!   `Arc`-shared, only staging/activation buffers are per-replica).
//!
//! Every replica records into its own private
//! [`ServeStats`] — no cross-replica lock contention on the hot path —
//! and snapshots merge by name-aligned layer stats
//! ([`ServeStats::merge_from`]), so undeploy returns one coherent view
//! even when work stealing left the replicas with different batch
//! counts.  The [`Admission`] controller's depth counter spans the
//! whole set: a request admitted at submit is released only when its
//! response (success *or* typed error) is sent by whichever replica
//! served it.
//!
//! Fault tolerance: backend factories are `Fn` (not `FnOnce`), so a
//! replica whose thread dies — a panic that escaped the per-batch
//! `catch_unwind` backstop — is **respawned by the dispatcher** from
//! the shared compiled artifact the factory closes over, and the batch
//! that discovered the corpse is re-dispatched to the fresh thread.
//! Backend panics, ABFT checksum sheds, watchdog trips and deadline
//! sheds all land in the replica's [`ServeStats::faults`] counters
//! instead of stderr.

use super::super::batcher::{Batch, Batcher, BatcherConfig};
use super::super::server::Backend;
use super::super::stats::{ReplicaStats, ServeStats};
use super::super::tensor::{RequestError, Tensor, TensorView};
use super::super::{Request, Response};
use super::admission::{Admission, AdmissionConfig};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

/// What the dispatcher holds per replica: the batch channel and the
/// in-flight batch counter the stealing policy reads.
struct ReplicaRoute {
    tx: mpsc::Sender<Batch>,
    outstanding: Arc<AtomicUsize>,
}

/// What the [`ReplicaSet`] holds per replica: the private stats and the
/// join handle (the batch sender lives with the dispatcher, so the
/// dispatcher's exit is what drains and stops the replicas).
struct ReplicaHandle {
    outstanding: Arc<AtomicUsize>,
    stats: Arc<Mutex<ServeStats>>,
    handle: Option<JoinHandle<()>>,
}

/// Everything the dispatcher needs to rebuild a dead replica in place:
/// the shared backend factories (cheap to re-run — compiled weights
/// and offline FFIP y terms stay `Arc`-shared), each replica's private
/// stats, and the list where respawned threads park their join handles
/// so shutdown still joins them.
struct RespawnCtx<F> {
    factories: Vec<Arc<F>>,
    stats: Vec<Arc<Mutex<ServeStats>>>,
    batch_cap: usize,
    respawned: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

/// A batcher-fed set of replica workers over one backend type (module
/// docs).  Constructed by
/// [`Coordinator::start_replicated`](crate::coordinator::Coordinator::start_replicated).
pub struct ReplicaSet {
    dispatcher: Option<JoinHandle<()>>,
    replicas: Vec<ReplicaHandle>,
    /// Threads the dispatcher respawned mid-run (module docs); joined
    /// after the originals at shutdown.
    respawned: Arc<Mutex<Vec<JoinHandle<()>>>>,
    admission: Admission,
    input_len: usize,
    output_len: usize,
    batch: usize,
}

impl ReplicaSet {
    /// Spawn one replica worker per factory (each factory runs *inside*
    /// its replica's thread) plus the dispatcher draining `rx`.
    /// Returns once every backend constructed successfully; any factory
    /// error aborts the whole set and is returned.
    ///
    /// Factories are `Fn`, not `FnOnce`: the dispatcher keeps them to
    /// respawn a replica whose thread died (module docs).
    pub fn start<B, F>(
        factories: Vec<F>,
        cfg: BatcherConfig,
        admission_cfg: AdmissionConfig,
        rx: mpsc::Receiver<Request>,
    ) -> anyhow::Result<Self>
    where
        B: Backend,
        F: Fn() -> anyhow::Result<B> + Send + Sync + 'static,
    {
        assert!(!factories.is_empty(), "a ReplicaSet needs >= 1 replica");
        let admission = Admission::new(admission_cfg);
        let batch_cap = cfg.batch;
        let mut replicas = Vec::new();
        let mut routes = Vec::new();
        let mut inits = Vec::new();
        let mut ctx_factories = Vec::new();
        for (idx, factory) in factories.into_iter().enumerate() {
            let factory = Arc::new(factory);
            let (btx, brx) = mpsc::channel::<Batch>();
            let (init_tx, init_rx) =
                mpsc::channel::<anyhow::Result<(usize, usize, usize)>>();
            let outstanding = Arc::new(AtomicUsize::new(0));
            let stats = Arc::new(Mutex::new(ServeStats::default()));
            let handle = spawn_replica(
                idx,
                factory.clone(),
                brx,
                batch_cap,
                stats.clone(),
                outstanding.clone(),
                admission.clone(),
                Some(init_tx),
            );
            inits.push(init_rx);
            ctx_factories.push(factory);
            routes.push(ReplicaRoute { tx: btx, outstanding: outstanding.clone() });
            replicas.push(ReplicaHandle {
                outstanding,
                stats,
                handle: Some(handle),
            });
        }
        // collect every replica's init result; one failure fails the set
        let mut dims: Option<(usize, usize, usize)> = None;
        let mut first_err: Option<anyhow::Error> = None;
        for (idx, init) in inits.iter().enumerate() {
            let got = match init.recv() {
                Ok(Ok(d)) => Some(d),
                Ok(Err(e)) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                    None
                }
                Err(_) => {
                    if first_err.is_none() {
                        first_err = Some(anyhow::anyhow!(
                            "replica {idx} died during init"
                        ));
                    }
                    None
                }
            };
            match (dims, got) {
                (None, Some(d)) => dims = Some(d),
                (Some(d0), Some(d)) if d0 != d && first_err.is_none() => {
                    first_err = Some(anyhow::anyhow!(
                        "replica {idx}: backend dims {d:?} disagree with \
                         replica 0's {d0:?}"
                    ));
                }
                _ => {}
            }
        }
        if let Some(e) = first_err {
            // close every batch channel so live replicas exit, then join
            drop(routes);
            for r in &mut replicas {
                if let Some(h) = r.handle.take() {
                    let _ = h.join();
                }
            }
            return Err(e);
        }
        let (input_len, output_len, batch) =
            dims.expect("at least one replica initialized");
        let respawned = Arc::new(Mutex::new(Vec::new()));
        let dispatcher = std::thread::Builder::new()
            .name("ffip-dispatch".into())
            .spawn({
                let admission = admission.clone();
                let ctx = RespawnCtx {
                    factories: ctx_factories,
                    stats: replicas.iter().map(|r| r.stats.clone()).collect(),
                    batch_cap,
                    respawned: respawned.clone(),
                };
                move || {
                    dispatcher_loop(Batcher::new(cfg, rx), routes, &admission, ctx)
                }
            })
            .expect("spawn dispatcher");
        Ok(ReplicaSet {
            dispatcher: Some(dispatcher),
            replicas,
            respawned,
            admission,
            input_len,
            output_len,
            batch,
        })
    }

    pub fn replica_count(&self) -> usize {
        self.replicas.len()
    }

    /// The set's admission controller (shared with every replica).
    pub fn admission(&self) -> &Admission {
        &self.admission
    }

    /// `(input_len, output_len, batch)` of the replicated backend.
    pub fn dims(&self) -> (usize, usize, usize) {
        (self.input_len, self.output_len, self.batch)
    }

    /// Batches currently in flight per replica (the stealing signal).
    pub fn outstanding(&self) -> Vec<usize> {
        self.replicas
            .iter()
            .map(|r| r.outstanding.load(Ordering::Relaxed))
            .collect()
    }

    /// Merged live snapshot: every replica's stats folded together
    /// ([`ServeStats::merge_from`]) plus the per-replica breakdown and
    /// the admission shed counter.
    pub fn stats(&self) -> ServeStats {
        let mut agg = ServeStats::default();
        for r in &self.replicas {
            // merge straight from the guard — no intermediate clone of
            // the (unbounded) latency vector while the replica's
            // response loop contends for the same mutex
            let s = r.stats.lock().unwrap();
            agg.replicas.push(ReplicaStats {
                requests: s.count(),
                batches: s.batches,
                busy_us: s.busy_us,
            });
            agg.merge_from(&s);
        }
        agg.shed = self.admission.shed_count();
        agg
    }

    /// Join the dispatcher and *every* replica worker, then return the
    /// final merged stats.  The caller must have dropped all request
    /// senders first (the dispatcher exits when the batcher drains), so
    /// every queued request is served before the snapshot is taken.
    pub fn shutdown(mut self) -> ServeStats {
        self.join();
        self.stats()
    }

    fn join(&mut self) {
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
        // the dispatcher owned the batch senders; its exit closed every
        // replica channel, so the replicas drain and stop
        for r in &mut self.replicas {
            if let Some(h) = r.handle.take() {
                let _ = h.join();
            }
        }
        // replicas the dispatcher respawned mid-run parked their
        // handles here; the dispatcher is gone, so no more appear
        for h in self.respawned.lock().unwrap().drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for ReplicaSet {
    fn drop(&mut self) {
        self.join();
    }
}

/// Round-robin with least-outstanding-work stealing: the rotating
/// candidate `rr` wins unless another replica has strictly fewer
/// batches in flight (first such replica in rotation order wins, so
/// equally-idle replicas still rotate).
fn pick_replica(rr: usize, routes: &[ReplicaRoute]) -> usize {
    let n = routes.len();
    let mut best = rr % n;
    let mut best_load = routes[best].outstanding.load(Ordering::Relaxed);
    for off in 1..n {
        let i = (rr + off) % n;
        let load = routes[i].outstanding.load(Ordering::Relaxed);
        if load < best_load {
            best = i;
            best_load = load;
        }
    }
    best
}

/// Spawn one replica worker thread: run the factory *inside* the
/// thread, validate the backend against the batcher's batch size, then
/// serve [`replica_loop`].  With `init_tx` (initial start) a factory
/// error is reported back and the thread exits — the half-built set
/// tears down.  Without it (dispatcher respawn) there is nobody to
/// report to, so a failed rebuild instead drains the batch channel and
/// answers everything typed — queued work is never dropped with its
/// admission slots pinned.
#[allow(clippy::too_many_arguments)]
fn spawn_replica<B, F>(
    idx: usize,
    factory: Arc<F>,
    brx: mpsc::Receiver<Batch>,
    batch_cap: usize,
    stats: Arc<Mutex<ServeStats>>,
    outstanding: Arc<AtomicUsize>,
    admission: Admission,
    init_tx: Option<mpsc::Sender<anyhow::Result<(usize, usize, usize)>>>,
) -> JoinHandle<()>
where
    B: Backend,
    F: Fn() -> anyhow::Result<B> + Send + Sync + 'static,
{
    std::thread::Builder::new()
        .name(format!("ffip-replica-{idx}"))
        .spawn(move || {
            let built = match factory() {
                Ok(b) if b.batch() != batch_cap => Err(anyhow::anyhow!(
                    "replica {idx}: backend batch {} != \
                     batcher batch {batch_cap}",
                    b.batch()
                )),
                other => other,
            };
            match built {
                Ok(backend) => {
                    if let Some(tx) = init_tx {
                        let _ = tx.send(Ok((
                            backend.input_len(),
                            backend.output_len(),
                            backend.batch(),
                        )));
                    }
                    replica_loop(
                        backend,
                        brx,
                        &stats,
                        &outstanding,
                        &admission,
                    );
                }
                Err(e) => match init_tx {
                    Some(tx) => {
                        let _ = tx.send(Err(e));
                    }
                    None => {
                        let msg = format!("replica respawn failed: {e:#}");
                        while let Ok(batch) = brx.recv() {
                            outstanding.fetch_sub(1, Ordering::Relaxed);
                            fail_batch(batch, &msg, &admission);
                        }
                    }
                },
            }
        })
        .expect("spawn replica worker")
}

/// Form batches and dispatch each to a replica until every request
/// sender is gone and the queue is drained.  A send to a dead replica
/// (its thread died — a panic escaped the per-batch backstop) respawns
/// the worker from the shared factory and re-dispatches the batch; the
/// death is counted in that replica's
/// [`ServeStats::faults`]`.backend_panics`.
fn dispatcher_loop<B, F>(
    mut batcher: Batcher,
    mut routes: Vec<ReplicaRoute>,
    admission: &Admission,
    ctx: RespawnCtx<F>,
) where
    B: Backend,
    F: Fn() -> anyhow::Result<B> + Send + Sync + 'static,
{
    let mut rr = 0usize;
    while let Some(batch) = batcher.next_batch() {
        let idx = pick_replica(rr, &routes);
        rr = (rr + 1) % routes.len();
        routes[idx].outstanding.fetch_add(1, Ordering::Relaxed);
        let sent = routes[idx].tx.send(batch);
        if let Err(mpsc::SendError(batch)) = sent {
            // the replica thread is gone: count the corpse, rebuild the
            // backend from the shared compiled artifact on a fresh
            // thread, and hand it the batch that found the body
            ctx.stats[idx].lock().unwrap().faults.backend_panics += 1;
            let (btx, brx) = mpsc::channel::<Batch>();
            let handle = spawn_replica(
                idx,
                ctx.factories[idx].clone(),
                brx,
                ctx.batch_cap,
                ctx.stats[idx].clone(),
                routes[idx].outstanding.clone(),
                admission.clone(),
                None,
            );
            ctx.respawned.lock().unwrap().push(handle);
            routes[idx].tx = btx;
            let resent = routes[idx].tx.send(batch);
            if let Err(mpsc::SendError(batch)) = resent {
                // unreachable in practice (the fresh thread holds the
                // receiver until it exits), but never drop a batch
                routes[idx].outstanding.fetch_sub(1, Ordering::Relaxed);
                fail_batch(batch, "replica worker is gone", admission);
            }
        }
    }
}

/// One replica worker: execute dispatched batches on its own backend,
/// answer every request (success or typed error), record into the
/// replica's private stats, and release each request's admission slot.
fn replica_loop<B: Backend>(
    mut backend: B,
    rx: mpsc::Receiver<Batch>,
    stats: &Mutex<ServeStats>,
    outstanding: &AtomicUsize,
    admission: &Admission,
) {
    {
        let mut s = stats.lock().unwrap();
        s.started = Some(Instant::now());
    }
    while let Ok(batch) = rx.recv() {
        run_batch(&mut backend, batch, stats, admission);
        outstanding.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Execute one batch (the historical coordinator worker-loop body):
/// sweep malformed and out-of-domain requests into typed per-request
/// errors, pad, infer, validate the output geometry, respond.
fn run_batch<B: Backend>(
    backend: &mut B,
    mut batch: Batch,
    stats: &Mutex<ServeStats>,
    admission: &Admission,
) {
    let in_len = backend.input_len();
    let out_len = backend.output_len();
    let cap = backend.batch();
    let t_batch = Instant::now();
    // malformed requests get typed error responses and never reach the
    // backend; the replica keeps serving
    for (req, t_in) in batch.take_malformed(in_len) {
        admission.complete();
        let _ = req.resp.send(Response {
            id: req.id,
            result: Err(RequestError::BadShape {
                expected: in_len,
                got: req.input.len(),
            }),
            latency: t_in.elapsed(),
        });
    }
    // invalid ragged length prefixes on attention backends are
    // structural (wire format), so they sweep before the value-domain
    // pass: one bad sequence length never fails its co-batched
    // neighbours, and reports as BadSequence even when the prefix also
    // happens to be out of the storage domain
    if let Some(max_seq) = backend.max_seq() {
        for (req, t_in, len) in batch.take_bad_sequence(max_seq) {
            admission.complete();
            let _ = req.resp.send(Response {
                id: req.id,
                result: Err(RequestError::BadSequence { len, max_seq }),
                latency: t_in.elapsed(),
            });
        }
    }
    // likewise out-of-domain values on narrow-storage backends:
    // per-request rejection, never a batch fault
    if let Some(bits) = backend.input_domain_bits() {
        for (req, t_in, value) in batch.take_out_of_domain(bits) {
            admission.complete();
            let _ = req.resp.send(Response {
                id: req.id,
                result: Err(RequestError::Domain { value, bits }),
                latency: t_in.elapsed(),
            });
        }
    }
    // stale work sheds typed before spending a batch slot: requests
    // queued behind a slow or wedged batch past the deployment's
    // deadline are answered DeadlineExceeded, their slots freed
    if let Some(deadline) = backend.request_deadline() {
        let expired = batch.take_expired(deadline);
        if !expired.is_empty() {
            stats.lock().unwrap().faults.deadline_shed +=
                expired.len() as u64;
            for (req, t_in) in expired {
                admission.complete();
                let waited = t_in.elapsed();
                let _ = req.resp.send(Response {
                    id: req.id,
                    result: Err(RequestError::DeadlineExceeded {
                        waited_ms: waited.as_millis() as u64,
                        deadline_ms: deadline.as_millis() as u64,
                    }),
                    latency: waited,
                });
            }
        }
    }
    if batch.is_empty() {
        return;
    }
    let padded = batch.padded_input(cap, in_len);
    let view = TensorView::new(cap, in_len, &padded);
    // a panicking backend must not unwind the replica thread: that
    // would drop the batch's response channels unanswered AND leak its
    // admission slots (each panic pins `batch` slots of a bounded
    // deployment's depth forever).  Catch it and fail the batch typed,
    // like any other backend error — the replica keeps serving.
    let inferred =
        std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            backend.infer(view)
        }));
    let outputs = match inferred {
        Ok(Ok(out)) if out.rows() == cap && out.row_len() == out_len => out,
        Ok(Ok(out)) => {
            drain_fault_counts(backend, stats);
            fail_batch(
                batch,
                &format!(
                    "backend returned {}x{} for a {cap}x{out_len} batch",
                    out.rows(),
                    out.row_len()
                ),
                admission,
            );
            return;
        }
        Ok(Err(err)) => {
            // the backend's own fault counters (ABFT trips on the way
            // to the shed, watchdog expiries) still land in the stats
            drain_fault_counts(backend, stats);
            // a typed error (FaultDetected, DeadlineExceeded) reaches
            // every rider verbatim; anything else wraps as Backend
            match err.downcast::<RequestError>() {
                Ok(e) => fail_batch_typed(batch, &e, admission),
                Err(err) => {
                    fail_batch(batch, &format!("{err:#}"), admission)
                }
            }
            return;
        }
        Err(_panic) => {
            // counted, not printed: panic recoveries are observable in
            // ServeStats.faults, and the replica keeps serving
            stats.lock().unwrap().faults.backend_panics += 1;
            fail_batch(batch, "backend panicked on this batch", admission);
            return;
        }
    };
    let done = Instant::now();
    // one stats lock per batch (not per request): the same mutex backs
    // live ReplicaSet::stats() snapshots, so the response loop below
    // runs lock-free
    {
        let mut s = stats.lock().unwrap();
        s.record_batch(batch.len(), cap);
        s.record_busy(done - t_batch);
        if let Some(ps) = backend.engine_stats() {
            s.record_engine(&ps);
        }
        if let Some(lt) = backend.layer_timings() {
            s.record_layer_timings(&lt);
        }
        if let Some(fc) = backend.fault_counts() {
            // transparently healed faults (ABFT recomputes) ride the
            // same drain as the fatal ones
            s.faults.merge_from(&fc);
        }
        for (_, t_in) in &batch.requests {
            s.record_latency(done - *t_in);
        }
        s.finished = Some(done);
    }
    for (slot, (req, t_in)) in batch.requests.into_iter().enumerate() {
        let latency = done - t_in;
        admission.complete();
        let row = outputs.row(slot).to_vec();
        // receiver may have gone away; that's fine
        let _ = req.resp.send(Response {
            id: req.id,
            result: Ok(Tensor::new(1, out_len, row)),
            latency,
        });
    }
}

/// Answer every request of a failed batch with a typed backend error,
/// releasing each one's admission slot.
fn fail_batch(batch: Batch, msg: &str, admission: &Admission) {
    fail_batch_typed(batch, &RequestError::Backend(msg.to_string()), admission)
}

/// Answer every request of a failed batch with the given typed error
/// (`FaultDetected`, `DeadlineExceeded`, ...), releasing each one's
/// admission slot.
fn fail_batch_typed(batch: Batch, err: &RequestError, admission: &Admission) {
    for (req, t_in) in batch.requests {
        admission.complete();
        let _ = req.resp.send(Response {
            id: req.id,
            result: Err(err.clone()),
            latency: t_in.elapsed(),
        });
    }
}

/// Fold the backend's accumulated fault counters into the replica's
/// stats — the error-path twin of the per-batch drain in the success
/// block (which already holds the lock).
fn drain_fault_counts<B: Backend>(
    backend: &mut B,
    stats: &Mutex<ServeStats>,
) {
    if let Some(fc) = backend.fault_counts() {
        if fc.any() {
            stats.lock().unwrap().faults.merge_from(&fc);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[allow(clippy::type_complexity)]
    fn routes(
        loads: &[usize],
    ) -> (Vec<ReplicaRoute>, Vec<mpsc::Receiver<Batch>>) {
        loads
            .iter()
            .map(|&l| {
                let (tx, rx) = mpsc::channel::<Batch>();
                (
                    ReplicaRoute {
                        tx,
                        outstanding: Arc::new(AtomicUsize::new(l)),
                    },
                    rx,
                )
            })
            .unzip()
    }

    #[test]
    fn pick_prefers_round_robin_among_equal_loads() {
        let (r, _keep) = routes(&[0, 0, 0]);
        assert_eq!(pick_replica(0, &r), 0);
        assert_eq!(pick_replica(1, &r), 1);
        assert_eq!(pick_replica(2, &r), 2);
        assert_eq!(pick_replica(3, &r), 0, "rotation wraps");
    }

    #[test]
    fn pick_steals_toward_strictly_less_outstanding_work() {
        // replica 0 (the rr candidate) is backed up; 2 is idle
        let (r, _keep) = routes(&[3, 2, 0]);
        assert_eq!(pick_replica(0, &r), 2);
        // ties do NOT steal: rr candidate keeps the dispatch
        let (r, _keep2) = routes(&[1, 1, 1]);
        assert_eq!(pick_replica(1, &r), 1);
        // first-less-loaded in rotation order wins among equals
        let (r, _keep3) = routes(&[5, 2, 2]);
        assert_eq!(pick_replica(0, &r), 1);
    }
}
