//! Replica-sharded batch execution: one batcher feeding N session
//! replicas on the shared pool.
//!
//! One deployment used to be one worker — one batch in flight, the
//! shared [`GemmPool`](crate::engine::GemmPool) idling between layers
//! while staging ran on the critical path.  A [`ReplicaSet`] splits the
//! deployment into:
//!
//! * a **dispatcher** thread running the existing [`Batcher`] and
//!   handing each formed batch to a replica, **round-robin with
//!   least-outstanding-work stealing**: the rotating candidate wins
//!   ties, but any replica with strictly fewer batches in flight steals
//!   the dispatch, so a replica stuck on a slow batch never builds a
//!   private backlog while its peers idle;
//! * N **replica workers**, each owning one backend built inside its
//!   own thread (PJRT handles are not `Send`, and session replicas are
//!   cheap — compiled weights and offline FFIP y terms stay
//!   `Arc`-shared, only staging/activation buffers are per-replica).
//!
//! Every replica records into its own private
//! [`ServeStats`] — no cross-replica lock contention on the hot path —
//! and snapshots merge by name-aligned layer stats
//! ([`ServeStats::merge_from`]), so undeploy returns one coherent view
//! even when work stealing left the replicas with different batch
//! counts.  The [`Admission`] controller's depth counter spans the
//! whole set: a request admitted at submit is released only when its
//! response (success *or* typed error) is sent by whichever replica
//! served it.

use super::super::batcher::{Batch, Batcher, BatcherConfig};
use super::super::server::Backend;
use super::super::stats::{ReplicaStats, ServeStats};
use super::super::tensor::{RequestError, Tensor, TensorView};
use super::super::{Request, Response};
use super::admission::{Admission, AdmissionConfig};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

/// What the dispatcher holds per replica: the batch channel and the
/// in-flight batch counter the stealing policy reads.
struct ReplicaRoute {
    tx: mpsc::Sender<Batch>,
    outstanding: Arc<AtomicUsize>,
}

/// What the [`ReplicaSet`] holds per replica: the private stats and the
/// join handle (the batch sender lives with the dispatcher, so the
/// dispatcher's exit is what drains and stops the replicas).
struct ReplicaHandle {
    outstanding: Arc<AtomicUsize>,
    stats: Arc<Mutex<ServeStats>>,
    handle: Option<std::thread::JoinHandle<()>>,
}

/// A batcher-fed set of replica workers over one backend type (module
/// docs).  Constructed by
/// [`Coordinator::start_replicated`](crate::coordinator::Coordinator::start_replicated).
pub struct ReplicaSet {
    dispatcher: Option<std::thread::JoinHandle<()>>,
    replicas: Vec<ReplicaHandle>,
    admission: Admission,
    input_len: usize,
    output_len: usize,
    batch: usize,
}

impl ReplicaSet {
    /// Spawn one replica worker per factory (each factory runs *inside*
    /// its replica's thread) plus the dispatcher draining `rx`.
    /// Returns once every backend constructed successfully; any factory
    /// error aborts the whole set and is returned.
    pub fn start<B, F>(
        factories: Vec<F>,
        cfg: BatcherConfig,
        admission_cfg: AdmissionConfig,
        rx: mpsc::Receiver<Request>,
    ) -> anyhow::Result<Self>
    where
        B: Backend,
        F: FnOnce() -> anyhow::Result<B> + Send + 'static,
    {
        assert!(!factories.is_empty(), "a ReplicaSet needs >= 1 replica");
        let admission = Admission::new(admission_cfg);
        let mut replicas = Vec::new();
        let mut routes = Vec::new();
        let mut inits = Vec::new();
        for (idx, factory) in factories.into_iter().enumerate() {
            let (btx, brx) = mpsc::channel::<Batch>();
            let (init_tx, init_rx) =
                mpsc::channel::<anyhow::Result<(usize, usize, usize)>>();
            let outstanding = Arc::new(AtomicUsize::new(0));
            let stats = Arc::new(Mutex::new(ServeStats::default()));
            let stats_w = stats.clone();
            let out_w = outstanding.clone();
            let adm = admission.clone();
            let batch_cap = cfg.batch;
            let handle = std::thread::Builder::new()
                .name(format!("ffip-replica-{idx}"))
                .spawn(move || {
                    let backend = match factory() {
                        Ok(b) if b.batch() != batch_cap => {
                            let _ = init_tx.send(Err(anyhow::anyhow!(
                                "replica {idx}: backend batch {} != \
                                 batcher batch {batch_cap}",
                                b.batch()
                            )));
                            return;
                        }
                        Ok(b) => {
                            let dims =
                                (b.input_len(), b.output_len(), b.batch());
                            let _ = init_tx.send(Ok(dims));
                            b
                        }
                        Err(e) => {
                            let _ = init_tx.send(Err(e));
                            return;
                        }
                    };
                    replica_loop(backend, brx, &stats_w, &out_w, &adm);
                })
                .expect("spawn replica worker");
            inits.push(init_rx);
            routes.push(ReplicaRoute { tx: btx, outstanding: outstanding.clone() });
            replicas.push(ReplicaHandle {
                outstanding,
                stats,
                handle: Some(handle),
            });
        }
        // collect every replica's init result; one failure fails the set
        let mut dims: Option<(usize, usize, usize)> = None;
        let mut first_err: Option<anyhow::Error> = None;
        for (idx, init) in inits.iter().enumerate() {
            let got = match init.recv() {
                Ok(Ok(d)) => Some(d),
                Ok(Err(e)) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                    None
                }
                Err(_) => {
                    if first_err.is_none() {
                        first_err = Some(anyhow::anyhow!(
                            "replica {idx} died during init"
                        ));
                    }
                    None
                }
            };
            match (dims, got) {
                (None, Some(d)) => dims = Some(d),
                (Some(d0), Some(d)) if d0 != d && first_err.is_none() => {
                    first_err = Some(anyhow::anyhow!(
                        "replica {idx}: backend dims {d:?} disagree with \
                         replica 0's {d0:?}"
                    ));
                }
                _ => {}
            }
        }
        if let Some(e) = first_err {
            // close every batch channel so live replicas exit, then join
            drop(routes);
            for r in &mut replicas {
                if let Some(h) = r.handle.take() {
                    let _ = h.join();
                }
            }
            return Err(e);
        }
        let (input_len, output_len, batch) =
            dims.expect("at least one replica initialized");
        let dispatcher = std::thread::Builder::new()
            .name("ffip-dispatch".into())
            .spawn({
                let admission = admission.clone();
                move || dispatcher_loop(Batcher::new(cfg, rx), routes, &admission)
            })
            .expect("spawn dispatcher");
        Ok(ReplicaSet {
            dispatcher: Some(dispatcher),
            replicas,
            admission,
            input_len,
            output_len,
            batch,
        })
    }

    pub fn replica_count(&self) -> usize {
        self.replicas.len()
    }

    /// The set's admission controller (shared with every replica).
    pub fn admission(&self) -> &Admission {
        &self.admission
    }

    /// `(input_len, output_len, batch)` of the replicated backend.
    pub fn dims(&self) -> (usize, usize, usize) {
        (self.input_len, self.output_len, self.batch)
    }

    /// Batches currently in flight per replica (the stealing signal).
    pub fn outstanding(&self) -> Vec<usize> {
        self.replicas
            .iter()
            .map(|r| r.outstanding.load(Ordering::Relaxed))
            .collect()
    }

    /// Merged live snapshot: every replica's stats folded together
    /// ([`ServeStats::merge_from`]) plus the per-replica breakdown and
    /// the admission shed counter.
    pub fn stats(&self) -> ServeStats {
        let mut agg = ServeStats::default();
        for r in &self.replicas {
            // merge straight from the guard — no intermediate clone of
            // the (unbounded) latency vector while the replica's
            // response loop contends for the same mutex
            let s = r.stats.lock().unwrap();
            agg.replicas.push(ReplicaStats {
                requests: s.count(),
                batches: s.batches,
                busy_us: s.busy_us,
            });
            agg.merge_from(&s);
        }
        agg.shed = self.admission.shed_count();
        agg
    }

    /// Join the dispatcher and *every* replica worker, then return the
    /// final merged stats.  The caller must have dropped all request
    /// senders first (the dispatcher exits when the batcher drains), so
    /// every queued request is served before the snapshot is taken.
    pub fn shutdown(mut self) -> ServeStats {
        self.join();
        self.stats()
    }

    fn join(&mut self) {
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
        // the dispatcher owned the batch senders; its exit closed every
        // replica channel, so the replicas drain and stop
        for r in &mut self.replicas {
            if let Some(h) = r.handle.take() {
                let _ = h.join();
            }
        }
    }
}

impl Drop for ReplicaSet {
    fn drop(&mut self) {
        self.join();
    }
}

/// Round-robin with least-outstanding-work stealing: the rotating
/// candidate `rr` wins unless another replica has strictly fewer
/// batches in flight (first such replica in rotation order wins, so
/// equally-idle replicas still rotate).
fn pick_replica(rr: usize, routes: &[ReplicaRoute]) -> usize {
    let n = routes.len();
    let mut best = rr % n;
    let mut best_load = routes[best].outstanding.load(Ordering::Relaxed);
    for off in 1..n {
        let i = (rr + off) % n;
        let load = routes[i].outstanding.load(Ordering::Relaxed);
        if load < best_load {
            best = i;
            best_load = load;
        }
    }
    best
}

/// Form batches and dispatch each to a replica until every request
/// sender is gone and the queue is drained.
fn dispatcher_loop(
    mut batcher: Batcher,
    routes: Vec<ReplicaRoute>,
    admission: &Admission,
) {
    let mut rr = 0usize;
    while let Some(batch) = batcher.next_batch() {
        let idx = pick_replica(rr, &routes);
        rr = (rr + 1) % routes.len();
        let route = &routes[idx];
        route.outstanding.fetch_add(1, Ordering::Relaxed);
        if let Err(mpsc::SendError(batch)) = route.tx.send(batch) {
            // the replica worker is gone (backend panic); answer the
            // batch with typed errors instead of dropping the channels
            route.outstanding.fetch_sub(1, Ordering::Relaxed);
            fail_batch(batch, "replica worker is gone", admission);
        }
    }
}

/// One replica worker: execute dispatched batches on its own backend,
/// answer every request (success or typed error), record into the
/// replica's private stats, and release each request's admission slot.
fn replica_loop<B: Backend>(
    mut backend: B,
    rx: mpsc::Receiver<Batch>,
    stats: &Mutex<ServeStats>,
    outstanding: &AtomicUsize,
    admission: &Admission,
) {
    {
        let mut s = stats.lock().unwrap();
        s.started = Some(Instant::now());
    }
    while let Ok(batch) = rx.recv() {
        run_batch(&mut backend, batch, stats, admission);
        outstanding.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Execute one batch (the historical coordinator worker-loop body):
/// sweep malformed and out-of-domain requests into typed per-request
/// errors, pad, infer, validate the output geometry, respond.
fn run_batch<B: Backend>(
    backend: &mut B,
    mut batch: Batch,
    stats: &Mutex<ServeStats>,
    admission: &Admission,
) {
    let in_len = backend.input_len();
    let out_len = backend.output_len();
    let cap = backend.batch();
    let t_batch = Instant::now();
    // malformed requests get typed error responses and never reach the
    // backend; the replica keeps serving
    for (req, t_in) in batch.take_malformed(in_len) {
        admission.complete();
        let _ = req.resp.send(Response {
            id: req.id,
            result: Err(RequestError::BadShape {
                expected: in_len,
                got: req.input.len(),
            }),
            latency: t_in.elapsed(),
        });
    }
    // invalid ragged length prefixes on attention backends are
    // structural (wire format), so they sweep before the value-domain
    // pass: one bad sequence length never fails its co-batched
    // neighbours, and reports as BadSequence even when the prefix also
    // happens to be out of the storage domain
    if let Some(max_seq) = backend.max_seq() {
        for (req, t_in, len) in batch.take_bad_sequence(max_seq) {
            admission.complete();
            let _ = req.resp.send(Response {
                id: req.id,
                result: Err(RequestError::BadSequence { len, max_seq }),
                latency: t_in.elapsed(),
            });
        }
    }
    // likewise out-of-domain values on narrow-storage backends:
    // per-request rejection, never a batch fault
    if let Some(bits) = backend.input_domain_bits() {
        for (req, t_in, value) in batch.take_out_of_domain(bits) {
            admission.complete();
            let _ = req.resp.send(Response {
                id: req.id,
                result: Err(RequestError::Domain { value, bits }),
                latency: t_in.elapsed(),
            });
        }
    }
    if batch.is_empty() {
        return;
    }
    let padded = batch.padded_input(cap, in_len);
    let view = TensorView::new(cap, in_len, &padded);
    // a panicking backend must not unwind the replica thread: that
    // would drop the batch's response channels unanswered AND leak its
    // admission slots (each panic pins `batch` slots of a bounded
    // deployment's depth forever).  Catch it and fail the batch typed,
    // like any other backend error — the replica keeps serving.
    let inferred =
        std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            backend.infer(view)
        }));
    let outputs = match inferred {
        Ok(Ok(out)) if out.rows() == cap && out.row_len() == out_len => out,
        Ok(Ok(out)) => {
            fail_batch(
                batch,
                &format!(
                    "backend returned {}x{} for a {cap}x{out_len} batch",
                    out.rows(),
                    out.row_len()
                ),
                admission,
            );
            return;
        }
        Ok(Err(err)) => {
            // fail the whole batch with typed error responses
            eprintln!("backend error: {err:#}");
            fail_batch(batch, &format!("{err:#}"), admission);
            return;
        }
        Err(_panic) => {
            eprintln!("backend panicked on a batch; replica continues");
            fail_batch(batch, "backend panicked on this batch", admission);
            return;
        }
    };
    let done = Instant::now();
    // one stats lock per batch (not per request): the same mutex backs
    // live ReplicaSet::stats() snapshots, so the response loop below
    // runs lock-free
    {
        let mut s = stats.lock().unwrap();
        s.record_batch(batch.len(), cap);
        s.record_busy(done - t_batch);
        if let Some(ps) = backend.engine_stats() {
            s.record_engine(&ps);
        }
        if let Some(lt) = backend.layer_timings() {
            s.record_layer_timings(&lt);
        }
        for (_, t_in) in &batch.requests {
            s.record_latency(done - *t_in);
        }
        s.finished = Some(done);
    }
    for (slot, (req, t_in)) in batch.requests.into_iter().enumerate() {
        let latency = done - t_in;
        admission.complete();
        let row = outputs.row(slot).to_vec();
        // receiver may have gone away; that's fine
        let _ = req.resp.send(Response {
            id: req.id,
            result: Ok(Tensor::new(1, out_len, row)),
            latency,
        });
    }
}

/// Answer every request of a failed batch with a typed backend error,
/// releasing each one's admission slot.
fn fail_batch(batch: Batch, msg: &str, admission: &Admission) {
    for (req, t_in) in batch.requests {
        admission.complete();
        let _ = req.resp.send(Response {
            id: req.id,
            result: Err(RequestError::Backend(msg.to_string())),
            latency: t_in.elapsed(),
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[allow(clippy::type_complexity)]
    fn routes(
        loads: &[usize],
    ) -> (Vec<ReplicaRoute>, Vec<mpsc::Receiver<Batch>>) {
        loads
            .iter()
            .map(|&l| {
                let (tx, rx) = mpsc::channel::<Batch>();
                (
                    ReplicaRoute {
                        tx,
                        outstanding: Arc::new(AtomicUsize::new(l)),
                    },
                    rx,
                )
            })
            .unzip()
    }

    #[test]
    fn pick_prefers_round_robin_among_equal_loads() {
        let (r, _keep) = routes(&[0, 0, 0]);
        assert_eq!(pick_replica(0, &r), 0);
        assert_eq!(pick_replica(1, &r), 1);
        assert_eq!(pick_replica(2, &r), 2);
        assert_eq!(pick_replica(3, &r), 0, "rotation wraps");
    }

    #[test]
    fn pick_steals_toward_strictly_less_outstanding_work() {
        // replica 0 (the rr candidate) is backed up; 2 is idle
        let (r, _keep) = routes(&[3, 2, 0]);
        assert_eq!(pick_replica(0, &r), 2);
        // ties do NOT steal: rr candidate keeps the dispatch
        let (r, _keep2) = routes(&[1, 1, 1]);
        assert_eq!(pick_replica(1, &r), 1);
        // first-less-loaded in rotation order wins among equals
        let (r, _keep3) = routes(&[5, 2, 2]);
        assert_eq!(pick_replica(0, &r), 1);
    }
}
