//! The coordinator worker: batcher -> backend -> responses.

use super::batcher::{Batcher, BatcherConfig};
use super::session::LayerTiming;
use super::stats::ServeStats;
use super::tensor::{RequestError, Tensor, TensorView};
use super::{Request, Response};
use crate::engine::PoolStats;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// An inference backend: consumes one padded batch tensor, returns one
/// output row per batch slot.
///
/// Backends need not be `Send` — PJRT handles hold `Rc`s — so the
/// coordinator constructs them *inside* its worker thread from a `Send`
/// factory closure ([`Coordinator::start`]).
pub trait Backend: 'static {
    /// Flat input row length per request.
    fn input_len(&self) -> usize;
    /// Output row length per request.
    fn output_len(&self) -> usize;
    /// Fixed accelerator batch size.
    fn batch(&self) -> usize;
    /// Run one padded batch (`batch() x input_len()` values); must
    /// return a `batch() x output_len()` tensor.
    fn infer(&mut self, batch: TensorView<'_>) -> anyhow::Result<Tensor>;
    /// Signed bit-width of the per-value input domain this backend
    /// accepts, when constrained (narrow-storage sessions); `None`
    /// means any `i32` is acceptable.  The worker sweeps out-of-domain
    /// requests *per request* before the batch reaches [`infer`], so
    /// one bad value never fails its co-batched neighbours.
    ///
    /// [`infer`]: Backend::infer
    fn input_domain_bits(&self) -> Option<u32> {
        None
    }

    /// Counters of the GEMM execution engine this backend runs on, if
    /// any; sampled into [`ServeStats`] after every batch.
    fn engine_stats(&self) -> Option<PoolStats> {
        None
    }
    /// Per-layer wall times of the most recent batch, if the backend
    /// measures them (drained per batch into [`ServeStats`]).
    fn layer_timings(&mut self) -> Option<Vec<LayerTiming>> {
        None
    }
}

/// Trivial backend for tests: output = input * 2.
pub struct EchoBackend {
    pub len: usize,
    pub batch: usize,
}

impl Backend for EchoBackend {
    fn input_len(&self) -> usize {
        self.len
    }
    fn output_len(&self) -> usize {
        self.len
    }
    fn batch(&self) -> usize {
        self.batch
    }
    fn infer(&mut self, batch: TensorView<'_>) -> anyhow::Result<Tensor> {
        let data = batch.data.iter().map(|&v| (v * 2) as f32).collect();
        Ok(Tensor::new(batch.rows(), batch.row_len(), data))
    }
}

/// Handle to a running coordinator.
pub struct Coordinator {
    tx: mpsc::Sender<Request>,
    pub stats: Arc<Mutex<ServeStats>>,
    worker: Option<std::thread::JoinHandle<()>>,
    next_id: std::sync::atomic::AtomicU64,
    input_len: usize,
}

impl Coordinator {
    /// Spawn the worker thread; `factory` runs *inside* it to build the
    /// backend (PJRT executables are not `Send`).  Returns once the
    /// backend constructed successfully.
    pub fn start<B, F>(factory: F, cfg: BatcherConfig) -> anyhow::Result<Self>
    where
        B: Backend,
        F: FnOnce() -> anyhow::Result<B> + Send + 'static,
    {
        let (tx, rx) = mpsc::channel::<Request>();
        let (init_tx, init_rx) =
            mpsc::channel::<anyhow::Result<(usize, usize)>>();
        let stats = Arc::new(Mutex::new(ServeStats::default()));
        let stats_w = stats.clone();
        let worker = std::thread::spawn(move || {
            let mut backend = match factory() {
                Ok(b) => {
                    let dims = (b.input_len(), b.batch());
                    assert_eq!(
                        cfg.batch,
                        b.batch(),
                        "batcher/backend batch size"
                    );
                    let _ = init_tx.send(Ok(dims));
                    b
                }
                Err(e) => {
                    let _ = init_tx.send(Err(e));
                    return;
                }
            };
            let mut batcher = Batcher::new(cfg, rx);
            let in_len = backend.input_len();
            let out_len = backend.output_len();
            let cap = backend.batch();
            {
                let mut s = stats_w.lock().unwrap();
                s.started = Some(Instant::now());
            }
            let domain_bits = backend.input_domain_bits();
            while let Some(mut batch) = batcher.next_batch() {
                // malformed requests get typed error responses and never
                // reach the backend; the worker keeps serving
                for (req, t_in) in batch.take_malformed(in_len) {
                    let _ = req.resp.send(Response {
                        id: req.id,
                        result: Err(RequestError::BadShape {
                            expected: in_len,
                            got: req.input.len(),
                        }),
                        latency: t_in.elapsed(),
                    });
                }
                // likewise out-of-domain values on narrow-storage
                // backends: per-request rejection, never a batch fault
                if let Some(bits) = domain_bits {
                    for (req, t_in, value) in batch.take_out_of_domain(bits)
                    {
                        let _ = req.resp.send(Response {
                            id: req.id,
                            result: Err(RequestError::Domain {
                                value,
                                bits,
                            }),
                            latency: t_in.elapsed(),
                        });
                    }
                }
                if batch.is_empty() {
                    continue;
                }
                let padded = batch.padded_input(cap, in_len);
                let view = TensorView::new(cap, in_len, &padded);
                let outputs = match backend.infer(view) {
                    Ok(out)
                        if out.rows() == cap && out.row_len() == out_len =>
                    {
                        out
                    }
                    Ok(out) => {
                        fail_batch(
                            batch,
                            &format!(
                                "backend returned {}x{} for a {cap}x{out_len} \
                                 batch",
                                out.rows(),
                                out.row_len()
                            ),
                        );
                        continue;
                    }
                    Err(err) => {
                        // fail the whole batch with typed error responses
                        eprintln!("backend error: {err:#}");
                        fail_batch(batch, &format!("{err:#}"));
                        continue;
                    }
                };
                let done = Instant::now();
                {
                    let mut s = stats_w.lock().unwrap();
                    s.record_batch(batch.len(), cap);
                    if let Some(ps) = backend.engine_stats() {
                        s.record_engine(&ps);
                    }
                    if let Some(lt) = backend.layer_timings() {
                        s.record_layer_timings(&lt);
                    }
                    s.finished = Some(done);
                }
                for (slot, (req, t_in)) in
                    batch.requests.into_iter().enumerate()
                {
                    let latency = done - t_in;
                    {
                        let mut s = stats_w.lock().unwrap();
                        s.record_latency(latency);
                    }
                    let row = outputs.row(slot).to_vec();
                    // receiver may have gone away; that's fine
                    let _ = req.resp.send(Response {
                        id: req.id,
                        result: Ok(Tensor::new(1, out_len, row)),
                        latency,
                    });
                }
            }
        });
        let (input_len, _batch) = init_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("worker died during init"))??;
        Ok(Coordinator {
            tx,
            stats,
            worker: Some(worker),
            next_id: std::sync::atomic::AtomicU64::new(0),
            input_len,
        })
    }

    /// Submit asynchronously; returns the response receiver.  A request
    /// whose row length does not match the deployed model receives an
    /// immediate [`RequestError::BadShape`] response on that channel —
    /// it never occupies a batch slot.
    pub fn submit(&self, input: Vec<i32>) -> mpsc::Receiver<Response> {
        let (tx, rx) = mpsc::channel();
        let id = self
            .next_id
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        if input.len() != self.input_len {
            let _ = tx.send(Response {
                id,
                result: Err(RequestError::BadShape {
                    expected: self.input_len,
                    got: input.len(),
                }),
                latency: std::time::Duration::ZERO,
            });
            return rx;
        }
        self.tx
            .send(Request { id, input, resp: tx })
            .expect("coordinator worker alive");
        rx
    }

    /// Blocking inference.
    pub fn infer(&self, input: Vec<i32>) -> Response {
        self.submit(input).recv().expect("response")
    }

    /// Drain and stop the worker.
    pub fn shutdown(mut self) -> ServeStats {
        let stats = self.stats.clone();
        // dropping self.tx closes the channel -> worker exits
        let worker = self.worker.take();
        drop(self);
        if let Some(w) = worker {
            let _ = w.join();
        }
        let s = stats.lock().unwrap().clone();
        s
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        if let Some(w) = self.worker.take() {
            // close the request channel first by replacing tx
            let (dead_tx, _) = mpsc::channel();
            self.tx = dead_tx;
            let _ = w.join();
        }
    }
}

/// Answer every request of a failed batch with a typed backend error.
fn fail_batch(batch: super::batcher::Batch, msg: &str) {
    for (req, t_in) in batch.requests {
        let _ = req.resp.send(Response {
            id: req.id,
            result: Err(RequestError::Backend(msg.to_string())),
            latency: t_in.elapsed(),
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::{Algo, Mat};
    use crate::coordinator::{
        compile, DeployConfig, InferenceSession, Model, SessionBackend,
    };
    use crate::engine::GemmPool;
    use crate::nn::models;
    use std::time::Duration;

    #[test]
    fn echo_roundtrip() {
        let c = Coordinator::start(
            || Ok(EchoBackend { len: 4, batch: 2 }),
            BatcherConfig { batch: 2, linger: Duration::from_millis(1) },
        )
        .unwrap();
        let r = c.infer(vec![1, 2, 3, 4]);
        assert_eq!(r.output().data, vec![2.0, 4.0, 6.0, 8.0]);
    }

    #[test]
    fn concurrent_requests_batched() {
        let c = Coordinator::start(
            || Ok(EchoBackend { len: 2, batch: 4 }),
            BatcherConfig { batch: 4, linger: Duration::from_millis(20) },
        )
        .unwrap();
        let rxs: Vec<_> =
            (0..8).map(|i| c.submit(vec![i, i])).collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            let r = rx.recv().unwrap();
            assert_eq!(r.output().data, vec![2.0 * i as f32; 2]);
        }
        let stats = c.shutdown();
        assert_eq!(stats.count(), 8);
        assert!(stats.batches <= 4, "batched into {} calls", stats.batches);
    }

    /// A single-FC compiled model served through the session backend is
    /// bit-exact with the direct GEMM oracle.
    #[test]
    fn session_backend_is_exact() {
        let model = Model::random(models::mlp(&[16, 8]), 7, 8);
        let weights = model.layer_weights(0).unwrap().w.clone();
        let cfg = DeployConfig::new(Algo::Ffip).with_tile(8, 4).with_batch(4);
        let compiled = compile(&model, cfg).unwrap();
        let c = Coordinator::start(
            move || {
                Ok(SessionBackend::new(InferenceSession::new(
                    &compiled,
                    Arc::new(GemmPool::new(0)),
                )))
            },
            cfg.batcher(),
        )
        .unwrap();
        let input: Vec<i32> = (0..16).map(|i| i - 8).collect();
        let r = c.infer(input.clone());
        // reference
        let a = Mat::from_fn(1, 16, |_, j| i64::from(input[j]));
        let gold = crate::algo::baseline_matmul(&a, &weights);
        let got: Vec<i64> =
            r.output().data.iter().map(|&v| v as i64).collect();
        assert_eq!(got, gold.data);
    }

    #[test]
    fn pooled_session_matches_serial_and_reports_engine_and_layers() {
        let model = Model::random(models::mlp(&[16, 8]), 13, 8);
        let weights = model.layer_weights(0).unwrap().w.clone();
        let cfg = DeployConfig::new(Algo::Ffip).with_tile(8, 4).with_batch(4);
        let compiled = compile(&model, cfg).unwrap();
        let pool = Arc::new(GemmPool::new(2));
        let pool2 = pool.clone();
        let c = Coordinator::start(
            move || {
                Ok(SessionBackend::new(InferenceSession::new(
                    &compiled, pool2,
                )))
            },
            cfg.batcher(),
        )
        .unwrap();
        let input: Vec<i32> = (0..16).map(|i| 7 - i).collect();
        let r = c.infer(input.clone());
        let a = Mat::from_fn(1, 16, |_, j| i64::from(input[j]));
        let gold = crate::algo::baseline_matmul(&a, &weights);
        let got: Vec<i64> =
            r.output().data.iter().map(|&v| v as i64).collect();
        assert_eq!(got, gold.data);
        let s = c.shutdown();
        let engine = s.engine.expect("engine snapshot recorded");
        assert!(engine.jobs >= 1, "{engine:?}");
        assert!(engine.items >= 1, "{engine:?}");
        assert_eq!(engine.workers, 2);
        // per-layer timing surfaced
        assert_eq!(s.layers.len(), 1);
        assert_eq!(s.layers[0].name, "fc1");
        assert!(s.layers[0].batches >= 1);
    }

    #[test]
    fn malformed_request_gets_typed_error_and_server_survives() {
        let c = Coordinator::start(
            || Ok(EchoBackend { len: 2, batch: 2 }),
            BatcherConfig { batch: 2, linger: Duration::from_millis(1) },
        )
        .unwrap();
        let bad = c.infer(vec![1, 2, 3]);
        assert_eq!(
            bad.result.unwrap_err(),
            RequestError::BadShape { expected: 2, got: 3 }
        );
        // the worker is still serving
        let ok = c.infer(vec![5, 6]);
        assert_eq!(ok.output().data, vec![10.0, 12.0]);
    }

    #[test]
    fn stats_populated() {
        let c = Coordinator::start(
            || Ok(EchoBackend { len: 1, batch: 1 }),
            BatcherConfig { batch: 1, linger: Duration::from_millis(1) },
        )
        .unwrap();
        for i in 0..10 {
            c.infer(vec![i]);
        }
        let s = c.shutdown();
        assert_eq!(s.count(), 10);
        assert!(s.throughput_rps() > 0.0);
        assert_eq!(s.occupancy(), 1.0);
    }
}
