//! The coordinator worker: batcher -> backend -> responses.

use super::batcher::{Batcher, BatcherConfig};
use super::stats::ServeStats;
use super::{Request, Response};
use crate::algo::{tiled_matmul, Algo, Mat, TileShape};
use crate::engine::{GemmPool, PoolStats};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// An inference backend: consumes a padded batch input, returns one
/// output row per batch slot.
///
/// Backends need not be `Send` — PJRT handles hold `Rc`s — so the
/// coordinator constructs them *inside* its worker thread from a `Send`
/// factory closure ([`Coordinator::start`]).
pub trait Backend: 'static {
    /// Flat input row length per request.
    fn input_len(&self) -> usize;
    /// Output row length per request.
    fn output_len(&self) -> usize;
    /// Fixed accelerator batch size.
    fn batch(&self) -> usize;
    /// Run one padded batch (`batch * input_len` values).
    fn infer(&mut self, padded: &[i32]) -> anyhow::Result<Vec<f32>>;
    /// Counters of the GEMM execution engine this backend runs on, if
    /// any; sampled into [`ServeStats`] after every batch.
    fn engine_stats(&self) -> Option<PoolStats> {
        None
    }
}

/// Trivial backend for tests: output = input * 2.
pub struct EchoBackend {
    pub len: usize,
    pub batch: usize,
}

impl Backend for EchoBackend {
    fn input_len(&self) -> usize {
        self.len
    }
    fn output_len(&self) -> usize {
        self.len
    }
    fn batch(&self) -> usize {
        self.batch
    }
    fn infer(&mut self, padded: &[i32]) -> anyhow::Result<Vec<f32>> {
        Ok(padded.iter().map(|&v| (v * 2) as f32).collect())
    }
}

/// Bit-exact simulated-accelerator backend: a single FFIP GEMM layer
/// (input row x stationary weights) through the tiled decomposition —
/// the functional fast path of the simulated MXU.
///
/// With a [`GemmPool`] attached ([`SimBackend::with_engine`]) the batch
/// GEMM runs on the persistent worker pool — the serving configuration;
/// without one it falls back to the serial [`tiled_matmul`].
pub struct SimBackend {
    pub weights: Mat<i64>,
    pub algo: Algo,
    pub tile: TileShape,
    pub batch: usize,
    pub engine: Option<Arc<GemmPool>>,
}

impl SimBackend {
    /// Serial (pool-less) backend — bring-up and tests.
    pub fn new(
        weights: Mat<i64>,
        algo: Algo,
        tile: TileShape,
        batch: usize,
    ) -> Self {
        SimBackend { weights, algo, tile, batch, engine: None }
    }

    /// Backend executing its batch GEMMs on a shared persistent pool.
    pub fn with_engine(
        weights: Mat<i64>,
        algo: Algo,
        tile: TileShape,
        batch: usize,
        engine: Arc<GemmPool>,
    ) -> Self {
        SimBackend { weights, algo, tile, batch, engine: Some(engine) }
    }
}

impl Backend for SimBackend {
    fn input_len(&self) -> usize {
        self.weights.rows
    }
    fn output_len(&self) -> usize {
        self.weights.cols
    }
    fn batch(&self) -> usize {
        self.batch
    }
    fn infer(&mut self, padded: &[i32]) -> anyhow::Result<Vec<f32>> {
        let k = self.weights.rows;
        let a = Mat::from_fn(self.batch, k, |i, j| {
            i64::from(padded[i * k + j])
        });
        let c = match &self.engine {
            Some(pool) => pool.gemm(&a, &self.weights, self.algo, self.tile),
            None => tiled_matmul(&a, &self.weights, self.algo, self.tile),
        };
        Ok(c.data.iter().map(|&v| v as f32).collect())
    }
    fn engine_stats(&self) -> Option<PoolStats> {
        self.engine.as_ref().map(|p| p.stats())
    }
}

/// Handle to a running coordinator.
pub struct Coordinator {
    tx: mpsc::Sender<Request>,
    pub stats: Arc<Mutex<ServeStats>>,
    worker: Option<std::thread::JoinHandle<()>>,
    next_id: std::sync::atomic::AtomicU64,
    input_len: usize,
}

impl Coordinator {
    /// Spawn the worker thread; `factory` runs *inside* it to build the
    /// backend (PJRT executables are not `Send`).  Returns once the
    /// backend constructed successfully.
    pub fn start<B, F>(factory: F, cfg: BatcherConfig) -> anyhow::Result<Self>
    where
        B: Backend,
        F: FnOnce() -> anyhow::Result<B> + Send + 'static,
    {
        let (tx, rx) = mpsc::channel::<Request>();
        let (init_tx, init_rx) =
            mpsc::channel::<anyhow::Result<(usize, usize)>>();
        let stats = Arc::new(Mutex::new(ServeStats::default()));
        let stats_w = stats.clone();
        let worker = std::thread::spawn(move || {
            let mut backend = match factory() {
                Ok(b) => {
                    let dims = (b.input_len(), b.batch());
                    assert_eq!(
                        cfg.batch,
                        b.batch(),
                        "batcher/backend batch size"
                    );
                    let _ = init_tx.send(Ok(dims));
                    b
                }
                Err(e) => {
                    let _ = init_tx.send(Err(e));
                    return;
                }
            };
            let mut batcher = Batcher::new(cfg, rx);
            let out_len = backend.output_len();
            let cap = backend.batch();
            {
                let mut s = stats_w.lock().unwrap();
                s.started = Some(Instant::now());
            }
            while let Some(batch) = batcher.next_batch() {
                let padded =
                    batch.padded_input(cap, backend.input_len());
                let outputs = match backend.infer(&padded) {
                    Ok(o) => o,
                    Err(err) => {
                        // fail the whole batch: drop the response
                        // channels, callers observe disconnection
                        eprintln!("backend error: {err:#}");
                        continue;
                    }
                };
                let done = Instant::now();
                {
                    let mut s = stats_w.lock().unwrap();
                    s.record_batch(batch.len(), cap);
                    if let Some(ps) = backend.engine_stats() {
                        s.record_engine(&ps);
                    }
                    s.finished = Some(done);
                }
                for (slot, (req, t_in)) in
                    batch.requests.into_iter().enumerate()
                {
                    let latency = done - t_in;
                    {
                        let mut s = stats_w.lock().unwrap();
                        s.record_latency(latency);
                    }
                    let output = outputs
                        [slot * out_len..(slot + 1) * out_len]
                        .to_vec();
                    // receiver may have gone away; that's fine
                    let _ = req.resp.send(Response {
                        id: req.id,
                        output,
                        latency,
                    });
                }
            }
        });
        let (input_len, _batch) = init_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("worker died during init"))??;
        Ok(Coordinator {
            tx,
            stats,
            worker: Some(worker),
            next_id: std::sync::atomic::AtomicU64::new(0),
            input_len,
        })
    }

    /// Submit asynchronously; returns the response receiver.
    pub fn submit(&self, input: Vec<i32>) -> mpsc::Receiver<Response> {
        assert_eq!(input.len(), self.input_len, "input row length");
        let (tx, rx) = mpsc::channel();
        let id = self
            .next_id
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        self.tx
            .send(Request { id, input, resp: tx })
            .expect("coordinator worker alive");
        rx
    }

    /// Blocking inference.
    pub fn infer(&self, input: Vec<i32>) -> Response {
        self.submit(input).recv().expect("response")
    }

    /// Drain and stop the worker.
    pub fn shutdown(mut self) -> ServeStats {
        drop(self.tx.clone()); // no-op; real close happens on drop below
        let stats = self.stats.clone();
        // dropping self.tx closes the channel -> worker exits
        let worker = self.worker.take();
        drop(self);
        if let Some(w) = worker {
            let _ = w.join();
        }
        let s = stats.lock().unwrap().clone();
        s
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        if let Some(w) = self.worker.take() {
            // close the request channel first by replacing tx
            let (dead_tx, _) = mpsc::channel();
            self.tx = dead_tx;
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;
    use std::time::Duration;

    #[test]
    fn echo_roundtrip() {
        let c = Coordinator::start(
            || Ok(EchoBackend { len: 4, batch: 2 }),
            BatcherConfig { batch: 2, linger: Duration::from_millis(1) },
        )
        .unwrap();
        let r = c.infer(vec![1, 2, 3, 4]);
        assert_eq!(r.output, vec![2.0, 4.0, 6.0, 8.0]);
    }

    #[test]
    fn concurrent_requests_batched() {
        let c = Coordinator::start(
            || Ok(EchoBackend { len: 2, batch: 4 }),
            BatcherConfig { batch: 4, linger: Duration::from_millis(20) },
        )
        .unwrap();
        let rxs: Vec<_> =
            (0..8).map(|i| c.submit(vec![i, i])).collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            let r = rx.recv().unwrap();
            assert_eq!(r.output, vec![2.0 * i as f32; 2]);
        }
        let stats = c.shutdown();
        assert_eq!(stats.count(), 8);
        assert!(stats.batches <= 4, "batched into {} calls", stats.batches);
    }

    #[test]
    fn sim_backend_is_exact() {
        let mut rng = Rng::new(7);
        let weights = Mat::from_fn(16, 8, |_, _| rng.fixed(8, true));
        let w2 = weights.clone();
        let c = Coordinator::start(
            move || {
                Ok(SimBackend::new(
                    w2,
                    Algo::Ffip,
                    TileShape::square(8, 4),
                    4,
                ))
            },
            BatcherConfig { batch: 4, linger: Duration::from_millis(1) },
        )
        .unwrap();
        let input: Vec<i32> = (0..16).map(|i| i - 8).collect();
        let r = c.infer(input.clone());
        // reference
        let a = Mat::from_fn(1, 16, |_, j| i64::from(input[j]));
        let gold = crate::algo::baseline_matmul(&a, &weights);
        let got: Vec<i64> =
            r.output.iter().map(|&v| v as i64).collect();
        assert_eq!(got, gold.data);
    }

    #[test]
    fn pooled_sim_backend_matches_serial_and_reports_engine() {
        let mut rng = Rng::new(13);
        let weights = Mat::from_fn(16, 8, |_, _| rng.fixed(8, true));
        let w2 = weights.clone();
        let pool = Arc::new(GemmPool::new(2));
        let pool2 = pool.clone();
        let c = Coordinator::start(
            move || {
                Ok(SimBackend::with_engine(
                    w2,
                    Algo::Ffip,
                    TileShape::square(8, 4),
                    4,
                    pool2,
                ))
            },
            BatcherConfig { batch: 4, linger: Duration::from_millis(1) },
        )
        .unwrap();
        let input: Vec<i32> = (0..16).map(|i| 7 - i).collect();
        let r = c.infer(input.clone());
        let a = Mat::from_fn(1, 16, |_, j| i64::from(input[j]));
        let gold = crate::algo::baseline_matmul(&a, &weights);
        let got: Vec<i64> = r.output.iter().map(|&v| v as i64).collect();
        assert_eq!(got, gold.data);
        let s = c.shutdown();
        let engine = s.engine.expect("engine snapshot recorded");
        assert!(engine.jobs >= 1, "{engine:?}");
        assert!(engine.items >= 1, "{engine:?}");
        assert_eq!(engine.workers, 2);
    }

    #[test]
    fn stats_populated() {
        let c = Coordinator::start(
            || Ok(EchoBackend { len: 1, batch: 1 }),
            BatcherConfig { batch: 1, linger: Duration::from_millis(1) },
        )
        .unwrap();
        for i in 0..10 {
            c.infer(vec![i]);
        }
        let s = c.shutdown();
        assert_eq!(s.count(), 10);
        assert!(s.throughput_rps() > 0.0);
        assert_eq!(s.occupancy(), 1.0);
    }
}
