//! The coordinator front door: admission → batcher → replica workers →
//! responses.
//!
//! [`Coordinator`] owns the submit side of one deployment.  The
//! per-batch execution loop lives in the replica scheduler
//! ([`scheduler::replica`](super::scheduler::replica)): a dispatcher
//! thread forms batches and hands them to N replica workers
//! (round-robin with least-outstanding-work stealing), and an
//! [`Admission`](super::scheduler::Admission) controller bounds the
//! in-flight depth, shedding excess arrivals with
//! [`RequestError::Overloaded`] before they ever occupy a queue slot.

use super::batcher::BatcherConfig;
use super::scheduler::{Admission, AdmissionConfig, ReplicaSet};
use super::session::LayerTiming;
use super::stats::{FaultCounts, ServeStats};
use super::tensor::{RequestError, Tensor, TensorView};
use super::{Request, Response};
use crate::engine::PoolStats;
use std::sync::mpsc;
use std::time::Duration;

/// An inference backend: consumes one padded batch tensor, returns one
/// output row per batch slot.
///
/// Backends need not be `Send` — PJRT handles hold `Rc`s — so each
/// replica worker constructs its backend *inside* its own thread from a
/// `Send` factory closure ([`Coordinator::start`] /
/// [`Coordinator::start_replicated`]).
pub trait Backend: 'static {
    /// Flat input row length per request.
    fn input_len(&self) -> usize;
    /// Output row length per request.
    fn output_len(&self) -> usize;
    /// Fixed accelerator batch size.
    fn batch(&self) -> usize;
    /// Run one padded batch (`batch() x input_len()` values); must
    /// return a `batch() x output_len()` tensor.
    fn infer(&mut self, batch: TensorView<'_>) -> anyhow::Result<Tensor>;
    /// Signed bit-width of the per-value input domain this backend
    /// accepts, when constrained (narrow-storage sessions); `None`
    /// means any `i32` is acceptable.  The replica worker sweeps
    /// out-of-domain requests *per request* before the batch reaches
    /// [`infer`], so one bad value never fails its co-batched
    /// neighbours.
    ///
    /// [`infer`]: Backend::infer
    fn input_domain_bits(&self) -> Option<u32> {
        None
    }

    /// The largest token count an attention request row may carry, when
    /// this backend serves the ragged `[len, tokens, pad]` wire format
    /// (the deployed model's input layer is attention); `None` for
    /// dense-row backends.  The replica worker sweeps rows whose length
    /// prefix is negative or exceeds this bound *per request*
    /// ([`RequestError::BadSequence`]) before the batch reaches
    /// [`infer`], so one bad length never fails its co-batched
    /// neighbours.
    ///
    /// [`infer`]: Backend::infer
    fn max_seq(&self) -> Option<usize> {
        None
    }

    /// Counters of the GEMM execution engine this backend runs on, if
    /// any; sampled into [`ServeStats`] after every batch.
    fn engine_stats(&self) -> Option<PoolStats> {
        None
    }
    /// Per-layer wall times of the most recent batch, if the backend
    /// measures them (drained per batch into [`ServeStats`]).
    fn layer_timings(&mut self) -> Option<Vec<LayerTiming>> {
        None
    }
    /// Fault-tolerance counters accumulated since the last drain, if
    /// the backend tracks them (ABFT checksum trips, healed recomputes,
    /// watchdog expiries); drained per batch into [`ServeStats`].
    fn fault_counts(&mut self) -> Option<FaultCounts> {
        None
    }
    /// The deployment's per-request deadline
    /// ([`DeployConfig::with_request_deadline`](super::DeployConfig)),
    /// if one is configured.  The replica worker sheds requests that
    /// already waited longer than this as typed
    /// [`RequestError::DeadlineExceeded`] responses *before* spending a
    /// batch slot on them.
    fn request_deadline(&self) -> Option<Duration> {
        None
    }
}

/// Boxed backends forward transparently, so call sites that choose a
/// backend implementation at runtime (e.g.
/// [`Router::deploy_model`](super::Router::deploy_model) picking the
/// pipelined or sequential executor per [`DeployConfig`](super::DeployConfig))
/// can build one uniform `Box<dyn Backend>` factory instead of
/// duplicating the spawn path per concrete type.
impl Backend for Box<dyn Backend> {
    fn input_len(&self) -> usize {
        self.as_ref().input_len()
    }
    fn output_len(&self) -> usize {
        self.as_ref().output_len()
    }
    fn batch(&self) -> usize {
        self.as_ref().batch()
    }
    fn infer(&mut self, batch: TensorView<'_>) -> anyhow::Result<Tensor> {
        self.as_mut().infer(batch)
    }
    fn input_domain_bits(&self) -> Option<u32> {
        self.as_ref().input_domain_bits()
    }
    fn max_seq(&self) -> Option<usize> {
        self.as_ref().max_seq()
    }
    fn engine_stats(&self) -> Option<PoolStats> {
        self.as_ref().engine_stats()
    }
    fn layer_timings(&mut self) -> Option<Vec<LayerTiming>> {
        self.as_mut().layer_timings()
    }
    fn fault_counts(&mut self) -> Option<FaultCounts> {
        self.as_mut().fault_counts()
    }
    fn request_deadline(&self) -> Option<Duration> {
        self.as_ref().request_deadline()
    }
}

/// Trivial backend for tests: output = input * 2.
pub struct EchoBackend {
    pub len: usize,
    pub batch: usize,
}

impl Backend for EchoBackend {
    fn input_len(&self) -> usize {
        self.len
    }
    fn output_len(&self) -> usize {
        self.len
    }
    fn batch(&self) -> usize {
        self.batch
    }
    fn infer(&mut self, batch: TensorView<'_>) -> anyhow::Result<Tensor> {
        let data = batch.data.iter().map(|&v| (v * 2) as f32).collect();
        Ok(Tensor::new(batch.rows(), batch.row_len(), data))
    }
}

/// Handle to a running coordinator (one deployment's submit side).
pub struct Coordinator {
    tx: mpsc::Sender<Request>,
    set: Option<ReplicaSet>,
    next_id: std::sync::atomic::AtomicU64,
    input_len: usize,
}

impl Coordinator {
    /// Spawn a single-replica coordinator with unbounded admission —
    /// the historical shape; `factory` runs *inside* the worker thread
    /// to build the backend (PJRT executables are not `Send`).  Returns
    /// once the backend constructed successfully.
    ///
    /// The factory is `Fn` (re-invokable), not `FnOnce`: the dispatcher
    /// keeps it to respawn the replica from the shared compiled
    /// artifact if its thread ever dies.
    pub fn start<B, F>(factory: F, cfg: BatcherConfig) -> anyhow::Result<Self>
    where
        B: Backend,
        F: Fn() -> anyhow::Result<B> + Send + Sync + 'static,
    {
        Self::start_replicated(vec![factory], cfg, AdmissionConfig::UNBOUNDED)
    }

    /// Spawn one replica worker per factory plus the shared dispatcher,
    /// under `admission`-bounded load shedding.  Every factory runs
    /// inside its own replica's thread; all backends must agree on
    /// `(input_len, output_len, batch)`.  Returns once every backend
    /// constructed successfully (any failure tears the whole set down
    /// and propagates).
    pub fn start_replicated<B, F>(
        factories: Vec<F>,
        cfg: BatcherConfig,
        admission: AdmissionConfig,
    ) -> anyhow::Result<Self>
    where
        B: Backend,
        F: Fn() -> anyhow::Result<B> + Send + Sync + 'static,
    {
        let (tx, rx) = mpsc::channel::<Request>();
        let set = ReplicaSet::start(factories, cfg, admission, rx)?;
        let (input_len, _, _) = set.dims();
        Ok(Coordinator {
            tx,
            set: Some(set),
            next_id: std::sync::atomic::AtomicU64::new(0),
            input_len,
        })
    }

    fn set(&self) -> &ReplicaSet {
        self.set.as_ref().expect("coordinator running")
    }

    /// Replica workers serving this deployment.
    pub fn replica_count(&self) -> usize {
        self.set().replica_count()
    }

    /// The deployment's admission controller (live depth/shed counters).
    pub fn admission(&self) -> &Admission {
        self.set().admission()
    }

    /// Merged live snapshot of the deployment's serving stats: every
    /// replica folded together plus the per-replica breakdown and the
    /// shed counter.
    pub fn stats(&self) -> ServeStats {
        self.set().stats()
    }

    /// Submit asynchronously; returns the response receiver.  A request
    /// whose row length does not match the deployed model receives an
    /// immediate [`RequestError::BadShape`] response on that channel,
    /// and one arriving while the admission queue is full an immediate
    /// [`RequestError::Overloaded`] — neither ever occupies a batch
    /// slot.
    pub fn submit(&self, input: Vec<i32>) -> mpsc::Receiver<Response> {
        let (tx, rx) = mpsc::channel();
        let id = self
            .next_id
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        if input.len() != self.input_len {
            let _ = tx.send(Response {
                id,
                result: Err(RequestError::BadShape {
                    expected: self.input_len,
                    got: input.len(),
                }),
                latency: std::time::Duration::ZERO,
            });
            return rx;
        }
        if let Err(shed) = self.set().admission().try_admit() {
            let _ = tx.send(Response {
                id,
                result: Err(shed),
                latency: std::time::Duration::ZERO,
            });
            return rx;
        }
        self.tx
            .send(Request { id, input, resp: tx })
            .expect("coordinator dispatcher alive");
        rx
    }

    /// Blocking inference.
    pub fn infer(&self, input: Vec<i32>) -> Response {
        self.submit(input).recv().expect("response")
    }

    /// Drain and stop the deployment: closes the request channel, waits
    /// for the dispatcher to flush the batcher and for **every** replica
    /// worker to finish its queued batches, then returns the final
    /// merged stats (per-replica layer stats summed by name, even when
    /// work stealing left replicas with different batch counts).
    pub fn shutdown(mut self) -> ServeStats {
        let set = self.set.take().expect("not yet shut down");
        // dropping the real sender closes the channel -> dispatcher
        // drains and exits -> replica channels close -> replicas drain
        let (dead_tx, _) = mpsc::channel();
        self.tx = dead_tx;
        set.shutdown()
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        if let Some(set) = self.set.take() {
            // close the request channel first by replacing tx
            let (dead_tx, _) = mpsc::channel();
            self.tx = dead_tx;
            drop(set); // joins dispatcher + replicas
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::{Algo, Mat};
    use crate::coordinator::{
        compile, DeployConfig, InferenceSession, Model, SessionBackend,
    };
    use crate::engine::GemmPool;
    use crate::nn::models;
    use std::sync::Arc;
    use std::time::Duration;

    #[test]
    fn echo_roundtrip() {
        let c = Coordinator::start(
            || Ok(EchoBackend { len: 4, batch: 2 }),
            BatcherConfig { batch: 2, linger: Duration::from_millis(1) },
        )
        .unwrap();
        let r = c.infer(vec![1, 2, 3, 4]);
        assert_eq!(r.output().data, vec![2.0, 4.0, 6.0, 8.0]);
    }

    #[test]
    fn concurrent_requests_batched() {
        let c = Coordinator::start(
            || Ok(EchoBackend { len: 2, batch: 4 }),
            BatcherConfig { batch: 4, linger: Duration::from_millis(20) },
        )
        .unwrap();
        let rxs: Vec<_> =
            (0..8).map(|i| c.submit(vec![i, i])).collect();
        for (i, rx) in rxs.into_iter().enumerate() {
            let r = rx.recv().unwrap();
            assert_eq!(r.output().data, vec![2.0 * i as f32; 2]);
        }
        let stats = c.shutdown();
        assert_eq!(stats.count(), 8);
        assert!(stats.batches <= 4, "batched into {} calls", stats.batches);
    }

    /// A single-FC compiled model served through the session backend is
    /// bit-exact with the direct GEMM oracle.
    #[test]
    fn session_backend_is_exact() {
        let model = Model::random(models::mlp(&[16, 8]), 7, 8);
        let weights = model.layer_weights(0).unwrap().w.clone();
        let cfg = DeployConfig::new(Algo::Ffip).with_tile(8, 4).with_batch(4);
        let compiled = compile(&model, cfg).unwrap();
        let c = Coordinator::start(
            move || {
                Ok(SessionBackend::new(InferenceSession::new(
                    &compiled,
                    Arc::new(GemmPool::new(0)),
                )))
            },
            cfg.batcher(),
        )
        .unwrap();
        let input: Vec<i32> = (0..16).map(|i| i - 8).collect();
        let r = c.infer(input.clone());
        // reference
        let a = Mat::from_fn(1, 16, |_, j| i64::from(input[j]));
        let gold = crate::algo::baseline_matmul(&a, &weights);
        let got: Vec<i64> =
            r.output().data.iter().map(|&v| v as i64).collect();
        assert_eq!(got, gold.data);
    }

    #[test]
    fn pooled_session_matches_serial_and_reports_engine_and_layers() {
        let model = Model::random(models::mlp(&[16, 8]), 13, 8);
        let weights = model.layer_weights(0).unwrap().w.clone();
        let cfg = DeployConfig::new(Algo::Ffip).with_tile(8, 4).with_batch(4);
        let compiled = compile(&model, cfg).unwrap();
        let pool = Arc::new(GemmPool::new(2));
        let pool2 = pool.clone();
        let c = Coordinator::start(
            move || {
                Ok(SessionBackend::new(InferenceSession::new(
                    &compiled,
                    pool2.clone(),
                )))
            },
            cfg.batcher(),
        )
        .unwrap();
        let input: Vec<i32> = (0..16).map(|i| 7 - i).collect();
        let r = c.infer(input.clone());
        let a = Mat::from_fn(1, 16, |_, j| i64::from(input[j]));
        let gold = crate::algo::baseline_matmul(&a, &weights);
        let got: Vec<i64> =
            r.output().data.iter().map(|&v| v as i64).collect();
        assert_eq!(got, gold.data);
        let s = c.shutdown();
        let engine = s.engine.expect("engine snapshot recorded");
        assert!(engine.jobs >= 1, "{engine:?}");
        assert!(engine.items >= 1, "{engine:?}");
        assert_eq!(engine.workers, 2);
        // per-layer timing surfaced
        assert_eq!(s.layers.len(), 1);
        assert_eq!(s.layers[0].name, "fc1");
        assert!(s.layers[0].batches >= 1);
    }

    #[test]
    fn malformed_request_gets_typed_error_and_server_survives() {
        let c = Coordinator::start(
            || Ok(EchoBackend { len: 2, batch: 2 }),
            BatcherConfig { batch: 2, linger: Duration::from_millis(1) },
        )
        .unwrap();
        let bad = c.infer(vec![1, 2, 3]);
        assert_eq!(
            bad.result.unwrap_err(),
            RequestError::BadShape { expected: 2, got: 3 }
        );
        // the worker is still serving
        let ok = c.infer(vec![5, 6]);
        assert_eq!(ok.output().data, vec![10.0, 12.0]);
    }

    #[test]
    fn stats_populated() {
        let c = Coordinator::start(
            || Ok(EchoBackend { len: 1, batch: 1 }),
            BatcherConfig { batch: 1, linger: Duration::from_millis(1) },
        )
        .unwrap();
        for i in 0..10 {
            c.infer(vec![i]);
        }
        let s = c.shutdown();
        assert_eq!(s.count(), 10);
        assert!(s.throughput_rps() > 0.0);
        assert_eq!(s.occupancy(), 1.0);
        assert_eq!(s.shed, 0, "unbounded admission sheds nothing");
        assert_eq!(s.replicas.len(), 1);
        assert_eq!(s.replicas[0].requests, 10);
    }

    /// Replicated echo deployment: every request answered correctly,
    /// the per-replica breakdown covers all traffic, and the merged
    /// batch count equals the sum over replicas.
    #[test]
    fn replicated_coordinator_serves_and_reports_breakdown() {
        let c = Coordinator::start_replicated(
            (0..3)
                .map(|_| || Ok(EchoBackend { len: 2, batch: 1 }))
                .collect::<Vec<_>>(),
            BatcherConfig { batch: 1, linger: Duration::ZERO },
            AdmissionConfig::UNBOUNDED,
        )
        .unwrap();
        assert_eq!(c.replica_count(), 3);
        for i in 0..12 {
            let r = c.infer(vec![i, -i]);
            assert_eq!(r.output().data, vec![2.0 * i as f32, -2.0 * i as f32]);
        }
        let s = c.shutdown();
        assert_eq!(s.count(), 12);
        assert_eq!(s.replicas.len(), 3);
        let by_replica: u64 = s.replicas.iter().map(|r| r.batches).sum();
        assert_eq!(by_replica, s.batches);
        let reqs: usize = s.replicas.iter().map(|r| r.requests).sum();
        assert_eq!(reqs, 12);
        // sequential blocking submits leave no outstanding skew, so the
        // round-robin rotation spreads work across every replica
        assert!(
            s.replicas.iter().all(|r| r.batches >= 1),
            "all replicas served: {:?}",
            s.replicas
        );
    }

    /// A factory error on any replica fails start_replicated loudly and
    /// tears the half-built set down (no hang, no leaked threads).
    #[test]
    fn replica_factory_error_fails_the_whole_set() {
        let factories: Vec<
            Box<dyn Fn() -> anyhow::Result<EchoBackend> + Send + Sync>,
        > =
            (0..3)
                .map(|i| {
                    let fail = i == 1;
                    Box::new(move || {
                        if fail {
                            anyhow::bail!("replica 1 has no accelerator")
                        }
                        Ok(EchoBackend { len: 1, batch: 1 })
                    }) as _
                })
                .collect();
        let r = Coordinator::start_replicated(
            factories,
            BatcherConfig { batch: 1, linger: Duration::ZERO },
            AdmissionConfig::UNBOUNDED,
        );
        let err = format!("{:#}", r.err().expect("must fail"));
        assert!(err.contains("no accelerator"), "{err}");
    }
}
