//! Stage 3 of `Model → CompiledModel → InferenceSession`: execute a
//! compiled model's layers sequentially on the shared
//! [`GemmPool`](crate::engine::GemmPool).
//!
//! A session owns the mutable execution state for one deployment worker:
//! preallocated inter-layer activation buffers (`act`), a staged GEMM A
//! operand (`a`) and the GEMM output (`c`), all reused across batches —
//! with [`GemmPool::gemm_into`](crate::engine::GemmPool::gemm_into)
//! writing into the reusable output, steady state allocates nothing per
//! request.  FC layers stage their batch rows directly; conv layers
//! stage through the in-place conv→GEMM walk
//! ([`Im2Gemm::fill_virtual_a`](crate::memory::Im2Gemm::fill_virtual_a),
//! §5.1 Algorithm 1).  FFIP deployments consume the compile-time
//! offline `y_from_b` weight terms (§3.3).
//!
//! Every layer's wall time is measured per batch ([`LayerTiming`]) and
//! surfaced through [`ServeStats`](super::ServeStats), so the paper's
//! §6 layer-wise throughput breakdown is observable from the server.
//!
//! [`SessionBackend`] adapts a session to the coordinator's [`Backend`]
//! trait — the single serving backend for simulated-accelerator models.

use super::model::{CompiledModel, LayerExec};
use super::server::Backend;
use super::tensor::{RequestError, Tensor, TensorView};
use crate::algo::Mat;
use crate::engine::{GemmPool, PoolStats};
use std::sync::Arc;
use std::time::Instant;

/// Wall time one layer spent on one batch (staging + GEMM + post-GEMM).
#[derive(Debug, Clone)]
pub struct LayerTiming {
    pub name: Arc<str>,
    pub micros: u64,
}

/// An inference session: executes one [`CompiledModel`] batch-by-batch
/// on a shared [`GemmPool`].
pub struct InferenceSession {
    model: Arc<CompiledModel>,
    pool: Arc<GemmPool>,
    /// Layer names shared with the per-batch timing records.
    names: Vec<Arc<str>>,
    /// Staged GEMM A operand (reused across layers and batches).
    a: Mat<i64>,
    /// GEMM output (reused; `gemm_into` resizes in place).
    c: Mat<i64>,
    /// Flat inter-layer activations, `rows * layer_len`.
    act: Vec<i64>,
    /// Per-layer wall times of the most recent batch.
    timings: Vec<LayerTiming>,
}

impl InferenceSession {
    /// Create a session with all inter-layer buffers preallocated to the
    /// model's largest layer.
    pub fn new(model: Arc<CompiledModel>, pool: Arc<GemmPool>) -> Self {
        let names = model
            .layers
            .iter()
            .map(|l| Arc::<str>::from(l.name.as_str()))
            .collect();
        let mut a = Mat::zeros(0, 0);
        a.data.reserve(model.max_a_elems());
        let mut c = Mat::zeros(0, 0);
        c.data.reserve(model.max_a_elems().max(model.max_act_elems()));
        let act = Vec::with_capacity(model.max_act_elems());
        let n_layers = model.layers.len();
        InferenceSession {
            model,
            pool,
            names,
            a,
            c,
            act,
            timings: Vec::with_capacity(n_layers),
        }
    }

    pub fn model(&self) -> &CompiledModel {
        &self.model
    }

    pub fn pool(&self) -> &Arc<GemmPool> {
        &self.pool
    }

    /// Execute one batch through every layer.  `input` is `rows` request
    /// rows (1 ≤ rows ≤ the compiled batch) of `input_len` activations;
    /// the result is `rows` rows of `output_len` values.
    pub fn infer_batch(
        &mut self,
        input: TensorView<'_>,
    ) -> Result<Tensor, RequestError> {
        let model = self.model.clone();
        if input.row_len() != model.input_len {
            return Err(RequestError::BadShape {
                expected: model.input_len,
                got: input.row_len(),
            });
        }
        let rows = input.rows();
        assert!(
            rows >= 1 && rows <= model.cfg.batch,
            "session batch rows {rows} outside 1..={}",
            model.cfg.batch
        );
        self.act.clear();
        self.act.extend(input.data.iter().map(|&v| i64::from(v)));
        self.timings.clear();
        for (li, layer) in model.layers.iter().enumerate() {
            let t0 = Instant::now();
            // stage the A operand from the flat activations
            match &layer.exec {
                LayerExec::Fc => {
                    self.a.rows = rows;
                    self.a.cols = layer.in_len;
                    self.a.data.clear();
                    self.a
                        .data
                        .extend_from_slice(&self.act[..rows * layer.in_len]);
                }
                LayerExec::Conv { ig } => {
                    // per-request OH*OW rows through the Algorithm 1 walk
                    let m1 = layer.gemm.m / model.cfg.batch;
                    self.a.rows = rows * m1;
                    self.a.cols = layer.gemm.k;
                    self.a.data.clear();
                    self.a.data.resize(rows * m1 * layer.gemm.k, 0);
                    for r in 0..rows {
                        let flat = &self.act
                            [r * layer.in_len..(r + 1) * layer.in_len];
                        ig.fill_virtual_a(flat, &mut self.a, r * m1);
                    }
                }
            }
            // the layer GEMM on the shared pool, into the reused output
            self.pool.gemm_into(
                &self.a,
                &layer.weights,
                layer.y.as_deref(),
                &mut self.c,
                model.cfg.algo,
                layer.tile,
            );
            // post-GEMM requantization (or raw pass-through) into the
            // next layer's activations
            self.act.clear();
            match &layer.post {
                Some(post) => {
                    let n = self.c.cols;
                    self.act.extend(
                        self.c
                            .data
                            .iter()
                            .enumerate()
                            .map(|(i, &v)| post.apply(v, i % n)),
                    );
                }
                None => self.act.extend_from_slice(&self.c.data),
            }
            self.timings.push(LayerTiming {
                name: self.names[li].clone(),
                micros: t0.elapsed().as_micros() as u64,
            });
        }
        let data = self.act.iter().map(|&v| v as f32).collect();
        Ok(Tensor::new(rows, model.output_len, data))
    }

    /// Per-layer wall times of the most recent batch (drains them).
    pub fn take_layer_timings(&mut self) -> Vec<LayerTiming> {
        std::mem::take(&mut self.timings)
    }
}

/// The coordinator [`Backend`] over an [`InferenceSession`] — how a
/// compiled model plugs into the batcher/worker/stats machinery.
pub struct SessionBackend {
    session: InferenceSession,
}

impl SessionBackend {
    pub fn new(session: InferenceSession) -> Self {
        SessionBackend { session }
    }

    pub fn session(&self) -> &InferenceSession {
        &self.session
    }
}

impl Backend for SessionBackend {
    fn input_len(&self) -> usize {
        self.session.model().input_len
    }

    fn output_len(&self) -> usize {
        self.session.model().output_len
    }

    fn batch(&self) -> usize {
        self.session.model().cfg.batch
    }

    fn infer(&mut self, batch: TensorView<'_>) -> anyhow::Result<Tensor> {
        self.session.infer_batch(batch).map_err(anyhow::Error::from)
    }

    fn engine_stats(&self) -> Option<PoolStats> {
        Some(self.session.pool().stats())
    }

    fn layer_timings(&mut self) -> Option<Vec<LayerTiming>> {
        Some(self.session.take_layer_timings())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::{baseline_matmul, Algo};
    use crate::coordinator::{compile, DeployConfig, Model, PostGemm};
    use crate::nn::models;
    use crate::quant::{requantize_tile, QuantScheme};
    use crate::util::Rng;

    fn session(
        model: &Model,
        cfg: DeployConfig,
        workers: usize,
    ) -> InferenceSession {
        let compiled = Arc::new(compile(model, cfg).unwrap());
        InferenceSession::new(compiled, Arc::new(GemmPool::new(workers)))
    }

    #[test]
    fn mlp_session_equals_composed_baseline() {
        let model = Model::random(models::mlp(&[12, 10, 6]), 7, 3);
        let cfg = DeployConfig::new(Algo::Ffip).with_tile(4, 3).with_batch(3);
        let mut s = session(&model, cfg, 2);
        let mut rng = Rng::new(8);
        let input: Vec<i32> =
            (0..3 * 12).map(|_| rng.fixed(4, true) as i32).collect();
        let out = s.infer_batch(TensorView::new(3, 12, &input)).unwrap();
        // oracle: compose the exact GEMMs layer by layer
        let mut act =
            Mat::from_fn(3, 12, |i, j| i64::from(input[i * 12 + j]));
        for idx in 0..2 {
            act = baseline_matmul(&act, &model.layer_weights(idx).unwrap().w);
        }
        let got: Vec<i64> = out.data.iter().map(|&v| v as i64).collect();
        assert_eq!(got, act.data);
        assert_eq!(out.shape, [3, 6]);
        // per-layer timings recorded for the batch
        let t = s.take_layer_timings();
        assert_eq!(t.len(), 2);
        assert_eq!(&*t[0].name, "fc1");
    }

    #[test]
    fn post_gemm_requantization_matches_requantize_tile() {
        let mut model = Model::random(models::mlp(&[8, 5]), 9, 4);
        let scheme = QuantScheme::symmetric_signed(8, 0.25);
        let bias: Vec<i64> = (0..5).map(|j| j as i64 * 3 - 6).collect();
        model
            .set_post(0, PostGemm { bias: bias.clone(), scheme, relu: true })
            .unwrap();
        let cfg =
            DeployConfig::new(Algo::Baseline).with_tile(4, 2).with_batch(2);
        let mut s = session(&model, cfg, 0);
        let mut rng = Rng::new(10);
        let input: Vec<i32> =
            (0..2 * 8).map(|_| rng.fixed(5, true) as i32).collect();
        let out = s.infer_batch(TensorView::new(2, 8, &input)).unwrap();
        let a = Mat::from_fn(2, 8, |i, j| i64::from(input[i * 8 + j]));
        let acc = baseline_matmul(&a, &model.layer_weights(0).unwrap().w);
        let want = requantize_tile(&acc, &bias, &scheme, true);
        let got: Vec<i64> = out.data.iter().map(|&v| v as i64).collect();
        assert_eq!(got, want.data);
    }

    #[test]
    fn wrong_row_length_is_a_typed_error() {
        let model = Model::random(models::mlp(&[6, 4]), 11, 3);
        let cfg = DeployConfig::new(Algo::Fip).with_tile(2, 2).with_batch(1);
        let mut s = session(&model, cfg, 0);
        let input = vec![0i32; 5];
        let err = s.infer_batch(TensorView::new(1, 5, &input)).unwrap_err();
        assert_eq!(err, RequestError::BadShape { expected: 6, got: 5 });
    }
}
