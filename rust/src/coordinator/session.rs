//! Stage 3 of `Model → CompiledModel → InferenceSession`: execute a
//! compiled model's layers sequentially on the shared
//! [`GemmPool`](crate::engine::GemmPool).
//!
//! A session owns the mutable execution state for one deployment
//! worker, **typed at the model's storage element**: preallocated
//! inter-layer activation buffers (`act: Vec<E>`), a staged GEMM A
//! operand (`Mat<E>`) and the widened GEMM output (`Mat<E::Acc>`), all
//! reused across batches — an int8 deployment stages, streams and
//! stores `i8` end to end, touching 1/8 the operand bytes of the
//! historical all-`i64` path (bench H8).  With
//! [`GemmPool::gemm_into`](crate::engine::GemmPool::gemm_into) writing
//! into the reusable output, steady state allocates nothing per
//! request.  FC layers stage their batch rows directly; conv layers
//! stage through the in-place conv→GEMM walk
//! ([`Im2Gemm::fill_virtual_a`](crate::memory::Im2Gemm::fill_virtual_a),
//! §5.1 Algorithm 1) at the same narrow width.  FFIP deployments
//! consume the compile-time offline `y_from_b` weight terms (§3.3) in
//! their native one-extra-bit storage, and each layer's post-GEMM
//! requantization emits the next layer's narrow operands directly
//! ([`PostGemm::apply_to`](super::PostGemm::apply_to)).
//!
//! The public [`InferenceSession`] is a width-tagged wrapper over the
//! typed implementation, constructed from whichever storage the
//! [`CompiledModel`] selected at compile time.
//!
//! Every layer's wall time is measured per batch ([`LayerTiming`]) and
//! surfaced through [`ServeStats`](super::ServeStats), so the paper's
//! §6 layer-wise throughput breakdown is observable from the server.
//!
//! [`SessionBackend`] adapts a session to the coordinator's [`Backend`]
//! trait — the single serving backend for simulated-accelerator models.

use super::model::{CompiledLayer, CompiledModel, LayerExec, TypedModel};
use super::server::Backend;
use super::tensor::{RequestError, Tensor, TensorView};
use crate::algo::element::{AccElem, ElemKind, Element};
use crate::algo::Mat;
use crate::engine::{GemmPool, PoolStats};
use crate::util::with_width;
use std::sync::Arc;
use std::time::Instant;

/// Wall time one layer spent on one batch (staging + GEMM + post-GEMM).
#[derive(Debug, Clone)]
pub struct LayerTiming {
    pub name: Arc<str>,
    pub micros: u64,
}

// ---------------------------------------------------------------------
// Staging / execution split: the three per-layer phases as free
// functions over explicit buffers, so the sequential session below and
// the pipelined executor (`scheduler::pipeline`, which interleaves
// phase 1 of layer l+1 with phase 2 of layer l across micro-batches)
// share one implementation of each.
// ---------------------------------------------------------------------

/// Phase 0 — narrow a slab of client `i32` values into storage
/// elements.  Out-of-domain inputs are a typed request error, not a
/// silent truncation.
pub(crate) fn narrow_rows<E: Element>(
    data: &[i32],
    act: &mut Vec<E>,
) -> Result<(), RequestError> {
    act.clear();
    for &v in data {
        match E::from_i64(i64::from(v)) {
            Some(e) => act.push(e),
            None => {
                return Err(RequestError::Domain { value: v, bits: E::BITS })
            }
        }
    }
    Ok(())
}

/// Phase 1 — stage one layer's GEMM A operand from `rows` requests'
/// flat activations: FC rows copy directly; conv rows walk the §5.1
/// Algorithm 1 conv→GEMM mapping
/// ([`Im2Gemm::fill_virtual_a`](crate::memory::Im2Gemm::fill_virtual_a)).
/// `batch_cap` is the deployment batch the layer's GEMM M was compiled
/// for; `rows <= batch_cap` stages a leading row block (row-block GEMM
/// decomposition is exact, which is what makes micro-batch pipelining
/// bit-identical to the unsplit batch).
pub(crate) fn stage_layer_a<E: Element>(
    layer: &CompiledLayer<E>,
    batch_cap: usize,
    rows: usize,
    act: &[E],
    a: &mut Mat<E>,
) {
    match &layer.exec {
        LayerExec::Fc => {
            a.rows = rows;
            a.cols = layer.in_len;
            a.data.clear();
            a.data.extend_from_slice(&act[..rows * layer.in_len]);
        }
        LayerExec::Conv { ig } => {
            // per-request OH*OW rows through the Algorithm 1 walk
            let m1 = layer.gemm.m / batch_cap;
            a.rows = rows * m1;
            a.cols = layer.gemm.k;
            a.data.clear();
            a.data.resize(rows * m1 * layer.gemm.k, E::default());
            for r in 0..rows {
                let flat = &act[r * layer.in_len..(r + 1) * layer.in_len];
                ig.fill_virtual_a(flat, a, r * m1);
            }
        }
    }
}

/// Phase 3 — post-GEMM requantization of the widened accumulators
/// straight into the next layer's narrow activations (or the identity
/// pass-through on wide raw-accumulator storage).
pub(crate) fn apply_post_gemm<E: Element>(
    layer: &CompiledLayer<E>,
    c: &Mat<E::Acc>,
    act: &mut Vec<E>,
) {
    act.clear();
    match &layer.post {
        Some(post) => {
            let n = c.cols;
            act.extend(
                c.data
                    .iter()
                    .enumerate()
                    .map(|(i, &v)| post.apply_to::<E>(v, i % n)),
            );
        }
        None => {
            // raw accumulator streaming is only compiled for wide
            // storage (compile()'s storage rule), where this conversion
            // is the identity
            act.extend(c.data.iter().map(|&v| {
                E::from_i64(v.to_i64()).expect(
                    "raw accumulator streaming implies wide storage \
                     (enforced at compile())",
                )
            }));
        }
    }
}

/// The typed execution state behind [`InferenceSession`]: one storage
/// element `E` end to end.
struct TypedSession<E: Element> {
    model: Arc<TypedModel<E>>,
    pool: Arc<GemmPool>,
    /// Layer names shared with the per-batch timing records.
    names: Vec<Arc<str>>,
    /// Staged GEMM A operand (reused across layers and batches).
    a: Mat<E>,
    /// Widened GEMM output (reused; `gemm_into` resizes in place).
    c: Mat<E::Acc>,
    /// Flat inter-layer activations at storage width, `rows * layer_len`.
    act: Vec<E>,
    /// Per-layer wall times of the most recent batch.
    timings: Vec<LayerTiming>,
}

impl<E: Element> TypedSession<E> {
    fn new(model: Arc<TypedModel<E>>, pool: Arc<GemmPool>) -> Self {
        let names = model
            .layers
            .iter()
            .map(|l| Arc::<str>::from(l.name.as_str()))
            .collect();
        let mut a = Mat::zeros(0, 0);
        a.data.reserve(model.max_a_elems());
        let mut c = Mat::zeros(0, 0);
        c.data.reserve(model.max_a_elems().max(model.max_act_elems()));
        let act = Vec::with_capacity(model.max_act_elems());
        let n_layers = model.layers.len();
        TypedSession {
            model,
            pool,
            names,
            a,
            c,
            act,
            timings: Vec::with_capacity(n_layers),
        }
    }

    fn infer_batch(
        &mut self,
        input: TensorView<'_>,
    ) -> Result<Tensor, RequestError> {
        let model = self.model.clone();
        if input.row_len() != model.input_len {
            return Err(RequestError::BadShape {
                expected: model.input_len,
                got: input.row_len(),
            });
        }
        let rows = input.rows();
        assert!(
            rows >= 1 && rows <= model.cfg.batch,
            "session batch rows {rows} outside 1..={}",
            model.cfg.batch
        );
        // narrow the client values into storage; out-of-domain inputs
        // are a typed request error, not a silent truncation
        narrow_rows(input.data, &mut self.act)?;
        self.timings.clear();
        for (li, layer) in model.layers.iter().enumerate() {
            let t0 = Instant::now();
            // stage the A operand from the flat activations
            stage_layer_a(layer, model.cfg.batch, rows, &self.act, &mut self.a);
            // the layer GEMM on the shared pool, into the reused output
            self.pool.gemm_into(
                &self.a,
                &layer.weights,
                layer.y.as_deref(),
                &mut self.c,
                model.cfg.algo,
                layer.tile,
            );
            // post-GEMM requantization straight into the next layer's
            // narrow activations (or raw pass-through on wide storage)
            apply_post_gemm(layer, &self.c, &mut self.act);
            self.timings.push(LayerTiming {
                name: self.names[li].clone(),
                micros: t0.elapsed().as_micros() as u64,
            });
        }
        let data = self.act.iter().map(|&v| v.to_i64() as f32).collect();
        Ok(Tensor::new(rows, model.output_len, data))
    }
}

/// The width-tagged session state (mirrors [`CompiledModel`]'s
/// variants; kept private so the typed machinery stays an
/// implementation detail).
enum SessionInner {
    I8(TypedSession<i8>),
    I16(TypedSession<i16>),
    I64(TypedSession<i64>),
}

/// An inference session: executes one [`CompiledModel`] batch-by-batch
/// on a shared [`GemmPool`], at the storage width the model compiled
/// to.
pub struct InferenceSession {
    inner: SessionInner,
}

impl InferenceSession {
    /// Create a session with all inter-layer buffers preallocated to
    /// the model's largest layer, at the model's compiled storage
    /// width.
    pub fn new(model: &CompiledModel, pool: Arc<GemmPool>) -> Self {
        let inner = match model {
            CompiledModel::I8(m) => {
                SessionInner::I8(TypedSession::new(m.clone(), pool))
            }
            CompiledModel::I16(m) => {
                SessionInner::I16(TypedSession::new(m.clone(), pool))
            }
            CompiledModel::I64(m) => {
                SessionInner::I64(TypedSession::new(m.clone(), pool))
            }
        };
        InferenceSession { inner }
    }

    /// The storage element width this session executes on.
    pub fn storage(&self) -> ElemKind {
        match &self.inner {
            SessionInner::I8(_) => ElemKind::I8,
            SessionInner::I16(_) => ElemKind::I16,
            SessionInner::I64(_) => ElemKind::I64,
        }
    }

    /// Flat per-request input length.
    pub fn input_len(&self) -> usize {
        with_width!(SessionInner, &self.inner, s => s.model.input_len)
    }

    /// Flat per-request output length.
    pub fn output_len(&self) -> usize {
        with_width!(SessionInner, &self.inner, s => s.model.output_len)
    }

    /// The deployment's accelerator batch size.
    pub fn batch(&self) -> usize {
        with_width!(SessionInner, &self.inner, s => s.model.cfg.batch)
    }

    pub fn pool(&self) -> &Arc<GemmPool> {
        with_width!(SessionInner, &self.inner, s => &s.pool)
    }

    /// Execute one batch through every layer.  `input` is `rows` request
    /// rows (1 ≤ rows ≤ the compiled batch) of `input_len` activations;
    /// the result is `rows` rows of `output_len` values.
    pub fn infer_batch(
        &mut self,
        input: TensorView<'_>,
    ) -> Result<Tensor, RequestError> {
        with_width!(SessionInner, &mut self.inner, s => s.infer_batch(input))
    }

    /// Per-layer wall times of the most recent batch (drains them).
    pub fn take_layer_timings(&mut self) -> Vec<LayerTiming> {
        with_width!(SessionInner, &mut self.inner, s => std::mem::take(&mut s.timings))
    }
}

/// The coordinator [`Backend`] over an [`InferenceSession`] — how a
/// compiled model plugs into the batcher/worker/stats machinery.
pub struct SessionBackend {
    session: InferenceSession,
}

impl SessionBackend {
    pub fn new(session: InferenceSession) -> Self {
        SessionBackend { session }
    }

    pub fn session(&self) -> &InferenceSession {
        &self.session
    }
}

impl Backend for SessionBackend {
    fn input_len(&self) -> usize {
        self.session.input_len()
    }

    fn output_len(&self) -> usize {
        self.session.output_len()
    }

    fn batch(&self) -> usize {
        self.session.batch()
    }

    fn infer(&mut self, batch: TensorView<'_>) -> anyhow::Result<Tensor> {
        self.session.infer_batch(batch).map_err(anyhow::Error::from)
    }

    fn input_domain_bits(&self) -> Option<u32> {
        // narrow storage constrains the per-value input domain; the
        // coordinator worker then rejects out-of-range values per
        // request (wide storage accepts any i32)
        match self.session.storage() {
            ElemKind::I32 | ElemKind::I64 => None,
            narrow => Some(narrow.bits()),
        }
    }

    fn engine_stats(&self) -> Option<PoolStats> {
        Some(self.session.pool().stats())
    }

    fn layer_timings(&mut self) -> Option<Vec<LayerTiming>> {
        Some(self.session.take_layer_timings())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::{baseline_matmul, Algo};
    use crate::coordinator::{
        compile, DeployConfig, Model, PostGemm, Storage,
    };
    use crate::nn::models;
    use crate::quant::{requantize_tile, QuantScheme};
    use crate::util::Rng;

    fn session(
        model: &Model,
        cfg: DeployConfig,
        workers: usize,
    ) -> InferenceSession {
        let compiled = compile(model, cfg).unwrap();
        InferenceSession::new(&compiled, Arc::new(GemmPool::new(workers)))
    }

    #[test]
    fn mlp_session_equals_composed_baseline() {
        let model = Model::random(models::mlp(&[12, 10, 6]), 7, 3);
        let cfg = DeployConfig::new(Algo::Ffip).with_tile(4, 3).with_batch(3);
        let mut s = session(&model, cfg, 2);
        // raw accumulator streaming (no post) compiles to wide storage
        assert_eq!(s.storage(), ElemKind::I64);
        let mut rng = Rng::new(8);
        let input: Vec<i32> =
            (0..3 * 12).map(|_| rng.fixed(4, true) as i32).collect();
        let out = s.infer_batch(TensorView::new(3, 12, &input)).unwrap();
        // oracle: compose the exact GEMMs layer by layer
        let mut act =
            Mat::from_fn(3, 12, |i, j| i64::from(input[i * 12 + j]));
        for idx in 0..2 {
            act = baseline_matmul(&act, &model.layer_weights(idx).unwrap().w);
        }
        let got: Vec<i64> = out.data.iter().map(|&v| v as i64).collect();
        assert_eq!(got, act.data);
        assert_eq!(out.shape, [3, 6]);
        // per-layer timings recorded for the batch
        let t = s.take_layer_timings();
        assert_eq!(t.len(), 2);
        assert_eq!(&*t[0].name, "fc1");
    }

    #[test]
    fn post_gemm_requantization_matches_requantize_tile() {
        let mut model = Model::random(models::mlp(&[8, 5]), 9, 4);
        let scheme = QuantScheme::symmetric_signed(8, 0.25);
        let bias: Vec<i64> = (0..5).map(|j| j as i64 * 3 - 6).collect();
        model
            .set_post(0, PostGemm { bias: bias.clone(), scheme, relu: true })
            .unwrap();
        let cfg =
            DeployConfig::new(Algo::Baseline).with_tile(4, 2).with_batch(2);
        let mut s = session(&model, cfg, 0);
        // a fully requantized 8-bit model executes on i8 storage
        assert_eq!(s.storage(), ElemKind::I8);
        let mut rng = Rng::new(10);
        let input: Vec<i32> =
            (0..2 * 8).map(|_| rng.fixed(5, true) as i32).collect();
        let out = s.infer_batch(TensorView::new(2, 8, &input)).unwrap();
        let a = Mat::from_fn(2, 8, |i, j| i64::from(input[i * 8 + j]));
        let acc = baseline_matmul(&a, &model.layer_weights(0).unwrap().w);
        let want = requantize_tile(&acc, &bias, &scheme, true);
        let got: Vec<i64> = out.data.iter().map(|&v| v as i64).collect();
        assert_eq!(got, want.data);
        // the same model forced wide gives the same bits
        let mut wide =
            session(&model, cfg.with_storage(Storage::I64), 0);
        assert_eq!(wide.storage(), ElemKind::I64);
        let out_wide =
            wide.infer_batch(TensorView::new(2, 8, &input)).unwrap();
        assert_eq!(out_wide.data, out.data);
    }

    #[test]
    fn wrong_row_length_is_a_typed_error() {
        let model = Model::random(models::mlp(&[6, 4]), 11, 3);
        let cfg = DeployConfig::new(Algo::Fip).with_tile(2, 2).with_batch(1);
        let mut s = session(&model, cfg, 0);
        let input = vec![0i32; 5];
        let err = s.infer_batch(TensorView::new(1, 5, &input)).unwrap_err();
        assert_eq!(err, RequestError::BadShape { expected: 6, got: 5 });
    }

    #[test]
    fn out_of_domain_input_is_a_typed_error_on_narrow_storage() {
        let mut model = Model::random(models::mlp(&[4, 2]), 12, 4);
        model
            .set_post(
                0,
                PostGemm {
                    bias: vec![0; 2],
                    scheme: QuantScheme::symmetric_signed(8, 1.0),
                    relu: false,
                },
            )
            .unwrap();
        let cfg =
            DeployConfig::new(Algo::Baseline).with_tile(2, 2).with_batch(1);
        let mut s = session(&model, cfg, 0);
        assert_eq!(s.storage(), ElemKind::I8);
        let input = vec![1000i32, 0, 0, 0]; // 1000 does not fit i8
        let err = s.infer_batch(TensorView::new(1, 4, &input)).unwrap_err();
        assert_eq!(err, RequestError::Domain { value: 1000, bits: 8 });
        // in-domain requests still serve
        let ok = s
            .infer_batch(TensorView::new(1, 4, &[1, -2, 3, -4]))
            .unwrap();
        assert_eq!(ok.shape, [1, 2]);
    }
}
