//! Stage 3 of `Model → CompiledModel → InferenceSession`: execute a
//! compiled model's layers sequentially on the shared
//! [`GemmPool`](crate::engine::GemmPool).
//!
//! A session owns the mutable execution state for one deployment
//! worker, **typed at the model's storage element**: preallocated
//! inter-layer activation buffers (`act: Vec<E>`), a staged GEMM A
//! operand (`Mat<E>`) and the widened GEMM output (`Mat<E::Acc>`), all
//! reused across batches — an int8 deployment stages, streams and
//! stores `i8` end to end, touching 1/8 the operand bytes of the
//! historical all-`i64` path (bench H8).  With
//! [`GemmPool::gemm_into`](crate::engine::GemmPool::gemm_into) writing
//! into the reusable output, steady state allocates nothing per
//! request.  FC layers stage their batch rows directly; conv layers
//! stage through the in-place conv→GEMM walk
//! ([`Im2Gemm::fill_virtual_a`](crate::memory::Im2Gemm::fill_virtual_a),
//! §5.1 Algorithm 1) at the same narrow width.  FFIP deployments
//! consume the compile-time offline `y_from_b` weight terms (§3.3) in
//! their native one-extra-bit storage, and each layer's post-GEMM
//! requantization emits the next layer's narrow operands directly
//! ([`PostGemm::apply_to`](super::PostGemm::apply_to)).
//!
//! The public [`InferenceSession`] is a width-tagged wrapper over the
//! typed implementation, constructed from whichever storage the
//! [`CompiledModel`] selected at compile time.
//!
//! Every layer's wall time is measured per batch ([`LayerTiming`]) and
//! surfaced through [`ServeStats`](super::ServeStats), so the paper's
//! §6 layer-wise throughput breakdown is observable from the server.
//!
//! [`SessionBackend`] adapts a session to the coordinator's [`Backend`]
//! trait — the single serving backend for simulated-accelerator models.

use super::model::{
    AttnExec, CompiledLayer, CompiledModel, LayerExec, PostGemm, TypedModel,
    WinoExec,
};
use super::server::Backend;
use super::stats::FaultCounts;
use super::tensor::{RequestError, Tensor, TensorView};
use crate::algo::element::{AccElem, ElemKind, Element};
use crate::algo::winograd::{input_transform, output_transform, to_wide};
use crate::algo::{y_from_b_into, Algo, Mat};
use crate::arith::saturate_signed;
use crate::engine::{GemmError, GemmPool, PendingGemm, PoolStats};
use crate::quant::{requantize_to, softmax_fixed_row, SoftmaxScratch};
use crate::util::with_width;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Wall time one layer spent on one batch (staging + GEMM + post-GEMM).
#[derive(Debug, Clone)]
pub struct LayerTiming {
    pub name: Arc<str>,
    pub micros: u64,
}

// ---------------------------------------------------------------------
// Staging / execution split: the three per-layer phases as free
// functions over explicit buffers, so the sequential session below and
// the pipelined executor (`scheduler::pipeline`, which interleaves
// phase 1 of layer l+1 with phase 2 of layer l across micro-batches)
// share one implementation of each.
// ---------------------------------------------------------------------

/// Map an engine fault ([`GemmError`]) to the typed per-request error
/// for the layer it struck, bumping the matching [`FaultCounts`]
/// counter: a poisoned job (worker panic) sheds as
/// [`RequestError::FaultDetected`], a watchdog expiry as
/// [`RequestError::DeadlineExceeded`].
pub(crate) fn gemm_error_to_request(
    e: GemmError,
    layer: &str,
    deadline: Option<Duration>,
    counts: &mut FaultCounts,
) -> RequestError {
    match e {
        GemmError::Poisoned => {
            counts.fault_shed += 1;
            RequestError::FaultDetected { layer: layer.to_string() }
        }
        GemmError::Timeout { waited } => {
            counts.watchdog_trips += 1;
            RequestError::DeadlineExceeded {
                waited_ms: waited.as_millis() as u64,
                deadline_ms: deadline.unwrap_or(waited).as_millis() as u64,
            }
        }
    }
}

/// Run the layer's ABFT verification over a finished GEMM, if the
/// layer compiled with checksums: transient corruption heals in place
/// (recorded in `counts`), persistent disagreement sheds the batch as
/// [`RequestError::FaultDetected`].  Layers without checksums (ABFT
/// off, no stationary B operand, or headroom gate failed) are a no-op.
pub(crate) fn verify_layer_abft<E: Element>(
    layer: &CompiledLayer<E>,
    a: &Mat<E>,
    c: &mut Mat<E::Acc>,
    pool: &GemmPool,
    counts: &mut FaultCounts,
) -> Result<(), RequestError> {
    let Some(check) = &layer.abft else { return Ok(()) };
    let fs = pool.fault_state();
    match check.verify_and_heal(
        a,
        &layer.weights,
        layer.y.as_deref(),
        c,
        fs.as_deref(),
    ) {
        Ok(rep) => {
            counts.detected += rep.trips;
            counts.recomputes += rep.recomputes;
            if rep.trips > 0 {
                counts.recovered += 1;
            }
            Ok(())
        }
        Err(f) => {
            counts.detected += f.trips;
            counts.recomputes += f.recomputes;
            counts.fault_shed += 1;
            Err(RequestError::FaultDetected { layer: layer.name.clone() })
        }
    }
}

/// One fault-checked stationary-weight layer GEMM: run on the pool
/// (typed errors for poisoned jobs and watchdog expiries), then verify
/// and heal through the layer's ABFT checksums.
pub(crate) fn gemm_layer_checked<E: Element>(
    pool: &GemmPool,
    layer: &CompiledLayer<E>,
    a: &Mat<E>,
    c: &mut Mat<E::Acc>,
    counts: &mut FaultCounts,
    deadline: Option<Duration>,
) -> Result<(), RequestError> {
    pool.gemm_into_checked(
        a,
        &layer.weights,
        layer.y.as_deref(),
        c,
        layer.algo,
        layer.tile,
    )
    .map_err(|e| gemm_error_to_request(e, &layer.name, deadline, counts))?;
    verify_layer_abft(layer, a, c, pool, counts)
}

/// Phase 0 — narrow a slab of client `i32` values into storage
/// elements.  Out-of-domain inputs are a typed request error, not a
/// silent truncation.
pub(crate) fn narrow_rows<E: Element>(
    data: &[i32],
    act: &mut Vec<E>,
) -> Result<(), RequestError> {
    act.clear();
    for &v in data {
        match E::from_i64(i64::from(v)) {
            Some(e) => act.push(e),
            None => {
                return Err(RequestError::Domain { value: v, bits: E::BITS })
            }
        }
    }
    Ok(())
}

/// Phase 1 — stage one layer's GEMM A operand from `rows` requests'
/// flat activations: FC rows copy directly; conv rows walk the §5.1
/// Algorithm 1 conv→GEMM mapping
/// ([`Im2Gemm::fill_virtual_a`](crate::memory::Im2Gemm::fill_virtual_a)).
/// `batch_cap` is the deployment batch the layer's GEMM M was compiled
/// for; `rows <= batch_cap` stages a leading row block (row-block GEMM
/// decomposition is exact, which is what makes micro-batch pipelining
/// bit-identical to the unsplit batch).
pub(crate) fn stage_layer_a<E: Element>(
    layer: &CompiledLayer<E>,
    batch_cap: usize,
    rows: usize,
    act: &[E],
    a: &mut Mat<E>,
) {
    match &layer.exec {
        LayerExec::Fc => {
            a.rows = rows;
            a.cols = layer.in_len;
            a.data.clear();
            a.data.extend_from_slice(&act[..rows * layer.in_len]);
        }
        LayerExec::Conv { ig } => {
            // per-request OH*OW rows through the Algorithm 1 walk
            let m1 = layer.gemm.m / batch_cap;
            a.rows = rows * m1;
            a.cols = layer.gemm.k;
            a.data.clear();
            a.data.resize(rows * m1 * layer.gemm.k, E::default());
            for r in 0..rows {
                let flat = &act[r * layer.in_len..(r + 1) * layer.in_len];
                ig.fill_virtual_a(flat, a, r * m1);
            }
        }
        LayerExec::WinoConv(_) => {
            unreachable!("winograd conv layers execute through run_winograd")
        }
        LayerExec::Attention(_) => {
            unreachable!("attention layers execute through run_attention")
        }
        LayerExec::TokenFc { .. } => {
            unreachable!("token-fc layers execute through run_token_fc")
        }
        LayerExec::Residual { .. } => {
            unreachable!("residual layers execute through run_residual")
        }
    }
}

/// Execute one [`LayerExec::TokenFc`] layer — an FC inside a ragged
/// transformer block: gather every request's valid tokens into dense
/// GEMM A rows, run one GEMM over all of them against the stationary
/// weights (offline y under FFIP), requantize, and scatter back under
/// the same `[len, tokens, pad]` length prefixes with the tail
/// re-zeroed.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_token_fc<E: Element>(
    layer: &CompiledLayer<E>,
    max_seq: usize,
    pool: &GemmPool,
    rows: usize,
    act: &mut Vec<E>,
    a: &mut Mat<E>,
    c: &mut Mat<E::Acc>,
    lens: &mut Vec<usize>,
    counts: &mut FaultCounts,
    deadline: Option<Duration>,
) -> Result<(), RequestError> {
    let d_in = layer.weights.rows;
    let d_out = layer.weights.cols;
    let row_in = 1 + max_seq * d_in;
    let row_out = 1 + max_seq * d_out;
    assert_eq!(act.len(), rows * row_in, "token-fc activation slab");
    lens.clear();
    for r in 0..rows {
        let len = act[r * row_in].to_i64();
        if len < 0 || len > max_seq as i64 {
            return Err(RequestError::BadSequence { len, max_seq });
        }
        lens.push(len as usize);
    }
    // gather the valid tokens of every request into dense GEMM rows
    let total: usize = lens.iter().sum();
    a.rows = total;
    a.cols = d_in;
    a.data.clear();
    for r in 0..rows {
        let base = r * row_in + 1;
        a.data.extend_from_slice(&act[base..base + lens[r] * d_in]);
    }
    if total > 0 {
        gemm_layer_checked(pool, layer, a, c, counts, deadline)?;
    }
    // scatter requantized outputs back under the same length prefixes
    act.clear();
    act.resize(rows * row_out, E::default());
    let mut tok = 0usize;
    for r in 0..rows {
        let s = lens[r];
        let row = &mut act[r * row_out..(r + 1) * row_out];
        row[0] = E::from_i64(s as i64)
            .expect("max_seq fits the storage element (compile-time check)");
        for i in 0..s {
            let crow = c.row(tok + i);
            let dst = &mut row[1 + i * d_out..1 + (i + 1) * d_out];
            match &layer.post {
                Some(post) => {
                    for (j, (&acc, o)) in
                        crow.iter().zip(dst.iter_mut()).enumerate()
                    {
                        *o = post.apply_to::<E>(acc, j);
                    }
                }
                None => {
                    for (&acc, o) in crow.iter().zip(dst.iter_mut()) {
                        *o = E::from_i64(acc.to_i64()).expect(
                            "raw accumulator streaming implies wide \
                             storage (enforced at compile())",
                        );
                    }
                }
            }
        }
        tok += s;
    }
    Ok(())
}

/// Execute one [`LayerExec::Residual`] layer: token-wise
/// `act += saved`, saturated to `bits` (the nearest preceding
/// post-GEMM quantized width, so the sum is bit-identical at every
/// storage width).  `saved` is the input slab of the layer `span`
/// positions back, snapshotted by the session before that layer ran.
/// Ragged rows skip their in-band length prefix slot (lengths are
/// preserved through the block, and the zero pads add to zero).
pub(crate) fn run_residual<E: Element>(
    bits: u32,
    ragged: bool,
    row_len: usize,
    rows: usize,
    saved: &[E],
    act: &mut [E],
) {
    assert_eq!(act.len(), rows * row_len, "residual activation slab");
    assert_eq!(saved.len(), act.len(), "saved input slab matches");
    let skip = usize::from(ragged);
    for r in 0..rows {
        for i in r * row_len + skip..(r + 1) * row_len {
            let sum = act[i].to_i64() + saved[i].to_i64();
            act[i] = E::from_i64(saturate_signed(sum, bits))
                .expect("saturated w-bit value fits the storage element");
        }
    }
}

/// Reusable execution state for one deployment worker's Winograd conv
/// layers: the 16 staged V operands, the recycled stage-product
/// buffers, and the in-flight stage jobs.  Everything grows to its
/// high-water size on the first batch, then steady state allocates
/// nothing.
pub(crate) struct WinoScratch<E: Element> {
    /// Staged Winograd-domain V operands (one per elementwise stage,
    /// recycled through [`PendingGemm::wait_with_inputs`]).
    v: Vec<Mat<E::Wide>>,
    /// Recycled stage-product buffers.
    m: Vec<Mat<<E::Wide as Element>::Acc>>,
    /// In-flight stage jobs (the Vec keeps its capacity).
    pend: Vec<PendingGemm<E::Wide>>,
    /// Products of the most recent batch, in stage order `i * 4 + j`.
    prods: Vec<Mat<<E::Wide as Element>::Acc>>,
}

impl<E: Element> WinoScratch<E> {
    pub(crate) fn new() -> Self {
        WinoScratch {
            v: Vec::new(),
            m: Vec::new(),
            pend: Vec::new(),
            prods: Vec::new(),
        }
    }
}

/// Execute one [`ConvAlgo::WinogradFfip`](crate::algo::ConvAlgo) conv
/// layer in place over the flat activation slab — the serving path of
/// the §6.2.2 Winograd×(F)FIP composition:
///
/// 1. gather each request's 4×4 input tiles (zero-filled beyond the
///    padded border) and scatter `BᵀdB` into the 16 stage operands as
///    [`Element::Wide`] values (the ×4 growth fits by construction);
/// 2. run the 16 `(rows·tiles × Cin) × Cout` stage GEMMs concurrently
///    on the pool against the compile-time-transformed stationary U
///    operands (offline y under FFIP), recycling every buffer;
/// 3. fold the products back through `AᵀMA` (an exact `/4`) and
///    requantize straight into the next layer's narrow activations.
///
/// Bit-identical to the direct conv oracle: the transforms are exact
/// over integers and the stage GEMMs run the same inner-product
/// kernels as every other layer.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_winograd<E: Element>(
    wx: &WinoExec<E>,
    post: Option<&PostGemm>,
    pool: &GemmPool,
    algo: Algo,
    rows: usize,
    act: &mut Vec<E>,
    scr: &mut WinoScratch<E>,
    lname: &str,
    counts: &mut FaultCounts,
    deadline: Option<Duration>,
) -> Result<(), RequestError> {
    let s = wx.shape;
    let (h, w, cin, cout) = (s.h, s.w, s.cin, s.cout);
    let (oh, ow) = (s.out_h(), s.out_w());
    let in_len = h * w * cin;
    let out_len = oh * ow * cout;
    let tpr = wx.th * wx.tw; // winograd tiles per request
    let vrows = rows * tpr;
    assert_eq!(act.len(), rows * in_len, "conv activation slab");
    let pad = s.pad as isize;
    // 1) input transform into the 16 stage operands
    while scr.v.len() < 16 {
        scr.v.push(Mat::zeros(0, 0));
    }
    for vm in scr.v.iter_mut() {
        vm.rows = vrows;
        vm.cols = cin;
        vm.data.clear();
        vm.data.resize(vrows * cin, <E::Wide>::default());
    }
    for r in 0..rows {
        let flat = &act[r * in_len..(r + 1) * in_len];
        for ty in 0..wx.th {
            for tx in 0..wx.tw {
                let vr = r * tpr + ty * wx.tw + tx;
                for c in 0..cin {
                    let mut d = [[<E::Acc>::default(); 4]; 4];
                    for (i, drow) in d.iter_mut().enumerate() {
                        let iy = (2 * ty + i) as isize - pad;
                        if iy < 0 || iy >= h as isize {
                            continue; // zero pad row
                        }
                        for (j, cell) in drow.iter_mut().enumerate() {
                            let ix = (2 * tx + j) as isize - pad;
                            if ix < 0 || ix >= w as isize {
                                continue; // zero pad column
                            }
                            *cell = flat
                                [(iy as usize * w + ix as usize) * cin + c]
                                .acc();
                        }
                    }
                    let t = input_transform(&d);
                    for (i, trow) in t.iter().enumerate() {
                        for (j, &tv) in trow.iter().enumerate() {
                            scr.v[i * 4 + j].data[vr * cin + c] =
                                to_wide::<E>(tv);
                        }
                    }
                }
            }
        }
    }
    // 2) the 16 elementwise-stage GEMMs, concurrently on the pool
    debug_assert!(scr.pend.is_empty());
    // a prior batch that shed mid-drain leaves its partial products
    // here; recycle them before staging this batch's jobs
    scr.m.extend(scr.prods.drain(..));
    for (xi, vm) in scr.v.drain(..).enumerate() {
        let c = scr.m.pop().unwrap_or_else(|| Mat::zeros(0, 0));
        scr.pend.push(pool.submit_into(
            vm,
            wx.u[xi].clone(),
            wx.yu[xi].clone(),
            c,
            algo,
            wx.tile,
        ));
    }
    // an early error return is safe with stage jobs still in flight:
    // dropping a PendingGemm settles it quietly
    for pend in scr.pend.drain(..) {
        let (prod, vbuf) = pend.wait_with_inputs_checked().map_err(|e| {
            gemm_error_to_request(e, lname, deadline, counts)
        })?;
        scr.v.push(vbuf);
        scr.prods.push(prod);
    }
    // 3) output transform (exact /4) + post-GEMM requantization
    act.clear();
    act.resize(rows * out_len, E::default());
    for r in 0..rows {
        for ty in 0..wx.th {
            for tx in 0..wx.tw {
                let vr = r * tpr + ty * wx.tw + tx;
                for co in 0..cout {
                    let mut mm =
                        [[<<E::Wide as Element>::Acc>::default(); 4]; 4];
                    for (i, mrow) in mm.iter_mut().enumerate() {
                        for (j, cell) in mrow.iter_mut().enumerate() {
                            *cell = scr.prods[i * 4 + j][(vr, co)];
                        }
                    }
                    let y = output_transform(&mm);
                    for (dy, yrow) in y.iter().enumerate() {
                        for (dx, &yv) in yrow.iter().enumerate() {
                            let (oy, ox) = (2 * ty + dy, 2 * tx + dx);
                            let v = match post {
                                Some(p) => p.apply(yv.to_i64(), co),
                                None => yv.to_i64(),
                            };
                            act[r * out_len + (oy * ow + ox) * cout + co] =
                                E::from_i64(v).expect(
                                    "requantized value fits the storage \
                                     element (compile-time invariant)",
                                );
                        }
                    }
                }
            }
        }
    }
    scr.m.extend(scr.prods.drain(..));
    Ok(())
}

/// Reusable execution state for one deployment worker's attention
/// layers: stacked-token staging mats, softmax scratch, and the free
/// pools of per-head operand buffers cycling through
/// [`GemmPool::submit_online`] jobs.  Everything grows to its
/// high-water size on the first batch, then steady state allocates
/// nothing.
pub(crate) struct AttnScratch<E: Element> {
    /// Every request's valid tokens stacked row-major (Σseq x d_model).
    xa: Mat<E>,
    /// Requantized Q/K/V projections, stacked like `xa`; after the
    /// output projection `q` is reused for the final token outputs.
    q: Mat<E>,
    k: Mat<E>,
    v: Mat<E>,
    /// Per-head attention outputs restacked for the output projection.
    o: Mat<E>,
    /// Widened projection accumulators.
    c: Mat<E::Acc>,
    /// Valid sequence length per batch row.
    lens: Vec<usize>,
    /// One QKᵀ score row widened to the softmax domain.
    zrow: Vec<i64>,
    /// One softmax probability row.
    probs: Vec<i64>,
    smax: SoftmaxScratch,
    /// Recycled per-head storage-width operand buffers.
    free_e: Vec<Mat<E>>,
    /// Recycled per-head accumulator buffers.
    free_acc: Vec<Mat<E::Acc>>,
    /// Recycled online-y buffers (FFIP deployments only).
    free_y: Vec<Mat<E::Y>>,
    /// In-flight per-head jobs (the Vecs keep their capacity).
    qk_pend: Vec<PendingGemm<E>>,
    av_pend: Vec<PendingGemm<E>>,
}

impl<E: Element> AttnScratch<E> {
    pub(crate) fn new() -> Self {
        AttnScratch {
            xa: Mat::zeros(0, 0),
            q: Mat::zeros(0, 0),
            k: Mat::zeros(0, 0),
            v: Mat::zeros(0, 0),
            o: Mat::zeros(0, 0),
            c: Mat::zeros(0, 0),
            lens: Vec::new(),
            zrow: Vec::new(),
            probs: Vec::new(),
            smax: SoftmaxScratch::default(),
            free_e: Vec::new(),
            free_acc: Vec::new(),
            free_y: Vec::new(),
            qk_pend: Vec::new(),
            av_pend: Vec::new(),
        }
    }
}

/// One projection GEMM over the stacked tokens against a stationary
/// weight (offline y is legal here), requantized straight into narrow
/// activations with the packed-bias segment at `bias_off`.  Engine
/// faults (poisoned job, watchdog expiry) surface as typed errors for
/// the caller to map onto the request.
#[allow(clippy::too_many_arguments)]
pub(crate) fn project<E: Element>(
    pool: &GemmPool,
    algo: Algo,
    xa: &Mat<E>,
    w: &Mat<E>,
    y: Option<&Mat<E::Y>>,
    tile: crate::algo::TileShape,
    post: &PostGemm,
    bias_off: usize,
    relu: bool,
    c: &mut Mat<E::Acc>,
    out: &mut Mat<E>,
) -> Result<(), GemmError> {
    pool.gemm_into_checked(xa, w, y, c, algo, tile)?;
    let n = c.cols;
    out.rows = c.rows;
    out.cols = n;
    out.data.clear();
    out.data.extend(c.data.iter().enumerate().map(|(i, &v)| {
        requantize_to::<E>(v, post.bias[bias_off + i % n], &post.scheme, relu)
    }));
    Ok(())
}

/// Execute one attention layer in place over the flat activation slab
/// (`rows` ragged `[len, tokens, pad]` rows of `1 + max_seq * d_model`
/// storage elements) — the serving path of
/// [`Layer::Attention`](crate::nn::Layer::Attention):
///
/// 1. validate every row's ragged length prefix ([`RequestError::BadSequence`]);
/// 2. stack the valid tokens and run the Q/K/V projections (stationary
///    weights, compile-time offline y) batched across requests;
/// 3. per request and head, QKᵀ on the pool via
///    [`GemmPool::submit_online`] — both operands are activations, so
///    under FFIP the y transform is computed **online** with
///    [`y_from_b_into`], the scenario that moves §3.3's Θ(NK)
///    subtractions onto the critical path;
/// 4. fixed-point softmax over each score row's `seq` valid keys
///    (never the zero pad: softmax is not padding-exact), probabilities
///    summing to exactly `softmax.one`;
/// 5. AV per head (K = seq zero-padded to even — exact for the
///    inner-product algorithms), requantized by `1/one` back to the
///    activation domain;
/// 6. output projection, then `[len, tokens, pad]` rows written back.
///
/// All heads of a request are in flight concurrently, and every operand
/// buffer cycles through the scratch free pools, so steady state
/// allocates nothing.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_attention<E: Element>(
    at: &AttnExec<E>,
    post: &PostGemm,
    pool: &GemmPool,
    algo: Algo,
    rows: usize,
    act: &mut [E],
    scr: &mut AttnScratch<E>,
    lname: &str,
    counts: &mut FaultCounts,
    deadline: Option<Duration>,
) -> Result<(), RequestError> {
    let d = at.d_model;
    let dh = at.d_head;
    let row_len = 1 + at.max_seq * d;
    assert_eq!(act.len(), rows * row_len, "attention activation slab");
    let AttnScratch {
        xa,
        q,
        k,
        v,
        o,
        c,
        lens,
        zrow,
        probs,
        smax,
        free_e,
        free_acc,
        free_y,
        qk_pend,
        av_pend,
    } = scr;
    // 1) ragged lengths ride in-band; a bad one is a typed per-request
    // error (swept before batching by the replica scheduler, and
    // checked again here as defense in depth)
    lens.clear();
    for r in 0..rows {
        let len = act[r * row_len].to_i64();
        if len < 0 || len > at.max_seq as i64 {
            return Err(RequestError::BadSequence {
                len,
                max_seq: at.max_seq,
            });
        }
        lens.push(len as usize);
    }
    let total: usize = lens.iter().sum();
    if total > 0 {
        // 2) stack the valid tokens of every request
        xa.rows = total;
        xa.cols = d;
        xa.data.clear();
        for r in 0..rows {
            let base = r * row_len + 1;
            xa.data.extend_from_slice(&act[base..base + lens[r] * d]);
        }
        // 3) Q/K/V projections batched across requests; the packed bias
        // carries one segment per projection
        let fault =
            |e, counts: &mut FaultCounts| gemm_error_to_request(e, lname, deadline, counts);
        project(pool, algo, xa, &at.wq, at.yq.as_deref(), at.proj_tile,
                post, 0, false, c, q)
            .map_err(|e| fault(e, counts))?;
        project(pool, algo, xa, &at.wk, at.yk.as_deref(), at.proj_tile,
                post, d, false, c, k)
            .map_err(|e| fault(e, counts))?;
        project(pool, algo, xa, &at.wv, at.yv.as_deref(), at.proj_tile,
                post, 2 * d, false, c, v)
            .map_err(|e| fault(e, counts))?;
        // 4)+5) per-request, per-head QKᵀ → softmax → AV
        o.reset_to(total, d);
        let mut base = 0usize;
        for r in 0..rows {
            let s = lens[r];
            if s == 0 {
                continue;
            }
            let s_pad = s + s % 2;
            // all heads' QKᵀ jobs in flight concurrently
            debug_assert!(qk_pend.is_empty());
            for h in 0..at.heads {
                let hc = h * dh;
                let mut a = free_e.pop().unwrap_or_else(|| Mat::zeros(0, 0));
                a.rows = s;
                a.cols = dh;
                a.data.clear();
                for i in 0..s {
                    a.data.extend_from_slice(&q.row(base + i)[hc..hc + dh]);
                }
                let mut b = free_e.pop().unwrap_or_else(|| Mat::zeros(0, 0));
                b.rows = dh;
                b.cols = s;
                b.data.clear();
                for i in 0..dh {
                    for j in 0..s {
                        b.data.push(k[(base + j, hc + i)]);
                    }
                }
                // the online-y critical path: no compile-time transform
                // exists for an activation B operand
                let y = (algo == Algo::Ffip).then(|| {
                    let mut y =
                        free_y.pop().unwrap_or_else(|| Mat::zeros(0, 0));
                    y_from_b_into(&b, at.qk_tile.y, &mut y);
                    y
                });
                let cbuf =
                    free_acc.pop().unwrap_or_else(|| Mat::zeros(0, 0));
                qk_pend.push(
                    pool.submit_online(a, b, y, cbuf, algo, at.qk_tile),
                );
            }
            // drain scores head by head, submitting each head's AV as
            // soon as its probabilities exist (an early error return is
            // safe with sibling heads in flight: dropping a PendingGemm
            // settles it quietly)
            debug_assert!(av_pend.is_empty());
            for pend in qk_pend.drain(..) {
                let hc = av_pend.len() * dh;
                let (scores, mut p, mut vp, y) = pend
                    .wait_with_operands_checked()
                    .map_err(|e| fault(e, counts))?;
                if let Some(y) = y {
                    free_y.push(y);
                }
                // softmax over each row's valid keys — all s of them,
                // or only keys 0..=i under causal masking — then P rows
                // (s x s_pad, the zero pad column keeps the AV depth
                // even and the masked-out tail at exactly zero)
                p.rows = s;
                p.cols = s_pad;
                p.data.clear();
                for i in 0..s {
                    let valid = if at.causal { i + 1 } else { s };
                    zrow.clear();
                    zrow.extend(
                        scores.row(i)[..valid].iter().map(|&z| z.to_i64()),
                    );
                    probs.clear();
                    probs.resize(valid, 0);
                    softmax_fixed_row(zrow, &at.softmax, smax, probs);
                    p.data.extend(probs.iter().map(|&pv| {
                        E::from_i64(pv).expect(
                            "probabilities fit the activation width \
                             (w <= storage bits)",
                        )
                    }));
                    p.data.resize((i + 1) * s_pad, E::default());
                }
                // the Kᵀ buffer becomes the zero-row-padded V_rh
                vp.rows = s_pad;
                vp.cols = dh;
                vp.data.clear();
                for j in 0..s {
                    vp.data
                        .extend_from_slice(&v.row(base + j)[hc..hc + dh]);
                }
                vp.data.resize(s_pad * dh, E::default());
                let y = (algo == Algo::Ffip).then(|| {
                    let mut y =
                        free_y.pop().unwrap_or_else(|| Mat::zeros(0, 0));
                    y_from_b_into(&vp, at.av_tile.y, &mut y);
                    y
                });
                av_pend.push(
                    pool.submit_online(p, vp, y, scores, algo, at.av_tile),
                );
            }
            // drain AV heads: requantize the probability-weighted V
            // sums (scale softmax.one) back to the activation domain
            for (h, pend) in av_pend.drain(..).enumerate() {
                let hc = h * dh;
                let (avc, p, vp, y) = pend
                    .wait_with_operands_checked()
                    .map_err(|e| fault(e, counts))?;
                if let Some(y) = y {
                    free_y.push(y);
                }
                for i in 0..s {
                    for (j, &acc) in avc.row(i).iter().enumerate() {
                        o[(base + i, hc + j)] =
                            requantize_to::<E>(acc, 0, &at.av_scheme, false);
                    }
                }
                free_e.push(p);
                free_e.push(vp);
                free_acc.push(avc);
            }
            base += s;
        }
        // 6) output projection over the restacked heads (bias segment
        // 3, the layer's ReLU if any); `q` is recycled as the result
        project(pool, algo, o, &at.wo, at.yo.as_deref(), at.proj_tile,
                post, 3 * d, post.relu, c, q)
            .map_err(|e| fault(e, counts))?;
    }
    // 7) emit `[len, tokens, zero pad]` rows in place
    let mut base = 0usize;
    for r in 0..rows {
        let s = lens[r];
        let row = &mut act[r * row_len..(r + 1) * row_len];
        row.fill(E::default());
        row[0] = E::from_i64(s as i64)
            .expect("max_seq fits the storage element (compile-time check)");
        for i in 0..s {
            row[1 + i * d..1 + (i + 1) * d].copy_from_slice(q.row(base + i));
        }
        base += s;
    }
    Ok(())
}

/// Phase 3 — post-GEMM requantization of the widened accumulators
/// straight into the next layer's narrow activations (or the identity
/// pass-through on wide raw-accumulator storage).
pub(crate) fn apply_post_gemm<E: Element>(
    layer: &CompiledLayer<E>,
    c: &Mat<E::Acc>,
    act: &mut Vec<E>,
) {
    act.clear();
    match &layer.post {
        Some(post) => {
            let n = c.cols;
            act.extend(
                c.data
                    .iter()
                    .enumerate()
                    .map(|(i, &v)| post.apply_to::<E>(v, i % n)),
            );
        }
        None => {
            // raw accumulator streaming is only compiled for wide
            // storage (compile()'s storage rule), where this conversion
            // is the identity
            act.extend(c.data.iter().map(|&v| {
                E::from_i64(v.to_i64()).expect(
                    "raw accumulator streaming implies wide storage \
                     (enforced at compile())",
                )
            }));
        }
    }
}

/// The typed execution state behind [`InferenceSession`]: one storage
/// element `E` end to end.
struct TypedSession<E: Element> {
    model: Arc<TypedModel<E>>,
    pool: Arc<GemmPool>,
    /// Layer names shared with the per-batch timing records.
    names: Vec<Arc<str>>,
    /// Staged GEMM A operand (reused across layers and batches).
    a: Mat<E>,
    /// Widened GEMM output (reused; `gemm_into` resizes in place).
    c: Mat<E::Acc>,
    /// Flat inter-layer activations at storage width, `rows * layer_len`.
    act: Vec<E>,
    /// Reusable attention execution state (empty for attention-free
    /// models).
    attn: AttnScratch<E>,
    /// Reusable Winograd conv execution state (empty for models with
    /// no winograd-lowered layers).
    wino: WinoScratch<E>,
    /// Saved input slabs, one per layer flagged
    /// [`CompiledLayer::save_input`] (a later residual adds it back);
    /// empty vecs elsewhere.
    saves: Vec<Vec<E>>,
    /// Per-request valid lengths of the token-fc ragged rows.
    tf_lens: Vec<usize>,
    /// Per-layer wall times of the most recent batch.
    timings: Vec<LayerTiming>,
    /// Fault-tolerance counters accumulated since the last drain (ABFT
    /// trips, heals, sheds, watchdog expiries).
    faults: FaultCounts,
}

impl<E: Element> TypedSession<E> {
    fn new(model: Arc<TypedModel<E>>, pool: Arc<GemmPool>) -> Self {
        let names = model
            .layers
            .iter()
            .map(|l| Arc::<str>::from(l.name.as_str()))
            .collect();
        let mut a = Mat::zeros(0, 0);
        a.data.reserve(model.max_a_elems());
        let mut c = Mat::zeros(0, 0);
        c.data.reserve(model.max_a_elems().max(model.max_act_elems()));
        let act = Vec::with_capacity(model.max_act_elems());
        let n_layers = model.layers.len();
        TypedSession {
            model,
            pool,
            names,
            a,
            c,
            act,
            attn: AttnScratch::new(),
            wino: WinoScratch::new(),
            saves: (0..n_layers).map(|_| Vec::new()).collect(),
            tf_lens: Vec::new(),
            timings: Vec::with_capacity(n_layers),
            faults: FaultCounts::default(),
        }
    }

    fn infer_batch(
        &mut self,
        input: TensorView<'_>,
    ) -> Result<Tensor, RequestError> {
        let model = self.model.clone();
        if input.row_len() != model.input_len {
            return Err(RequestError::BadShape {
                expected: model.input_len,
                got: input.row_len(),
            });
        }
        let rows = input.rows();
        assert!(
            rows >= 1 && rows <= model.cfg.batch,
            "session batch rows {rows} outside 1..={}",
            model.cfg.batch
        );
        // narrow the client values into storage; out-of-domain inputs
        // are a typed request error, not a silent truncation
        narrow_rows(input.data, &mut self.act)?;
        self.timings.clear();
        let deadline = model.cfg.request_deadline;
        for (li, layer) in model.layers.iter().enumerate() {
            let t0 = Instant::now();
            if layer.save_input {
                // a later residual adds this layer's input back in
                self.saves[li].clear();
                self.saves[li].extend_from_slice(&self.act);
            }
            match &layer.exec {
                LayerExec::Attention(at) => {
                    // attention runs its whole projection/QKᵀ/softmax/AV
                    // plan in place over the ragged activation rows
                    let post = layer
                        .post
                        .as_ref()
                        .expect("attention compiles with a post-GEMM stage");
                    run_attention(
                        at,
                        post,
                        &self.pool,
                        layer.algo,
                        rows,
                        &mut self.act,
                        &mut self.attn,
                        &layer.name,
                        &mut self.faults,
                        deadline,
                    )?;
                }
                LayerExec::WinoConv(wx) => {
                    // winograd conv stages, runs and untransforms its 16
                    // stage GEMMs itself
                    run_winograd(
                        wx,
                        layer.post.as_ref(),
                        &self.pool,
                        layer.algo,
                        rows,
                        &mut self.act,
                        &mut self.wino,
                        &layer.name,
                        &mut self.faults,
                        deadline,
                    )?;
                }
                LayerExec::TokenFc { max_seq } => {
                    run_token_fc(
                        layer,
                        *max_seq,
                        &self.pool,
                        rows,
                        &mut self.act,
                        &mut self.a,
                        &mut self.c,
                        &mut self.tf_lens,
                        &mut self.faults,
                        deadline,
                    )?;
                }
                LayerExec::Residual { span, bits, ragged } => {
                    run_residual(
                        *bits,
                        *ragged,
                        layer.in_len,
                        rows,
                        &self.saves[li - span],
                        &mut self.act,
                    );
                }
                LayerExec::Fc | LayerExec::Conv { .. } => {
                    // stage the A operand from the flat activations
                    stage_layer_a(
                        layer,
                        model.cfg.batch,
                        rows,
                        &self.act,
                        &mut self.a,
                    );
                    // the fault-checked layer GEMM on the shared pool,
                    // into the reused output, verified and healed
                    // through the layer's ABFT checksums
                    gemm_layer_checked(
                        &self.pool,
                        layer,
                        &self.a,
                        &mut self.c,
                        &mut self.faults,
                        deadline,
                    )?;
                    // post-GEMM requantization straight into the next
                    // layer's narrow activations (or raw pass-through
                    // on wide storage)
                    apply_post_gemm(layer, &self.c, &mut self.act);
                }
            }
            self.timings.push(LayerTiming {
                name: self.names[li].clone(),
                micros: t0.elapsed().as_micros() as u64,
            });
        }
        let data = self.act.iter().map(|&v| v.to_i64() as f32).collect();
        Ok(Tensor::new(rows, model.output_len, data))
    }
}

/// The width-tagged session state (mirrors [`CompiledModel`]'s
/// variants; kept private so the typed machinery stays an
/// implementation detail).
enum SessionInner {
    I8(TypedSession<i8>),
    I16(TypedSession<i16>),
    I64(TypedSession<i64>),
}

/// An inference session: executes one [`CompiledModel`] batch-by-batch
/// on a shared [`GemmPool`], at the storage width the model compiled
/// to.
pub struct InferenceSession {
    inner: SessionInner,
}

impl InferenceSession {
    /// Create a session with all inter-layer buffers preallocated to
    /// the model's largest layer, at the model's compiled storage
    /// width.
    pub fn new(model: &CompiledModel, pool: Arc<GemmPool>) -> Self {
        let inner = match model {
            CompiledModel::I8(m) => {
                SessionInner::I8(TypedSession::new(m.clone(), pool))
            }
            CompiledModel::I16(m) => {
                SessionInner::I16(TypedSession::new(m.clone(), pool))
            }
            CompiledModel::I64(m) => {
                SessionInner::I64(TypedSession::new(m.clone(), pool))
            }
        };
        InferenceSession { inner }
    }

    /// The storage element width this session executes on.
    pub fn storage(&self) -> ElemKind {
        match &self.inner {
            SessionInner::I8(_) => ElemKind::I8,
            SessionInner::I16(_) => ElemKind::I16,
            SessionInner::I64(_) => ElemKind::I64,
        }
    }

    /// Flat per-request input length.
    pub fn input_len(&self) -> usize {
        with_width!(SessionInner, &self.inner, s => s.model.input_len)
    }

    /// Flat per-request output length.
    pub fn output_len(&self) -> usize {
        with_width!(SessionInner, &self.inner, s => s.model.output_len)
    }

    /// The deployment's accelerator batch size.
    pub fn batch(&self) -> usize {
        with_width!(SessionInner, &self.inner, s => s.model.cfg.batch)
    }

    pub fn pool(&self) -> &Arc<GemmPool> {
        with_width!(SessionInner, &self.inner, s => &s.pool)
    }

    /// The compiled `max_seq` when request rows carry the ragged
    /// attention wire format; `None` for dense-row models.
    pub fn max_seq(&self) -> Option<usize> {
        with_width!(SessionInner, &self.inner, s => s.model.max_seq())
    }

    /// Execute one batch through every layer.  `input` is `rows` request
    /// rows (1 ≤ rows ≤ the compiled batch) of `input_len` activations;
    /// the result is `rows` rows of `output_len` values.
    pub fn infer_batch(
        &mut self,
        input: TensorView<'_>,
    ) -> Result<Tensor, RequestError> {
        with_width!(SessionInner, &mut self.inner, s => s.infer_batch(input))
    }

    /// Per-layer wall times of the most recent batch (drains them).
    pub fn take_layer_timings(&mut self) -> Vec<LayerTiming> {
        with_width!(SessionInner, &mut self.inner, s => std::mem::take(&mut s.timings))
    }

    /// Fault-tolerance counters accumulated since the last drain
    /// (drains them): ABFT checksum trips, healed recomputes, typed
    /// sheds, watchdog expiries.  All zeros on a fault-free run.
    pub fn take_fault_counts(&mut self) -> FaultCounts {
        with_width!(SessionInner, &mut self.inner, s => std::mem::take(&mut s.faults))
    }

    /// The deployment's per-request deadline knob
    /// ([`DeployConfig::with_request_deadline`]), if configured.
    pub fn request_deadline(&self) -> Option<Duration> {
        with_width!(SessionInner, &self.inner, s => s.model.cfg.request_deadline)
    }
}

/// The coordinator [`Backend`] over an [`InferenceSession`] — how a
/// compiled model plugs into the batcher/worker/stats machinery.
pub struct SessionBackend {
    session: InferenceSession,
}

impl SessionBackend {
    pub fn new(session: InferenceSession) -> Self {
        SessionBackend { session }
    }

    pub fn session(&self) -> &InferenceSession {
        &self.session
    }
}

impl Backend for SessionBackend {
    fn input_len(&self) -> usize {
        self.session.input_len()
    }

    fn output_len(&self) -> usize {
        self.session.output_len()
    }

    fn batch(&self) -> usize {
        self.session.batch()
    }

    fn infer(&mut self, batch: TensorView<'_>) -> anyhow::Result<Tensor> {
        self.session.infer_batch(batch).map_err(anyhow::Error::from)
    }

    fn input_domain_bits(&self) -> Option<u32> {
        // narrow storage constrains the per-value input domain; the
        // coordinator worker then rejects out-of-range values per
        // request (wide storage accepts any i32)
        match self.session.storage() {
            ElemKind::I32 | ElemKind::I64 => None,
            narrow => Some(narrow.bits()),
        }
    }

    fn max_seq(&self) -> Option<usize> {
        self.session.max_seq()
    }

    fn engine_stats(&self) -> Option<PoolStats> {
        Some(self.session.pool().stats())
    }

    fn layer_timings(&mut self) -> Option<Vec<LayerTiming>> {
        Some(self.session.take_layer_timings())
    }

    fn fault_counts(&mut self) -> Option<FaultCounts> {
        Some(self.session.take_fault_counts())
    }

    fn request_deadline(&self) -> Option<Duration> {
        self.session.request_deadline()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::{baseline_matmul, Algo};
    use crate::coordinator::{
        compile, DeployConfig, Model, PostGemm, Storage,
    };
    use crate::nn::models;
    use crate::quant::{requantize_tile, QuantScheme};
    use crate::util::Rng;

    fn session(
        model: &Model,
        cfg: DeployConfig,
        workers: usize,
    ) -> InferenceSession {
        let compiled = compile(model, cfg).unwrap();
        InferenceSession::new(&compiled, Arc::new(GemmPool::new(workers)))
    }

    #[test]
    fn mlp_session_equals_composed_baseline() {
        let model = Model::random(models::mlp(&[12, 10, 6]), 7, 3);
        let cfg = DeployConfig::new(Algo::Ffip).with_tile(4, 3).with_batch(3);
        let mut s = session(&model, cfg, 2);
        // raw accumulator streaming (no post) compiles to wide storage
        assert_eq!(s.storage(), ElemKind::I64);
        let mut rng = Rng::new(8);
        let input: Vec<i32> =
            (0..3 * 12).map(|_| rng.fixed(4, true) as i32).collect();
        let out = s.infer_batch(TensorView::new(3, 12, &input)).unwrap();
        // oracle: compose the exact GEMMs layer by layer
        let mut act =
            Mat::from_fn(3, 12, |i, j| i64::from(input[i * 12 + j]));
        for idx in 0..2 {
            act = baseline_matmul(&act, &model.layer_weights(idx).unwrap().w);
        }
        let got: Vec<i64> = out.data.iter().map(|&v| v as i64).collect();
        assert_eq!(got, act.data);
        assert_eq!(out.shape, [3, 6]);
        // per-layer timings recorded for the batch
        let t = s.take_layer_timings();
        assert_eq!(t.len(), 2);
        assert_eq!(&*t[0].name, "fc1");
    }

    #[test]
    fn post_gemm_requantization_matches_requantize_tile() {
        let mut model = Model::random(models::mlp(&[8, 5]), 9, 4);
        let scheme = QuantScheme::symmetric_signed(8, 0.25);
        let bias: Vec<i64> = (0..5).map(|j| j as i64 * 3 - 6).collect();
        model
            .set_post(0, PostGemm { bias: bias.clone(), scheme, relu: true })
            .unwrap();
        let cfg =
            DeployConfig::new(Algo::Baseline).with_tile(4, 2).with_batch(2);
        let mut s = session(&model, cfg, 0);
        // a fully requantized 8-bit model executes on i8 storage
        assert_eq!(s.storage(), ElemKind::I8);
        let mut rng = Rng::new(10);
        let input: Vec<i32> =
            (0..2 * 8).map(|_| rng.fixed(5, true) as i32).collect();
        let out = s.infer_batch(TensorView::new(2, 8, &input)).unwrap();
        let a = Mat::from_fn(2, 8, |i, j| i64::from(input[i * 8 + j]));
        let acc = baseline_matmul(&a, &model.layer_weights(0).unwrap().w);
        let want = requantize_tile(&acc, &bias, &scheme, true);
        let got: Vec<i64> = out.data.iter().map(|&v| v as i64).collect();
        assert_eq!(got, want.data);
        // the same model forced wide gives the same bits
        let mut wide =
            session(&model, cfg.with_storage(Storage::I64), 0);
        assert_eq!(wide.storage(), ElemKind::I64);
        let out_wide =
            wide.infer_batch(TensorView::new(2, 8, &input)).unwrap();
        assert_eq!(out_wide.data, out.data);
    }

    /// A residual layer over the flat wire adds the spanned-back input
    /// token-wise, saturated at the preceding post-GEMM width — checked
    /// against the composed scalar oracle.
    #[test]
    fn flat_residual_adds_saturated() {
        use crate::nn::{Graph, Layer};
        let g = Graph {
            name: "res".into(),
            layers: vec![
                Layer::Fc { name: "fc".into(), cin: 6, cout: 6 },
                Layer::Residual { name: "res".into(), span: 1 },
            ],
        };
        let mut model = Model::random(g, 21, 4);
        let scheme = QuantScheme::symmetric_signed(8, 0.5);
        let bias = vec![0i64; 6];
        model
            .set_post(
                0,
                PostGemm { bias: bias.clone(), scheme, relu: false },
            )
            .unwrap();
        let cfg = DeployConfig::new(Algo::Ffip).with_tile(2, 3).with_batch(2);
        let mut s = session(&model, cfg, 0);
        assert_eq!(s.storage(), ElemKind::I8);
        let mut rng = Rng::new(22);
        let input: Vec<i32> =
            (0..2 * 6).map(|_| rng.fixed(7, true) as i32).collect();
        let out = s.infer_batch(TensorView::new(2, 6, &input)).unwrap();
        // oracle: requantize(x W) + x, saturated to the 8-bit domain
        let a = Mat::from_fn(2, 6, |i, j| i64::from(input[i * 6 + j]));
        let acc = baseline_matmul(&a, &model.layer_weights(0).unwrap().w);
        let fc = requantize_tile(&acc, &bias, &scheme, false);
        for (idx, &got) in out.data.iter().enumerate() {
            let want = crate::arith::saturate_signed(
                fc.data[idx] + a.data[idx],
                8,
            );
            assert_eq!(got as i64, want, "slot {idx}");
        }
    }

    #[test]
    fn wrong_row_length_is_a_typed_error() {
        let model = Model::random(models::mlp(&[6, 4]), 11, 3);
        let cfg = DeployConfig::new(Algo::Fip).with_tile(2, 2).with_batch(1);
        let mut s = session(&model, cfg, 0);
        let input = vec![0i32; 5];
        let err = s.infer_batch(TensorView::new(1, 5, &input)).unwrap_err();
        assert_eq!(err, RequestError::BadShape { expected: 6, got: 5 });
    }

    #[test]
    fn out_of_domain_input_is_a_typed_error_on_narrow_storage() {
        let mut model = Model::random(models::mlp(&[4, 2]), 12, 4);
        model
            .set_post(
                0,
                PostGemm {
                    bias: vec![0; 2],
                    scheme: QuantScheme::symmetric_signed(8, 1.0),
                    relu: false,
                },
            )
            .unwrap();
        let cfg =
            DeployConfig::new(Algo::Baseline).with_tile(2, 2).with_batch(1);
        let mut s = session(&model, cfg, 0);
        assert_eq!(s.storage(), ElemKind::I8);
        let input = vec![1000i32, 0, 0, 0]; // 1000 does not fit i8
        let err = s.infer_batch(TensorView::new(1, 4, &input)).unwrap_err();
        assert_eq!(err, RequestError::Domain { value: 1000, bits: 8 });
        // in-domain requests still serve
        let ok = s
            .infer_batch(TensorView::new(1, 4, &[1, -2, 3, -4]))
            .unwrap();
        assert_eq!(ok.shape, [1, 2]);
    }
}
