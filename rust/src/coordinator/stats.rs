//! Serving statistics: latency distribution + throughput.

use std::time::Duration;

/// Aggregated over a serving run.
#[derive(Debug, Clone, Default)]
pub struct ServeStats {
    latencies_us: Vec<u64>,
    pub batches: u64,
    pub padded_rows: u64,
    pub started: Option<std::time::Instant>,
    pub finished: Option<std::time::Instant>,
}

impl ServeStats {
    pub fn record_batch(&mut self, batch_len: usize, capacity: usize) {
        self.batches += 1;
        self.padded_rows += (capacity - batch_len) as u64;
    }

    pub fn record_latency(&mut self, d: Duration) {
        self.latencies_us.push(d.as_micros() as u64);
    }

    pub fn count(&self) -> usize {
        self.latencies_us.len()
    }

    /// Latency percentile in microseconds (p in [0, 100]).
    pub fn latency_pct_us(&self, p: f64) -> u64 {
        if self.latencies_us.is_empty() {
            return 0;
        }
        let mut v = self.latencies_us.clone();
        v.sort_unstable();
        let idx = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
        v[idx.min(v.len() - 1)]
    }

    pub fn mean_latency_us(&self) -> f64 {
        if self.latencies_us.is_empty() {
            return 0.0;
        }
        self.latencies_us.iter().sum::<u64>() as f64
            / self.latencies_us.len() as f64
    }

    /// Requests per second over the run's wall clock.
    pub fn throughput_rps(&self) -> f64 {
        match (self.started, self.finished) {
            (Some(a), Some(b)) if b > a => {
                self.count() as f64 / (b - a).as_secs_f64()
            }
            _ => 0.0,
        }
    }

    /// Batch occupancy: served rows / total accelerator rows.
    pub fn occupancy(&self) -> f64 {
        let served = self.count() as f64;
        let total = served + self.padded_rows as f64;
        if total == 0.0 {
            return 0.0;
        }
        served / total
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles() {
        let mut s = ServeStats::default();
        for us in [100u64, 200, 300, 400, 500] {
            s.record_latency(Duration::from_micros(us));
        }
        assert_eq!(s.latency_pct_us(0.0), 100);
        assert_eq!(s.latency_pct_us(50.0), 300);
        assert_eq!(s.latency_pct_us(100.0), 500);
        assert!((s.mean_latency_us() - 300.0).abs() < 1e-9);
    }

    #[test]
    fn occupancy() {
        let mut s = ServeStats::default();
        s.record_batch(3, 4);
        s.record_batch(4, 4);
        for _ in 0..7 {
            s.record_latency(Duration::from_micros(10));
        }
        assert!((s.occupancy() - 7.0 / 8.0).abs() < 1e-9);
    }

    #[test]
    fn empty_safe() {
        let s = ServeStats::default();
        assert_eq!(s.latency_pct_us(99.0), 0);
        assert_eq!(s.throughput_rps(), 0.0);
        assert_eq!(s.occupancy(), 0.0);
    }
}
