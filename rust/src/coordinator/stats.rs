//! Serving statistics: latency distribution, throughput, the GEMM
//! engine's pool/queue occupancy, and the per-layer wall-time breakdown
//! (the paper's §6 layer-wise throughput view, observable live from the
//! server).

use super::session::LayerTiming;
use crate::engine::PoolStats;
use std::time::Duration;

/// Accumulated wall time of one model layer across every served batch.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LayerStats {
    pub name: String,
    /// Batches this layer executed.
    pub batches: u64,
    /// Total wall time across those batches, microseconds.
    pub total_us: u64,
}

impl LayerStats {
    /// Mean wall time per batch, microseconds.
    pub fn mean_us(&self) -> f64 {
        if self.batches == 0 {
            return 0.0;
        }
        self.total_us as f64 / self.batches as f64
    }
}

/// Aggregated over a serving run.
#[derive(Debug, Clone, Default)]
pub struct ServeStats {
    latencies_us: Vec<u64>,
    pub batches: u64,
    pub padded_rows: u64,
    pub started: Option<std::time::Instant>,
    pub finished: Option<std::time::Instant>,
    /// Latest engine counters (None when the backend runs no pool).
    pub engine: Option<PoolStats>,
    /// Per-layer wall-time breakdown (empty when the backend does not
    /// measure layers).
    pub layers: Vec<LayerStats>,
    queue_depth_sum: u64,
    queue_depth_samples: u64,
}

impl ServeStats {
    pub fn record_batch(&mut self, batch_len: usize, capacity: usize) {
        self.batches += 1;
        self.padded_rows += (capacity - batch_len) as u64;
    }

    /// Sample the execution engine after a batch: keeps the latest
    /// cumulative counters and accumulates queue depth for the mean.
    ///
    /// Note the sample is taken *after* this model's own (synchronous)
    /// batch GEMM drained, so with a single deployed model the
    /// instantaneous depth reads 0; use
    /// [`PoolStats::mean_enqueue_backlog`] on the snapshot for the
    /// submit-side contention signal.
    pub fn record_engine(&mut self, s: &PoolStats) {
        self.queue_depth_sum += s.queue_depth as u64;
        self.queue_depth_samples += 1;
        self.engine = Some(*s);
    }

    /// Mean engine queue depth observed at batch boundaries (0.0 when
    /// no engine was sampled).
    pub fn mean_engine_queue_depth(&self) -> f64 {
        if self.queue_depth_samples == 0 {
            return 0.0;
        }
        self.queue_depth_sum as f64 / self.queue_depth_samples as f64
    }

    /// Fold one batch's per-layer wall times into the running breakdown.
    /// The layer list is rebuilt if its shape changes (e.g. a backend
    /// swap); normal serving accumulates in place.
    pub fn record_layer_timings(&mut self, timings: &[LayerTiming]) {
        let aligned = self.layers.len() == timings.len()
            && self
                .layers
                .iter()
                .zip(timings)
                .all(|(s, t)| s.name == *t.name);
        if !aligned {
            self.layers = timings
                .iter()
                .map(|t| LayerStats {
                    name: t.name.to_string(),
                    batches: 0,
                    total_us: 0,
                })
                .collect();
        }
        for (s, t) in self.layers.iter_mut().zip(timings) {
            s.batches += 1;
            s.total_us += t.micros;
        }
    }

    /// Share of total measured layer time spent in layer `idx` (0.0
    /// when nothing is measured).
    pub fn layer_share(&self, idx: usize) -> f64 {
        let total: u64 = self.layers.iter().map(|l| l.total_us).sum();
        match self.layers.get(idx) {
            Some(l) if total > 0 => l.total_us as f64 / total as f64,
            _ => 0.0,
        }
    }

    pub fn record_latency(&mut self, d: Duration) {
        self.latencies_us.push(d.as_micros() as u64);
    }

    pub fn count(&self) -> usize {
        self.latencies_us.len()
    }

    /// Latency percentile in microseconds (p in [0, 100]).
    pub fn latency_pct_us(&self, p: f64) -> u64 {
        if self.latencies_us.is_empty() {
            return 0;
        }
        let mut v = self.latencies_us.clone();
        v.sort_unstable();
        let idx = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
        v[idx.min(v.len() - 1)]
    }

    pub fn mean_latency_us(&self) -> f64 {
        if self.latencies_us.is_empty() {
            return 0.0;
        }
        self.latencies_us.iter().sum::<u64>() as f64
            / self.latencies_us.len() as f64
    }

    /// Requests per second over the run's wall clock.
    pub fn throughput_rps(&self) -> f64 {
        match (self.started, self.finished) {
            (Some(a), Some(b)) if b > a => {
                self.count() as f64 / (b - a).as_secs_f64()
            }
            _ => 0.0,
        }
    }

    /// Batch occupancy: served rows / total accelerator rows.
    pub fn occupancy(&self) -> f64 {
        let served = self.count() as f64;
        let total = served + self.padded_rows as f64;
        if total == 0.0 {
            return 0.0;
        }
        served / total
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles() {
        let mut s = ServeStats::default();
        for us in [100u64, 200, 300, 400, 500] {
            s.record_latency(Duration::from_micros(us));
        }
        assert_eq!(s.latency_pct_us(0.0), 100);
        assert_eq!(s.latency_pct_us(50.0), 300);
        assert_eq!(s.latency_pct_us(100.0), 500);
        assert!((s.mean_latency_us() - 300.0).abs() < 1e-9);
    }

    #[test]
    fn occupancy() {
        let mut s = ServeStats::default();
        s.record_batch(3, 4);
        s.record_batch(4, 4);
        for _ in 0..7 {
            s.record_latency(Duration::from_micros(10));
        }
        assert!((s.occupancy() - 7.0 / 8.0).abs() < 1e-9);
    }

    #[test]
    fn empty_safe() {
        let s = ServeStats::default();
        assert_eq!(s.latency_pct_us(99.0), 0);
        assert_eq!(s.throughput_rps(), 0.0);
        assert_eq!(s.occupancy(), 0.0);
        assert!(s.engine.is_none());
        assert_eq!(s.mean_engine_queue_depth(), 0.0);
        assert!(s.layers.is_empty());
        assert_eq!(s.layer_share(0), 0.0);
    }

    #[test]
    fn layer_timings_accumulate_per_layer() {
        use std::sync::Arc;
        let mut s = ServeStats::default();
        let t = |name: &str, us: u64| LayerTiming {
            name: Arc::from(name),
            micros: us,
        };
        s.record_layer_timings(&[t("fc1", 100), t("fc2", 300)]);
        s.record_layer_timings(&[t("fc1", 200), t("fc2", 400)]);
        assert_eq!(s.layers.len(), 2);
        assert_eq!(s.layers[0].name, "fc1");
        assert_eq!(s.layers[0].batches, 2);
        assert_eq!(s.layers[0].total_us, 300);
        assert!((s.layers[0].mean_us() - 150.0).abs() < 1e-9);
        assert!((s.layer_share(1) - 0.7).abs() < 1e-9);
        // a shape change rebuilds the breakdown
        s.record_layer_timings(&[t("conv1", 50)]);
        assert_eq!(s.layers.len(), 1);
        assert_eq!(s.layers[0].batches, 1);
    }

    #[test]
    fn engine_samples_keep_latest_and_average_depth() {
        let mut s = ServeStats::default();
        s.record_engine(&PoolStats {
            workers: 4,
            jobs: 1,
            items: 16,
            queue_depth: 2,
            peak_queue_depth: 2,
            ..Default::default()
        });
        s.record_engine(&PoolStats {
            workers: 4,
            jobs: 5,
            items: 80,
            queue_depth: 0,
            peak_queue_depth: 3,
            ..Default::default()
        });
        let e = s.engine.unwrap();
        assert_eq!(e.jobs, 5);
        assert_eq!(e.peak_queue_depth, 3);
        assert!((s.mean_engine_queue_depth() - 1.0).abs() < 1e-9);
    }
}
