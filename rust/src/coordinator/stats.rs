//! Serving statistics: latency distribution, throughput, the GEMM
//! engine's pool/queue occupancy, the per-layer wall-time breakdown
//! (the paper's §6 layer-wise throughput view, observable live from the
//! server), and — for replica-sharded deployments — the per-replica
//! breakdown plus the admission controller's shed counter.
//!
//! Each replica worker records into its own private [`ServeStats`];
//! the coordinator merges them on demand with [`ServeStats::merge_from`]
//! (layer stats align by name, so replicas whose batch counts differ —
//! work stealing makes that the normal case — still sum correctly).

use super::session::LayerTiming;
use crate::engine::PoolStats;
use std::time::Duration;

/// One replica's share of a merged [`ServeStats`] snapshot.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ReplicaStats {
    /// Requests this replica answered with an output row (matches
    /// [`ServeStats::count`]; typed error responses are answered but
    /// not counted here, same as the single-worker historical stats).
    pub requests: usize,
    /// Batches this replica executed.
    pub batches: u64,
    /// Wall time this replica spent executing batches, microseconds.
    pub busy_us: u64,
}

/// Fault-tolerance counters for one serving run: what the ABFT
/// checksums ([`crate::engine::AbftCheck`]), the pool watchdog, and the
/// replica scheduler's panic containment observed.  Recorded per
/// replica (drained from the backend after every batch via
/// [`Backend::fault_counts`](super::Backend::fault_counts)) and summed
/// into merged [`ServeStats`] snapshots; surfaced to scrapes as
/// [`FaultMetrics`](crate::metrics::FaultMetrics).  All zeros on a
/// fault-free run — the checksums have no false positives.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultCounts {
    /// Output rows whose ABFT checksum tripped (each one a detected
    /// corruption of a served GEMM).
    pub detected: u64,
    /// GEMMs healed back to bit-exact by scalar-oracle recomputes
    /// (transient faults that never reached a response).
    pub recovered: u64,
    /// Work items recomputed through the scalar oracle while healing.
    pub recomputes: u64,
    /// Requests shed as
    /// [`RequestError::FaultDetected`](super::RequestError) — persistent
    /// faults the oracle could not out-run, plus poisoned (panicked)
    /// GEMM jobs on the serving path.
    pub fault_shed: u64,
    /// Pool-watchdog expiries observed on the serving path
    /// ([`GemmError::Timeout`](crate::engine::GemmError)).
    pub watchdog_trips: u64,
    /// Batches shed as
    /// [`RequestError::DeadlineExceeded`](super::RequestError) — stale
    /// work dropped by the replica or decode scheduler.
    pub deadline_shed: u64,
    /// Backend panics caught and contained by the replica scheduler.
    pub backend_panics: u64,
}

impl FaultCounts {
    /// Sum another run's counters into this one.
    pub fn merge_from(&mut self, other: &FaultCounts) {
        self.detected += other.detected;
        self.recovered += other.recovered;
        self.recomputes += other.recomputes;
        self.fault_shed += other.fault_shed;
        self.watchdog_trips += other.watchdog_trips;
        self.deadline_shed += other.deadline_shed;
        self.backend_panics += other.backend_panics;
    }

    /// Did this run observe any fault at all?
    pub fn any(&self) -> bool {
        *self != FaultCounts::default()
    }
}

/// Accumulated wall time of one model layer across every served batch.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LayerStats {
    pub name: String,
    /// Batches this layer executed.
    pub batches: u64,
    /// Total wall time across those batches, microseconds.
    pub total_us: u64,
}

impl LayerStats {
    /// Mean wall time per batch, microseconds.
    pub fn mean_us(&self) -> f64 {
        if self.batches == 0 {
            return 0.0;
        }
        self.total_us as f64 / self.batches as f64
    }
}

/// Aggregated over a serving run.
#[derive(Debug, Clone, Default)]
pub struct ServeStats {
    latencies_us: Vec<u64>,
    pub batches: u64,
    pub padded_rows: u64,
    pub started: Option<std::time::Instant>,
    pub finished: Option<std::time::Instant>,
    /// Latest engine counters (None when the backend runs no pool).
    pub engine: Option<PoolStats>,
    /// Per-layer wall-time breakdown (empty when the backend does not
    /// measure layers).
    pub layers: Vec<LayerStats>,
    /// Requests shed by the admission controller
    /// ([`RequestError::Overloaded`](super::RequestError::Overloaded));
    /// set on merged snapshots, 0 on per-replica stats.
    pub shed: u64,
    /// Wall time spent executing batches, microseconds (the replica's
    /// busy clock; merged snapshots sum every replica's).
    pub busy_us: u64,
    /// Per-replica breakdown; populated only on merged snapshots of a
    /// replica-sharded deployment (index = replica id).
    pub replicas: Vec<ReplicaStats>,
    /// Fault-tolerance counters: ABFT trips/heals, watchdog expiries,
    /// deadline sheds and contained backend panics (all zero on a
    /// fault-free run).
    pub faults: FaultCounts,
    queue_depth_sum: u64,
    queue_depth_samples: u64,
}

impl ServeStats {
    pub fn record_batch(&mut self, batch_len: usize, capacity: usize) {
        self.batches += 1;
        self.padded_rows += (capacity - batch_len) as u64;
    }

    /// Sample the execution engine after a batch: keeps the latest
    /// cumulative counters and accumulates queue depth for the mean.
    ///
    /// Note the sample is taken *after* this model's own (synchronous)
    /// batch GEMM drained, so with a single deployed model the
    /// instantaneous depth reads 0; use
    /// [`PoolStats::mean_enqueue_backlog`] on the snapshot for the
    /// submit-side contention signal.
    pub fn record_engine(&mut self, s: &PoolStats) {
        self.queue_depth_sum += s.queue_depth as u64;
        self.queue_depth_samples += 1;
        self.engine = Some(*s);
    }

    /// Mean engine queue depth observed at batch boundaries (0.0 when
    /// no engine was sampled).
    pub fn mean_engine_queue_depth(&self) -> f64 {
        if self.queue_depth_samples == 0 {
            return 0.0;
        }
        self.queue_depth_sum as f64 / self.queue_depth_samples as f64
    }

    /// Fold one batch's per-layer wall times into the running breakdown.
    /// The layer list is rebuilt if its shape changes (e.g. a backend
    /// swap); normal serving accumulates in place.
    pub fn record_layer_timings(&mut self, timings: &[LayerTiming]) {
        let aligned = self.layers.len() == timings.len()
            && self
                .layers
                .iter()
                .zip(timings)
                .all(|(s, t)| s.name == *t.name);
        if !aligned {
            self.layers = timings
                .iter()
                .map(|t| LayerStats {
                    name: t.name.to_string(),
                    batches: 0,
                    total_us: 0,
                })
                .collect();
        }
        for (s, t) in self.layers.iter_mut().zip(timings) {
            s.batches += 1;
            s.total_us += t.micros;
        }
    }

    /// Share of total measured layer time spent in layer `idx` (0.0
    /// when nothing is measured).
    pub fn layer_share(&self, idx: usize) -> f64 {
        let total: u64 = self.layers.iter().map(|l| l.total_us).sum();
        match self.layers.get(idx) {
            Some(l) if total > 0 => l.total_us as f64 / total as f64,
            _ => 0.0,
        }
    }

    /// Add one batch's execution wall time to the busy clock.
    pub fn record_busy(&mut self, d: Duration) {
        self.busy_us += d.as_micros() as u64;
    }

    /// Fold another run's counters into this one — how a
    /// replica-sharded deployment's final stats are assembled at
    /// undeploy (and on every live snapshot).  Layer stats align **by
    /// name**, so replicas whose batch counts differ merge correctly;
    /// latencies concatenate (percentiles stay exact); the engine
    /// snapshot keeps the most recent one (highest lifetime job count —
    /// replicas share one pool, so counters are cumulative).
    pub fn merge_from(&mut self, other: &ServeStats) {
        self.latencies_us.extend_from_slice(&other.latencies_us);
        self.batches += other.batches;
        self.padded_rows += other.padded_rows;
        self.busy_us += other.busy_us;
        self.shed += other.shed;
        self.faults.merge_from(&other.faults);
        self.queue_depth_sum += other.queue_depth_sum;
        self.queue_depth_samples += other.queue_depth_samples;
        self.started = match (self.started, other.started) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        };
        self.finished = match (self.finished, other.finished) {
            (Some(a), Some(b)) => Some(a.max(b)),
            (a, b) => a.or(b),
        };
        self.engine = match (self.engine, other.engine) {
            (Some(s), Some(o)) => Some(if o.jobs >= s.jobs { o } else { s }),
            (s, o) => o.or(s),
        };
        for t in &other.layers {
            match self.layers.iter_mut().find(|l| l.name == t.name) {
                Some(l) => {
                    l.batches += t.batches;
                    l.total_us += t.total_us;
                }
                None => self.layers.push(t.clone()),
            }
        }
    }

    pub fn record_latency(&mut self, d: Duration) {
        self.latencies_us.push(d.as_micros() as u64);
    }

    pub fn count(&self) -> usize {
        self.latencies_us.len()
    }

    /// Latency percentile in microseconds (p in [0, 100]).
    pub fn latency_pct_us(&self, p: f64) -> u64 {
        if self.latencies_us.is_empty() {
            return 0;
        }
        let mut v = self.latencies_us.clone();
        v.sort_unstable();
        let idx = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
        v[idx.min(v.len() - 1)]
    }

    pub fn mean_latency_us(&self) -> f64 {
        if self.latencies_us.is_empty() {
            return 0.0;
        }
        self.latencies_us.iter().sum::<u64>() as f64
            / self.latencies_us.len() as f64
    }

    /// Requests per second over the run's wall clock.
    pub fn throughput_rps(&self) -> f64 {
        match (self.started, self.finished) {
            (Some(a), Some(b)) if b > a => {
                self.count() as f64 / (b - a).as_secs_f64()
            }
            _ => 0.0,
        }
    }

    /// Batch occupancy: served rows / total accelerator rows.
    pub fn occupancy(&self) -> f64 {
        let served = self.count() as f64;
        let total = served + self.padded_rows as f64;
        if total == 0.0 {
            return 0.0;
        }
        served / total
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles() {
        let mut s = ServeStats::default();
        for us in [100u64, 200, 300, 400, 500] {
            s.record_latency(Duration::from_micros(us));
        }
        assert_eq!(s.latency_pct_us(0.0), 100);
        assert_eq!(s.latency_pct_us(50.0), 300);
        assert_eq!(s.latency_pct_us(100.0), 500);
        assert!((s.mean_latency_us() - 300.0).abs() < 1e-9);
    }

    #[test]
    fn occupancy() {
        let mut s = ServeStats::default();
        s.record_batch(3, 4);
        s.record_batch(4, 4);
        for _ in 0..7 {
            s.record_latency(Duration::from_micros(10));
        }
        assert!((s.occupancy() - 7.0 / 8.0).abs() < 1e-9);
    }

    #[test]
    fn empty_safe() {
        let s = ServeStats::default();
        assert_eq!(s.latency_pct_us(99.0), 0);
        assert_eq!(s.throughput_rps(), 0.0);
        assert_eq!(s.occupancy(), 0.0);
        assert!(s.engine.is_none());
        assert_eq!(s.mean_engine_queue_depth(), 0.0);
        assert!(s.layers.is_empty());
        assert_eq!(s.layer_share(0), 0.0);
    }

    #[test]
    fn layer_timings_accumulate_per_layer() {
        use std::sync::Arc;
        let mut s = ServeStats::default();
        let t = |name: &str, us: u64| LayerTiming {
            name: Arc::from(name),
            micros: us,
        };
        s.record_layer_timings(&[t("fc1", 100), t("fc2", 300)]);
        s.record_layer_timings(&[t("fc1", 200), t("fc2", 400)]);
        assert_eq!(s.layers.len(), 2);
        assert_eq!(s.layers[0].name, "fc1");
        assert_eq!(s.layers[0].batches, 2);
        assert_eq!(s.layers[0].total_us, 300);
        assert!((s.layers[0].mean_us() - 150.0).abs() < 1e-9);
        assert!((s.layer_share(1) - 0.7).abs() < 1e-9);
        // a shape change rebuilds the breakdown
        s.record_layer_timings(&[t("conv1", 50)]);
        assert_eq!(s.layers.len(), 1);
        assert_eq!(s.layers[0].batches, 1);
    }

    /// merge_from sums replicas whose batch counts differ: layer stats
    /// align by name, latencies concatenate, the busier engine snapshot
    /// wins, and the busy/shed counters add up.
    #[test]
    fn merge_aligns_layers_by_name_across_unequal_replicas() {
        use std::sync::Arc;
        let t = |name: &str, us: u64| LayerTiming {
            name: Arc::from(name),
            micros: us,
        };
        // replica 0 served 2 batches, replica 1 only 1 (stolen work)
        let mut r0 = ServeStats::default();
        r0.record_batch(4, 4);
        r0.record_batch(2, 4);
        r0.record_layer_timings(&[t("fc1", 100), t("fc2", 200)]);
        r0.record_layer_timings(&[t("fc1", 300), t("fc2", 400)]);
        r0.record_latency(Duration::from_micros(50));
        r0.record_busy(Duration::from_micros(700));
        r0.record_engine(&PoolStats { jobs: 7, ..Default::default() });
        let mut r1 = ServeStats::default();
        r1.record_batch(4, 4);
        r1.record_layer_timings(&[t("fc1", 10), t("fc2", 20)]);
        r1.record_latency(Duration::from_micros(150));
        r1.record_busy(Duration::from_micros(30));
        r1.record_engine(&PoolStats { jobs: 9, ..Default::default() });
        let mut m = ServeStats::default();
        m.merge_from(&r0);
        m.merge_from(&r1);
        assert_eq!(m.batches, 3);
        assert_eq!(m.count(), 2);
        assert_eq!(m.busy_us, 730);
        assert_eq!(m.layers.len(), 2);
        assert_eq!(m.layers[0].name, "fc1");
        assert_eq!(m.layers[0].batches, 3, "2 + 1 unequal batch counts");
        assert_eq!(m.layers[0].total_us, 410);
        assert_eq!(m.layers[1].total_us, 620);
        assert_eq!(m.engine.unwrap().jobs, 9, "latest pool snapshot wins");
        assert_eq!(m.latency_pct_us(100.0), 150);
        // merging an empty run changes nothing
        let before = m.batches;
        m.merge_from(&ServeStats::default());
        assert_eq!(m.batches, before);
    }

    #[test]
    fn fault_counters_sum_across_replicas() {
        let mut r0 = ServeStats::default();
        r0.faults.detected = 3;
        r0.faults.recovered = 2;
        r0.faults.recomputes = 5;
        r0.faults.backend_panics = 1;
        let mut r1 = ServeStats::default();
        r1.faults.detected = 1;
        r1.faults.watchdog_trips = 2;
        r1.faults.deadline_shed = 4;
        r1.faults.fault_shed = 1;
        let mut m = ServeStats::default();
        assert!(!m.faults.any(), "fault-free runs read all zeros");
        m.merge_from(&r0);
        m.merge_from(&r1);
        assert_eq!(
            m.faults,
            FaultCounts {
                detected: 4,
                recovered: 2,
                recomputes: 5,
                fault_shed: 1,
                watchdog_trips: 2,
                deadline_shed: 4,
                backend_panics: 1,
            }
        );
        assert!(m.faults.any());
    }

    #[test]
    fn engine_samples_keep_latest_and_average_depth() {
        let mut s = ServeStats::default();
        s.record_engine(&PoolStats {
            workers: 4,
            jobs: 1,
            items: 16,
            queue_depth: 2,
            peak_queue_depth: 2,
            ..Default::default()
        });
        s.record_engine(&PoolStats {
            workers: 4,
            jobs: 5,
            items: 80,
            queue_depth: 0,
            peak_queue_depth: 3,
            ..Default::default()
        });
        let e = s.engine.unwrap();
        assert_eq!(e.jobs, 5);
        assert_eq!(e.peak_queue_depth, 3);
        assert!((s.mean_engine_queue_depth() - 1.0).abs() < 1e-9);
    }
}
