//! Typed request/response tensors and the request-level error type.
//!
//! The serving stack moves data across three boundaries — client →
//! batcher (one flat row), batcher → backend (a padded batch), backend →
//! client (one output row per slot) — and each used to be an untyped
//! `&[i32]`/`Vec<f32>` slab whose shape lived in the reader's head.
//! [`TensorView`] (borrowed, what [`Backend::infer`] consumes) and
//! [`Tensor`] (owned, what it produces and what a [`Response`] carries)
//! make the `rows x row_len` geometry explicit and checked.
//!
//! [`RequestError`] is the typed per-request failure delivered *on the
//! response channel*: a malformed request (wrong row length) or a failed
//! backend batch produces an error response instead of panicking the
//! model's worker thread or silently dropping the channel.
//!
//! [`Backend::infer`]: super::Backend::infer
//! [`Response`]: super::Response

/// Borrowed 2-D integer tensor: `rows` request rows of `row_len`
/// quantized activations each (row-major).  The batcher hands one of
/// these per padded batch to [`Backend::infer`].
///
/// [`Backend::infer`]: super::Backend::infer
#[derive(Debug, Clone, Copy)]
pub struct TensorView<'a> {
    /// `[rows, row_len]`.
    pub shape: [usize; 2],
    pub data: &'a [i32],
}

impl<'a> TensorView<'a> {
    /// View `data` as `rows` rows of `row_len`; checks the element count.
    pub fn new(rows: usize, row_len: usize, data: &'a [i32]) -> Self {
        assert_eq!(data.len(), rows * row_len, "tensor element count");
        TensorView { shape: [rows, row_len], data }
    }

    pub fn rows(&self) -> usize {
        self.shape[0]
    }

    pub fn row_len(&self) -> usize {
        self.shape[1]
    }

    /// Row `i` as a slice.
    pub fn row(&self, i: usize) -> &'a [i32] {
        let w = self.shape[1];
        &self.data[i * w..(i + 1) * w]
    }
}

/// Owned 2-D output tensor: one row per batch slot (or a single row for
/// a per-request [`Response`]).
///
/// [`Response`]: super::Response
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    /// `[rows, row_len]`.
    pub shape: [usize; 2],
    pub data: Vec<f32>,
}

impl Tensor {
    /// Own `data` as `rows` rows of `row_len`; checks the element count.
    pub fn new(rows: usize, row_len: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * row_len, "tensor element count");
        Tensor { shape: [rows, row_len], data }
    }

    pub fn rows(&self) -> usize {
        self.shape[0]
    }

    pub fn row_len(&self) -> usize {
        self.shape[1]
    }

    /// Row `i` as a slice.
    pub fn row(&self, i: usize) -> &[f32] {
        let w = self.shape[1];
        &self.data[i * w..(i + 1) * w]
    }
}

/// Typed per-request serving failure, delivered on the response channel
/// so one bad client input can never take down (or starve) the model's
/// worker thread.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RequestError {
    /// The request row length does not match the deployed model's input.
    BadShape { expected: usize, got: usize },
    /// An input value does not fit the deployed model's quantized
    /// storage domain (e.g. 1000 sent to an `i8`-storage model) — the
    /// request is rejected before it stages anything.
    Domain { value: i32, bits: u32 },
    /// The backend failed the whole batch this request was part of.
    Backend(String),
    /// The deployment's admission controller shed this request: the
    /// bounded queue already holds `max_queue_depth` in-flight requests,
    /// and shedding keeps latency bounded instead of letting the queue
    /// (and every queued request's wait) grow without limit.  Clients
    /// should back off and retry.
    Overloaded { max_queue_depth: usize },
}

impl std::fmt::Display for RequestError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RequestError::BadShape { expected, got } => write!(
                f,
                "bad request shape: expected a row of {expected} values, \
                 got {got}"
            ),
            RequestError::Domain { value, bits } => write!(
                f,
                "input value {value} does not fit the model's {bits}-bit \
                 quantized input storage"
            ),
            RequestError::Backend(msg) => {
                write!(f, "backend failed the batch: {msg}")
            }
            RequestError::Overloaded { max_queue_depth } => write!(
                f,
                "server overloaded: {max_queue_depth} requests already in \
                 flight (admission queue full); back off and retry"
            ),
        }
    }
}

impl std::error::Error for RequestError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn view_rows_are_contiguous() {
        let data = [1, 2, 3, 4, 5, 6];
        let v = TensorView::new(2, 3, &data);
        assert_eq!(v.row(0), &[1, 2, 3]);
        assert_eq!(v.row(1), &[4, 5, 6]);
        assert_eq!((v.rows(), v.row_len()), (2, 3));
    }

    #[test]
    fn tensor_round_trip() {
        let t = Tensor::new(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(t.row(1), &[3.0, 4.0]);
    }

    #[test]
    #[should_panic(expected = "tensor element count")]
    fn mismatched_element_count_is_rejected() {
        let _ = Tensor::new(2, 3, vec![0.0; 5]);
    }

    #[test]
    fn request_error_displays_actionably() {
        let e = RequestError::BadShape { expected: 4, got: 7 };
        let msg = e.to_string();
        assert!(msg.contains('4') && msg.contains('7'), "{msg}");
        let b = RequestError::Backend("boom".into());
        assert!(b.to_string().contains("boom"));
        let d = RequestError::Domain { value: 1000, bits: 8 };
        let msg = d.to_string();
        assert!(msg.contains("1000") && msg.contains('8'), "{msg}");
        let o = RequestError::Overloaded { max_queue_depth: 16 };
        let msg = o.to_string();
        assert!(msg.contains("16") && msg.contains("overloaded"), "{msg}");
    }
}
