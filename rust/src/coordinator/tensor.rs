//! Typed request/response tensors and the request-level error type.
//!
//! The serving stack moves data across three boundaries — client →
//! batcher (one flat row), batcher → backend (a padded batch), backend →
//! client (one output row per slot) — and each used to be an untyped
//! `&[i32]`/`Vec<f32>` slab whose shape lived in the reader's head.
//! [`TensorView`] (borrowed, what [`Backend::infer`] consumes) and
//! [`Tensor`] (owned, what it produces and what a [`Response`] carries)
//! make the `rows x row_len` geometry explicit and checked.
//!
//! [`RequestError`] is the typed per-request failure delivered *on the
//! response channel*: a malformed request (wrong row length) or a failed
//! backend batch produces an error response instead of panicking the
//! model's worker thread or silently dropping the channel.
//!
//! [`Backend::infer`]: super::Backend::infer
//! [`Response`]: super::Response

/// Borrowed 2-D integer tensor: `rows` request rows of `row_len`
/// quantized activations each (row-major).  The batcher hands one of
/// these per padded batch to [`Backend::infer`].
///
/// [`Backend::infer`]: super::Backend::infer
#[derive(Debug, Clone, Copy)]
pub struct TensorView<'a> {
    /// `[rows, row_len]`.
    pub shape: [usize; 2],
    pub data: &'a [i32],
}

impl<'a> TensorView<'a> {
    /// View `data` as `rows` rows of `row_len`; checks the element count.
    pub fn new(rows: usize, row_len: usize, data: &'a [i32]) -> Self {
        assert_eq!(data.len(), rows * row_len, "tensor element count");
        TensorView { shape: [rows, row_len], data }
    }

    pub fn rows(&self) -> usize {
        self.shape[0]
    }

    pub fn row_len(&self) -> usize {
        self.shape[1]
    }

    /// Row `i` as a slice.
    pub fn row(&self, i: usize) -> &'a [i32] {
        let w = self.shape[1];
        &self.data[i * w..(i + 1) * w]
    }
}

/// Owned 2-D output tensor: one row per batch slot (or a single row for
/// a per-request [`Response`]).
///
/// [`Response`]: super::Response
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    /// `[rows, row_len]`.
    pub shape: [usize; 2],
    pub data: Vec<f32>,
}

impl Tensor {
    /// Own `data` as `rows` rows of `row_len`; checks the element count.
    pub fn new(rows: usize, row_len: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * row_len, "tensor element count");
        Tensor { shape: [rows, row_len], data }
    }

    pub fn rows(&self) -> usize {
        self.shape[0]
    }

    pub fn row_len(&self) -> usize {
        self.shape[1]
    }

    /// Row `i` as a slice.
    pub fn row(&self, i: usize) -> &[f32] {
        let w = self.shape[1];
        &self.data[i * w..(i + 1) * w]
    }
}

/// Typed per-request serving failure, delivered on the response channel
/// so one bad client input can never take down (or starve) the model's
/// worker thread.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RequestError {
    /// The request row length does not match the deployed model's input.
    BadShape { expected: usize, got: usize },
    /// An input value does not fit the deployed model's quantized
    /// storage domain (e.g. 1000 sent to an `i8`-storage model) — the
    /// request is rejected before it stages anything.
    Domain { value: i32, bits: u32 },
    /// The backend failed the whole batch this request was part of.
    Backend(String),
    /// The deployment's admission controller shed this request: the
    /// bounded queue already holds `max_queue_depth` in-flight requests,
    /// and shedding keeps latency bounded instead of letting the queue
    /// (and every queued request's wait) grow without limit.  Clients
    /// should back off and retry.
    Overloaded { max_queue_depth: usize },
    /// An attention request's ragged sequence-length prefix is invalid:
    /// negative, or more tokens than the compiled `max_seq`.  Swept per
    /// request (like [`RequestError::Domain`]) so one bad length never
    /// fails its co-batched neighbours.
    BadSequence { len: i64, max_seq: usize },
    /// The decode subsystem's KV-byte budget cannot hold another
    /// sequence's K/V strips: admitting would need `needed` more bytes
    /// against a `max_kv_bytes` budget with `in_use` already resident.
    /// Shed (typed, at admission) instead of panicking or queueing
    /// unboundedly; retiring a sequence frees its bytes.
    KvExhausted { needed: usize, in_use: usize, max_kv_bytes: usize },
    /// The ABFT checksum verification (`engine::abft`) found a GEMM
    /// result that disagrees with its checksum invariant *and* the
    /// scalar-oracle recompute reproduced the disagreement — a
    /// persistent fault in this request's datapath.  Transient faults
    /// heal silently (the recompute wins and is re-verified); only
    /// persistent disagreement sheds, and only this request.
    FaultDetected { layer: String },
    /// The request sat queued longer than the deployment's
    /// [`DeployConfig::with_request_deadline`](super::DeployConfig::with_request_deadline)
    /// allows, so it was shed before wasting backend work on an answer
    /// the client has likely given up on.  Admission slots are
    /// released; nothing was mutated.
    DeadlineExceeded { waited_ms: u64, deadline_ms: u64 },
}

impl std::fmt::Display for RequestError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RequestError::BadShape { expected, got } => write!(
                f,
                "bad request shape: expected a row of {expected} values, \
                 got {got}"
            ),
            RequestError::Domain { value, bits } => write!(
                f,
                "input value {value} does not fit the model's {bits}-bit \
                 quantized input storage"
            ),
            RequestError::Backend(msg) => {
                write!(f, "backend failed the batch: {msg}")
            }
            RequestError::Overloaded { max_queue_depth } => write!(
                f,
                "server overloaded: {max_queue_depth} requests already in \
                 flight (admission queue full); back off and retry"
            ),
            RequestError::BadSequence { len, max_seq } => write!(
                f,
                "bad sequence length {len}: attention requests carry 0 to \
                 {max_seq} tokens"
            ),
            RequestError::KvExhausted { needed, in_use, max_kv_bytes } => {
                write!(
                    f,
                    "KV cache exhausted: admitting this sequence needs \
                     {needed} bytes but {in_use} of {max_kv_bytes} are \
                     already resident; retire a sequence (or raise \
                     max_kv_bytes) and retry"
                )
            }
            RequestError::FaultDetected { layer } => write!(
                f,
                "persistent arithmetic fault detected at layer {layer:?}: \
                 the ABFT checksum disagreed and the scalar recompute \
                 reproduced the disagreement; retry on another replica"
            ),
            RequestError::DeadlineExceeded { waited_ms, deadline_ms } => {
                write!(
                    f,
                    "request deadline exceeded: waited {waited_ms} ms \
                     against a {deadline_ms} ms deadline; the request was \
                     shed before execution"
                )
            }
        }
    }
}

impl std::error::Error for RequestError {}

/// Pack a ragged token sequence into one attention request row:
/// `[len, tokens (row-major, seq x d_model), zero pad]` of fixed length
/// `1 + max_seq * d_model` — the wire format of
/// [`Layer::Attention`](crate::nn::Layer::Attention) serving rows.
/// `tokens.len()` must be a multiple of `d_model` with at most
/// `max_seq` rows.
pub fn pack_ragged_row(
    tokens: &[i32],
    d_model: usize,
    max_seq: usize,
) -> Vec<i32> {
    assert!(d_model >= 1, "d_model must be >= 1");
    assert_eq!(
        tokens.len() % d_model,
        0,
        "token buffer must be whole d_model rows"
    );
    let len = tokens.len() / d_model;
    assert!(len <= max_seq, "sequence length {len} exceeds max_seq {max_seq}");
    let mut row = vec![0i32; 1 + max_seq * d_model];
    row[0] = len as i32;
    row[1..1 + tokens.len()].copy_from_slice(tokens);
    row
}

/// Inverse of [`pack_ragged_row`] for an output row: the valid
/// `len x d_model` token values, dropping the prefix and the pad.
pub fn unpack_ragged_row(row: &[f32], d_model: usize) -> Vec<f32> {
    assert!(!row.is_empty(), "attention rows carry a length prefix");
    let len = row[0] as usize;
    assert!(1 + len * d_model <= row.len(), "length prefix out of range");
    row[1..1 + len * d_model].to_vec()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn view_rows_are_contiguous() {
        let data = [1, 2, 3, 4, 5, 6];
        let v = TensorView::new(2, 3, &data);
        assert_eq!(v.row(0), &[1, 2, 3]);
        assert_eq!(v.row(1), &[4, 5, 6]);
        assert_eq!((v.rows(), v.row_len()), (2, 3));
    }

    #[test]
    fn tensor_round_trip() {
        let t = Tensor::new(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(t.row(1), &[3.0, 4.0]);
    }

    #[test]
    #[should_panic(expected = "tensor element count")]
    fn mismatched_element_count_is_rejected() {
        let _ = Tensor::new(2, 3, vec![0.0; 5]);
    }

    #[test]
    fn request_error_displays_actionably() {
        let e = RequestError::BadShape { expected: 4, got: 7 };
        let msg = e.to_string();
        assert!(msg.contains('4') && msg.contains('7'), "{msg}");
        let b = RequestError::Backend("boom".into());
        assert!(b.to_string().contains("boom"));
        let d = RequestError::Domain { value: 1000, bits: 8 };
        let msg = d.to_string();
        assert!(msg.contains("1000") && msg.contains('8'), "{msg}");
        let o = RequestError::Overloaded { max_queue_depth: 16 };
        let msg = o.to_string();
        assert!(msg.contains("16") && msg.contains("overloaded"), "{msg}");
        let s = RequestError::BadSequence { len: 9, max_seq: 8 };
        let msg = s.to_string();
        assert!(msg.contains('9') && msg.contains('8'), "{msg}");
        let k = RequestError::KvExhausted {
            needed: 512,
            in_use: 768,
            max_kv_bytes: 1024,
        };
        let msg = k.to_string();
        assert!(
            msg.contains("512") && msg.contains("768") && msg.contains("1024"),
            "{msg}"
        );
        let fd = RequestError::FaultDetected { layer: "fc1".into() };
        let msg = fd.to_string();
        assert!(msg.contains("fc1") && msg.contains("fault"), "{msg}");
        let dl = RequestError::DeadlineExceeded {
            waited_ms: 250,
            deadline_ms: 100,
        };
        let msg = dl.to_string();
        assert!(msg.contains("250") && msg.contains("100"), "{msg}");
    }

    #[test]
    fn ragged_row_pack_unpack_roundtrip() {
        // 2 tokens of d_model 3, padded to max_seq 4
        let row = pack_ragged_row(&[1, 2, 3, 4, 5, 6], 3, 4);
        assert_eq!(row.len(), 1 + 4 * 3);
        assert_eq!(&row[..7], &[2, 1, 2, 3, 4, 5, 6]);
        assert!(row[7..].iter().all(|&v| v == 0), "pad slots are zero");
        // empty sequences are legal (zero-padded batch slots)
        let empty = pack_ragged_row(&[], 3, 4);
        assert_eq!(empty, vec![0; 13]);
        let out: Vec<f32> = row.iter().map(|&v| v as f32).collect();
        assert_eq!(
            unpack_ragged_row(&out, 3),
            vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]
        );
    }

    #[test]
    #[should_panic(expected = "exceeds max_seq")]
    fn overlong_sequences_fail_to_pack() {
        let _ = pack_ragged_row(&[0; 9], 3, 2);
    }
}
