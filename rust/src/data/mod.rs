//! Published prior-work comparison data (paper §6.2.2, Tables 1-3).
//!
//! These are *constants transcribed from the paper* — we cannot re-run
//! the cited bitstreams — printed next to our measured/estimated rows by
//! the table benches.  Metric values (GOPS, GOPS/multiplier,
//! ops/multiplier/cycle) are stored exactly as published rather than
//! recomputed, preserving each work's own counting conventions.

pub mod prior_works;

pub use prior_works::{table1, table2, table3, PriorEntry, PriorWork};
