//! Tables 1-3 prior-work columns, transcribed from the paper.

/// One model row of a prior work's column.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PriorEntry {
    pub model: &'static str,
    pub gops: f64,
    pub gops_per_mult: f64,
    pub ops_per_mult_cycle: f64,
}

/// One prior-work column of a comparison table.
#[derive(Debug, Clone, PartialEq)]
pub struct PriorWork {
    /// venue + citation as the paper headers it, e.g. "TNNLS '22 [27]"
    pub label: &'static str,
    pub fpga: &'static str,
    pub datatype: &'static str,
    pub alms_k: Option<f64>,
    pub registers_k: Option<f64>,
    pub memories: Option<u64>,
    pub dsps: u64,
    /// multipliers under the paper's Eq. 31 normalization
    pub multipliers: u64,
    pub freq_mhz: f64,
    pub entries: Vec<PriorEntry>,
    /// uses Winograd minimal filtering (footnote 5)
    pub winograd: bool,
    /// CPU-FPGA heterogeneous (footnote 6)
    pub heterogeneous: bool,
}

fn e(
    model: &'static str,
    gops: f64,
    gpm: f64,
    opc: f64,
) -> PriorEntry {
    PriorEntry { model, gops, gops_per_mult: gpm, ops_per_mult_cycle: opc }
}

/// Table 1: 8-bit-input accelerators on the Arria 10 family.
pub fn table1() -> Vec<PriorWork> {
    vec![
        PriorWork {
            label: "TNNLS '22 [27]",
            fpga: "Arria 10 GX 1150",
            datatype: "8-bit fixed",
            alms_k: Some(304.0),
            registers_k: Some(889.0),
            memories: Some(2334),
            dsps: 1473,
            multipliers: 1473 * 4, // 6-bit packing: 4 mults/DSP
            freq_mhz: 200.0,
            entries: vec![
                e("ResNet-50", 1519.0, 0.258, 1.289),
                e("VGG16", 1295.0, 0.220, 1.099),
            ],
            winograd: false,
            heterogeneous: false,
        },
        PriorWork {
            label: "TCAD '22 [28]",
            fpga: "Arria 10 GX 1150",
            datatype: "8-bit fixed",
            alms_k: Some(304.0),
            registers_k: Some(890.0),
            memories: Some(2334),
            dsps: 1473,
            multipliers: 1473 * 4,
            freq_mhz: 220.0,
            entries: vec![
                e("Bayes ResNet-18", 1590.0, 0.270, 1.277),
                e("Bayes VGG11", 534.0, 0.091, 0.412),
            ],
            winograd: false,
            heterogeneous: false,
        },
        PriorWork {
            label: "Entropy '22 [29]",
            fpga: "Arria 10 GX 1150",
            datatype: "8-bit fixed",
            alms_k: Some(303.0),
            registers_k: None,
            memories: Some(1953),
            dsps: 1503,
            multipliers: 1503 * 2,
            freq_mhz: 172.0,
            entries: vec![
                e("R-CNN (ResNet-50)", 719.0, 0.239, 1.391),
                e("R-CNN (VGG16)", 865.0, 0.288, 1.673),
            ],
            winograd: false,
            heterogeneous: false,
        },
    ]
}

/// Table 2: 16-bit-input accelerators on the Arria 10 family.
pub fn table2() -> Vec<PriorWork> {
    vec![
        PriorWork {
            label: "TCAD '20 [30]",
            fpga: "Arria 10 GX 1150",
            datatype: "16-bit fixed",
            alms_k: Some(286.0), // 286K/335K/208K per model; first listed
            registers_k: None,
            memories: Some(2356),
            dsps: 1518,
            multipliers: 1518 * 2,
            freq_mhz: 240.0,
            entries: vec![
                e("ResNet-50", 600.0, 0.198, 0.823),
                e("ResNet-152", 697.0, 0.230, 0.957),
                e("VGG16", 968.0, 0.319, 1.329),
            ],
            winograd: false,
            heterogeneous: false,
        },
        PriorWork {
            label: "TVLSI '20 [18]",
            fpga: "Arria 10",
            datatype: "16-bit fixed",
            alms_k: Some(181.0),
            registers_k: None,
            memories: Some(1310),
            dsps: 1344,
            multipliers: 1344 * 2,
            freq_mhz: 250.0,
            entries: vec![
                e("VGG16", 1642.0, 0.611, 2.443),
                e("Modified VGG16", 1788.0, 0.655, 2.661),
            ],
            winograd: true,
            heterogeneous: false,
        },
        PriorWork {
            label: "TCAS-II '22 [31]",
            fpga: "Arria 10 GX 1150",
            datatype: "8/16-bit fixed",
            alms_k: None,
            registers_k: None,
            memories: Some(1565),
            dsps: 1161,
            multipliers: 1161 * 2,
            freq_mhz: 163.0,
            entries: vec![e("CTPN (VGG+BiLSTM)", 1224.0, 0.527, 3.234)],
            winograd: true,
            heterogeneous: true,
        },
        PriorWork {
            label: "TCAS-I '23 [32]",
            fpga: "Arria 10 SoC",
            datatype: "16-bit fixed",
            alms_k: Some(189.0),
            registers_k: None,
            memories: None,
            dsps: 1536,
            multipliers: 1536 * 2,
            freq_mhz: 200.0,
            entries: vec![e("Modified StyleNet", 670.0, 0.218, 1.090)],
            winograd: false,
            heterogeneous: false,
        },
    ]
}

/// Table 3: cross-FPGA comparisons at matched models/bitwidths. Grouped
/// by (model, datatype); each group's prior works precede ours.
pub fn table3() -> Vec<PriorWork> {
    vec![
        PriorWork {
            label: "TVLSI '19 [33]",
            fpga: "XC7VX690T",
            datatype: "16-bit fixed",
            alms_k: Some(468.0), // LUTs for AMD
            registers_k: Some(649.0),
            memories: Some(1465),
            dsps: 1436,
            multipliers: 1436,
            freq_mhz: 200.0,
            entries: vec![e("AlexNet", 434.0, 0.302, 1.511)],
            winograd: true,
            heterogeneous: false,
        },
        PriorWork {
            label: "TCAS-II '21 [34]",
            fpga: "VC709",
            datatype: "8/16-bit fixed",
            alms_k: Some(121.0),
            registers_k: Some(160.0),
            memories: Some(1470),
            dsps: 664,
            multipliers: 664,
            freq_mhz: 200.0,
            entries: vec![e("AlexNet", 220.0, 0.331, 1.657)],
            winograd: false,
            heterogeneous: false,
        },
        PriorWork {
            label: "TNNLS '22 [27]",
            fpga: "Arria 10 GX 1150",
            datatype: "8-bit fixed",
            alms_k: Some(304.0),
            registers_k: Some(889.0),
            memories: Some(2334),
            dsps: 1473,
            multipliers: 1473 * 4,
            freq_mhz: 200.0,
            entries: vec![e("ResNet-50", 1519.0, 0.258, 1.289)],
            winograd: false,
            heterogeneous: false,
        },
        PriorWork {
            label: "TCAS-I '23 [35]",
            fpga: "XCVU9P",
            datatype: "8-bit fixed",
            alms_k: None,
            registers_k: None,
            memories: None,
            dsps: 2048,
            multipliers: 2048,
            freq_mhz: 200.0,
            entries: vec![e("ResNet-50", 287.0, 0.140, 0.701)],
            winograd: false,
            heterogeneous: false,
        },
        PriorWork {
            label: "TCAD '20 [30]",
            fpga: "Arria 10 GX 1150",
            datatype: "16-bit fixed",
            alms_k: Some(286.0),
            registers_k: None,
            memories: Some(2356),
            dsps: 1518,
            multipliers: 1518 * 2,
            freq_mhz: 240.0,
            entries: vec![
                e("ResNet-50", 600.0, 0.198, 0.823),
                e("ResNet-152", 697.0, 0.230, 0.957),
            ],
            winograd: false,
            heterogeneous: false,
        },
        PriorWork {
            label: "TNNLS '22 [36]",
            fpga: "VX980",
            datatype: "8/16-bit fixed",
            alms_k: Some(480.0),
            registers_k: None,
            memories: Some(1457),
            dsps: 3121,
            multipliers: 3121,
            freq_mhz: 100.0,
            entries: vec![e("ResNet-101", 600.0, 0.192, 1.922)],
            winograd: false,
            heterogeneous: false,
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transcription_self_consistency() {
        // GOPS/mult must equal GOPS / multipliers within table rounding
        for t in [table1(), table2(), table3()] {
            for w in &t {
                for en in &w.entries {
                    let calc = en.gops / w.multipliers as f64;
                    // 0.02 tolerance: the paper's own rounding (e.g.
                    // [18] Modified VGG16 prints 0.655 vs 1788/2688)
                    assert!(
                        (calc - en.gops_per_mult).abs() < 0.02,
                        "{} {}: {calc} vs {}",
                        w.label,
                        en.model,
                        en.gops_per_mult
                    );
                }
            }
        }
    }

    #[test]
    fn table_sizes() {
        assert_eq!(table1().len(), 3);
        assert_eq!(table2().len(), 4);
        assert_eq!(table3().len(), 6);
    }

    #[test]
    fn best_prior_op_per_mult_cycle_below_ffip_band() {
        // the paper's headline: FFIP reaches 2.66-3.41 ops/mult/cycle;
        // best non-Winograd prior sits well below
        let best_non_wino = [table1(), table2(), table3()]
            .into_iter()
            .flatten()
            .filter(|w| !w.winograd)
            .flat_map(|w| w.entries.clone())
            .map(|e| e.ops_per_mult_cycle)
            .fold(0.0f64, f64::max);
        assert!(best_non_wino < 2.0, "{best_non_wino}");
    }
}
