//! Algorithm-based fault tolerance (ABFT) for the exact integer GEMMs
//! — Huang–Abraham checksums made *bit-exact*.
//!
//! ## The invariant
//!
//! For `C = A B` over the integers, every output row obeys
//!
//! ```text
//! Σ_j C[i][j]  ==  Σ_k A[i][k] · bsum[k],    bsum[k] = Σ_j B[k][j]
//! ```
//!
//! exactly — not approximately, as in the floating-point ABFT
//! literature, but bit-for-bit, because the whole engine computes in
//! exact fixed-point.  Baseline, FIP and FFIP produce bit-identical
//! products (the repo's core differential property), and the offline
//! FFIP y transform is an exact function of B, so *one* checksum of the
//! stationary B covers every algorithm and every datapath that touches
//! it: a flipped bit in a packed SWAR strip, a corrupted accumulator, a
//! dropped work item, or corrupted offline-y terms all surface as a row
//! whose sum disagrees — with **zero false positives** by construction.
//!
//! ## The protocol
//!
//! [`AbftCheck::build`] runs once per compiled layer (stationary B):
//! it stores the per-N-strip row-sums of B — `strip_bsums[jt][k] =
//! Σ_{j ∈ strip jt} B[k][j]` — and their total `bsum`, both in
//! [`Element::Acc`] width.  The headroom is gated by
//! [`FixedSpec::abft_acc_bits`](crate::arith::FixedSpec::abft_acc_bits)
//! (see [`abft_fits`]): a layer whose checksummed worst case exceeds
//! the accumulator compiles with ABFT disabled rather than risking a
//! checksum overflow where the guarded accumulator itself would still
//! be exact.
//!
//! [`AbftCheck::verify_and_heal`] runs post-drain, after a checked
//! GEMM: it folds a checksum over the staged A rows and compares
//! against the C row sums — `O(M·N + M·K)` work against the GEMM's
//! `O(M·N·K)`.  On a mismatch it localizes the damage with the
//! per-strip checksums (band × strip = exactly one pool work item) and
//! recomputes the affected items through the scalar oracle kernel
//! ([`compute_item_scalar`]), which shares no state with the vectorized
//! production path.  A transient fault therefore **heals silently**
//! (counted, re-verified); only a *persistent* fault — one that
//! corrupts the recompute too, modeled by
//! [`FaultState::fire_on_recompute`] — escalates to [`AbftFault`],
//! which the serving tier sheds as a typed
//! [`RequestError::FaultDetected`](crate::coordinator::RequestError)
//! for that request alone.
//!
//! [`compute_item_scalar`]: super::kernels::compute_item_scalar

use super::faults::FaultState;
use super::kernels::{self, Scratch};
use crate::algo::element::AccElem;
use crate::algo::{Algo, Element, Mat, TileShape};
use crate::arith::FixedSpec;
use crate::util::ceil_div;
use std::sync::Arc;

/// Would ABFT checksums for a `k × n` stationary operand fit `E`'s
/// accumulator?  The gate mirrors the engine's own
/// [`gemm_acc_bits`](crate::arith::FixedSpec::gemm_acc_bits) guard:
/// both sides of the row invariant are bounded by `n ×` the guarded
/// GEMM worst case, so a passing gate means checksum arithmetic can
/// never overflow before the accumulator guard itself would have
/// rejected the job.
pub fn abft_fits<E: Element>(
    spec: &FixedSpec,
    algo: Algo,
    x: usize,
    k: usize,
    n: usize,
) -> bool {
    spec.abft_acc_bits(algo.is_fast(), x, k, n) <= <E::Acc as AccElem>::BITS
}

/// What a verification pass observed (the healed case).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct AbftReport {
    /// Output rows whose checksum tripped (0 on a clean pass).
    pub trips: u64,
    /// Work items recomputed through the scalar oracle to heal them.
    pub recomputes: u64,
}

/// Persistent fault: the checksum disagreed *and* the scalar-oracle
/// recompute reproduced the disagreement.  The serving tier sheds the
/// affected request as
/// [`RequestError::FaultDetected`](crate::coordinator::RequestError).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AbftFault {
    /// Rows still failing verification after the heal attempt.
    pub rows: usize,
    /// Rows that tripped on the first pass.
    pub trips: u64,
    /// Items recomputed during the (failed) heal.
    pub recomputes: u64,
}

impl std::fmt::Display for AbftFault {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "persistent arithmetic fault: {} row checksum(s) still \
             disagree after recomputing {} item(s) through the scalar \
             oracle",
            self.rows, self.recomputes
        )
    }
}

impl std::error::Error for AbftFault {}

/// Precomputed checksums of one stationary B operand (one compiled
/// layer's weights), shared behind an `Arc` by every session serving
/// that layer.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct AbftCheck<E: Element> {
    algo: Algo,
    shape: TileShape,
    k: usize,
    n: usize,
    /// N-strip count (`ceil(n / shape.y)`) — the localization grid.
    nt: usize,
    /// Strip-major `nt × k`: row-sums of B restricted to each N strip.
    strip_bsums: Vec<E::Acc>,
    /// Total row-sums of B (length `k`): `Σ_jt strip_bsums[jt]`.
    bsum: Vec<E::Acc>,
}

impl<E: Element> AbftCheck<E> {
    /// Checksum a stationary operand once (compile time for weights).
    /// The caller is responsible for the [`abft_fits`] headroom gate;
    /// the sums themselves are debug-asserted to fit `E::Acc`.
    pub fn build(b: &Mat<E>, algo: Algo, shape: TileShape) -> Arc<Self> {
        let (k, n) = (b.rows, b.cols);
        let nt = ceil_div(n.max(1), shape.y);
        let mut strip_bsums = vec![<E::Acc>::default(); nt * k];
        let mut bsum = vec![0i64; k];
        for jt in 0..nt {
            let j0 = jt * shape.y;
            let cols = shape.y.min(n - j0);
            for r in 0..k {
                let s: i64 = b.data[r * n + j0..r * n + j0 + cols]
                    .iter()
                    .map(|v| v.to_i64())
                    .sum();
                strip_bsums[jt * k + r] = <E::Acc>::from_i64(s);
                bsum[r] += s;
            }
        }
        Arc::new(AbftCheck {
            algo,
            shape,
            k,
            n,
            nt,
            strip_bsums,
            bsum: bsum.into_iter().map(<E::Acc>::from_i64).collect(),
        })
    }

    /// `Σ_k A[i][k] · w[k]` in wide arithmetic (checksum side of the
    /// invariant; `w` is a total or per-strip B row-sum vector).
    fn row_checksum(&self, arow: &[E], w: &[E::Acc]) -> i128 {
        arow.iter()
            .zip(w)
            .map(|(&av, &bs)| av.to_i64() as i128 * bs.to_i64() as i128)
            .sum()
    }

    /// Post-drain verification and healing for `c = a · b` computed by
    /// any engine path with this check's `algo`/`shape`.  `y` must be
    /// the same offline-y buffer the GEMM ran with (the scalar
    /// recompute replays the exact production configuration).
    ///
    /// Returns the clean/healed [`AbftReport`], or [`AbftFault`] when
    /// the damage survives the scalar-oracle recompute (a persistent
    /// fault — `faults` lets an installed stuck-at plan corrupt the
    /// recompute too, which is how `tests/faults.rs` proves this path).
    pub fn verify_and_heal(
        &self,
        a: &Mat<E>,
        b: &Mat<E>,
        y: Option<&Mat<E::Y>>,
        c: &mut Mat<E::Acc>,
        faults: Option<&FaultState>,
    ) -> Result<AbftReport, AbftFault> {
        let m = a.rows;
        assert_eq!(a.cols, self.k, "A depth vs checksummed B");
        assert_eq!((b.rows, b.cols), (self.k, self.n), "B vs checksums");
        assert_eq!((c.rows, c.cols), (m, self.n), "C vs checksummed GEMM");
        let bad_rows = self.failing_rows(a, c, 0..m);
        if bad_rows.is_empty() {
            return Ok(AbftReport::default());
        }
        let trips = bad_rows.len() as u64;
        let tm = self.shape.tm;

        // Localize: per affected M-band, the per-strip invariant marks
        // exactly the (it, jt) items whose block holds corrupted
        // values; recompute those through the scalar oracle.
        let mut bands: Vec<usize> = bad_rows.iter().map(|&i| i / tm).collect();
        bands.dedup();
        let mut recomputes = 0u64;
        let mut scratch = Scratch::<E>::default();
        for &it in &bands {
            let i0 = it * tm;
            let rows = tm.min(m - i0);
            for jt in 0..self.nt {
                let j0 = jt * self.shape.y;
                let cols = self.shape.y.min(self.n - j0);
                let w = &self.strip_bsums[jt * self.k..(jt + 1) * self.k];
                let dirty = (i0..i0 + rows).any(|i| {
                    let want =
                        self.row_checksum(&a.data[i * self.k..(i + 1) * self.k], w);
                    let got: i128 = c.data
                        [i * self.n + j0..i * self.n + j0 + cols]
                        .iter()
                        .map(|v| v.to_i64() as i128)
                        .sum();
                    want != got
                });
                if !dirty {
                    continue;
                }
                // SAFETY: single-threaded here — the GEMM has drained,
                // we hold `&mut c`, and (it, jt) addresses a valid item
                // of this geometry.
                unsafe {
                    kernels::compute_item_scalar::<E>(
                        &a.data,
                        &b.data,
                        y.map(|ym| ym.data.as_slice()),
                        c.data.as_mut_ptr(),
                        m,
                        self.k,
                        self.n,
                        self.algo,
                        self.shape,
                        it,
                        jt,
                        &mut scratch,
                    );
                }
                recomputes += 1;
                if let Some(f) = faults {
                    if f.fire_on_recompute() {
                        // a stuck-at fault corrupts the oracle pass
                        // too: re-damage the freshly recomputed block
                        // so re-verification must escalate
                        let slot = i0 * self.n + j0;
                        c.data[slot] = <E::Acc>::from_i64(
                            c.data[slot].to_i64() + f.delta(),
                        );
                    }
                }
            }
        }

        let still_bad = self
            .failing_rows(a, c, bad_rows.iter().copied())
            .len();
        if still_bad > 0 {
            return Err(AbftFault { rows: still_bad, trips, recomputes });
        }
        Ok(AbftReport { trips, recomputes })
    }

    /// Rows of `c` (among `rows`) violating the total-checksum
    /// invariant.
    fn failing_rows(
        &self,
        a: &Mat<E>,
        c: &Mat<E::Acc>,
        rows: impl IntoIterator<Item = usize>,
    ) -> Vec<usize> {
        rows.into_iter()
            .filter(|&i| {
                let want = self
                    .row_checksum(&a.data[i * self.k..(i + 1) * self.k], &self.bsum);
                let got: i128 = c.data[i * self.n..(i + 1) * self.n]
                    .iter()
                    .map(|v| v.to_i64() as i128)
                    .sum();
                want != got
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::tiled_matmul;
    use crate::engine::{FaultKind, FaultPlan};
    use crate::util::Rng;

    #[test]
    fn clean_gemms_never_trip_for_any_algorithm() {
        let mut rng = Rng::new(0xAB71);
        let shape = TileShape { x: 4, y: 3, tm: 2 };
        for &(m, k, n) in &[(7usize, 8usize, 9usize), (16, 12, 5)] {
            let a = Mat::from_fn(m, k, |_, _| rng.fixed(8, true) as i8);
            let b = Mat::from_fn(k, n, |_, _| rng.fixed(8, true) as i8);
            for algo in Algo::ALL {
                let check = AbftCheck::build(&b, algo, shape);
                let mut c: Mat<i32> = tiled_matmul(&a, &b, algo, shape);
                let rep = check
                    .verify_and_heal(&a, &b, None, &mut c, None)
                    .expect("clean result must verify");
                assert_eq!(rep, AbftReport::default(), "{algo:?} {m}x{k}x{n}");
            }
        }
    }

    #[test]
    fn transient_corruption_heals_bit_exactly() {
        let mut rng = Rng::new(0xAB72);
        let shape = TileShape { x: 4, y: 3, tm: 2 };
        let a = Mat::from_fn(9, 8, |_, _| rng.fixed(8, true) as i8);
        let b = Mat::from_fn(8, 7, |_, _| rng.fixed(8, true) as i8);
        let y = crate::algo::y_from_b(&b, shape.y);
        let check = AbftCheck::build(&b, Algo::Ffip, shape);
        let gold: Mat<i32> = tiled_matmul(&a, &b, Algo::Ffip, shape);
        let mut c = gold.clone();
        // corrupt three scattered accumulators across distinct items
        c.data[0] ^= 1 << 7;
        c.data[4 * 7 + 5] += 1234;
        c.data[8 * 7 + 2] -= 99;
        let rep = check
            .verify_and_heal(&a, &b, Some(&y), &mut c, None)
            .expect("transient corruption must heal");
        assert_eq!(c, gold, "healed output is bit-identical");
        assert_eq!(rep.trips, 3);
        assert!(rep.recomputes >= 3, "each damaged item recomputed");
        // and the healed result re-verifies clean
        let rep2 = check
            .verify_and_heal(&a, &b, Some(&y), &mut c, None)
            .unwrap();
        assert_eq!(rep2, AbftReport::default());
    }

    #[test]
    fn persistent_faults_escalate_instead_of_healing() {
        let mut rng = Rng::new(0xAB73);
        let shape = TileShape { x: 4, y: 4, tm: 2 };
        let a = Mat::from_fn(6, 8, |_, _| rng.fixed(8, true) as i8);
        let b = Mat::from_fn(8, 8, |_, _| rng.fixed(8, true) as i8);
        let check = AbftCheck::build(&b, Algo::Fip, shape);
        let mut c: Mat<i32> = tiled_matmul(&a, &b, Algo::Fip, shape);
        c.data[3] += 7;
        let st = FaultState::new(
            FaultPlan::new(FaultKind::AccCorrupt).persistent(),
        );
        let fault = check
            .verify_and_heal(&a, &b, None, &mut c, Some(&st))
            .expect_err("stuck-at corruption must escalate");
        assert!(fault.rows >= 1 && fault.trips >= 1);
        assert!(fault.recomputes >= 1, "the heal was attempted");
        assert!(fault.to_string().contains("persistent"), "{fault}");
    }

    #[test]
    fn headroom_gate_tracks_the_accumulator_width() {
        let spec8 = FixedSpec { w: 8, sign_a: true, sign_b: true };
        // i8 serving geometry fits its i32 accumulator with checksums
        assert!(abft_fits::<i8>(&spec8, Algo::Ffip, 16, 512, 512));
        // but a pathologically wide output does not — the layer must
        // compile with ABFT off rather than risk checksum overflow
        assert!(!abft_fits::<i8>(
            &spec8,
            Algo::Baseline,
            16,
            1 << 14,
            1 << 14
        ));
        // the i64 accumulator absorbs the same geometry easily
        assert!(abft_fits::<i16>(
            &FixedSpec { w: 16, sign_a: true, sign_b: true },
            Algo::Ffip,
            16,
            4096,
            4096
        ));
    }
}
