//! Deterministic, seeded fault injection for the GEMM engine — the
//! provability half of the ABFT story (`engine/abft.rs`).
//!
//! A fault-tolerance layer that has never seen a fault is an assertion,
//! not a property.  [`FaultPlan`] describes one injectable fault —
//! which datapath it corrupts ([`FaultKind`]), when it fires (the
//! `after`-th item the pool executes), whether it is transient or
//! persistent (stuck-at), and the seed that picks the corrupted
//! bit/slot — and installs per deployment via
//! [`DeployConfig::with_fault_plan`](crate::coordinator::DeployConfig::with_fault_plan)
//! (or directly on a pool with
//! [`GemmPool::install_fault_plan`](super::GemmPool::install_fault_plan)).
//! Injection is test-only by default: no plan installed means the hot
//! path pays one branch on an `Option` per item.
//!
//! Every fault kind maps to a recovery path that `tests/faults.rs`
//! proves end to end:
//!
//! | kind                | corrupts                    | recovered by |
//! |---------------------|-----------------------------|--------------|
//! | [`FaultKind::StripBitFlip`] | a packed SWAR B/y strip word | ABFT verify → scalar recompute |
//! | [`FaultKind::AccCorrupt`]   | one output accumulator       | ABFT verify → scalar recompute |
//! | [`FaultKind::DropItem`]     | one item never executes      | ABFT verify → scalar recompute |
//! | [`FaultKind::PanicKernel`]  | one item's kernel panics     | typed [`GemmError::Poisoned`](super::GemmError) |
//! | [`FaultKind::StallWorker`]  | the executing worker wedges  | watchdog [`GemmError::Timeout`](super::GemmError) |
//!
//! A `persistent` plan keeps firing — including during the ABFT
//! recompute, modeling a stuck-at hardware fault the oracle cannot
//! out-run — which is what escalates a silent heal into a typed
//! [`RequestError::FaultDetected`](crate::coordinator::RequestError).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Which engine datapath a [`FaultPlan`] corrupts.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Flip one bit of the per-worker packed SWAR B/y strip right
    /// after it is (re)built — the cache-resident stationary operand
    /// every subsequent item of the column strip reads.
    StripBitFlip,
    /// Add a nonzero delta to one accumulator of an item's finished
    /// output tile.
    AccCorrupt,
    /// Skip executing one claimed item entirely, leaving its output
    /// tile stale (the recycled-buffer serving path makes "stale"
    /// mean "the previous batch's values", not zero).
    DropItem,
    /// Panic inside one item's kernel — exercises the poison latch
    /// and the typed error it must become on the serving path.
    PanicKernel,
    /// Wedge the executing worker for [`FaultPlan::stall`] before it
    /// runs the item — exercises the pool watchdog.
    StallWorker,
}

/// One deterministic injectable fault.  `Copy` so it rides inside
/// [`DeployConfig`](crate::coordinator::DeployConfig) without breaking
/// its `Copy` derive.
#[derive(Debug, Clone, Copy)]
pub struct FaultPlan {
    pub kind: FaultKind,
    /// Fire on the `after`-th matching item execution (0-based) since
    /// the plan was installed.
    pub after: u64,
    /// Keep firing on every subsequent matching execution *and* during
    /// ABFT recomputes — a stuck-at fault instead of a transient one.
    /// Persistent corruption is what the verifier escalates to a typed
    /// [`RequestError::FaultDetected`](crate::coordinator::RequestError).
    pub persistent: bool,
    /// Seed choosing the corrupted bit/slot and the corruption delta.
    pub seed: u64,
    /// How long a [`FaultKind::StallWorker`] stays wedged (default
    /// 500 ms — comfortably past any test watchdog, bounded so suites
    /// terminate).
    pub stall: Duration,
}

impl FaultPlan {
    pub fn new(kind: FaultKind) -> Self {
        FaultPlan {
            kind,
            after: 0,
            persistent: false,
            seed: 0x9e37_79b9_7f4a_7c15,
            stall: Duration::from_millis(500),
        }
    }

    /// Fire on the `after`-th matching execution instead of the first.
    pub fn with_after(mut self, after: u64) -> Self {
        self.after = after;
        self
    }

    /// Make the fault stuck-at: it fires on every matching execution
    /// from `after` on, including ABFT recomputes.
    pub fn persistent(mut self) -> Self {
        self.persistent = true;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn with_stall(mut self, stall: Duration) -> Self {
        self.stall = stall;
        self
    }
}

/// Runtime state of an installed [`FaultPlan`]: the match counter and
/// the injected-fault count ([`PoolStats::faults_injected`](super::PoolStats)).
/// Shared by the pool's workers behind an `Arc`.
#[derive(Debug)]
pub struct FaultState {
    plan: FaultPlan,
    /// Matching executions seen so far (the `after` clock).
    count: AtomicU64,
    /// Faults actually fired.
    injected: AtomicU64,
}

impl FaultState {
    pub fn new(plan: FaultPlan) -> Self {
        FaultState {
            plan,
            count: AtomicU64::new(0),
            injected: AtomicU64::new(0),
        }
    }

    pub fn plan(&self) -> FaultPlan {
        self.plan
    }

    /// Faults fired since installation.
    pub fn injected(&self) -> u64 {
        self.injected.load(Ordering::Relaxed)
    }

    /// Should a `kind`-site execution inject right now?  Advances the
    /// match clock only for the plan's own kind, so `after` counts
    /// executions of the targeted datapath.
    pub fn fire(&self, kind: FaultKind) -> bool {
        if self.plan.kind != kind {
            return false;
        }
        let n = self.count.fetch_add(1, Ordering::Relaxed);
        let hit = if self.plan.persistent {
            n >= self.plan.after
        } else {
            n == self.plan.after
        };
        if hit {
            self.injected.fetch_add(1, Ordering::Relaxed);
        }
        hit
    }

    /// Should the ABFT scalar recompute be corrupted too?  Only
    /// persistent (stuck-at) plans survive the oracle; transient ones
    /// heal.  Counted as an injection when it fires.
    pub fn fire_on_recompute(&self) -> bool {
        let stuck = self.plan.persistent
            && matches!(
                self.plan.kind,
                FaultKind::StripBitFlip
                    | FaultKind::AccCorrupt
                    | FaultKind::DropItem
            );
        if stuck {
            self.injected.fetch_add(1, Ordering::Relaxed);
        }
        stuck
    }

    /// Deterministic slot choice in `0..len` (seed-derived).
    pub fn pick(&self, len: usize) -> usize {
        debug_assert!(len > 0);
        (self.plan.seed as usize).wrapping_mul(0x2545_F491) % len.max(1)
    }

    /// Deterministic nonzero corruption delta.
    pub fn delta(&self) -> i64 {
        ((self.plan.seed >> 16) % 251) as i64 + 1
    }

    /// Flip one seed-chosen bit of a packed strip.
    pub fn corrupt_words(&self, words: &mut [u64]) {
        if words.is_empty() {
            return;
        }
        let bit = self.pick(words.len() * 64);
        words[bit / 64] ^= 1u64 << (bit % 64);
    }

    /// Flip a seed-chosen *low-lane* bit of a packed strip's first
    /// word.  Word 0 / lane 0 holds the first packed operand of the
    /// strip's first kept column in every SWAR layout, so — unlike a
    /// uniformly random flip, which can land in zero padding or a
    /// skipped column and change no output bit — this corruption is
    /// guaranteed load-bearing whenever a later item reads the strip.
    pub fn corrupt_strip_word(&self, words: &mut [u64]) {
        if let Some(w) = words.first_mut() {
            *w ^= 1u64 << (self.plan.seed % 8);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transient_plans_fire_exactly_once() {
        let st = FaultState::new(
            FaultPlan::new(FaultKind::AccCorrupt).with_after(2),
        );
        // wrong kind never fires and never advances the clock
        assert!(!st.fire(FaultKind::DropItem));
        let hits: Vec<bool> =
            (0..5).map(|_| st.fire(FaultKind::AccCorrupt)).collect();
        assert_eq!(hits, vec![false, false, true, false, false]);
        assert_eq!(st.injected(), 1);
        // transient faults do not survive the oracle recompute
        assert!(!st.fire_on_recompute());
    }

    #[test]
    fn persistent_plans_keep_firing_and_survive_recompute() {
        let st = FaultState::new(
            FaultPlan::new(FaultKind::DropItem).with_after(1).persistent(),
        );
        let hits: Vec<bool> =
            (0..4).map(|_| st.fire(FaultKind::DropItem)).collect();
        assert_eq!(hits, vec![false, true, true, true]);
        assert!(st.fire_on_recompute(), "stuck-at faults out-run the oracle");
        assert_eq!(st.injected(), 4);
        // a persistent *panic* plan has no recompute site to corrupt
        let p = FaultState::new(
            FaultPlan::new(FaultKind::PanicKernel).persistent(),
        );
        assert!(!p.fire_on_recompute());
    }

    #[test]
    fn corruption_is_deterministic_and_nonzero() {
        let st = FaultState::new(
            FaultPlan::new(FaultKind::StripBitFlip).with_seed(77),
        );
        let mut a = vec![0u64; 8];
        let mut b = vec![0u64; 8];
        st.corrupt_words(&mut a);
        st.corrupt_words(&mut b);
        assert_eq!(a, b, "same seed, same flipped bit");
        assert_eq!(
            a.iter().map(|w| w.count_ones()).sum::<u32>(),
            1,
            "exactly one bit flipped"
        );
        assert!(st.delta() != 0);
        assert!(st.pick(13) < 13);
    }
}
