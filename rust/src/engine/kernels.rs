//! Allocation-free GEMM item kernels for the persistent worker pool.
//!
//! One *work item* is an (M-band × N-tile) block of the output: `tm`
//! consecutive A rows against one `y`-wide column strip of B,
//! accumulated over all K tiles of depth `x` — the same decomposition as
//! [`crate::algo::tiled_matmul`], restructured so that
//!
//! * every buffer the tile loop touches lives in a per-worker
//!   [`Scratch`] that is reused across items and jobs (zero heap
//!   allocation inside the tile loop, unlike the functional path which
//!   allocates tile copies and alpha/beta/y vectors per K tile);
//! * tiles are read straight out of the source matrices with row-slice
//!   copies instead of per-element closure indexing;
//! * the FFIP y transform (Eq. 9) and the FIP/FFIP beta terms (Eq. 4)
//!   are produced in a single pass over the B strip, with no
//!   intermediate y matrix or transpose allocation.
//!
//! ## Dispatch: vector lanes by element width
//!
//! [`compute_item`] picks an implementation per job:
//!
//! * **SWAR** (`simd.rs`, stable Rust, the default) — narrow storage
//!   (`i8`/`i16`) runs u64-packed lane-parallel kernels: 4 × 16-bit or
//!   2 × 32-bit lanes per ALU op, with the B/y strip packed once per
//!   (job, N-strip) into a per-worker cache and reused across M-bands
//!   (the pool claims items column-major to exploit this);
//! * **`portable_simd`** (feature-gated, nightly) — the scalar-
//!   structured path below with its inner loops upgraded to explicit
//!   `std::simd` lanes;
//! * **scalar** — the reference kernels, always used for the wide
//!   oracle widths (`i32`/`i64`) and any uncovered combination.
//!
//! All paths are bit-identical (exact integer sums, property-tested
//! against each other and the functional algorithms at every level).
//!
//! The kernels are generic over the storage [`Element`]: A and B stream
//! in their quantized width (`i8`/`i16` for deployed models, `i64` for
//! the oracle path), an optional offline y buffer streams in
//! [`Element::Y`] (one extra bit, §4.4), and every arithmetic step —
//! pair sums, products, the g recurrence, corrections, cross-tile
//! accumulation — runs in the widened [`Element::Acc`] scratch.  The
//! accumulator cannot overflow in release builds because the pool
//! asserts [`FixedSpec::gemm_acc_bits`][gab] `<= Acc::BITS` for every
//! narrow-element job before any item runs (see `pool.rs`).
//!
//! [gab]: crate::arith::FixedSpec::gemm_acc_bits

use super::simd;
use crate::algo::element::{AccElem, Element};
use crate::algo::{Algo, Mat, TileShape};
use crate::util::ceil_div;
use std::sync::atomic::{AtomicU64, Ordering};

/// Process-unique GEMM job ids for the per-worker packed-strip cache:
/// every job a [`compute_item`] call can belong to gets a distinct tag,
/// so a scratch reused across jobs (and across pools — the helper
/// scratch is thread-local) can recognize "same job, same N strip"
/// without ever aliasing two jobs' strips.  Id 0 is reserved as the
/// cache-empty sentinel.
static NEXT_JOB: AtomicU64 = AtomicU64::new(1);

/// Allocate a fresh job id (see [`NEXT_JOB`]).
pub(crate) fn next_job_id() -> u64 {
    NEXT_JOB.fetch_add(1, Ordering::Relaxed)
}

/// Per-worker reusable buffers for one storage element type.  Sized
/// lazily by `ensure`; `resize` is a no-op when the tile geometry is
/// unchanged, so steady state performs no allocation at all.
pub struct Scratch<E: Element> {
    /// Output accumulator for one item: up to `tm * y`.
    pub(super) acc: Vec<E::Acc>,
    /// Transposed B-derived tile (`y` for FFIP, plain B for FIP),
    /// widened: `y * x` (scalar path).
    bt: Vec<E::Acc>,
    /// Per-tile-column beta terms (Eq. 4): `y` (scalar path).
    beta: Vec<E::Acc>,
    /// FFIP g recurrence state (Eqs. 8a-8c): `x` (scalar path).
    g: Vec<E::Acc>,
    /// Zero-padded, widened A row fragment: `x` (scalar path).
    arow: Vec<E::Acc>,
    // --- packed SWAR state (`simd.rs`; untouched by the scalar path) ---
    /// Packed widened A row fragment: `ceil(x / lanes)` words.
    pub(super) pa: Vec<u64>,
    /// Packed FFIP g state: `ceil(x / lanes)` words.
    pub(super) pg: Vec<u64>,
    /// Baseline per-row lane accumulators: `ceil(y / 2)` words.
    pub(super) pacc: Vec<u64>,
    /// The cache-resident packed B/y strip: every K tile of the current
    /// `(job, jt)` N strip, transposed/packed/differenced once and
    /// reused across all M-bands of the strip.
    pub(super) strip: Vec<u64>,
    /// Per-(K-tile, column) correction sums for the cached strip: beta
    /// terms (Eq. 4) for FIP/FFIP, biased column sums for the baseline.
    pub(super) strip_sums: Vec<E::Acc>,
    /// Per-(K-tile, column) skip flags for the cached FIP/FFIP strip:
    /// nonzero marks an all-zero B tile column whose packed words the
    /// SWAR inner loops skip entirely (its contribution is provably
    /// zero; see `simd.rs`).  Unused by the baseline (biased storage).
    pub(super) strip_skip: Vec<u8>,
    /// Which job the cached strip belongs to (0 = none).
    pub(super) strip_job: u64,
    /// Which N strip of that job is cached.
    pub(super) strip_jt: usize,
    /// Which K band of that strip is resident when the strip is in
    /// *banded* mode (pathological deep-K × wide-y jobs cap the cache
    /// at one K band; see `simd::STRIP_CACHE_MAX_WORDS`).  Meaningless
    /// in full-strip mode.
    pub(super) strip_kt: usize,
    /// Lane-MACs elided by zero-column skipping since the last
    /// [`ScratchSet::take_counters`] drain.
    pub(super) lanes_skipped: u64,
    /// Packed-strip (re)builds since the last drain.
    pub(super) strips_built: u64,
}

impl<E: Element> Default for Scratch<E> {
    fn default() -> Self {
        Scratch {
            acc: Vec::new(),
            bt: Vec::new(),
            beta: Vec::new(),
            g: Vec::new(),
            arow: Vec::new(),
            pa: Vec::new(),
            pg: Vec::new(),
            pacc: Vec::new(),
            strip: Vec::new(),
            strip_sums: Vec::new(),
            strip_skip: Vec::new(),
            strip_job: 0,
            strip_jt: 0,
            strip_kt: 0,
            lanes_skipped: 0,
            strips_built: 0,
        }
    }
}

impl<E: Element> Scratch<E> {
    /// Size only the output accumulator — all that the packed SWAR
    /// path shares with the scalar path (its tiles live in the packed
    /// buffers sized by `simd::ensure_packed`, so a worker that only
    /// serves vector-covered jobs never allocates the scalar tile
    /// buffers).
    pub(super) fn ensure_acc(&mut self, shape: TileShape) {
        self.acc.resize(shape.tm * shape.y, <E::Acc>::default());
    }

    /// Size the scalar-path tile buffers (plus the accumulator).
    pub(super) fn ensure(&mut self, shape: TileShape) {
        let zero = <E::Acc>::default();
        self.ensure_acc(shape);
        self.bt.resize(shape.y * shape.x, zero);
        self.beta.resize(shape.y, zero);
        self.g.resize(shape.x, zero);
        self.arow.resize(shape.x, zero);
    }
}

/// One reusable [`Scratch`] per storage width, so a single pool worker
/// serves jobs of any element type without reallocating between widths
/// (jobs carry an [`ElemKind`](crate::algo::ElemKind) tag; `pool.rs`
/// dispatches to the matching field).
#[derive(Default)]
pub(crate) struct ScratchSet {
    pub(crate) s8: Scratch<i8>,
    pub(crate) s16: Scratch<i16>,
    pub(crate) s32: Scratch<i32>,
    pub(crate) s64: Scratch<i64>,
}

impl ScratchSet {
    /// Drain the sparsity counters accumulated across all widths since
    /// the last call: `(lanes_skipped, strips_built)`.  The pool flushes
    /// these into its shared [`PoolStats`](super::PoolStats) after every
    /// job it helps execute.
    pub(crate) fn take_counters(&mut self) -> (u64, u64) {
        fn drain<E: Element>(s: &mut Scratch<E>) -> (u64, u64) {
            let out = (s.lanes_skipped, s.strips_built);
            s.lanes_skipped = 0;
            s.strips_built = 0;
            out
        }
        let parts = [
            drain(&mut self.s8),
            drain(&mut self.s16),
            drain(&mut self.s32),
            drain(&mut self.s64),
        ];
        parts
            .iter()
            .fold((0, 0), |(l, b), &(pl, pb)| (l + pl, b + pb))
    }
}

/// Compute one (M-band × N-tile) output block of `C = A B` and write it
/// to `c`, dispatching to the vector kernels where they cover the job
/// (module docs) and the scalar reference kernels otherwise.
///
/// `a` and `b` are the full row-major input buffers (`m*k` and `k*n`
/// elements); `(it, jt)` select the M-band (rows `it*tm ..`) and N-tile
/// (columns `jt*y ..`).  For `Algo::Fip`/`Algo::Ffip` the caller must
/// guarantee an even tile depth `shape.x` (asserted at pool submit).
///
/// `y_off` is an optional *precomputed offline* FFIP weight transform —
/// the full `k*n` buffer of `y_from_b(b, shape.y)` in the widened-by-
/// one-bit [`Element::Y`] storage (§3.3: the Θ(NK) y-forming
/// subtractions leave the inference path when weights are stored
/// pre-transformed).  When present (FFIP only) the kernel copies y
/// tiles straight out of it instead of differencing the B strip per
/// K-tile pass; beta terms still come from `b`.
///
/// `job` tags the GEMM this item belongs to ([`next_job_id`]); all
/// items of one GEMM must share the tag, and distinct concurrent GEMMs
/// must not (it keys the scratch's packed-strip cache).
///
/// `faults` is the pool's installed fault-injection state
/// (`engine/faults.rs`), `None` everywhere outside the fault tests; the
/// SWAR path consults it to corrupt a freshly built packed strip.  The
/// scalar kernel never sees it — [`compute_item_scalar`] stays the
/// clean oracle the ABFT verifier recomputes with.
///
/// # Safety
///
/// `c` must be valid for writes across the whole `m * n` output buffer,
/// the buffer must stay alive for the duration of the call, and no other
/// thread may concurrently access the `(it, jt)` region this call
/// writes.  Distinct `(it, jt)` items touch disjoint regions, which is
/// what makes the pool's work-claiming sound.
#[allow(clippy::too_many_arguments)]
pub(crate) unsafe fn compute_item<E: Element>(
    a: &[E],
    b: &[E],
    y_off: Option<&[E::Y]>,
    c: *mut E::Acc,
    m: usize,
    k: usize,
    n: usize,
    algo: Algo,
    shape: TileShape,
    it: usize,
    jt: usize,
    job: u64,
    scratch: &mut Scratch<E>,
    faults: Option<&crate::engine::FaultState>,
) {
    // With `portable_simd` the scalar-structured path upgrades its
    // inner loops to explicit `std::simd` lanes (the simd.rs hooks), so
    // it takes precedence; on stable, the u64 SWAR kernel is the
    // default wherever it covers the job.
    if !cfg!(feature = "portable_simd") && simd::covers::<E>(algo, shape) {
        return simd::compute_item_swar(
            a, b, y_off, c, m, k, n, algo, shape, it, jt, job, scratch,
            faults,
        );
    }
    compute_item_scalar(a, b, y_off, c, m, k, n, algo, shape, it, jt, scratch)
}

/// The scalar reference item kernel — the oracle every vector path is
/// property-tested against, and the production path for the wide
/// (`i32`/`i64`) storage widths.
///
/// # Safety
///
/// Same contract as [`compute_item`].
#[allow(clippy::too_many_arguments)]
pub(crate) unsafe fn compute_item_scalar<E: Element>(
    a: &[E],
    b: &[E],
    y_off: Option<&[E::Y]>,
    c: *mut E::Acc,
    m: usize,
    k: usize,
    n: usize,
    algo: Algo,
    shape: TileShape,
    it: usize,
    jt: usize,
    scratch: &mut Scratch<E>,
) {
    let (x, yw, tm) = (shape.x, shape.y, shape.tm);
    let i0 = it * tm;
    let j0 = jt * yw;
    debug_assert!(i0 < m && j0 < n);
    let rows = tm.min(m - i0);
    let cols = yw.min(n - j0);
    let kt_n = ceil_div(k, x);
    let zero = <E::Acc>::default();
    scratch.ensure(shape);
    let Scratch { acc, bt, beta, g, arow, .. } = scratch;
    let acc = &mut acc[..rows * cols];
    acc.fill(zero);

    for kt in 0..kt_n {
        let k0 = kt * x;
        let kv = x.min(k - k0);
        match algo {
            Algo::Baseline => {
                // Eq. (1), ikj order over the strip: contiguous B and C
                // rows so the MAC row runs on whole lanes.
                for i in 0..rows {
                    let ar = &a[(i0 + i) * k + k0..(i0 + i) * k + k0 + kv];
                    let accrow = &mut acc[i * cols..(i + 1) * cols];
                    for (r, &av) in ar.iter().enumerate() {
                        let av = av.acc();
                        let brow =
                            &b[(k0 + r) * n + j0..(k0 + r) * n + j0 + cols];
                        simd::mac_row::<E>(av, brow, accrow);
                    }
                }
            }
            Algo::Fip => {
                // Transpose the zero-padded B tile once per K tile so
                // each output column's operands are contiguous.
                let btile = &mut bt[..cols * x];
                btile.fill(zero);
                for r in 0..kv {
                    let brow =
                        &b[(k0 + r) * n + j0..(k0 + r) * n + j0 + cols];
                    for (j, &bv) in brow.iter().enumerate() {
                        btile[j * x + r] = bv.acc();
                    }
                }
                let betas = &mut beta[..cols];
                beta_into(b, k0, kv, n, j0, betas);
                for i in 0..rows {
                    let ar = &mut arow[..x];
                    widen_into(
                        &a[(i0 + i) * k + k0..(i0 + i) * k + k0 + kv],
                        ar,
                    );
                    let alpha = simd::pair_product_sum::<E>(ar);
                    let accrow = &mut acc[i * cols..(i + 1) * cols];
                    for (j, cv) in accrow.iter_mut().enumerate() {
                        let btj = &btile[j * x..(j + 1) * x];
                        // Eq. (2): (a_odd + b_even)(a_even + b_odd)
                        let s = simd::fip_col::<E>(ar, btj);
                        *cv += s - alpha - betas[j];
                    }
                }
            }
            Algo::Ffip => {
                // Eq. (9) with tile restart at the strip's first column:
                // emit y directly transposed, no intermediate matrix —
                // or, with an offline-precomputed y buffer, copy its
                // rows (restart geometry matches: y_from_b(b, shape.y)
                // restarts exactly at the j0 = jt*y strip boundaries).
                let ytile = &mut bt[..cols * x];
                ytile.fill(zero);
                for r in 0..kv {
                    match y_off {
                        Some(yb) => {
                            let yrow = &yb
                                [(k0 + r) * n + j0..(k0 + r) * n + j0 + cols];
                            for (j, &yv) in yrow.iter().enumerate() {
                                ytile[j * x + r] = E::y_to_acc(yv);
                            }
                        }
                        None => {
                            let brow = &b
                                [(k0 + r) * n + j0..(k0 + r) * n + j0 + cols];
                            let mut prev = zero;
                            for (j, &bv) in brow.iter().enumerate() {
                                let bv = bv.acc();
                                ytile[j * x + r] = bv - prev;
                                prev = bv;
                            }
                        }
                    }
                }
                let betas = &mut beta[..cols];
                beta_into(b, k0, kv, n, j0, betas);
                for i in 0..rows {
                    let ar = &mut arow[..x];
                    widen_into(
                        &a[(i0 + i) * k + k0..(i0 + i) * k + k0 + kv],
                        ar,
                    );
                    let alpha = simd::pair_product_sum::<E>(ar);
                    // Eqs. (8a)/(8b): seed g with the swapped a pairs.
                    let gs = &mut g[..x];
                    let mut p = 0;
                    while p < x {
                        gs[p] = ar[p + 1];
                        gs[p + 1] = ar[p];
                        p += 2;
                    }
                    let accrow = &mut acc[i * cols..(i + 1) * cols];
                    for (j, cv) in accrow.iter_mut().enumerate() {
                        // Eq. (8c) then Eq. (7)
                        let yrow = &ytile[j * x..(j + 1) * x];
                        let s = simd::ffip_col::<E>(gs, yrow);
                        *cv += s - alpha - betas[j];
                    }
                }
            }
        }
    }

    // SAFETY: forwarded caller contract — rows i0+i < m and columns
    // j0..j0+cols <= n lie within the caller-guaranteed m*n buffer,
    // and regions of distinct items are disjoint.
    unsafe {
        write_block(c, acc, n, i0, j0, rows, cols);
    }
}

/// Copy a finished item block from the scratch accumulator into the
/// output buffer; each item owns a disjoint region.
///
/// # Safety
///
/// `c` must be valid for writes over the whole `m * n` output (rows
/// `i0..i0+rows`, columns `j0..j0+cols` in range) and no other thread
/// may concurrently access this block.
pub(super) unsafe fn write_block<A: AccElem>(
    c: *mut A,
    acc: &[A],
    n: usize,
    i0: usize,
    j0: usize,
    rows: usize,
    cols: usize,
) {
    for i in 0..rows {
        let src = &acc[i * cols..(i + 1) * cols];
        let dst = std::slice::from_raw_parts_mut(c.add((i0 + i) * n + j0), cols);
        dst.copy_from_slice(src);
    }
}

/// Widen `src` into the front of `dst`, zero-filling the tail (the
/// zero-padded A row fragment of an edge K tile).
#[inline(always)]
fn widen_into<E: Element>(src: &[E], dst: &mut [E::Acc]) {
    for (d, &s) in dst.iter_mut().zip(src) {
        *d = s.acc();
    }
    dst[src.len()..].fill(<E::Acc>::default());
}

/// The release-mode accumulator-width guard (§4.4): for the quantized
/// narrow storage types (`i8`/`i16`, [`Element::GUARDED`]), assert that
/// the worst-case magnitude of *every* tile partial and the full
/// cross-tile accumulation fits the widened accumulator.  Wide/oracle
/// storage (`i32`/`i64`) keeps the historical semantics: exact in
/// practice for quantized data, debug-checked arithmetic otherwise.
/// Asserted by the pool at enqueue and by [`item_gemm`] before its
/// serial sweep.
pub(super) fn assert_acc_fits<E: Element>(algo: Algo, x: usize, k: usize) {
    if !E::GUARDED {
        return;
    }
    let spec = crate::arith::FixedSpec::signed(E::BITS);
    let need = spec.gemm_acc_bits(algo.is_fast(), x, k);
    let have = <E::Acc as AccElem>::BITS;
    assert!(
        need <= have,
        "{} GEMM over {} operands needs a {need}-bit accumulator but {} \
         provides {have} bits (2w + clog2 rule, w = {}, x = {x}, K = {k}); \
         compile the model with wider storage",
        algo.name(),
        E::NAME,
        std::any::type_name::<E::Acc>(),
        E::BITS,
    );
}

/// Eq. (4) beta terms for the zero-padded `(k0, kv)` × `(j0, cols)` B
/// tile, written into `betas` (length `cols`).  Rows past `kv` are
/// implicit zeros, so an odd valid depth pairs its last row with zero.
pub(super) fn beta_into<E: Element>(
    b: &[E],
    k0: usize,
    kv: usize,
    n: usize,
    j0: usize,
    betas: &mut [E::Acc],
) {
    betas.fill(<E::Acc>::default());
    let cols = betas.len();
    let mut r = 0;
    while r + 1 < kv {
        let b0 = &b[(k0 + r) * n + j0..(k0 + r) * n + j0 + cols];
        let b1 = &b[(k0 + r + 1) * n + j0..(k0 + r + 1) * n + j0 + cols];
        for ((bj, &v0), &v1) in betas.iter_mut().zip(b0).zip(b1) {
            *bj += v0.acc() * v1.acc();
        }
        r += 2;
    }
}

/// Which item-kernel implementation [`item_gemm`] runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelPath {
    /// The production dispatch: vector lanes (SWAR on stable,
    /// `std::simd` under `portable_simd`) wherever they cover the job,
    /// scalar otherwise.
    Auto,
    /// Force the scalar reference kernels.
    Scalar,
}

/// Drive a whole GEMM through the item kernels *serially* on a single
/// scratch — the raw per-item compute with no pool scheduling around
/// it.  This is the bench H10 surface (vector vs scalar item
/// throughput) and the tests' path-vs-path oracle hook; production
/// traffic goes through [`GemmPool`](super::GemmPool), which claims the
/// same items concurrently.  Items run column-strip-major, so the
/// packed-strip reuse matches what a single pool worker sees.
pub fn item_gemm<E: Element>(
    a: &Mat<E>,
    b: &Mat<E>,
    y: Option<&Mat<E::Y>>,
    algo: Algo,
    shape: TileShape,
    path: KernelPath,
) -> Mat<E::Acc> {
    assert_eq!(a.cols, b.rows, "inner dimensions must match");
    if let Some(ym) = y {
        assert_eq!(
            (ym.rows, ym.cols),
            (b.rows, b.cols),
            "offline y must match B's dimensions"
        );
    }
    // the same preconditions GemmPool::enqueue enforces, so both
    // kernel paths reject a bad job identically instead of one
    // panicking on a raw index and the other silently degrading
    assert!(
        shape.x >= 1 && shape.y >= 1 && shape.tm >= 1,
        "degenerate tile shape {shape:?}"
    );
    if algo.is_fast() {
        assert_eq!(
            shape.x % 2,
            0,
            "{} requires an even tile depth x (pad with a zero row)",
            algo.name()
        );
    }
    assert_acc_fits::<E>(algo, shape.x, a.cols);
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let (mt, _, nt) = shape.tiles(m, k, n);
    let mut c = Mat::zeros(m, n);
    let mut scratch = Scratch::default();
    let job = next_job_id();
    let yd = y.map(|ym| ym.data.as_slice());
    for jt in 0..nt {
        for it in 0..mt {
            // SAFETY: single-threaded — c outlives the call and items
            // write disjoint blocks.
            unsafe {
                match path {
                    KernelPath::Auto => compute_item(
                        &a.data, &b.data, yd, c.data.as_mut_ptr(), m, k,
                        n, algo, shape, it, jt, job, &mut scratch, None,
                    ),
                    KernelPath::Scalar => compute_item_scalar(
                        &a.data, &b.data, yd, c.data.as_mut_ptr(), m, k,
                        n, algo, shape, it, jt, &mut scratch,
                    ),
                }
            }
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::{tiled_matmul, y_from_b, Mat};
    use crate::util::{prop, Rng};

    /// Both kernel paths, against the functional tiled oracle.
    fn check_paths<E: Element>(
        a: &Mat<E>,
        b: &Mat<E>,
        y: Option<&Mat<E::Y>>,
        algo: Algo,
        shape: TileShape,
        ctx: &str,
    ) where
        E::Acc: Element,
    {
        let gold = tiled_matmul(&a.widen(), &b.widen(), algo, shape);
        let scalar = item_gemm(a, b, y, algo, shape, KernelPath::Scalar);
        let auto = item_gemm(a, b, y, algo, shape, KernelPath::Auto);
        assert_eq!(scalar.widen(), gold, "scalar vs oracle: {ctx}");
        assert_eq!(auto, scalar, "vector vs scalar: {ctx}");
    }

    #[test]
    fn items_match_tiled_matmul_all_algos() {
        let mut rng = Rng::new(0xE11);
        for &(m, k, n, x, y, tm) in &[
            (5usize, 8usize, 12usize, 4usize, 5usize, 2usize),
            (16, 16, 16, 8, 8, 8),
            (10, 147, 64, 64, 16, 16), // ResNet conv1 edge tiles
            (1, 2, 1, 2, 1, 1),
            (7, 6, 9, 2, 3, 3),
        ] {
            let a = Mat::from_fn(m, k, |_, _| rng.fixed(8, true));
            let b = Mat::from_fn(k, n, |_, _| rng.fixed(8, true));
            let shape = TileShape { x, y, tm };
            for algo in Algo::ALL {
                let got = item_gemm(&a, &b, None, algo, shape, KernelPath::Auto);
                let want = tiled_matmul(&a, &b, algo, shape);
                assert_eq!(
                    got, want,
                    "{algo:?} m={m} k={k} n={n} x={x} y={y} tm={tm}"
                );
            }
        }
    }

    /// Narrow-element items equal the widened i64 oracle exactly on
    /// both kernel paths, with and without the offline y transform.
    #[test]
    fn narrow_items_match_widened_oracle() {
        let mut rng = Rng::new(0xE14);
        for &(m, k, n, x, yw, tm) in &[
            (5usize, 8usize, 12usize, 4usize, 5usize, 2usize),
            (10, 147, 64, 64, 16, 16),
            (7, 6, 9, 2, 3, 3),
        ] {
            let a8 = Mat::from_fn(m, k, |_, _| rng.fixed(8, true) as i8);
            let b8 = Mat::from_fn(k, n, |_, _| rng.fixed(8, true) as i8);
            let a16 =
                Mat::from_fn(m, k, |_, _| rng.fixed(16, true) as i16);
            let b16 =
                Mat::from_fn(k, n, |_, _| rng.fixed(16, true) as i16);
            let shape = TileShape { x, y: yw, tm };
            for algo in Algo::ALL {
                check_paths(
                    &a8,
                    &b8,
                    None,
                    algo,
                    shape,
                    &format!("i8 {algo:?} m={m} k={k} n={n}"),
                );
                check_paths(
                    &a16,
                    &b16,
                    None,
                    algo,
                    shape,
                    &format!("i16 {algo:?} m={m} k={k} n={n}"),
                );
            }
            // offline y (i16 storage for i8 operands — the §4.4 extra bit)
            let y8 = y_from_b(&b8, yw);
            check_paths(
                &a8,
                &b8,
                Some(&y8),
                Algo::Ffip,
                shape,
                &format!("i8 offline-y m={m} k={k} n={n}"),
            );
        }
    }

    /// The SWAR/SIMD kernels are bit-exact against the scalar kernels
    /// for all three algorithms × both narrow widths, with geometry
    /// biased hard toward the edge cases: odd `cols`, ragged `kv < x`
    /// K tiles, short `rows < tm` M bands, tiny and lane-misaligned
    /// tile depths, and full-scale operand values.
    #[test]
    fn vector_matches_scalar_on_edge_geometry() {
        prop::check("swar == scalar (edge tiles)", 48, 12, |c| {
            let m = c.rng.range(1, c.size + 2);
            let k = c.rng.range(1, 4 * c.size + 2);
            // odd-biased n so the last N tile and the baseline column
            // pairing both go ragged
            let n = 2 * c.rng.range(0, c.size + 1) + 1;
            let x = 2 * c.rng.range(1, 8); // even, often > kv at the edge
            let yw = c.rng.range(1, 9);
            let tm = c.rng.range(1, 6);
            let shape = TileShape { x, y: yw, tm };
            let full = c.rng.range(0, 2) == 0; // full-scale half the time
            let a8 = Mat::from_fn(m, k, |_, _| {
                if full {
                    [-128i8, 127][c.rng.range(0, 2)]
                } else {
                    c.rng.fixed(8, true) as i8
                }
            });
            let b8 = Mat::from_fn(k, n, |_, _| {
                if full {
                    [-128i8, 127][c.rng.range(0, 2)]
                } else {
                    c.rng.fixed(8, true) as i8
                }
            });
            let a16 = Mat::from_fn(m, k, |_, _| {
                if full {
                    [i16::MIN, i16::MAX][c.rng.range(0, 2)]
                } else {
                    c.rng.fixed(16, true) as i16
                }
            });
            let b16 = Mat::from_fn(k, n, |_, _| {
                if full {
                    [i16::MIN, i16::MAX][c.rng.range(0, 2)]
                } else {
                    c.rng.fixed(16, true) as i16
                }
            });
            for algo in Algo::ALL {
                let ctx = format!(
                    "{algo:?} m={m} k={k} n={n} x={x} y={yw} tm={tm} \
                     full={full}"
                );
                check_paths(&a8, &b8, None, algo, shape, &ctx);
                check_paths(&a16, &b16, None, algo, shape, &ctx);
            }
            let y8 = y_from_b(&b8, yw);
            check_paths(
                &a8,
                &b8,
                Some(&y8),
                Algo::Ffip,
                shape,
                &format!("offline-y m={m} k={k} n={n} x={x} y={yw}"),
            );
        });
    }

    /// Lane-overflow guard test at the extremes of
    /// `FixedSpec::gemm_acc_bits`: a serving-depth K of full-scale i8
    /// operands sits just inside the 32-bit accumulator budget
    /// (`gemm_acc_bits(true, 64, 4608) <= 32`, see `arith`), so the
    /// vector paths must agree with the scalar oracle with zero
    /// headroom to hide a lane carry.
    #[test]
    fn vector_is_exact_at_accumulator_guard_extremes() {
        let shape = TileShape { x: 64, y: 3, tm: 2 };
        // alternate ±extreme so pair sums, alphas and betas all hit
        // their worst magnitudes
        let a8 = Mat::from_fn(3, 4608, |i, j| {
            if (i + j) % 2 == 0 {
                -128i8
            } else {
                127
            }
        });
        let b8 = Mat::from_fn(4608, 5, |i, j| {
            if (i + j) % 3 == 0 {
                -128i8
            } else {
                127
            }
        });
        for algo in Algo::ALL {
            check_paths(&a8, &b8, None, algo, shape, &format!("{algo:?}"));
        }
        let y8 = y_from_b(&b8, shape.y);
        check_paths(&a8, &b8, Some(&y8), Algo::Ffip, shape, "offline-y");
        // i16 extremes (i64 accumulator): worst-case pair-sum products
        let a16 = Mat::from_fn(2, 512, |i, j| {
            if (i + j) % 2 == 0 {
                i16::MIN
            } else {
                i16::MAX
            }
        });
        let b16 = Mat::from_fn(512, 3, |_, j| {
            if j % 2 == 0 {
                i16::MIN
            } else {
                i16::MAX
            }
        });
        for algo in Algo::ALL {
            check_paths(
                &a16,
                &b16,
                None,
                algo,
                shape,
                &format!("i16 {algo:?}"),
            );
        }
    }

    /// The packed-strip cache never leaks across jobs: interleaving
    /// items of two different GEMMs (distinct job tags, same geometry,
    /// same scratch, same `jt`) must not reuse the other job's strip.
    #[test]
    fn strip_cache_is_isolated_across_jobs() {
        let mut rng = Rng::new(0xE15);
        let (m, k, n) = (6usize, 10usize, 7usize);
        let shape = TileShape { x: 4, y: 4, tm: 2 };
        let a = Mat::from_fn(m, k, |_, _| rng.fixed(8, true) as i8);
        let b1 = Mat::from_fn(k, n, |_, _| rng.fixed(8, true) as i8);
        let b2 = Mat::from_fn(k, n, |_, _| rng.fixed(8, true) as i8);
        let (mt, _, nt) = shape.tiles(m, k, n);
        let mut scratch = Scratch::default();
        let mut c1: Mat<i32> = Mat::zeros(m, n);
        let mut c2: Mat<i32> = Mat::zeros(m, n);
        for algo in Algo::ALL {
            // one GEMM = one job tag (per the compute_item contract)
            let (j1, j2) = (next_job_id(), next_job_id());
            for jt in 0..nt {
                for it in 0..mt {
                    // SAFETY: single-threaded, outputs outlive the calls.
                    unsafe {
                        compute_item(
                            &a.data, &b1.data, None,
                            c1.data.as_mut_ptr(), m, k, n, algo, shape,
                            it, jt, j1, &mut scratch, None,
                        );
                        compute_item(
                            &a.data, &b2.data, None,
                            c2.data.as_mut_ptr(), m, k, n, algo, shape,
                            it, jt, j2, &mut scratch, None,
                        );
                    }
                }
            }
            assert_eq!(c1, tiled_matmul(&a, &b1, algo, shape), "{algo:?} b1");
            assert_eq!(c2, tiled_matmul(&a, &b2, algo, shape), "{algo:?} b2");
        }
    }

    #[test]
    fn precomputed_offline_y_matches_inline_differencing() {
        let mut rng = Rng::new(0xE13);
        for &(m, k, n, x, yw, tm) in &[
            (5usize, 8usize, 12usize, 4usize, 5usize, 2usize),
            (10, 147, 64, 64, 16, 16),
            (7, 6, 9, 2, 3, 3),
        ] {
            let a = Mat::from_fn(m, k, |_, _| rng.fixed(8, true));
            let b = Mat::from_fn(k, n, |_, _| rng.fixed(8, true));
            let shape = TileShape { x, y: yw, tm };
            // offline transform with restarts at the tile-strip width
            let y = y_from_b(&b, yw);
            let got =
                item_gemm(&a, &b, Some(&y), Algo::Ffip, shape, KernelPath::Auto);
            let want = tiled_matmul(&a, &b, Algo::Ffip, shape);
            assert_eq!(got, want, "m={m} k={k} n={n} x={x} y={yw} tm={tm}");
        }
    }

    #[test]
    fn scratch_is_reused_across_geometries() {
        // shrinking then growing tile shapes must stay correct, on the
        // narrow (vector) width so the packed buffers resize too
        let mut rng = Rng::new(0xE12);
        let a = Mat::from_fn(9, 10, |_, _| rng.fixed(8, true) as i8);
        let b = Mat::from_fn(10, 11, |_, _| rng.fixed(8, true) as i8);
        let mut scratch = Scratch::default();
        for shape in [
            TileShape { x: 8, y: 8, tm: 8 },
            TileShape { x: 2, y: 3, tm: 1 },
            TileShape { x: 10, y: 11, tm: 9 },
        ] {
            let (mt, _, nt) = shape.tiles(9, 10, 11);
            let job = next_job_id();
            let mut c: Mat<i32> = Mat::zeros(9, 11);
            for jt in 0..nt {
                for it in 0..mt {
                    // SAFETY: single-threaded, c outlives the call.
                    unsafe {
                        compute_item(
                            &a.data, &b.data, None, c.data.as_mut_ptr(),
                            9, 10, 11, Algo::Ffip, shape, it, jt, job,
                            &mut scratch, None,
                        );
                    }
                }
            }
            assert_eq!(c, tiled_matmul(&a, &b, Algo::Ffip, shape), "{shape:?}");
        }
    }
}
