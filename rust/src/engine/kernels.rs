//! Allocation-free GEMM item kernels for the persistent worker pool.
//!
//! One *work item* is an (M-band × N-tile) block of the output: `tm`
//! consecutive A rows against one `y`-wide column strip of B,
//! accumulated over all K tiles of depth `x` — the same decomposition as
//! [`crate::algo::tiled_matmul`], restructured so that
//!
//! * every buffer the tile loop touches lives in a per-worker
//!   [`Scratch`] that is reused across items and jobs (zero heap
//!   allocation inside the tile loop, unlike the functional path which
//!   allocates tile copies and alpha/beta/y vectors per K tile);
//! * tiles are read straight out of the source matrices with row-slice
//!   copies instead of per-element closure indexing;
//! * the FFIP y transform (Eq. 9) and the FIP/FFIP beta terms (Eq. 4)
//!   are produced in a single pass over the B strip, with no
//!   intermediate y matrix or transpose allocation.
//!
//! The kernels are generic over the storage [`Element`]: A and B stream
//! in their quantized width (`i8`/`i16` for deployed models, `i64` for
//! the oracle path), an optional offline y buffer streams in
//! [`Element::Y`] (one extra bit, §4.4), and every arithmetic step —
//! pair sums, products, the g recurrence, corrections, cross-tile
//! accumulation — runs in the widened [`Element::Acc`] scratch.  The
//! accumulator cannot overflow in release builds because the pool
//! asserts [`FixedSpec::gemm_acc_bits`][gab] `<= Acc::BITS` for every
//! narrow-element job before any item runs (see `pool.rs`).
//!
//! Numerically each kernel evaluates exactly the sums of the reference
//! algorithms in [`crate::algo`] on the same zero-padded tiles, so pool
//! results are bit-identical to `tiled_matmul` (asserted by property
//! tests; see EXPERIMENTS.md §Perf for the throughput delta this
//! restructuring buys).
//!
//! [gab]: crate::arith::FixedSpec::gemm_acc_bits

use crate::algo::element::Element;
use crate::algo::{Algo, TileShape};
use crate::util::ceil_div;

/// Per-worker reusable buffers for one storage element type.  Sized
/// lazily by `ensure`; `resize` is a no-op when the tile geometry is
/// unchanged, so steady state performs no allocation at all.
pub struct Scratch<E: Element> {
    /// Output accumulator for one item: up to `tm * y`.
    acc: Vec<E::Acc>,
    /// Transposed B-derived tile (`y` for FFIP, plain B for FIP),
    /// widened: `y * x`.
    bt: Vec<E::Acc>,
    /// Per-tile-column beta terms (Eq. 4): `y`.
    beta: Vec<E::Acc>,
    /// FFIP g recurrence state (Eqs. 8a-8c): `x`.
    g: Vec<E::Acc>,
    /// Zero-padded, widened A row fragment: `x`.
    arow: Vec<E::Acc>,
}

impl<E: Element> Default for Scratch<E> {
    fn default() -> Self {
        Scratch {
            acc: Vec::new(),
            bt: Vec::new(),
            beta: Vec::new(),
            g: Vec::new(),
            arow: Vec::new(),
        }
    }
}

impl<E: Element> Scratch<E> {
    fn ensure(&mut self, shape: TileShape) {
        let zero = <E::Acc>::default();
        self.acc.resize(shape.tm * shape.y, zero);
        self.bt.resize(shape.y * shape.x, zero);
        self.beta.resize(shape.y, zero);
        self.g.resize(shape.x, zero);
        self.arow.resize(shape.x, zero);
    }
}

/// One reusable [`Scratch`] per storage width, so a single pool worker
/// serves jobs of any element type without reallocating between widths
/// (jobs carry an [`ElemKind`](crate::algo::ElemKind) tag; `pool.rs`
/// dispatches to the matching field).
#[derive(Default)]
pub(crate) struct ScratchSet {
    pub(crate) s8: Scratch<i8>,
    pub(crate) s16: Scratch<i16>,
    pub(crate) s32: Scratch<i32>,
    pub(crate) s64: Scratch<i64>,
}

/// Compute one (M-band × N-tile) output block of `C = A B` and write it
/// to `c`.
///
/// `a` and `b` are the full row-major input buffers (`m*k` and `k*n`
/// elements); `(it, jt)` select the M-band (rows `it*tm ..`) and N-tile
/// (columns `jt*y ..`).  For `Algo::Fip`/`Algo::Ffip` the caller must
/// guarantee an even tile depth `shape.x` (asserted at pool submit).
///
/// `y_off` is an optional *precomputed offline* FFIP weight transform —
/// the full `k*n` buffer of `y_from_b(b, shape.y)` in the widened-by-
/// one-bit [`Element::Y`] storage (§3.3: the Θ(NK) y-forming
/// subtractions leave the inference path when weights are stored
/// pre-transformed).  When present (FFIP only) the kernel copies y
/// tiles straight out of it instead of differencing the B strip per
/// K-tile pass; beta terms still come from `b`.
///
/// # Safety
///
/// `c` must be valid for writes across the whole `m * n` output buffer,
/// the buffer must stay alive for the duration of the call, and no other
/// thread may concurrently access the `(it, jt)` region this call
/// writes.  Distinct `(it, jt)` items touch disjoint regions, which is
/// what makes the pool's work-claiming sound.
#[allow(clippy::too_many_arguments)]
pub(crate) unsafe fn compute_item<E: Element>(
    a: &[E],
    b: &[E],
    y_off: Option<&[E::Y]>,
    c: *mut E::Acc,
    m: usize,
    k: usize,
    n: usize,
    algo: Algo,
    shape: TileShape,
    it: usize,
    jt: usize,
    scratch: &mut Scratch<E>,
) {
    let (x, yw, tm) = (shape.x, shape.y, shape.tm);
    let i0 = it * tm;
    let j0 = jt * yw;
    debug_assert!(i0 < m && j0 < n);
    let rows = tm.min(m - i0);
    let cols = yw.min(n - j0);
    let kt_n = ceil_div(k, x);
    let zero = <E::Acc>::default();
    scratch.ensure(shape);
    let Scratch { acc, bt, beta, g, arow } = scratch;
    let acc = &mut acc[..rows * cols];
    acc.fill(zero);

    for kt in 0..kt_n {
        let k0 = kt * x;
        let kv = x.min(k - k0);
        match algo {
            Algo::Baseline => {
                // Eq. (1), ikj order over the strip: contiguous B and C
                // rows so the MAC loop auto-vectorizes.
                for i in 0..rows {
                    let ar = &a[(i0 + i) * k + k0..(i0 + i) * k + k0 + kv];
                    let accrow = &mut acc[i * cols..(i + 1) * cols];
                    for (r, &av) in ar.iter().enumerate() {
                        let av = av.acc();
                        let brow =
                            &b[(k0 + r) * n + j0..(k0 + r) * n + j0 + cols];
                        for (cv, &bv) in accrow.iter_mut().zip(brow) {
                            *cv += av * bv.acc();
                        }
                    }
                }
            }
            Algo::Fip => {
                // Transpose the zero-padded B tile once per K tile so
                // each output column's operands are contiguous.
                let btile = &mut bt[..cols * x];
                btile.fill(zero);
                for r in 0..kv {
                    let brow =
                        &b[(k0 + r) * n + j0..(k0 + r) * n + j0 + cols];
                    for (j, &bv) in brow.iter().enumerate() {
                        btile[j * x + r] = bv.acc();
                    }
                }
                let betas = &mut beta[..cols];
                beta_into(b, k0, kv, n, j0, betas);
                for i in 0..rows {
                    let ar = &mut arow[..x];
                    widen_into(
                        &a[(i0 + i) * k + k0..(i0 + i) * k + k0 + kv],
                        ar,
                    );
                    let mut alpha = zero;
                    for p in ar.chunks_exact(2) {
                        alpha += p[0] * p[1];
                    }
                    let accrow = &mut acc[i * cols..(i + 1) * cols];
                    for (j, cv) in accrow.iter_mut().enumerate() {
                        let btj = &btile[j * x..(j + 1) * x];
                        // Eq. (2): (a_odd + b_even)(a_even + b_odd)
                        let mut s = zero;
                        let mut p = 0;
                        while p < x {
                            s += (ar[p] + btj[p + 1]) * (ar[p + 1] + btj[p]);
                            p += 2;
                        }
                        *cv += s - alpha - betas[j];
                    }
                }
            }
            Algo::Ffip => {
                // Eq. (9) with tile restart at the strip's first column:
                // emit y directly transposed, no intermediate matrix —
                // or, with an offline-precomputed y buffer, copy its
                // rows (restart geometry matches: y_from_b(b, shape.y)
                // restarts exactly at the j0 = jt*y strip boundaries).
                let ytile = &mut bt[..cols * x];
                ytile.fill(zero);
                for r in 0..kv {
                    match y_off {
                        Some(yb) => {
                            let yrow = &yb
                                [(k0 + r) * n + j0..(k0 + r) * n + j0 + cols];
                            for (j, &yv) in yrow.iter().enumerate() {
                                ytile[j * x + r] = E::y_to_acc(yv);
                            }
                        }
                        None => {
                            let brow = &b
                                [(k0 + r) * n + j0..(k0 + r) * n + j0 + cols];
                            let mut prev = zero;
                            for (j, &bv) in brow.iter().enumerate() {
                                let bv = bv.acc();
                                ytile[j * x + r] = bv - prev;
                                prev = bv;
                            }
                        }
                    }
                }
                let betas = &mut beta[..cols];
                beta_into(b, k0, kv, n, j0, betas);
                for i in 0..rows {
                    let ar = &mut arow[..x];
                    widen_into(
                        &a[(i0 + i) * k + k0..(i0 + i) * k + k0 + kv],
                        ar,
                    );
                    let mut alpha = zero;
                    for p in ar.chunks_exact(2) {
                        alpha += p[0] * p[1];
                    }
                    // Eqs. (8a)/(8b): seed g with the swapped a pairs.
                    let gs = &mut g[..x];
                    let mut p = 0;
                    while p < x {
                        gs[p] = ar[p + 1];
                        gs[p + 1] = ar[p];
                        p += 2;
                    }
                    let accrow = &mut acc[i * cols..(i + 1) * cols];
                    for (j, cv) in accrow.iter_mut().enumerate() {
                        // Eq. (8c): g += y column j
                        let yrow = &ytile[j * x..(j + 1) * x];
                        for (gv, &yv) in gs.iter_mut().zip(yrow.iter()) {
                            *gv += yv;
                        }
                        // Eq. (7)
                        let mut s = zero;
                        for pair in gs.chunks_exact(2) {
                            s += pair[0] * pair[1];
                        }
                        *cv += s - alpha - betas[j];
                    }
                }
            }
        }
    }

    // Write the finished block back; each item owns a disjoint region.
    for i in 0..rows {
        let src = &acc[i * cols..(i + 1) * cols];
        // SAFETY: rows i0+i < m and columns j0..j0+cols <= n, within the
        // caller-guaranteed m*n buffer; regions of distinct items are
        // disjoint (see function-level contract).
        let dst = unsafe {
            std::slice::from_raw_parts_mut(c.add((i0 + i) * n + j0), cols)
        };
        dst.copy_from_slice(src);
    }
}

/// Widen `src` into the front of `dst`, zero-filling the tail (the
/// zero-padded A row fragment of an edge K tile).
#[inline(always)]
fn widen_into<E: Element>(src: &[E], dst: &mut [E::Acc]) {
    for (d, &s) in dst.iter_mut().zip(src) {
        *d = s.acc();
    }
    dst[src.len()..].fill(<E::Acc>::default());
}

/// Eq. (4) beta terms for the zero-padded `(k0, kv)` × `(j0, cols)` B
/// tile, written into `betas` (length `cols`).  Rows past `kv` are
/// implicit zeros, so an odd valid depth pairs its last row with zero.
fn beta_into<E: Element>(
    b: &[E],
    k0: usize,
    kv: usize,
    n: usize,
    j0: usize,
    betas: &mut [E::Acc],
) {
    betas.fill(<E::Acc>::default());
    let cols = betas.len();
    let mut r = 0;
    while r + 1 < kv {
        let b0 = &b[(k0 + r) * n + j0..(k0 + r) * n + j0 + cols];
        let b1 = &b[(k0 + r + 1) * n + j0..(k0 + r + 1) * n + j0 + cols];
        for ((bj, &v0), &v1) in betas.iter_mut().zip(b0).zip(b1) {
            *bj += v0.acc() * v1.acc();
        }
        r += 2;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::{tiled_matmul, y_from_b, Mat};
    use crate::util::Rng;

    /// Drive every item of a GEMM through `compute_item` serially and
    /// compare against the functional tiled path.
    fn run_all_items<E: Element>(
        a: &Mat<E>,
        b: &Mat<E>,
        y: Option<&Mat<E::Y>>,
        algo: Algo,
        shape: TileShape,
    ) -> Mat<E::Acc> {
        let (m, k, n) = (a.rows, a.cols, b.cols);
        let (mt, _, nt) = shape.tiles(m, k, n);
        let mut c = Mat::zeros(m, n);
        let mut scratch = Scratch::default();
        for it in 0..mt {
            for jt in 0..nt {
                // SAFETY: single-threaded, c outlives the call.
                unsafe {
                    compute_item(
                        &a.data,
                        &b.data,
                        y.map(|m| m.data.as_slice()),
                        c.data.as_mut_ptr(),
                        m,
                        k,
                        n,
                        algo,
                        shape,
                        it,
                        jt,
                        &mut scratch,
                    );
                }
            }
        }
        c
    }

    #[test]
    fn items_match_tiled_matmul_all_algos() {
        let mut rng = Rng::new(0xE11);
        for &(m, k, n, x, y, tm) in &[
            (5usize, 8usize, 12usize, 4usize, 5usize, 2usize),
            (16, 16, 16, 8, 8, 8),
            (10, 147, 64, 64, 16, 16), // ResNet conv1 edge tiles
            (1, 2, 1, 2, 1, 1),
            (7, 6, 9, 2, 3, 3),
        ] {
            let a = Mat::from_fn(m, k, |_, _| rng.fixed(8, true));
            let b = Mat::from_fn(k, n, |_, _| rng.fixed(8, true));
            let shape = TileShape { x, y, tm };
            for algo in Algo::ALL {
                let got = run_all_items(&a, &b, None, algo, shape);
                let want = tiled_matmul(&a, &b, algo, shape);
                assert_eq!(
                    got, want,
                    "{algo:?} m={m} k={k} n={n} x={x} y={y} tm={tm}"
                );
            }
        }
    }

    /// Narrow-element items equal the widened i64 oracle exactly, with
    /// and without the offline y transform.
    #[test]
    fn narrow_items_match_widened_oracle() {
        let mut rng = Rng::new(0xE14);
        for &(m, k, n, x, yw, tm) in &[
            (5usize, 8usize, 12usize, 4usize, 5usize, 2usize),
            (10, 147, 64, 64, 16, 16),
            (7, 6, 9, 2, 3, 3),
        ] {
            let a8 = Mat::from_fn(m, k, |_, _| rng.fixed(8, true) as i8);
            let b8 = Mat::from_fn(k, n, |_, _| rng.fixed(8, true) as i8);
            let a16 =
                Mat::from_fn(m, k, |_, _| rng.fixed(16, true) as i16);
            let b16 =
                Mat::from_fn(k, n, |_, _| rng.fixed(16, true) as i16);
            let shape = TileShape { x, y: yw, tm };
            for algo in Algo::ALL {
                let gold8 =
                    tiled_matmul(&a8.widen(), &b8.widen(), algo, shape);
                assert_eq!(
                    run_all_items(&a8, &b8, None, algo, shape).widen(),
                    gold8,
                    "i8 {algo:?} m={m} k={k} n={n}"
                );
                let gold16 =
                    tiled_matmul(&a16.widen(), &b16.widen(), algo, shape);
                assert_eq!(
                    run_all_items(&a16, &b16, None, algo, shape).widen(),
                    gold16,
                    "i16 {algo:?} m={m} k={k} n={n}"
                );
            }
            // offline y (i16 storage for i8 operands — the §4.4 extra bit)
            let y8 = y_from_b(&b8, yw);
            let gold8 =
                tiled_matmul(&a8.widen(), &b8.widen(), Algo::Ffip, shape);
            assert_eq!(
                run_all_items(&a8, &b8, Some(&y8), Algo::Ffip, shape)
                    .widen(),
                gold8,
                "i8 offline-y m={m} k={k} n={n}"
            );
        }
    }

    #[test]
    fn precomputed_offline_y_matches_inline_differencing() {
        let mut rng = Rng::new(0xE13);
        for &(m, k, n, x, yw, tm) in &[
            (5usize, 8usize, 12usize, 4usize, 5usize, 2usize),
            (10, 147, 64, 64, 16, 16),
            (7, 6, 9, 2, 3, 3),
        ] {
            let a = Mat::from_fn(m, k, |_, _| rng.fixed(8, true));
            let b = Mat::from_fn(k, n, |_, _| rng.fixed(8, true));
            let shape = TileShape { x, y: yw, tm };
            // offline transform with restarts at the tile-strip width
            let y = y_from_b(&b, yw);
            let got = run_all_items(&a, &b, Some(&y), Algo::Ffip, shape);
            let want = tiled_matmul(&a, &b, Algo::Ffip, shape);
            assert_eq!(got, want, "m={m} k={k} n={n} x={x} y={yw} tm={tm}");
        }
    }

    #[test]
    fn scratch_is_reused_across_geometries() {
        // shrinking then growing tile shapes must stay correct
        let mut rng = Rng::new(0xE12);
        let a = Mat::from_fn(9, 10, |_, _| rng.fixed(8, true));
        let b = Mat::from_fn(10, 11, |_, _| rng.fixed(8, true));
        let mut scratch = Scratch::default();
        for shape in [
            TileShape { x: 8, y: 8, tm: 8 },
            TileShape { x: 2, y: 3, tm: 1 },
            TileShape { x: 10, y: 11, tm: 9 },
        ] {
            let (mt, _, nt) = shape.tiles(9, 10, 11);
            let mut c = Mat::zeros(9, 11);
            for it in 0..mt {
                for jt in 0..nt {
                    // SAFETY: single-threaded, c outlives the call.
                    unsafe {
                        compute_item(
                            &a.data,
                            &b.data,
                            None,
                            c.data.as_mut_ptr(),
                            9,
                            10,
                            11,
                            Algo::Ffip,
                            shape,
                            it,
                            jt,
                            &mut scratch,
                        );
                    }
                }
            }
            assert_eq!(c, tiled_matmul(&a, &b, Algo::Ffip, shape), "{shape:?}");
        }
    }
}
