//! The persistent-pool GEMM execution engine — the serving hot path.
//!
//! The functional fast path used to fan each
//! [`tiled_matmul_parallel`](crate::algo::tiled_matmul_parallel) call
//! out over freshly spawned `std::thread::scope` threads, and the tiled
//! inner loop allocated tile copies and alpha/beta/y vectors for every
//! K tile.  Fine for one-shot experiments; wrong shape for a server
//! that performs thousands of GEMMs per second.  This module replaces
//! that with
//!
//! * [`GemmPool`] — a long-lived pool of workers pulling
//!   (M-band × N-tile) work items from a shared queue (`pool.rs` module
//!   docs cover the claiming protocol and the safety argument); items
//!   are claimed column-strip-major so a worker's packed B/y strip
//!   stays cache-resident across the M-bands it executes;
//! * `kernels.rs` — allocation-free Baseline/FIP/FFIP item kernels
//!   with per-worker reusable scratch (nothing allocates inside the
//!   tile loop), dispatching narrow-storage jobs to the vectorized
//!   kernels;
//! * `simd.rs` — the lane-parallel item kernels: stable-Rust
//!   u64-packed SWAR (4 × 16-bit lanes for `i8`, 2 × 32-bit lanes for
//!   `i16`, always on) with optional `std::simd` versions behind the
//!   nightly-only `portable_simd` feature, every path bit-identical to
//!   the scalar kernels ([`item_gemm`] exposes the per-path compute
//!   for benches and oracles — bench H10);
//! * a submit/wait API: blocking [`GemmPool::gemm`] /
//!   [`GemmPool::gemm_into`] (the latter writes into a caller-owned,
//!   reusable output buffer and optionally consumes a precomputed
//!   offline FFIP y transform — what
//!   [`crate::coordinator::InferenceSession`] calls per layer on the
//!   request path) plus [`GemmPool::submit`] / [`GemmPool::submit_y`] /
//!   [`GemmPool::submit_into`] → [`PendingGemm::wait`] for callers
//!   that overlap GEMMs with other work (`submit_into` additionally
//!   recycles a caller-owned output ring, so the pipelined serving
//!   executor allocates nothing in steady state).
//!
//! The whole engine is generic over the storage
//! [`Element`](crate::algo::Element): one pool serves `i8`, `i16` and
//! `i64` jobs interleaved, with operands streamed at their quantized
//! width, offline y terms at one extra bit, and arithmetic in the
//! widened accumulator — the §4.4 datapath made concrete, and 4–8×
//! less operand traffic than the historical all-`i64` path (bench H8).
//! Narrow jobs are release-safe by construction: enqueue asserts the
//! `2w + clog2(X)`-derived accumulator bound
//! ([`FixedSpec::gemm_acc_bits`](crate::arith::FixedSpec::gemm_acc_bits)).
//!
//! Results are bit-identical to [`crate::algo::tiled_matmul`] for every
//! algorithm, element type, shape and thread count (property-tested in
//! `tests/engine.rs`).  The spawn-per-call vs persistent-pool
//! comparison is bench H6 in `benches/hotpath.rs`, logged in
//! EXPERIMENTS.md §Perf.  Pool occupancy is observable through
//! [`PoolStats`], surfaced by `coordinator::ServeStats` and
//! [`crate::metrics::PoolMetrics`].
//!
//! Robustness (`abft.rs`, `faults.rs`): because the arithmetic is
//! exact and integer, Huang–Abraham-style checksums are *bit-exact*
//! invariants — [`AbftCheck`] verifies `rowsum(C) = A · rowsum(B)`
//! after every checked GEMM with zero false positives, heals transient
//! corruption by recomputing affected items through the scalar oracle,
//! and escalates persistent disagreement as a typed fault.
//! [`FaultPlan`] injects deterministic faults (strip bit-flips,
//! accumulator corruption, dropped items, kernel panics, wedged
//! workers) so every recovery path is provable; [`GemmError`] and the
//! pool watchdog ([`GemmPool::set_watchdog`]) turn item panics and
//! wedged workers into typed errors instead of unwinds or hangs.

mod abft;
mod faults;
mod kernels;
mod pool;
mod simd;

pub use abft::{abft_fits, AbftCheck, AbftFault, AbftReport};
pub use faults::{FaultKind, FaultPlan, FaultState};
pub use kernels::{item_gemm, KernelPath};
pub use pool::{GemmError, GemmPool, PendingGemm, PoolStats};
