//! The persistent worker pool: long-lived threads, a shared job queue,
//! and cooperative work claiming.
//!
//! ## Execution model
//!
//! A [`GemmPool::gemm`] (or [`GemmPool::submit`]) call turns one GEMM
//! into `mt * nt` (M-band × N-tile) *work items* (the `kernels.rs`
//! granularity) and enqueues a single job describing them.  Workers —
//! and the submitting thread itself, while it waits — claim item
//! indices from the job's atomic cursor and execute them with their own
//! reusable [`Scratch`].  Items are numbered column-strip-major
//! (`jt * mt + it`): a worker claiming consecutive indices walks down
//! the M-bands of one N strip, so its packed B/y strip (built once per
//! job/strip by the SWAR kernels, `simd.rs`) stays cache-resident
//! between items.  Consequently,
//!
//! * no thread is ever spawned per call (the pool outlives every job);
//! * a pool with zero workers still completes every job (the caller
//!   drains its own work), so sizing is a pure performance knob;
//! * multiple coordinators can share one pool; jobs queue FIFO and each
//!   waiter only blocks on its own job's completion latch.
//!
//! ## Element types
//!
//! The pool is generic over the storage [`Element`]: one pool serves
//! `i8`, `i16` and `i64` jobs interleaved (each worker keeps one
//! reusable scratch per width).  Jobs erase the element type into raw
//! `*const u8` pointers plus an [`ElemKind`] width tag — the tag is set
//! from `E` at enqueue and is the *only* key used to cast the pointers
//! back, so a job is always executed at exactly the types it was
//! submitted with.
//!
//! For narrow elements the widened accumulator is finite (`i32` for
//! `i8` operands), so enqueue asserts the release-mode overflow guard
//! [`FixedSpec::gemm_acc_bits`] `<=` `Acc::BITS`: the worst-case
//! magnitude over every tile *and* the full cross-tile accumulation
//! provably fits, making release builds safe by construction (debug
//! builds additionally keep Rust's checked arithmetic).  Wide (`i64`)
//! jobs skip the guard and keep the historical oracle semantics.
//!
//! ## Why the `unsafe` is sound
//!
//! A job carries raw pointers to the A/B inputs (plus an optional
//! offline-y buffer) and the C output instead of references, because
//! worker threads are `'static` while job data is not.  Four
//! invariants restore safety, all enforced by construction:
//!
//! 1. **Liveness** — [`GemmPool::gemm`]/[`GemmPool::gemm_into`] borrow
//!    their inputs (and output buffer) and do not return until the job's
//!    latch is set (and nothing on that path can unwind earlier:
//!    `run_job` catches item panics and re-raises them only after the
//!    latch); [`GemmPool::submit`] takes *ownership* of
//!    its inputs and parks them in the returned [`PendingGemm`], whose
//!    `wait`/`Drop` also blocks on the latch — and leaking the handle
//!    (`mem::forget`) leaks the buffers too, so the pointers can dangle
//!    in no reachable execution.
//! 2. **Typing** — the `kind` tag is written once at enqueue from the
//!    `E` the pointers were derived from, and every dereference first
//!    dispatches on it, so pointers are only ever cast back to the
//!    types (`E`, `E::Y`, `E::Acc`) they came from.
//! 3. **Disjoint writes** — item `(it, jt)` writes exactly the output
//!    block `rows it*tm.. × cols jt*y..`; distinct items are disjoint,
//!    and the atomic claim cursor hands each index to exactly one
//!    thread.
//! 4. **Visibility** — every item completion is a release increment of
//!    the job's `done` counter; the final increment sets the latch under
//!    a mutex that the waiter reads under, so all writes to C
//!    happen-before the waiter regains the output matrix.
//!
//! [`FixedSpec::gemm_acc_bits`]: crate::arith::FixedSpec::gemm_acc_bits

use super::faults::{FaultKind, FaultPlan, FaultState};
use super::kernels::{self, Scratch, ScratchSet};
use crate::algo::element::{ElemKind, Element};
use crate::algo::{Algo, Mat, TileShape};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Typed pool-level GEMM failure — what the serving path sees instead
/// of a panic (poison) or an infinite block (wedged worker).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GemmError {
    /// An item's kernel panicked during pool execution; the job is
    /// poisoned and its output must not be trusted.  The legacy
    /// [`PendingGemm::wait`]/[`GemmPool::gemm_into`] paths re-raise
    /// this as a panic; the `*_checked` serving paths return it.
    Poisoned,
    /// The pool watchdog ([`GemmPool::set_watchdog`]) expired before
    /// the job's completion latch was set — a worker is wedged (or the
    /// job is starved) and the waiter refused to block forever.
    Timeout {
        /// How long the waiter actually waited.
        waited: Duration,
    },
}

impl std::fmt::Display for GemmError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            GemmError::Poisoned => write!(
                f,
                "a GEMM item panicked during pool execution; the job \
                 is poisoned and the batch must be failed"
            ),
            GemmError::Timeout { waited } => write!(
                f,
                "GEMM watchdog expired after {waited:?}: a pool worker \
                 is wedged or the job is starved"
            ),
        }
    }
}

impl std::error::Error for GemmError {}

/// One queued GEMM: type-erased input/output pointers plus the width
/// tag that recovers their element types, and the item cursor.
struct Job {
    a: *const u8,
    b: *const u8,
    /// Precomputed offline FFIP y buffer (`y_from_b(b, shape.y)`, in
    /// `E::Y` storage), or null when the kernel differences B inline;
    /// same `k*n` element extent and liveness contract as `b`.
    y: *const u8,
    c: *mut u8,
    /// Storage width of `a`/`b` (and thereby of `y` = `E::Y` and
    /// `c` = `E::Acc`).  Set from `E` at enqueue; the only key used to
    /// cast the raw pointers back (typing invariant, module docs).
    kind: ElemKind,
    /// Process-unique job tag keying the workers' packed-strip caches
    /// (see `kernels::next_job_id`).
    id: u64,
    m: usize,
    k: usize,
    n: usize,
    algo: Algo,
    shape: TileShape,
    /// M-band count.  Items are numbered **column-strip-major**
    /// (`jt * mt + it`): consecutive claims walk down the M-bands of
    /// one N strip, so a worker reuses its cache-resident packed B/y
    /// strip (`engine/simd.rs`) across M-bands before moving to the
    /// next strip.
    mt: usize,
    /// Total work items; 0 only for degenerate empty outputs.
    total: usize,
    /// Next unclaimed item index.
    next: AtomicUsize,
    /// Completed item count.
    done: AtomicUsize,
    /// Set when an item's kernel panicked (e.g. debug-build overflow);
    /// the waiter re-raises so pool and serial paths fail alike.
    poisoned: AtomicBool,
    /// Completion latch (waiters block on it).
    finished: Mutex<bool>,
    fin_cv: Condvar,
}

// SAFETY: the raw pointers are only dereferenced while executing a
// claimed item, and the liveness/typing/disjointness/visibility
// invariants (module docs) guarantee those accesses are valid, at the
// correct types, and race-free.
unsafe impl Send for Job {}
unsafe impl Sync for Job {}

impl Job {
    /// Block until every item of this job has completed, then re-raise
    /// any item panic on the waiting thread (skipped when this thread is
    /// already unwinding, to avoid a double-panic abort).
    fn wait_finished(&self) {
        if self.wait_finished_checked().is_err() && !std::thread::panicking()
        {
            panic!("engine: a GEMM item panicked during pool execution");
        }
    }

    /// [`Job::wait_finished`] with the poison re-raise converted into a
    /// typed [`GemmError::Poisoned`] — the serving-path variant, so a
    /// worker-item panic fails one request instead of unwinding into
    /// the session thread.
    fn wait_finished_checked(&self) -> Result<(), GemmError> {
        let mut fin = self.finished.lock().unwrap();
        while !*fin {
            fin = self.fin_cv.wait(fin).unwrap();
        }
        drop(fin);
        if self.poisoned.load(Ordering::Relaxed) {
            return Err(GemmError::Poisoned);
        }
        Ok(())
    }

    /// Bounded wait: like [`Job::wait_finished_checked`] but gives up
    /// with [`GemmError::Timeout`] when the latch is not set within
    /// `timeout` — the watchdog primitive that turns a wedged worker
    /// into a typed error instead of an infinite block.  A timeout
    /// does **not** cancel the job: its items remain claimable and the
    /// caller stays responsible for the liveness invariant (see
    /// [`PendingGemm::wait_checked`] for the sound abandonment story).
    fn wait_finished_for(&self, timeout: Duration) -> Result<(), GemmError> {
        let start = Instant::now();
        let mut fin = self.finished.lock().unwrap();
        while !*fin {
            let waited = start.elapsed();
            let Some(left) = timeout.checked_sub(waited) else {
                return Err(GemmError::Timeout { waited });
            };
            let (f, _timed_out) =
                self.fin_cv.wait_timeout(fin, left).unwrap();
            fin = f;
        }
        drop(fin);
        if self.poisoned.load(Ordering::Relaxed) {
            return Err(GemmError::Poisoned);
        }
        Ok(())
    }
}

/// Drop fully-claimed jobs off the queue front.  Called everywhere the
/// queue lock is already held, so even a zero-worker pool (no
/// `worker_loop` to prune) cannot accumulate finished jobs.
fn prune_front(q: &mut Queue) {
    while q
        .jobs
        .front()
        .is_some_and(|j| j.next.load(Ordering::Relaxed) >= j.total)
    {
        q.jobs.pop_front();
    }
}

/// Queue plus bookkeeping guarded by one mutex.
struct Queue {
    jobs: VecDeque<Arc<Job>>,
    peak: usize,
}

struct Shared {
    queue: Mutex<Queue>,
    work_cv: Condvar,
    shutdown: AtomicBool,
    jobs_submitted: AtomicU64,
    async_jobs: AtomicU64,
    items_executed: AtomicU64,
    /// Sum over enqueues of the jobs already waiting ahead (the
    /// submit-side backlog; see [`PoolStats::mean_enqueue_backlog`]).
    enqueue_backlog_sum: AtomicU64,
    enqueued_jobs: AtomicU64,
    /// Lane-MACs elided by the SWAR kernels' zero-column skipping
    /// (`engine/simd.rs`), flushed from the per-thread scratches.
    lanes_skipped: AtomicU64,
    /// Packed B/y strip (re)builds, flushed likewise.
    strips_built: AtomicU64,
    /// Worker threads the pool was built with (so the stall-plan
    /// helping rule below can never deadlock a zero-worker pool).
    worker_count: usize,
    /// Installed fault-injection plan (`engine/faults.rs`), test-only
    /// by default: `None` costs one uncontended lock per `run_job`
    /// participation, nothing per item.
    faults: Mutex<Option<Arc<FaultState>>>,
    /// Watchdog for the `*_checked` waits, in milliseconds; 0 = off.
    watchdog_ms: AtomicU64,
}

impl Shared {
    fn fault_state(&self) -> Option<Arc<FaultState>> {
        self.faults.lock().unwrap().clone()
    }

    fn watchdog(&self) -> Option<Duration> {
        match self.watchdog_ms.load(Ordering::Relaxed) {
            0 => None,
            ms => Some(Duration::from_millis(ms)),
        }
    }

    /// With a [`FaultKind::StallWorker`] plan armed (and at least one
    /// real worker to take the bait), submitters wait instead of
    /// helping: the wedged item is then guaranteed to be owned by a
    /// pool worker, which is what makes the watchdog tests
    /// deterministic rather than racing on who claims the stalled
    /// item.  Never triggers without an installed plan.
    fn helping_disabled(&self) -> bool {
        self.worker_count > 0
            && self
                .fault_state()
                .is_some_and(|f| f.plan().kind == FaultKind::StallWorker)
    }
}

thread_local! {
    /// Reusable per-width scratches for *submitting* threads helping
    /// their own jobs (workers carry their own in `worker_loop`), so
    /// the request path stays allocation-free in steady state.
    static HELPER_SCRATCH: std::cell::RefCell<ScratchSet> =
        std::cell::RefCell::new(ScratchSet::default());
}

/// Help execute `job` with this thread's reusable scratch, then block
/// until its latch is set (re-raising any item panic).
fn help_and_wait(shared: &Shared, job: &Job) {
    if !shared.helping_disabled() {
        HELPER_SCRATCH.with(|s| run_job(shared, job, &mut s.borrow_mut()));
    }
    job.wait_finished();
}

/// [`help_and_wait`] for the serving path: poison becomes a typed
/// [`GemmError::Poisoned`], and when a pool watchdog is set
/// ([`GemmPool::set_watchdog`]) the wait is bounded.  A
/// [`GemmError::Timeout`] return means the job may still be running —
/// the caller must uphold the liveness invariant (block again, or own
/// and leak the buffers) before letting them go.
fn help_and_wait_checked(shared: &Shared, job: &Job) -> Result<(), GemmError> {
    if !shared.helping_disabled() {
        HELPER_SCRATCH.with(|s| run_job(shared, job, &mut s.borrow_mut()));
    }
    match shared.watchdog() {
        Some(d) => job.wait_finished_for(d),
        None => job.wait_finished_checked(),
    }
}

/// Counters exposed to [`crate::coordinator::ServeStats`] and
/// [`crate::metrics::PoolMetrics`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct PoolStats {
    /// Worker threads owned by the pool (excludes helping submitters).
    pub workers: usize,
    /// GEMM jobs submitted over the pool's lifetime.
    pub jobs: u64,
    /// Jobs submitted asynchronously ([`GemmPool::submit`] /
    /// [`GemmPool::submit_y`]) — the overlap-shaped traffic (a pipelined
    /// serving session submits layer GEMMs async and stages the next
    /// operand while they drain).
    pub async_jobs: u64,
    /// Work items executed over the pool's lifetime.
    pub items: u64,
    /// Jobs currently enqueued (approximate; claimed-but-running jobs
    /// may still be counted until lazily pruned).
    pub queue_depth: usize,
    /// Highwater queue depth since pool creation.
    pub peak_queue_depth: usize,
    /// Sum over enqueues of the jobs already waiting ahead — the
    /// submit-side backlog (instantaneous `queue_depth` reads ~0 for a
    /// single synchronous caller, because its job is drained before it
    /// can observe the queue again).
    pub enqueue_backlog_sum: u64,
    /// Jobs that actually entered the queue (excludes empty outputs).
    pub enqueued_jobs: u64,
    /// Lane-MACs elided by zero-column skipping in the SWAR inner loops
    /// (`engine/simd.rs`): all-zero packed B/y columns are flagged at
    /// strip-build time and skipped per M-band row, so sparse —
    /// notably Winograd-transformed or pruned — weights translate
    /// directly into fewer executed lane operations.  Exactly zero for
    /// dense weights and for baseline jobs (biased storage is dense).
    pub lanes_skipped: u64,
    /// Packed B/y strip (re)builds across all workers — the
    /// denominator for strip-cache efficiency: items per build ≈
    /// `items / strips_built` M-bands reused each resident strip.
    pub strips_built: u64,
    /// Faults actually fired by the installed
    /// [`FaultPlan`](super::FaultPlan) (0 without one) — the ground
    /// truth the ABFT detection counters are audited against in
    /// `tests/faults.rs`.
    pub faults_injected: u64,
}

impl PoolStats {
    /// Mean number of jobs already queued when a new job arrived —
    /// sustained values near or above `workers` mean the serving tier
    /// is GEMM-bound and the pool (or MXU) should grow.
    pub fn mean_enqueue_backlog(&self) -> f64 {
        if self.enqueued_jobs == 0 {
            return 0.0;
        }
        self.enqueue_backlog_sum as f64 / self.enqueued_jobs as f64
    }
}

/// Persistent-pool GEMM execution engine (see module docs).
pub struct GemmPool {
    shared: Arc<Shared>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl GemmPool {
    /// Spawn a pool with `threads` long-lived workers.  `threads == 0`
    /// is valid: jobs are then executed entirely by their submitters.
    pub fn new(threads: usize) -> Self {
        let shared = Arc::new(Shared {
            queue: Mutex::new(Queue { jobs: VecDeque::new(), peak: 0 }),
            work_cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            jobs_submitted: AtomicU64::new(0),
            async_jobs: AtomicU64::new(0),
            items_executed: AtomicU64::new(0),
            enqueue_backlog_sum: AtomicU64::new(0),
            enqueued_jobs: AtomicU64::new(0),
            lanes_skipped: AtomicU64::new(0),
            strips_built: AtomicU64::new(0),
            worker_count: threads,
            faults: Mutex::new(None),
            watchdog_ms: AtomicU64::new(0),
        });
        let workers = (0..threads)
            .map(|i| {
                let s = shared.clone();
                std::thread::Builder::new()
                    .name(format!("ffip-engine-{i}"))
                    .spawn(move || worker_loop(&s))
                    .expect("spawn engine worker")
            })
            .collect();
        GemmPool { shared, workers }
    }

    /// A reasonable worker count for this host (`available_parallelism`
    /// minus one for the submitting thread, at least 1).
    pub fn default_threads() -> usize {
        std::thread::available_parallelism()
            .map(|p| p.get().saturating_sub(1).max(1))
            .unwrap_or(1)
    }

    /// Worker threads owned by the pool.
    pub fn threads(&self) -> usize {
        self.workers.len()
    }

    /// Install a deterministic fault-injection plan
    /// (`engine/faults.rs`); subsequent jobs execute against it.
    /// Test-only by default — nothing installs a plan in production —
    /// and replaced wholesale on each call (the match clock restarts).
    pub fn install_fault_plan(&self, plan: FaultPlan) {
        *self.shared.faults.lock().unwrap() =
            Some(Arc::new(FaultState::new(plan)));
    }

    /// Remove any installed fault plan (its counters die with it).
    pub fn clear_fault_plan(&self) {
        *self.shared.faults.lock().unwrap() = None;
    }

    /// The installed plan's runtime state, if any — the ABFT verifier
    /// consults it to model stuck-at faults during recomputes.
    pub fn fault_state(&self) -> Option<Arc<FaultState>> {
        self.shared.fault_state()
    }

    /// Arm (or disarm, with `None`) the pool watchdog: the `*_checked`
    /// waits give up with a typed [`GemmError::Timeout`] when a job's
    /// latch is not set within this bound, instead of blocking forever
    /// on a wedged worker.  Sub-millisecond durations round up to 1 ms.
    pub fn set_watchdog(&self, timeout: Option<Duration>) {
        let ms = timeout.map_or(0, |d| (d.as_millis() as u64).max(1));
        self.shared.watchdog_ms.store(ms, Ordering::Relaxed);
    }

    /// Blocking `C = A B` on the pool: the drop-in replacement for
    /// [`crate::algo::tiled_matmul_parallel`], generic over the storage
    /// [`Element`].  The calling thread helps execute its own job while
    /// it waits.
    pub fn gemm<E: Element>(
        &self,
        a: &Mat<E>,
        b: &Mat<E>,
        algo: Algo,
        shape: TileShape,
    ) -> Mat<E::Acc> {
        let mut c = Mat::zeros(a.rows, b.cols);
        self.gemm_into(a, b, None, &mut c, algo, shape);
        c
    }

    /// Blocking `C = A B` into a caller-owned output buffer — the
    /// serving path ([`crate::coordinator::InferenceSession`]) reuses
    /// preallocated inter-layer activation matrices across batches, so
    /// steady state allocates nothing per request.  `c` is resized (a
    /// no-op when the geometry repeats) and fully overwritten.
    ///
    /// `y` optionally supplies the precomputed offline FFIP weight
    /// transform `y_from_b(b, shape.y)` (§3.3) in its native
    /// [`Element::Y`] storage; it must match `b`'s dimensions and is
    /// only meaningful for [`Algo::Ffip`].
    pub fn gemm_into<E: Element>(
        &self,
        a: &Mat<E>,
        b: &Mat<E>,
        y: Option<&Mat<E::Y>>,
        c: &mut Mat<E::Acc>,
        algo: Algo,
        shape: TileShape,
    ) {
        if let Some(ym) = y {
            assert_eq!(
                (ym.rows, ym.cols),
                (b.rows, b.cols),
                "offline y must match B's dimensions"
            );
            assert_eq!(
                algo,
                Algo::Ffip,
                "offline y terms only apply to FFIP"
            );
        }
        let job = self.enqueue(a, b, y, c, algo, shape);
        // Nothing on this path can unwind before the latch is observed
        // (run_job catches item panics), so the borrowed pointers stay
        // live for as long as workers can see them.
        help_and_wait(&self.shared, &job);
    }

    /// [`GemmPool::gemm_into`] for the serving path: an item panic
    /// returns a typed [`GemmError::Poisoned`] instead of re-raising,
    /// and an armed watchdog reports [`GemmError::Timeout`].  Because
    /// this path *borrows* its buffers, a timeout cannot abandon the
    /// job — the call re-blocks until the job truly finishes (sound:
    /// the pointers stay live) and only then reports the missed
    /// deadline, so a bounded stall is detected promptly while a
    /// truly-dead worker still needs the owned
    /// [`PendingGemm::wait_checked`] path to hand control back.
    pub fn gemm_into_checked<E: Element>(
        &self,
        a: &Mat<E>,
        b: &Mat<E>,
        y: Option<&Mat<E::Y>>,
        c: &mut Mat<E::Acc>,
        algo: Algo,
        shape: TileShape,
    ) -> Result<(), GemmError> {
        if let Some(ym) = y {
            assert_eq!(
                (ym.rows, ym.cols),
                (b.rows, b.cols),
                "offline y must match B's dimensions"
            );
            assert_eq!(algo, Algo::Ffip, "offline y terms only apply to FFIP");
        }
        let job = self.enqueue(a, b, y, c, algo, shape);
        let res = help_and_wait_checked(&self.shared, &job);
        if let Err(GemmError::Timeout { .. }) = res {
            // Liveness: the borrowed A/B/y/C may still be referenced by
            // the wedged worker.  Restore safety before returning, then
            // report the deadline violation (poison, if any, wins).
            job.wait_finished_checked()?;
        }
        res
    }

    /// Asynchronous submit: takes ownership of the activation matrix and
    /// a shared handle to the (typically weight) matrix, so the returned
    /// [`PendingGemm`] keeps every buffer alive however it is used (or
    /// leaked).  The sequential serving sessions use
    /// [`GemmPool::gemm_into`]; this is for callers that overlap GEMMs
    /// with other work — the pipelined serving executor stages the next
    /// layer's operand while a submitted job drains.
    pub fn submit<E: Element>(
        &self,
        a: Mat<E>,
        b: Arc<Mat<E>>,
        algo: Algo,
        shape: TileShape,
    ) -> PendingGemm<E> {
        self.submit_y(a, b, None, algo, shape)
    }

    /// [`GemmPool::submit`] with an optional precomputed offline FFIP
    /// weight transform `y = y_from_b(b, shape.y)` (§3.3) in its native
    /// [`Element::Y`] storage — the async analogue of
    /// [`GemmPool::gemm_into`]'s `y` parameter.  The returned handle
    /// keeps the shared `y` buffer alive for the job's lifetime.
    ///
    /// Allocates a fresh output per job; callers with a recyclable
    /// output ring use [`GemmPool::submit_into`].
    pub fn submit_y<E: Element>(
        &self,
        a: Mat<E>,
        b: Arc<Mat<E>>,
        y: Option<Arc<Mat<E::Y>>>,
        algo: Algo,
        shape: TileShape,
    ) -> PendingGemm<E> {
        self.submit_into(a, b, y, Mat::zeros(0, 0), algo, shape)
    }

    /// [`GemmPool::submit_y`] into a caller-owned output buffer — the
    /// async analogue of [`GemmPool::gemm_into`].  `c` is resized (a
    /// no-op when its capacity already fits, e.g. the product matrix
    /// of an earlier job handed back by
    /// [`PendingGemm::wait_with_inputs`] after its accumulators were
    /// consumed) and fully overwritten; together with the recycled A
    /// staging buffers this makes the pipelined serving executor
    /// allocation-free in steady state.
    pub fn submit_into<E: Element>(
        &self,
        a: Mat<E>,
        b: Arc<Mat<E>>,
        y: Option<Arc<Mat<E::Y>>>,
        mut c: Mat<E::Acc>,
        algo: Algo,
        shape: TileShape,
    ) -> PendingGemm<E> {
        if let Some(ym) = &y {
            assert_eq!(
                (ym.rows, ym.cols),
                (b.rows, b.cols),
                "offline y must match B's dimensions"
            );
            assert_eq!(
                algo,
                Algo::Ffip,
                "offline y terms only apply to FFIP"
            );
        }
        let job = self.enqueue(&a, &b, y.as_deref(), &mut c, algo, shape);
        self.shared.async_jobs.fetch_add(1, Ordering::Relaxed);
        PendingGemm {
            job,
            shared: self.shared.clone(),
            result: Some(c),
            settled: false,
            a: Some(a),
            b_shared: Some(b),
            b_owned: None,
            y_shared: y,
            y_owned: None,
        }
    }

    /// Asynchronous submit where **both operands are per-request
    /// activations** — attention's QKᵀ and AV GEMMs.  There is no
    /// weight matrix to share and no compile-time y transform: when
    /// `algo` is FFIP the caller computes `y = y_from_b_into(&b,
    /// shape.y, ..)` **online**, on the serving critical path, and
    /// hands the owned buffer in here.  The returned handle owns all
    /// four buffers; [`PendingGemm::wait_with_operands`] hands A, B and
    /// y back for recycling, so a session's steady state allocates
    /// nothing.
    ///
    /// Moving the `Mat`s into the handle is safe for the same reason
    /// the owned A of [`GemmPool::submit`] is: a `Vec`'s heap buffer
    /// does not move with the `Vec` value, so the job's raw pointers
    /// stay valid wherever the handle goes (liveness invariant, module
    /// docs).
    pub fn submit_online<E: Element>(
        &self,
        a: Mat<E>,
        b: Mat<E>,
        y: Option<Mat<E::Y>>,
        mut c: Mat<E::Acc>,
        algo: Algo,
        shape: TileShape,
    ) -> PendingGemm<E> {
        if let Some(ym) = &y {
            assert_eq!(
                (ym.rows, ym.cols),
                (b.rows, b.cols),
                "online y must match B's dimensions"
            );
            assert_eq!(algo, Algo::Ffip, "online y terms only apply to FFIP");
        }
        let job = self.enqueue(&a, &b, y.as_ref(), &mut c, algo, shape);
        self.shared.async_jobs.fetch_add(1, Ordering::Relaxed);
        PendingGemm {
            job,
            shared: self.shared.clone(),
            result: Some(c),
            settled: false,
            a: Some(a),
            b_shared: None,
            b_owned: Some(b),
            y_shared: None,
            y_owned: y,
        }
    }

    /// Validate, size the output matrix and build the job, then enqueue
    /// it.  Callers must ensure the A/B/y/C buffers outlive the job (see
    /// the module-level safety argument); note the returned job captures
    /// `c`'s heap buffer, which must not be reallocated until the job's
    /// latch is observed.
    fn enqueue<E: Element>(
        &self,
        a: &Mat<E>,
        b: &Mat<E>,
        y: Option<&Mat<E::Y>>,
        c: &mut Mat<E::Acc>,
        algo: Algo,
        shape: TileShape,
    ) -> Arc<Job> {
        assert_eq!(a.cols, b.rows, "inner dimensions must match");
        assert!(
            shape.x >= 1 && shape.y >= 1 && shape.tm >= 1,
            "degenerate tile shape {shape:?}"
        );
        if algo.is_fast() {
            assert_eq!(
                shape.x % 2,
                0,
                "{} requires an even tile depth x (pad with a zero row)",
                algo.name()
            );
        }
        kernels::assert_acc_fits::<E>(algo, shape.x, a.cols);
        let (m, k, n) = (a.rows, a.cols, b.cols);
        c.rows = m;
        c.cols = n;
        c.data.clear();
        c.data.resize(m * n, <E::Acc>::default());
        let (mt, _kt, nt) = shape.tiles(m, k, n);
        let total = mt * nt;
        let job = Arc::new(Job {
            a: a.data.as_ptr().cast(),
            b: b.data.as_ptr().cast(),
            y: y.map_or(std::ptr::null(), |ym| ym.data.as_ptr().cast()),
            c: c.data.as_mut_ptr().cast(),
            kind: E::KIND,
            id: kernels::next_job_id(),
            m,
            k,
            n,
            algo,
            shape,
            mt,
            total,
            next: AtomicUsize::new(0),
            done: AtomicUsize::new(0),
            poisoned: AtomicBool::new(false),
            finished: Mutex::new(total == 0),
            fin_cv: Condvar::new(),
        });
        self.shared.jobs_submitted.fetch_add(1, Ordering::Relaxed);
        if total > 0 {
            let mut q = self.shared.queue.lock().unwrap();
            prune_front(&mut q);
            let backlog = q.jobs.len() as u64;
            q.jobs.push_back(job.clone());
            q.peak = q.peak.max(q.jobs.len());
            drop(q);
            self.shared
                .enqueue_backlog_sum
                .fetch_add(backlog, Ordering::Relaxed);
            self.shared.enqueued_jobs.fetch_add(1, Ordering::Relaxed);
            self.shared.work_cv.notify_all();
        }
        job
    }

    /// Current counters.
    pub fn stats(&self) -> PoolStats {
        let mut q = self.shared.queue.lock().unwrap();
        prune_front(&mut q);
        PoolStats {
            workers: self.workers.len(),
            jobs: self.shared.jobs_submitted.load(Ordering::Relaxed),
            async_jobs: self.shared.async_jobs.load(Ordering::Relaxed),
            items: self.shared.items_executed.load(Ordering::Relaxed),
            queue_depth: q.jobs.len(),
            peak_queue_depth: q.peak,
            enqueue_backlog_sum: self
                .shared
                .enqueue_backlog_sum
                .load(Ordering::Relaxed),
            enqueued_jobs: self.shared.enqueued_jobs.load(Ordering::Relaxed),
            lanes_skipped: self
                .shared
                .lanes_skipped
                .load(Ordering::Relaxed),
            strips_built: self.shared.strips_built.load(Ordering::Relaxed),
            faults_injected: self
                .shared
                .fault_state()
                .map_or(0, |f| f.injected()),
        }
    }

    /// Jobs currently enqueued.
    pub fn queue_depth(&self) -> usize {
        let mut q = self.shared.queue.lock().unwrap();
        prune_front(&mut q);
        q.jobs.len()
    }

    /// Drain the queue and join every worker; returns the final
    /// counters (with `workers` reporting the pool's lifetime size,
    /// not the zero that remain after the join).
    pub fn shutdown(mut self) -> PoolStats {
        let workers = self.workers.len();
        self.join_workers();
        let mut s = self.stats();
        s.workers = workers;
        s
    }

    fn join_workers(&mut self) {
        // Set the flag under the queue lock so a worker between its
        // empty-check and its wait cannot miss the wakeup.
        {
            let _q = self.shared.queue.lock().unwrap();
            self.shared.shutdown.store(true, Ordering::Relaxed);
        }
        self.shared.work_cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for GemmPool {
    fn drop(&mut self) {
        self.join_workers();
    }
}

/// Handle to an in-flight pool GEMM submitted with
/// [`GemmPool::submit`].  Owns the input buffers for the job's
/// lifetime; [`wait`](PendingGemm::wait) joins the computation (helping
/// execute it) and returns the product, and merely dropping the handle
/// also joins, so results can be safely abandoned.
pub struct PendingGemm<E: Element = i64> {
    job: Arc<Job>,
    shared: Arc<Shared>,
    result: Option<Mat<E::Acc>>,
    settled: bool,
    a: Option<Mat<E>>,
    /// The B operand, held one of two ways for the job's lifetime:
    /// shared compiled weights ([`GemmPool::submit`]/
    /// [`GemmPool::submit_y`]) or an owned per-request activation
    /// ([`GemmPool::submit_online`]).  Exactly one is `Some`.  The
    /// shared slots are never read — they exist purely to keep the
    /// job's pointers live (module docs).
    #[allow(dead_code)]
    b_shared: Option<Arc<Mat<E>>>,
    b_owned: Option<Mat<E>>,
    /// Likewise for the FFIP y transform: offline (shared, computed at
    /// compile time) or online (owned, computed on the critical path).
    #[allow(dead_code)]
    y_shared: Option<Arc<Mat<E::Y>>>,
    y_owned: Option<Mat<E::Y>>,
}

impl<E: Element> PendingGemm<E> {
    /// Help execute the job, block until every item completed, and
    /// return the product.
    pub fn wait(mut self) -> Mat<E::Acc> {
        self.settle();
        self.result.take().expect("settled exactly once")
    }

    /// [`wait`](PendingGemm::wait), additionally handing back the owned
    /// A operand so callers can recycle the staging buffer (the
    /// pipelined serving executor reuses one A buffer pool across
    /// layers and batches, keeping steady state allocation-light).
    pub fn wait_with_inputs(mut self) -> (Mat<E::Acc>, Mat<E>) {
        self.settle();
        (
            self.result.take().expect("settled exactly once"),
            self.a.take().expect("settled exactly once"),
        )
    }

    /// [`wait`](PendingGemm::wait) for an online-operand job
    /// ([`GemmPool::submit_online`]): hands back the product *and* all
    /// owned operand buffers (A, B, optional online y) so the attention
    /// serving path can recycle every one of them — zero steady-state
    /// allocation across requests.  Panics if the job was submitted
    /// with a shared (weight) B.
    #[allow(clippy::type_complexity)]
    pub fn wait_with_operands(
        mut self,
    ) -> (Mat<E::Acc>, Mat<E>, Mat<E>, Option<Mat<E::Y>>) {
        self.settle();
        (
            self.result.take().expect("settled exactly once"),
            self.a.take().expect("settled exactly once"),
            self.b_owned
                .take()
                .expect("wait_with_operands needs an owned B (submit_online)"),
            self.y_owned.take(),
        )
    }

    /// [`wait`](PendingGemm::wait) for the serving path: poison is a
    /// typed [`GemmError::Poisoned`], and with an armed pool watchdog
    /// ([`GemmPool::set_watchdog`]) a wedged worker yields a typed
    /// [`GemmError::Timeout`] instead of blocking forever.  On timeout
    /// the handle **deliberately leaks** its job and operand buffers
    /// (the only sound way to hand control back while a wedged thread
    /// may still reach the job's pointers — the same contract as
    /// `mem::forget`-ing the handle, see the module liveness docs);
    /// the serving tier then sheds the request and the bounded leak is
    /// the price of not hanging.
    pub fn wait_checked(mut self) -> Result<Mat<E::Acc>, GemmError> {
        match self.settle_checked() {
            Ok(()) => Ok(self.result.take().expect("settled exactly once")),
            Err(e) => Err(self.abandon(e)),
        }
    }

    /// [`wait_checked`](PendingGemm::wait_checked) that also hands the
    /// owned A operand back on success (the async analogue of
    /// [`wait_with_inputs`](PendingGemm::wait_with_inputs)).
    pub fn wait_with_inputs_checked(
        mut self,
    ) -> Result<(Mat<E::Acc>, Mat<E>), GemmError> {
        match self.settle_checked() {
            Ok(()) => Ok((
                self.result.take().expect("settled exactly once"),
                self.a.take().expect("settled exactly once"),
            )),
            Err(e) => Err(self.abandon(e)),
        }
    }

    /// [`wait_checked`](PendingGemm::wait_checked) for an
    /// online-operand job (the async analogue of
    /// [`wait_with_operands`](PendingGemm::wait_with_operands)).
    #[allow(clippy::type_complexity)]
    pub fn wait_with_operands_checked(
        mut self,
    ) -> Result<(Mat<E::Acc>, Mat<E>, Mat<E>, Option<Mat<E::Y>>), GemmError>
    {
        match self.settle_checked() {
            Ok(()) => Ok((
                self.result.take().expect("settled exactly once"),
                self.a.take().expect("settled exactly once"),
                self.b_owned.take().expect(
                    "wait_with_operands needs an owned B (submit_online)",
                ),
                self.y_owned.take(),
            )),
            Err(e) => Err(self.abandon(e)),
        }
    }

    /// Dispose of a failed handle: a poisoned job is already complete
    /// (its buffers drop normally here); a timed-out job may still be
    /// executing, so the handle is leaked to keep its pointers live
    /// forever (liveness invariant) rather than blocked on.
    fn abandon(self, e: GemmError) -> GemmError {
        if matches!(e, GemmError::Timeout { .. }) {
            std::mem::forget(self);
        }
        e
    }

    fn settle(&mut self) {
        if self.settled {
            return;
        }
        // The submitter claims items too: a zero-worker pool completes,
        // and a busy pool gets a free extra hand for this job.
        help_and_wait(&self.shared, &self.job);
        self.settled = true;
    }

    /// [`settle`](PendingGemm::settle) with typed failure.  Leaves the
    /// handle unsettled on timeout (the job is still in flight), so
    /// `Drop` — if it ever ran — would still block soundly; the
    /// `*_checked` waiters leak instead (see
    /// [`abandon`](PendingGemm::abandon)).
    fn settle_checked(&mut self) -> Result<(), GemmError> {
        if self.settled {
            return Ok(());
        }
        let res = help_and_wait_checked(&self.shared, &self.job);
        if !matches!(res, Err(GemmError::Timeout { .. })) {
            self.settled = true;
        }
        res
    }
}

impl<E: Element> Drop for PendingGemm<E> {
    fn drop(&mut self) {
        // Uphold the liveness invariant even when the result is
        // abandoned: the owned buffers stay untouched until no thread
        // can still reach the job's pointers.  The wait is unbounded
        // (never the watchdog) — a timed-out "settle" here would free
        // buffers a worker may still write — and poison is swallowed:
        // an abandoned handle needs only completion, and serving
        // callers drop sibling handles while propagating a typed error
        // for the one that failed (re-raising during that return would
        // panic the session thread the typed path exists to protect).
        if self.settled {
            return;
        }
        if !self.shared.helping_disabled() {
            HELPER_SCRATCH
                .with(|s| run_job(&self.shared, &self.job, &mut s.borrow_mut()));
        }
        let _ = self.job.wait_finished_checked();
        self.settled = true;
    }
}

fn worker_loop(shared: &Shared) {
    let mut scratch = ScratchSet::default();
    loop {
        let job = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                prune_front(&mut q);
                if let Some(j) = q.jobs.front() {
                    break j.clone();
                }
                if shared.shutdown.load(Ordering::Relaxed) {
                    return;
                }
                q = shared.work_cv.wait(q).unwrap();
            }
        };
        run_job(shared, &job, &mut scratch);
    }
}

/// Execute one claimed item at the job's tagged element type.
///
/// # Safety
///
/// The job's pointers must be live (liveness invariant), `E` must match
/// `job.kind` (typing invariant — callers dispatch on the tag), and the
/// caller must own item `(it, jt)` via the claim cursor.
unsafe fn exec_item<E: Element>(
    job: &Job,
    it: usize,
    jt: usize,
    scratch: &mut Scratch<E>,
    faults: Option<&FaultState>,
) {
    kernels::compute_item::<E>(
        std::slice::from_raw_parts(job.a.cast::<E>(), job.m * job.k),
        std::slice::from_raw_parts(job.b.cast::<E>(), job.k * job.n),
        if job.y.is_null() {
            None
        } else {
            Some(std::slice::from_raw_parts(
                job.y.cast::<E::Y>(),
                job.k * job.n,
            ))
        },
        job.c.cast::<E::Acc>(),
        job.m,
        job.k,
        job.n,
        job.algo,
        job.shape,
        it,
        jt,
        job.id,
        scratch,
        faults,
    );
}

/// Inject an accumulator corruption: flip one seed-chosen bit of one
/// seed-chosen `Acc` element inside item `(it, jt)`'s output block.
/// Byte-level so it works at every tagged width without generic
/// arithmetic.
///
/// # Safety
///
/// Same contract as [`exec_item`]: the caller owns item `(it, jt)` and
/// `job.c` is live.
unsafe fn corrupt_item_acc(job: &Job, it: usize, jt: usize, f: &FaultState) {
    let i0 = it * job.shape.tm;
    let j0 = jt * job.shape.y;
    let rows = job.shape.tm.min(job.m - i0);
    let cols = job.shape.y.min(job.n - j0);
    let slot = f.pick(rows * cols);
    let (r, cc) = (slot / cols, slot % cols);
    let elem = (i0 + r) * job.n + (j0 + cc);
    let acc_bytes = match job.kind {
        ElemKind::I8 => 4, // i8 accumulates in i32
        _ => 8,            // everything wider in i64
    };
    let bit = (f.delta() as usize) % (acc_bytes * 8);
    let p = job.c.add(elem * acc_bytes + bit / 8);
    *p ^= 1u8 << (bit % 8);
}

/// Claim and execute items of `job` until its cursor is exhausted.
///
/// Never unwinds: an item panic (e.g. debug-build integer overflow in
/// the kernel) is caught, poisons the job, and still counts the item as
/// done — so waiters always wake (no deadlock), the liveness invariant
/// holds even across panics, and [`Job::wait_finished`] re-raises on
/// the waiting thread, matching where the serial path would panic.
fn run_job(shared: &Shared, job: &Job, scratch: &mut ScratchSet) {
    let faults = shared.fault_state();
    let faults = faults.as_deref();
    let mut claimed = false;
    loop {
        let idx = job.next.fetch_add(1, Ordering::Relaxed);
        if idx >= job.total {
            break;
        }
        claimed = true;
        // column-strip-major numbering: consecutive claims share the
        // N strip, so a worker's packed B/y strip stays cache-resident
        // across the M-bands it executes (see `engine/simd.rs`)
        let jt = idx / job.mt;
        let it = idx % job.mt;
        if let Some(f) = faults {
            // wedge this executor before the item runs (the waiter's
            // watchdog, not this sleep, bounds the observable delay)
            if f.fire(FaultKind::StallWorker) {
                std::thread::sleep(f.plan().stall);
            }
            // skip the item entirely: its output block keeps whatever
            // the recycled buffer held, which ABFT must catch
            if f.fire(FaultKind::DropItem) {
                shared.items_executed.fetch_add(1, Ordering::Relaxed);
                let done = job.done.fetch_add(1, Ordering::AcqRel) + 1;
                if done == job.total {
                    *job.finished.lock().unwrap() = true;
                    job.fin_cv.notify_all();
                }
                continue;
            }
        }
        let outcome =
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                if let Some(f) = faults {
                    if f.fire(FaultKind::PanicKernel) {
                        panic!("injected kernel panic (fault plan)");
                    }
                }
                // SAFETY: the job's pointers are live (liveness
                // invariant), this thread exclusively owns item
                // (it, jt) via the claim cursor, and the kind tag
                // recovers the exact submit-time element types; see
                // module docs.
                unsafe {
                    match job.kind {
                        ElemKind::I8 => exec_item::<i8>(
                            job,
                            it,
                            jt,
                            &mut scratch.s8,
                            faults,
                        ),
                        ElemKind::I16 => exec_item::<i16>(
                            job,
                            it,
                            jt,
                            &mut scratch.s16,
                            faults,
                        ),
                        ElemKind::I32 => exec_item::<i32>(
                            job,
                            it,
                            jt,
                            &mut scratch.s32,
                            faults,
                        ),
                        ElemKind::I64 => exec_item::<i64>(
                            job,
                            it,
                            jt,
                            &mut scratch.s64,
                            faults,
                        ),
                    }
                    if let Some(f) = faults {
                        if f.fire(FaultKind::AccCorrupt) {
                            // SAFETY: this thread still owns (it, jt)
                            corrupt_item_acc(job, it, jt, f);
                        }
                    }
                }
            }));
        if outcome.is_err() {
            job.poisoned.store(true, Ordering::Relaxed);
        }
        shared.items_executed.fetch_add(1, Ordering::Relaxed);
        // Release so the final increment publishes every item's writes;
        // Acquire so the finisher observes them before setting the latch.
        let done = job.done.fetch_add(1, Ordering::AcqRel) + 1;
        if done == job.total {
            *job.finished.lock().unwrap() = true;
            job.fin_cv.notify_all();
        }
    }
    if claimed {
        // flush the scratch's sparsity counters so `stats()` sees the
        // skipping a job's items performed (drained, not sampled —
        // helper scratches are thread-local and otherwise unreachable)
        let (lanes, strips) = scratch.take_counters();
        if lanes > 0 {
            shared.lanes_skipped.fetch_add(lanes, Ordering::Relaxed);
        }
        if strips > 0 {
            shared.strips_built.fetch_add(strips, Ordering::Relaxed);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::tiled_matmul;
    use crate::util::Rng;

    fn rand_mat(rng: &mut Rng, rows: usize, cols: usize) -> Mat<i64> {
        Mat::from_fn(rows, cols, |_, _| rng.fixed(8, true))
    }

    #[test]
    fn pool_matches_serial_for_all_algos() {
        let pool = GemmPool::new(2);
        let mut rng = Rng::new(0x9001);
        let shape = TileShape { x: 8, y: 8, tm: 8 };
        for &(m, k, n) in &[(17, 23, 19), (64, 64, 64), (1, 2, 1)] {
            let a = rand_mat(&mut rng, m, k);
            let b = rand_mat(&mut rng, k, n);
            for algo in Algo::ALL {
                assert_eq!(
                    pool.gemm(&a, &b, algo, shape),
                    tiled_matmul(&a, &b, algo, shape),
                    "{algo:?} {m}x{k}x{n}"
                );
            }
        }
    }

    /// One pool serves interleaved i8 / i16 / i64 jobs; narrow results
    /// equal the widened i64 oracle exactly.
    #[test]
    fn pool_serves_mixed_element_widths() {
        let pool = GemmPool::new(2);
        let mut rng = Rng::new(0x9003);
        let shape = TileShape { x: 8, y: 5, tm: 4 };
        for &(m, k, n) in &[(9usize, 14usize, 11usize), (16, 8, 20)] {
            let a8 = Mat::from_fn(m, k, |_, _| rng.fixed(8, true) as i8);
            let b8 = Mat::from_fn(k, n, |_, _| rng.fixed(8, true) as i8);
            let a16 =
                Mat::from_fn(m, k, |_, _| rng.fixed(16, true) as i16);
            let b16 =
                Mat::from_fn(k, n, |_, _| rng.fixed(16, true) as i16);
            for algo in Algo::ALL {
                let gold8 =
                    tiled_matmul(&a8.widen(), &b8.widen(), algo, shape);
                assert_eq!(
                    pool.gemm(&a8, &b8, algo, shape).widen(),
                    gold8,
                    "i8 {algo:?} {m}x{k}x{n}"
                );
                let gold16 =
                    tiled_matmul(&a16.widen(), &b16.widen(), algo, shape);
                assert_eq!(
                    pool.gemm(&a16, &b16, algo, shape).widen(),
                    gold16,
                    "i16 {algo:?} {m}x{k}x{n}"
                );
            }
            // interleave a wide job between narrow ones
            let a = a16.widen();
            let b = b16.widen();
            assert_eq!(
                pool.gemm(&a, &b, Algo::Ffip, shape),
                tiled_matmul(&a, &b, Algo::Ffip, shape)
            );
        }
    }

    /// The release-mode accumulator guard rejects narrow jobs whose
    /// worst case cannot fit the widened accumulator.
    #[test]
    #[should_panic(expected = "bit accumulator")]
    fn narrow_acc_guard_rejects_overdeep_k() {
        let pool = GemmPool::new(0);
        // K = 2^18 of full-scale i8: worst case needs > 31 magnitude
        // bits (see arith::gemm_acc_bits tests)
        let k = 1usize << 18;
        let a = Mat::from_fn(1, k, |_, _| 1i8);
        let b = Mat::from_fn(k, 1, |_, _| 1i8);
        let shape = TileShape { x: 64, y: 1, tm: 1 };
        let _ = pool.gemm(&a, &b, Algo::Baseline, shape);
    }

    #[test]
    fn zero_worker_pool_is_caller_driven() {
        let pool = GemmPool::new(0);
        let mut rng = Rng::new(3);
        let a = rand_mat(&mut rng, 9, 10);
        let b = rand_mat(&mut rng, 10, 7);
        let shape = TileShape { x: 4, y: 3, tm: 2 };
        assert_eq!(
            pool.gemm(&a, &b, Algo::Ffip, shape),
            tiled_matmul(&a, &b, Algo::Ffip, shape)
        );
        let s = pool.stats();
        assert_eq!(s.workers, 0);
        assert_eq!(s.jobs, 1);
    }

    #[test]
    fn stats_count_jobs_and_items() {
        let pool = GemmPool::new(1);
        let mut rng = Rng::new(5);
        let a = rand_mat(&mut rng, 16, 8);
        let b = rand_mat(&mut rng, 8, 12);
        let shape = TileShape { x: 8, y: 4, tm: 4 };
        // 4 M-bands x 3 N-tiles = 12 items per job
        for _ in 0..3 {
            pool.gemm(&a, &b, Algo::Baseline, shape);
        }
        let s = pool.shutdown();
        assert_eq!(s.jobs, 3);
        assert_eq!(s.items, 36);
        assert!(s.peak_queue_depth >= 1);
        assert_eq!(s.queue_depth, 0);
    }

    #[test]
    fn gemm_into_reuses_buffer_and_offline_y_is_exact() {
        let pool = GemmPool::new(2);
        let mut rng = Rng::new(0x9002);
        let shape = TileShape { x: 8, y: 5, tm: 4 };
        let mut c = Mat::zeros(1, 1); // deliberately wrong size: resized
        for &(m, k, n) in &[(9usize, 12usize, 11usize), (16, 8, 20)] {
            let a = rand_mat(&mut rng, m, k);
            let b = rand_mat(&mut rng, k, n);
            let gold = tiled_matmul(&a, &b, Algo::Ffip, shape);
            // inline differencing path
            pool.gemm_into(&a, &b, None, &mut c, Algo::Ffip, shape);
            assert_eq!(c, gold, "inline {m}x{k}x{n}");
            // precomputed offline y path (restart width = shape.y)
            let y = crate::algo::y_from_b(&b, shape.y);
            pool.gemm_into(&a, &b, Some(&y), &mut c, Algo::Ffip, shape);
            assert_eq!(c, gold, "offline-y {m}x{k}x{n}");
        }
    }

    /// The typed offline-y path on narrow storage: y streams as i16
    /// (one extra bit over the i8 operands, §4.4) and the pool result
    /// still equals the widened oracle.
    #[test]
    fn narrow_offline_y_gemm_into_is_exact() {
        let pool = GemmPool::new(1);
        let mut rng = Rng::new(0x9004);
        let shape = TileShape { x: 4, y: 3, tm: 2 };
        let a = Mat::from_fn(7, 8, |_, _| rng.fixed(8, true) as i8);
        let b = Mat::from_fn(8, 9, |_, _| rng.fixed(8, true) as i8);
        let y: Mat<i16> = crate::algo::y_from_b(&b, shape.y);
        let mut c: Mat<i32> = Mat::zeros(0, 0);
        pool.gemm_into(&a, &b, Some(&y), &mut c, Algo::Ffip, shape);
        let gold = tiled_matmul(&a.widen(), &b.widen(), Algo::Ffip, shape);
        assert_eq!(c.widen(), gold);
    }

    #[test]
    fn submit_owns_inputs_and_wait_returns_product() {
        let pool = GemmPool::new(2);
        let mut rng = Rng::new(7);
        let a = rand_mat(&mut rng, 32, 16);
        let b = Arc::new(rand_mat(&mut rng, 16, 32));
        let shape = TileShape { x: 8, y: 8, tm: 8 };
        let gold = tiled_matmul(&a, &b, Algo::Fip, shape);
        let pending = pool.submit(a.clone(), b.clone(), Algo::Fip, shape);
        assert_eq!(pending.wait(), gold);
        // dropped without wait(): must still join, not hang or race
        {
            let _abandoned =
                pool.submit(a.clone(), b.clone(), Algo::Ffip, shape);
        }
        // the pool remains usable afterwards
        assert_eq!(pool.gemm(&a, &b, Algo::Fip, shape), gold);
    }

    /// submit_y drives the offline-y FFIP path asynchronously (narrow
    /// storage), wait_with_inputs hands the staged A buffer back
    /// untouched, and the async-job counter tracks the traffic.
    #[test]
    fn submit_y_is_exact_and_returns_the_a_buffer() {
        let pool = GemmPool::new(1);
        let mut rng = Rng::new(0x9005);
        let shape = TileShape { x: 4, y: 3, tm: 2 };
        let a = Mat::from_fn(7, 8, |_, _| rng.fixed(8, true) as i8);
        let b = Arc::new(Mat::from_fn(8, 9, |_, _| rng.fixed(8, true) as i8));
        let y: Arc<Mat<i16>> =
            Arc::new(crate::algo::y_from_b(&b, shape.y));
        let gold = tiled_matmul(&a.widen(), &b.widen(), Algo::Ffip, shape);
        let pending =
            pool.submit_y(a.clone(), b.clone(), Some(y), Algo::Ffip, shape);
        let (c, a_back) = pending.wait_with_inputs();
        assert_eq!(c.widen(), gold);
        assert_eq!(a_back, a, "A operand returned bit-identical");
        let s = pool.stats();
        assert_eq!(s.async_jobs, 1);
        assert_eq!(s.jobs, 1);
        // synchronous gemm does not count as async traffic
        let _ = pool.gemm(&a, &b, Algo::Ffip, shape);
        assert_eq!(pool.stats().async_jobs, 1);
    }

    /// submit_into recycles a caller-owned output ring: the same C
    /// buffer cycles through consecutive async jobs without
    /// reallocation (capacity is preserved across wait → resubmit),
    /// and every product stays exact.
    #[test]
    fn submit_into_recycles_the_output_ring() {
        let pool = GemmPool::new(1);
        let mut rng = Rng::new(0x9006);
        let shape = TileShape { x: 4, y: 3, tm: 2 };
        let a = Mat::from_fn(6, 8, |_, _| rng.fixed(8, true) as i8);
        let b = Arc::new(Mat::from_fn(8, 9, |_, _| rng.fixed(8, true) as i8));
        let y: Arc<Mat<i16>> = Arc::new(crate::algo::y_from_b(&b, shape.y));
        let gold = tiled_matmul(&a.widen(), &b.widen(), Algo::Ffip, shape);
        let mut ring: Mat<i32> = Mat::zeros(0, 0);
        for round in 0..3 {
            let pending = pool.submit_into(
                a.clone(),
                b.clone(),
                Some(y.clone()),
                ring,
                Algo::Ffip,
                shape,
            );
            let (c, _a_back) = pending.wait_with_inputs();
            assert_eq!(c.widen(), gold, "round {round}");
            if round > 0 {
                // steady state: the recycled buffer already fits
                assert!(c.data.capacity() >= 6 * 9);
            }
            ring = c;
        }
        assert_eq!(pool.stats().async_jobs, 3);
    }

    /// submit_online owns both activation operands plus the online y
    /// transform, stays exact, and wait_with_operands hands every
    /// buffer back for recycling (no steady-state growth across jobs).
    #[test]
    fn submit_online_is_exact_and_recycles_all_operands() {
        let pool = GemmPool::new(1);
        let mut rng = Rng::new(0x9007);
        let shape = TileShape { x: 4, y: 3, tm: 2 };
        let mut bufs: Option<(Mat<i8>, Mat<i8>, Mat<i16>, Mat<i32>)> = None;
        for round in 0..3 {
            let (mut a, mut b, mut y, c) = bufs.take().unwrap_or_else(|| {
                (
                    Mat::zeros(0, 0),
                    Mat::zeros(0, 0),
                    Mat::zeros(0, 0),
                    Mat::zeros(0, 0),
                )
            });
            a.reset_to(6, 8);
            b.reset_to(8, 9);
            a.data
                .iter_mut()
                .chain(b.data.iter_mut())
                .for_each(|v| *v = rng.fixed(8, true) as i8);
            crate::algo::y_from_b_into(&b, shape.y, &mut y);
            let gold = tiled_matmul(&a.widen(), &b.widen(), Algo::Ffip, shape);
            let pending =
                pool.submit_online(a, b, Some(y), c, Algo::Ffip, shape);
            let (c, a, b, y) = pending.wait_with_operands();
            assert_eq!(c.widen(), gold, "round {round}");
            bufs = Some((a, b, y.expect("online y handed back"), c));
        }
        assert_eq!(pool.stats().async_jobs, 3);
        // a shared-weight submit has no owned B to hand back
        let (a, b, _, _) = bufs.unwrap();
        let p = pool.submit(a, Arc::new(b), Algo::Baseline, shape);
        let _ = p.wait();
    }

    /// An injected kernel panic becomes a typed [`GemmError::Poisoned`]
    /// on the checked path (the legacy path still re-raises), and
    /// clearing the plan restores clean execution.
    #[test]
    fn injected_panic_is_a_typed_error_on_the_checked_path() {
        let pool = GemmPool::new(0);
        let mut rng = Rng::new(0x9010);
        let a = rand_mat(&mut rng, 8, 8);
        let b = rand_mat(&mut rng, 8, 8);
        let shape = TileShape { x: 4, y: 4, tm: 4 };
        pool.install_fault_plan(FaultPlan::new(FaultKind::PanicKernel));
        let mut c = Mat::zeros(0, 0);
        assert_eq!(
            pool.gemm_into_checked(&a, &b, None, &mut c, Algo::Ffip, shape),
            Err(GemmError::Poisoned)
        );
        assert_eq!(pool.stats().faults_injected, 1);
        pool.clear_fault_plan();
        pool.gemm_into_checked(&a, &b, None, &mut c, Algo::Ffip, shape)
            .expect("clean after the plan is cleared");
        assert_eq!(c, tiled_matmul(&a, &b, Algo::Ffip, shape));
    }

    /// A dropped item leaves a visibly wrong (stale-zero) output block
    /// and counts as an injection — the raw corruption ABFT must catch.
    #[test]
    fn dropped_item_corrupts_the_output_and_is_counted() {
        let pool = GemmPool::new(0);
        let mut rng = Rng::new(0x9011);
        let a = Mat::from_fn(8, 8, |_, _| rng.fixed(8, true).max(1));
        let b = Mat::from_fn(8, 8, |_, _| rng.fixed(8, true).max(1));
        let shape = TileShape { x: 4, y: 4, tm: 4 };
        let gold = tiled_matmul(&a, &b, Algo::Baseline, shape);
        pool.install_fault_plan(FaultPlan::new(FaultKind::DropItem));
        let mut c = Mat::zeros(0, 0);
        pool.gemm_into(&a, &b, None, &mut c, Algo::Baseline, shape);
        assert_ne!(c, gold, "the dropped item's block stays stale");
        assert_eq!(pool.stats().faults_injected, 1);
    }

    /// A wedged worker resolves via the watchdog as a typed
    /// [`GemmError::Timeout`] instead of an infinite block, and the
    /// pool stays usable afterwards.
    #[test]
    fn watchdog_turns_a_wedged_worker_into_a_typed_timeout() {
        let pool = GemmPool::new(1);
        let mut rng = Rng::new(0x9012);
        let a = rand_mat(&mut rng, 8, 8);
        let b = Arc::new(rand_mat(&mut rng, 8, 8));
        let shape = TileShape { x: 4, y: 4, tm: 4 };
        pool.install_fault_plan(
            FaultPlan::new(FaultKind::StallWorker)
                .with_stall(Duration::from_millis(400)),
        );
        pool.set_watchdog(Some(Duration::from_millis(30)));
        let pending = pool.submit(a.clone(), b.clone(), Algo::Fip, shape);
        match pending.wait_checked() {
            Err(GemmError::Timeout { waited }) => {
                assert!(waited >= Duration::from_millis(30));
            }
            other => panic!("expected a watchdog timeout, got {other:?}"),
        }
        assert_eq!(pool.stats().faults_injected, 1);
        // the stall is bounded, so the pool drains and serves again
        pool.clear_fault_plan();
        pool.set_watchdog(None);
        assert_eq!(
            pool.gemm(&a, &b, Algo::Fip, shape),
            tiled_matmul(&a, &b, Algo::Fip, shape)
        );
    }

    #[test]
    fn overlapping_submissions_complete() {
        let pool = GemmPool::new(2);
        let mut rng = Rng::new(9);
        let a = rand_mat(&mut rng, 24, 16);
        let b = Arc::new(rand_mat(&mut rng, 16, 24));
        let shape = TileShape { x: 8, y: 8, tm: 8 };
        let p1 = pool.submit(a.clone(), b.clone(), Algo::Baseline, shape);
        let p2 = pool.submit(a.clone(), b.clone(), Algo::Ffip, shape);
        let gold = tiled_matmul(&a, &b, Algo::Baseline, shape);
        assert_eq!(p1.wait(), gold);
        assert_eq!(p2.wait(), gold);
    }
}
