//! Lane-parallel item kernels: u64-packed SWAR on stable Rust, with
//! optional `std::simd` versions behind the `portable_simd` feature.
//!
//! ## Why SWAR, and why it is exact
//!
//! The paper's premise (Eqs. 7–9) is that the fast inner-product
//! algorithms trade half the multiplications for cheap additions.  On a
//! CPU reproduction the analogous lever is packing many narrow values
//! into each 64-bit ALU op: `i8` operands travel as **4 × 16-bit
//! lanes** per `u64` word, `i16` operands as **2 × 32-bit lanes**
//! (the descriptor lives on [`Element`]).  Everything the fast-path
//! inner loops hold per lane is provably lane-bounded:
//!
//! * operands widen from `w` bits into a `2w`-bit lane;
//! * FIP pair sums `a + b` span at most `w + 1` bits (Eq. 2);
//! * the FFIP g state telescopes — `g_j = a_swapped + Σ y = a_swapped +
//!   b_j` (Eqs. 8a–8c with Eq. 9's differences) — so it also spans at
//!   most `w + 1` bits.  This is the same observation that lets the
//!   paper keep the in-PE adders narrow (§4.2), reproduced in software;
//! * offline y terms span `w + 1` bits (§4.4).
//!
//! Lane-wise addition therefore never overflows a lane, and the classic
//! carry-isolated SWAR add ([`swar_add`]) is *exact*, not approximate.
//! Products are widened out of the lanes ([`Element::swar_mul_pairs`])
//! into the [`Element::Acc`] domain, so every kernel here computes
//! exactly the same integer sums as the scalar kernels in `kernels.rs`
//! — bit-identical results, property-tested in this module and at the
//! pool/serving levels.
//!
//! ## The three vectorized loops
//!
//! * **Baseline (i8 only)** — the MAC row runs on *biased* operands:
//!   with `á = a + 2^{w−1}` and `b́ = b + 2^{w−1}` both non-negative and
//!   `< 2^w`, one `u64` multiply forms two 32-bit-lane products
//!   `á·b́` at once, and `Σ a·b = Σ á·b́ − 2^{w−1}(Σá + Σb́) + kv·2^{2w−2}`
//!   recovers the true dot product from per-row/per-column bias sums.
//!   Per-lane partials stay below `kv · 2^{2w} < 2^32` (enforced by
//!   [`BASELINE_SWAR_MAX_X`]), so lanes never carry into each other.
//!   16-bit operands cannot play this trick exactly (a single `á·b́`
//!   product already fills 32 bits), so `i16` baseline stays on the
//!   scalar MAC loop.
//! * **FIP** — the packed B strip is stored transposed *and
//!   pair-swapped* (lane `p` holds `b[p ^ 1]`), so a single [`swar_add`]
//!   against the packed A row forms both Eq. (2) pair sums, and one
//!   [`Element::swar_mul_pairs`] evaluates the products.
//! * **FFIP** — the packed y strip feeds the g recurrence: per output
//!   column, one [`swar_add`] advances all lanes of g (Eq. 8c) and one
//!   [`Element::swar_mul_pairs`] evaluates Eq. (7).  The g seed is the
//!   packed A row with adjacent lanes swapped ([`swap_pairs`], Eqs.
//!   8a/8b).
//!
//! ## The cache-resident B/y strip
//!
//! Tiles are packed once per **(job, N-strip)** into a per-worker cache
//! ([`Scratch`] keeps the packed strip plus the per-column correction
//! sums) and reused across all M-bands of that strip: the pool claims
//! items column-major (`jt` outermost, see `pool.rs`), so a worker
//! streams down the M dimension re-using its resident, already
//! transposed/packed/differenced B strip — the ROADMAP's tile-residency
//! scheduling.  With i8 weights a 64-deep packed column is 128 bytes;
//! a whole 1024×64 strip is 16 KiB and stays L1/L2-resident.
//!
//! Pathological deep-K × wide-y jobs whose full strip would exceed
//! [`STRIP_CACHE_MAX_WORDS`] fall back to **banded** packing: the strip
//! buffer holds one K band at a time (`Scratch::strip_kt` tracks which)
//! and repacks as the item's K loop advances, bounding the cache
//! footprint at one band instead of growing with K.  Results are
//! bit-identical either way — banding only changes *when* a band is
//! packed, never what it contains.
//!
//! ## Zero-column skipping
//!
//! Strip building additionally flags every all-zero B tile column
//! (`Scratch::strip_skip`), and the FIP/FFIP inner loops skip the
//! flagged columns outright.  The skip is exact: a zero column's pair
//! sums collapse to alpha and its beta term is zero, so its
//! contribution is identically zero.  For FFIP the g recurrence must
//! still telescope across the gap, so the build folds a skipped
//! column's y terms into the next kept column (offline-y path) or
//! simply leaves `prev` untouched (inline differencing) — either way
//! the stored value is `b_j − b_last_kept`, which spans the same
//! `w + 1` bits as any other y term and fits its `2w`-bit lane.
//! Winograd-transformed and pruned weights are
//! zero-rich, so this turns weight sparsity into elided lane-MACs;
//! the elision is counted per scratch and surfaced as
//! [`PoolStats::lanes_skipped`](super::PoolStats::lanes_skipped).
//! Baseline strips store *biased* operands (zero is a nonzero word),
//! so the baseline path stays dense.
//!
//! ## Edge tiles
//!
//! Ragged K tiles (`kv < x`), odd `cols` and short M bands (`rows <
//! tm`) need no special cases: lanes beyond `kv` pack as zeros, which
//! flow through pair sums and products exactly as the scalar kernels'
//! zero-padded tails do (property-tested with edge-biased geometry
//! below).

use super::kernels::{beta_into, Scratch};
use crate::algo::element::{AccElem, Element};
use crate::algo::{Algo, TileShape};
use crate::util::{ceil_div, round_up};

/// Depth bound for the biased baseline SWAR path: per-lane partial sums
/// `Σ_{r<kv} á·b́ < kv · 2^{2w}` must stay below the 32-bit lane, so
/// `kv ≤ x ≤ 2^14` keeps them under `2^30` for 8-bit operands.  Deeper
/// tiles (absurd for an MXU model) fall back to the scalar kernel.
pub(crate) const BASELINE_SWAR_MAX_X: usize = 1 << 14;

/// Word cap for the cache-resident FIP/FFIP packed strip.  A full strip
/// is `kt_n * y * wpt` u64 words; past this bound it no longer lives in
/// the fast cache levels (2^15 words = 256 KiB), so packing falls back
/// to **banded** mode: the strip buffer holds exactly one K band
/// (`y * wpt` words, tracked by `Scratch::strip_kt`) and is repacked as
/// the item's K loop advances.  Banding trades the cross-M-band strip
/// residency for bounded memory — the right trade for pathological
/// deep-K × wide-y jobs whose full strip would thrash anyway.  Every
/// geometry the tile planner emits sits far under the cap; the baseline
/// keeps its dense strip (its biased layout is `x * ceil(y/2)` words per
/// band and `covers` already bounds `x`).
pub(crate) const STRIP_CACHE_MAX_WORDS: usize = 1 << 15;

/// True when the SWAR path covers this element/algorithm/tile combination
/// (the `compute_item` dispatch predicate): any vectorized width for the
/// fast algorithms, 8-bit storage with a sane depth for the baseline MAC.
pub(crate) fn covers<E: Element>(algo: Algo, shape: TileShape) -> bool {
    if E::SWAR_LANES <= 1 {
        return false;
    }
    match algo {
        Algo::Baseline => E::BITS == 8 && shape.x <= BASELINE_SWAR_MAX_X,
        Algo::Fip | Algo::Ffip => true,
    }
}

/// Lane-wise wrapping addition of two packed words with carries
/// isolated per lane: mask the lane sign bits so low-bit carries cannot
/// cross a lane boundary, then restore each sign bit as the xor of the
/// operands' sign bits and the incoming carry.  Exact whenever the true
/// per-lane sums fit their lanes — guaranteed by the operand bounds in
/// the module docs.
#[inline(always)]
fn swar_add<E: Element>(x: u64, y: u64) -> u64 {
    ((x & !E::SWAR_HI).wrapping_add(y & !E::SWAR_HI)) ^ ((x ^ y) & E::SWAR_HI)
}

/// Swap adjacent even/odd lanes (`[l1, l0, l3, l2, ..]`) — the packed
/// form of the Eqs. (8a)/(8b) g seeding.
#[inline(always)]
fn swap_pairs<E: Element>(w: u64) -> u64 {
    ((w & E::SWAR_EVEN) << E::SWAR_LANE_BITS)
        | ((w >> E::SWAR_LANE_BITS) & E::SWAR_EVEN)
}

/// Size the packed buffers for this job geometry, invalidating the
/// strip cache when the geometry (and hence the layout) changed.
/// Returns whether the strip runs in banded mode (one resident K band;
/// see [`STRIP_CACHE_MAX_WORDS`]).
fn ensure_packed<E: Element>(
    s: &mut Scratch<E>,
    shape: TileShape,
    k: usize,
    algo: Algo,
) -> bool {
    let wpt = round_up(shape.x, E::SWAR_LANES) / E::SWAR_LANES;
    let kt_n = ceil_div(k, shape.x);
    let full_words = match algo {
        Algo::Baseline => kt_n * shape.x * ceil_div(shape.y, 2),
        Algo::Fip | Algo::Ffip => kt_n * shape.y * wpt,
    };
    let banded = matches!(algo, Algo::Fip | Algo::Ffip)
        && kt_n > 1
        && full_words > STRIP_CACHE_MAX_WORDS;
    let (strip_words, sum_len) = if banded {
        (shape.y * wpt, shape.y)
    } else {
        (full_words, kt_n * shape.y)
    };
    if s.strip.len() != strip_words
        || s.strip_sums.len() != sum_len
        || s.strip_skip.len() != sum_len
    {
        s.strip_job = 0;
    }
    s.pa.resize(wpt, 0);
    s.pg.resize(wpt, 0);
    s.pacc.resize(ceil_div(shape.y, 2), 0);
    s.strip.resize(strip_words, 0);
    s.strip_sums.resize(sum_len, <E::Acc>::default());
    s.strip_skip.resize(sum_len, 0);
    banded
}

/// The SWAR item kernel: same contract as
/// [`compute_item`](super::kernels::compute_item) (which dispatches
/// here when [`covers`] holds), bit-identical results.
///
/// `job` tags the GEMM this item belongs to (see
/// [`next_job_id`](super::kernels::next_job_id)); items of the same
/// `(job, jt)` N strip reuse the scratch's packed B/y strip instead of
/// re-packing it.  An offline `y_off` buffer must be
/// `y_from_b(b, shape.y)` — the §4.4 `w + 1`-bit bound on its values is
/// what keeps the g lanes exact (debug-asserted at packing).
///
/// # Safety
///
/// Same as `compute_item`: `c` valid for the whole `m * n` output, no
/// concurrent access to this item's `(it, jt)` block.
#[allow(clippy::too_many_arguments)]
pub(crate) unsafe fn compute_item_swar<E: Element>(
    a: &[E],
    b: &[E],
    y_off: Option<&[E::Y]>,
    c: *mut E::Acc,
    m: usize,
    k: usize,
    n: usize,
    algo: Algo,
    shape: TileShape,
    it: usize,
    jt: usize,
    job: u64,
    scratch: &mut Scratch<E>,
    faults: Option<&super::FaultState>,
) {
    debug_assert!(covers::<E>(algo, shape));
    let (x, yw, tm) = (shape.x, shape.y, shape.tm);
    let i0 = it * tm;
    let j0 = jt * yw;
    debug_assert!(i0 < m && j0 < n);
    let rows = tm.min(m - i0);
    let cols = yw.min(n - j0);
    let kt_n = ceil_div(k, x);
    let l = E::SWAR_LANES;
    let lb = E::SWAR_LANE_BITS;
    let wpt = round_up(x, l) / l;
    let zero = <E::Acc>::default();
    scratch.ensure_acc(shape);
    let banded = ensure_packed(scratch, shape, k, algo);
    let rebuild = scratch.strip_job != job || scratch.strip_jt != jt;
    if rebuild {
        // invalidate BEFORE touching the strip: a panic mid-rebuild
        // (debug overflow, out-of-contract y buffer) is caught by the
        // pool and must not leave half-written data tagged with the
        // previous (job, jt) — the tag is re-committed only after a
        // completed build (below)
        scratch.strip_job = 0;
    }
    scratch.acc[..rows * cols].fill(zero);

    match algo {
        Algo::Baseline => {
            // biased-operand SWAR MAC (module docs); 8-bit storage only
            let bias = 1i64 << (E::BITS - 1);
            let bias_acc = <E::Acc>::from_i32(bias as i32);
            let cw = ceil_div(yw, 2);
            let cw_used = ceil_div(cols, 2);
            if rebuild {
                for kt in 0..kt_n {
                    let k0 = kt * x;
                    let kv = x.min(k - k0);
                    let tbase = kt * x * cw;
                    scratch.strip[tbase..tbase + x * cw].fill(0);
                    let sums = &mut scratch.strip_sums
                        [kt * yw..kt * yw + cols];
                    sums.fill(zero);
                    for r in 0..kv {
                        let brow = &b
                            [(k0 + r) * n + j0..(k0 + r) * n + j0 + cols];
                        let words = &mut scratch.strip
                            [tbase + r * cw..tbase + r * cw + cw_used];
                        for (j, &bv) in brow.iter().enumerate() {
                            let biased = (bv.to_i64() + bias) as u64;
                            words[j / 2] |= biased << (32 * (j % 2) as u32);
                            sums[j] += <E::Acc>::from_i32(biased as i32);
                        }
                    }
                }
            }
            for kt in 0..kt_n {
                let k0 = kt * x;
                let kv = x.min(k - k0);
                let tbase = kt * x * cw;
                for i in 0..rows {
                    let ar =
                        &a[(i0 + i) * k + k0..(i0 + i) * k + k0 + kv];
                    let pacc = &mut scratch.pacc[..cw_used];
                    pacc.fill(0);
                    let mut sa = zero;
                    for (r, &av) in ar.iter().enumerate() {
                        let ab = (av.to_i64() + bias) as u64;
                        sa += <E::Acc>::from_i32(ab as i32);
                        let words = &scratch.strip
                            [tbase + r * cw..tbase + r * cw + cw_used];
                        // one u64 multiply forms two 32-bit-lane
                        // products á·b́ < 2^{2w}; per-lane partials stay
                        // < kv·2^{2w} < 2^32, so lanes never interact
                        for (pw, &bw) in pacc.iter_mut().zip(words) {
                            *pw += ab * bw;
                        }
                    }
                    let sums =
                        &scratch.strip_sums[kt * yw..kt * yw + cols];
                    let sa_bias = sa * bias_acc;
                    let kv_term = <E::Acc>::from_i32(kv as i32)
                        * <E::Acc>::from_i32((bias * bias) as i32);
                    let accrow =
                        &mut scratch.acc[i * cols..(i + 1) * cols];
                    for (j, cv) in accrow.iter_mut().enumerate() {
                        let lane = (scratch.pacc[j / 2]
                            >> (32 * (j % 2) as u32))
                            as u32;
                        // un-bias: Σ a·b = Σ á·b́ − 2^{w−1}(Σá + Σb́)
                        //                 + kv·2^{2w−2}
                        *cv += <E::Acc>::from_i32(lane as i32)
                            - sa_bias
                            - sums[j] * bias_acc
                            + kv_term;
                    }
                }
            }
        }
        Algo::Fip | Algo::Ffip => {
            let tile_words = yw * wpt;
            let mut skipped_cols = 0u64;
            for kt in 0..kt_n {
                let k0 = kt * x;
                let kv = x.min(k - k0);
                // banded mode holds exactly one K band at offset 0 and
                // repacks whenever `strip_kt` moves; full-strip mode
                // keeps every band resident at its own offset and packs
                // them all on the first item of the (job, jt) strip
                let (tbase, sbase) = if banded {
                    (0, 0)
                } else {
                    (kt * tile_words, kt * yw)
                };
                let stale = if banded {
                    scratch.strip_job != job
                        || scratch.strip_jt != jt
                        || scratch.strip_kt != kt
                } else {
                    rebuild
                };
                if stale {
                    if banded {
                        // same panic-safety rule as the full rebuild
                        // above: never leave a half-packed band tagged
                        // valid
                        scratch.strip_job = 0;
                    }
                    scratch.strip[tbase..tbase + cols * wpt].fill(0);
                    // mark all-zero B tile columns once per build: the
                    // inner loops skip their packed words entirely (a
                    // zero column's FIP/FFIP contribution is exactly
                    // zero — pair sums reduce to alpha and its beta is
                    // zero — so the skip changes no output bits)
                    let skips = &mut scratch.strip_skip
                        [sbase..sbase + cols];
                    for (j, sk) in skips.iter_mut().enumerate() {
                        let col = j0 + j;
                        *sk = (0..kv).all(|r| {
                            b[(k0 + r) * n + col].to_i64() == 0
                        }) as u8;
                    }
                    for r in 0..kv {
                        // FIP pre-swaps the lanes (lane p holds
                        // b[p ^ 1]) so one SWAR add against the packed
                        // A row forms both Eq. (2) pair sums; FFIP
                        // stores the y tile in natural lane order
                        let lane = match algo {
                            Algo::Fip => r ^ 1,
                            _ => r,
                        };
                        let (wi, sh) =
                            (lane / l, (lane % l) as u32 * lb);
                        match (algo, y_off) {
                            (Algo::Ffip, Some(yb)) => {
                                // fold skipped columns' y terms into
                                // the next kept column so the g
                                // recurrence (which now only visits
                                // kept columns) still telescopes to
                                // a_swapped + b_j; the folded value is
                                // b_j − b_last_kept, the same w + 1-bit
                                // bound as any y term
                                let yrow = &yb[(k0 + r) * n + j0
                                    ..(k0 + r) * n + j0 + cols];
                                let mut pend = zero;
                                for (j, &yv) in yrow.iter().enumerate()
                                {
                                    let yv = E::y_to_acc(yv);
                                    if skips[j] != 0 {
                                        pend += yv;
                                        continue;
                                    }
                                    scratch.strip
                                        [tbase + j * wpt + wi] |=
                                        E::swar_lane(yv + pend) << sh;
                                    pend = zero;
                                }
                            }
                            (Algo::Ffip, None) => {
                                // Eq. (9) with restart at the strip's
                                // first column, differenced inline
                                // over the *kept* columns (a skipped
                                // column leaves `prev` untouched, the
                                // differencing analogue of the y fold
                                // above)
                                let brow = &b[(k0 + r) * n + j0
                                    ..(k0 + r) * n + j0 + cols];
                                let mut prev = zero;
                                for (j, &bv) in brow.iter().enumerate()
                                {
                                    if skips[j] != 0 {
                                        continue;
                                    }
                                    let bv = bv.acc();
                                    scratch.strip
                                        [tbase + j * wpt + wi] |=
                                        E::swar_lane(bv - prev) << sh;
                                    prev = bv;
                                }
                            }
                            _ => {
                                let brow = &b[(k0 + r) * n + j0
                                    ..(k0 + r) * n + j0 + cols];
                                for (j, &bv) in brow.iter().enumerate()
                                {
                                    scratch.strip
                                        [tbase + j * wpt + wi] |=
                                        E::swar_lane(bv.acc()) << sh;
                                }
                            }
                        }
                    }
                    beta_into(
                        b,
                        k0,
                        kv,
                        n,
                        j0,
                        &mut scratch.strip_sums[sbase..sbase + cols],
                    );
                    if banded {
                        // a band is a build of its own (the strip-cache
                        // efficiency denominator must reflect the
                        // repacking the fallback performs), and its tag
                        // commits only after the completed pack
                        scratch.strips_built += 1;
                        scratch.strip_job = job;
                        scratch.strip_jt = jt;
                        scratch.strip_kt = kt;
                        if let Some(f) = faults {
                            if f.fire(super::FaultKind::StripBitFlip) {
                                f.corrupt_strip_word(&mut scratch.strip);
                            }
                        }
                    }
                }
                for i in 0..rows {
                    // pack the zero-padded widened A row fragment
                    let ar =
                        &a[(i0 + i) * k + k0..(i0 + i) * k + k0 + kv];
                    let pa = &mut scratch.pa[..wpt];
                    pa.fill(0);
                    for (r, &av) in ar.iter().enumerate() {
                        pa[r / l] |=
                            E::swar_lane(av.acc()) << ((r % l) as u32 * lb);
                    }
                    // Eq. (3): alpha from the packed A pairs
                    let mut alpha = zero;
                    for &aw in pa.iter() {
                        alpha += E::swar_mul_pairs(aw);
                    }
                    match algo {
                        Algo::Fip => {
                            for j in 0..cols {
                                // all-zero column: pair sums reduce to
                                // alpha and beta is zero, so the whole
                                // column of lane-MACs is elided
                                if scratch.strip_skip[sbase + j] != 0 {
                                    skipped_cols += 1;
                                    continue;
                                }
                                let bw = &scratch.strip[tbase + j * wpt
                                    ..tbase + (j + 1) * wpt];
                                let mut s = zero;
                                for (&aw, &bv) in pa.iter().zip(bw) {
                                    // Eq. (2): one SWAR add, one
                                    // pairwise widening product-sum
                                    s += E::swar_mul_pairs(
                                        swar_add::<E>(aw, bv),
                                    );
                                }
                                scratch.acc[i * cols + j] += s
                                    - alpha
                                    - scratch.strip_sums[sbase + j];
                            }
                        }
                        _ => {
                            // Eqs. (8a)/(8b): seed g with swapped pairs
                            let pg = &mut scratch.pg[..wpt];
                            for (gw, &aw) in pg.iter_mut().zip(pa.iter())
                            {
                                *gw = swap_pairs::<E>(aw);
                            }
                            for j in 0..cols {
                                // skipped column: g is not advanced —
                                // the strip build folded its y terms
                                // into the next kept column, so the
                                // recurrence stays exact
                                if scratch.strip_skip[sbase + j] != 0 {
                                    skipped_cols += 1;
                                    continue;
                                }
                                let yws = &scratch.strip[tbase + j * wpt
                                    ..tbase + (j + 1) * wpt];
                                let mut s = zero;
                                for (gw, &yv) in
                                    pg.iter_mut().zip(yws)
                                {
                                    // Eq. (8c) then Eq. (7)
                                    *gw = swar_add::<E>(*gw, yv);
                                    s += E::swar_mul_pairs(*gw);
                                }
                                scratch.acc[i * cols + j] += s
                                    - alpha
                                    - scratch.strip_sums[sbase + j];
                            }
                        }
                    }
                }
            }
            scratch.lanes_skipped +=
                skipped_cols * (wpt as u64) * (l as u64);
        }
    }
    // banded strips committed their tags (and counted their builds)
    // per band inside the K loop
    if rebuild && !banded {
        scratch.strips_built += 1;
        scratch.strip_job = job;
        scratch.strip_jt = jt;
        // fault injection (`engine/faults.rs`): flip a low-lane bit of
        // the freshly committed strip, so every later item that reads
        // this worker's cached strip computes from corrupted data —
        // exactly the silent-datapath fault ABFT must catch.  Injected
        // only after a completed build; the strip stays corrupt until
        // the next rebuild (transient plans fire once).
        if let Some(f) = faults {
            if f.fire(super::FaultKind::StripBitFlip) {
                f.corrupt_strip_word(&mut scratch.strip);
            }
        }
    }

    // SAFETY: forwarded caller contract (see function docs).
    unsafe {
        super::kernels::write_block(
            c,
            &scratch.acc[..rows * cols],
            n,
            i0,
            j0,
            rows,
            cols,
        );
    }
}

// ---------------------------------------------------------------------
// Scalar inner-loop hooks.  The scalar item kernel in `kernels.rs`
// routes its innermost loops through these so the `portable_simd`
// feature can upgrade them to explicit `std::simd` lanes without
// touching the (shared) tile-staging structure.  Without the feature
// they compile to exactly the historical scalar loops.
// ---------------------------------------------------------------------

/// Baseline MAC row: `acc[j] += av * b[j]` over one contiguous B row.
#[inline(always)]
pub(super) fn mac_row<E: Element>(
    av: E::Acc,
    brow: &[E],
    accrow: &mut [E::Acc],
) {
    #[cfg(feature = "portable_simd")]
    if portable::mac_row::<E>(av, brow, accrow) {
        return;
    }
    for (cv, &bv) in accrow.iter_mut().zip(brow) {
        *cv += av * bv.acc();
    }
}

/// `Σ_t vals[2t] · vals[2t+1]` — Eq. (3) alpha terms and Eq. (7)'s
/// pairwise products.  `vals.len()` must be even.
#[inline(always)]
pub(super) fn pair_product_sum<E: Element>(vals: &[E::Acc]) -> E::Acc {
    #[cfg(feature = "portable_simd")]
    if let Some(s) = portable::pair_product_sum::<E>(vals) {
        return s;
    }
    let mut s = <E::Acc>::default();
    for p in vals.chunks_exact(2) {
        s += p[0] * p[1];
    }
    s
}

/// One FIP output column (Eq. 2): `Σ_t (ar[2t] + bt[2t+1])(ar[2t+1] +
/// bt[2t])` over the zero-padded widened tile column.
#[inline(always)]
pub(super) fn fip_col<E: Element>(ar: &[E::Acc], btj: &[E::Acc]) -> E::Acc {
    #[cfg(feature = "portable_simd")]
    if let Some(s) = portable::fip_col::<E>(ar, btj) {
        return s;
    }
    let mut s = <E::Acc>::default();
    let mut p = 0;
    while p < ar.len() {
        s += (ar[p] + btj[p + 1]) * (ar[p + 1] + btj[p]);
        p += 2;
    }
    s
}

/// One FFIP output column: advance the g recurrence by this column's y
/// (Eq. 8c) and evaluate Eq. (7).
#[inline(always)]
pub(super) fn ffip_col<E: Element>(
    gs: &mut [E::Acc],
    yrow: &[E::Acc],
) -> E::Acc {
    #[cfg(feature = "portable_simd")]
    if let Some(s) = portable::ffip_col::<E>(gs, yrow) {
        return s;
    }
    let mut s = <E::Acc>::default();
    for (gp, yp) in gs.chunks_exact_mut(2).zip(yrow.chunks_exact(2)) {
        gp[0] += yp[0];
        gp[1] += yp[1];
        s += gp[0] * gp[1];
    }
    s
}

/// Explicit `std::simd` versions of the inner loops (nightly-only,
/// opt-in: the crate's always-on vector path is the stable SWAR kernel
/// above).  Each entry point dispatches on [`ElemKind`] — the same
/// 1:1 tag↔type invariant the engine's type-erased jobs rely on — and
/// returns "not handled" for the wide oracle widths, which keep the
/// scalar loops.
#[cfg(feature = "portable_simd")]
mod portable {
    use crate::algo::element::{AccElem, ElemKind, Element};
    use std::mem::size_of;
    use std::simd::num::SimdInt;
    use std::simd::{simd_swizzle, Simd};

    /// SAFETY precondition for both casts: the caller matched
    /// `E::KIND`, which identifies the concrete element/accumulator
    /// types (the engine-wide tag invariant; see `element.rs`).
    #[inline(always)]
    unsafe fn cast_slice<T, U>(s: &[T]) -> &[U] {
        debug_assert_eq!(size_of::<T>(), size_of::<U>());
        std::slice::from_raw_parts(s.as_ptr().cast(), s.len())
    }

    #[inline(always)]
    unsafe fn cast_slice_mut<T, U>(s: &mut [T]) -> &mut [U] {
        debug_assert_eq!(size_of::<T>(), size_of::<U>());
        std::slice::from_raw_parts_mut(s.as_mut_ptr().cast(), s.len())
    }

    pub(super) fn mac_row<E: Element>(
        av: E::Acc,
        brow: &[E],
        accrow: &mut [E::Acc],
    ) -> bool {
        match E::KIND {
            ElemKind::I8 => {
                // SAFETY: KIND == I8 ⟹ E == i8, E::Acc == i32
                let (b, acc) = unsafe {
                    (
                        cast_slice::<E, i8>(brow),
                        cast_slice_mut::<E::Acc, i32>(accrow),
                    )
                };
                mac_row_i8(av.to_i64() as i32, b, acc);
                true
            }
            ElemKind::I16 => {
                // SAFETY: KIND == I16 ⟹ E == i16, E::Acc == i64
                let (b, acc) = unsafe {
                    (
                        cast_slice::<E, i16>(brow),
                        cast_slice_mut::<E::Acc, i64>(accrow),
                    )
                };
                mac_row_i16(av.to_i64(), b, acc);
                true
            }
            _ => false,
        }
    }

    fn mac_row_i8(av: i32, brow: &[i8], accrow: &mut [i32]) {
        let n = brow.len() / 8 * 8;
        for (ac, bc) in accrow[..n]
            .chunks_exact_mut(8)
            .zip(brow[..n].chunks_exact(8))
        {
            let bv = Simd::<i8, 8>::from_slice(bc).cast::<i32>();
            let cv = Simd::<i32, 8>::from_slice(ac)
                + Simd::splat(av) * bv;
            cv.copy_to_slice(ac);
        }
        for (cv, &bv) in accrow[n..].iter_mut().zip(&brow[n..]) {
            *cv += av * i32::from(bv);
        }
    }

    fn mac_row_i16(av: i64, brow: &[i16], accrow: &mut [i64]) {
        let n = brow.len() / 4 * 4;
        for (ac, bc) in accrow[..n]
            .chunks_exact_mut(4)
            .zip(brow[..n].chunks_exact(4))
        {
            let bv = Simd::<i16, 4>::from_slice(bc).cast::<i64>();
            let cv = Simd::<i64, 4>::from_slice(ac)
                + Simd::splat(av) * bv;
            cv.copy_to_slice(ac);
        }
        for (cv, &bv) in accrow[n..].iter_mut().zip(&brow[n..]) {
            *cv += av * i64::from(bv);
        }
    }

    pub(super) fn pair_product_sum<E: Element>(
        vals: &[E::Acc],
    ) -> Option<E::Acc> {
        match E::KIND {
            ElemKind::I8 => {
                // SAFETY: KIND == I8 ⟹ E::Acc == i32
                let v = unsafe { cast_slice::<E::Acc, i32>(vals) };
                Some(acc_from_i64::<E>(i64::from(pair_sum_i32(v))))
            }
            ElemKind::I16 => {
                // SAFETY: KIND == I16 ⟹ E::Acc == i64
                let v = unsafe { cast_slice::<E::Acc, i64>(vals) };
                Some(acc_from_i64::<E>(pair_sum_i64(v)))
            }
            _ => None,
        }
    }

    /// Exact round-trip from a concrete kernel result back into the
    /// generic accumulator (identity after monomorphization: the value
    /// came out of an `E::Acc`-typed computation).
    #[inline(always)]
    fn acc_from_i64<E: Element>(v: i64) -> E::Acc {
        <E::Acc>::from_i64(v)
    }

    fn pair_sum_i32(vals: &[i32]) -> i32 {
        let mut acc = Simd::<i32, 4>::splat(0);
        let n = vals.len() / 8 * 8;
        for ch in vals[..n].chunks_exact(8) {
            let v = Simd::<i32, 8>::from_slice(ch);
            acc += simd_swizzle!(v, [0, 2, 4, 6])
                * simd_swizzle!(v, [1, 3, 5, 7]);
        }
        let mut s = acc.reduce_sum();
        let mut p = n;
        while p < vals.len() {
            s += vals[p] * vals[p + 1];
            p += 2;
        }
        s
    }

    fn pair_sum_i64(vals: &[i64]) -> i64 {
        let mut acc = Simd::<i64, 2>::splat(0);
        let n = vals.len() / 4 * 4;
        for ch in vals[..n].chunks_exact(4) {
            let v = Simd::<i64, 4>::from_slice(ch);
            acc +=
                simd_swizzle!(v, [0, 2]) * simd_swizzle!(v, [1, 3]);
        }
        let mut s = acc.reduce_sum();
        let mut p = n;
        while p < vals.len() {
            s += vals[p] * vals[p + 1];
            p += 2;
        }
        s
    }

    pub(super) fn fip_col<E: Element>(
        ar: &[E::Acc],
        btj: &[E::Acc],
    ) -> Option<E::Acc> {
        match E::KIND {
            ElemKind::I8 => {
                // SAFETY: KIND == I8 ⟹ E::Acc == i32
                let (a, b) = unsafe {
                    (
                        cast_slice::<E::Acc, i32>(ar),
                        cast_slice::<E::Acc, i32>(btj),
                    )
                };
                Some(acc_from_i64::<E>(i64::from(fip_col_i32(a, b))))
            }
            ElemKind::I16 => {
                // SAFETY: KIND == I16 ⟹ E::Acc == i64
                let (a, b) = unsafe {
                    (
                        cast_slice::<E::Acc, i64>(ar),
                        cast_slice::<E::Acc, i64>(btj),
                    )
                };
                Some(acc_from_i64::<E>(fip_col_i64(a, b)))
            }
            _ => None,
        }
    }

    fn fip_col_i32(ar: &[i32], btj: &[i32]) -> i32 {
        let mut acc = Simd::<i32, 4>::splat(0);
        let n = ar.len() / 8 * 8;
        for (ac, bc) in
            ar[..n].chunks_exact(8).zip(btj[..n].chunks_exact(8))
        {
            let av = Simd::<i32, 8>::from_slice(ac);
            let bv = Simd::<i32, 8>::from_slice(bc);
            let u = av + simd_swizzle!(bv, [1, 0, 3, 2, 5, 4, 7, 6]);
            acc += simd_swizzle!(u, [0, 2, 4, 6])
                * simd_swizzle!(u, [1, 3, 5, 7]);
        }
        let mut s = acc.reduce_sum();
        let mut p = n;
        while p < ar.len() {
            s += (ar[p] + btj[p + 1]) * (ar[p + 1] + btj[p]);
            p += 2;
        }
        s
    }

    fn fip_col_i64(ar: &[i64], btj: &[i64]) -> i64 {
        let mut acc = Simd::<i64, 2>::splat(0);
        let n = ar.len() / 4 * 4;
        for (ac, bc) in
            ar[..n].chunks_exact(4).zip(btj[..n].chunks_exact(4))
        {
            let av = Simd::<i64, 4>::from_slice(ac);
            let bv = Simd::<i64, 4>::from_slice(bc);
            let u = av + simd_swizzle!(bv, [1, 0, 3, 2]);
            acc +=
                simd_swizzle!(u, [0, 2]) * simd_swizzle!(u, [1, 3]);
        }
        let mut s = acc.reduce_sum();
        let mut p = n;
        while p < ar.len() {
            s += (ar[p] + btj[p + 1]) * (ar[p + 1] + btj[p]);
            p += 2;
        }
        s
    }

    pub(super) fn ffip_col<E: Element>(
        gs: &mut [E::Acc],
        yrow: &[E::Acc],
    ) -> Option<E::Acc> {
        match E::KIND {
            ElemKind::I8 => {
                // SAFETY: KIND == I8 ⟹ E::Acc == i32
                let (g, y) = unsafe {
                    (
                        cast_slice_mut::<E::Acc, i32>(gs),
                        cast_slice::<E::Acc, i32>(yrow),
                    )
                };
                Some(acc_from_i64::<E>(i64::from(ffip_col_i32(g, y))))
            }
            ElemKind::I16 => {
                // SAFETY: KIND == I16 ⟹ E::Acc == i64
                let (g, y) = unsafe {
                    (
                        cast_slice_mut::<E::Acc, i64>(gs),
                        cast_slice::<E::Acc, i64>(yrow),
                    )
                };
                Some(acc_from_i64::<E>(ffip_col_i64(g, y)))
            }
            _ => None,
        }
    }

    fn ffip_col_i32(gs: &mut [i32], yrow: &[i32]) -> i32 {
        let mut acc = Simd::<i32, 4>::splat(0);
        let n = gs.len() / 8 * 8;
        for (gc, yc) in gs[..n]
            .chunks_exact_mut(8)
            .zip(yrow[..n].chunks_exact(8))
        {
            let g = Simd::<i32, 8>::from_slice(gc)
                + Simd::<i32, 8>::from_slice(yc);
            g.copy_to_slice(gc);
            acc += simd_swizzle!(g, [0, 2, 4, 6])
                * simd_swizzle!(g, [1, 3, 5, 7]);
        }
        let mut s = acc.reduce_sum();
        let mut p = n;
        while p < gs.len() {
            gs[p] += yrow[p];
            gs[p + 1] += yrow[p + 1];
            s += gs[p] * gs[p + 1];
            p += 2;
        }
        s
    }

    fn ffip_col_i64(gs: &mut [i64], yrow: &[i64]) -> i64 {
        let mut acc = Simd::<i64, 2>::splat(0);
        let n = gs.len() / 4 * 4;
        for (gc, yc) in gs[..n]
            .chunks_exact_mut(4)
            .zip(yrow[..n].chunks_exact(4))
        {
            let g = Simd::<i64, 4>::from_slice(gc)
                + Simd::<i64, 4>::from_slice(yc);
            g.copy_to_slice(gc);
            acc +=
                simd_swizzle!(g, [0, 2]) * simd_swizzle!(g, [1, 3]);
        }
        let mut s = acc.reduce_sum();
        let mut p = n;
        while p < gs.len() {
            gs[p] += yrow[p];
            gs[p + 1] += yrow[p + 1];
            s += gs[p] * gs[p + 1];
            p += 2;
        }
        s
    }
}
