//! Shared glue for the examples and the `serve` subcommand: a
//! [`crate::coordinator::Backend`] that drives the AOT-compiled MiniCNN
//! artifact through PJRT — the full L3->runtime->artifact request path
//! with Python nowhere in sight.

use crate::coordinator::{Backend, Tensor, TensorView};
use crate::runtime::{Input, Runtime};
use anyhow::{Context, Result};
use std::path::Path;
use std::sync::Arc;

/// PJRT-backed MiniCNN inference backend (artifact `mini_cnn_b4`:
/// int32[4,16,16,4] -> float32[4,10]).
pub struct MiniCnnBackend {
    exe: Arc<crate::runtime::Executable>,
    batch: usize,
    row: usize,
    out_row: usize,
}

impl MiniCnnBackend {
    pub fn new(artifacts: &Path) -> Result<Self> {
        let mut rt = Runtime::new(artifacts)?;
        let exe = rt.load("mini_cnn_b4").context("mini_cnn_b4 artifact")?;
        let in_spec = &exe.spec.inputs[0];
        let out_spec = &exe.spec.outputs[0];
        let batch = in_spec.shape[0];
        let row = in_spec.numel() / batch;
        let out_row = out_spec.numel() / batch;
        Ok(MiniCnnBackend { exe, batch, row, out_row })
    }

    pub fn batch_size(&self) -> usize {
        self.batch
    }

    pub fn row_len(&self) -> usize {
        self.row
    }
}

impl Backend for MiniCnnBackend {
    fn input_len(&self) -> usize {
        self.row
    }

    fn output_len(&self) -> usize {
        self.out_row
    }

    fn batch(&self) -> usize {
        self.batch
    }

    fn infer(&mut self, batch: TensorView<'_>) -> Result<Tensor> {
        let out = self.exe.run_f32(&[Input::I32(batch.data.to_vec())])?;
        Ok(Tensor::new(self.batch, self.out_row, out))
    }
}
