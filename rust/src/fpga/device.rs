//! FPGA device inventories and DSP packing rules (paper §6.2.1).
//!
//! `#multipliers` is `#DSPs * 2` on Intel/Altera (two 18x19 multipliers
//! per DSP block) and `#DSPs * 1` on AMD/Xilinx (one 18x27) — the
//! normalization the paper uses to compare across vendors (Eq. 31b/c
//! discussion).

/// DSP block architecture of a device family.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DspArch {
    /// Intel/Altera: two 18x19-bit multipliers per DSP block.
    Intel2x18x19,
    /// AMD/Xilinx: one 18x27-bit multiplier per DSP slice.
    Amd1x18x27,
}

impl DspArch {
    /// Fixed-point multipliers per DSP block.
    pub fn mults_per_dsp(&self) -> usize {
        match self {
            DspArch::Intel2x18x19 => 2,
            DspArch::Amd1x18x27 => 1,
        }
    }
}

/// One FPGA device's resource inventory.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Device {
    pub name: &'static str,
    pub alms: u64,
    /// dedicated flip-flops (Intel: 4 per ALM)
    pub registers: u64,
    /// M20K (Intel) / 36Kb BRAM (AMD) blocks
    pub memories: u64,
    pub dsps: u64,
    pub dsp_arch: DspArch,
}

impl Device {
    /// Arria 10 GX 1150 — the comparison device of Tables 1-3.
    pub const fn arria10_gx1150() -> Device {
        Device {
            name: "Arria 10 GX 1150",
            alms: 427_200,
            registers: 1_708_800,
            memories: 2_713,
            dsps: 1_518,
            dsp_arch: DspArch::Intel2x18x19,
        }
    }

    /// Arria 10 SX 660 — the SoC dev-kit device of Fig. 9 (§6: fewer
    /// soft-logic resources, more DSPs than the GX 1150).
    pub const fn arria10_sx660() -> Device {
        Device {
            name: "Arria 10 SX 660",
            alms: 251_680,
            registers: 1_006_720,
            memories: 2_131,
            dsps: 1_687,
            dsp_arch: DspArch::Intel2x18x19,
        }
    }

    pub fn by_name(name: &str) -> Option<Device> {
        match name.to_ascii_lowercase().as_str() {
            "gx1150" | "arria10-gx1150" => Some(Self::arria10_gx1150()),
            "sx660" | "arria10-sx660" => Some(Self::arria10_sx660()),
            _ => None,
        }
    }

    /// Total fixed-point multipliers the device can instantiate.
    pub fn total_multipliers(&self) -> u64 {
        self.dsps * self.dsp_arch.mults_per_dsp() as u64
    }

    /// DSP blocks needed for `mults` multipliers of width <= 18x19.
    pub fn dsps_for_mults(&self, mults: u64) -> u64 {
        mults.div_ceil(self.dsp_arch.mults_per_dsp() as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn packing_rules() {
        let gx = Device::arria10_gx1150();
        assert_eq!(gx.total_multipliers(), 3036);
        assert_eq!(gx.dsps_for_mults(2144), 1072); // FFIP 64x64 (Table 1)
        assert_eq!(gx.dsps_for_mults(2145), 1073);
    }

    #[test]
    fn fig9_device_bounds() {
        // §6.1: baseline stops at 56x56, (F)FIP reaches 80x80 on SX660.
        let sx = Device::arria10_sx660();
        let baseline_mults = |s: u64| s * s + s; // + Y rescale
        let ffip_mults = |s: u64| (s / 2) * (s + 1) + s;
        assert!(sx.dsps_for_mults(baseline_mults(56)) <= sx.dsps);
        assert!(sx.dsps_for_mults(baseline_mults(64)) > sx.dsps);
        assert!(sx.dsps_for_mults(ffip_mults(80)) <= sx.dsps);
        assert!(sx.dsps_for_mults(ffip_mults(88)) > sx.dsps);
    }
}
