//! Clock-frequency (fmax) model (paper §6.1, Fig. 9).
//!
//! `period = t_critical_path(PE kind, bitwidths) + t_routing(fill, ...)`,
//! with delay coefficients calibrated to the paper's measured clocks:
//!
//! | anchor | paper value |
//! |---|---|
//! | FFIP 64x64, 8-bit, GX 1150 | 388 MHz (Table 1) |
//! | FFIP 64x64, 16-bit, GX 1150 | 346 MHz (Table 2) |
//! | FIP fmax | ~30 % below baseline (§6.1) |
//! | FFIP fmax | >= baseline's, at (F)FIP's DSP count (§6.1) |
//!
//! Model structure (all delays in ns):
//! * `t_mult(b)` — hard DSP multiplier, weak width dependence;
//! * `t_add(b)` — soft-logic carry chain, linear in width;
//! * PE paths (Fig. 1): baseline `mult + acc-add`; FIP
//!   `pre-add + mult + acc-add` **with doubled routing** (the
//!   unregistered pre-add network spans the systolic buffers of the
//!   neighboring PE — §4.2's non-local path); FFIP `mult + acc-add`
//!   (the g register absorbs the pre-add — the "free pipeline");
//! * routing pressure grows with DSP-column fill of the device;
//! * the Fig. 7 broadcast weight loader adds a fanout term that the
//!   Fig. 8 localized loader eliminates (§5.2);
//! * the memory tilers cap the clock at `B x f_tiler` unless banked
//!   (§5.1.1) — the B=1 ablation shows why banking exists.

use super::device::Device;
use super::resources;
use crate::algo::Algo;
use crate::arith::FixedSpec;
use crate::mxu::LoaderKind;

/// Tunable model coefficients (defaults = calibrated values).
#[derive(Debug, Clone, Copy)]
pub struct FreqParams {
    /// DSP multiplier delay: `m0 + m1 * bits` (ns).
    pub mult_base: f64,
    pub mult_per_bit: f64,
    /// Soft adder delay: `a0 + a1 * bits` (ns).
    pub add_base: f64,
    pub add_per_bit: f64,
    /// Routing delay at zero fill (ns) and its fill coefficient.
    pub route_base: f64,
    pub route_fill: f64,
    /// Extra routing multiplier for FIP's unregistered cross-PE path.
    pub fip_route_factor: f64,
    /// Broadcast-loader fanout delay per PE row (ns) — Fig. 7 penalty.
    pub broadcast_fanout_per_row: f64,
    /// Memory tiler standalone fmax (MHz) — §5.1.1; the effective cap is
    /// `banks x` this.
    pub tiler_fmax_mhz: f64,
}

impl Default for FreqParams {
    fn default() -> Self {
        FreqParams {
            mult_base: 1.05,
            mult_per_bit: 0.02,
            add_base: 0.30,
            add_per_bit: 0.011,
            route_base: 0.664,
            route_fill: 0.30,
            fip_route_factor: 2.0,
            broadcast_fanout_per_row: 0.004,
            tiler_fmax_mhz: 230.0,
        }
    }
}

impl FreqParams {
    pub fn t_mult(&self, bits: u32) -> f64 {
        self.mult_base + self.mult_per_bit * f64::from(bits)
    }

    pub fn t_add(&self, bits: u32) -> f64 {
        self.add_base + self.add_per_bit * f64::from(bits)
    }
}

/// Achievable MXU clock in MHz for the given architecture on `device`,
/// with `banks`-way layer-IO banking and the chosen weight loader.
#[allow(clippy::too_many_arguments)]
pub fn fmax_mhz_with(
    p: &FreqParams,
    algo: Algo,
    spec: FixedSpec,
    x: usize,
    y: usize,
    device: &Device,
    loader: LoaderKind,
    banks: usize,
) -> f64 {
    let w = spec.w;
    let d = spec.d();
    let acc = spec.acc_bits(x);

    // routing pressure from device fill
    let mults = resources::multiplier_count(algo, x, y);
    let fill =
        (mults as f64 / device.total_multipliers() as f64).min(1.0);
    let route = p.route_base * (1.0 + p.route_fill * fill);

    // register-to-register PE critical path (Fig. 1)
    let t_pe = match algo {
        Algo::Baseline => p.t_mult(w) + p.t_add(acc) + route,
        Algo::Fip => {
            p.t_add(w + d)
                + p.t_mult(w + d)
                + p.t_add(acc)
                + route * p.fip_route_factor
        }
        Algo::Ffip => p.t_mult(w + d) + p.t_add(acc) + route,
    };

    // Fig. 7 loader: enable fans out to every row element unbuffered
    let t_loader = match loader {
        LoaderKind::Broadcast => {
            p.broadcast_fanout_per_row * (y as f64)
        }
        LoaderKind::Localized => 0.0,
    };

    let f_pe = 1000.0 / (t_pe + t_loader);

    // §5.1.1: unbanked tilers cap the whole accelerator
    let f_mem = p.tiler_fmax_mhz * banks as f64;
    f_pe.min(f_mem)
}

/// Default-parameter fmax with the paper's configuration (Fig. 8 loader,
/// B = 2 banking).
pub fn fmax_mhz(
    algo: Algo,
    spec: FixedSpec,
    x: usize,
    y: usize,
    device: &Device,
) -> f64 {
    fmax_mhz_with(
        &FreqParams::default(),
        algo,
        spec,
        x,
        y,
        device,
        LoaderKind::Localized,
        2,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    const GX: Device = Device::arria10_gx1150();
    const SX: Device = Device::arria10_sx660();

    #[test]
    fn ffip_64_anchor_clocks() {
        let f8 = fmax_mhz(Algo::Ffip, FixedSpec::signed(8), 64, 64, &GX);
        assert!((f8 - 388.0).abs() / 388.0 < 0.01, "8-bit: {f8}");
        let f16 = fmax_mhz(Algo::Ffip, FixedSpec::signed(16), 64, 64, &GX);
        assert!((f16 - 346.0).abs() / 346.0 < 0.02, "16-bit: {f16}");
    }

    #[test]
    fn fip_30pct_below_baseline() {
        // §6.1: FIP clock ~30% below baseline; FFIP recovers it.
        let spec = FixedSpec::signed(8);
        let b = fmax_mhz(Algo::Baseline, spec, 56, 56, &SX);
        let f = fmax_mhz(Algo::Fip, spec, 56, 56, &SX);
        let ffip = fmax_mhz(Algo::Ffip, spec, 56, 56, &SX);
        let drop = 1.0 - f / b;
        assert!((0.25..=0.35).contains(&drop), "FIP drop = {drop}");
        assert!(ffip / f > 1.3, "FFIP/FIP = {}", ffip / f);
        assert!(ffip >= 0.97 * b, "FFIP {ffip} vs baseline {b}");
    }

    #[test]
    fn frequency_declines_with_array_size() {
        let spec = FixedSpec::signed(8);
        let f32_ = fmax_mhz(Algo::Ffip, spec, 32, 32, &SX);
        let f80 = fmax_mhz(Algo::Ffip, spec, 80, 80, &SX);
        assert!(f32_ > f80, "{f32_} vs {f80}");
        assert!(f80 > 300.0, "still serviceable at full fill: {f80}");
    }

    #[test]
    fn broadcast_loader_costs_frequency() {
        // §5.2's motivation for the Fig. 8 design
        let p = FreqParams::default();
        let spec = FixedSpec::signed(8);
        let f7 = fmax_mhz_with(
            &p, Algo::Ffip, spec, 64, 64, &GX, LoaderKind::Broadcast, 2,
        );
        let f8 = fmax_mhz_with(
            &p, Algo::Ffip, spec, 64, 64, &GX, LoaderKind::Localized, 2,
        );
        assert!(f8 > f7 * 1.05, "{f8} vs {f7}");
    }

    #[test]
    fn unbanked_memory_caps_the_clock() {
        // §5.1.1: B=1 caps at the tiler fmax (230 MHz), well below the
        // MXU's potential; B=2 removes the cap.
        let p = FreqParams::default();
        let spec = FixedSpec::signed(8);
        let f_b1 = fmax_mhz_with(
            &p, Algo::Ffip, spec, 64, 64, &GX, LoaderKind::Localized, 1,
        );
        let f_b2 = fmax_mhz_with(
            &p, Algo::Ffip, spec, 64, 64, &GX, LoaderKind::Localized, 2,
        );
        assert_eq!(f_b1, 230.0);
        assert!(f_b2 > 380.0);
    }

    #[test]
    fn wider_data_is_slower() {
        for algo in Algo::ALL {
            let f8 = fmax_mhz(algo, FixedSpec::signed(8), 32, 32, &GX);
            let f16 = fmax_mhz(algo, FixedSpec::signed(16), 32, 32, &GX);
            assert!(f8 > f16, "{algo:?}: {f8} vs {f16}");
        }
    }
}
