//! FPGA device, resource and frequency models (paper §6, Fig. 9).
//!
//! The paper's artifact is a SystemVerilog design compiled by Quartus; we
//! have no FPGA, so these models reproduce the *deterministic analyses*
//! behind the paper's numbers (the paper itself reports GX 1150 numbers
//! from a <1%-error estimation analysis, §6):
//!
//! * [`device`] — Arria 10 device inventories (ALMs, registers, M20Ks,
//!   DSPs) and the Intel DSP packing rule (two 18x19 multipliers per
//!   block);
//! * [`resources`] — utilization estimates built *bottom-up* from the PE
//!   register equations (Eqs. 17-19), physical PE counts (§4.1) and
//!   calibrated system overheads (anchors documented per constant);
//! * [`frequency`] — critical-path + routing-pressure fmax model
//!   calibrated to the paper's measured clocks (FFIP 64x64: 388 MHz at
//!   8-bit, 346 MHz at 16-bit; FIP ~30% below baseline).
//!
//! Every calibration anchor is listed in EXPERIMENTS.md with the paper
//! value it reproduces.

pub mod device;
pub mod frequency;
pub mod resources;

pub use device::{Device, DspArch};
pub use frequency::{fmax_mhz, fmax_mhz_with, FreqParams};
pub use resources::{
    estimate, max_instances, max_square_mxu, multiplier_count, Utilization,
};
