//! Bottom-up FPGA resource estimation (paper §6.1, Fig. 9, Tables 1-3).
//!
//! Structure: exact architectural counts (multipliers from §4.1 physical
//! dims + the Y Post-GEMM rescale multipliers; PE register bits from
//! Eqs. 17-19) plus *calibrated* soft-logic/system constants.  Every
//! calibrated constant is annotated with the paper anchor it reproduces:
//!
//! | anchor | paper value | where |
//! |---|---|---|
//! | FFIP 64x64, 8-bit registers | 311 K | Table 1 |
//! | FFIP 64x64, 16-bit registers | 530 K | Table 2 |
//! | FFIP 64x64, 8-bit ALMs | 118 K | Table 1 |
//! | FFIP 64x64, 16-bit ALMs | 199 K | Table 2 |
//! | FFIP 64x64, 8-bit M20Ks | 1782 | Table 1 |
//! | FFIP 64x64, 16-bit M20Ks | 2713 | Table 2 |
//! | FFIP 64x64 DSPs | 1072 | Tables 1-2 |
//! | FIP vs baseline ALM/register overhead | +15-20 % | §6.1 |
//!
//! On FPGAs the baseline MAC's accumulator and input registers live
//! *inside* the hard DSP block, so baseline soft-logic cost per MAC is
//! low; FIP/FFIP spend ALM logic and flip-flops on the pre-adders and g
//! registers instead — which is exactly the 15-20 % soft-logic overhead
//! the paper reports against the ~2x DSP reduction.

use super::device::Device;
use crate::algo::Algo;
use crate::arith::FixedSpec;
use crate::pe;

/// Estimated utilization of one accelerator instance.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Utilization {
    pub alms: u64,
    pub registers: u64,
    pub memories: u64,
    pub dsps: u64,
    pub multipliers: u64,
    /// true iff every resource fits the device
    pub fits: bool,
}

impl Utilization {
    /// Element-wise worst case of two estimates: the resources a device
    /// must reserve to host *either* configuration (the design-space
    /// tuner sizes a per-layer-reconfigurable deployment by the worst
    /// case over the algorithms its plan uses).  `fits` ANDs.
    pub fn component_max(a: Utilization, b: Utilization) -> Utilization {
        Utilization {
            alms: a.alms.max(b.alms),
            registers: a.registers.max(b.registers),
            memories: a.memories.max(b.memories),
            dsps: a.dsps.max(b.dsps),
            multipliers: a.multipliers.max(b.multipliers),
            fits: a.fits && b.fits,
        }
    }
}

/// How many independent copies of an accelerator instance with
/// utilization `u` the device can host — the replica axis of the
/// design-space search (each serving replica of a deployment maps to
/// one array instance).  Zero when even one copy does not fit.
pub fn max_instances(u: &Utilization, device: &Device) -> usize {
    if !u.fits {
        return 0;
    }
    let per = |have: u64, need: u64| {
        if need == 0 {
            usize::MAX
        } else {
            (have / need) as usize
        }
    };
    per(device.alms, u.alms)
        .min(per(device.registers, u.registers))
        .min(per(device.memories, u.memories))
        .min(per(device.dsps, u.dsps))
}

/// Total fixed-point multipliers: MXU array (§4.1) + Y Post-GEMM rescale
/// multipliers (§6) ; the zero-point adjuster's single multiplier packs
/// into the odd DSP half left by the Y rescalers.
pub fn multiplier_count(algo: Algo, x: usize, y: usize) -> u64 {
    (pe::mxu_multipliers(algo, x, y) + y) as u64
}

/// Soft-logic registers per PE (outside the DSP block).
fn soft_regs_per_pe(algo: Algo, spec: FixedSpec) -> f64 {
    let w = f64::from(spec.w);
    let d = f64::from(spec.d());
    match algo {
        // per MAC: a-path register + glue (acc + b input live in DSP)
        Algo::Baseline => w + 5.3,
        // two a regs (2w each lane pair = 4w) + control glue
        Algo::Fip => 4.0 * w + 16.0,
        // + two g registers' extra width and the enable chain
        Algo::Ffip => 4.0 * w + 2.0 * d + 2.0 + 16.0,
    }
}

/// Soft-logic ALMs per PE.
fn alms_per_pe(algo: Algo, spec: FixedSpec) -> f64 {
    let w = f64::from(spec.w);
    let d = f64::from(spec.d());
    match algo {
        Algo::Baseline => 0.4 * w + 3.7,
        // two (w+d)-bit pre-adders at ~0.75 ALM/bit + glue
        Algo::Fip => 1.5 * (w + d) + 8.0,
        Algo::Ffip => 1.5 * (w + d) + 10.0,
    }
}

/// System-level (non-PE) registers: datapath buses, triangular input
/// buffers, Post-GEMM, tilers, PCIe FIFOs.  Scales with datapath width
/// (x) and bitwidth.  Anchors: FFIP 64x64 totals 311 K / 530 K.
fn system_regs(spec: FixedSpec, x: usize) -> f64 {
    (46_240.0 + 19_055.0 * f64::from(spec.w)) * (x as f64 / 64.0)
}

/// System-level ALMs. Anchors: FFIP 64x64 totals 118 K / 199 K.
fn system_alms(spec: FixedSpec, x: usize) -> f64 {
    (13_080.0 + 7_005.0 * f64::from(spec.w)) * (x as f64 / 64.0)
}

/// M20K memories: banked layer-IO memory (dominant; §6.2.2 explains it is
/// deliberately generous so off-chip bandwidth is never the bottleneck)
/// plus the double-buffered weight tiles.  The layer-IO capacity is set
/// by feature-map sizes, not MXU width — Fig. 9 shows memories nearly
/// flat across MXU sizes.  Anchors: 1782 / 2706 + wbuf at 64x64.
fn memories(spec: FixedSpec, x: usize, y: usize) -> f64 {
    let w = f64::from(spec.w);
    let layer_io = 850.0 + 116.0 * w;
    // two b/y tile buffers of x*y values at w+1 bits, in 20Kb blocks
    let wbuf = (2.0 * (x * y) as f64 * (w + 1.0) / 20_480.0).ceil();
    layer_io + wbuf
}

/// Estimate utilization of an `algo` MXU of effective size `x` x `y` with
/// datapath `spec` hosted by the §5 system on `device`.
pub fn estimate(
    algo: Algo,
    spec: FixedSpec,
    x: usize,
    y: usize,
    device: &Device,
) -> Utilization {
    let mults = multiplier_count(algo, x, y);
    let dsps = device.dsps_for_mults(mults);
    let n_pe = pe::physical_dims(algo, x, y);
    let n_pe = (n_pe.0 * n_pe.1) as f64;
    let registers =
        (n_pe * soft_regs_per_pe(algo, spec) + system_regs(spec, x)) as u64;
    let alms =
        (n_pe * alms_per_pe(algo, spec) + system_alms(spec, x)) as u64;
    let memories = memories(spec, x, y) as u64;
    let fits = dsps <= device.dsps
        && alms <= device.alms
        && registers <= device.registers
        && memories <= device.memories;
    Utilization { alms, registers, memories, dsps, multipliers: mults, fits }
}

/// Largest square MXU (multiple of 8, as swept in Fig. 9) of each algo
/// kind that fits the device — §6.1's 56 -> 80 headline.
pub fn max_square_mxu(algo: Algo, spec: FixedSpec, device: &Device) -> usize {
    let mut best = 0;
    let mut s = 8;
    loop {
        let u = estimate(algo, spec, s, s, device);
        if !u.fits {
            break;
        }
        best = s;
        s += 8;
        if s > 512 {
            break;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    const GX: Device = Device::arria10_gx1150();
    const SX: Device = Device::arria10_sx660();

    #[test]
    fn ffip_64_anchors_8bit() {
        let u = estimate(Algo::Ffip, FixedSpec::signed(8), 64, 64, &GX);
        assert_eq!(u.dsps, 1072); // Table 1 exactly
        let within = |got: u64, paper: f64, tol: f64| {
            (got as f64 - paper).abs() / paper < tol
        };
        assert!(within(u.registers, 311_000.0, 0.03), "{}", u.registers);
        assert!(within(u.alms, 118_000.0, 0.03), "{}", u.alms);
        assert!(within(u.memories, 1782.0, 0.03), "{}", u.memories);
        assert!(u.fits);
    }

    #[test]
    fn ffip_64_anchors_16bit() {
        let u = estimate(Algo::Ffip, FixedSpec::signed(16), 64, 64, &GX);
        assert_eq!(u.dsps, 1072); // Table 2
        let within = |got: u64, paper: f64, tol: f64| {
            (got as f64 - paper).abs() / paper < tol
        };
        assert!(within(u.registers, 530_000.0, 0.03), "{}", u.registers);
        assert!(within(u.alms, 199_000.0, 0.03), "{}", u.alms);
        assert!(within(u.memories, 2713.0, 0.03), "{}", u.memories);
    }

    #[test]
    fn fip_soft_logic_overhead_15_to_20_pct() {
        // §6.1: "The FIP architecture uses up to 15-20% more ALMs and
        // registers than the baseline"
        for w in [8u32, 16] {
            let spec = FixedSpec::signed(w);
            let f = estimate(Algo::Fip, spec, 56, 56, &SX);
            let b = estimate(Algo::Baseline, spec, 56, 56, &SX);
            let alm_ratio = f.alms as f64 / b.alms as f64;
            let reg_ratio = f.registers as f64 / b.registers as f64;
            assert!(
                (1.10..=1.25).contains(&alm_ratio),
                "w={w} alm ratio {alm_ratio}"
            );
            assert!(
                (1.10..=1.25).contains(&reg_ratio),
                "w={w} reg ratio {reg_ratio}"
            );
        }
    }

    #[test]
    fn near_2x_dsp_reduction() {
        let spec = FixedSpec::signed(8);
        let b = estimate(Algo::Baseline, spec, 56, 56, &SX);
        let f = estimate(Algo::Ffip, spec, 56, 56, &SX);
        let ratio = b.dsps as f64 / f.dsps as f64;
        assert!((1.8..=2.05).contains(&ratio), "DSP ratio {ratio}");
    }

    #[test]
    fn max_mxu_56_to_80_headline() {
        // §6.1: largest baseline MXU on the SX 660 is 56x56; (F)FIP
        // reaches 80x80 — "an increase of over 2x in effective PEs".
        let spec = FixedSpec::signed(8);
        assert_eq!(max_square_mxu(Algo::Baseline, spec, &SX), 56);
        assert_eq!(max_square_mxu(Algo::Fip, spec, &SX), 80);
        assert_eq!(max_square_mxu(Algo::Ffip, spec, &SX), 80);
        let gain = (80.0f64 * 80.0) / (56.0 * 56.0);
        assert!(gain > 2.0);
    }

    #[test]
    fn instance_packing_is_memory_bound() {
        // §6.2.2: the layer-IO memory is deliberately generous, so even
        // a small array's instance is M20K-bound — one instance per
        // device despite plenty of spare DSPs (the tuner's replica axis
        // therefore scales out across devices, not within one).
        let spec = FixedSpec::signed(8);
        let u = estimate(Algo::Ffip, spec, 32, 32, &GX);
        assert!(u.fits);
        assert!(GX.dsps / u.dsps >= 5, "DSPs alone would host 5+");
        assert_eq!(max_instances(&u, &GX), 1, "M20Ks cap at one");
        // a non-fitting estimate hosts zero instances
        let big = estimate(Algo::Baseline, spec, 64, 64, &SX);
        assert!(!big.fits);
        assert_eq!(max_instances(&big, &SX), 0);
    }

    #[test]
    fn component_max_takes_worst_case_per_resource() {
        let spec = FixedSpec::signed(8);
        let b = estimate(Algo::Baseline, spec, 32, 32, &GX);
        let f = estimate(Algo::Ffip, spec, 32, 32, &GX);
        let m = Utilization::component_max(b, f);
        // baseline spends more DSPs, FFIP more soft logic
        assert_eq!(m.dsps, b.dsps.max(f.dsps));
        assert_eq!(m.alms, b.alms.max(f.alms));
        assert_eq!(m.registers, b.registers.max(f.registers));
        assert_eq!(m.memories, b.memories.max(f.memories));
        assert!(m.fits);
        // one non-fitting side poisons the fold
        let big = estimate(Algo::Baseline, spec, 64, 64, &SX);
        assert!(!Utilization::component_max(f, big).fits);
    }

    #[test]
    fn mixed_signedness_costs_more() {
        // §4.4: d = 2 widens pre-adders and multipliers
        let same = estimate(Algo::Ffip, FixedSpec::signed(8), 64, 64, &GX);
        let mixed = estimate(Algo::Ffip, FixedSpec::mixed(8), 64, 64, &GX);
        assert!(mixed.alms > same.alms);
        assert!(mixed.registers > same.registers);
    }
}
