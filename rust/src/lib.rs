// `portable_simd` opts the engine's inner loops into explicit
// `std::simd` lanes (nightly-only; the stable default is the always-on
// u64-packed SWAR path in engine/simd.rs).
#![cfg_attr(feature = "portable_simd", feature(portable_simd))]

//! # FFIP — Fast Inner-Product Algorithms and Architectures
//!
//! A full reproduction of Pogue & Nicolici, *"Fast Inner-Product Algorithms
//! and Architectures for Deep Neural Network Accelerators"* (IEEE TC 2023):
//! the FIP (Winograd 1968) and FFIP (free-pipeline) inner-product
//! algorithms, a cycle-level systolic-array accelerator simulator, the
//! TPUv1-like memory/system architecture that hosts it, FPGA resource and
//! frequency models calibrated to the paper's Arria 10 results, and the
//! benchmark harness that regenerates every figure and table in the
//! paper's evaluation.
//!
//! The crate is Layer 3 of a three-layer stack: JAX/Pallas kernels
//! (Layer 1) and the quantized model graph (Layer 2) are AOT-lowered to
//! HLO text at build time (`make artifacts`) and executed from
//! [`runtime`] via the PJRT C API — Python is never on the request path.
//!
//! ## Module map
//!
//! | module | contents | paper section |
//! |--------|----------|---------------|
//! | [`arith`] | fixed-point widths, saturation, the d-rule, accumulator guard | §4.1, §4.4 |
//! | [`algo`] | baseline / FIP / FFIP matmuls (generic over [`algo::Element`] storage) + op counts | §2.2, §3 |
//! | [`engine`] | persistent worker-pool GEMM engine (i8/i16/i64 jobs, SWAR/SIMD item kernels) | §5 |
//! | [`pe`] | PE datapath models, register cost (Eqs 17-19) | §4.2 |
//! | [`mxu`] | cycle-level systolic array simulator | §4.3, §5.2 |
//! | [`memory`] | tilers (Algorithm 1), conv→GEMM, banking | §5.1 |
//! | [`quant`] | quantization schemes, β folding, zero points | §3.3, §4.4 |
//! | [`nn`] | model graphs: AlexNet, VGG, ResNets, transformer | §6 |
//! | [`sched`] | tiling planner + deterministic timing model | §6 |
//! | [`fpga`] | Arria 10 device/resource/frequency models | §6.1 |
//! | [`tune`] | design-space autotuner: per-layer algorithm/tile + deployment geometry search over the analytical models | §6, Fig. 9 |
//! | [`metrics`] | GOPS, GOPS/mult, ops/mult/cycle (Eqs 21-31) | §6.2.1 |
//! | [`data`] | prior-work comparison constants (Tables 1-3) | §6.2.2 |
//! | [`report`] | paper-style table and figure renderers | §6 |
//! | [`runtime`] | PJRT loader/executor for the AOT artifacts | - |
//! | [`coordinator`] | model serving: `Model → CompiledModel → InferenceSession`, router, batcher, stats | §5, §6 |
//!
//! ## Serving in one breath
//!
//! Bind quantized weights to an [`nn::Graph`] with
//! [`coordinator::Model`], lower it with [`coordinator::compile`] (per
//! layer: conv→GEMM mapping, tile planning, offline FFIP `y` terms,
//! and the narrowest legal storage element — an int8 model compiles to
//! `i8` operands with `i16` y terms and `i32` accumulators, the §4.4
//! datapath widths), deploy the [`coordinator::CompiledModel`] on a
//! [`coordinator::Router`] sharing one persistent
//! [`engine::GemmPool`] — N session replicas per deployment with
//! pipeline-overlapped staging and admission-bounded backpressure
//! ([`coordinator::scheduler`]) — and send flat rows: responses carry
//! typed [`coordinator::Tensor`]s or per-request
//! [`coordinator::RequestError`]s (including `Overloaded` sheds), and
//! [`coordinator::ServeStats`] reports latency percentiles, engine
//! occupancy, the per-layer wall-time breakdown and the per-replica
//! split.  `examples/serve.rs` is the walkthrough.

pub mod algo;
pub mod arith;
pub mod bench_harness;
pub mod cli;
pub mod coordinator;
pub mod data;
pub mod engine;
pub mod examples_support;
pub mod fpga;
pub mod memory;
pub mod metrics;
pub mod mxu;
pub mod nn;
pub mod pe;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod sched;
pub mod tune;
pub mod util;

pub use algo::{AccElem, ElemKind, Element, Mat};
