//! `ffip` — the leader binary: experiment regeneration, simulation,
//! verification and the serving demo.  See `ffip help`.

use anyhow::{anyhow, bail, Context, Result};
use ffip::algo::{baseline_matmul, Algo, Mat};
use ffip::arith::FixedSpec;
use ffip::cli::{Args, USAGE};
use ffip::coordinator::{BatcherConfig, Coordinator};
use ffip::fpga::{self, Device};
use ffip::metrics::PerfMetrics;
use ffip::mxu::{MxuConfig, MxuSim};
use ffip::nn::models;
use ffip::report::experiments;
use ffip::runtime::{Input, Runtime};
use ffip::sched;
use ffip::util::Rng;
use std::path::Path;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match Args::parse(&argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{USAGE}");
            std::process::exit(2);
        }
    };
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn parse_algo(s: &str) -> Result<Algo> {
    match s.to_ascii_lowercase().as_str() {
        "baseline" => Ok(Algo::Baseline),
        "fip" => Ok(Algo::Fip),
        "ffip" => Ok(Algo::Ffip),
        other => bail!("unknown algo {other:?}"),
    }
}

fn parse_device(s: &str) -> Result<Device> {
    Device::by_name(s).ok_or_else(|| anyhow!("unknown device {s:?}"))
}

fn run(args: &Args) -> Result<()> {
    match args.cmd.as_str() {
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        "fig2" => {
            args.expect_only(&[]).map_err(|e| anyhow!(e))?;
            let (t, chart) = experiments::fig2();
            println!("{}", t.render());
            println!("{chart}");
            Ok(())
        }
        "fig9" => {
            args.expect_only(&["device", "wbits"]).map_err(|e| anyhow!(e))?;
            let device = parse_device(&args.get_or("device", "sx660"))?;
            let w = args.get_usize("wbits", 8).map_err(|e| anyhow!(e))? as u32;
            let (t, charts) = experiments::fig9(&device, w);
            println!("{}", t.render());
            for c in charts {
                println!("{c}");
            }
            Ok(())
        }
        "table" => {
            args.expect_only(&["id"]).map_err(|e| anyhow!(e))?;
            let id = args.get_usize("id", 1).map_err(|e| anyhow!(e))?;
            if !(1..=3).contains(&id) {
                bail!("--id must be 1, 2 or 3");
            }
            println!("{}", experiments::comparison_table(id).render());
            Ok(())
        }
        "simulate" => cmd_simulate(args),
        "workload" => cmd_workload(args),
        "verify" => cmd_verify(args),
        "runtime-check" => cmd_runtime_check(args),
        "serve" => cmd_serve(args),
        other => bail!("unknown command {other:?}\n\n{USAGE}"),
    }
}

fn cmd_simulate(args: &Args) -> Result<()> {
    args.expect_only(&["model", "algo", "mxu", "wbits", "device"])
        .map_err(|e| anyhow!(e))?;
    let model_name = args.get_or("model", "resnet-50");
    let graph = models::by_name(&model_name)
        .ok_or_else(|| anyhow!("unknown model {model_name:?}"))?;
    let algo = parse_algo(&args.get_or("algo", "ffip"))?;
    let size = args.get_usize("mxu", 64).map_err(|e| anyhow!(e))?;
    let w = args.get_usize("wbits", 8).map_err(|e| anyhow!(e))? as u32;
    let device = parse_device(&args.get_or("device", "gx1150"))?;
    let spec = FixedSpec::signed(w);

    let util = fpga::estimate(algo, spec, size, size, &device);
    let fmax = fpga::fmax_mhz(algo, spec, size, size, &device);
    let nt = sched::network_timing(&graph, algo, size, size, fmax);
    let m = PerfMetrics::from_measured(
        graph.ops_per_inference(),
        nt.inferences_per_second(),
        util.multipliers,
        fmax,
    );

    println!(
        "model {} on {} {}x{} ({}-bit) @ {}",
        graph.name,
        algo.name(),
        size,
        size,
        w,
        device.name
    );
    println!(
        "  resources: {} ALMs, {} regs, {} M20K, {} DSPs ({} mults){}",
        util.alms,
        util.registers,
        util.memories,
        util.dsps,
        util.multipliers,
        if util.fits { "" } else { "  ** DOES NOT FIT **" }
    );
    println!("  fmax: {fmax:.0} MHz");
    println!(
        "  inference: {:.3} ms  ({:.0} inf/s)",
        nt.seconds_per_inference() * 1e3,
        nt.inferences_per_second()
    );
    println!(
        "  throughput: {:.0} GOPS   {:.3} GOPS/mult   {:.3} ops/mult/cycle",
        m.gops, m.gops_per_multiplier, m.ops_per_multiplier_per_cycle
    );
    println!(
        "  utilization: {:.1}%",
        100.0 * sched::utilization(&nt.per_gemm)
    );
    Ok(())
}

/// Per-layer GEMM trace + timing breakdown for one model.
fn cmd_workload(args: &Args) -> Result<()> {
    args.expect_only(&["model", "algo", "mxu", "wbits"])
        .map_err(|e| anyhow!(e))?;
    let model_name = args.get_or("model", "resnet-50");
    let graph = models::by_name(&model_name)
        .ok_or_else(|| anyhow!("unknown model {model_name:?}"))?;
    let algo = parse_algo(&args.get_or("algo", "ffip"))?;
    let size = args.get_usize("mxu", 64).map_err(|e| anyhow!(e))?;
    let w = args.get_usize("wbits", 8).map_err(|e| anyhow!(e))? as u32;
    let device = Device::arria10_gx1150();
    let fmax =
        fpga::fmax_mhz(algo, FixedSpec::signed(w), size, size, &device);
    let nt = sched::network_timing(&graph, algo, size, size, fmax);

    let mut t = ffip::report::Table::new(
        &format!(
            "{} GEMM trace on {} {size}x{size} @ {fmax:.0} MHz \
             (cycles per image, streaming batch {})",
            graph.name,
            algo.name(),
            sched::STREAM_BATCH
        ),
        &["layer", "M", "K", "N", "MMACs", "cycles", "util %"],
    );
    for (name, gt) in &nt.per_gemm {
        t.row(vec![
            name.clone(),
            gt.gemm.m.to_string(),
            gt.gemm.k.to_string(),
            gt.gemm.n.to_string(),
            format!("{:.1}", gt.gemm.macs() as f64 / 1e6),
            gt.cycles.to_string(),
            format!("{:.1}", 100.0 * gt.utilization()),
        ]);
    }
    println!("{}", t.render());
    println!(
        "total: {} cycles/image, {:.3} ms, overall utilization {:.1}%",
        nt.total_cycles,
        nt.seconds_per_inference() * 1e3,
        100.0 * sched::utilization(&nt.per_gemm)
    );
    Ok(())
}

fn cmd_verify(args: &Args) -> Result<()> {
    args.expect_only(&["size"]).map_err(|e| anyhow!(e))?;
    let n = args.get_usize("size", 24).map_err(|e| anyhow!(e))?;
    let mut rng = Rng::new(0xFF19);
    let a = Mat::from_fn(n, n, |_, _| rng.fixed(8, true));
    let b = Mat::from_fn(n, n, |_, _| rng.fixed(8, true));
    let gold = baseline_matmul(&a, &b);
    for algo in Algo::ALL {
        let cfg = MxuConfig::new(algo, 8, 8, 16);
        let mut sim = MxuSim::new(cfg, FixedSpec::signed(8));
        let (c, stats) = sim.gemm(&a, &b);
        if c != gold {
            bail!("{} cycle simulation mismatch!", algo.name());
        }
        println!(
            "{:<8}: OK ({} tiles, {} cycles pipelined, {} MAC activations)",
            algo.name(),
            stats.tiles,
            stats.cycles_pipelined,
            stats.mac_ops
        );
    }
    println!("cycle-accurate simulation == Eq. (1) GEMM for all algorithms");
    Ok(())
}

fn cmd_runtime_check(args: &Args) -> Result<()> {
    args.expect_only(&["artifacts"]).map_err(|e| anyhow!(e))?;
    let dir = args.get_or("artifacts", "artifacts");
    let mut rt = Runtime::new(Path::new(&dir))?;
    println!("PJRT platform: {}", rt.platform());
    let names = rt.artifact_names();
    for name in &names {
        let exe = rt.load(name)?;
        // synthesize deterministic inputs per the manifest
        let mut rng = Rng::new(42);
        let inputs: Vec<Input> = exe
            .spec
            .inputs
            .iter()
            .map(|ts| match ts.dtype.as_str() {
                "float32" => Input::F32(
                    (0..ts.numel())
                        .map(|_| (rng.fixed(8, true) as f32) / 64.0)
                        .collect(),
                ),
                _ => Input::I32(
                    (0..ts.numel())
                        .map(|_| rng.fixed(7, true) as i32)
                        .collect(),
                ),
            })
            .collect();
        let out_dtype = &exe.spec.outputs[0].dtype;
        let n_out: usize = exe.spec.outputs[0].numel();
        let got_len = if out_dtype == "float32" {
            exe.run_f32(&inputs)?.len()
        } else {
            exe.run_i32(&inputs)?.len()
        };
        if got_len != n_out {
            bail!("{name}: output length {got_len} != manifest {n_out}");
        }
        println!("{name:<28} OK ({got_len} outputs)");
    }
    println!("all {} artifacts load + execute", names.len());
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    args.expect_only(&["requests", "artifacts"]).map_err(|e| anyhow!(e))?;
    let n = args.get_usize("requests", 64).map_err(|e| anyhow!(e))?;
    let dir = args.get_or("artifacts", "artifacts");
    // read dims from the manifest before spawning the worker
    let manifest = ffip::runtime::Manifest::load(Path::new(&dir))?;
    let spec = manifest.get("mini_cnn_b4")?;
    let batch = spec.inputs[0].shape[0];
    let row = spec.inputs[0].numel() / batch;
    let dir2 = dir.clone();
    let c = Coordinator::start(
        move || {
            ffip::examples_support::MiniCnnBackend::new(Path::new(&dir2))
        },
        BatcherConfig {
            batch,
            linger: std::time::Duration::from_millis(2),
        },
    )?;
    let mut rng = Rng::new(7);
    let rxs: Vec<_> = (0..n)
        .map(|_| {
            let input: Vec<i32> =
                (0..row).map(|_| rng.fixed(7, true) as i32).collect();
            c.submit(input)
        })
        .collect();
    for rx in rxs {
        rx.recv().context("response")?;
    }
    let s = c.shutdown();
    println!(
        "served {} requests in {} batches  (occupancy {:.0}%)",
        s.count(),
        s.batches,
        100.0 * s.occupancy()
    );
    println!(
        "latency: mean {:.2} ms  p50 {:.2} ms  p99 {:.2} ms  |  {:.0} req/s",
        s.mean_latency_us() / 1e3,
        s.latency_pct_us(50.0) as f64 / 1e3,
        s.latency_pct_us(99.0) as f64 / 1e3,
        s.throughput_rps()
    );
    Ok(())
}
