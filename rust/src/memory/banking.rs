//! B-way layer-IO memory partitioning (paper §5.1.1, Fig. 6).
//!
//! The memory tiler counters could not close timing at the MXU clock, so
//! the layer-IO memory is split into `B` (power of two) blocks along the
//! W dimension, each with its own tiler running at `1/B` of the main
//! clock; the main clock reads the blocks' outputs interleaved.
//!
//! The subtlety the paper calls out: when the `kw` digit advances far
//! enough, the W slice a block *starts* from belongs to the adjacent
//! block ("when kw = 3 then block 2 will be accessed first ... the
//! interleaving order ... is modified").  [`BankedMemory::schedule`]
//! implements that rotation and the per-bank rate check.

use crate::util::ceil_div;

/// A W-axis banked layer-IO memory: `banks` blocks, each holding the W
/// slices `s` with `(s / ws) % banks == block` (Fig. 6 layout, slices of
/// `ws` elements).
#[derive(Debug, Clone)]
pub struct BankedMemory {
    pub banks: usize,
    /// W-dimension slice width (the `Ws` stride of the layer).
    pub ws: usize,
}

/// Result of scheduling an address stream onto the banks.
#[derive(Debug, Clone, Default)]
pub struct BankSchedule {
    /// per-bank access streams (main-clock cycle, address)
    pub per_bank: Vec<Vec<(u64, i64)>>,
    /// true iff every bank sees at most one access per B main cycles —
    /// the condition for the 1/B-clock tilers to keep up.
    pub rate_ok: bool,
    /// number of main-clock cycles where the interleave order had to be
    /// rotated because `kw` crossed a block boundary (§5.1.1).
    pub rotations: u64,
}

impl BankedMemory {
    pub fn new(banks: usize, ws: usize) -> Self {
        assert!(banks.is_power_of_two(), "B must be a power of 2");
        assert!(ws >= 1);
        BankedMemory { banks, ws }
    }

    /// Which bank holds W coordinate `w`.
    pub fn bank_of_w(&self, w: usize) -> usize {
        (w / self.ws) % self.banks
    }

    /// Schedule a stream of per-main-cycle W coordinates (the innermost
    /// `w` digit of Algorithm 1, after the kw offset is applied) onto the
    /// banks, verifying the 1/B rate constraint.
    pub fn schedule(&self, w_coords: &[usize]) -> BankSchedule {
        let mut sched = BankSchedule {
            per_bank: vec![Vec::new(); self.banks],
            rate_ok: true,
            rotations: 0,
        };
        let mut last_cycle: Vec<Option<u64>> = vec![None; self.banks];
        let mut expect_bank = self.bank_of_w(*w_coords.first().unwrap_or(&0));
        for (cycle, &w) in w_coords.iter().enumerate() {
            let cycle = cycle as u64;
            let b = self.bank_of_w(w);
            if b != expect_bank {
                // kw crossed a slice boundary: rotate the interleave
                sched.rotations += 1;
                expect_bank = b;
            }
            if let Some(prev) = last_cycle[b] {
                if cycle - prev < self.banks as u64 {
                    sched.rate_ok = false;
                }
            }
            last_cycle[b] = Some(cycle);
            sched.per_bank[b].push((cycle, w as i64));
            expect_bank = (expect_bank + 1) % self.banks;
        }
        sched
    }

    /// The main-clock W visit order for one output row of Algorithm 1:
    /// `w = kw + ow * ws` for `ow` in `0..out_w` — consecutive visits
    /// alternate banks because the stride is one slice.
    pub fn row_visit_order(&self, kw: usize, out_w: usize) -> Vec<usize> {
        (0..out_w).map(|ow| kw + ow * self.ws).collect()
    }

    /// Frequency multiplier the banking buys: the tiler clock may run at
    /// `1/B` of the main clock (§5.1.1).
    pub fn tiler_clock_ratio(&self) -> f64 {
        1.0 / self.banks as f64
    }

    /// M20K overhead factor of splitting into B blocks (each block needs
    /// its own read port margin; small constant per bank).
    pub fn m20k_overhead(&self, total_words: usize) -> usize {
        // each bank rounds its capacity up to whole M20Ks
        self.banks * ceil_div(total_words / self.banks + 1, 1024)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bank_layout_fig6() {
        // Ws = 2, B = 2: slices [0,1]->bank0, [2,3]->bank1, [4,5]->bank0
        let m = BankedMemory::new(2, 2);
        assert_eq!(m.bank_of_w(0), 0);
        assert_eq!(m.bank_of_w(1), 0);
        assert_eq!(m.bank_of_w(2), 1);
        assert_eq!(m.bank_of_w(4), 0);
    }

    #[test]
    fn alternating_visits_satisfy_rate() {
        // kw in {1,2}: row visits alternate banks -> each bank accessed
        // every other main cycle -> 1/2-clock tilers keep up (§5.1.1)
        let m = BankedMemory::new(2, 2);
        for kw in [1usize, 2] {
            let visits = m.row_visit_order(kw, 8);
            let sched = m.schedule(&visits);
            assert!(sched.rate_ok, "kw={kw}");
        }
    }

    #[test]
    fn kw_crossing_rotates_interleave() {
        // the paper's example: kh=kw=3, Hs=Ws=2, B=2. When kw=3 the
        // first element comes from block 2 (bank 1) — interleave rotates
        // but the rate constraint still holds.
        let m = BankedMemory::new(2, 2);
        let visits = m.row_visit_order(3, 8);
        assert_eq!(m.bank_of_w(visits[0]), 1, "starts at the adjacent bank");
        let sched = m.schedule(&visits);
        assert!(sched.rate_ok);
    }

    #[test]
    fn same_bank_twice_in_a_row_violates_rate() {
        let m = BankedMemory::new(2, 2);
        // w=0 then w=1: same slice, same bank, back-to-back
        let sched = m.schedule(&[0, 1]);
        assert!(!sched.rate_ok);
    }

    #[test]
    fn four_way_banking() {
        let m = BankedMemory::new(4, 2);
        let visits = m.row_visit_order(0, 16);
        let sched = m.schedule(&visits);
        assert!(sched.rate_ok);
        assert_eq!(m.tiler_clock_ratio(), 0.25);
    }

    #[test]
    #[should_panic(expected = "power of 2")]
    fn non_power_of_two_rejected() {
        BankedMemory::new(3, 2);
    }
}
