//! External weight DRAM model (paper §5.1.1).
//!
//! The external memory stores *only* weights (layer inputs/outputs stay
//! on-chip); it is accessed in bursts so the control runs at a fraction
//! of the main clock, and the paper engineers the system so its bandwidth
//! is "rarely a bottleneck".  This model accounts bytes and cycles per
//! weight-tile fetch so the scheduler can verify that property per layer.

/// Burst-access DRAM channel for weights.
#[derive(Debug, Clone, Copy)]
pub struct WeightDram {
    /// Data bus bytes transferred per DRAM clock.
    pub bytes_per_clock: u64,
    /// DRAM clock as a fraction of the accelerator main clock.
    pub clock_ratio: f64,
    /// Fraction of peak bandwidth sustained (bursts amortize control).
    pub efficiency: f64,
}

impl WeightDram {
    /// DDR4-2400 x64 as on the Arria 10 SoC dev kit, relative to a
    /// ~400 MHz accelerator clock.
    pub fn arria10_devkit() -> Self {
        WeightDram {
            bytes_per_clock: 8 * 2, // 64-bit DDR
            clock_ratio: 1200.0 / 400.0,
            efficiency: 0.8,
        }
    }

    /// Sustained weight bytes deliverable per accelerator main-clock
    /// cycle.
    pub fn bytes_per_main_cycle(&self) -> f64 {
        self.bytes_per_clock as f64 * self.clock_ratio * self.efficiency
    }

    /// Main-clock cycles to fetch one weight tile of `x * y` elements at
    /// `w` bits each.
    pub fn tile_fetch_cycles(&self, x: usize, y: usize, w: u32) -> u64 {
        let bytes = (x * y) as f64 * f64::from(w) / 8.0;
        (bytes / self.bytes_per_main_cycle()).ceil() as u64
    }

    /// True if fetching the next weight tile hides under a compute pass
    /// of `compute_cycles` (double-buffered tile, §4.3).
    pub fn fetch_hidden(
        &self,
        x: usize,
        y: usize,
        w: u32,
        compute_cycles: u64,
    ) -> bool {
        self.tile_fetch_cycles(x, y, w) <= compute_cycles
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn devkit_bandwidth() {
        let d = WeightDram::arria10_devkit();
        // 16 B/clk * 3.0 * 0.8 = 38.4 B per main cycle
        assert!((d.bytes_per_main_cycle() - 38.4).abs() < 1e-9);
    }

    #[test]
    fn tile_fetch_cycles_64x64_8bit() {
        let d = WeightDram::arria10_devkit();
        // 4096 bytes / 38.4 = 106.7 -> 107 cycles
        assert_eq!(d.tile_fetch_cycles(64, 64, 8), 107);
    }

    #[test]
    fn fetch_hidden_under_typical_stream() {
        let d = WeightDram::arria10_devkit();
        // streaming M >= 128 rows per tile easily hides a 107-cycle fetch
        assert!(d.fetch_hidden(64, 64, 8, 128));
        // but a tiny M=1 pass (FC layer at batch 1) does not
        assert!(!d.fetch_hidden(64, 64, 8, 64));
    }

    #[test]
    fn wider_data_doubles_fetch() {
        let d = WeightDram::arria10_devkit();
        let c8 = d.tile_fetch_cycles(64, 64, 8);
        let c16 = d.tile_fetch_cycles(64, 64, 16);
        assert!(c16 >= 2 * c8 - 1);
    }
}
