//! Bounded FIFO with stall accounting — the simple interfaces through
//! which the Memory Unit, Arithmetic Unit and PCIe DMA talk to each
//! other (paper Fig. 4: "the tilers allow the Memory Unit and external
//! DRAM to be interfaced from the Arithmetic Unit using simple
//! first-in first-out interfaces").

use std::collections::VecDeque;

/// Fixed-capacity FIFO; pushes to a full FIFO and pops from an empty one
/// are counted as producer/consumer stalls (backpressure events).
#[derive(Debug, Clone)]
pub struct Fifo<T> {
    cap: usize,
    q: VecDeque<T>,
    pub push_stalls: u64,
    pub pop_stalls: u64,
    pub max_occupancy: usize,
}

impl<T> Fifo<T> {
    pub fn new(cap: usize) -> Self {
        assert!(cap >= 1);
        Fifo {
            cap,
            q: VecDeque::with_capacity(cap),
            push_stalls: 0,
            pop_stalls: 0,
            max_occupancy: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn len(&self) -> usize {
        self.q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }

    pub fn is_full(&self) -> bool {
        self.q.len() == self.cap
    }

    /// Try to push; on a full FIFO the value is returned and a stall is
    /// recorded (the producer must retry next cycle).
    pub fn push(&mut self, v: T) -> Result<(), T> {
        if self.is_full() {
            self.push_stalls += 1;
            return Err(v);
        }
        self.q.push_back(v);
        self.max_occupancy = self.max_occupancy.max(self.q.len());
        Ok(())
    }

    /// Try to pop; an empty FIFO records a consumer stall.
    pub fn pop(&mut self) -> Option<T> {
        match self.q.pop_front() {
            Some(v) => Some(v),
            None => {
                self.pop_stalls += 1;
                None
            }
        }
    }

    pub fn peek(&self) -> Option<&T> {
        self.q.front()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order() {
        let mut f = Fifo::new(4);
        for i in 0..4 {
            assert!(f.push(i).is_ok());
        }
        assert!(f.is_full());
        assert_eq!(f.pop(), Some(0));
        assert_eq!(f.pop(), Some(1));
        assert_eq!(f.len(), 2);
    }

    #[test]
    fn backpressure_accounting() {
        let mut f = Fifo::new(2);
        f.push(1).unwrap();
        f.push(2).unwrap();
        assert_eq!(f.push(3), Err(3));
        assert_eq!(f.push_stalls, 1);
        f.pop();
        f.pop();
        assert!(f.pop().is_none());
        assert_eq!(f.pop_stalls, 1);
        assert_eq!(f.max_occupancy, 2);
    }

    #[test]
    fn producer_consumer_rates() {
        // producer 1/cycle, consumer 1 per 2 cycles, cap 8: FIFO fills
        // then producer stalls every other cycle
        let mut f = Fifo::new(8);
        let mut produced = 0u64;
        for t in 0..100u64 {
            if f.push(t).is_ok() {
                produced += 1;
            }
            if t % 2 == 0 {
                f.pop();
            }
        }
        assert!(f.push_stalls > 30, "stalls={}", f.push_stalls);
        assert!(produced < 70);
    }
}
